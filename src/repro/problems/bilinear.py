"""Stochastic bilinear minimax game with box constraints (paper §4.1).

    min_{x ∈ C^n} max_{y ∈ C^n}  E_ξ [ xᵀA y + (b+ξ)ᵀx + (c+ξ)ᵀy ],
    C^n = [-1, 1]^n,  ξ ~ N(0, σ² I).

Dataset generation follows the paper: b, c ~ U[-1,1]^n; A = Ā / max(b_max,
c_max) with Ā a random symmetric matrix in [-1,1]^{n×n} (symmetric, NOT
semidefinite). Quality metrics:

* KKT residual Res(x,y)² = ‖x − Π(x − (Ay+b))‖² + ‖y − Π(y + (Aᵀx+c))‖²
  (the paper's §4.1 criterion),
* exact duality gap over the box (closed form via the l1 norm).
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from ..core import projections
from ..core.types import MinimaxProblem


@dataclasses.dataclass(frozen=True)
class BilinearGame:
    a: jax.Array          # (n, n) symmetric coupling matrix
    b: jax.Array          # (n,)
    c: jax.Array          # (n,)
    sigma: float          # oracle noise level
    problem: MinimaxProblem

    @property
    def n(self) -> int:
        return self.b.shape[0]

    def residual(self, z) -> jax.Array:
        """Paper's KKT residual Res(x, y)."""
        x, y = z
        rx = x - jnp.clip(x - (self.a @ y + self.b), -1.0, 1.0)
        ry = y - jnp.clip(y + (self.a.T @ x + self.c), -1.0, 1.0)
        return jnp.sqrt(jnp.sum(rx**2) + jnp.sum(ry**2))

    def duality_gap(self, z) -> jax.Array:
        """Exact DualGap(z̄) over the box: inner max/min are l1 norms."""
        x, y = z
        max_y = self.b @ x + jnp.sum(jnp.abs(self.a.T @ x + self.c))
        min_x = self.c @ y - jnp.sum(jnp.abs(self.a @ y + self.b))
        return max_y - min_x


def make_bilinear_game(
    rng, n: int = 10, sigma: float = 0.1, name: str = "bilinear"
) -> BilinearGame:
    r_a, r_b, r_c = jax.random.split(rng, 3)
    b = jax.random.uniform(r_b, (n,), minval=-1.0, maxval=1.0)
    c = jax.random.uniform(r_c, (n,), minval=-1.0, maxval=1.0)
    a_bar = jax.random.uniform(r_a, (n, n), minval=-1.0, maxval=1.0)
    a_bar = 0.5 * (a_bar + a_bar.T)
    a = a_bar / jnp.maximum(jnp.max(jnp.abs(b)), jnp.max(jnp.abs(c)))

    def init(rng):
        rx, ry = jax.random.split(rng)
        x0 = jax.random.uniform(rx, (n,), minval=-1.0, maxval=1.0)
        y0 = jax.random.uniform(ry, (n,), minval=-1.0, maxval=1.0)
        return (x0, y0)

    def sample(rng):
        return sigma * jax.random.normal(rng, (n,))

    def oracle(z, xi):
        # Descent form G = [∂x F, −∂y F]: the update z ← Π(z − ηG) descends
        # in x and ascends in y.
        x, y = z
        gx = a @ y + b + xi
        gy = a.T @ x + c + xi
        return (gx, -gy)

    def mean_oracle(z, _):
        x, y = z
        return (a @ y + b, -(a.T @ x + c))

    problem = MinimaxProblem(
        init=init,
        sample=sample,
        oracle=oracle,
        project=projections.box(-1.0, 1.0),
        mean_oracle=mean_oracle,
        name=name,
    )
    return BilinearGame(a=a, b=b, c=c, sigma=sigma, problem=problem)
