"""Strongly-convex-strongly-concave quadratic saddle problem.

    F(x, y) = ½ xᵀP x − ½ yᵀQ y + xᵀA y + bᵀx + cᵀy,   P, Q ≻ 0.

Smooth (Assumption 4 with L = ‖[P A; Aᵀ Q]‖) with a unique saddle point
available in closed form — the workhorse for exactness tests of every
optimizer in the zoo, and the "smooth case" (Theorem 2) validation problem.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from ..core import projections
from ..core.types import MinimaxProblem


@dataclasses.dataclass(frozen=True)
class QuadraticGame:
    p: jax.Array
    q: jax.Array
    a: jax.Array
    b: jax.Array
    c: jax.Array
    sigma: float
    problem: MinimaxProblem
    z_star: tuple[jax.Array, jax.Array]

    def distance_to_saddle(self, z) -> jax.Array:
        x, y = z
        xs, ys = self.z_star
        return jnp.sqrt(jnp.sum((x - xs) ** 2) + jnp.sum((y - ys) ** 2))


def make_quadratic_game(
    rng,
    n: int = 10,
    sigma: float = 0.1,
    mu: float = 1.0,
    radius: float = 10.0,
) -> QuadraticGame:
    r_p, r_q, r_a, r_b, r_c = jax.random.split(rng, 5)

    def psd(r):
        m = jax.random.normal(r, (n, n)) / jnp.sqrt(n)
        return m @ m.T + mu * jnp.eye(n)

    p, q = psd(r_p), psd(r_q)
    a = jax.random.normal(r_a, (n, n)) / jnp.sqrt(n)
    b = jax.random.normal(r_b, (n,))
    c = jax.random.normal(r_c, (n,))

    # Saddle: Px + Ay = −b ;  Aᵀx − Qy = −c.
    block = jnp.block([[p, a], [a.T, -q]])
    rhs = jnp.concatenate([-b, -c])
    sol = jnp.linalg.solve(block, rhs)
    z_star = (sol[:n], sol[n:])

    def init(rng):
        rx, ry = jax.random.split(rng)
        return (
            jax.random.normal(rx, (n,)),
            jax.random.normal(ry, (n,)),
        )

    def sample(rng):
        return sigma * jax.random.normal(rng, (2 * n,))

    def oracle(z, xi):
        x, y = z
        gx = p @ x + a @ y + b + xi[:n]
        gy = a.T @ x - q @ y + c + xi[n:]
        return (gx, -gy)

    def mean_oracle(z, _):
        x, y = z
        return (p @ x + a @ y + b, -(a.T @ x - q @ y + c))

    problem = MinimaxProblem(
        init=init,
        sample=sample,
        oracle=oracle,
        project=projections.l2_ball(radius),
        mean_oracle=mean_oracle,
        name="quadratic",
    )
    return QuadraticGame(
        p=p, q=q, a=a, b=b, c=c, sigma=sigma, problem=problem, z_star=z_star
    )
