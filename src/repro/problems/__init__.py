"""Minimax problem instances used by the paper's experiments (+ extras)."""
from .bilinear import BilinearGame, make_bilinear_game
from .quadratic import make_quadratic_game
from .robust import make_robust_logistic
from .wgan import make_wgan_problem

__all__ = [
    "BilinearGame",
    "make_bilinear_game",
    "make_quadratic_game",
    "make_robust_logistic",
    "make_wgan_problem",
]
