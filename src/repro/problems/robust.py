"""Distributionally-robust logistic regression — a real convex-concave
finite-sum minimax (beyond the paper's experiment set, same problem class):

    min_{w ∈ B(r)} max_{p ∈ Δ_n}  Σ_i p_i · ℓ_i(w) − (λ/2)‖p − 1/n‖²,

with ℓ_i the logistic loss of example i. Convex in w, strongly concave in p.
The stochastic oracle samples a minibatch of examples: unbiased for the w
block (importance-weighted by p) and for the p block (loss entries with
uniform inclusion correction).
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from ..core import projections
from ..core.types import MinimaxProblem


@dataclasses.dataclass(frozen=True)
class RobustLogistic:
    features: jax.Array   # (n, d)
    labels: jax.Array     # (n,) in {-1, +1}
    lam: float
    problem: MinimaxProblem

    def losses(self, w) -> jax.Array:
        margins = self.labels * (self.features @ w)
        return jnp.logaddexp(0.0, -margins)

    def objective(self, z) -> jax.Array:
        w, p = z
        n = self.labels.shape[0]
        return p @ self.losses(w) - 0.5 * self.lam * jnp.sum((p - 1.0 / n) ** 2)


def make_robust_logistic(
    rng,
    n: int = 128,
    d: int = 16,
    batch: int = 16,
    lam: float = 0.1,
    radius: float = 5.0,
) -> RobustLogistic:
    r_x, r_w, r_flip = jax.random.split(rng, 3)
    features = jax.random.normal(r_x, (n, d))
    w_true = jax.random.normal(r_w, (d,))
    labels = jnp.sign(features @ w_true)
    # 10% label noise makes the robust weighting non-trivial.
    flips = jax.random.bernoulli(r_flip, 0.1, (n,))
    labels = jnp.where(flips, -labels, labels)

    def init(rng):
        return (
            0.01 * jax.random.normal(rng, (d,)),
            jnp.full((n,), 1.0 / n),
        )

    def sample(rng):
        return jax.random.randint(rng, (batch,), 0, n)

    def loss_vec(w, idx):
        f, lab = features[idx], labels[idx]
        return jnp.logaddexp(0.0, -lab * (f @ w))

    def oracle(z, idx):
        w, p = z
        # w-block: ∇w Σ_i p_i ℓ_i(w), estimated on the minibatch with
        # inclusion correction n/batch.
        scale = n / idx.shape[0]

        def wloss(w_):
            return scale * jnp.sum(p[idx] * loss_vec(w_, idx))

        gw = jax.grad(wloss)(w)
        # p-block: ∂p = ℓ(w) − λ(p − 1/n); minibatch entries scattered.
        ell = jnp.zeros_like(p).at[idx].add(scale * loss_vec(w, idx))
        gp = ell - lam * (p - 1.0 / n)
        return (gw, -gp)

    def mean_oracle(z, _):
        w, p = z

        def wloss(w_):
            m = labels * (features @ w_)
            return p @ jnp.logaddexp(0.0, -m)

        gw = jax.grad(wloss)(w)
        m = labels * (features @ w)
        gp = jnp.logaddexp(0.0, -m) - lam * (p - 1.0 / n)
        return (gw, -gp)

    problem = MinimaxProblem(
        init=init,
        sample=sample,
        oracle=oracle,
        project=projections.product(
            projections.l2_ball(radius), projections.simplex()
        ),
        mean_oracle=mean_oracle,
        name="robust_logistic",
    )
    return RobustLogistic(features=features, labels=labels, lam=lam, problem=problem)
