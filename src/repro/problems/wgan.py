"""Wasserstein GAN with gradient penalty on synthetic data (paper §4.2).

The paper trains WGAN-GP (Eq. E44) on MNIST; offline we use an 8-mode 2-D
Gaussian mixture — the standard synthetic GAN benchmark — so the adversarial
dynamics (the part the optimizer paper cares about) are preserved while the
data pipeline stays deterministic. Generator and critic are MLPs.

    min_G max_D  E_x[D(x)] − E_z[D(G(z))] − λ·E_x̂[(‖∇_x̂ D(x̂)‖ − 1)²]

Quality proxies (no inception network offline):
* wasserstein estimate  E D(real) − E D(fake)  (→ 0 as G matches data),
* moment distance ‖μ_r − μ_g‖ + ‖Σ_r − Σ_g‖_F  (FID is exactly this in
  inception-feature space; we compute it in data space).
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

from ..core import projections
from ..core.types import MinimaxProblem

PyTree = Any


def _mlp_init(rng, sizes, scale=0.1):
    params = []
    for i, (fan_in, fan_out) in enumerate(zip(sizes[:-1], sizes[1:])):
        rng, r = jax.random.split(rng)
        w = scale * jax.random.normal(r, (fan_in, fan_out)) / jnp.sqrt(fan_in)
        params.append({"w": w, "b": jnp.zeros((fan_out,))})
    return params


def _mlp_apply(params, x):
    for i, layer in enumerate(params):
        x = x @ layer["w"] + layer["b"]
        if i + 1 < len(params):
            x = jax.nn.tanh(x)
    return x


def _mixture_sample(rng, batch, modes=8, radius=2.0, std=0.05):
    r_mode, r_noise = jax.random.split(rng)
    k = jax.random.randint(r_mode, (batch,), 0, modes)
    theta = 2.0 * jnp.pi * k.astype(jnp.float32) / modes
    centers = radius * jnp.stack([jnp.cos(theta), jnp.sin(theta)], axis=-1)
    return centers + std * jax.random.normal(r_noise, (batch, 2))


@dataclasses.dataclass(frozen=True)
class WGANProblem:
    problem: MinimaxProblem
    latent_dim: int
    data_dim: int
    batch: int
    gp_weight: float

    def generate(self, gen_params, rng, n: int) -> jax.Array:
        z = jax.random.normal(rng, (n, self.latent_dim))
        return _mlp_apply(gen_params, z)

    def wasserstein_estimate(self, z, rng, n: int = 512) -> jax.Array:
        gen, disc = z
        r1, r2 = jax.random.split(rng)
        real = _mixture_sample(r1, n)
        fake = self.generate(gen, r2, n)
        return jnp.mean(_mlp_apply(disc, real)) - jnp.mean(_mlp_apply(disc, fake))

    def moment_distance(self, z, rng, n: int = 1024) -> jax.Array:
        """FID-style moment matching distance in data space."""
        gen, _ = z
        r1, r2 = jax.random.split(rng)
        real = _mixture_sample(r1, n)
        fake = self.generate(gen, r2, n)
        mu_r, mu_g = jnp.mean(real, 0), jnp.mean(fake, 0)
        cov = lambda s, mu: (s - mu).T @ (s - mu) / s.shape[0]
        return jnp.sum((mu_r - mu_g) ** 2) + jnp.sum(
            (cov(real, mu_r) - cov(fake, mu_g)) ** 2
        )


def make_wgan_problem(
    rng,
    latent_dim: int = 8,
    data_dim: int = 2,
    hidden: int = 64,
    batch: int = 64,
    gp_weight: float = 1.0,
) -> WGANProblem:
    def init(rng):
        rg, rd = jax.random.split(rng)
        gen = _mlp_init(rg, (latent_dim, hidden, hidden, data_dim), scale=1.0)
        disc = _mlp_init(rd, (data_dim, hidden, hidden, 1), scale=1.0)
        return (gen, disc)

    def sample(rng):
        r_real, r_z, r_eps = jax.random.split(rng, 3)
        return {
            "real": _mixture_sample(r_real, batch),
            "z": jax.random.normal(r_z, (batch, latent_dim)),
            "eps": jax.random.uniform(r_eps, (batch, 1)),
        }

    def saddle_loss(z, xi):
        """f((θ_G, θ_D), ξ): min over θ_G, max over θ_D."""
        gen, disc = z
        fake = _mlp_apply(gen, xi["z"])
        d_real = _mlp_apply(disc, xi["real"])
        d_fake = _mlp_apply(disc, fake)
        # gradient penalty at interpolates
        x_hat = xi["eps"] * xi["real"] + (1.0 - xi["eps"]) * fake

        def d_scalar(v):
            return _mlp_apply(disc, v[None, :])[0, 0]

        grads = jax.vmap(jax.grad(d_scalar))(x_hat)
        gp = jnp.mean((jnp.sqrt(jnp.sum(grads**2, -1) + 1e-12) - 1.0) ** 2)
        return jnp.mean(d_real) - jnp.mean(d_fake) - gp_weight * gp

    def oracle(z, xi):
        gg, gd = jax.grad(lambda zz: saddle_loss(zz, xi))(z)
        return (gg, jax.tree.map(jnp.negative, gd))

    problem = MinimaxProblem(
        init=init,
        sample=sample,
        oracle=oracle,
        project=projections.identity(),
        name="wgan_gp",
    )
    return WGANProblem(
        problem=problem,
        latent_dim=latent_dim,
        data_dim=data_dim,
        batch=batch,
        gp_weight=gp_weight,
    )
