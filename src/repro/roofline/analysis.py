"""Roofline-term extraction from compiled dry-run artifacts.

Three terms per (arch × shape × mesh), in seconds (TPU v5e constants):

    compute    = HLO_FLOPs        / peak_FLOP/s          (197 Tbf16/s·chip)
    memory     = HLO_bytes        / HBM_bw               (819 GB/s·chip)
    collective = collective_bytes / link_bw              (~50 GB/s/link ICI)

``cost_analysis()`` on a GSPMD-partitioned module reports *per-device*
FLOPs/bytes (the module is the per-device program), so no further division
by chip count is applied. collective_bytes is not in cost_analysis — we
parse the post-partitioning HLO and sum the result-shape bytes of every
all-reduce / all-gather / reduce-scatter / all-to-all / collective-permute.
"""
from __future__ import annotations

import re

import numpy as np

PEAK_FLOPS = 197e12        # bf16 FLOP/s per v5e chip
HBM_BW = 819e9             # bytes/s per chip
ICI_BW = 50e9              # bytes/s per link

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "f8e4m3fn": 1, "f8e5m2": 1,
}

_COLLECTIVES = (
    "all-reduce", "all-gather", "reduce-scatter", "all-to-all",
    "collective-permute",
)

# e.g.:  %all-gather.7 = bf16[16,4096]{1,0} all-gather(bf16[1,4096]{1,0} %x)
_INSTR_RE = re.compile(
    r"=\s*(?:\()?([a-z0-9]+)\[([\d,]*)\][^=]*?\s"
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start)?\("
)


def _shape_bytes(dtype: str, dims: str) -> int:
    if dtype not in _DTYPE_BYTES:
        return 0
    n = 1
    if dims:
        for d in dims.split(","):
            n *= int(d)
    return n * _DTYPE_BYTES[dtype]


def collective_stats(hlo_text: str) -> dict:
    """Sum result-shape bytes + counts of every collective in the HLO."""
    bytes_by_kind: dict[str, int] = {}
    count_by_kind: dict[str, int] = {}
    for line in hlo_text.splitlines():
        # cheap pre-filter
        if "all-" not in line and "reduce-scatter" not in line and \
                "collective-permute" not in line:
            continue
        if "-done(" in line:  # async pair: count the -start only
            continue
        m = _INSTR_RE.search(line)
        if not m:
            continue
        dtype, dims, kind = m.groups()
        b = _shape_bytes(dtype, dims)
        bytes_by_kind[kind] = bytes_by_kind.get(kind, 0) + b
        count_by_kind[kind] = count_by_kind.get(kind, 0) + 1
    return {
        "bytes_by_kind": bytes_by_kind,
        "count_by_kind": count_by_kind,
        "total_bytes": int(sum(bytes_by_kind.values())),
    }


def _cost_value(cost, key, default=0.0):
    try:
        return float(cost.get(key, default))
    except AttributeError:
        return default


def analyze_compiled(lowered, compiled, mesh) -> dict:
    from .hlo_parse import collective_stats_v2

    cost = compiled.cost_analysis()
    if isinstance(cost, (list, tuple)):
        cost = cost[0]
    mem = compiled.memory_analysis()
    hlo = compiled.as_text()
    coll = collective_stats_v2(hlo, mesh)

    flops = _cost_value(cost, "flops")
    hbm_bytes = _cost_value(cost, "bytes accessed")
    bytes_per_device = float(
        getattr(mem, "argument_size_in_bytes", 0)
        + getattr(mem, "output_size_in_bytes", 0)
        + getattr(mem, "temp_size_in_bytes", 0)
        - getattr(mem, "alias_size_in_bytes", 0)
    )

    t_compute = flops / PEAK_FLOPS
    t_memory = hbm_bytes / HBM_BW
    t_collective = coll["total_bytes"] / ICI_BW
    terms = {
        "compute": t_compute, "memory": t_memory, "collective": t_collective
    }
    return {
        "flops": flops,
        "hbm_bytes": hbm_bytes,
        "collective_bytes": coll["total_bytes"],
        "collectives": coll["count_by_kind"],
        "collective_bytes_by_kind": coll["bytes_by_kind"],
        "collective_bytes_by_axis": coll.get("bytes_by_axis", {}),
        "bytes_per_device": bytes_per_device,
        "t_compute_s": t_compute,
        "t_memory_s": t_memory,
        "t_collective_s": t_collective,
        "bottleneck": max(terms, key=terms.get),
        "num_devices": int(mesh.size),
    }


def model_flops(n_params_active: float, tokens: float) -> float:
    """6·N·D rule of thumb (per forward+backward over D tokens)."""
    return 6.0 * n_params_active * tokens
