"""Roofline-term extraction from compiled artifacts."""
from .analysis import analyze_compiled, collective_stats, model_flops
