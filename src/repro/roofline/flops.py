"""Analytic FLOP / parameter / HBM-byte accounting per architecture.

XLA's cost_analysis counts while-loop (scanned-layer) bodies once, and full
unrolling does not compile within budget for the ≥27B configs — so the
roofline compute term uses this exact matmul-level estimator (the standard
MaxText-style accounting), cross-validated against unrolled HLO counts on
the small architectures (see EXPERIMENTS.md §Roofline/validation).

Conventions: a (m×k)·(k×n) matmul is 2·m·k·n FLOPs; backward = 2× forward;
rematerialized training forward is recomputed once inside backward, so a
train step costs (1 + 1·remat + 2) × forward; an extragradient local step
makes TWO gradient calls.
"""
from __future__ import annotations

import dataclasses

from ..configs.base import ArchConfig


@dataclasses.dataclass(frozen=True)
class FlopsBreakdown:
    forward: float            # per-sample forward FLOPs
    params: float             # total parameter count
    params_active: float      # active per token (MoE: top-k experts only)

    def train_step(self, remat: bool = True) -> float:
        """fwd + bwd (+ remat re-forward) for ONE gradient call."""
        return self.forward * (4.0 if remat else 3.0)

    def eg_local_step(self, remat: bool = True) -> float:
        return 2.0 * self.train_step(remat)


def _attn_flops(cfg: ArchConfig, s: int, window: int | None,
                kv_len: int | None = None) -> float:
    """Per-sample attention-layer FLOPs for query length s."""
    d, h, kh, dh = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.head_dim_
    proj = 2.0 * s * d * dh * (2 * h + 2 * kh)     # q,k,v,o projections
    if kv_len is None:
        # causal self-attention: average context s/2, or ≈window when it
        # clips (small overcount for the first `window` positions)
        avg = window if (window and window < s) else s / 2.0
    else:
        avg = kv_len
    qk_av = 2.0 * 2.0 * s * avg * h * dh           # logits + prob·V
    return proj + qk_av


def _mlp_flops(cfg: ArchConfig, s: int) -> float:
    gated = cfg.activation in ("silu", "gelu")
    return 2.0 * s * cfg.d_model * cfg.d_ff * (3 if gated else 2)


def _moe_flops(cfg: ArchConfig, s: int) -> float:
    router = 2.0 * s * cfg.d_model * cfg.num_experts
    # capacity-padded expert compute: E·cap tokens, cap from capacity_factor
    eff_tokens = s * cfg.experts_per_token * cfg.capacity_factor
    expert = 2.0 * eff_tokens * cfg.d_model * cfg.d_ff * 3
    return router + expert


def _ssm_flops(cfg: ArchConfig, s: int) -> float:
    d, di, n, h = cfg.d_model, cfg.d_inner, cfg.ssm_state, cfg.ssm_heads
    p, q = cfg.ssm_head_dim, cfg.ssm_chunk
    proj = 2.0 * s * d * (2 * di + 2 * n + h) + 2.0 * s * di * d
    conv = 2.0 * s * (di + 2 * n) * cfg.ssm_conv_width
    # SSD per chunk: scores Q²N + y_diag Q²HP + states/y_off 2·QHPN
    per_chunk = 2.0 * (q * q * n + q * q * h * p + 2 * q * h * p * n)
    ssd = (s / q) * per_chunk
    return proj + conv + ssd


def _rglru_flops(cfg: ArchConfig, s: int) -> float:
    d, dr = cfg.d_model, cfg.d_rnn
    proj = 2.0 * s * d * 2 * dr + 2.0 * s * dr * d
    gates = 2.0 * s * dr * dr * 2
    return proj + gates


def _layer_params(cfg: ArchConfig, kind: dict) -> tuple[float, float]:
    """(total, active-per-token) params of one layer."""
    d, h, kh, dh = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.head_dim_
    if kind["kind"] == "attn":
        mix = d * dh * (2 * h + 2 * kh)
    elif kind["kind"] == "ssm":
        di, n = cfg.d_inner, cfg.ssm_state
        mix = d * (2 * di + 2 * n + cfg.ssm_heads) + di * d
    else:
        dr = cfg.d_rnn
        mix = d * 2 * dr + dr * d + 2 * dr * dr
    if kind.get("cross_attn"):
        mix += d * dh * (2 * h + 2 * kh)
    gated = 3 if cfg.activation in ("silu", "gelu") else 2
    if kind.get("moe"):
        total_mlp = cfg.num_experts * d * cfg.d_ff * 3 + d * cfg.num_experts
        active_mlp = cfg.experts_per_token * d * cfg.d_ff * 3
    elif cfg.d_ff > 0:
        total_mlp = active_mlp = gated * d * cfg.d_ff
    else:
        total_mlp = active_mlp = 0
    return mix + total_mlp, mix + active_mlp


def estimate(cfg: ArchConfig, seq: int, *, kv_len: int | None = None,
             decode: bool = False) -> FlopsBreakdown:
    """Per-sample forward FLOPs + parameter counts.

    ``decode=True``: seq is ignored for queries (1 token) and ``kv_len``
    gives the attention context length.
    """
    s = 1 if decode else seq
    fwd = 0.0
    params_total = cfg.vocab_size * cfg.d_model
    params_active = params_total
    for kind in cfg.layer_kinds():
        pt, pa = _layer_params(cfg, kind)
        params_total += pt
        params_active += pa
        if kind["kind"] == "attn":
            if decode:
                ctx = min(kind["window"] or kv_len, kv_len)
                fwd += _attn_flops(cfg, 1, None, kv_len=ctx)
            else:
                fwd += _attn_flops(cfg, s, kind["window"])
        elif kind["kind"] == "ssm":
            fwd += _ssm_flops(cfg, s)
        else:
            fwd += _rglru_flops(cfg, s)
        if kind.get("cross_attn") and cfg.encoder_seq:
            fwd += _attn_flops(cfg, s, None, kv_len=cfg.encoder_seq)
        if kind.get("moe"):
            fwd += _moe_flops(cfg, s)
        elif cfg.d_ff > 0:
            fwd += _mlp_flops(cfg, s)
    # encoder (whisper): full non-causal stack over encoder_seq
    if cfg.is_encoder_decoder:
        se = cfg.encoder_seq
        enc = cfg.encoder_layers * (
            _attn_flops(cfg, se, None, kv_len=se) + _mlp_flops(cfg, se)
        )
        fwd += enc
        params_total += cfg.encoder_layers * (
            cfg.d_model * cfg.head_dim_ * (2 * cfg.num_heads + 2 * cfg.num_kv_heads)
            + 3 * cfg.d_model * cfg.d_ff
        )
    # lm head
    fwd += 2.0 * s * cfg.d_model * cfg.vocab_size
    if not cfg.tie_embeddings:
        params_total += cfg.d_model * cfg.vocab_size
        params_active += cfg.d_model * cfg.vocab_size
    return FlopsBreakdown(
        forward=fwd, params=params_total, params_active=params_active
    )
