"""Trip-count- and mesh-axis-aware collective accounting from HLO text.

XLA's ``cost_analysis`` counts a ``while`` body once, but a scanned layer
stack executes it ``known_trip_count`` times; and for LocalAdaSEG the key
question is *which mesh axis* each collective crosses (worker-sync traffic
is amortized 1/K, tensor-parallel traffic is not). This module parses the
post-partitioning HLO:

1. splits it into named computations,
2. reads every ``while`` instruction's body/condition and
   ``known_trip_count`` backend config,
3. propagates execution multipliers from ENTRY through (possibly nested)
   while bodies,
4. decodes ``replica_groups`` (explicit ``{{0,1},{2,3}}``, iota
   ``[G,S]<=[N]`` and transposed-iota ``[G,S]<=[a,b]T(p)`` forms) and maps
   each collective onto the mesh axes its groups span.
"""
from __future__ import annotations

import re

import numpy as np

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "f8e4m3fn": 1, "f8e5m2": 1,
}

_COLL_RE = re.compile(
    r"=\s*(?P<result>[^=]*?)\s"
    r"(?P<kind>all-reduce|all-gather|reduce-scatter|all-to-all|"
    r"collective-permute)(?P<start>-start)?\("
)
_TYPE_RE = re.compile(r"([a-z0-9]+)\[([\d,]*)\]")
_WHILE_RE = re.compile(
    r"while\(.*?condition=(%[\w\.\-]+), body=(%[\w\.\-]+)"
)
_TRIP_RE = re.compile(r'"known_trip_count":\{"n":"(\d+)"\}')
_GROUPS_EXPL_RE = re.compile(r"replica_groups=\{\{([\d,{} ]*)\}\}")
_GROUPS_IOTA_RE = re.compile(
    r"replica_groups=\[(\d+),(\d+)\]<=\[([\d,]+)\](?:T\(([\d,]+)\))?"
)
_PAIRS_RE = re.compile(r"source_target_pairs=\{([\d,{} ]*)\}")


def _shape_bytes(dtype: str, dims: str) -> int:
    if dtype not in _DTYPE_BYTES:
        return 0
    n = 1
    if dims:
        for d in dims.split(","):
            n *= int(d)
    return n * _DTYPE_BYTES[dtype]


def split_computations(hlo: str) -> dict[str, list[str]]:
    """computation name -> its instruction lines. ENTRY is named 'ENTRY'."""
    comps: dict[str, list[str]] = {}
    cur = None
    for line in hlo.splitlines():
        stripped = line.strip()
        if not line.startswith(" ") and stripped.endswith("{"):
            m = re.match(r"(ENTRY\s+)?(%[\w\.\-]+)", stripped)
            if m:
                cur = "ENTRY" if m.group(1) else m.group(2)
                comps[cur] = []
                continue
        if stripped == "}":
            cur = None
            continue
        if cur is not None:
            comps[cur].append(stripped)
    return comps


def while_multipliers(comps: dict[str, list[str]]) -> dict[str, int]:
    """Execution count per computation, via whiles reachable from ENTRY."""
    # computation -> [(child_comp, trip)] for its while instructions
    edges: dict[str, list[tuple[str, int]]] = {}
    for name, lines in comps.items():
        for line in lines:
            m = _WHILE_RE.search(line)
            if not m:
                continue
            cond, body = m.groups()
            t = _TRIP_RE.search(line)
            trip = int(t.group(1)) if t else 1
            edges.setdefault(name, []).append((body, trip))
            edges.setdefault(name, []).append((cond, trip + 1))
    mult: dict[str, int] = {k: 0 for k in comps}
    if "ENTRY" in mult:
        mult["ENTRY"] = 1
    # propagate (computations form a DAG of calls; iterate to fixpoint)
    for _ in range(len(comps)):
        changed = False
        for parent, children in edges.items():
            for child, trip in children:
                want = mult.get(parent, 0) * trip
                if child in mult and want > mult[child]:
                    mult[child] = want
                    changed = True
        if not changed:
            break
    # non-while computations (fusions, reducers) keep their parent's count
    # implicitly — collectives never appear inside fusions, so computations
    # never reached through whiles score max(1, ·) when scanning ENTRY-level.
    return mult


def _decode_groups(line: str) -> list[list[int]] | None:
    m = _GROUPS_IOTA_RE.search(line)
    if m:
        g, s, dims, perm = m.groups()
        dims = [int(d) for d in dims.split(",")]
        arr = np.arange(int(np.prod(dims))).reshape(dims)
        if perm:
            arr = arr.transpose([int(p) for p in perm.split(",")])
        return arr.reshape(int(g), int(s)).tolist()
    m = _GROUPS_EXPL_RE.search(line)
    if m:
        return [
            [int(x) for x in grp.split(",") if x.strip()]
            for grp in m.group(1).split("},{")
        ]
    m = _PAIRS_RE.search(line)
    if m:  # collective-permute: treat each pair as a group
        flat = [int(x) for x in re.findall(r"\d+", m.group(1))]
        return [flat[i : i + 2] for i in range(0, len(flat), 2)]
    return None


def classify_axes(groups, mesh) -> str:
    """Which mesh axes do the groups span? Returns e.g. 'model', 'data',
    'pod,data', or 'unknown'."""
    if not groups:
        return "unknown"
    shape = tuple(mesh.shape[a] for a in mesh.axis_names)
    id_to_coord = {}
    for idx, dev in np.ndenumerate(mesh.devices):
        id_to_coord[dev.id] = idx
    varying: set[str] = set()
    for grp in groups:
        if len(grp) < 2:
            continue
        coords = [id_to_coord.get(d) for d in grp]
        if any(c is None for c in coords):
            return "unknown"
        base = coords[0]
        for c in coords[1:]:
            for ax_i, (a, b) in enumerate(zip(base, c)):
                if a != b:
                    varying.add(mesh.axis_names[ax_i])
    return ",".join(
        a for a in mesh.axis_names if a in varying
    ) or "self"


def collective_stats_v2(hlo: str, mesh=None) -> dict:
    comps = split_computations(hlo)
    mult = while_multipliers(comps)
    bytes_by_kind: dict[str, int] = {}
    count_by_kind: dict[str, int] = {}
    bytes_by_axis: dict[str, int] = {}
    for name, lines in comps.items():
        m = mult.get(name, 0)
        if name != "ENTRY" and m == 0:
            # Not reached through a while. Collectives normally live in
            # ENTRY or while bodies; a stray one (e.g. inside a called
            # conditional branch) is counted once.
            m = 1 if any(_COLL_RE.search(ln) for ln in lines) else 0
        if m == 0:
            continue
        for line in lines:
            cm = _COLL_RE.search(line)
            if not cm:
                continue
            if "-done(" in line:
                continue
            kind = cm.group("kind")
            # result may be a tuple type — XLA combines many all-reduces
            # into one tuple-shaped op; sum every element's bytes.
            b1 = sum(
                _shape_bytes(d, s) for d, s in _TYPE_RE.findall(
                    cm.group("result")
                )
            )
            groups = _decode_groups(line)
            if kind == "reduce-scatter" and groups:
                # result is the scattered shard — scale to the full operand
                b1 *= max(len(g) for g in groups)
            b = b1 * m
            bytes_by_kind[kind] = bytes_by_kind.get(kind, 0) + b
            count_by_kind[kind] = count_by_kind.get(kind, 0) + m
            if mesh is not None:
                axis = classify_axes(groups, mesh)
                bytes_by_axis[axis] = bytes_by_axis.get(axis, 0) + b
    return {
        "bytes_by_kind": bytes_by_kind,
        "count_by_kind": count_by_kind,
        "bytes_by_axis": bytes_by_axis,
        "total_bytes": int(sum(bytes_by_kind.values())),
    }
