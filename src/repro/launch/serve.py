"""Serving: single-token decode steps against a sharded KV/SSM cache.

Decode shapes (decode_32k, long_500k) lower ``serve_step`` — ONE new token
with a cache covering the full context. There is no worker axis in serving:
one model copy, tensor-parallel over ``model`` and weight-sharded over
``data`` (FSDP-style — needed for the ≥27B configs to fit HBM), with the
request batch sharded over ``data``.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P

from ..configs.base import ArchConfig
from ..models import cache_specs, decode_step, init_cache
from ..sharding.specs import build_param_shardings, sanitize_spec, _axis_size


@dataclasses.dataclass(frozen=True)
class ServePlan:
    cfg: ArchConfig
    batch: int
    context_len: int           # cache capacity (= shape's seq_len)

    def needs_frontend(self) -> bool:
        return bool(self.cfg.encoder_seq)


def make_serve_step(plan: ServePlan):
    cfg = plan.cfg

    def serve_step(params, cache, token, pos, enc_states=None):
        logits, new_cache = decode_step(
            params, cfg, token, pos, cache, enc_states=enc_states
        )
        # greedy next token — keeps sampling out of the roofline path
        next_tok = jnp.argmax(logits[:, -1, :], axis=-1).astype(jnp.int32)
        return next_tok[:, None], new_cache

    return serve_step


def make_prefill_step(plan: ServePlan, *, head_last_only: bool = True):
    """Inference prefill: full-sequence forward producing the first decoded
    token (the cache write is a pure scatter of the K/V activations —
    excluded here so the roofline isolates the compute-bound part).

    ``head_last_only=True`` (default, §Perf): the LM head runs on the final
    position only — the (B, S, V) logits tensor otherwise dominates prefill
    HBM/collective traffic (measured 25× on qwen2-0.5b × prefill_32k).
    ``False`` is kept as the naive baseline for the hillclimb log.
    """
    from ..models import forward
    from ..models.transformer import encode

    cfg = plan.cfg

    def prefill(params, tokens, frontend=None):
        enc = None
        if cfg.is_encoder_decoder:
            enc = encode(params, cfg, frontend)
        elif cfg.cross_attn_every:
            enc = frontend
        logits, _ = forward(params, cfg, tokens, enc_states=enc,
                            head_last_only=head_last_only)
        return jnp.argmax(logits[:, -1, :], axis=-1).astype(jnp.int32)

    return prefill


def _repair_model_axis(spec: P, shape, mesh) -> P:
    """Place 'model' on the last divisible dim if its intended dim was
    dropped by sanitation (e.g. kv_heads=8 on a 16-way model axis → shard
    head_dim instead)."""
    spec = sanitize_spec(spec, shape, mesh)
    if any(
        (e == "model" or (isinstance(e, tuple) and "model" in e)) for e in spec
    ):
        return spec
    size = _axis_size(mesh, "model")
    entries = list(spec) + [None] * (len(shape) - len(spec))
    for i in range(len(shape) - 1, 0, -1):  # never the batch dim
        if entries[i] is None and shape[i] % size == 0 and shape[i] >= size:
            entries[i] = "model"
            return P(*entries)
    return P(*entries)


def abstract_cache(plan: ServePlan, dtype=None) -> list:
    cfg = plan.cfg
    dtype = dtype or jnp.dtype(cfg.compute_dtype)
    return jax.eval_shape(
        lambda: init_cache(cfg, plan.batch, plan.context_len, dtype)
    )


def make_serve_shardings(plan: ServePlan, mesh):
    """(param_sh, cache_sh, token_sh, pos_sh, frontend_sh | None)."""
    from .train import _spec_tree

    params_abs, specs = _spec_tree(plan.cfg)
    param_sh = build_param_shardings(
        params_abs, specs, mesh, worker_axes=(), fsdp=True
    )
    cache_abs = abstract_cache(plan)
    cspecs = cache_specs(plan.cfg, worker_axes=())

    def _cache_sharding(leaf, sp):
        sp = _repair_model_axis(sp, leaf.shape, mesh)
        # long-context single-request decode: batch=1 is unshardable on
        # 'data' — shard the cache's slot axis instead (KV slots / conv
        # window), keeping per-device cache O(S/data).
        has_data = any(
            e == "data" or (isinstance(e, tuple) and "data" in e) for e in sp
        )
        if not has_data and leaf.ndim >= 3:
            size = _axis_size(mesh, "data")
            entries = list(sp) + [None] * (leaf.ndim - len(sp))
            if entries[1] is None and leaf.shape[1] % size == 0 and \
                    leaf.shape[1] >= size:
                entries[1] = "data"
                sp = P(*entries)
        return NamedSharding(mesh, sp)

    cache_sh = jax.tree.map(_cache_sharding, cache_abs, cspecs)
    tok_sh = NamedSharding(mesh, sanitize_spec(P("data"), (plan.batch,), mesh))
    tok2_sh = NamedSharding(
        mesh, sanitize_spec(P("data", None), (plan.batch, 1), mesh)
    )
    fr_sh = None
    if plan.needs_frontend():
        fr_sh = NamedSharding(
            mesh,
            sanitize_spec(
                P("data", None, None),
                (plan.batch, plan.cfg.encoder_seq, plan.cfg.d_model),
                mesh,
            ),
        )
    return param_sh, cache_sh, tok2_sh, tok_sh, fr_sh
