"""Assigned input shapes and per-(arch × shape) lowering plans."""
from __future__ import annotations

import dataclasses

from ..configs import get_config
from ..core.adaseg import AdaSEGConfig
from .mesh import num_workers, worker_axes_for
from .train import TrainPlan


@dataclasses.dataclass(frozen=True)
class InputShape:
    name: str
    seq: int
    batch: int          # global
    kind: str           # train | prefill | decode


INPUT_SHAPES = {
    "train_4k": InputShape("train_4k", 4096, 256, "train"),
    "prefill_32k": InputShape("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": InputShape("decode_32k", 32768, 128, "decode"),
    "long_500k": InputShape("long_500k", 524288, 1, "decode"),
}

# long_500k needs sub-quadratic attention (DESIGN.md §long_500k skips):
# SSM / hybrid / all-SWA / local+global run it; pure full-attention archs
# and the enc-dec audio decoder skip it.
LONG_CONTEXT_ARCHS = {
    "mamba2-370m", "recurrentgemma-9b", "mixtral-8x22b", "gemma2-27b",
}

# Paper-faithful worker placement (M = every data shard) only fits HBM for
# the small configs; the large ones use the hierarchical (pod-worker) mode.
PAPER_MODE_ARCHS = {
    "granite-moe-1b-a400m", "qwen2-0.5b", "mamba2-370m", "whisper-small",
}


def applicable_shapes(arch: str) -> list[str]:
    shapes = ["train_4k", "prefill_32k", "decode_32k"]
    if arch in LONG_CONTEXT_ARCHS:
        shapes.append("long_500k")
    return shapes


def default_worker_mode(arch: str) -> str:
    return "paper" if arch in PAPER_MODE_ARCHS else "hierarchical"


def plan_for(arch: str, shape_name: str, mesh, *, k_local: int = 4,
             worker_mode: str | None = None,
             dtype: str = "bfloat16", accurate_cost: bool = False) -> TrainPlan:
    """TrainPlan for a train-kind shape (bf16 params/compute for the
    production lowering; the AdaSEG state stays f32).

    ``accurate_cost=True`` unrolls both the layer-group scan and the K local
    steps so XLA's cost analysis counts every executed op (while-loop bodies
    are otherwise counted once) — slower to compile, used by §Roofline.
    """
    cfg = get_config(arch)
    cfg = dataclasses.replace(
        cfg, param_dtype=dtype, compute_dtype=dtype,
        scan_layers=not accurate_cost,
    )
    shape = INPUT_SHAPES[shape_name]
    mode = worker_mode or default_worker_mode(arch)
    m = num_workers(mesh, worker_axes_for(mesh, mode))
    adaseg = AdaSEGConfig(
        g0=1.0, diameter=10.0, alpha=1.0 / (m**0.5), k=k_local,
        average_output=False,
    )
    return TrainPlan(
        cfg=cfg,
        adaseg=adaseg,
        worker_mode=mode,
        k_local=k_local,
        global_batch=shape.batch,
        seq=shape.seq,
        scan_rounds=not accurate_cost,
    )
