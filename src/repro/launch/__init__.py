"""Launchers: production mesh, dry-run, train/serve drivers, and the
shard_map LocalAdaSEG driver (``sharded.run_local_adaseg_sharded``)."""
