"""Production mesh construction (TPU v5e target).

Kept as functions — importing this module never touches jax device state.
"""
from __future__ import annotations

import jax


def _mesh(shape, axes):
    try:
        from jax.sharding import AxisType  # JAX >= 0.5
    except ImportError:
        return jax.make_mesh(shape, axes)

    return jax.make_mesh(
        shape, axes, axis_types=(AxisType.Auto,) * len(axes)
    )


def make_production_mesh(*, multi_pod: bool = False):
    """16×16 single-pod (256 chips) or 2×16×16 two-pod (512 chips) mesh."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return _mesh(shape, axes)


def make_test_mesh(data: int = 2, model: int = 2, pods: int | None = None):
    """Small mesh over however many (possibly fake) devices exist — used by
    CPU integration tests with xla_force_host_platform_device_count."""
    if pods:
        return _mesh((pods, data, model), ("pod", "data", "model"))
    return _mesh((data, model), ("data", "model"))


def worker_axes_for(mesh, mode: str) -> tuple[str, ...]:
    """LocalAdaSEG worker placement.

    * ``paper``        — every data shard is a worker (M = pod·data): the
                         Parameter-Server topology of the paper.
    * ``hierarchical`` — workers = pods (M = #pods); intra-pod axes do
                         FSDP/TP with per-step sync; only the slow inter-pod
                         link pays the K-amortized LocalAdaSEG sync.
    """
    names = mesh.axis_names
    if mode == "paper":
        return tuple(n for n in ("pod", "data") if n in names)
    if mode == "hierarchical":
        return ("pod",) if "pod" in names else ()
    raise ValueError(f"unknown worker mode {mode!r}")


def num_workers(mesh, worker_axes: tuple[str, ...]) -> int:
    m = 1
    for a in worker_axes:
        m *= mesh.shape[a]
    return max(m, 1)
