"""Distributed LocalAdaSEG training: the paper's Algorithm 1 composed with
the LM substrate under GSPMD.

The lowered unit is one **communication round**: a ``lax.scan`` of K local
extragradient steps (each = two vmapped grad calls over the worker axis, no
cross-worker collectives) followed by the inverse-η weighted parameter
average — one all-reduce over the worker mesh axes. The compiled HLO thus
exhibits the paper's collective schedule directly: worker-sync bytes are
amortized 1/K, which is what §Roofline measures.

Worker placement (see ``launch.mesh.worker_axes_for``):
* paper mode        — M = pod·data workers, params replicated per worker
                      (tensor-parallel over ``model`` only).
* hierarchical mode — M = #pods; within a worker params are FSDP-sharded
                      over ``data`` (per-step reduce-scatter/all-gather),
                      and only the inter-pod sync is K-amortized.

Since the unified-stack refactor this module owns **no optimizer math of its
own**: η and the Line-7 sync come from ``core.adaseg`` (``eta_of``,
``sync_weighted_stacked``) — the same functions the PS engines compile —
and :func:`make_ps_engine` turns a :class:`TrainPlan` directly into a
:class:`repro.ps.PSEngine` / :class:`repro.ps.AsyncPSEngine` over a
:class:`repro.ps.ModelWorker`, which is how the examples and benchmarks
drive real-model training. ``make_round_fn`` remains as the GSPMD-lowering
adapter (one jit-able round over pre-materialized batches) for the
dry-run/roofline tooling.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P

from ..configs.base import ArchConfig
from ..core.adaseg import AdaSEGConfig, eta_of, sync_weighted_stacked
from ..core.tree import tree_norm_sq
from ..data.synthetic import batch_struct, make_batch
from ..models import init_model, loss_fn
from ..sharding.specs import build_param_shardings, sanitize_spec, stack_spec
from .mesh import num_workers, worker_axes_for

PyTree = Any


class TrainState(NamedTuple):
    params: PyTree          # z̃ — worker-stacked (M, …)
    sum_sq: jax.Array       # (M,) Σ (Z_τ)²  (f32)
    t: jax.Array            # scalar int32
    grad_sq_sum: jax.Array  # (M,) V_t diagnostic


# Per-worker ‖·‖² over a (M, …) stacked pytree → (M,): the canonical
# tree_norm_sq vmapped over the worker axis (bit-exact vs the old private
# reduction — pinned by tests/test_model_worker.py).
_stacked_norm_sq = jax.vmap(tree_norm_sq)


def _bcast(eta: jax.Array, leaf: jax.Array) -> jax.Array:
    return eta.reshape((-1,) + (1,) * (leaf.ndim - 1)).astype(leaf.dtype)


@dataclasses.dataclass(frozen=True)
class TrainPlan:
    """Everything needed to lower/run one arch's training round."""

    cfg: ArchConfig
    adaseg: AdaSEGConfig
    worker_mode: str           # "paper" | "hierarchical"
    k_local: int
    global_batch: int
    seq: int
    # scan the K local steps (fast compile) vs python-unroll them (XLA cost
    # analysis counts every step — used together with cfg.scan_layers=False
    # for the accurate §Roofline pass)
    scan_rounds: bool = True
    # explicit worker count for single-device (CPU example) runs where the
    # mesh carries no worker axis but M stacked workers are still wanted
    workers_override: int | None = None
    # --- §Perf levers (hillclimb) -----------------------------------------
    # re-place a sanitation-dropped 'model' axis on the largest divisible
    # dim (MoE experts < model-axis size → TP within expert)
    repair_model: bool = False
    # pad the frontend patch/frame axis to a shardable multiple (VLM:
    # 6404 patches → e.g. 6656 = 16·416, avoids involuntary resharding)
    frontend_pad_to: int | None = None

    def worker_axes(self, mesh):
        return worker_axes_for(mesh, self.worker_mode)

    def num_workers(self, mesh) -> int:
        if self.workers_override:
            return self.workers_override
        return num_workers(mesh, self.worker_axes(mesh))

    def per_worker_batch(self, mesh) -> int:
        m = self.num_workers(mesh)
        assert self.global_batch % m == 0, (self.global_batch, m)
        return self.global_batch // m


def make_round_fn(plan: TrainPlan):
    """Returns round_fn(state, batches) -> (state, metrics).

    ``batches``: pytree with leading (K, 2, M, per_worker, …) — K local
    steps × two oracle calls (extragradient) × M workers.
    """
    cfg, acfg = plan.cfg, plan.adaseg

    def worker_loss(params, batch):
        return loss_fn(params, cfg, batch)

    vgrad = jax.vmap(jax.value_and_grad(worker_loss))

    def local_step(carry: TrainState, batch_k):
        b1 = jax.tree.map(lambda v: v[0], batch_k)
        b2 = jax.tree.map(lambda v: v[1], batch_k)
        eta = eta_of(acfg, carry.sum_sq)                 # (M,)

        _, m_t = vgrad(carry.params, b1)                 # M_t = G(z̃)
        z_t = jax.tree.map(
            lambda z, g: z - _bcast(eta, z) * g, carry.params, m_t
        )
        loss, g_t = vgrad(z_t, b2)                       # g_t = G(z_t)
        z_new = jax.tree.map(
            lambda z, g: z - _bcast(eta, z) * g, carry.params, g_t
        )

        diff1 = jax.tree.map(jnp.subtract, z_t, carry.params)
        diff2 = jax.tree.map(jnp.subtract, z_t, z_new)
        z_sq = (_stacked_norm_sq(diff1) + _stacked_norm_sq(diff2)) / (
            5.0 * eta**2
        )
        gss = carry.grad_sq_sum + _stacked_norm_sq(g_t) + _stacked_norm_sq(m_t)
        new = TrainState(
            params=z_new,
            sum_sq=carry.sum_sq + z_sq,
            t=carry.t + 1,
            grad_sq_sum=gss,
        )
        return new, jnp.mean(loss)

    def sync(state: TrainState) -> TrainState:
        """Line 7: the engine's inverse-η weighted average
        (``core.adaseg.sync_weighted_stacked``), accumulated in f32 like
        the historical driver (a no-op cast for f32 params)."""
        inv_eta = 1.0 / eta_of(acfg, state.sum_sq)       # (M,)
        f32 = jax.tree.map(lambda l: l.astype(jnp.float32), state.params)
        avg = sync_weighted_stacked(f32, inv_eta)
        params = jax.tree.map(
            lambda a, l: a.astype(l.dtype), avg, state.params
        )
        return state._replace(params=params)

    def round_fn(state: TrainState, batches):
        state = sync(state)
        if plan.scan_rounds:
            state, losses = jax.lax.scan(local_step, state, batches)
        else:
            losses = []
            for k in range(plan.k_local):
                state, loss_k = local_step(
                    state, jax.tree.map(lambda v: v[k], batches)
                )
                losses.append(loss_k)
            losses = jnp.stack(losses)
        return state, {"loss": losses, "eta": eta_of(acfg, state.sum_sq)}

    return round_fn


# ---------------------------------------------------------------------------
# Concrete state/batch construction & shardings
# ---------------------------------------------------------------------------

def init_train_state(rng, plan: TrainPlan, mesh) -> TrainState:
    """Materialized state for real (small-mesh / CPU) runs."""
    m = plan.num_workers(mesh)
    rngs = jax.random.split(rng, m)
    params = jax.vmap(lambda r: init_model(r, plan.cfg)[0])(rngs)
    return TrainState(
        params=params,
        sum_sq=jnp.zeros((m,), jnp.float32),
        t=jnp.int32(0),
        grad_sq_sum=jnp.zeros((m,), jnp.float32),
    )


def abstract_train_state(plan: TrainPlan, mesh) -> TrainState:
    """ShapeDtypeStruct state — used by the dry-run (no allocation)."""
    m = plan.num_workers(mesh)
    params, _ = _spec_tree(plan.cfg)
    params = jax.tree.map(
        lambda l: jax.ShapeDtypeStruct((m, *l.shape), l.dtype), params
    )
    return TrainState(
        params=params,
        sum_sq=jax.ShapeDtypeStruct((m,), jnp.float32),
        t=jax.ShapeDtypeStruct((), jnp.int32),
        grad_sq_sum=jax.ShapeDtypeStruct((m,), jnp.float32),
    )


def make_shardings(plan: TrainPlan, mesh):
    """(state_shardings, batch_shardings) for jit in/out."""
    waxes = plan.worker_axes(mesh)
    _, specs = _spec_tree(plan.cfg)
    abstract = abstract_train_state(plan, mesh)
    param_sh = build_param_shardings(
        abstract.params, specs, mesh,
        worker_axes=waxes, fsdp=(plan.worker_mode == "hierarchical"),
        repair_model=plan.repair_model,
    )
    scal = NamedSharding(mesh, P())
    vec_m = NamedSharding(
        mesh, sanitize_spec(P(waxes if waxes else None),
                            (plan.num_workers(mesh),), mesh)
    )
    state_sh = TrainState(params=param_sh, sum_sq=vec_m, t=scal,
                          grad_sq_sum=vec_m)

    lead = None if not waxes else (waxes if len(waxes) != 1 else waxes[0])
    data_free = "data" not in waxes
    bspec = P(None, None, lead, "data" if data_free else None, None)
    # frontend: shard the patch/frame axis over 'model' when divisible —
    # cross-attn KV is then produced already-sharded (perf lever)
    ecfg = effective_cfg(plan)
    patch_axis = None
    if ecfg.encoder_seq and ecfg.encoder_seq % mesh.shape["model"] == 0:
        patch_axis = "model"
    fspec = P(None, None, lead, "data" if data_free else None, patch_axis,
              None)
    bsh = {
        "tokens": NamedSharding(mesh, bspec),
        "labels": NamedSharding(mesh, bspec),
    }
    if plan.cfg.encoder_seq:
        bsh["frontend"] = NamedSharding(mesh, fspec)
    return state_sh, bsh


_SPEC_CACHE: dict = {}


def _spec_tree(cfg: ArchConfig):
    """(abstract_params, specs) without allocating real parameters.

    ``init_model`` is traced abstractly; the PartitionSpec tree is captured
    as a trace-time side effect (specs are plain Python objects)."""
    if cfg.name in _SPEC_CACHE and _SPEC_CACHE[cfg.name][0] is cfg:
        return _SPEC_CACHE[cfg.name][1]
    box = {}

    def build(seed):
        key = jax.random.wrap_key_data(seed)
        p, s = init_model(key, cfg)
        box["specs"] = s
        return p

    shapes = jax.eval_shape(build, jax.ShapeDtypeStruct((2,), jnp.uint32))
    out = (shapes, box["specs"])
    _SPEC_CACHE[cfg.name] = (cfg, out)
    return out


def effective_cfg(plan: TrainPlan):
    cfg = plan.cfg
    if plan.frontend_pad_to and cfg.encoder_seq:
        cfg = dataclasses.replace(
            cfg, encoder_seq=max(cfg.encoder_seq, plan.frontend_pad_to)
        )
    return cfg


def abstract_batches(plan: TrainPlan, mesh):
    m = plan.num_workers(mesh)
    return batch_struct(
        effective_cfg(plan),
        (plan.k_local, 2, m),
        plan.per_worker_batch(mesh),
        plan.seq,
        dtype=jnp.dtype(plan.cfg.compute_dtype),
    )


def make_batches(rng, plan: TrainPlan, mesh):
    """Materialized (K, 2, M, b, S) batches for real runs."""
    m = plan.num_workers(mesh)
    b = plan.per_worker_batch(mesh)
    flat = make_batch(rng, plan.cfg, plan.k_local * 2 * m * b, plan.seq)
    return jax.tree.map(
        lambda v: v.reshape(plan.k_local, 2, m, b, *v.shape[1:]), flat
    )


# ---------------------------------------------------------------------------
# The unified stack: a TrainPlan is a PSEngine configuration
# ---------------------------------------------------------------------------

def make_ps_engine(
    plan: TrainPlan,
    rng,
    *,
    rounds: int,
    mesh=None,
    hetero: bool = False,
    schedule=None,
    compressor=None,
    faults=None,
    codec_backend: str = "reference",
    latency=None,
    staleness_bound: float | None = None,
    staleness_discount: float = 1.0,
    eval_fn="loss",
    trace_meta: dict | None = None,
    tracer=None,
    metrics=None,
):
    """A TrainPlan as a Parameter-Server engine — the one training stack.

    Builds the plan's architecture as a :func:`repro.models.make_lm_problem`
    and its AdaSEG spelling as a :class:`repro.ps.ModelWorker`, then hands
    both to the PS runtime, so real-model training gets schedules,
    compression + error feedback, faults, checkpoint/resume and telemetry
    from the exact same code path as the optimizer zoo.

    * ``mesh=None`` — serial vmap engine (``plan.workers_override`` sets M).
    * ``mesh=...``  — ``shard_map`` engine over ``plan.worker_axes(mesh)``.
    * ``latency``/``staleness_bound`` — :class:`repro.ps.AsyncPSEngine`
      discrete-event simulation instead (serial path; τ=0 is bit-exact with
      the synchronous engine by shared code).

    ``eval_fn="loss"`` installs :func:`repro.models.make_eval_loss` on a
    held-out batch; pass ``None`` (or a callable) to override.
    """
    from ..models.problem import make_eval_loss, make_lm_problem
    from ..models.worker import ModelWorker
    from ..ps import AsyncPSConfig, AsyncPSEngine, PSConfig, PSEngine

    m = plan.num_workers(mesh) if mesh is not None else plan.workers_override
    if not m:
        raise ValueError(
            "make_ps_engine needs a mesh or plan.workers_override"
        )
    b = plan.per_worker_batch(mesh) if mesh is not None else (
        plan.global_batch // m
    )
    problem = make_lm_problem(
        plan.cfg, batch=b, seq=plan.seq,
        hetero_workers=(m if hetero else None),
    )
    worker = ModelWorker(plan.adaseg, arch=plan.cfg.name)
    if eval_fn == "loss":
        eval_fn = make_eval_loss(plan.cfg, batch=b, seq=plan.seq)

    is_async = latency is not None or staleness_bound is not None
    common = dict(
        num_workers=m, rounds=rounds, worker=worker, local_k=plan.k_local,
        schedule=schedule, compressor=compressor, faults=faults,
        codec_backend=codec_backend,
    )
    if is_async:
        if mesh is not None:
            raise ValueError("the async engine runs the serial path only")
        config = AsyncPSConfig(
            **common, latency=latency,
            staleness_bound=(math.inf if staleness_bound is None
                             else staleness_bound),
            staleness_discount=staleness_discount,
        )
        return AsyncPSEngine(problem, config, rng, eval_fn=eval_fn,
                             trace_meta=trace_meta, tracer=tracer,
                             metrics=metrics)
    config = PSConfig(**common)
    waxes = plan.worker_axes(mesh) if mesh is not None else ("data",)
    return PSEngine(problem, config, rng, mesh=mesh,
                    worker_axes=waxes, eval_fn=eval_fn,
                    trace_meta=trace_meta, tracer=tracer,
                    metrics=metrics)
