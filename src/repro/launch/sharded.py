"""shard_map production driver for LocalAdaSEG.

The serial driver (``core.adaseg.run_local_adaseg``) stacks M workers on a
leading axis and vmaps the step — fine for CPU experiments, but every
worker's parameters live on one device. This driver places one worker (or
one worker group) per mesh shard with ``shard_map``: each shard runs its K
local steps independently, and the paper's Parameter-Server round
(Line 5–8: gather → inverse-stepsize weighted average → broadcast)
collapses to a single ``lax.psum`` all-reduce of w·z̃ per round via
``core.adaseg.make_psum_sync`` — the K-amortized communication pattern the
paper's bounds are about.

RNG derivation is bit-identical to the serial driver, so for a given seed
``run_local_adaseg_sharded`` reproduces ``run_local_adaseg`` trajectories
exactly (up to all-reduce summation order) — the parity tests in
``tests/test_distributed.py`` pin this. The step backend is pluggable here
exactly as in the serial driver (``backend="reference" | "fused"``).
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental.shard_map import shard_map
from jax.sharding import PartitionSpec as P

from ..core.adaseg import (
    AdaSEGConfig,
    eta_of,
    init,
    local_step,
    make_psum_sync,
)
from ..core.types import MinimaxProblem


def _worker_count(mesh, worker_axes: tuple[str, ...]) -> int:
    return math.prod(mesh.shape[a] for a in worker_axes)


def run_local_adaseg_sharded(
    problem: MinimaxProblem,
    cfg: AdaSEGConfig,
    *,
    mesh,
    worker_axes: tuple[str, ...] = ("data",),
    rounds: int,
    rng,
    backend: str = "reference",
    collect_aux: bool = False,
):
    """Run LocalAdaSEG with one worker per shard of ``worker_axes``.

    Returns ``(z_bar, (state, history))`` exactly like the serial driver:
    ``z_bar`` is the global output iterate (replicated), ``state`` carries
    the leading worker axis (sharded over ``worker_axes``), and ``history``
    holds per-step diagnostics stacked as (R, K, M) when ``collect_aux``.
    Uniform K per worker (the paper's synchronous Parameter-Server setting);
    use the serial driver for the heterogeneous-K asynchronous variant.
    """
    if not worker_axes:
        raise ValueError("worker_axes must name at least one mesh axis")
    m = _worker_count(mesh, worker_axes)
    k = int(cfg.k)

    # Identical rng derivation to run_local_adaseg: worker inits from
    # split(rng, M+1)[1:], then per-round step rngs split(round_rng, K·M)
    # laid out as (K, M, 2) — transposed here to a leading worker axis.
    init_rngs = jax.random.split(rng, m + 1)
    rng0, worker_rngs = init_rngs[0], init_rngs[1:]
    round_rngs = jax.random.split(rng0, rounds)
    step_rngs = jax.vmap(
        lambda r: jax.random.split(r, k * m).reshape(k, m, 2)
    )(round_rngs)                                     # (R, K, M, 2)
    step_rngs = jnp.transpose(step_rngs, (2, 0, 1, 3))  # (M, R, K, 2)
    worker_ids = jnp.arange(m, dtype=jnp.int32)

    sync = make_psum_sync(worker_axes)
    lead = worker_axes if len(worker_axes) > 1 else worker_axes[0]

    def shard_fn(w_rng, s_rngs, wid):
        # Per-shard shapes: w_rng (1, 2), s_rngs (1, R, K, 2), wid (1,).
        state = init(problem, cfg, w_rng[0], wid[0])

        def round_fn(st, rngs_round):
            # Line 5–8: weighted sync at the top of each round, as one
            # all-reduce of w·z̃ across the worker axes.
            inv_eta = 1.0 / eta_of(cfg, st.sum_sq)
            st = st._replace(z_tilde=sync(st.z_tilde, inv_eta))

            def body(s, r):
                return local_step(problem, cfg, s, r, backend=backend)

            return lax.scan(body, st, rngs_round)

        state, hist = lax.scan(round_fn, state, s_rngs[0])

        # Line 14 global output: uniform average of worker means.
        z_bar = jax.tree.map(
            lambda v: lax.psum(v, worker_axes) / m, state.z_bar
        )
        state_out = jax.tree.map(lambda v: v[None], state)
        hist_out = jax.tree.map(lambda v: v[:, :, None], hist)  # (R, K, 1)
        return z_bar, state_out, hist_out

    spec_w = P(lead)
    fn = shard_map(
        shard_fn,
        mesh=mesh,
        in_specs=(spec_w, P(lead, None, None, None), spec_w),
        # Prefix specs: z_bar replicated (post-psum), state leaves carry the
        # leading worker axis, history is (R, K, M) with M sharded.
        out_specs=(P(), spec_w, P(None, None, lead)),
        check_rep=False,
    )
    z_bar, state, hist = jax.jit(fn)(worker_rngs, step_rngs, worker_ids)
    return z_bar, (state, hist if collect_aux else None)
