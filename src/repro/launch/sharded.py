"""shard_map production driver for LocalAdaSEG.

The serial driver (``core.adaseg.run_local_adaseg``) stacks M workers on a
leading axis and vmaps the step — fine for CPU experiments, but every
worker's parameters live on one device. This driver places one worker (or
one worker group) per mesh shard with ``shard_map``: each shard runs its K
local steps independently, and the paper's Parameter-Server round
(Line 5–8: gather → inverse-stepsize weighted average → broadcast)
collapses to a single ``lax.psum`` all-reduce of w·z̃ per round via
``core.adaseg.make_psum_sync`` — the K-amortized communication pattern the
paper's bounds are about.

RNG derivation is bit-identical to the serial driver, so for a given seed
``run_local_adaseg_sharded`` reproduces ``run_local_adaseg`` trajectories
exactly (up to all-reduce summation order) — the parity tests in
``tests/test_distributed.py`` pin this. The step backend is pluggable here
exactly as in the serial driver (``backend="reference" | "fused"``).

Heterogeneous local work (the asynchronous variant of Appendix E.1) is
supported via ``local_steps``: per-worker K_m with the same ``enabled``
masking semantics as the serial driver, parity-pinned against it. The
Line-7 sync is a hook: pass ``sync_fn(z_tilde, inv_eta)`` — or, for codecs
that need randomness, ``sync_fn(z_tilde, inv_eta, rng)`` — to replace the
dense psum with e.g. a compressed-psum from ``repro.ps.compress``
(``make_compressed_psum_sync``). Sync rngs are derived eagerly on the host
(fold_in(round_rng, 7), split per worker — the PS engine's derivation):
with the default non-partitionable threefry, key derivation inside the jit
that feeds a shard_map would be re-sharded and silently change the stream.

This module remains the *one-shot* sharded driver for Algorithm 1. The
configurable runtime — schedules × compression × faults × resume, for
LocalAdaSEG and the whole optimizer zoo — is ``repro.ps.PSEngine`` with
``mesh=``, whose sharded chunk reproduces this driver's psum-sync and rng
semantics (parity-pinned in ``tests/test_distributed.py``).
"""
from __future__ import annotations

import inspect
import math

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental.shard_map import shard_map
from jax.sharding import PartitionSpec as P

from ..core.adaseg import (
    AdaSEGConfig,
    eta_of,
    init,
    local_step,
    make_psum_sync,
)
from ..core.types import MinimaxProblem


def _worker_count(mesh, worker_axes: tuple[str, ...]) -> int:
    return math.prod(mesh.shape[a] for a in worker_axes)


def run_local_adaseg_sharded(
    problem: MinimaxProblem,
    cfg: AdaSEGConfig,
    *,
    mesh,
    worker_axes: tuple[str, ...] = ("data",),
    rounds: int,
    rng,
    backend: str = "reference",
    collect_aux: bool = False,
    local_steps=None,
    sync_fn=None,
):
    """Run LocalAdaSEG with one worker per shard of ``worker_axes``.

    Returns ``(z_bar, (state, history))`` exactly like the serial driver:
    ``z_bar`` is the global output iterate (replicated), ``state`` carries
    the leading worker axis (sharded over ``worker_axes``), and ``history``
    holds per-step diagnostics stacked as (R, K, M) when ``collect_aux``.

    ``local_steps`` (int array of shape (M,), optional) gives heterogeneous
    per-worker step counts K_m — the asynchronous Parameter-Server variant —
    with the same masking semantics as the serial driver (workers beyond
    their K_m hold their state; the Line-14 output weights workers by their
    realized step counts). ``sync_fn`` overrides the Line-7 all-reduce
    (default: ``make_psum_sync(worker_axes)``); a 3-argument hook also
    receives a per-worker, per-round rng for stochastic codecs.
    """
    if not worker_axes:
        raise ValueError("worker_axes must name at least one mesh axis")
    m = _worker_count(mesh, worker_axes)

    has_ls = local_steps is not None
    if has_ls:
        ls = jnp.asarray(local_steps, dtype=jnp.int32)
        if ls.shape != (m,):
            raise ValueError(f"local_steps must have shape ({m},), got {ls.shape}")
        k = int(jnp.max(ls))
    else:
        ls = None
        k = int(cfg.k)

    sync = sync_fn if sync_fn is not None else make_psum_sync(worker_axes)
    wants_rng = (
        sync_fn is not None
        and len(inspect.signature(sync_fn).parameters) >= 3
    )

    # Identical rng derivation to run_local_adaseg: worker inits from
    # split(rng, M+1)[1:], then per-round step rngs split(round_rng, K·M)
    # laid out as (K, M, 2) — transposed here to a leading worker axis.
    init_rngs = jax.random.split(rng, m + 1)
    rng0, worker_rngs = init_rngs[0], init_rngs[1:]
    round_rngs = jax.random.split(rng0, rounds)
    step_rngs = jax.vmap(
        lambda r: jax.random.split(r, k * m).reshape(k, m, 2)
    )(round_rngs)                                     # (R, K, M, 2)
    step_rngs = jnp.transpose(step_rngs, (2, 0, 1, 3))  # (M, R, K, 2)
    worker_ids = jnp.arange(m, dtype=jnp.int32)

    lead = worker_axes if len(worker_axes) > 1 else worker_axes[0]
    spec_w = P(lead)

    operands = [worker_rngs, step_rngs, worker_ids]
    in_specs = [spec_w, P(lead, None, None, None), spec_w]
    if has_ls:
        operands.append(ls)
        in_specs.append(spec_w)
    if wants_rng:
        sync_rngs = jax.vmap(
            lambda r: jax.random.split(jax.random.fold_in(r, 7), m)
        )(round_rngs)                                 # (R, M, 2)
        operands.append(jnp.transpose(sync_rngs, (1, 0, 2)))  # (M, R, 2)
        in_specs.append(P(lead, None, None))

    def shard_fn(w_rng, s_rngs, wid, *rest):
        # Per-shard shapes: w_rng (1, 2), s_rngs (1, R, K, 2), wid (1,),
        # then optionally ls (1,) and sync_rngs (1, R, 2).
        rest = list(rest)
        k_m = rest.pop(0)[0] if has_ls else None
        sy_rngs = rest.pop(0)[0] if wants_rng else jnp.zeros(
            (rounds, 2), jnp.uint32
        )
        state = init(problem, cfg, w_rng[0], wid[0])

        def round_fn(st, inputs):
            rngs_round, sync_rng = inputs
            # Line 5–8: weighted sync at the top of each round, as one
            # all-reduce of (possibly compressed) w·z̃ across worker axes.
            with jax.named_scope("sync"):
                inv_eta = 1.0 / eta_of(cfg, st.sum_sq)
                if wants_rng:
                    st = st._replace(
                        z_tilde=sync(st.z_tilde, inv_eta, sync_rng)
                    )
                else:
                    st = st._replace(z_tilde=sync(st.z_tilde, inv_eta))

            with jax.named_scope("local-compute"):
                if has_ls:
                    def body(s, inp):
                        r, i = inp
                        return local_step(problem, cfg, s, r,
                                          enabled=i < k_m, backend=backend)

                    return lax.scan(body, st, (rngs_round, jnp.arange(k)))

                def body(s, r):
                    return local_step(problem, cfg, s, r, backend=backend)

                return lax.scan(body, st, rngs_round)

        state, hist = lax.scan(round_fn, state, (s_rngs[0], sy_rngs))

        # Line 14 global output: worker means weighted by realized step
        # counts (uniform K degenerates to the plain mean).
        if has_ls:
            count_m = (k_m * rounds).astype(jnp.float32)
            w_m = count_m / lax.psum(count_m, worker_axes)
            z_bar = jax.tree.map(
                lambda v: lax.psum(w_m.astype(v.dtype) * v, worker_axes),
                state.z_bar,
            )
        else:
            z_bar = jax.tree.map(
                lambda v: lax.psum(v, worker_axes) / m, state.z_bar
            )
        state_out = jax.tree.map(lambda v: v[None], state)
        hist_out = jax.tree.map(lambda v: v[:, :, None], hist)  # (R, K, 1)
        return z_bar, state_out, hist_out

    fn = shard_map(
        shard_fn,
        mesh=mesh,
        in_specs=tuple(in_specs),
        # Prefix specs: z_bar replicated (post-psum), state leaves carry the
        # leading worker axis, history is (R, K, M) with M sharded.
        out_specs=(P(), spec_w, P(None, None, lead)),
        check_rep=False,
    )
    z_bar, state, hist = jax.jit(fn)(*operands)
    return z_bar, (state, hist if collect_aux else None)
