import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (architecture × input shape)
against the production meshes, using ShapeDtypeStruct stand-ins (no device
allocation), and dump memory/cost/collective analyses for §Roofline.

MUST set XLA_FLAGS before any other import — jax locks the device count at
first initialization.

Usage:
    PYTHONPATH=src python -m repro.launch.dryrun                  # everything
    PYTHONPATH=src python -m repro.launch.dryrun --arch qwen3-8b --shape train_4k
    PYTHONPATH=src python -m repro.launch.dryrun --multi-pod --out dryrun.json
"""
import argparse
import json
import sys
import traceback

import jax
import jax.numpy as jnp

from ..configs import get_config, list_archs
from ..core.adaseg import AdaSEGConfig
from ..roofline.analysis import analyze_compiled
from .mesh import make_production_mesh
from .shapes import INPUT_SHAPES, applicable_shapes, plan_for
from .train import (
    abstract_batches,
    abstract_train_state,
    make_round_fn,
    make_shardings,
)
from .serve import (
    ServePlan,
    abstract_cache,
    make_prefill_step,
    make_serve_shardings,
    make_serve_step,
)


def lower_train(arch: str, shape_name: str, mesh, *, k_local: int = 4,
                worker_mode: str | None = None, accurate_cost: bool = False,
                optimized: bool = False):
    plan = plan_for(arch, shape_name, mesh, k_local=k_local,
                    worker_mode=worker_mode, accurate_cost=accurate_cost)
    if optimized:
        import dataclasses as _dc

        # Both MoE levers only help when 'data' is a pure batch/FSDP axis
        # (hierarchical); in paper mode 'data' carries the per-worker
        # parameter copies and the same constraints REGRESS collectives
        # ×10-60 (measured — see EXPERIMENTS §Perf/optimized-sweep).
        hier = plan.worker_mode == "hierarchical"
        cfg = _dc.replace(plan.cfg, moe_shard_dispatch=hier)
        pad = None
        # VLM only: sharding the patch axis pays at 6404×4096; for the small
        # whisper encoder (1500×768) it costs more than it saves (measured)
        if cfg.cross_attn_every and cfg.encoder_seq % 256:
            pad = (cfg.encoder_seq + 255) // 256 * 256  # 6404 → 6656
        plan = _dc.replace(
            plan, cfg=cfg, repair_model=hier, frontend_pad_to=pad,
        )
    round_fn = make_round_fn(plan)
    state_sh, batch_sh = make_shardings(plan, mesh)
    state = abstract_train_state(plan, mesh)
    batches = abstract_batches(plan, mesh)
    with mesh:
        lowered = jax.jit(
            round_fn,
            in_shardings=(state_sh, batch_sh),
            out_shardings=(state_sh, None),
        ).lower(state, batches)
        compiled = lowered.compile()
    return lowered, compiled, plan


def lower_serve(arch: str, shape_name: str, mesh):
    cfg = get_config(arch)
    shape = INPUT_SHAPES[shape_name]
    plan = ServePlan(cfg=cfg, batch=shape.batch, context_len=shape.seq)
    step = make_serve_step(plan)
    param_sh, cache_sh, tok_sh, pos_sh, fr_sh = make_serve_shardings(plan, mesh)

    from .train import _spec_tree

    params_abs, _ = _spec_tree(cfg)
    cache_abs = abstract_cache(plan)
    tok = jax.ShapeDtypeStruct((plan.batch, 1), jnp.int32)
    pos = jax.ShapeDtypeStruct((plan.batch,), jnp.int32)
    args = [params_abs, cache_abs, tok, pos]
    in_sh = [param_sh, cache_sh, tok_sh, pos_sh]
    if plan.needs_frontend():
        args.append(
            jax.ShapeDtypeStruct(
                (plan.batch, cfg.encoder_seq, cfg.d_model),
                jnp.dtype(cfg.compute_dtype),
            )
        )
        in_sh.append(fr_sh)
    with mesh:
        lowered = jax.jit(
            step,
            in_shardings=tuple(in_sh),
            out_shardings=(tok_sh, cache_sh),
        ).lower(*args)
        compiled = lowered.compile()
    return lowered, compiled, plan


def lower_prefill(arch: str, shape_name: str, mesh, *,
                  accurate_cost: bool = False):
    import dataclasses as _dc

    cfg = get_config(arch)
    cfg = _dc.replace(cfg, param_dtype="bfloat16", compute_dtype="bfloat16",
                      scan_layers=not accurate_cost)
    shape = INPUT_SHAPES[shape_name]
    plan = ServePlan(cfg=cfg, batch=shape.batch, context_len=shape.seq)
    step = make_prefill_step(plan)
    param_sh, _, _, pos_sh, fr_sh = make_serve_shardings(plan, mesh)

    from jax.sharding import NamedSharding, PartitionSpec as P
    from ..sharding.specs import sanitize_spec
    from .train import _spec_tree

    params_abs, _ = _spec_tree(cfg)
    tok = jax.ShapeDtypeStruct((plan.batch, shape.seq), jnp.int32)
    tok_sh = NamedSharding(
        mesh, sanitize_spec(P("data", None), tok.shape, mesh)
    )
    args = [params_abs, tok]
    in_sh = [param_sh, tok_sh]
    if plan.needs_frontend():
        args.append(
            jax.ShapeDtypeStruct(
                (plan.batch, cfg.encoder_seq, cfg.d_model),
                jnp.dtype(cfg.compute_dtype),
            )
        )
        in_sh.append(fr_sh)
    with mesh:
        lowered = jax.jit(
            step, in_shardings=tuple(in_sh), out_shardings=pos_sh
        ).lower(*args)
        compiled = lowered.compile()
    return lowered, compiled, plan


def run_one(arch: str, shape_name: str, mesh, mesh_name: str, *,
            k_local: int = 4, worker_mode: str | None = None,
            accurate_cost: bool = False, optimized: bool = False) -> dict:
    shape = INPUT_SHAPES[shape_name]
    if shape.kind == "train":
        lowered, compiled, plan = lower_train(
            arch, shape_name, mesh, k_local=k_local, worker_mode=worker_mode,
            accurate_cost=accurate_cost, optimized=optimized,
        )
        extra = {"worker_mode": plan.worker_mode,
                 "num_workers": plan.num_workers(mesh),
                 "k_local": plan.k_local}
    elif shape.kind == "prefill":
        lowered, compiled, plan = lower_prefill(
            arch, shape_name, mesh, accurate_cost=accurate_cost
        )
        extra = {}
    else:
        lowered, compiled, plan = lower_serve(arch, shape_name, mesh)
        extra = {}
    rec = analyze_compiled(lowered, compiled, mesh)
    rec.update(
        arch=arch, shape=shape_name, mesh=mesh_name, status="ok", **extra
    )
    return rec


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None, help="one arch (default: all)")
    ap.add_argument("--shape", default=None, help="one shape (default: all)")
    ap.add_argument("--mesh", default="single", choices=("single", "multi", "both"),
                    help="single = 16×16 (256 chips), multi = 2×16×16 (512)")
    ap.add_argument("--k-local", type=int, default=4)
    ap.add_argument("--worker-mode", default=None,
                    choices=(None, "paper", "hierarchical"))
    ap.add_argument("--optimized", action="store_true",
                    help="§Perf levers on: repair_model, moe_shard_dispatch, "
                         "frontend padding (chunked attention and last-token "
                         "prefill head are always-on defaults)")
    ap.add_argument("--out", default=None, help="write JSON records here")
    args = ap.parse_args(argv)

    meshes = []
    if args.mesh in ("single", "both"):
        meshes.append(("pod16x16", make_production_mesh(multi_pod=False)))
    if args.mesh in ("multi", "both"):
        meshes.append(("pods2x16x16", make_production_mesh(multi_pod=True)))

    archs = [args.arch] if args.arch else list_archs()
    records = []
    failures = 0
    for mesh_name, mesh in meshes:
        for arch in archs:
            shapes = [args.shape] if args.shape else applicable_shapes(arch)
            for shape_name in shapes:
                tag = f"{mesh_name} × {arch} × {shape_name}"
                try:
                    rec = run_one(arch, shape_name, mesh, mesh_name,
                                  k_local=args.k_local,
                                  worker_mode=args.worker_mode,
                                  optimized=args.optimized)
                    records.append(rec)
                    print(f"[ok]   {tag}: "
                          f"bytes/dev={rec['bytes_per_device']:.3e} "
                          f"flops={rec['flops']:.3e} "
                          f"coll_bytes={rec['collective_bytes']:.3e}")
                except Exception as e:  # noqa: BLE001 — report, keep going
                    failures += 1
                    print(f"[FAIL] {tag}: {type(e).__name__}: {e}")
                    traceback.print_exc(limit=3)
    if args.out:
        with open(args.out, "w") as f:
            json.dump(records, f, indent=1)
        print(f"wrote {len(records)} records to {args.out}")
    print(f"dry-run complete: {len(records)} ok, {failures} failed")
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
