"""Fault policies: which workers are alive each round (Line 5–8 membership).

A :class:`FaultPolicy` yields a per-round boolean aliveness table. A worker
that is *down* for round ``r``:

* runs no local steps (its ``enabled`` mask is forced off),
* sends nothing uphill — its inverse-stepsize weight is removed and the
  Line-7 weights ``w ∝ 1/η`` are renormalized over the survivors,
* receives nothing downhill — it keeps its stale anchor ``z̃`` and rejoins
  with it (and its accumulated Σ(Z)², so its η is still honest) when the
  policy brings it back.

Like the schedules, fault policies are deterministic functions of their own
``seed`` so a resumed run replays the exact same failure trace.
"""
from __future__ import annotations

import dataclasses

import numpy as np


class FaultPolicy:
    def alive(self, num_workers: int, rounds: int) -> np.ndarray:
        """(rounds, num_workers) bool table; True = worker participates."""
        raise NotImplementedError


@dataclasses.dataclass(frozen=True)
class NoFaults(FaultPolicy):
    def alive(self, num_workers: int, rounds: int) -> np.ndarray:
        return np.ones((rounds, num_workers), dtype=bool)


@dataclasses.dataclass(frozen=True)
class BernoulliFaults(FaultPolicy):
    """Each round every worker independently fails with probability ``p``.
    ``protect_one`` keeps worker 0 always alive so the weighted average is
    never over an empty survivor set (the engine also tolerates an all-dead
    round: every weight masks to zero and nobody receives, so all anchors
    simply carry over)."""

    p: float
    seed: int = 0
    protect_one: bool = True

    def alive(self, num_workers: int, rounds: int) -> np.ndarray:
        rng = np.random.default_rng(self.seed)
        up = rng.random((rounds, num_workers)) >= self.p
        if self.protect_one:
            up[:, 0] = True
        return up


@dataclasses.dataclass(frozen=True)
class OutageFaults(FaultPolicy):
    """Scripted outages: ``events`` is a tuple of (worker, start_round,
    end_round) half-open intervals during which the worker is down. Good for
    reproducing a specific incident in tests and benchmarks."""

    events: tuple  # ((worker, start, end), ...)

    def alive(self, num_workers: int, rounds: int) -> np.ndarray:
        up = np.ones((rounds, num_workers), dtype=bool)
        for worker, start, end in self.events:
            up[int(start):int(end), int(worker)] = False
        return up
