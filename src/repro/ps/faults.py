"""Fault policies: which workers are alive each round (Line 5–8 membership).

A :class:`FaultPolicy` yields a per-round boolean aliveness table. A worker
that is *down* for round ``r``:

* runs no local steps (its ``enabled`` mask is forced off),
* sends nothing uphill — its inverse-stepsize weight is removed and the
  Line-7 weights ``w ∝ 1/η`` are renormalized over the survivors,
* receives nothing downhill — it keeps its stale anchor ``z̃`` and rejoins
  with it (and its accumulated Σ(Z)², so its η is still honest) when the
  policy brings it back.

Like the schedules, fault policies are deterministic functions of their own
``seed`` so a resumed run replays the exact same failure trace.
"""
from __future__ import annotations

import dataclasses

import numpy as np


class FaultPolicy:
    """Base class. Subclasses fill in :meth:`alive`.

    Examples
    --------
    Every policy is a deterministic (rounds, workers) aliveness table:

    >>> table = BernoulliFaults(p=0.5, seed=0).alive(4, 6)
    >>> table.shape, table.dtype.name
    ((6, 4), 'bool')
    >>> bool((table == BernoulliFaults(p=0.5, seed=0).alive(4, 6)).all())
    True
    """

    def alive(self, num_workers: int, rounds: int) -> np.ndarray:
        """(rounds, num_workers) bool table; True = worker participates."""
        raise NotImplementedError


@dataclasses.dataclass(frozen=True)
class NoFaults(FaultPolicy):
    """Everyone up, every round — the engines' default (and the static
    guarantee that lets them skip aliveness masking entirely).

    Examples
    --------
    >>> bool(NoFaults().alive(2, 3).all())
    True
    """

    def alive(self, num_workers: int, rounds: int) -> np.ndarray:
        return np.ones((rounds, num_workers), dtype=bool)


@dataclasses.dataclass(frozen=True)
class BernoulliFaults(FaultPolicy):
    """Each round every worker independently fails with probability ``p``.
    ``protect_one`` keeps worker 0 always alive so the weighted average is
    never over an empty survivor set (the engine also tolerates an all-dead
    round: every weight masks to zero and nobody receives, so all anchors
    simply carry over).

    Examples
    --------
    >>> table = BernoulliFaults(p=0.9, seed=1).alive(3, 8)
    >>> bool(table[:, 0].all())                  # protected worker
    True
    >>> bool(table[:, 1:].all())                 # the rest actually fail
    False
    """

    p: float
    seed: int = 0
    protect_one: bool = True

    def alive(self, num_workers: int, rounds: int) -> np.ndarray:
        rng = np.random.default_rng(self.seed)
        up = rng.random((rounds, num_workers)) >= self.p
        if self.protect_one:
            up[:, 0] = True
        return up


@dataclasses.dataclass(frozen=True)
class OutageFaults(FaultPolicy):
    """Scripted outages: ``events`` is a tuple of (worker, start_round,
    end_round) half-open intervals during which the worker is down. Good for
    reproducing a specific incident in tests and benchmarks.

    Examples
    --------
    Worker 1 down for rounds [1, 3):

    >>> OutageFaults(events=((1, 1, 3),)).alive(2, 4)[:, 1]
    array([ True, False, False,  True])
    """

    events: tuple  # ((worker, start, end), ...)

    def alive(self, num_workers: int, rounds: int) -> np.ndarray:
        up = np.ones((rounds, num_workers), dtype=bool)
        for worker, start, end in self.events:
            up[int(start):int(end), int(worker)] = False
        return up
