"""Parameter-Server runtime: ONE engine for the whole optimizer zoo —
heterogeneity, compression, faults, resume and telemetry, for LocalAdaSEG
*and* every baseline the paper compares it against (§4/Fig. 4).

The one-shot drivers (``core.adaseg.run_local_adaseg``,
``launch.sharded.run_local_adaseg_sharded``) execute an *idealized* PS: every
worker synchronous, every message dense, nobody ever dies. This package turns
the round loop into a configurable runtime, generic over the
:class:`~repro.core.worker.LocalWorker` protocol:

* ``PSConfig(adaseg=AdaSEGConfig(...))`` — the paper's Algorithm 1, with the
  ``backend="reference" | "fused"`` Pallas step kernels passing through;
* ``PSConfig(worker=MinimaxWorker(opt), local_k=K)`` — any zoo optimizer
  (``optim.methods``: SGDA, SEGDA, minimax-Adam, UMP, ASMP) on the exact
  same runtime, so the paper's comparison figures run under the same
  hostile-fleet scenarios (``benchmarks/bench_fig4_scenarios.py``).

Map from engine hooks to the paper's Algorithm 1 (LocalAdaSEG) lines:

====================  =====================================================
Algorithm 1           engine hook
====================  =====================================================
Line 3–4              ``WorkerSchedule`` → per-round K_m^r local steps, run
(local steps,         by ``LocalWorker.step`` with the ``enabled`` mask;
adaptive η)           adaptive rates stay worker-local — stragglers simply
                      take fewer steps.
Line 5                ``SyncCompressor`` → each survivor uploads a
(workers → server)    compressed w·payload message (bytes-up telemetry);
                      biased codecs run under error feedback.
Line 6                ``FaultPolicy`` → the sync weights (1/η for AdaSEG,
(weights w ∝ 1/η)     uniform for the plain zoo) are renormalized over the
                      round's survivors; dead workers keep a stale payload.
Line 7                server sums the decompressed messages — identity
(weighted average)    compression reproduces ``sync_weighted_stacked``
                      bit-exactly; sharded execution collapses this to one
                      ``lax.psum`` all-reduce.
Line 8                survivors receive the new anchor/iterate (bytes-down
(server → workers)    telemetry).
Line 14               ``PSEngine.z_bar`` → worker outputs weighted by
(global output z̄)     *realized* step counts (``weighted_worker_average``).
====================  =====================================================

The sync hot path has its own kernel backend (``codec_backend="reference" |
"fused"`` on either config): the fused path runs the whole Line-5/7 uplink —
error-feedback add, 1/η weighting, stochastic quantize / top-k, residual
write-back — and the server-side weighted merge as fused Pallas sweeps
(``kernels.sync_compress``), with the quantizer's rounding bits generated
in-kernel from the same threefry derivation the reference codecs use.
Identity/top-k are bit-exact across backends, stochastic quantize agrees to
rtol=1e-5, in all three execution semantics (``tests/test_sync_compress.py``,
``tests/test_distributed.py``).

``PSEngine`` drives both execution paths (serial vmap / ``shard_map`` with a
compressed psum), records per-round traces with wall-clock and
local-steps/sec throughput (``ps.trace``), and checkpoints mid-stream via
``checkpoint.serialize`` — schedules and fault traces are deterministic
functions of their seeds, so a resumed run replays the exact same scenario,
and optimizer-specific ``inner`` state (Adam moments, UMP accumulators)
round-trips bit-exactly. Restores from a different seed or optimizer are
rejected. ``ps.partition`` carves Dirichlet-skewed per-worker oracles so
homogeneous vs heterogeneous data is a config flag.

Second execution semantics — **simulated time** (``ps.async_engine``):
``AsyncPSEngine`` replaces the per-round barrier with a discrete-event
simulation. A :mod:`~repro.ps.latency` model (constant, lognormal jitter,
Markov slow/fast, trace-driven — all seed-deterministic) assigns every
worker-round its compute and network delays; the server admits each
worker's uplink *as it arrives* under a bounded-staleness rule (τ =
``staleness_bound``: ∞ never blocks, 0 is a barrier), re-weights the Line-7
average by ``1/η · 1/(1+staleness)^γ`` over its last-heard payload table,
and broadcasts back per arrival. Traces gain ``sim_time_s``, per-entry
``staleness`` and fleet ``idle_frac``, turning ``benchmarks/bench_async``
into genuine time-to-target-residual curves.

The sync engine is a *special case with a guarantee*: whenever an admission
batch is the whole fleet in the same round (worker-equal constant latency
with any τ, or any latency with τ=0), ``AsyncPSEngine`` executes
``PSEngine``'s own compiled round chunk — so the synchronous trajectory is
reproduced **bit-exactly** by shared code (identity compression/no faults;
pinned by ``tests/test_ps_async.py``). Schedules, compressors (per-payload
uplinks with error feedback), fault policies and checkpoint/resume all
compose: a killed simulation restores mid-event-queue bit-exactly — the
per-worker event machine (status/time/round arrays) *is* the queue, so
loading the arrays restores it wholesale, with every policy re-derived from
its seed.

Third axis — **fleet scale** (partial client participation):
``ClientSampler`` (``ps.sampler``) makes ``num_workers`` a *fleet* size N
while each round materializes only ``sample`` = M drawn workers: the sync
engine gathers the M sampled lanes out of the compact (N, …) fleet store,
runs the round chunk at width M, and scatters the updated lanes back;
the async engine skips un-drawn rounds at zero simulated cost. Draws are
seed-deterministic (uniform or weighted, without replacement), checkpoints
carry a sampler fingerprint so resumes can't silently replay a different
participation table, and ``sampler=None`` preserves the full-participation
trajectories bit-exactly (``benchmarks/bench_fleet.py`` sweeps the axis).

Fourth axis — **hostility** (``ps.robust``): the fleet stops being honest.
``PSConfig(byzantine=…)`` corrupts a seed-deterministic per-(round, worker)
subset of uplinks *after* local compute and *before* compression (sign-flip,
scaled noise, zeros, collusion); ``aggregator=…`` swaps the Line-7 weighted
mean for a robust order-statistic merge (trimmed-mean(β), coordinate-median,
multi-Krum) with fused-Pallas and reference twins; ``dp=…`` adds per-worker
l2 clipping + Gaussian noise against an honest-but-curious server. All three
compose with codecs/EF, faults, client sampling, and both engines (the async
machine attacks at store time and robust-merges at admission — τ=0 lockstep
still runs the *same compiled chunk* as the sync engine). At zero
robustness budget the historical bit-exact paths are compiled unchanged;
checkpoints gain an ``aggregator_fp`` so a resume can't silently switch
merge semantics (``tests/test_robust_agg.py``).

Fifth axis — **two-level optimization** (``ps.server_opt``): the server
stops being a passive averager. ``PSConfig(server_opt=…)`` treats each
round's merged delta Δ = merge(z̃) − z_server as a pseudo-gradient and
runs an outer optimizer over it — :class:`ServerMomentum`,
:class:`ServerNesterov` (DiLoCo's choice), or :class:`ServerAdam`
(FedOpt's FedAdam) — broadcasting the *post-step* server anchor instead
of the raw mean. The outer step runs **downstream** of the robust
aggregators and the fused merge kernel, with a fused Pallas variant that
keeps the moment update + apply in-register (one extra HBM pass over the
merged leaf) and a bit-exact reference twin; the async engine applies it
per admission (τ=0 lockstep shares the sync engine's compiled chunk).
Checkpoints serialize the outer moments plus a ``server_opt_fp``
fingerprint; ``server_opt=None`` / :class:`NoServerOpt` compiles the
historical Line-7 broadcast byte-identically (``tests/test_server_opt.py``).

    >>> from repro.ps import NoServerOpt, ServerNesterov
    >>> NoServerOpt().spec is None
    True
    >>> ServerNesterov(lr=0.5).spec
    ('nesterov', 0.5, 0.9)
"""
from ..core.worker import AdaSEGWorker, LocalWorker
from ..models.worker import ModelWorker
from .async_engine import AsyncPSConfig, AsyncPSEngine
from .compress import (
    IdentityCompressor,
    StochasticQuantizeCompressor,
    SyncCompressor,
    TopKCompressor,
    check_codec_backend,
    dense_bytes,
    make_compressed_psum_sync,
)
from .engine import PSConfig, PSEngine
from .faults import BernoulliFaults, FaultPolicy, NoFaults, OutageFaults
from .sampler import ClientSampler
from .latency import (
    ConstantLatency,
    LatencyModel,
    LatencyTables,
    LognormalLatency,
    MarkovLatency,
    TraceLatency,
)
from .robust import (
    ByzantinePolicy,
    CollusionAttack,
    CoordinateMedian,
    DPUplink,
    MultiKrum,
    RobustAggregator,
    ScaledNoiseAttack,
    SignFlipAttack,
    TrimmedMean,
    WeightedMean,
    ZeroAttack,
)
from .partition import (
    heterogeneous_bilinear,
    heterogeneous_robust,
    heterogeneous_wgan,
    heterogenize,
)
from .server_opt import (
    NoServerOpt,
    ServerAdam,
    ServerMomentum,
    ServerNesterov,
    ServerOptimizer,
    resolve_server_opt,
)
from .schedule import (
    ElasticSchedule,
    FixedSchedule,
    StragglerSchedule,
    UniformSchedule,
    WorkerSchedule,
)
from .trace import RoundRecord, TraceRecorder

__all__ = [
    "AdaSEGWorker",
    "AsyncPSConfig",
    "AsyncPSEngine",
    "BernoulliFaults",
    "ByzantinePolicy",
    "ClientSampler",
    "CollusionAttack",
    "ConstantLatency",
    "CoordinateMedian",
    "DPUplink",
    "ElasticSchedule",
    "FaultPolicy",
    "FixedSchedule",
    "IdentityCompressor",
    "LatencyModel",
    "LatencyTables",
    "LocalWorker",
    "LognormalLatency",
    "MarkovLatency",
    "ModelWorker",
    "MultiKrum",
    "NoFaults",
    "NoServerOpt",
    "OutageFaults",
    "PSConfig",
    "PSEngine",
    "RobustAggregator",
    "RoundRecord",
    "ScaledNoiseAttack",
    "ServerAdam",
    "ServerMomentum",
    "ServerNesterov",
    "ServerOptimizer",
    "SignFlipAttack",
    "TraceLatency",
    "StochasticQuantizeCompressor",
    "StragglerSchedule",
    "SyncCompressor",
    "TopKCompressor",
    "TraceRecorder",
    "TrimmedMean",
    "UniformSchedule",
    "WeightedMean",
    "WorkerSchedule",
    "ZeroAttack",
    "check_codec_backend",
    "dense_bytes",
    "heterogeneous_bilinear",
    "heterogeneous_robust",
    "heterogeneous_wgan",
    "heterogenize",
    "make_compressed_psum_sync",
    "resolve_server_opt",
]
