"""Parameter-Server runtime: LocalAdaSEG's Algorithm 1 as a distributed-
system simulator — heterogeneity, compression, faults and resume.

The one-shot drivers (``core.adaseg.run_local_adaseg``,
``launch.sharded.run_local_adaseg_sharded``) execute an *idealized* PS: every
worker synchronous, every message dense, nobody ever dies. This package turns
the round loop into a configurable runtime. Map from engine hooks to the
paper's Algorithm 1 (LocalAdaSEG) line numbers:

====================  =====================================================
Algorithm 1           engine hook
====================  =====================================================
Line 3–4              ``WorkerSchedule`` → per-round K_m^r local
(local extragradient  extragradient steps, run by ``core.adaseg.local_step``
steps, adaptive η)    with the ``enabled`` mask; η stays the worker-local
                      AdaGrad rate — stragglers simply take fewer steps.
Line 5                ``SyncCompressor`` → each survivor uploads a
(workers → server)    compressed w·z̃ message (bytes-up telemetry); biased
                      codecs run under error feedback.
Line 6                ``FaultPolicy`` → the inverse-stepsize weights
(weights w ∝ 1/η)     w_m ∝ 1/η_m are renormalized over the round's
                      survivors; dead workers keep their stale anchor.
Line 7                server sums the decompressed messages — identity
(weighted average)    compression reproduces ``sync_weighted_stacked``
                      bit-exactly; sharded execution collapses this to one
                      ``lax.psum`` all-reduce.
Line 8                survivors receive the new anchor z̃° (bytes-down
(server → workers)    telemetry).
Line 14               ``PSEngine.z_bar`` → worker means weighted by
(global output z̄)     *realized* step counts (``weighted_worker_average``).
====================  =====================================================

``PSEngine`` drives both execution paths (serial vmap / ``shard_map`` with a
compressed psum) with ``backend="reference" | "fused"`` passing through to
the step kernels, records per-round traces (``ps.trace``), and checkpoints
mid-stream via ``checkpoint.serialize`` — schedules and fault traces are
deterministic functions of their seeds, so a resumed run replays the exact
same scenario. ``ps.partition`` carves Dirichlet-skewed per-worker oracles
so homogeneous vs heterogeneous data is a config flag.
"""
from .compress import (
    IdentityCompressor,
    StochasticQuantizeCompressor,
    SyncCompressor,
    TopKCompressor,
    dense_bytes,
    make_compressed_psum_sync,
)
from .engine import PSConfig, PSEngine
from .faults import BernoulliFaults, FaultPolicy, NoFaults, OutageFaults
from .partition import (
    heterogeneous_bilinear,
    heterogeneous_robust,
    heterogeneous_wgan,
    heterogenize,
)
from .schedule import (
    ElasticSchedule,
    FixedSchedule,
    StragglerSchedule,
    UniformSchedule,
    WorkerSchedule,
)
from .trace import RoundRecord, TraceRecorder

__all__ = [
    "BernoulliFaults",
    "ElasticSchedule",
    "FaultPolicy",
    "FixedSchedule",
    "IdentityCompressor",
    "NoFaults",
    "OutageFaults",
    "PSConfig",
    "PSEngine",
    "RoundRecord",
    "StochasticQuantizeCompressor",
    "StragglerSchedule",
    "SyncCompressor",
    "TopKCompressor",
    "TraceRecorder",
    "UniformSchedule",
    "WorkerSchedule",
    "dense_bytes",
    "heterogeneous_bilinear",
    "heterogeneous_robust",
    "heterogeneous_wgan",
    "heterogenize",
    "make_compressed_psum_sync",
]
