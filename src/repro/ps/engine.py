"""Parameter-Server round engine — optimizer-generic (Algorithm 1 at fleet
scale, for the whole zoo).

The engine owns the round loop of the paper's Parameter-Server model and
threads the pluggable policies through it:

* :class:`~repro.core.worker.LocalWorker` → everything optimizer-specific:
  init, the (enabled-masked) local step, the Line-7 sync weight/payload,
  the output iterate. ``AdaSEGWorker`` is the paper's Algorithm 1;
  ``optim.base.MinimaxWorker`` lifts every zoo baseline (SGDA, SEGDA,
  Adam, UMP, ASMP) onto the same runtime;
* :class:`~repro.ps.schedule.WorkerSchedule` → per-round, per-worker local
  step counts K_m^r (Line 3–4), fed through the worker's ``enabled`` mask;
* :class:`~repro.ps.compress.SyncCompressor` → lossy codec for the uphill
  w·payload messages (Line 5/7), with error feedback when biased;
* :class:`~repro.ps.faults.FaultPolicy` → per-round worker failures, with
  the sync weights renormalized over survivors (Line 6–7) and dead workers
  keeping their stale payload;
* :class:`~repro.ps.trace.TraceRecorder` → per-round telemetry (bytes
  up/down, effective K, η spread, wall-clock, local-steps/sec, residual).

Two execution paths, same semantics:

* ``mesh=None`` — the serial vmap path (a stacked worker axis). With the
  AdaSEG worker, the identity compressor, no faults and a uniform schedule
  this path is **bit-exact** with ``core.adaseg.run_local_adaseg``; with a
  ``MinimaxWorker`` it reproduces the historical ``optim.base.run_local``
  trajectories (each worker carries its family's rng derivation).
* ``mesh=...`` — one worker per shard of ``worker_axes`` via ``shard_map``,
  with Line 7 as a single psum all-reduce of the (compressed) weighted
  messages, like ``launch.sharded.run_local_adaseg_sharded``.

Checkpointed execution: the engine state (per-worker optimizer state —
including optimizer-specific ``inner`` extras like Adam moments or UMP
accumulators — error-feedback memory, round counter, seed and optimizer
fingerprints) serializes through ``checkpoint.serialize``; schedules and
fault traces are *re-derived* from the config seeds rather than stored, so
a killed run resumes bit-exactly (serial) mid-stream. Restores from a
different seed *or a different optimizer* are rejected.
"""
from __future__ import annotations

import dataclasses
import warnings
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax

from ..checkpoint.serialize import load_pytree, save_pytree
from ..core.adaseg import AdaSEGConfig, weighted_worker_average
from ..core.tree import tree_add, tree_sub, tree_where, tree_zeros_like
from ..core.types import MinimaxProblem
from ..core.worker import AdaSEGWorker, LocalWorker
from ..obs import MetricsRegistry, SpanTracer, modeled_sync_cost
from .compress import (
    IdentityCompressor,
    SyncCompressor,
    check_codec_backend,
    dense_bytes,
)
from .faults import FaultPolicy, NoFaults
from .robust import ByzantinePolicy, DPUplink, RobustAggregator, WeightedMean
from .sampler import ClientSampler
from .schedule import UniformSchedule, WorkerSchedule
from .server_opt import NoServerOpt, ServerOptimizer, resolve_server_opt
from .trace import RoundRecord, TraceRecorder

PyTree = Any

# The chunk jits donate the stacked state/EF buffers (the engine never
# reads them after the call), so a 10k-worker fleet updates in place
# instead of round-tripping host<->device copies every chunk. CPU ignores
# donation (it has no aliasing support in this jax build) and would warn
# once per compile; the semantics are identical either way.
warnings.filterwarnings(
    "ignore", message="Some donated buffers were not usable"
)


@dataclasses.dataclass(frozen=True)
class PSConfig:
    """Everything the Parameter-Server simulator needs beyond the problem.

    The optimizer is given either as ``adaseg=`` (an :class:`AdaSEGConfig`,
    wrapped into an :class:`AdaSEGWorker` with ``backend`` — the historical
    spelling, kept as the primary one for the paper's method) or as
    ``worker=`` (any :class:`LocalWorker`, e.g. ``MinimaxWorker(sgda(...))``
    for the zoo). Generic workers carry no communication interval of their
    own, so give them ``local_k=`` (or an explicit ``schedule=``).

    Examples
    --------
    >>> from repro.core import AdaSEGConfig
    >>> cfg = PSConfig(adaseg=AdaSEGConfig(g0=1.0, diameter=2.0, k=5),
    ...                num_workers=4, rounds=10, codec_backend="fused")
    >>> cfg.num_workers, cfg.codec_backend
    (4, 'fused')
    """

    num_workers: int
    rounds: int
    adaseg: AdaSEGConfig | None = None       # AdaSEG spelling (+ backend)
    worker: LocalWorker | None = None        # generic spelling
    local_k: int | None = None               # uniform K for generic workers
    schedule: WorkerSchedule | None = None   # default: uniform K
    compressor: SyncCompressor | None = None  # default: identity
    faults: FaultPolicy | None = None        # default: no faults
    backend: str = "reference"               # AdaSEG step backend
    codec_backend: str = "reference"         # sync codec: reference | fused
    # Sampled-client rounds: draw sampler.sample of num_workers fleet
    # members per round (None = full participation, the historical path).
    sampler: ClientSampler | None = None
    # Hostile-fleet subsystem (repro.ps.robust). Any of these switches the
    # uplink to the unweighted wire format with Line-7 weights applied
    # server-side; all None (or a zero-budget aggregator) compiles the
    # identical historical path.
    byzantine: ByzantinePolicy | None = None  # adversarial uplinks
    aggregator: RobustAggregator | None = None  # robust server merge
    dp: DPUplink | None = None               # l2 clip + Gaussian noise
    # Server-side outer optimizer over round deltas (DiLoCo/FedOpt):
    # None (or NoServerOpt) is the historical Line-7 broadcast, bit-exact.
    server_opt: ServerOptimizer | None = None


@dataclasses.dataclass(frozen=True)
class RobustPipeline:
    """The *resolved* hostile-fleet configuration threaded into the chunk
    builders (and their process-wide cache keys): the attack policy, the
    static merge spec at the compiled lane width, and the DP transform.
    ``None`` anywhere means that layer is off; the engines only build a
    pipeline at all when at least one layer is active."""

    byzantine: ByzantinePolicy | None
    agg: tuple | None
    dp: DPUplink | None


def resolve_robust(config: PSConfig, lanes: int) -> RobustPipeline | None:
    """Resolve a config's hostile-fleet fields at compiled lane width
    ``lanes`` (the sampled width under a ``ClientSampler``, else the
    fleet). Returns ``None`` — the exact historical path — when no attack,
    no DP, and the aggregator degrades (``spec(lanes) is None``)."""
    agg = config.aggregator or WeightedMean()
    spec = agg.spec(lanes)
    if config.byzantine is None and spec is None and config.dp is None:
        return None
    return RobustPipeline(config.byzantine, spec, config.dp)


def _resolve_worker(config: PSConfig) -> LocalWorker:
    if config.worker is not None and config.adaseg is not None:
        raise ValueError("give either adaseg= or worker=, not both")
    if config.worker is not None:
        if config.backend != "reference":
            # backend only parameterizes the AdaSEGWorker this config would
            # build; a custom worker brings its own — don't ignore it silently
            raise ValueError(
                "backend= has no effect on an explicit worker=; set the "
                "backend on the worker itself (e.g. AdaSEGWorker(cfg, "
                "backend=...))"
            )
        return config.worker
    if config.adaseg is not None:
        return AdaSEGWorker(config.adaseg, backend=config.backend)
    raise ValueError("PSConfig needs adaseg= or worker=")


def _resolve_schedule(config: PSConfig) -> WorkerSchedule:
    if config.schedule is not None:
        return config.schedule
    if config.local_k is not None:
        return UniformSchedule(config.local_k)
    if config.adaseg is not None:
        return UniformSchedule(config.adaseg.k)
    raise ValueError(
        "a generic worker has no communication interval of its own — "
        "give PSConfig a schedule= or local_k="
    )


def _per_worker(mask, leaf):
    """Broadcast a (M,) mask over a worker-stacked leaf."""
    return mask.reshape((-1,) + (1,) * (leaf.ndim - 1))


# ---------------------------------------------------------------------------
# Compiled-chunk cache. One jitted chunk per (problem, worker, compressor,
# fleet, k_pad, eval, faults, codec, sampler) configuration, shared across
# every engine instance in the process — so building a second engine with
# the same config (benchmark loops, checkpoint-restore drills, the async
# engine's lockstep path) reuses the compiled program instead of retracing.
# jax.jit's own cache then keys on argument shapes, so a remainder chunk
# (checkpoint_every leaving rounds % every != 0) costs exactly one extra
# trace per distinct scan length, ever.
# ---------------------------------------------------------------------------

_CHUNK_CACHE: dict = {}
_TRACE_COUNT = 0


def _count_trace() -> None:
    # Called from inside the traced chunk body: jax executes the Python
    # body exactly once per trace (i.e. per compilation), so this global
    # counts compilations — the same signal jax.monitoring's
    # '/jax/core/compile' events carry, without requiring a listener hook.
    global _TRACE_COUNT
    _TRACE_COUNT += 1


def serial_chunk_traces() -> int:
    """Process-wide count of serial round-chunk tracings (≈ compilations).
    Regression tests read deltas of this to pin that remainder chunks and
    same-config engines do not retrigger compilation."""
    return _TRACE_COUNT


def _hashable(x):
    try:
        hash(x)
        return x
    except TypeError:
        return id(x)


def cached_chunk(key: tuple, builder, *, donate: bool = True):
    """Memoize ``jax.jit(builder(), donate_argnums=(0, 1))`` on ``key``.

    Unhashable key components fall back to ``id()``; the cache entry keeps
    a strong reference to the raw key objects so an id is never recycled
    while its entry is alive."""
    k = tuple(_hashable(x) for x in key) + (donate,)
    hit = _CHUNK_CACHE.get(k)
    if hit is not None:
        return hit[0]
    fn = jax.jit(builder(), donate_argnums=(0, 1) if donate else ())
    _CHUNK_CACHE[k] = (fn, key)
    return fn


def make_sync_stacked(worker: LocalWorker, compressor: SyncCompressor,
                      num_workers: int, codec_backend: str = "reference",
                      robust: RobustPipeline | None = None,
                      server: ServerOptimizer | None = None):
    """Line 5–8 on the stacked worker axis: compress(w·payload) per worker,
    server sum, broadcast to survivors. The returned function takes
    ``(state, ef, alive_r, c_rng)``; ``alive_r is None`` means the fault
    policy statically guarantees everyone is up — that path emits the *same
    expressions* as the one-shot drivers' syncs, so identity/no-fault
    rounds stay bit-exact with them (dynamic all-True masks would still
    perturb XLA fusion).

    ``codec_backend="fused"`` swaps the message-scale / EF add / codec /
    residual tree pipeline and the weighted-sum-broadcast server side for
    the fused Pallas sweeps of ``kernels.sync_compress`` (identity and
    top-k stay bit-exact with this reference path; stochastic quantize
    agrees to float tolerance under the shared threefry derivation).

    Module-level so the event-driven engine can build the *identical*
    program: bit-parity between the engines is shared code, not a
    maintained coincidence.

    ``robust`` (a resolved :class:`RobustPipeline`) swaps in the hostile-
    fleet round and changes the signature to ``(state, ef, alive_r, c_rng,
    byz_r)`` — ``byz_r`` the (M,) attacked-lane mask. The wire format
    becomes *unweighted* (the async engine's native one): attacks and the
    DP transform corrupt/privatize the raw z̃ payload after local compute,
    the codec compresses that, and Line-7 weights + the robust aggregation
    happen server-side in ``sync_merge_stacked(agg=...)`` — order
    statistics must rank workers' iterates, not their weighted messages.
    Attack/DP keys fold constants 13/11 off the per-worker codec keys, so
    both engines (and resumes) corrupt identically.

    ``server`` (a *resolved* :class:`~repro.ps.server_opt.ServerOptimizer`,
    i.e. never ``NoServerOpt``) inserts the outer-optimizer step between
    the (robust) merge and delivery: the merge runs ungated, its row-0
    mean becomes the pseudo-gradient Δ against the server anchor, and the
    *post-step* anchor is what survivors receive — recv gating moves from
    the merge to the broadcast, which is semantics-preserving because recv
    only ever gated delivery, never the mean. The closures then take a
    trailing ``srv = (z, moments, t)`` carry and return
    ``(state, ef_new, srv_new, telem)`` with ``telem = [eff_lr, ‖Δ‖]``.
    ``server=None`` compiles the byte-identical historical closures.
    """
    comp = compressor
    m = num_workers

    if server is not None:
        from ..kernels.sync_compress.ops import server_outer_apply

        srv_spec = server.spec
        srv_kernel = codec_backend == "fused"

        def outer_broadcast(state, merged, recv, payload, srv):
            """Row-0 of the ungated merge → outer step → gated delivery."""
            z, mom, t = srv
            merged_row = jax.tree.map(lambda v: v[:1], merged)
            z_new, mom_new, t_new, eff_lr, dn = server_outer_apply(
                merged_row, z, mom, t, spec=srv_spec,
                use_kernel=srv_kernel,
            )
            if recv is None:
                synced = jax.tree.map(
                    lambda v, old: jnp.broadcast_to(v, old.shape),
                    z_new, payload,
                )
            else:
                synced = jax.tree.map(
                    lambda v, old: jnp.where(
                        _per_worker(recv, old),
                        jnp.broadcast_to(v, old.shape), old,
                    ),
                    z_new, payload,
                )
            telem = jnp.stack([eff_lr, dn])
            return (worker.merge_synced(state, synced),
                    (z_new, mom_new, t_new), telem)
    if robust is not None:
        from ..kernels.sync_compress.ops import (
            codec_uplink_stacked,
            sync_merge_stacked,
        )

        use_kernel = codec_backend == "fused"

        @jax.named_scope("sync-robust")
        def sync_stacked_robust(state, ef, alive_r, c_rng, byz_r,
                                srv=None):
            sw = jax.vmap(worker.sync_weight)(state)          # (M,)
            if alive_r is None:
                w_raw = sw
                recv = None
            else:
                w_raw = jnp.where(alive_r, sw, jnp.zeros_like(sw))
                any_alive = jnp.sum(w_raw) > 0.0
                recv = jnp.logical_and(alive_r, any_alive)
            payload = worker.sync_payload(state)
            c_rngs = jax.random.split(c_rng, m)
            uplink = payload
            if robust.byzantine is not None:
                a_rngs = jax.vmap(
                    lambda k: jax.random.fold_in(k, 13)
                )(c_rngs)
                uplink = robust.byzantine.apply(uplink, byz_r, a_rngs)
            if robust.dp is not None:
                d_rngs = jax.vmap(
                    lambda k: jax.random.fold_in(k, 11)
                )(c_rngs)
                uplink = robust.dp.apply(uplink, d_rngs)
            if comp.is_identity:
                sent, ef_new = uplink, ef
            else:
                sent, ef_new = codec_uplink_stacked(
                    uplink, c_rngs, w=None,
                    ef=ef if comp.error_feedback else None,
                    alive=alive_r, codec=comp.codec_spec,
                    use_kernel=use_kernel,
                )
                if not comp.error_feedback:
                    ef_new = ef
            if server is not None:
                # ungated robust merge → outer step → gated delivery
                merged = sync_merge_stacked(
                    sent, w=w_raw, normalize=True, agg=robust.agg,
                    use_kernel=use_kernel,
                )
                state, srv_new, telem = outer_broadcast(
                    state, merged, recv, payload, srv
                )
                return state, ef_new, srv_new, telem
            synced = sync_merge_stacked(
                sent, w=w_raw, recv=recv,
                old=None if recv is None else payload,
                normalize=True, agg=robust.agg, use_kernel=use_kernel,
            )
            return worker.merge_synced(state, synced), ef_new

        return sync_stacked_robust

    if codec_backend == "fused":
        from ..kernels.sync_compress.ops import (
            codec_uplink_stacked,
            sync_merge_stacked,
        )

        @jax.named_scope("sync")
        def sync_stacked_fused(state, ef, alive_r, c_rng, srv=None):
            sw = jax.vmap(worker.sync_weight)(state)          # (M,)
            if alive_r is None:
                recv = None
                w = sw / jnp.sum(sw)
            else:
                w_raw = jnp.where(alive_r, sw, jnp.zeros_like(sw))
                denom = jnp.sum(w_raw)
                any_alive = denom > 0.0
                w = w_raw / jnp.where(any_alive, denom, 1.0)
                recv = jnp.logical_and(alive_r, any_alive)
            payload = worker.sync_payload(state)
            if comp.is_identity:
                if server is not None:
                    merged = sync_merge_stacked(payload, w)
                    state, srv_new, telem = outer_broadcast(
                        state, merged, recv, payload, srv
                    )
                    return state, ef, srv_new, telem
                # one fused sweep: w-scale + server sum + broadcast
                synced = sync_merge_stacked(payload, w, recv=recv,
                                            old=None if recv is None
                                            else payload)
                return worker.merge_synced(state, synced), ef
            c_rngs = jax.random.split(c_rng, m)
            sent, ef_new = codec_uplink_stacked(
                payload, c_rngs, w=w,
                ef=ef if comp.error_feedback else None,
                alive=alive_r, codec=comp.codec_spec,
            )
            if not comp.error_feedback:
                ef_new = ef
            if server is not None:
                merged = sync_merge_stacked(sent)
                state, srv_new, telem = outer_broadcast(
                    state, merged, recv, payload, srv
                )
                return state, ef_new, srv_new, telem
            synced = sync_merge_stacked(sent, recv=recv,
                                        old=None if recv is None
                                        else payload)
            return worker.merge_synced(state, synced), ef_new

        return sync_stacked_fused

    @jax.named_scope("sync")
    def sync_stacked(state, ef, alive_r, c_rng, srv=None):
        sw = jax.vmap(worker.sync_weight)(state)              # (M,)
        if alive_r is None:
            any_alive = None
            w = sw / jnp.sum(sw)
        else:
            w_raw = jnp.where(alive_r, sw, jnp.zeros_like(sw))
            denom = jnp.sum(w_raw)
            any_alive = denom > 0.0
            w = w_raw / jnp.where(any_alive, denom, 1.0)

        payload = worker.sync_payload(state)
        messages = jax.tree.map(
            lambda leaf: _per_worker(w, leaf).astype(leaf.dtype) * leaf,
            payload,
        )
        if comp.is_identity:
            sent, ef_new = messages, ef
        elif alive_r is None:
            c_rngs = jax.random.split(c_rng, m)
            eff = tree_add(messages, ef) if comp.error_feedback else messages
            sent = jax.vmap(comp.compress)(eff, c_rngs)
            ef_new = tree_sub(eff, sent) if comp.error_feedback else ef
        else:
            c_rngs = jax.random.split(c_rng, m)
            eff = tree_add(messages, ef) if comp.error_feedback else messages
            sent = jax.vmap(comp.compress)(eff, c_rngs)
            # dead workers send nothing and keep their error memory frozen
            sent = jax.tree.map(
                lambda s: jnp.where(_per_worker(alive_r, s), s, 0.0), sent
            )
            if comp.error_feedback:
                ef_new = jax.tree.map(
                    lambda e_new, e_old: jnp.where(
                        _per_worker(alive_r, e_new), e_new, e_old
                    ),
                    tree_sub(eff, sent), ef,
                )
            else:
                ef_new = ef

        if server is not None:
            merged = jax.tree.map(
                lambda s: jnp.sum(s, axis=0, keepdims=True), sent
            )
            recv = (None if alive_r is None
                    else jnp.logical_and(alive_r, any_alive))
            state, srv_new, telem = outer_broadcast(
                state, merged, recv, payload, srv
            )
            return state, ef_new, srv_new, telem
        if alive_r is None:
            synced = jax.tree.map(
                lambda s: jnp.broadcast_to(
                    jnp.sum(s, axis=0, keepdims=True), s.shape
                ),
                sent,
            )
        else:
            recv = jnp.logical_and(alive_r, any_alive)        # (M,)
            synced = jax.tree.map(
                lambda s, old: jnp.where(
                    _per_worker(recv, old),
                    jnp.broadcast_to(
                        jnp.sum(s, axis=0, keepdims=True), old.shape
                    ),
                    old,
                ),
                sent, payload,
            )
        return worker.merge_synced(state, synced), ef_new

    return sync_stacked


def make_serial_chunk(
    problem: MinimaxProblem,
    worker: LocalWorker,
    compressor: SyncCompressor,
    num_workers: int,
    k_pad: int,
    eval_fn,
    no_faults: bool,
    codec_backend: str = "reference",
    robust: RobustPipeline | None = None,
    server: ServerOptimizer | None = None,
):
    """Build the serial-path round chunk: scan of (sync → K_m^r masked local
    steps) over a leading rounds axis. ``PSEngine`` jits this as its whole
    execution path; ``AsyncPSEngine`` jits the identical program and feeds
    it one-round slices whenever an admission batch is full-fleet lockstep,
    which is what makes the synchronous engine a *bit-exact special case*
    of the event-driven one (the chunking-invariance test pins that a
    1-round slice equals the full scan).

    With a :class:`RobustPipeline` the chunk signature gains a ``byz``
    ``(C, M)`` attacked-lane table between ``alive`` and ``counts_cum``.

    Returns ``(state, ef, eta_stats, ress)`` where ``eta_stats`` is
    ``(C, 3)`` per-round ``[min, max, mean]`` over the fleet — the
    telemetry reduction happens on device so the per-chunk device→host
    transfer is O(rounds), not O(rounds × fleet).

    A resolved ``server`` outer optimizer threads its ``(z, moments, t)``
    state through the scan carry: the chunk takes it as a trailing ``srv``
    argument (after ``counts_cum``, so the donated state/EF positions are
    untouched) and the return grows to ``(state, ef, eta_stats, ress,
    srv, outer)`` with ``outer`` the per-round ``(C, 2)``
    ``[eff_lr, ‖Δ‖]`` telemetry. ``server=None`` builds the historical
    chunk, signature and jaxpr unchanged."""
    m = num_workers
    sync_stacked = make_sync_stacked(worker, compressor, m, codec_backend,
                                     robust, server)

    vstep = jax.vmap(
        lambda st, rr, en: worker.step(problem, st, rr, enabled=en)
    )
    veta = jax.vmap(worker.eta)

    def round_body(carry, inputs):
        if server is not None:
            state, ef, srv = carry
        else:
            state, ef = carry
            srv = None
        telem = None
        if robust is not None:
            rng_round, ks_r, alive_r, byz_r, counts_r = inputs
            sync_args = (state, ef, None if no_faults else alive_r,
                         jax.random.fold_in(rng_round, 7), byz_r)
        else:
            rng_round, ks_r, alive_r, counts_r = inputs
            sync_args = (state, ef, None if no_faults else alive_r,
                         jax.random.fold_in(rng_round, 7))
        if server is not None:
            state, ef, srv, telem = sync_stacked(*sync_args, srv)
        else:
            state, ef = sync_stacked(*sync_args)

        # Line 3–4: K_m^r masked local steps.
        step_rngs = jax.random.split(rng_round, k_pad * m).reshape(
            k_pad, m, 2
        )

        def body(st, inp):
            rngs, i = inp
            enabled = i < ks_r
            if not no_faults:
                enabled = jnp.logical_and(enabled, alive_r)
            st = vstep(st, rngs, enabled)
            return st, None

        with jax.named_scope("local-compute"):
            state, _ = lax.scan(
                body, state, (step_rngs, jnp.arange(k_pad))
            )

        eta_end = veta(state)                             # (M,)
        eta_stats = jnp.stack([
            jnp.min(eta_end), jnp.max(eta_end), jnp.mean(eta_end)
        ])
        with jax.named_scope("eval"):
            if eval_fn is None:
                res = jnp.float32(jnp.nan)
            else:
                counts = jnp.where(
                    jnp.sum(counts_r) > 0.0, counts_r,
                    jnp.ones_like(counts_r),
                )
                res = jnp.asarray(
                    eval_fn(weighted_worker_average(
                        worker.output(state), counts
                    )),
                    dtype=jnp.float32,
                )
        if server is not None:
            return (state, ef, srv), (eta_stats, res, telem)
        return (state, ef), (eta_stats, res)

    if server is not None:
        if robust is not None:
            def chunk(state, ef, round_rngs, ks, alive, byz, counts_cum,
                      srv):
                _count_trace()
                (state, ef, srv), (eta_stats, ress, outer) = lax.scan(
                    round_body, (state, ef, srv),
                    (round_rngs, ks, alive, byz, counts_cum),
                )
                return state, ef, eta_stats, ress, srv, outer
        else:
            def chunk(state, ef, round_rngs, ks, alive, counts_cum, srv):
                _count_trace()
                (state, ef, srv), (eta_stats, ress, outer) = lax.scan(
                    round_body, (state, ef, srv),
                    (round_rngs, ks, alive, counts_cum),
                )
                return state, ef, eta_stats, ress, srv, outer
    elif robust is not None:
        def chunk(state, ef, round_rngs, ks, alive, byz, counts_cum):
            _count_trace()
            (state, ef), (eta_stats, ress) = lax.scan(
                round_body, (state, ef),
                (round_rngs, ks, alive, byz, counts_cum),
            )
            return state, ef, eta_stats, ress
    else:
        def chunk(state, ef, round_rngs, ks, alive, counts_cum):
            _count_trace()
            (state, ef), (eta_stats, ress) = lax.scan(
                round_body, (state, ef), (round_rngs, ks, alive, counts_cum)
            )
            return state, ef, eta_stats, ress

    return chunk


def make_sampled_chunk(
    problem: MinimaxProblem,
    worker: LocalWorker,
    compressor: SyncCompressor,
    fleet: int,
    sample: int,
    k_pad: int,
    eval_fn,
    no_faults: bool,
    codec_backend: str = "reference",
    robust: RobustPipeline | None = None,
    server: ServerOptimizer | None = None,
):
    """Sampled-client round chunk (partial participation). The fleet store
    stays ``(N, ...)`` in the scan carry; each round gathers the
    M = ``sample`` drawn workers' rows — optimizer state *and* persistent
    error-feedback residuals — runs the usual sync + K masked local steps
    on the compact ``(M, ...)`` stack, then scatters the rows back. Workers
    not drawn this round keep their η accumulators and EF memory frozen in
    the store, exactly as if the round never reached them. The sampled
    lanes compose with schedules/faults/compression unchanged: ``ks_r`` /
    ``alive_r`` inputs are the fleet tables gathered onto the drawn lanes.

    Same return convention as :func:`make_serial_chunk`; ``eta_stats`` is
    reduced over the *sampled* lanes, and ``counts_cum`` rows are fleet-
    shaped ``(N,)`` so the in-chunk residual evaluates the true Line-14
    z̄ over everyone who has ever participated. A :class:`RobustPipeline`
    adds a ``byz`` ``(C, S)`` lane table (gathered onto the drawn lanes)
    between ``alive`` and ``counts_cum``, like the serial chunk.

    A resolved ``server`` outer optimizer carries ONE global ``srv``
    through the scan (trailing chunk argument, like the serial chunk): the
    outer step sees the merge of the drawn lanes, and only those lanes
    receive the post-step anchor — undrawn workers keep their stale one,
    exactly as the round never reached them."""
    del fleet  # shapes are carried by the arrays; kept for cache keying
    m = sample
    sync_stacked = make_sync_stacked(worker, compressor, m, codec_backend,
                                     robust, server)
    vstep = jax.vmap(
        lambda st, rr, en: worker.step(problem, st, rr, enabled=en)
    )
    veta = jax.vmap(worker.eta)
    has_ef = compressor.error_feedback

    def round_body(carry, inputs):
        if server is not None:
            state, ef, srv = carry
        else:
            state, ef = carry
            srv = None
        telem = None
        if robust is not None:
            idx_r, rng_round, ks_r, alive_r, byz_r, counts_r = inputs
        else:
            idx_r, rng_round, ks_r, alive_r, counts_r = inputs

        with jax.named_scope("gather-sampled"):
            sub = jax.tree.map(lambda v: v[idx_r], state)
            sub_ef = jax.tree.map(lambda v: v[idx_r], ef) if has_ef else ef

        if robust is not None:
            sync_args = (sub, sub_ef, None if no_faults else alive_r,
                         jax.random.fold_in(rng_round, 7), byz_r)
        else:
            sync_args = (sub, sub_ef, None if no_faults else alive_r,
                         jax.random.fold_in(rng_round, 7))
        if server is not None:
            sub, sub_ef, srv, telem = sync_stacked(*sync_args, srv)
        else:
            sub, sub_ef = sync_stacked(*sync_args)

        step_rngs = jax.random.split(rng_round, k_pad * m).reshape(
            k_pad, m, 2
        )

        def body(st, inp):
            rngs, i = inp
            enabled = i < ks_r
            if not no_faults:
                enabled = jnp.logical_and(enabled, alive_r)
            return vstep(st, rngs, enabled), None

        with jax.named_scope("local-compute"):
            sub, _ = lax.scan(body, sub, (step_rngs, jnp.arange(k_pad)))

        with jax.named_scope("scatter-sampled"):
            # draws are without replacement, so idx_r rows are unique and
            # the scatter is well-defined
            state = jax.tree.map(
                lambda v, s: v.at[idx_r].set(s), state, sub
            )
            if has_ef:
                ef = jax.tree.map(
                    lambda v, s: v.at[idx_r].set(s), ef, sub_ef
                )

        eta_end = veta(sub)                               # (M,) lanes
        eta_stats = jnp.stack([
            jnp.min(eta_end), jnp.max(eta_end), jnp.mean(eta_end)
        ])
        with jax.named_scope("eval"):
            if eval_fn is None:
                res = jnp.float32(jnp.nan)
            else:
                counts = jnp.where(
                    jnp.sum(counts_r) > 0.0, counts_r,
                    jnp.ones_like(counts_r),
                )
                res = jnp.asarray(
                    eval_fn(weighted_worker_average(
                        worker.output(state), counts
                    )),
                    dtype=jnp.float32,
                )
        if server is not None:
            return (state, ef, srv), (eta_stats, res, telem)
        return (state, ef), (eta_stats, res)

    if server is not None:
        if robust is not None:
            def chunk(state, ef, idx, round_rngs, ks, alive, byz,
                      counts_cum, srv):
                _count_trace()
                (state, ef, srv), (eta_stats, ress, outer) = lax.scan(
                    round_body, (state, ef, srv),
                    (idx, round_rngs, ks, alive, byz, counts_cum),
                )
                return state, ef, eta_stats, ress, srv, outer
        else:
            def chunk(state, ef, idx, round_rngs, ks, alive, counts_cum,
                      srv):
                _count_trace()
                (state, ef, srv), (eta_stats, ress, outer) = lax.scan(
                    round_body, (state, ef, srv),
                    (idx, round_rngs, ks, alive, counts_cum),
                )
                return state, ef, eta_stats, ress, srv, outer
    elif robust is not None:
        def chunk(state, ef, idx, round_rngs, ks, alive, byz, counts_cum):
            _count_trace()
            (state, ef), (eta_stats, ress) = lax.scan(
                round_body, (state, ef),
                (idx, round_rngs, ks, alive, byz, counts_cum),
            )
            return state, ef, eta_stats, ress
    else:
        def chunk(state, ef, idx, round_rngs, ks, alive, counts_cum):
            _count_trace()
            (state, ef), (eta_stats, ress) = lax.scan(
                round_body, (state, ef),
                (idx, round_rngs, ks, alive, counts_cum),
            )
            return state, ef, eta_stats, ress

    return chunk


class PSEngine:
    """Configurable Parameter-Server runtime, generic over LocalWorker.

    Examples
    --------
    Two workers, two rounds of K=2 local steps on the bilinear game, with
    per-round telemetry:

    >>> import jax
    >>> from repro.core import AdaSEGConfig
    >>> from repro.problems import make_bilinear_game
    >>> game = make_bilinear_game(jax.random.PRNGKey(0), n=4, sigma=0.1)
    >>> cfg = PSConfig(adaseg=AdaSEGConfig(g0=1.0, diameter=2.0, k=2),
    ...                num_workers=2, rounds=2)
    >>> eng = PSEngine(game.problem, cfg, rng=jax.random.PRNGKey(1),
    ...                eval_fn=game.residual)
    >>> zbar = eng.run()                  # z̄ = (x̄, ȳ), Line 14
    >>> [v.shape for v in jax.tree.leaves(zbar)], eng.round
    ([(4,), (4,)], 2)
    >>> len(eng.trace.rounds), eng.trace.rounds[-1].residual is not None
    (2, True)
    """

    def __init__(
        self,
        problem: MinimaxProblem,
        config: PSConfig,
        rng,
        *,
        mesh=None,
        worker_axes: tuple[str, ...] = ("data",),
        eval_fn: Callable[[PyTree], jax.Array] | None = None,
        trace_meta: dict | None = None,
        tracer: SpanTracer | None = None,
        metrics: MetricsRegistry | None = None,
    ):
        self.problem = problem
        self.config = config
        # Observability is host-side only (spans/metrics never enter a jit),
        # so the default-enabled tracer cannot perturb the numerics — the
        # inertness pins in tests/test_obs.py run with it on.
        self.tracer = tracer if tracer is not None else SpanTracer()
        self.metrics = metrics if metrics is not None else MetricsRegistry()
        self.worker = _resolve_worker(config)
        self.schedule = _resolve_schedule(config)
        self.compressor = config.compressor or IdentityCompressor()
        self.faults = config.faults or NoFaults()
        check_codec_backend(config.codec_backend, self.compressor)
        self.codec_backend = config.codec_backend
        self.eval_fn = eval_fn
        self._mesh = mesh
        self._worker_axes = tuple(worker_axes)
        if mesh is not None:
            import math

            m_mesh = math.prod(mesh.shape[a] for a in self._worker_axes)
            if m_mesh != config.num_workers:
                raise ValueError(
                    f"mesh worker axes give {m_mesh} workers, "
                    f"config.num_workers={config.num_workers}"
                )

        m, r = config.num_workers, config.rounds
        # Deterministic policy tables — re-derived (never stored) on resume.
        self._ks = np.asarray(
            self.schedule.steps(m, r), dtype=np.int32
        )                                                     # (R, M)
        self._alive = np.asarray(self.faults.alive(m, r), dtype=bool)
        if self._ks.shape != (r, m) or self._alive.shape != (r, m):
            raise ValueError("schedule/fault table shape mismatch")
        self._k_pad = int(self.schedule.max_steps(m))
        if not (self._ks <= self._k_pad).all():
            # the per-round scan runs max_steps iterations — larger entries
            # would silently truncate local work while still being counted
            raise ValueError(
                f"schedule emits step counts above its max_steps={self._k_pad}"
            )
        self._eff_steps = np.where(self._alive, self._ks, 0)  # (R, M)
        self._counts_cum = np.cumsum(
            self._eff_steps, axis=0
        ).astype(np.float32)

        # Sampled-client rounds: gather the fleet policy tables onto the
        # M drawn lanes per round; effective step counts scatter back to
        # fleet shape so z̄ / counts_cum stay Line-14 over the whole fleet.
        self.sampler = config.sampler
        if self.sampler is not None:
            if mesh is not None:
                raise NotImplementedError(
                    "sampled-client rounds run on the serial path only "
                    "(mesh=None)"
                )
            self._draws = self.sampler.draws(m, r)            # (R, S)
            self._ks_lane = np.take_along_axis(
                self._ks, self._draws, axis=1
            )
            self._alive_lane = np.take_along_axis(
                self._alive, self._draws, axis=1
            )
            self._eff_lane = np.where(
                self._alive_lane, self._ks_lane, 0
            )                                                 # (R, S)
            eff_fleet = np.zeros((r, m), dtype=self._eff_lane.dtype)
            np.put_along_axis(
                eff_fleet, self._draws, self._eff_lane, axis=1
            )
            self._eff_steps = eff_fleet                       # (R, N)
            self._counts_cum = np.cumsum(
                eff_fleet, axis=0
            ).astype(np.float32)
        else:
            self._draws = None

        # Hostile-fleet subsystem: resolve the attack/aggregator/DP config
        # at the compiled lane width (the sampled width under a sampler).
        lanes = self.sampler.sample if self.sampler is not None else m
        self.aggregator = config.aggregator or WeightedMean()
        self.byzantine = config.byzantine
        self.dp = config.dp
        self._robust = resolve_robust(config, lanes)
        if self._robust is not None and mesh is not None:
            raise NotImplementedError(
                "the hostile-fleet subsystem (byzantine/aggregator/dp) "
                "runs on the serial path only — robust aggregation needs "
                "full cross-worker order statistics, not a psum (mesh=None)"
            )
        if self.byzantine is not None:
            self._byz = np.asarray(
                self.byzantine.attacked(m, r), dtype=bool
            )
            if self._byz.shape != (r, m):
                raise ValueError("byzantine table shape mismatch")
        else:
            self._byz = np.zeros((r, m), dtype=bool)
        self._byz_lane = (
            np.take_along_axis(self._byz, self._draws, axis=1)
            if self._draws is not None else None
        )

        # Server-side outer optimizer: resolve to None (the historical
        # Line-7 broadcast, identical compiled chunk) for None/NoServerOpt.
        self.server_opt = config.server_opt or NoServerOpt()
        self._server = resolve_server_opt(config)
        if self._server is not None and mesh is not None:
            raise NotImplementedError(
                "the server-side outer optimizer runs on the serial path "
                "only — the outer step needs the gathered server merge, "
                "not a per-shard psum (mesh=None)"
            )

        # RNG derivation — each worker family keeps its historical stream
        # (AdaSEG: run_local_adaseg's; the zoo: run_local's), so the engine
        # reproduces the pre-engine drivers bit-exactly.
        rng0, worker_rngs = self.worker.derive_rngs(jnp.asarray(rng), m)
        self._rng0 = np.asarray(rng0)
        self._round_rngs = jax.random.split(rng0, r)          # (R, 2)
        self._state: PyTree = jax.vmap(
            lambda rr, w: self.worker.init(problem, rr, w)
        )(worker_rngs, jnp.arange(m, dtype=jnp.int32))
        self._ef: PyTree = (
            tree_zeros_like(self.worker.sync_payload(self._state))
            if self.compressor.error_feedback else ()
        )
        self.round = 0

        # Outer-optimizer state (z_server, moment trees, round count). The
        # anchor starts at the fleet mean of the initial payloads, so the
        # first round's pseudo-gradient measures the fleet's movement, not
        # an arbitrary worker's init.
        if self._server is not None:
            z0 = jax.tree.map(
                lambda v: jnp.mean(v, axis=0, keepdims=True),
                self.worker.sync_payload(self._state),
            )
            self._srv = (z0, self._server.init_moments(z0), jnp.int32(0))
        else:
            self._srv = None

        z_like = jax.tree.map(
            lambda v: v[0], self.worker.sync_payload(self._state)
        )
        self._msg_bytes = self.compressor.message_bytes(z_like)
        self._dense_bytes = dense_bytes(z_like)
        self.trace = TraceRecorder(meta={
            "problem": problem.name,
            "optimizer": self.worker.name,
            "workers": m,
            "rounds": r,
            "schedule": type(self.schedule).__name__,
            "compressor": self.compressor.name,
            "faults": type(self.faults).__name__,
            # the worker's actual step backend (None for workers without one)
            "backend": getattr(self.worker, "backend", None),
            "codec_backend": self.codec_backend,
            "execution": "sharded" if mesh is not None else "serial",
            **({"sampler": self.sampler.name,
                "sample": self.sampler.sample}
               if self.sampler is not None else {}),
            **({"byzantine": self.byzantine.name}
               if self.byzantine is not None else {}),
            **({"aggregator": self.aggregator.name,
                "dp": None if self.dp is None else self.dp.name}
               if self._robust is not None else {}),
            **({"server_opt": self.server_opt.name}
               if self._server is not None else {}),
            **(trace_meta or {}),
        })

        # Static: a NoFaults policy lets the chunk builders skip the
        # aliveness masking entirely, keeping identity/no-fault rounds
        # bit-exact with the one-shot drivers.
        self._no_faults = isinstance(self.faults, NoFaults)

        if mesh is None:
            # Process-wide compiled-chunk cache + buffer donation: the
            # stacked state/EF inputs are dead after each call (the engine
            # rebinds them to the outputs), so XLA may update in place.
            if self.sampler is not None:
                key = ("sampled", self.problem, self.worker,
                       self.compressor, m, self.sampler.sample,
                       self._k_pad, self.eval_fn, self._no_faults,
                       self.codec_backend, self._robust, self._server)
                self._chunk_fn = cached_chunk(
                    key, self._make_sampled_chunk
                )
            else:
                key = ("serial", self.problem, self.worker,
                       self.compressor, m, self._k_pad, self.eval_fn,
                       self._no_faults, self.codec_backend, self._robust,
                       self._server)
                self._chunk_fn = cached_chunk(
                    key, self._make_serial_chunk
                )
        else:
            # NOT jit-wrapped here: the sharded chunk derives its rng tables
            # eagerly and jits only the shard_map body — with the default
            # non-partitionable threefry, deriving keys inside the jit that
            # feeds a shard_map re-shards the key computation itself and
            # silently changes the stream (same reason the one-shot sharded
            # driver precomputes its step rngs on the host).
            self._chunk_fn = self._make_sharded_chunk()

    # ------------------------------------------------------------------
    # Round-loop bodies
    # ------------------------------------------------------------------

    def _make_serial_chunk(self):
        return make_serial_chunk(
            self.problem, self.worker, self.compressor,
            self.config.num_workers, self._k_pad, self.eval_fn,
            self._no_faults, self.codec_backend, self._robust,
            self._server,
        )

    def _make_sampled_chunk(self):
        return make_sampled_chunk(
            self.problem, self.worker, self.compressor,
            self.config.num_workers, self.sampler.sample, self._k_pad,
            self.eval_fn, self._no_faults, self.codec_backend,
            self._robust, self._server,
        )

    def _make_sharded_chunk(self):
        from jax.experimental.shard_map import shard_map
        from jax.sharding import PartitionSpec as P

        problem, worker = self.problem, self.worker
        comp = self.compressor
        codec_backend = self.codec_backend
        m, k_pad = self.config.num_workers, self._k_pad
        axes = self._worker_axes
        lead = axes if len(axes) > 1 else axes[0]

        def shard_fn(state_s, ef_s, s_rngs, c_rngs, ks_m, alive_m):
            # Per-shard shapes: state leaves (1, ...), s_rngs (1, C, K, 2),
            # c_rngs (1, C, 2), ks_m/alive_m (1, C).
            st0 = jax.tree.map(lambda v: v[0], state_s)
            ef0 = jax.tree.map(lambda v: v[0], ef_s)

            no_faults = self._no_faults

            def round_body(carry, inputs):
                st, ef = carry
                rngs_round, c_rng, k_m, al = inputs

                # Line 5–8 as one all-reduce of the compressed message.
                sw = worker.sync_weight(st)
                if no_faults:
                    # same expressions as core.adaseg.make_psum_sync
                    any_alive = None
                    w = sw / lax.psum(sw, axes)
                else:
                    w_raw = jnp.where(al, sw, 0.0)
                    denom = lax.psum(w_raw, axes)
                    any_alive = denom > 0.0
                    w = w_raw / jnp.where(any_alive, denom, 1.0)
                payload = worker.sync_payload(st)
                if comp.is_identity:
                    msg = jax.tree.map(
                        lambda v: w.astype(v.dtype) * v, payload
                    )
                    sent, ef_new = msg, ef
                elif codec_backend == "fused":
                    # fused uplink sweep: w scaling + EF add + codec +
                    # residual write-back, aliveness handled in-kernel
                    from ..kernels.sync_compress.ops import codec_uplink

                    sent, ef_new = codec_uplink(
                        payload, c_rng, w=w,
                        ef=ef if comp.error_feedback else None,
                        alive=None if no_faults else al,
                        codec=comp.codec_spec,
                    )
                    if not comp.error_feedback:
                        ef_new = ef
                else:
                    msg = jax.tree.map(
                        lambda v: w.astype(v.dtype) * v, payload
                    )
                    eff = tree_add(msg, ef) if comp.error_feedback else msg
                    sent = comp.compress(eff, c_rng)
                    if not no_faults:
                        sent = tree_where(al, sent, tree_zeros_like(sent))
                    ef_new = ef
                    if comp.error_feedback:
                        ef_new = tree_sub(eff, sent)
                        if not no_faults:
                            ef_new = tree_where(al, ef_new, ef)
                z_sum = jax.tree.map(lambda v: lax.psum(v, axes), sent)
                if no_faults:
                    st = worker.merge_synced(st, z_sum)
                else:
                    recv = jnp.logical_and(al, any_alive)
                    st = worker.merge_synced(
                        st, tree_where(recv, z_sum, payload)
                    )

                def body(s, inp):
                    rngs, i = inp
                    enabled = i < k_m
                    if not no_faults:
                        enabled = jnp.logical_and(enabled, al)
                    s = worker.step(problem, s, rngs, enabled=enabled)
                    return s, None

                st, _ = lax.scan(
                    body, st, (rngs_round, jnp.arange(k_pad))
                )
                return (st, ef_new), worker.eta(st)

            (st, ef), etas = lax.scan(
                round_body, (st0, ef0),
                (s_rngs[0], c_rngs[0], ks_m[0], alive_m[0]),
            )
            state_out = jax.tree.map(lambda v: v[None], st)
            ef_out = jax.tree.map(lambda v: v[None], ef)
            return state_out, ef_out, etas[:, None]           # (C, 1)

        spec_w = P(lead)
        fn = shard_map(
            shard_fn,
            mesh=self._mesh,
            in_specs=(spec_w, spec_w, P(lead, None, None, None),
                      P(lead, None, None), P(lead, None), P(lead, None)),
            out_specs=(spec_w, spec_w, P(None, lead)),
            check_rep=False,
        )

        jfn = jax.jit(fn)

        def chunk(state, ef, round_rngs, ks, alive, counts_cum):
            del counts_cum  # sharded residuals are chunk-boundary only
            # Eager rng derivation (see __init__): keys must be materialized
            # before they cross the shard_map boundary.
            step_rngs = jax.vmap(
                lambda rr: jax.random.split(rr, k_pad * m).reshape(
                    k_pad, m, 2
                )
            )(round_rngs)                                     # (C, K, M, 2)
            step_rngs = jnp.transpose(step_rngs, (2, 0, 1, 3))  # (M, C, K, 2)
            c_rngs = jax.vmap(
                lambda rr: jax.random.split(jax.random.fold_in(rr, 7), m)
            )(round_rngs)                                     # (C, M, 2)
            c_rngs = jnp.transpose(c_rngs, (1, 0, 2))         # (M, C, 2)
            state, ef, etas = jfn(
                state, ef, step_rngs, c_rngs,
                jnp.asarray(ks).T, jnp.asarray(alive).T,
            )
            eta_stats = jnp.stack(
                [etas.min(axis=1), etas.max(axis=1), etas.mean(axis=1)],
                axis=1,
            )                                                 # (C, 3)
            ress = jnp.full((round_rngs.shape[0],), jnp.nan, jnp.float32)
            return state, ef, eta_stats, ress

        return chunk

    # ------------------------------------------------------------------
    # Driving, output, telemetry
    # ------------------------------------------------------------------

    def _run_chunk(self, r0: int, r1: int) -> None:
        sl = slice(r0, r1)
        with self.tracer.span(f"chunk [{r0},{r1})", cat="chunk",
                              rounds=r1 - r0) as chunk_sp:
            if self._draws is not None:
                args = [
                    self._state, self._ef,
                    jnp.asarray(self._draws[sl]),
                    self._round_rngs[sl],
                    jnp.asarray(self._ks_lane[sl]),
                    jnp.asarray(self._alive_lane[sl]),
                ]
                if self._robust is not None:
                    args.append(jnp.asarray(self._byz_lane[sl]))
            else:
                args = [
                    self._state, self._ef,
                    self._round_rngs[sl],
                    jnp.asarray(self._ks[sl]),
                    jnp.asarray(self._alive[sl]),
                ]
                if self._robust is not None:
                    args.append(jnp.asarray(self._byz[sl]))
            args.append(jnp.asarray(self._counts_cum[sl]))
            if self._server is not None:
                args.append(self._srv)
                (state, ef, etas, ress,
                 self._srv, outer) = self._chunk_fn(*args)
            else:
                state, ef, etas, ress = self._chunk_fn(*args)
                outer = None
            jax.block_until_ready(state)
        self._state, self._ef = state, ef
        self.round = r1

        # Attribute the chunk's wall-clock uniformly across its rounds
        # (dispatch is per-chunk; finer attribution would need per-round
        # host sync, which is exactly what the chunked scan avoids). The
        # timing source is the span layer, not an ad-hoc timer.
        wall = chunk_sp.wall_dur
        per_round_wall = wall / max(r1 - r0, 1)
        cost = modeled_sync_cost(
            getattr(self.compressor, "codec_spec", None),
            self._dense_bytes, workers=self.config.num_workers,
            backend=self.codec_backend,
        )
        # Bulk telemetry: the chunk already reduced η to per-round
        # [min, max, mean] on device, so this is one O(rounds) transfer —
        # never O(rounds × fleet) — regardless of fleet size.
        stats = np.asarray(etas)                              # (C, 3)
        ress = np.asarray(ress)
        outer = None if outer is None else np.asarray(outer)  # (C, 2)
        sampled = self._draws is not None
        for i, r in enumerate(range(r0, r1)):
            if sampled:
                alive = self._alive_lane[r]
                steps_row = self._eff_lane[r]
                sampled_workers = self._draws[r].tolist()
                byz_ids = (self._draws[r][self._byz_lane[r]].tolist()
                           if self.byzantine is not None else None)
            else:
                alive = self._alive[r]
                steps_row = self._eff_steps[r]
                sampled_workers = None
                byz_ids = (np.nonzero(self._byz[r])[0].tolist()
                           if self.byzantine is not None else None)
            n_alive = int(alive.sum())
            eff = int(steps_row.sum())
            res = float(ress[i])
            if np.isnan(res):
                res = None
            if (res is None and self.eval_fn is not None and r == r1 - 1):
                # sharded path: residual at the chunk boundary, host-side
                with self.tracer.span(f"eval r{r}", cat="eval", round=r):
                    res = float(self.eval_fn(self.z_bar()))
            rec = RoundRecord(
                round=r,
                local_steps=steps_row.tolist(),
                alive=alive.tolist(),
                bytes_up=n_alive * self._msg_bytes,
                bytes_down=n_alive * self._dense_bytes,
                eta_min=float(stats[i, 0]),
                eta_max=float(stats[i, 1]),
                eta_mean=float(stats[i, 2]),
                residual=res,
                wall_time_s=per_round_wall,
                steps_per_sec=eff / per_round_wall if per_round_wall > 0
                else None,
                sampled_workers=sampled_workers,
                byzantine_workers=byz_ids,
                outer_lr=None if outer is None else float(outer[i, 0]),
                delta_norm=None if outer is None else float(outer[i, 1]),
            )
            self.trace.record(rec)
            # Round span: the chunk's wall uniformly attributed, carrying
            # the full RoundRecord so TraceRecorder.from_spans can rebuild
            # the telemetry from the span layer alone. (vars(), not
            # dataclasses.asdict: the record is flat and asdict's deep copy
            # costs ~25µs — real money in the per-round hot path.)
            if self.tracer.enabled:
                self.tracer.add_span(
                    f"round {r}", cat="round", parent=chunk_sp.id,
                    wall_t0=chunk_sp.wall_t0 + i * per_round_wall,
                    wall_t1=chunk_sp.wall_t0 + (i + 1) * per_round_wall,
                    **vars(rec),
                )
            self.metrics.inc("bytes_up", rec.bytes_up, engine="sync")
            self.metrics.inc("bytes_down", rec.bytes_down, engine="sync")
            self.metrics.inc("local_steps", eff, engine="sync")
            self.metrics.set_gauge("eta_spread", rec.eta_spread,
                                   engine="sync")
            if self._server is not None:
                self.metrics.set_gauge(
                    "outer_delta_norm", rec.delta_norm, engine="sync",
                    server_opt=self.server_opt.name,
                )
            if self._robust is not None:
                self.metrics.inc("byzantine_workers",
                                 len(byz_ids or []), engine="sync")
                self.metrics.set_gauge(
                    "agg_reject_frac",
                    self.aggregator.reject_frac(
                        len(alive)), engine="sync",
                    aggregator=self.aggregator.name,
                )
            # measured round wall next to the traffic model's prediction
            self.metrics.observe(
                "round_wall_s", per_round_wall, engine="sync",
                codec=self.compressor.name, backend=self.codec_backend,
                modeled_hbm_passes=cost["hbm_passes"],
                modeled_hbm_s=cost["hbm_s"],
            )

    def run(
        self,
        *,
        until_round: int | None = None,
        checkpoint_path: str | None = None,
        checkpoint_every: int | None = None,
    ) -> PyTree:
        """Advance to ``until_round`` (default: all rounds) and return the
        global output iterate z̄ (Line 14). ``checkpoint_every`` chunks the
        round scan and writes ``checkpoint_path`` at each boundary."""
        target = self.config.rounds if until_round is None else int(until_round)
        target = min(target, self.config.rounds)
        with self.tracer.span(f"run [{self.round},{target})", cat="run",
                              engine="sync"):
            while self.round < target:
                r1 = (min(target, self.round + checkpoint_every)
                      if checkpoint_every else target)
                self._run_chunk(self.round, r1)
                if checkpoint_path is not None:
                    self.save(checkpoint_path)
        return self.z_bar()

    def step_round(self) -> None:
        """Advance exactly one round (smoke tests, interactive driving)."""
        if self.round >= self.config.rounds:
            raise ValueError("engine already ran all configured rounds")
        self._run_chunk(self.round, self.round + 1)

    @property
    def state(self) -> PyTree:
        return self._state

    def z_bar(self) -> PyTree:
        """Global output iterate: worker outputs weighted by realized step
        counts — the same expression as the serial drivers' Line 14."""
        counts = self._eff_steps[:max(self.round, 1)].sum(axis=0)
        counts = counts.astype(np.float32)
        if counts.sum() == 0.0:
            counts = np.ones_like(counts)
        return weighted_worker_average(
            self.worker.output(self._state), jnp.asarray(counts)
        )

    # ------------------------------------------------------------------
    # Checkpointing
    # ------------------------------------------------------------------

    def _ckpt_tree(self) -> dict:
        tree = {
            "worker_state": self._state,
            "ef": self._ef,
            "round": jnp.int32(self.round),
            "rng0": jnp.asarray(self._rng0),
            "worker_fp": jnp.uint32(self.worker.fingerprint),
        }
        if self.sampler is not None:
            # present only for sampled runs: a sampled checkpoint can never
            # be restored into a full-participation engine (or vice versa)
            # because the leaf structure itself differs
            tree["sampler_fp"] = jnp.uint32(self.sampler.fingerprint)
        if self._robust is not None:
            # present only for robust runs — the merge semantics (and the
            # threat model the EF memory accumulated under) must match
            tree["aggregator_fp"] = jnp.uint32(self.aggregator.fingerprint)
        if self._server is not None:
            # present only under an active outer optimizer, so the
            # historical (`none`) layout stays byte-identical
            z, mom, t = self._srv
            tree["server_opt"] = {"z": z, "mom": mom, "t": t}
            tree["server_opt_fp"] = jnp.uint32(self.server_opt.fingerprint)
        return tree

    def save(self, path: str) -> None:
        """Serialize engine state via checkpoint.serialize (msgpack)."""
        with self.tracer.span(f"checkpoint r{self.round}", cat="checkpoint",
                              round=self.round) as sp:
            sp.attrs["bytes"] = save_pytree(path, self._ckpt_tree())
            self.metrics.inc("checkpoint_bytes", sp.attrs["bytes"],
                             engine="sync")

    def restore(self, path: str) -> "PSEngine":
        """Resume mid-stream: policies and rng streams are re-derived from
        the config, so only the worker states, error-feedback memory and the
        round counter come from disk. Refuses checkpoints from a different
        seed (the round-rng stream would silently diverge) or a different
        optimizer (the state leaves would be reinterpreted)."""
        try:
            loaded = load_pytree(path, self._ckpt_tree())
        except ValueError as e:
            raise ValueError(
                "checkpoint does not match this engine's optimizer state "
                f"layout ({self.worker.name}): {e}"
            ) from e
        if int(np.asarray(loaded["worker_fp"])) != self.worker.fingerprint:
            raise ValueError(
                "checkpoint was written by a run with a different optimizer "
                f"(engine runs {self.worker.name})"
            )
        if not np.array_equal(
            np.asarray(loaded["rng0"]), np.asarray(self._rng0)
        ):
            raise ValueError(
                "checkpoint was written by a run with a different seed"
            )
        if self.sampler is not None and int(
            np.asarray(loaded["sampler_fp"])
        ) != self.sampler.fingerprint:
            raise ValueError(
                "checkpoint was written by a run with a different client "
                "sampler (the participation tables would diverge)"
            )
        if self._robust is not None and int(
            np.asarray(loaded["aggregator_fp"])
        ) != self.aggregator.fingerprint:
            raise ValueError(
                "checkpoint was written by a run with a different robust "
                "aggregator (the merge semantics would diverge)"
            )
        if self._server is not None:
            if int(
                np.asarray(loaded["server_opt_fp"])
            ) != self.server_opt.fingerprint:
                raise ValueError(
                    "checkpoint was written by a run with a different "
                    "server-side outer optimizer (engine runs "
                    f"{self.server_opt.name})"
                )
            so = loaded["server_opt"]
            self._srv = (so["z"], tuple(so["mom"]), so["t"])
        self._state = loaded["worker_state"]
        self._ef = loaded["ef"]
        self.round = int(loaded["round"])
        # drop telemetry from rounds past the restore point so a rewound
        # engine doesn't accumulate duplicate round records
        self.trace.rounds = [
            rec for rec in self.trace.rounds if rec.round < self.round
        ]
        return self
