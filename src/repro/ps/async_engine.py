"""Event-driven asynchronous Parameter-Server over simulated time.

The synchronous :class:`~repro.ps.engine.PSEngine` counts *rounds*: every
worker blocks on one barrier per round no matter how fast it ran. This
module adds the missing time axis. :class:`AsyncPSEngine` is a discrete-
event simulator of the same Parameter-Server fleet: a
:class:`~repro.ps.latency.LatencyModel` assigns every worker-round its
compute and network delays, an event queue advances a simulated clock, and
the server **admits each worker's uplink as it arrives** — no barrier —
under a configurable bounded-staleness rule:

* every worker cycles through ``send payload → receive broadcast → run its
  K_m^r local steps`` at its own speed (Line 3–8 of Algorithm 1, unrolled
  per worker instead of per barrier);
* the server keeps the **last heard** payload and 1/η sync weight of every
  worker; on each admission it recomputes the Line-7 weighted average over
  the whole table with staleness-aware re-weighting
  ``w_m ∝ sw_m / (1 + s_m)^γ`` (``s_m`` = how many rounds behind the
  freshest entry worker ``m``'s stored payload is) and broadcasts back *to
  the admitted workers only*;
* a round-``r`` uplink is admitted only once every live worker's round-
  ``(r − τ)`` uplink has landed (τ = ``staleness_bound``) — the stale-
  synchronous-parallel rule, gated on what the server has *heard*, not on
  what workers have started. ``τ=∞`` never blocks; ``τ=0`` is a true
  barrier, which makes the synchronous engine a special case *along the
  staleness axis* and gives the sync baseline its simulated-time cost
  under any latency model.

Parity anchor (pinned by ``tests/test_ps_async.py``): with worker-equal
:class:`~repro.ps.latency.ConstantLatency`, ``τ=∞`` (or ``τ=0``), identity
compression and no faults, the fleet moves in lockstep, every arrival lands
in one batch, and this engine reproduces ``PSEngine``'s serial path
**bit-exactly**. Two mechanisms make that structural rather than
approximate: local phases execute on the *full stacked worker state* with a
one-hot ``enabled`` mask (per-worker unbatched math has different matmul
accumulation order and is NOT bit-equal to the engine's vmapped steps), and
full-fleet lockstep admissions execute the synchronous engine's own
compiled round chunk (``engine.make_serial_chunk``) — shared code rather
than a parallel implementation, because even re-emitting the identical
expression sequence in a differently-shaped jit graph perturbs XLA fusion
at the last ulp.

Everything PR 2–3 built composes: schedules feed ``K_m^r``, compressors run
on the payload uplinks (error feedback per worker; the Line-7 weights are
applied server-side where the normalizer lives — the one place the async
wire format must differ from the sync engine's pre-weighted messages),
fault policies knock workers out of their own round ``r`` (no send, no
receive, no steps — a reboot that only costs time), and both
``AdaSEGWorker`` and ``MinimaxWorker`` run unmodified. Checkpoint/resume
serializes the *dynamic* state only — stacked worker state, server table,
per-worker event-machine arrays, the simulated clock — while schedules,
faults, latency tables and rng streams are re-derived from the config
seeds, so a killed simulation resumes bit-exactly mid-event-queue.

Execution is host-driven and serial by design: the simulator's product is
*simulated* time-to-accuracy, not wall-clock throughput — the sharded
``shard_map`` path remains the synchronous engine's domain.

One timeline nuance: local phases normally execute when they *complete* on
the simulated clock (so mid-run residuals only count finished work), but a
full-fleet lockstep admission runs the synchronous chunk eagerly — those
workers' states may then be up to one phase ahead of the clock until their
START events fire. Admission records are written before the chunk, and
resume replays the same decision, so telemetry and checkpoints stay
consistent either way.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax

from ..checkpoint.serialize import load_pytree, save_pytree
from ..core.adaseg import weighted_worker_average
from ..core.tree import tree_add, tree_sub, tree_zeros_like
from ..core.types import MinimaxProblem
from .compress import IdentityCompressor, check_codec_backend, dense_bytes
from .engine import (
    PSConfig,
    _per_worker,
    _resolve_schedule,
    _resolve_worker,
    cached_chunk,
    make_serial_chunk,
    resolve_robust,
)
from ..obs import MetricsRegistry, SpanTracer, modeled_sync_cost
from .faults import NoFaults
from .latency import ConstantLatency, LatencyModel
from .robust import WeightedMean
from .server_opt import NoServerOpt, resolve_server_opt
from .trace import RoundRecord, TraceRecorder

PyTree = Any

# Worker event-machine status codes (serialized in checkpoints).
#
# The per-worker arrays (_status, _ev_time, _ev_round, ...) ARE the event
# queue: each worker has at most one pending event, so "pop the next
# event" is an argmin over _ev_time of the workers in an event-bearing
# status — a vectorized numpy scan rather than a heap, which lets the
# driver process *every* event at one timestamp in a single sweep.
#
# Deterministic tie-break (pinned by tests/test_ps_async.py): at one
# simulated instant, START events (compute/reboot completions) are
# processed before ARRIVE events (uplink landings) — a START may spawn a
# same-instant ARRIVE under zero network delay, never the reverse — and
# the admission batch formed afterwards is ordered by ascending worker
# id. The order is a pure function of the deterministic latency/schedule
# tables, so it is identical across reruns and across checkpoint/resume.
_UPLINK = 0    # uplink in flight — an ARRIVE event is pending
_COMPUTE = 1   # computing/rebooting — a START event is pending
_HELD = 2      # arrived, held at the server by the staleness bound
_DONE = 3      # all rounds finished


@dataclasses.dataclass(frozen=True)
class AsyncPSConfig(PSConfig):
    """:class:`PSConfig` plus the async policy layer.

    ``latency`` assigns per-(round, worker) compute/network delays (default:
    zero-delay lockstep). ``staleness_bound`` is the SSP τ — a round-``r``
    uplink is held until every live worker's round-``(r − τ)`` uplink has
    arrived at the server; ``math.inf`` never waits, ``0`` is a full
    barrier. ``staleness_discount`` is the γ in the server's
    staleness-aware re-weighting ``w ∝ sw/(1+s)^γ`` (``0`` disables the
    discount).
    """

    latency: LatencyModel | None = None
    staleness_bound: float = math.inf
    staleness_discount: float = 1.0


class AsyncPSEngine:
    """Discrete-event asynchronous Parameter-Server runtime (serial path).

    Examples
    --------
    A 2-worker fleet with a 3× straggler under bounded staleness τ=1: the
    run finishes on the simulated clock with per-admission telemetry.

    >>> import jax
    >>> from repro.core import AdaSEGConfig
    >>> from repro.problems import make_bilinear_game
    >>> from repro.ps import ConstantLatency
    >>> game = make_bilinear_game(jax.random.PRNGKey(0), n=4, sigma=0.1)
    >>> acfg = AsyncPSConfig(adaseg=AdaSEGConfig(g0=1.0, diameter=2.0, k=2),
    ...                      num_workers=2, rounds=2,
    ...                      latency=ConstantLatency(step_s=(1.0, 3.0),
    ...                                              up_s=0.1, down_s=0.1),
    ...                      staleness_bound=1.0)
    >>> eng = AsyncPSEngine(game.problem, acfg, rng=jax.random.PRNGKey(1))
    >>> zbar = eng.run()
    >>> eng.done, eng.sim_time > 0.0
    (True, True)
    >>> eng.trace.rounds[-1].sim_time_s is not None
    True
    """

    def __init__(
        self,
        problem: MinimaxProblem,
        config: AsyncPSConfig,
        rng,
        *,
        eval_fn: Callable[[PyTree], jax.Array] | None = None,
        trace_meta: dict | None = None,
        tracer: SpanTracer | None = None,
        metrics: MetricsRegistry | None = None,
    ):
        if config.staleness_bound < 0:
            raise ValueError("staleness_bound must be >= 0")
        # Dual-clock observability: spans carry the simulated clock (exact —
        # the event machine knows each phase's interval) next to host wall
        # time; recording is host-side only, so it cannot perturb numerics.
        self.tracer = tracer if tracer is not None else SpanTracer()
        self.metrics = metrics if metrics is not None else MetricsRegistry()
        self.problem = problem
        self.config = config
        self.worker = _resolve_worker(config)
        self.schedule = _resolve_schedule(config)
        self.compressor = config.compressor or IdentityCompressor()
        self.faults = config.faults or NoFaults()
        check_codec_backend(config.codec_backend, self.compressor)
        self.codec_backend = config.codec_backend
        self.latency = config.latency or ConstantLatency()
        self.eval_fn = eval_fn
        self.tau = float(config.staleness_bound)
        self.gamma = float(config.staleness_discount)

        m, r = config.num_workers, config.rounds
        # Deterministic policy tables — re-derived (never stored) on resume,
        # exactly like the synchronous engine's.
        self._ks = np.asarray(self.schedule.steps(m, r), dtype=np.int32)
        self._alive = np.asarray(self.faults.alive(m, r), dtype=bool)
        if self._ks.shape != (r, m) or self._alive.shape != (r, m):
            raise ValueError("schedule/fault table shape mismatch")
        self._k_pad = int(self.schedule.max_steps(m))
        if not (self._ks <= self._k_pad).all():
            raise ValueError(
                f"schedule emits step counts above its max_steps={self._k_pad}"
            )
        lat = self.latency.tables(m, r)
        if lat.step_s.shape != (r, m):
            raise ValueError(
                f"latency tables have shape {lat.step_s.shape}, "
                f"engine needs ({r}, {m})"
            )
        self._lat = lat
        # Sampled-client rounds: a (R, M) participation mask — rounds a
        # worker isn't drawn for are skipped at zero simulated cost (no
        # send, no receive, no steps, no reboot), with progress advanced
        # through the skip so the staleness gate never waits on a round
        # that will never uplink.
        self.sampler = config.sampler
        self._sampled = (
            None if self.sampler is None
            else self.sampler.participation(m, r)
        )
        # Hostile-fleet subsystem: attacks corrupt uplinks at *store* time
        # (per the sender's own round), the robust merge runs at admission
        # over the last-heard table. Resolved at full fleet width — the
        # async table always spans every worker.
        self.aggregator = config.aggregator or WeightedMean()
        self.byzantine = config.byzantine
        self.dp = config.dp
        self._robust = resolve_robust(config, m)
        # Server-side outer optimizer (DiLoCo/FedOpt): in the event-driven
        # engine the outer step runs once per *admission* — Δ is the change
        # of the staleness-weighted table average between consecutive
        # admissions, so partial batches take smaller, more frequent outer
        # steps while a τ=0 lockstep fleet reproduces the synchronous
        # engine's per-round cadence through the shared chunk.
        self.server_opt = config.server_opt or NoServerOpt()
        self._server = resolve_server_opt(config)
        if self.byzantine is not None:
            self._byz = np.asarray(
                self.byzantine.attacked(m, r), dtype=bool
            )
            if self._byz.shape != (r, m):
                raise ValueError("byzantine table shape mismatch")
        else:
            self._byz = np.zeros((r, m), dtype=bool)

        # RNG derivation: identical to PSEngine so the lockstep trajectory
        # (and each worker family's historical stream) is reproduced.
        rng0, worker_rngs = self.worker.derive_rngs(jnp.asarray(rng), m)
        self._rng0 = np.asarray(rng0)
        self._round_rngs = jax.random.split(rng0, r)
        self._state: PyTree = jax.vmap(
            lambda rr, w: self.worker.init(problem, rr, w)
        )(worker_rngs, jnp.arange(m, dtype=jnp.int32))
        self._ef: PyTree = (
            tree_zeros_like(self.worker.sync_payload(self._state))
            if self.compressor.error_feedback else ()
        )

        # Server memory: last-heard payload/weight per worker.
        self._srv_payload: PyTree = tree_zeros_like(
            self.worker.sync_payload(self._state)
        )
        self._srv_sw = np.zeros((m,), np.float32)
        self._srv_version = np.full((m,), -1, np.int32)
        self._heard = np.zeros((m,), bool)
        # Outer-optimizer state (z_server, moment trees, admission count) —
        # the same fleet-mean anchor derivation as PSEngine, so a τ=0
        # lockstep run feeds the shared chunk an identical srv carry.
        if self._server is not None:
            z0 = jax.tree.map(
                lambda v: jnp.mean(v, axis=0, keepdims=True),
                self.worker.sync_payload(self._state),
            )
            self._srv = (z0, self._server.init_moments(z0), jnp.int32(0))
        else:
            self._srv = None

        # Per-worker event machine (one outstanding event per worker).
        self._status = np.full((m,), _COMPUTE, np.int32)
        self._ev_time = np.zeros((m,), np.float64)
        self._ev_round = np.zeros((m,), np.int32)
        self._ev_busy = np.zeros((m,), np.float64)
        self._ev_is_phase = np.zeros((m,), bool)
        # Server-side progress knowledge: the highest round whose uplink has
        # arrived, per worker (−1 before the init send lands). The staleness
        # gate reads this — a round-r uplink is admitted only once every
        # live worker's round-(r−τ) uplink has landed — so τ=0 is a true
        # barrier: the server waits for the whole fleet's payloads, not
        # merely for the fleet to have started the round.
        self._progress = np.full((m,), -1, np.int32)
        self._arrive_t = np.zeros((m,), np.float64)   # span layer only
        self._busy_s = np.zeros((m,), np.float64)
        self._steps_cum = np.zeros((m,), np.int32)
        # Steps already attributed to a trace record: each admission records
        # the *previous* phase's steps, so the terminal record carries the
        # remainder (steps_cum − steps_recorded) and the trace's total
        # matches the work actually done.
        self._steps_recorded = np.zeros((m,), np.int32)
        self._done_at = np.zeros((m,), np.float64)
        self.now = 0.0
        self.n_admissions = 0
        self._final_recorded = False

        z_like = jax.tree.map(
            lambda v: v[0], self.worker.sync_payload(self._state)
        )
        self._msg_bytes = self.compressor.message_bytes(z_like)
        self._dense_bytes = dense_bytes(z_like)
        self.trace = TraceRecorder(meta={
            "problem": problem.name,
            "optimizer": self.worker.name,
            "workers": m,
            "rounds": r,
            "schedule": type(self.schedule).__name__,
            "compressor": self.compressor.name,
            "faults": type(self.faults).__name__,
            "latency": type(self.latency).__name__,
            "staleness_bound": (None if math.isinf(self.tau) else self.tau),
            "staleness_discount": self.gamma,
            "backend": getattr(self.worker, "backend", None),
            "codec_backend": self.codec_backend,
            "execution": "event-driven",
            **({"sampler": self.sampler.name,
                "sample": self.sampler.sample}
               if self.sampler is not None else {}),
            **({"byzantine": self.byzantine.name}
               if self.byzantine is not None else {}),
            **({"server_opt": self.server_opt.name}
               if self._server is not None else {}),
            **({"aggregator": self.aggregator.name,
                "dp": None if self.dp is None else self.dp.name}
               if self._robust is not None else {}),
            **(trace_meta or {}),
        })

        self._rng_cache: dict[int, jax.Array] = {}
        self._np_rng_cache: dict[int, np.ndarray] = {}
        self._c_rng_cache: dict[int, jax.Array] = {}
        # Whenever an admission batch is the whole fleet in the same round
        # (lockstep), the engine runs the synchronous engine's own round
        # chunk instead of the per-arrival path — so "sync is a special
        # case" is shared compiled code, bit-exact by construction, not a
        # reimplementation that happens to agree. Only the identity/
        # no-fault configuration can take it (a faultful PSEngine compiles
        # the masked sync branch, and async compression has per-payload
        # semantics — see _admit_batch).
        self._lockstep_ok = (
            isinstance(self.faults, NoFaults)
            and self.compressor.is_identity
            and self.sampler is None
        )
        self._build_jit()
        for w in range(m):
            self._enter_round(w, 0, 0.0)

    # ------------------------------------------------------------------
    # Jitted numerics — the exact expression sequences of PSEngine's
    # serial path, reindexed for per-arrival execution.
    # ------------------------------------------------------------------

    def _build_jit(self) -> None:
        worker, problem = self.worker, self.problem
        comp = self.compressor
        k_pad = self._k_pad

        vstep = jax.vmap(
            lambda st, rr, en: worker.step(problem, st, rr, enabled=en)
        )

        def phase(state, step_rngs, ks_vec):
            # One worker's K_m^r local steps on the stacked state: ks_vec is
            # one-hot in the worker, so every other lane's update is masked
            # off bit-exactly — the engine's own heterogeneous-K mechanism.
            def body(st, inp):
                rngs, i = inp
                enabled = i < ks_vec
                st = vstep(st, rngs, enabled)
                return st, None

            state, _ = lax.scan(
                body, state, (step_rngs, jnp.arange(k_pad))
            )
            return state

        def store(state, table, sw, mask):
            # Admit uplinks: overwrite the masked lanes of the server table
            # with the senders' current payload/weight. (A blocked sender's
            # lane hasn't changed since send time, so reading it at
            # admission is exact.)
            payload = worker.sync_payload(state)
            new_table = jax.tree.map(
                lambda cur, old: jnp.where(_per_worker(mask, cur), cur, old),
                payload, table,
            )
            sw_now = jax.vmap(worker.sync_weight)(state)
            return new_table, jnp.where(mask, sw_now, sw)

        def store_compressed(state, table, sw, ef, mask, c_rngs):
            payload = worker.sync_payload(state)
            if self.codec_backend == "fused":
                # fused per-payload uplink: EF add + codec + residual
                # write-back in kernel sweeps; the admission mask plays the
                # aliveness role (non-admitted workers keep their residual)
                from ..kernels.sync_compress.ops import codec_uplink_stacked

                sent, ef_new = codec_uplink_stacked(
                    payload, c_rngs,
                    ef=ef if comp.error_feedback else None,
                    alive=mask, codec=comp.codec_spec,
                )
                if not comp.error_feedback:
                    ef_new = ef
            else:
                eff = (tree_add(payload, ef) if comp.error_feedback
                       else payload)
                sent = jax.vmap(comp.compress)(eff, c_rngs)
                if comp.error_feedback:
                    ef_new = jax.tree.map(
                        lambda e_new, e_old: jnp.where(
                            _per_worker(mask, e_new), e_new, e_old
                        ),
                        tree_sub(eff, sent), ef,
                    )
                else:
                    ef_new = ef
            new_table = jax.tree.map(
                lambda s, old: jnp.where(_per_worker(mask, s), s, old),
                sent, table,
            )
            sw_now = jax.vmap(worker.sync_weight)(state)
            return new_table, jnp.where(mask, sw_now, sw), ef_new

        robust = self._robust

        def store_robust(state, table, sw, ef, mask, byz_mask, c_rngs):
            # Robust store: corrupt (attack) then privatize (DP) the raw
            # payload, codec the result *unweighted* — the same pipeline the
            # synchronous robust sync runs, so τ=0 stays a shared-semantics
            # special case. ``byz_mask`` selects the admitted lanes whose
            # sender is adversarial in its own round.
            payload = worker.sync_payload(state)
            uplink = payload
            if robust.byzantine is not None:
                a_rngs = jax.vmap(
                    lambda k: jax.random.fold_in(k, 13)
                )(c_rngs)
                uplink = robust.byzantine.apply(uplink, byz_mask, a_rngs)
            if robust.dp is not None:
                d_rngs = jax.vmap(
                    lambda k: jax.random.fold_in(k, 11)
                )(c_rngs)
                uplink = robust.dp.apply(uplink, d_rngs)
            if comp.is_identity:
                sent, ef_new = uplink, ef
            else:
                from ..kernels.sync_compress.ops import codec_uplink_stacked

                sent, ef_new = codec_uplink_stacked(
                    uplink, c_rngs, w=None,
                    ef=ef if comp.error_feedback else None,
                    alive=mask, codec=comp.codec_spec,
                    use_kernel=self.codec_backend == "fused",
                )
                if not comp.error_feedback:
                    ef_new = ef
            new_table = jax.tree.map(
                lambda s, old: jnp.where(_per_worker(mask, s), s, old),
                sent, table,
            )
            sw_now = jax.vmap(worker.sync_weight)(state)
            return new_table, jnp.where(mask, sw_now, sw), ef_new

        server = self._server

        def outer_broadcast(state, merged, recv, payload, srv):
            # Row-0 of the ungated merge → outer step → recv-gated delivery:
            # the event-driven twin of engine.make_sync_stacked's helper.
            from ..kernels.sync_compress.ops import server_outer_apply

            z, mom, t = srv
            merged_row = jax.tree.map(lambda v: v[:1], merged)
            z_new, mom_new, t_new, eff_lr, dn = server_outer_apply(
                merged_row, z, mom, t, spec=server.spec,
                use_kernel=self.codec_backend == "fused",
            )
            synced = jax.tree.map(
                lambda v, old: jnp.where(
                    _per_worker(recv, old),
                    jnp.broadcast_to(v, old.shape), old,
                ),
                z_new, payload,
            )
            return (worker.merge_synced(state, synced),
                    (z_new, mom_new, t_new), jnp.stack([eff_lr, dn]))

        def admit_robust(state, table, sw, discount, heard, recv, srv=None):
            # Robust Line 5–8 per arrival: the table rows are unweighted
            # z̃ uplinks, so the robust merge (and its weight
            # renormalization over heard lanes) runs server-side — the
            # same sync_merge_stacked(agg=...) call the synchronous robust
            # path compiles. An active outer optimizer takes the merge
            # ungated (recv only ever gated delivery, never the mean) and
            # runs the outer step downstream of the robust aggregation.
            from ..kernels.sync_compress.ops import sync_merge_stacked

            sw_eff = sw * discount
            w_raw = jnp.where(heard, sw_eff, jnp.zeros_like(sw_eff))
            payload = worker.sync_payload(state)
            if server is not None:
                merged = sync_merge_stacked(
                    table, w=w_raw, normalize=True, agg=robust.agg,
                    use_kernel=self.codec_backend == "fused",
                )
                return outer_broadcast(state, merged, recv, payload, srv)
            synced = sync_merge_stacked(
                table, w=w_raw, recv=recv, old=payload,
                normalize=True, agg=robust.agg,
                use_kernel=self.codec_backend == "fused",
            )
            return worker.merge_synced(state, synced)

        def admit(state, table, sw, discount, heard, recv, srv=None):
            # Line 5–8 per arrival: weighted average of the whole last-heard
            # table, broadcast to the admitted workers only. Mirrors
            # engine.make_sync_stacked's no-fault branch with the staleness
            # discount folded into the weights (full-lockstep batches don't
            # come here — they run the shared synchronous chunk).
            sw_eff = sw * discount
            w_raw = jnp.where(heard, sw_eff, jnp.zeros_like(sw_eff))
            w = w_raw / jnp.sum(w_raw)
            msg = jax.tree.map(
                lambda leaf: _per_worker(w, leaf).astype(leaf.dtype) * leaf,
                table,
            )
            payload = worker.sync_payload(state)
            if server is not None:
                merged = jax.tree.map(
                    lambda s: jnp.sum(s, axis=0, keepdims=True), msg
                )
                return outer_broadcast(state, merged, recv, payload, srv)
            synced = jax.tree.map(
                lambda s, old: jnp.where(
                    _per_worker(recv, old),
                    jnp.broadcast_to(
                        jnp.sum(s, axis=0, keepdims=True), old.shape
                    ),
                    old,
                ),
                msg, payload,
            )
            return worker.merge_synced(state, synced)

        self._phase_fn = jax.jit(phase)
        self._store_fn = jax.jit(store)
        self._store_c_fn = jax.jit(store_compressed)
        self._store_r_fn = jax.jit(store_robust) if robust else None
        self._admit_fn = jax.jit(admit_robust if robust else admit)
        self._veta = jax.jit(jax.vmap(worker.eta))
        # Shared with PSEngine through the process-wide chunk cache: a
        # lockstep-eligible async engine literally reuses the synchronous
        # engine's *compiled* round chunk (same cache key ⇒ same jitted
        # callable), donation included. A robust pipeline keys (and
        # builds) the robust variant of the same chunk.
        self._lockstep_chunk = (
            cached_chunk(
                ("serial", self.problem, worker, comp,
                 self.config.num_workers, k_pad, self.eval_fn, True,
                 self.codec_backend, robust, server),
                lambda: make_serial_chunk(
                    self.problem, worker, comp, self.config.num_workers,
                    k_pad, self.eval_fn, no_faults=True,
                    codec_backend=self.codec_backend, robust=robust,
                    server=server,
                ),
            )
            if self._lockstep_ok else None
        )

    def _step_rngs(self, r: int) -> jax.Array:
        """(k_pad, M, 2) step-key table of round ``r`` — the engine's
        derivation, so a worker in round ``r`` consumes the same keys the
        synchronous serial chunk would feed its lane."""
        if r not in self._rng_cache:
            m = self.config.num_workers
            self._rng_cache[r] = jax.random.split(
                self._round_rngs[r], self._k_pad * m
            ).reshape(self._k_pad, m, 2)
        return self._rng_cache[r]

    def _np_step_rngs(self, r: int) -> np.ndarray:
        """Host copy of :meth:`_step_rngs` — mixed-round phase batches
        splice per-worker key columns out of these."""
        if r not in self._np_rng_cache:
            self._np_rng_cache[r] = np.asarray(self._step_rngs(r))
        return self._np_rng_cache[r]

    def _c_rngs(self, r: int) -> jax.Array:
        if r not in self._c_rng_cache:
            self._c_rng_cache[r] = jax.random.split(
                jax.random.fold_in(self._round_rngs[r], 7),
                self.config.num_workers,
            )
        return self._c_rng_cache[r]

    # ------------------------------------------------------------------
    # Event machine
    # ------------------------------------------------------------------

    def _enter_round(self, m: int, r: int, t: float) -> None:
        """Worker ``m`` enters round ``r`` at simulated time ``t``: send the
        uplink (alive), burn a reboot (dead), skip (not sampled), or finish
        (r == rounds)."""
        if self._sampled is not None:
            # rounds the worker isn't drawn for cost nothing; progress
            # advances through the skip as if the round had trivially
            # arrived, so the staleness gate never deadlocks on it
            while r < self.config.rounds and not self._sampled[r, m]:
                self._progress[m] = max(int(self._progress[m]), r)
                r += 1
        if r >= self.config.rounds:
            self._status[m] = _DONE
            self._done_at[m] = t
            self._progress[m] = r
            return
        if self._alive[r, m]:
            self._status[m] = _UPLINK
            self._ev_round[m] = r
            self._ev_time[m] = t + self._lat.up_s[r, m]
        else:
            # Dead round: no send, no receive, no steps — the worker keeps
            # its stale anchor and the server keeps its stale entry (the
            # synchronous fault semantics, minus the barrier); rebooting
            # costs the compute time the round's steps would have taken.
            reboot = float(self._ks[r, m]) * self._lat.step_s[r, m]
            self._status[m] = _COMPUTE
            self._ev_round[m] = r + 1
            self._ev_time[m] = t + reboot
            self._ev_busy[m] = reboot
            self._ev_is_phase[m] = False
            self.tracer.add_span(
                f"reboot r{r}", cat="reboot", track=f"worker/{m}",
                sim_t0=t, sim_t1=t + reboot, round=int(r), worker=int(m),
            )

    def _run_phases(self, ms: list[int]) -> None:
        """Execute the pending local phases of workers ``ms`` (their rounds
        may differ) in ONE compiled masked scan. vmap lanes are independent
        — lane ``m``'s result depends only on its own (state column, key
        column, K) — so a multi-hot ``ks_vec`` is bit-identical to running
        the same phases one-hot sequentially, in any order; batching just
        collapses the per-event dispatch overhead."""
        live = []
        ks_vec = np.zeros((self.config.num_workers,), np.int32)
        for m in ms:
            r = int(self._ev_round[m]) - 1
            k = int(self._ks[r, m])
            if k:
                ks_vec[m] = k
                live.append((m, r, k))
        if not live:
            return
        rounds = {r for _, r, _ in live}
        if len(rounds) == 1:
            # single-round batch: feed the round's key table untouched so
            # the call hits the same jit-cache entry (and the same key
            # buffers) a one-hot phase would
            rngs = self._step_rngs(live[0][1])
        else:
            # mixed rounds at one instant: splice each worker's key column
            # out of its own round's table — lane m consumes exactly the
            # keys the synchronous chunk would feed its lane in round r
            cols = self._np_step_rngs(live[0][1]).copy()
            for m, r, _ in live[1:]:
                cols[:, m] = self._np_step_rngs(r)[:, m]
            rngs = jnp.asarray(cols)
        # wall-clock view: the host executes phases back-to-back; each
        # phase's sim interval was spanned at admission time
        label = (f"phase r{live[0][1]} w{live[0][0]}" if len(live) == 1
                 else f"phase-batch ×{len(live)}")
        with self.tracer.span(label, cat="local-compute",
                              workers=[m for m, _, _ in live],
                              steps=int(sum(k for _, _, k in live))):
            self._state = self._phase_fn(
                self._state, rngs, jnp.asarray(ks_vec)
            )
        for m, _, k in live:
            self._steps_cum[m] += k

    def _handle_starts(self, idx: np.ndarray, t: float) -> None:
        """Complete every compute/reboot ending at instant ``t``: run the
        pending phases as one batch, then enter each worker's next round."""
        phase_ms = [int(m) for m in idx if self._ev_is_phase[m]]
        if phase_ms:
            self._run_phases(phase_ms)
            self._ev_is_phase[phase_ms] = False
        self._busy_s[idx] += self._ev_busy[idx]
        self._ev_busy[idx] = 0.0
        for m in idx:
            self._enter_round(int(m), int(self._ev_round[m]), t)

    def _handle_arrivals(self, idx: np.ndarray, t: float) -> None:
        """Land every uplink arriving at instant ``t`` at the server."""
        self._status[idx] = _HELD
        self._progress[idx] = self._ev_round[idx]
        self._arrive_t[idx] = t
        if self.tracer.enabled:
            for m in idx:
                r = int(self._ev_round[m])
                self.tracer.add_span(
                    f"uplink r{r}", cat="uplink", track=f"worker/{int(m)}",
                    sim_t0=t - float(self._lat.up_s[r, m]), sim_t1=t,
                    round=r, worker=int(m),
                    bytes=float(self._msg_bytes),
                )

    def _min_progress(self) -> int:
        active = self._status != _DONE
        if not active.any():
            return self.config.rounds
        return int(self._progress[active].min())

    def _admissible(self) -> list[int]:
        # ascending worker id — the documented admission order within a
        # batch (np.nonzero enumerates in index order)
        floor = self._min_progress() + self.tau
        return [int(m) for m in np.nonzero(
            (self._status == _HELD) & (self._ev_round <= floor)
        )[0]]

    def _admit_batch(self, adm: list[int], t: float) -> None:
        """One server update: fold the admitted uplinks into the last-heard
        table, recompute the staleness-weighted Line-7 average, broadcast to
        the admitted workers, and schedule their local phases."""
        m_tot = self.config.num_workers
        mask = np.zeros((m_tot,), bool)
        mask[adm] = True
        rounds_of = {m: int(self._ev_round[m]) for m in adm}
        byz_mask = np.zeros((m_tot,), bool)
        if self.byzantine is not None:
            for m in adm:
                byz_mask[m] = self._byz[rounds_of[m], m]

        with self.tracer.span(
            f"admission {self.n_admissions}", cat="admission",
            sim_t0=t, sim_t1=t, admitted=len(adm),
        ) as adm_sp:
            with self.tracer.span("uplink-decode", cat="uplink-encode",
                                  sim_t0=t, sim_t1=t):
                if self._robust is not None:
                    # attack/DP keys derive from the sender's own round, so
                    # even the identity codec needs the spliced key table
                    c_rngs = np.asarray(self._c_rngs(0)).copy()
                    for m in adm:
                        c_rngs[m] = np.asarray(self._c_rngs(rounds_of[m]))[m]
                    self._srv_payload, srv_sw, self._ef = self._store_r_fn(
                        self._state, self._srv_payload,
                        jnp.asarray(self._srv_sw), self._ef,
                        jnp.asarray(mask), jnp.asarray(byz_mask),
                        jnp.asarray(c_rngs),
                    )
                elif self.compressor.is_identity:
                    self._srv_payload, srv_sw = self._store_fn(
                        self._state, self._srv_payload,
                        jnp.asarray(self._srv_sw), jnp.asarray(mask),
                    )
                else:
                    c_rngs = np.asarray(self._c_rngs(0)).copy()
                    for m in adm:
                        c_rngs[m] = np.asarray(self._c_rngs(rounds_of[m]))[m]
                    self._srv_payload, srv_sw, self._ef = self._store_c_fn(
                        self._state, self._srv_payload,
                        jnp.asarray(self._srv_sw),
                        self._ef, jnp.asarray(mask), jnp.asarray(c_rngs),
                    )
            self._srv_sw = np.asarray(srv_sw)
            for m in adm:
                self._srv_version[m] = rounds_of[m]
            self._heard[adm] = True

            # Staleness of every stored entry, rounds behind the freshest.
            vmax = int(self._srv_version[self._heard].max())
            stale = np.where(self._heard, vmax - self._srv_version, 0)

            r0 = rounds_of[adm[0]]
            lockstep = (
                self._lockstep_chunk is not None
                and len(adm) == m_tot
                and all(r == r0 for r in rounds_of.values())
            )
            # Record before mutating state: η and residual at admission time
            # (post-previous-phase, pre-merge — merge_synced never touches
            # the output iterate, so the residual is the same either side).
            self._record_admission(
                adm, t, np.asarray(self._veta(self._state)), stale, byz_mask
            )
            rec = self.trace.rounds[-1]

            with self.tracer.span("server-merge", cat="server-merge",
                                  sim_t0=t, sim_t1=t,
                                  lockstep=lockstep) as merge_sp:
                if lockstep:
                    # The whole fleet is here, in the same round, with zero
                    # staleness: run the synchronous engine's compiled round
                    # body (sync + all local steps fused), making PSEngine a
                    # bit-exact special case by shared code. Phases are
                    # thereby pre-executed; the START events below only
                    # carry the timing.
                    counts = (
                        self._steps_cum + self._ks[r0] * self._alive[r0]
                    ).astype(np.float32)
                    chunk_args = [
                        self._state, self._ef,
                        self._round_rngs[r0:r0 + 1],
                        jnp.asarray(self._ks[r0:r0 + 1]),
                        jnp.asarray(self._alive[r0:r0 + 1]),
                    ]
                    if self._robust is not None:
                        chunk_args.append(jnp.asarray(self._byz[r0:r0 + 1]))
                    chunk_args.append(jnp.asarray(counts[None]))
                    if self._server is not None:
                        chunk_args.append(self._srv)
                        (self._state, self._ef, _, _, self._srv,
                         outer) = self._lockstep_chunk(*chunk_args)
                        outer = np.asarray(outer)[0]
                    else:
                        self._state, self._ef, _, _ = self._lockstep_chunk(
                            *chunk_args
                        )
                        outer = None
                else:
                    discount = np.asarray(
                        (1.0 + stale) ** (-self.gamma), np.float32
                    )
                    admit_args = [
                        self._state, self._srv_payload,
                        jnp.asarray(self._srv_sw),
                        jnp.asarray(discount), jnp.asarray(self._heard),
                        jnp.asarray(mask),
                    ]
                    if self._server is not None:
                        admit_args.append(self._srv)
                        self._state, self._srv, outer = self._admit_fn(
                            *admit_args
                        )
                        outer = np.asarray(outer)
                    else:
                        self._state = self._admit_fn(*admit_args)
                        outer = None
                if outer is not None:
                    rec.outer_lr = float(outer[0])
                    rec.delta_norm = float(outer[1])
                jax.block_until_ready(jax.tree.leaves(self._state)[0])

            # Schedule every admitted worker's next compute in one sweep:
            # status/time/round updates are plain array writes (the arrays
            # are the event queue), so a 10k-worker admission costs numpy
            # vector ops, not 10k heap pushes.
            adm_idx = np.asarray(adm, dtype=np.intp)
            rs = self._ev_round[adm_idx]
            compute = (self._ks[rs, adm_idx].astype(np.float64)
                       * self._lat.step_s[rs, adm_idx])
            down = self._lat.down_s[rs, adm_idx]
            self._status[adm_idx] = _COMPUTE
            self._ev_round[adm_idx] = rs + 1
            self._ev_time[adm_idx] = t + down + compute
            self._ev_busy[adm_idx] = compute
            self._ev_is_phase[adm_idx] = not lockstep
            if lockstep:
                self._steps_cum[adm_idx] += self._ks[rs, adm_idx]
            if self.tracer.enabled:
                # Per-worker simulated-clock story of this admission: the
                # staleness hold, the broadcast flight, and the local phase
                # the worker now starts (its sim interval is known exactly).
                for i, m in enumerate(adm):
                    r = int(rs[i])
                    track = f"worker/{m}"
                    if t > self._arrive_t[m]:
                        self.tracer.add_span(
                            f"held r{r}", cat="held", track=track,
                            sim_t0=float(self._arrive_t[m]), sim_t1=t,
                            round=r, worker=int(m),
                        )
                    if down[i] > 0.0:
                        self.tracer.add_span(
                            f"broadcast r{r}", cat="broadcast", track=track,
                            sim_t0=t, sim_t1=t + float(down[i]),
                            round=r, worker=int(m),
                            bytes=float(self._dense_bytes),
                        )
                    if compute[i] > 0.0:
                        self.tracer.add_span(
                            f"local-compute r{r}", cat="local-compute",
                            track=track, sim_t0=t + float(down[i]),
                            sim_t1=t + float(down[i]) + float(compute[i]),
                            round=r, worker=int(m),
                            steps=int(self._ks[r, m]),
                            staleness=int(stale[m]),
                        )
            self.n_admissions += 1

        # Wall timing stays in the span layer (the recorded trace must be
        # deterministic for crash-resume bit-exactness); the full record
        # rides on the admission span so TraceRecorder.from_spans can
        # rebuild it with wall_time_s derived from the span.
        adm_sp.attrs.update(vars(rec))
        self.metrics.inc("bytes_up", rec.bytes_up, engine="async")
        self.metrics.inc("bytes_down", rec.bytes_down, engine="async")
        self.metrics.inc("admissions", 1, engine="async")
        self.metrics.set_gauge("eta_spread", rec.eta_spread, engine="async")
        if self._robust is not None:
            self.metrics.inc("byzantine_workers",
                             len(rec.byzantine_workers or []),
                             engine="async")
            self.metrics.set_gauge(
                "agg_reject_frac", self.aggregator.reject_frac(len(adm)),
                engine="async", aggregator=self.aggregator.name,
            )
        if self._server is not None and rec.delta_norm is not None:
            self.metrics.set_gauge(
                "outer_delta_norm", rec.delta_norm, engine="async",
                server_opt=self.server_opt.name,
            )
        if rec.idle_frac is not None:
            self.metrics.set_gauge("idle_frac", rec.idle_frac,
                                   engine="async", t_sim=t)
        for m in adm:
            self.metrics.observe("staleness", float(stale[m]),
                                 engine="async", t_sim=t)
        cost = modeled_sync_cost(
            getattr(self.compressor, "codec_spec", None),
            self._dense_bytes, workers=len(adm),
            backend=self.codec_backend,
        )
        self.metrics.observe(
            "admission_wall_s", adm_sp.wall_dur, engine="async",
            codec=self.compressor.name, backend=self.codec_backend,
            modeled_hbm_passes=cost["hbm_passes"],
            modeled_hbm_s=cost["hbm_s"], t_sim=t,
        )

    def _idle_frac(self, t: float) -> float | None:
        if t <= 0.0:
            return None
        busy = float(self._busy_s.sum())
        return max(0.0, 1.0 - busy / (self.config.num_workers * t))

    def _record_admission(self, adm, t, etas, stale, byz_mask) -> None:
        m_tot = self.config.num_workers
        # Steps newly completed since the worker's previous record: exactly
        # one phase lies between its consecutive admissions (or none, when
        # the intervening round was a dead reboot or an unsampled skip), so
        # the delta is that phase's K — and the ledger stays conserved
        # (Σ local_steps over all records ≡ steps_cum) under faults and
        # client sampling alike.
        steps = [0] * m_tot
        for m in adm:
            d = int(self._steps_cum[m] - self._steps_recorded[m])
            steps[m] = d
            self._steps_recorded[m] += d
        adm_etas = etas[list(adm)]
        res = None
        if self.eval_fn is not None:
            res = float(self.eval_fn(self.z_bar()))
        self.trace.record(RoundRecord(
            round=self.n_admissions,
            local_steps=steps,
            alive=[bool(m in adm) for m in range(m_tot)],
            bytes_up=len(adm) * self._msg_bytes,
            bytes_down=len(adm) * self._dense_bytes,
            eta_min=float(adm_etas.min()),
            eta_max=float(adm_etas.max()),
            eta_mean=float(adm_etas.mean()),
            residual=res,
            sim_time_s=float(t),
            staleness=[int(s) if h else None
                       for s, h in zip(stale, self._heard)],
            idle_frac=self._idle_frac(t),
            byzantine_workers=(
                [int(m) for m in adm if byz_mask[m]]
                if self.byzantine is not None else None
            ),
        ))

    def _record_final(self) -> None:
        """Terminal record once the whole fleet has finished: the final
        residual/η state at the fleet's completion time, carrying the last
        phases' step counts (there is no sync after the last local phase,
        so no admission covers them)."""
        if self._final_recorded:
            return
        t = float(self._done_at.max())
        etas = np.asarray(self._veta(self._state))
        res = None
        if self.eval_fn is not None:
            res = float(self.eval_fn(self.z_bar()))
        if self._heard.any():
            vmax = int(self._srv_version[self._heard].max())
            stale = np.where(self._heard, vmax - self._srv_version, 0)
        else:
            # an all-dead fleet never uplinked anything
            stale = np.zeros_like(self._srv_version)
        final_steps = self._steps_cum - self._steps_recorded
        self._steps_recorded += final_steps
        rec = RoundRecord(
            round=self.n_admissions,
            local_steps=final_steps.tolist(),
            alive=[False] * self.config.num_workers,
            bytes_up=0.0,
            bytes_down=0.0,
            eta_min=float(etas.min()),
            eta_max=float(etas.max()),
            eta_mean=float(etas.mean()),
            residual=res,
            sim_time_s=t,
            staleness=[int(s) if h else None
                       for s, h in zip(stale, self._heard)],
            idle_frac=self._idle_frac(t),
        )
        self.trace.record(rec)
        self.tracer.add_span(
            "final", cat="admission", sim_t0=t, sim_t1=t, **vars(rec)
        )
        self._final_recorded = True

    # ------------------------------------------------------------------
    # Driving
    # ------------------------------------------------------------------

    @property
    def done(self) -> bool:
        return bool((self._status == _DONE).all())

    @property
    def sim_time(self) -> float:
        """Current simulated-clock reading (seconds)."""
        return float(self._done_at.max()) if self.done else self.now

    def idle_fraction(self) -> float | None:
        """Fleet fraction of elapsed simulated time not spent computing
        (communication + staleness blocking; in-progress phases count as
        idle until they complete)."""
        return self._idle_frac(self.sim_time)

    def run(
        self,
        *,
        until_time: float | None = None,
        until_admissions: int | None = None,
        checkpoint_path: str | None = None,
        checkpoint_every: int | None = None,
    ) -> PyTree:
        """Drive the event queue (to completion by default) and return the
        global output iterate z̄. ``until_time`` stops before the first
        event past that simulated instant; ``until_admissions`` stops after
        that many server admissions (lifetime total); ``checkpoint_every``
        saves ``checkpoint_path`` every that-many admissions."""
        last_ckpt = self.n_admissions
        t_start = self.now
        with self.tracer.span("run", cat="run", engine="async",
                              tau=self.tau) as run_sp:
            self._drive(until_time, until_admissions,
                        checkpoint_path, checkpoint_every, last_ckpt)
            run_sp.sim_t0 = t_start
            run_sp.sim_t1 = self.sim_time
        return self.z_bar()

    def _next_time(self) -> float | None:
        """Earliest pending event instant — min over the per-worker event
        machine's COMPUTE (phase end) and UPLINK (arrival) times. ``None``
        when no worker has a pending event (fleet done, or deadlocked)."""
        pending = (self._status == _COMPUTE) | (self._status == _UPLINK)
        if not pending.any():
            return None
        return float(self._ev_time[pending].min())

    def _drive(self, until_time, until_admissions, checkpoint_path,
               checkpoint_every, last_ckpt) -> None:
        while True:
            t = self._next_time()
            if t is None:
                if not self.done:
                    raise RuntimeError(
                        "event queue drained with workers still blocked — "
                        "staleness deadlock (this is a bug)"
                    )
                break
            if until_time is not None and t > until_time:
                break
            if (until_admissions is not None
                    and self.n_admissions >= until_admissions):
                break
            # Drain every event at instant t: phase ends (STARTs) first —
            # they may spawn same-instant arrivals under zero uplink delay —
            # then arrivals, looping until the instant is quiet. This is
            # the documented tie-break (see the event-machine note up top).
            while True:
                at_t = self._ev_time == t
                s_idx = np.nonzero((self._status == _COMPUTE) & at_t)[0]
                if s_idx.size:
                    self._handle_starts(s_idx, t)
                    continue
                a_idx = np.nonzero((self._status == _UPLINK) & at_t)[0]
                if a_idx.size:
                    self._handle_arrivals(a_idx, t)
                    continue
                break
            self.now = t
            adm = self._admissible()
            if adm:
                self._admit_batch(adm, t)
            if (checkpoint_path is not None and checkpoint_every
                    and self.n_admissions - last_ckpt >= checkpoint_every):
                self.save(checkpoint_path)
                last_ckpt = self.n_admissions
        if self.done:
            self._record_final()
        if checkpoint_path is not None:
            self.save(checkpoint_path)

    @property
    def state(self) -> PyTree:
        return self._state

    def z_bar(self) -> PyTree:
        """Global output iterate: worker outputs weighted by the local step
        counts *completed on the simulated clock* — the synchronous
        engine's Line-14 expression over realized work."""
        counts = self._steps_cum.astype(np.float32)
        if counts.sum() == 0.0:
            counts = np.ones_like(counts)
        return weighted_worker_average(
            self.worker.output(self._state), jnp.asarray(counts)
        )

    # ------------------------------------------------------------------
    # Checkpointing — dynamic state only; policies re-derived from seeds.
    # ------------------------------------------------------------------

    def _ckpt_tree(self) -> dict:
        tree = {
            "worker_state": self._state,
            "ef": self._ef,
            "srv_payload": self._srv_payload,
            "srv_sw": jnp.asarray(self._srv_sw),
            "srv_version": jnp.asarray(self._srv_version),
            "heard": jnp.asarray(self._heard),
            "status": jnp.asarray(self._status),
            "ev_round": jnp.asarray(self._ev_round),
            "ev_is_phase": jnp.asarray(self._ev_is_phase),
            "progress": jnp.asarray(self._progress),
            "steps_cum": jnp.asarray(self._steps_cum),
            "steps_recorded": jnp.asarray(self._steps_recorded),
            # float64 event times round-trip as raw bytes: jnp would
            # silently truncate them to float32 without jax_enable_x64.
            "ev_time": _f64_bytes(self._ev_time),
            "ev_busy": _f64_bytes(self._ev_busy),
            "busy_s": _f64_bytes(self._busy_s),
            "done_at": _f64_bytes(self._done_at),
            "now": _f64_bytes(np.float64([self.now])),
            "n_admissions": jnp.int32(self.n_admissions),
            "final_recorded": jnp.asarray(bool(self._final_recorded)),
            "rng0": jnp.asarray(self._rng0),
            "worker_fp": jnp.uint32(self.worker.fingerprint),
        }
        if self._robust is not None:
            # only when the robust subsystem changes the merge semantics —
            # plain runs keep the historical checkpoint layout byte-for-byte
            tree["aggregator_fp"] = jnp.uint32(self.aggregator.fingerprint)
        if self._server is not None:
            # present only under an active outer optimizer — `none` keeps
            # the historical checkpoint layout byte-identical
            z, mom, t = self._srv
            tree["server_opt"] = {"z": z, "mom": mom, "t": t}
            tree["server_opt_fp"] = jnp.uint32(self.server_opt.fingerprint)
        return tree

    def save(self, path: str) -> None:
        with self.tracer.span("checkpoint-save", cat="checkpoint",
                              sim_t0=self.now, sim_t1=self.now,
                              path=path) as sp:
            sp.attrs["bytes"] = save_pytree(path, self._ckpt_tree())
            self.metrics.inc("checkpoint_bytes", sp.attrs["bytes"],
                             engine="async")

    def restore(self, path: str) -> "AsyncPSEngine":
        """Resume mid-event-queue: the per-worker event machine (status,
        times, rounds) IS the queue, so loading the arrays restores it
        wholesale; schedules, faults, latency tables and rng streams are
        re-derived from the config. Refuses checkpoints from a different
        seed or optimizer, like the synchronous engine."""
        try:
            loaded = load_pytree(path, self._ckpt_tree())
        except ValueError as e:
            raise ValueError(
                "checkpoint does not match this engine's state layout "
                f"({self.worker.name}): {e}"
            ) from e
        if int(np.asarray(loaded["worker_fp"])) != self.worker.fingerprint:
            raise ValueError(
                "checkpoint was written by a run with a different optimizer "
                f"(engine runs {self.worker.name})"
            )
        if not np.array_equal(
            np.asarray(loaded["rng0"]), np.asarray(self._rng0)
        ):
            raise ValueError(
                "checkpoint was written by a run with a different seed"
            )
        if self._robust is not None and (
            int(np.asarray(loaded["aggregator_fp"]))
            != self.aggregator.fingerprint
        ):
            raise ValueError(
                "checkpoint was written by a run with a different robust "
                "aggregator (the merge semantics would diverge)"
            )
        if self._server is not None:
            if int(
                np.asarray(loaded["server_opt_fp"])
            ) != self.server_opt.fingerprint:
                raise ValueError(
                    "checkpoint was written by a run with a different "
                    "server-side outer optimizer (engine runs "
                    f"{self.server_opt.name})"
                )
            so = loaded["server_opt"]
            self._srv = (so["z"], tuple(so["mom"]), so["t"])
        m = self.config.num_workers
        self._state = loaded["worker_state"]
        self._ef = loaded["ef"]
        self._srv_payload = loaded["srv_payload"]
        self._srv_sw = np.asarray(loaded["srv_sw"]).copy()
        self._srv_version = np.asarray(loaded["srv_version"]).copy()
        self._heard = np.asarray(loaded["heard"]).copy()
        self._status = np.asarray(loaded["status"]).copy()
        self._ev_round = np.asarray(loaded["ev_round"]).copy()
        self._ev_is_phase = np.asarray(loaded["ev_is_phase"]).copy()
        self._progress = np.asarray(loaded["progress"]).copy()
        self._steps_cum = np.asarray(loaded["steps_cum"]).copy()
        self._steps_recorded = np.asarray(loaded["steps_recorded"]).copy()
        self._ev_time = _f64_unbytes(loaded["ev_time"], m)
        self._ev_busy = _f64_unbytes(loaded["ev_busy"], m)
        self._busy_s = _f64_unbytes(loaded["busy_s"], m)
        self._done_at = _f64_unbytes(loaded["done_at"], m)
        self.now = float(_f64_unbytes(loaded["now"], 1)[0])
        self.n_admissions = int(np.asarray(loaded["n_admissions"]))
        self._final_recorded = bool(np.asarray(loaded["final_recorded"]))
        # drop telemetry from admissions past the restore point so a
        # rewound engine doesn't accumulate duplicate records
        self.trace.rounds = [
            rec for rec in self.trace.rounds if rec.round < self.n_admissions
        ]
        # held workers' uplink-arrival instants aren't checkpointed (span
        # layer only); clamp to "arrived by now" so held spans stay sane
        self._arrive_t[:] = np.minimum(self._arrive_t, self.now)
        return self


def _f64_bytes(arr: np.ndarray) -> jnp.ndarray:
    return jnp.asarray(
        np.frombuffer(np.ascontiguousarray(arr, np.float64).tobytes(),
                      np.uint8)
    )


def _f64_unbytes(leaf, n: int) -> np.ndarray:
    return np.frombuffer(
        np.asarray(leaf, np.uint8).tobytes(), np.float64
    ).reshape(n).copy()
