"""Heterogeneous data layer: Dirichlet-skewed per-worker oracles (§4.2/E.2).

The paper's federated setting gives every worker its own local distribution
``P_m``; the repo's problems carry this through ``MinimaxProblem.sample_worker``
(``(rng, worker_id) -> ξ``), which the serial, sharded and PS-engine drivers
all route through ``core.types.draw``. This module carves those per-worker
distributions for the three problem families with one knob — the Dirichlet
concentration ``alpha`` — so homogeneous vs heterogeneous is a config flag:

* **bilinear**  — workers see mean-shifted noise: worker m's ξ is centered at
  a Dirichlet-weighted combination of random directions, with the shifts
  centered across workers so the *global* mean problem is unchanged (the
  federated objective still equals the paper's §4.1 game).
* **robust-logistic** — the n examples are grouped into feature-space
  quantile bins and each worker samples minibatch indices with probability
  ∝ its Dirichlet mass on the example's group (a soft non-iid partition).
* **wgan** — each worker's real-data distribution reweights the 8 mixture
  modes by its Dirichlet row (the Fig. E2 heterogeneous GAN setting).

``heterogenize`` dispatches on the problem wrapper type.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from ..core.types import MinimaxProblem
from ..data.synthetic import (
    dirichlet_proportions,
    group_sampling_logits,
    quantile_groups,
)
from ..problems.bilinear import BilinearGame
from ..problems.robust import RobustLogistic
from ..problems.wgan import WGANProblem


def heterogeneous_bilinear(
    game: BilinearGame,
    num_workers: int,
    rng,
    alpha: float = 0.5,
    shift_scale: float = 0.5,
    num_components: int | None = None,
) -> MinimaxProblem:
    """Per-worker noise means δ_m = shift_scale·(p_m − mean_m p_m)·B with
    p_m ~ Dir(alpha) over ``num_components`` random unit directions B. The
    across-worker mean of the shifts is exactly zero, so averaging the local
    objectives recovers the original game.

    Examples
    --------
    >>> import jax
    >>> from repro.problems import make_bilinear_game
    >>> game = make_bilinear_game(jax.random.PRNGKey(0), n=4, sigma=0.1)
    >>> prob = heterogeneous_bilinear(game, 2, jax.random.PRNGKey(1),
    ...                               alpha=0.5)
    >>> prob.name
    'bilinear@hetero'
    >>> xi0 = prob.sample_worker(jax.random.PRNGKey(2), 0)
    >>> xi1 = prob.sample_worker(jax.random.PRNGKey(2), 1)
    >>> bool((xi0 != xi1).any())      # same rng, different local laws
    True
    """
    n = game.n
    g = num_components or min(8, n)
    r_p, r_b = jax.random.split(rng)
    props = dirichlet_proportions(r_p, num_workers, g, alpha)      # (M, G)
    basis = jax.random.normal(r_b, (g, n))
    basis = basis / jnp.linalg.norm(basis, axis=1, keepdims=True)
    shifts = shift_scale * (props - 1.0 / g) @ basis               # (M, n)
    shifts = shifts - jnp.mean(shifts, axis=0, keepdims=True)
    sigma = game.sigma

    def sample_worker(rng, worker_id):
        return shifts[worker_id] + sigma * jax.random.normal(rng, (n,))

    return dataclasses.replace(
        game.problem, sample_worker=sample_worker,
        name=game.problem.name + "@hetero",
    )


def heterogeneous_robust(
    rl: RobustLogistic,
    num_workers: int,
    rng,
    alpha: float = 0.5,
    num_groups: int = 4,
) -> MinimaxProblem:
    """Soft Dirichlet partition of the n examples: groups are quantile bins
    of a random feature projection; worker m draws minibatch indices with
    probability ∝ p_m[group(i)].

    Examples
    --------
    >>> import jax
    >>> from repro.problems import make_robust_logistic
    >>> rl = make_robust_logistic(jax.random.PRNGKey(0), n=32, d=4, batch=4)
    >>> prob = heterogeneous_robust(rl, 2, jax.random.PRNGKey(1), alpha=0.3)
    >>> idx = prob.sample_worker(jax.random.PRNGKey(2), 0)
    >>> idx.shape, bool((idx >= 0).all() and (idx < 32).all())
    ((4,), True)
    """
    d = rl.features.shape[1]
    r_p, r_u = jax.random.split(rng)
    proj = rl.features @ jax.random.normal(r_u, (d,))
    group_of = quantile_groups(proj, num_groups)
    props = dirichlet_proportions(r_p, num_workers, num_groups, alpha)
    logits = group_sampling_logits(props, group_of)                # (M, n)
    batch = int(rl.problem.sample(jax.random.PRNGKey(0)).shape[0])

    def sample_worker(rng, worker_id):
        return jax.random.categorical(rng, logits[worker_id], shape=(batch,))

    return dataclasses.replace(
        rl.problem, sample_worker=sample_worker,
        name=rl.problem.name + "@hetero",
    )


def heterogeneous_wgan(
    wg: WGANProblem,
    num_workers: int,
    rng,
    alpha: float = 0.6,
    modes: int = 8,
    radius: float = 2.0,
    std: float = 0.05,
) -> MinimaxProblem:
    """Per-worker real-data distribution over the mixture modes, reweighted
    by a Dirichlet row (Fig. E2's non-iid GAN setting).

    Examples
    --------
    >>> import jax
    >>> from repro.problems import make_wgan_problem
    >>> wg = make_wgan_problem(jax.random.PRNGKey(0), latent_dim=2,
    ...                        hidden=4, batch=4)
    >>> prob = heterogeneous_wgan(wg, 2, jax.random.PRNGKey(1), alpha=0.6)
    >>> xi = prob.sample_worker(jax.random.PRNGKey(2), 1)
    >>> sorted(xi), xi["real"].shape
    (['eps', 'real', 'z'], (4, 2))
    """
    props = dirichlet_proportions(rng, num_workers, modes, alpha)
    mode_logits = jnp.log(props + 1e-8)                            # (M, modes)

    def sample_worker(rng, worker_id):
        r_mode, r_noise, r_z, r_eps = jax.random.split(rng, 4)
        k = jax.random.categorical(
            r_mode, mode_logits[worker_id], shape=(wg.batch,)
        )
        theta = 2.0 * jnp.pi * k.astype(jnp.float32) / modes
        centers = radius * jnp.stack([jnp.cos(theta), jnp.sin(theta)], -1)
        real = centers + std * jax.random.normal(r_noise, (wg.batch, 2))
        return {
            "real": real,
            "z": jax.random.normal(r_z, (wg.batch, wg.latent_dim)),
            "eps": jax.random.uniform(r_eps, (wg.batch, 1)),
        }

    return dataclasses.replace(
        wg.problem, sample_worker=sample_worker,
        name=wg.problem.name + "@hetero",
    )


def heterogenize(obj, num_workers: int, rng, alpha: float = 0.5,
                 **kwargs) -> MinimaxProblem:
    """Dispatch on the problem wrapper: BilinearGame, RobustLogistic or
    WGANProblem → the matching Dirichlet-skewed per-worker problem.

    Examples
    --------
    >>> import jax
    >>> from repro.problems import make_bilinear_game
    >>> game = make_bilinear_game(jax.random.PRNGKey(0), n=4, sigma=0.1)
    >>> heterogenize(game, 2, jax.random.PRNGKey(1)).name
    'bilinear@hetero'
    >>> heterogenize(object(), 2, jax.random.PRNGKey(1))
    Traceback (most recent call last):
        ...
    TypeError: no heterogeneous partition for object
    """
    if isinstance(obj, BilinearGame):
        return heterogeneous_bilinear(obj, num_workers, rng, alpha, **kwargs)
    if isinstance(obj, RobustLogistic):
        return heterogeneous_robust(obj, num_workers, rng, alpha, **kwargs)
    if isinstance(obj, WGANProblem):
        return heterogeneous_wgan(obj, num_workers, rng, alpha, **kwargs)
    raise TypeError(f"no heterogeneous partition for {type(obj).__name__}")
