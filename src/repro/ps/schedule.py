"""Worker schedules: who does how much local work each round (Line 3–4).

A :class:`WorkerSchedule` decides, for every round ``r`` and worker ``m``,
how many local extragradient steps ``K_m^r`` the worker runs before the next
Parameter-Server sync. The engine pads every round to the schedule's static
``max_steps`` and masks the tail with the ``enabled`` argument of
``core.adaseg.local_step`` — exactly the mechanism the serial driver already
uses for the paper's asynchronous variant (Appendix E.1).

Schedules are *deterministic*: stochastic ones derive every draw from their
own integer ``seed`` with numpy, so the full (R, M) table is reproducible
from the config alone. This is what makes checkpoint/resume bit-exact — the
engine never stores the table, it re-derives it.

``K_m^r = 0`` models elastic membership: the worker skips the round's local
work but stays a member — it still contributes its (stale) anchor to the
weighted average and receives the broadcast. Workers *removed* from the
average entirely are the business of :mod:`repro.ps.faults`.
"""
from __future__ import annotations

import dataclasses

import numpy as np


class WorkerSchedule:
    """Base class. Subclasses fill in :meth:`steps`.

    Examples
    --------
    Any schedule yields a reproducible ``(rounds, workers)`` table bounded
    by its static ``max_steps``:

    >>> sched = StragglerSchedule(k=5, min_frac=0.4, seed=1)
    >>> table = sched.steps(num_workers=3, rounds=4)
    >>> table.shape, bool((table <= sched.max_steps(3)).all())
    ((4, 3), True)
    >>> bool((table == sched.steps(3, 4)).all())  # seed-deterministic
    True
    """

    def max_steps(self, num_workers: int) -> int:
        """Static upper bound on K_m^r — the engine's per-round scan length."""
        raise NotImplementedError

    def steps(self, num_workers: int, rounds: int) -> np.ndarray:
        """(rounds, num_workers) int32 table of per-round local step counts."""
        raise NotImplementedError


@dataclasses.dataclass(frozen=True)
class UniformSchedule(WorkerSchedule):
    """Every worker runs ``k`` steps every round — the paper's synchronous
    Parameter-Server setting. The engine with this schedule (plus identity
    compression and no faults) reproduces ``run_local_adaseg`` bit-exactly.

    Examples
    --------
    >>> UniformSchedule(k=3).steps(num_workers=2, rounds=2)
    array([[3, 3],
           [3, 3]], dtype=int32)
    """

    k: int

    def max_steps(self, num_workers: int) -> int:
        return int(self.k)

    def steps(self, num_workers: int, rounds: int) -> np.ndarray:
        return np.full((rounds, num_workers), self.k, dtype=np.int32)


@dataclasses.dataclass(frozen=True)
class FixedSchedule(WorkerSchedule):
    """Static per-worker K_m, constant across rounds — the asynchronous
    variant of Appendix E.1 ('Asynch-50' = K_m ∈ {50, 45, 40, 35}).

    Examples
    --------
    >>> FixedSchedule([3, 1]).steps(num_workers=2, rounds=2)
    array([[3, 1],
           [3, 1]], dtype=int32)
    """

    local_steps: tuple

    def __init__(self, local_steps):
        object.__setattr__(
            self, "local_steps",
            tuple(int(k) for k in np.asarray(local_steps).reshape(-1)),
        )

    def max_steps(self, num_workers: int) -> int:
        return max(self.local_steps)

    def steps(self, num_workers: int, rounds: int) -> np.ndarray:
        ks = np.asarray(self.local_steps, dtype=np.int32)
        if ks.shape[0] != num_workers:
            raise ValueError(
                f"schedule has {ks.shape[0]} workers, engine has {num_workers}"
            )
        return np.broadcast_to(ks, (rounds, num_workers)).copy()


@dataclasses.dataclass(frozen=True)
class StragglerSchedule(WorkerSchedule):
    """Seed-driven straggler/delay model: each round every worker completes
    ``K_m^r ~ Uniform{ceil(min_frac·k), …, k}`` steps before the sync
    deadline. Workers listed in ``slow_workers`` are persistent stragglers
    pinned at the minimum — the adversarial-straggler scenario.

    Examples
    --------
    >>> sched = StragglerSchedule(k=10, min_frac=0.5, seed=0,
    ...                           slow_workers=(1,))
    >>> table = sched.steps(num_workers=3, rounds=5)
    >>> bool((table[:, 1] == 5).all())           # pinned straggler
    True
    >>> bool((table >= 5).all() and (table <= 10).all())
    True
    """

    k: int
    min_frac: float = 0.5
    seed: int = 0
    slow_workers: tuple = ()

    def max_steps(self, num_workers: int) -> int:
        return int(self.k)

    def steps(self, num_workers: int, rounds: int) -> np.ndarray:
        lo = max(1, int(np.ceil(self.min_frac * self.k)))
        rng = np.random.default_rng(self.seed)
        ks = rng.integers(lo, self.k + 1, size=(rounds, num_workers))
        for m in self.slow_workers:
            ks[:, int(m)] = lo
        return ks.astype(np.int32)


@dataclasses.dataclass(frozen=True)
class ElasticSchedule(WorkerSchedule):
    """Elastic membership on top of an inner schedule: each round every
    worker independently sits out (K_m^r = 0) with probability ``dropout``.
    Sitting out ≠ failing — the worker still syncs (its stale anchor keeps
    its 1/η weight in the Line-7 average).

    Examples
    --------
    >>> sched = ElasticSchedule(UniformSchedule(k=4), dropout=0.5, seed=3)
    >>> table = sched.steps(num_workers=4, rounds=6)
    >>> sorted(set(table.reshape(-1).tolist()))  # sat-out rounds are 0
    [0, 4]
    """

    inner: WorkerSchedule
    dropout: float = 0.2
    seed: int = 0

    def max_steps(self, num_workers: int) -> int:
        return self.inner.max_steps(num_workers)

    def steps(self, num_workers: int, rounds: int) -> np.ndarray:
        ks = self.inner.steps(num_workers, rounds)
        rng = np.random.default_rng(self.seed)
        out = rng.random((rounds, num_workers)) < self.dropout
        return np.where(out, 0, ks).astype(np.int32)
