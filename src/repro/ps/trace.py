"""Per-round telemetry for the Parameter-Server engine.

One :class:`RoundRecord` per engine round: communication volume (bytes up =
survivors × compressed message size, bytes down = survivors × dense anchor
broadcast), the effective local step count per worker, the aliveness mask,
the η spread across workers at the end of the round, the round's wall-clock
share and local-steps/sec throughput, and — when the engine was given an
``eval_fn`` — the problem residual of the running global output iterate.
The recorder serializes to JSON for the bench harnesses
(``benchmarks/bench_ps.py``, ``benchmarks/bench_fig4_scenarios.py``) and
for offline plotting.
"""
from __future__ import annotations

import dataclasses
import json
from typing import Any


@dataclasses.dataclass
class RoundRecord:
    round: int
    local_steps: list          # effective K per worker (0 = sat out / down)
    alive: list                # bool per worker
    bytes_up: float            # Σ_alive compressed message bytes
    bytes_down: float          # Σ_alive dense broadcast bytes
    eta_min: float
    eta_max: float
    eta_mean: float
    residual: float | None = None
    wall_time_s: float | None = None   # this round's share of chunk wall time
    steps_per_sec: float | None = None  # effective local steps / wall_time_s

    @property
    def eta_spread(self) -> float:
        return self.eta_max / max(self.eta_min, 1e-30)


class TraceRecorder:
    """Accumulates RoundRecords and summarizes/serializes them."""

    def __init__(self, meta: dict | None = None):
        self.meta: dict = dict(meta or {})
        self.rounds: list[RoundRecord] = []

    def record(self, rec: RoundRecord) -> None:
        self.rounds.append(rec)

    # -- aggregates ---------------------------------------------------------

    @property
    def total_bytes_up(self) -> float:
        return sum(r.bytes_up for r in self.rounds)

    @property
    def total_bytes_down(self) -> float:
        return sum(r.bytes_down for r in self.rounds)

    @property
    def total_steps(self) -> int:
        return int(sum(sum(r.local_steps) for r in self.rounds))

    @property
    def total_wall_time_s(self) -> float:
        return sum(r.wall_time_s for r in self.rounds
                   if r.wall_time_s is not None)

    @property
    def steps_per_sec(self) -> float | None:
        """Aggregate local-steps/sec over every timed round."""
        timed = [r for r in self.rounds if r.wall_time_s]
        wall = sum(r.wall_time_s for r in timed)
        if wall <= 0.0:
            return None
        return sum(sum(r.local_steps) for r in timed) / wall

    def summary(self) -> dict:
        out = {
            "rounds": len(self.rounds),
            "total_steps": self.total_steps,
            "bytes_up": self.total_bytes_up,
            "bytes_down": self.total_bytes_down,
        }
        wall = self.total_wall_time_s
        if wall > 0.0:
            out["wall_time_s"] = wall
            out["steps_per_sec"] = self.steps_per_sec
        residuals = [r.residual for r in self.rounds if r.residual is not None]
        if residuals:
            out["final_residual"] = residuals[-1]
        return out

    # -- serialization ------------------------------------------------------

    def to_json(self) -> str:
        def _plain(v: Any):
            if hasattr(v, "item") and getattr(v, "ndim", 1) == 0:
                return v.item()
            return v

        payload = {
            "meta": self.meta,
            "summary": self.summary(),
            "rounds": [
                {k: _plain(v) for k, v in dataclasses.asdict(r).items()}
                for r in self.rounds
            ],
        }
        return json.dumps(payload, indent=2)

    def save(self, path: str) -> None:
        with open(path, "w") as f:
            f.write(self.to_json())

    @classmethod
    def load(cls, path: str) -> "TraceRecorder":
        with open(path) as f:
            payload = json.load(f)
        rec = cls(meta=payload.get("meta"))
        for r in payload.get("rounds", []):
            rec.record(RoundRecord(**r))
        return rec
