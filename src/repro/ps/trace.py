"""Per-round telemetry for the Parameter-Server engines.

One :class:`RoundRecord` per engine round (synchronous ``PSEngine``) or per
server admission batch (event-driven ``AsyncPSEngine``): communication
volume (bytes up = survivors × compressed message size, bytes down =
survivors × dense anchor broadcast), the effective local step count per
worker, the aliveness/participation mask, the η spread across workers at the
end of the round, the round's wall-clock share and local-steps/sec
throughput, and — when the engine was given an ``eval_fn`` — the problem
residual of the running global output iterate.

Records from the async engine additionally carry the *simulated-time* story:
``sim_time_s`` (when the server admitted the batch on the simulated clock),
``staleness`` (per worker, how many rounds behind the freshest contribution
its stored payload is) and ``idle_frac`` (fleet fraction of simulated time
spent blocked on communication or the staleness bound rather than
computing). All three default to ``None`` so traces written before the
async engine existed still load.

The recorder serializes to JSON (:meth:`TraceRecorder.save`) and loads back
(:meth:`TraceRecorder.load` — the inverse, tolerant of records written by
newer versions with extra fields) for the bench harnesses
(``benchmarks/bench_ps.py``, ``benchmarks/bench_async.py``,
``benchmarks/bench_fig4_scenarios.py``) and for offline plotting.
"""
from __future__ import annotations

import dataclasses
import json
from typing import Any

#: Trace JSON schema version, continuing the field-shaped revision history
#: of ``docs/formats.md`` (1–4 were implicit). 5 is the first revision to
#: stamp the file with an explicit ``version`` key; loaders treat a missing
#: key as 1, the oldest vintage — safe, since every post-v1 field is
#: optional anyway. 6 adds sampled-client participation: a per-record
#: ``sampled_workers`` id list plus ``sampler``/``sample`` meta keys. 7 adds
#: the hostile-fleet story: a per-record ``byzantine_workers`` id list plus
#: ``byzantine``/``aggregator``/``dp`` meta keys (v6 traces still load —
#: every new field is optional). 8 adds the server-side outer optimizer:
#: per-record ``outer_lr``/``delta_norm`` telemetry plus a ``server_opt``
#: meta key (v7 traces still load — same optional-field discipline).
TRACE_VERSION = 8


@dataclasses.dataclass
class RoundRecord:
    """One round's (or admission batch's) telemetry — the unit of the trace
    JSON's ``rounds`` list (field-by-field spec: ``docs/formats.md``).

    Examples
    --------
    >>> rec = RoundRecord(round=0, local_steps=[3, 2], alive=[True, True],
    ...                   bytes_up=80.0, bytes_down=80.0,
    ...                   eta_min=0.5, eta_max=1.0, eta_mean=0.75)
    >>> rec.eta_spread
    2.0
    >>> rec.sim_time_s is None        # sync engines leave async fields None
    True
    """

    round: int
    local_steps: list          # effective K per worker (0 = sat out / down)
    alive: list                # bool per worker
    bytes_up: float            # Σ_alive compressed message bytes
    bytes_down: float          # Σ_alive dense broadcast bytes
    eta_min: float
    eta_max: float
    eta_mean: float
    residual: float | None = None
    wall_time_s: float | None = None   # this round's share of chunk wall time
    steps_per_sec: float | None = None  # effective local steps / wall_time_s
    # --- async (simulated-time) telemetry; None for synchronous engines ----
    sim_time_s: float | None = None    # simulated clock at server admission
    staleness: list | None = None      # per worker: rounds behind freshest
    idle_frac: float | None = None     # fleet idle fraction up to sim_time_s
    # --- sampled-client rounds (v6); None = full participation ------------
    # fleet ids drawn this round, ascending; when set, the per-worker lists
    # above (local_steps/alive/staleness) are per *sampled lane*, length
    # meta["sample"], aligned with these ids
    sampled_workers: list | None = None
    # --- hostile-fleet rounds (v7); None = no Byzantine policy configured --
    # fleet ids of the workers whose uplink was adversarially corrupted this
    # round (empty list = policy active but nobody attacked this round)
    byzantine_workers: list | None = None
    # --- server-side outer optimizer (v8); None = historical Line-7 merge --
    outer_lr: float | None = None      # effective outer step size this round
    delta_norm: float | None = None    # ‖Δ‖₂ of the round's pseudo-gradient

    @property
    def eta_spread(self) -> float:
        return self.eta_max / max(self.eta_min, 1e-30)


class TraceRecorder:
    """Accumulates RoundRecords and summarizes/serializes them.

    Examples
    --------
    >>> import os, tempfile
    >>> rec = TraceRecorder(meta={"problem": "demo"})
    >>> rec.record(RoundRecord(round=0, local_steps=[2, 2],
    ...                        alive=[True, True], bytes_up=8.0,
    ...                        bytes_down=8.0, eta_min=1.0, eta_max=1.0,
    ...                        eta_mean=1.0, residual=0.5))
    >>> rec.total_steps, rec.total_bytes_up
    (4, 8.0)
    >>> with tempfile.TemporaryDirectory() as d:
    ...     rec.save(os.path.join(d, "t.json"))
    ...     back = TraceRecorder.load(os.path.join(d, "t.json"))
    >>> back.meta["problem"], back.rounds[0].residual
    ('demo', 0.5)
    """

    def __init__(self, meta: dict | None = None):
        self.meta: dict = dict(meta or {})
        self.rounds: list[RoundRecord] = []
        self.version: int = TRACE_VERSION

    def record(self, rec: RoundRecord) -> None:
        self.rounds.append(rec)

    @classmethod
    def from_spans(cls, tracer_or_spans, meta: dict | None = None
                   ) -> "TraceRecorder":
        """Rebuild a recorder from the span layer (:mod:`repro.obs.spans`).

        The engines attach every :class:`RoundRecord`'s fields to the span
        that timed it (``cat="round"`` in the sync engine, ``"admission"``
        in the event-driven one), so the span trace alone reconstructs the
        trace JSON — with ``wall_time_s``/``steps_per_sec`` *derived from
        the span's wall clock* when the record itself left them unset (the
        async engine's records stay deterministic for crash-resume
        bit-exactness; the nondeterministic wall timing lives here).

        Examples
        --------
        >>> from repro.obs.spans import SpanTracer
        >>> tr = SpanTracer()
        >>> _ = tr.add_span("round 0", cat="round", wall_t0=1.0, wall_t1=1.5,
        ...                 round=0, local_steps=[2, 2], alive=[True, True],
        ...                 bytes_up=8.0, bytes_down=8.0, eta_min=1.0,
        ...                 eta_max=1.0, eta_mean=1.0)
        >>> rec = TraceRecorder.from_spans(tr)
        >>> rec.rounds[0].wall_time_s, rec.rounds[0].steps_per_sec
        (0.5, 8.0)
        """
        spans = getattr(tracer_or_spans, "spans", tracer_or_spans)
        known = {f.name for f in dataclasses.fields(RoundRecord)}
        rec = cls(meta=meta)
        for sp in spans:
            if sp.cat not in ("round", "admission"):
                continue
            fields = {k: v for k, v in sp.attrs.items() if k in known}
            if "round" not in fields or "local_steps" not in fields:
                continue  # a span without a riding record (e.g. bare timing)
            r = RoundRecord(**fields)
            if r.wall_time_s is None and sp.wall_dur is not None:
                r.wall_time_s = sp.wall_dur
                steps = sum(r.local_steps)
                if r.wall_time_s > 0.0 and steps:
                    r.steps_per_sec = steps / r.wall_time_s
            rec.record(r)
        rec.rounds.sort(key=lambda r: r.round)
        return rec

    # -- aggregates ---------------------------------------------------------

    @property
    def total_bytes_up(self) -> float:
        return sum(r.bytes_up for r in self.rounds)

    @property
    def total_bytes_down(self) -> float:
        return sum(r.bytes_down for r in self.rounds)

    @property
    def total_steps(self) -> int:
        return int(sum(sum(r.local_steps) for r in self.rounds))

    @property
    def total_wall_time_s(self) -> float:
        return sum(r.wall_time_s for r in self.rounds
                   if r.wall_time_s is not None)

    @property
    def sim_time_s(self) -> float | None:
        """Final simulated-clock reading (async engines only)."""
        times = [r.sim_time_s for r in self.rounds if r.sim_time_s is not None]
        return max(times) if times else None

    @property
    def max_staleness(self) -> int | None:
        """Largest per-entry staleness any admission ever averaged over
        (``None`` entries — workers the server hadn't heard from yet — are
        ignored)."""
        vals = [s for r in self.rounds if r.staleness
                for s in r.staleness if s is not None]
        return int(max(vals)) if vals else None

    def time_to_residual(self, target: float) -> float | None:
        """First simulated time at which the recorded residual reached
        ``target`` — the time-to-accuracy metric ``bench_async`` plots."""
        for r in self.rounds:
            if (r.sim_time_s is not None and r.residual is not None
                    and r.residual <= target):
                return float(r.sim_time_s)
        return None

    @property
    def steps_per_sec(self) -> float | None:
        """Aggregate local-steps/sec over every timed round."""
        timed = [r for r in self.rounds if r.wall_time_s]
        wall = sum(r.wall_time_s for r in timed)
        if wall <= 0.0:
            return None
        return sum(sum(r.local_steps) for r in timed) / wall

    def summary(self) -> dict:
        out = {
            "rounds": len(self.rounds),
            "total_steps": self.total_steps,
            "bytes_up": self.total_bytes_up,
            "bytes_down": self.total_bytes_down,
        }
        wall = self.total_wall_time_s
        if wall > 0.0:
            out["wall_time_s"] = wall
            out["steps_per_sec"] = self.steps_per_sec
        residuals = [r.residual for r in self.rounds if r.residual is not None]
        if residuals:
            out["final_residual"] = residuals[-1]
        sim = self.sim_time_s
        if sim is not None:
            out["sim_time_s"] = sim
            stale = self.max_staleness
            if stale is not None:
                out["max_staleness"] = stale
            idles = [r.idle_frac for r in self.rounds
                     if r.idle_frac is not None]
            if idles:
                out["idle_frac"] = idles[-1]
        return out

    # -- serialization ------------------------------------------------------

    def to_json(self) -> str:
        def _plain(v: Any):
            if hasattr(v, "item") and getattr(v, "ndim", 1) == 0:
                return v.item()
            return v

        payload = {
            "version": self.version,
            "meta": self.meta,
            "summary": self.summary(),
            "rounds": [
                {k: _plain(v) for k, v in dataclasses.asdict(r).items()}
                for r in self.rounds
            ],
        }
        return json.dumps(payload, indent=2)

    def save(self, path: str) -> None:
        with open(path, "w") as f:
            f.write(self.to_json())

    @classmethod
    def load(cls, path: str) -> "TraceRecorder":
        """Inverse of :meth:`save`. Fields missing from old trace files fall
        back to the RoundRecord defaults, and fields this version doesn't
        know (written by a newer one) are dropped — so bench/plot code can
        read any vintage of trace through one API instead of re-parsing the
        JSON by hand."""
        with open(path) as f:
            payload = json.load(f)
        known = {f.name for f in dataclasses.fields(RoundRecord)}
        rec = cls(meta=payload.get("meta"))
        # pre-versioning traces carry no "version" key: that's version 1
        rec.version = int(payload.get("version", 1))
        for r in payload.get("rounds", []):
            rec.record(RoundRecord(**{k: v for k, v in r.items()
                                      if k in known}))
        return rec
