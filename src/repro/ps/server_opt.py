"""Server-side *outer* optimizers — the DiLoCo/FedOpt two-level scheme.

The paper's server merge (Algorithm 1, Line 7) replaces every worker's
anchor with the 1/η-weighted average of the fleet's local iterates. The
two-level view (ROADMAP item 2; Sharma et al. 2022, Sun & Wei 2022 for the
minimax case; the DiLoCo recipe for the LM case) treats the per-round
movement of that merge as a *pseudo-gradient*,

    Δ_r = merge(z̃_1..M) − z_server ,

and runs a small stateful optimizer over it on the server: the broadcast
anchor becomes ``z_server ← z_server + lr · update(Δ_r)`` instead of the
raw merge. With ``lr = 1`` and no momentum this IS Line 7 — which is why
the ``none`` policy resolves to the historical code path bit-exactly.

Policies are frozen dataclasses mirroring ``repro.ps.robust``: each has a
stable ``name`` (hyperparameters folded in), a crc32 ``fingerprint``
(checkpointed as ``server_opt_fp`` so a restore under a different outer
optimizer is rejected), and a static ``spec`` tuple that the fused Pallas
kernel and its jnp reference twin
(``kernels.sync_compress.ops.server_outer_apply``) switch on without a
semantics fork:

* ``("momentum", lr, β)``       — m′ = β·m + Δ;  z′ = z + lr·m′
* ``("nesterov", lr, β)``       — m′ = β·m + Δ;  z′ = z + lr·(Δ + β·m′)
* ``("adam", lr, β₁, β₂, ε)``   — bias-corrected Adam over Δ (t counts
  server rounds, not worker steps)

The sign convention is ascent along Δ: Δ already points from the current
server anchor toward the fleet's merged iterate, so the outer optimizer
*follows* it (an outer SGD with lr=1 is a no-op relative to Line 7).

Engine placement: the outer step runs **downstream of robust
aggregation** — Byzantine rejection happens on the raw worker iterates,
then the surviving merge is fed to the optimizer — and upstream of
delivery gating (workers that miss the broadcast keep their stale anchor,
exactly like the historical path).

Examples
--------
Policies are hashable specs with checkpoint fingerprints:

>>> from repro.ps.server_opt import (NoServerOpt, ServerAdam,
...                                  ServerMomentum, ServerNesterov)
>>> ServerNesterov(lr=0.7, beta=0.9).spec
('nesterov', 0.7, 0.9)
>>> ServerAdam().slots          # two moment trees (m, v)
2
>>> opts = [ServerMomentum(), ServerNesterov(), ServerAdam()]
>>> len({o.fingerprint for o in opts}) == 3   # distinct per policy+hypers
True
>>> ServerMomentum().fingerprint != ServerMomentum(beta=0.5).fingerprint
True

``none`` resolves away entirely — the engine compiles the identical
historical merge:

>>> NoServerOpt().spec is None
True
"""
from __future__ import annotations

import dataclasses
import zlib

import jax
import jax.numpy as jnp


class ServerOptimizer:
    """Base protocol: a server-side optimizer over round deltas.

    Subclasses define ``name`` (hyperparameters folded in — it feeds the
    checkpoint fingerprint) and ``spec`` (the static tuple the fused
    kernel switches on; ``None`` means *no outer step*, the historical
    Line-7 path). ``slots`` is the number of z-shaped moment trees the
    policy carries (0 for none, 1 for momentum/nesterov, 2 for adam).

    Examples
    --------
    >>> from repro.ps import (NoServerOpt, ServerAdam, ServerMomentum,
    ...                       ServerNesterov)
    >>> ServerMomentum(lr=0.5, beta=0.8).spec
    ('momentum', 0.5, 0.8)
    >>> ServerNesterov().spec                 # DiLoCo's outer optimizer
    ('nesterov', 1.0, 0.9)
    >>> ServerAdam().spec                     # FedOpt's FedAdam shape
    ('adam', 1.0, 0.9, 0.99, 1e-08)
    >>> (NoServerOpt().slots, ServerNesterov().slots, ServerAdam().slots)
    (0, 1, 2)
    >>> import jax.numpy as jnp
    >>> mom = ServerAdam().init_moments({"p": jnp.ones((1, 3))})
    >>> len(mom), float(mom[0]["p"].sum())
    (2, 0.0)
    """

    slots = 0

    @property
    def name(self) -> str:
        raise NotImplementedError

    @property
    def spec(self):
        """Static math spec consumed by ``server_outer_apply`` — or None
        for the identity (historical) server."""
        return None

    @property
    def fingerprint(self) -> int:
        """crc32 of the policy name — serialized as ``server_opt_fp`` so
        restores under a different outer optimizer (or different
        hyperparameters) are rejected."""
        return zlib.crc32(self.name.encode()) & 0xFFFFFFFF

    def init_moments(self, z):
        """Zero moment trees shaped like the server anchor ``z``."""
        return tuple(jax.tree.map(jnp.zeros_like, z)
                     for _ in range(self.slots))


@dataclasses.dataclass(frozen=True)
class NoServerOpt(ServerOptimizer):
    """Explicit historical server: broadcast the merge as-is (Line 7).

    Resolves to the same compiled functions as ``server_opt=None`` —
    bit-exact, including the checkpoint layout (no ``server_opt_fp``).
    """

    @property
    def name(self) -> str:
        return "none"


@dataclasses.dataclass(frozen=True)
class ServerMomentum(ServerOptimizer):
    """Heavy-ball over round deltas: m′ = β·m + Δ, z′ = z + lr·m′."""

    lr: float = 1.0
    beta: float = 0.9
    slots = 1

    def __post_init__(self):
        if not (self.lr > 0):
            raise ValueError(f"lr must be positive, got {self.lr}")
        if not (0.0 <= self.beta < 1.0):
            raise ValueError(f"beta must be in [0, 1), got {self.beta}")

    @property
    def name(self) -> str:
        return f"momentum[lr={self.lr:g},beta={self.beta:g}]"

    @property
    def spec(self):
        return ("momentum", float(self.lr), float(self.beta))


@dataclasses.dataclass(frozen=True)
class ServerNesterov(ServerOptimizer):
    """Nesterov over round deltas — the DiLoCo outer optimizer:
    m′ = β·m + Δ, z′ = z + lr·(Δ + β·m′)."""

    lr: float = 1.0
    beta: float = 0.9
    slots = 1

    def __post_init__(self):
        if not (self.lr > 0):
            raise ValueError(f"lr must be positive, got {self.lr}")
        if not (0.0 <= self.beta < 1.0):
            raise ValueError(f"beta must be in [0, 1), got {self.beta}")

    @property
    def name(self) -> str:
        return f"nesterov[lr={self.lr:g},beta={self.beta:g}]"

    @property
    def spec(self):
        return ("nesterov", float(self.lr), float(self.beta))


@dataclasses.dataclass(frozen=True)
class ServerAdam(ServerOptimizer):
    """Bias-corrected Adam over round deltas (FedOpt's FedAdam shape);
    ``t`` counts server rounds, so the bias correction warms up over the
    first few syncs exactly like step-indexed Adam."""

    lr: float = 1.0
    beta1: float = 0.9
    beta2: float = 0.99
    eps: float = 1e-8
    slots = 2

    def __post_init__(self):
        if not (self.lr > 0):
            raise ValueError(f"lr must be positive, got {self.lr}")
        for nm, b in (("beta1", self.beta1), ("beta2", self.beta2)):
            if not (0.0 <= b < 1.0):
                raise ValueError(f"{nm} must be in [0, 1), got {b}")
        if not (self.eps > 0):
            raise ValueError(f"eps must be positive, got {self.eps}")

    @property
    def name(self) -> str:
        return (f"adam[lr={self.lr:g},b1={self.beta1:g},"
                f"b2={self.beta2:g},eps={self.eps:g}]")

    @property
    def spec(self):
        return ("adam", float(self.lr), float(self.beta1),
                float(self.beta2), float(self.eps))


def resolve_server_opt(config):
    """The engine-side resolution: ``None`` for the historical path.

    ``server_opt=None`` and an explicit :class:`NoServerOpt` both resolve
    to ``None`` — the engines then compile the *identical* merge closure
    (same signature, same cache key component) and keep the historical
    checkpoint layout byte-identical, mirroring ``resolve_robust``.

    Examples
    --------
    >>> from repro.ps.server_opt import (NoServerOpt, ServerNesterov,
    ...                                  resolve_server_opt)
    >>> class Cfg: server_opt = None
    >>> resolve_server_opt(Cfg()) is None
    True
    >>> Cfg.server_opt = NoServerOpt()
    >>> resolve_server_opt(Cfg()) is None     # explicit none also resolves
    True
    >>> Cfg.server_opt = ServerNesterov()
    >>> resolve_server_opt(Cfg()).name
    'nesterov[lr=1,beta=0.9]'
    """
    so = getattr(config, "server_opt", None)
    if so is None or so.spec is None:
        return None
    return so
