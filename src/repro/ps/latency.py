"""Latency models: how long compute and communication take, per worker.

The event-driven engine (:mod:`repro.ps.async_engine`) advances a *simulated*
clock; this module decides what the clock advances by. A
:class:`LatencyModel` produces, for a fleet of ``M`` workers over ``R``
worker-rounds, three ``(R, M)`` float64 tables (:class:`LatencyTables`):

* ``step_s``  — seconds per local step (round ``r`` of worker ``m`` costs
  ``K_m^r · step_s[r, m]`` of compute),
* ``up_s``    — network delay of the round's uplink message,
* ``down_s``  — network delay of the round's downlink broadcast.

Like the schedules (:mod:`repro.ps.schedule`) and fault policies
(:mod:`repro.ps.faults`), latency models are *deterministic functions of
their own integer seed*: the engine never stores the tables, it re-derives
them — which is what makes crash/resume of the event queue bit-exact, and
lets a benchmark re-run the exact same fleet.

``ConstantLatency`` with worker-equal values is the *degenerate* model: the
whole fleet moves in lockstep, every arrival batches, and the async engine
reproduces the synchronous :class:`~repro.ps.engine.PSEngine` bit-exactly
(the parity anchor pinned by ``tests/test_ps_async.py``).
"""
from __future__ import annotations

import dataclasses

import numpy as np


def _per_worker_row(value, num_workers: int, name: str) -> np.ndarray:
    """A scalar or length-M sequence -> (M,) float64 row."""
    row = np.asarray(value, dtype=np.float64).reshape(-1)
    if row.size == 1:
        row = np.full((num_workers,), float(row[0]))
    if row.shape != (num_workers,):
        raise ValueError(
            f"{name} must be a scalar or length-{num_workers} sequence, "
            f"got shape {row.shape}"
        )
    if (row < 0.0).any():
        raise ValueError(f"{name} must be nonnegative")
    return row


@dataclasses.dataclass(frozen=True)
class LatencyTables:
    """Realized (R, M) float64 delay tables for one fleet run.

    Examples
    --------
    >>> import numpy as np
    >>> t = LatencyTables(step_s=np.ones((2, 3)), up_s=np.zeros((2, 3)),
    ...                   down_s=np.zeros((2, 3)))
    >>> t.step_s.shape
    (2, 3)
    """

    step_s: np.ndarray   # seconds per local step
    up_s: np.ndarray     # uplink delay per round
    down_s: np.ndarray   # downlink delay per round

    def __post_init__(self):
        shapes = {self.step_s.shape, self.up_s.shape, self.down_s.shape}
        if len(shapes) != 1 or len(self.step_s.shape) != 2:
            raise ValueError(f"latency tables must share one (R, M) shape, "
                             f"got {shapes}")


class LatencyModel:
    """Base class. Subclasses fill in :meth:`tables`.

    Examples
    --------
    Models are seed-deterministic (R, M) table factories:

    >>> lat = LognormalLatency(step_s=1.0, sigma=0.5, seed=2)
    >>> a, b = lat.tables(3, 4), lat.tables(3, 4)
    >>> a.step_s.shape, bool((a.step_s == b.step_s).all())
    ((4, 3), True)
    """

    def tables(self, num_workers: int, rounds: int) -> LatencyTables:
        raise NotImplementedError


@dataclasses.dataclass(frozen=True)
class ConstantLatency(LatencyModel):
    """Deterministic delays; each field is a scalar or a per-worker tuple.

    Worker-equal values are the degenerate lockstep model (the sync-parity
    anchor); per-worker ``step_s`` like ``(1, 1, 1, 4)`` is the classic
    persistent-straggler fleet.

    Examples
    --------
    >>> lat = ConstantLatency(step_s=(1.0, 4.0), up_s=0.5)
    >>> t = lat.tables(num_workers=2, rounds=3)
    >>> t.step_s[:, 1].tolist(), t.up_s[0].tolist()
    ([4.0, 4.0, 4.0], [0.5, 0.5])
    """

    step_s: float | tuple = 1.0
    up_s: float | tuple = 0.0
    down_s: float | tuple = 0.0

    def tables(self, num_workers: int, rounds: int) -> LatencyTables:
        def table(value, name):
            row = _per_worker_row(value, num_workers, name)
            return np.broadcast_to(row, (rounds, num_workers)).copy()

        return LatencyTables(
            step_s=table(self.step_s, "step_s"),
            up_s=table(self.up_s, "up_s"),
            down_s=table(self.down_s, "down_s"),
        )


@dataclasses.dataclass(frozen=True)
class LognormalLatency(LatencyModel):
    """Heavy-tailed jitter: every (round, worker) compute/uplink draw is the
    median scaled by an independent lognormal multiplier ``exp(sigma · N)``
    — the standard model for datacenter straggler tails (median = the
    configured value, mean above it).

    Examples
    --------
    >>> lat = LognormalLatency(step_s=2.0, sigma=0.3, seed=7)
    >>> t = lat.tables(num_workers=4, rounds=5)
    >>> bool((t.step_s > 0).all())
    True
    """

    step_s: float = 1.0
    sigma: float = 0.5        # log-std of the per-round compute multiplier
    up_s: float = 0.0
    down_s: float = 0.0
    net_sigma: float = 0.0    # log-std of the uplink/downlink multipliers
    seed: int = 0

    def tables(self, num_workers: int, rounds: int) -> LatencyTables:
        rng = np.random.default_rng(self.seed)
        shape = (rounds, num_workers)

        def jitter(median, sig, name):
            base = np.broadcast_to(
                _per_worker_row(median, num_workers, name), shape
            )
            if sig <= 0.0:
                return base.copy()
            return base * np.exp(sig * rng.standard_normal(shape))

        return LatencyTables(
            step_s=jitter(self.step_s, self.sigma, "step_s"),
            up_s=jitter(self.up_s, self.net_sigma, "up_s"),
            down_s=jitter(self.down_s, self.net_sigma, "down_s"),
        )


@dataclasses.dataclass(frozen=True)
class MarkovLatency(LatencyModel):
    """Gilbert–Elliott slow/fast compute: each worker carries a two-state
    Markov chain over its rounds — fast workers fall into a ``slow_factor``×
    slower state with probability ``p_slow`` per round and recover with
    probability ``p_recover``. Models transient co-tenancy/thermal
    throttling rather than a permanently slow machine; ``start_slow`` pins
    chosen workers into the slow state at round 0.

    Examples
    --------
    >>> lat = MarkovLatency(step_s=1.0, slow_factor=8.0, start_slow=(0,),
    ...                     p_recover=0.0, p_slow=0.0, seed=0)
    >>> t = lat.tables(num_workers=2, rounds=3)
    >>> t.step_s[:, 0].tolist(), t.step_s[:, 1].tolist()
    ([8.0, 8.0, 8.0], [1.0, 1.0, 1.0])
    """

    step_s: float = 1.0
    slow_factor: float = 8.0
    p_slow: float = 0.1
    p_recover: float = 0.3
    up_s: float = 0.0
    down_s: float = 0.0
    seed: int = 0
    start_slow: tuple = ()

    def tables(self, num_workers: int, rounds: int) -> LatencyTables:
        rng = np.random.default_rng(self.seed)
        draws = rng.random((rounds, num_workers))
        slow = np.zeros((rounds, num_workers), dtype=bool)
        state = np.zeros((num_workers,), dtype=bool)
        state[list(self.start_slow)] = True
        for r in range(rounds):
            slow[r] = state
            flip = np.where(state, draws[r] < self.p_recover,
                            draws[r] < self.p_slow)
            state = state ^ flip
        step = np.where(slow, self.step_s * self.slow_factor, self.step_s)
        net = np.broadcast_to
        return LatencyTables(
            step_s=step.astype(np.float64),
            up_s=net(_per_worker_row(self.up_s, num_workers, "up_s"),
                     (rounds, num_workers)).copy(),
            down_s=net(_per_worker_row(self.down_s, num_workers, "down_s"),
                       (rounds, num_workers)).copy(),
        )


@dataclasses.dataclass(frozen=True)
class TraceLatency(LatencyModel):
    """Trace-driven delays: replay measured per-round tables (e.g. profiled
    from a real fleet). Inputs are array-likes of shape ``(R0, M)`` (or
    ``(M,)``, or scalars); rounds beyond ``R0`` cycle through the trace.

    Examples
    --------
    A 2-round trace cycling over 3 simulated rounds:

    >>> lat = TraceLatency(step_s=[[1.0, 2.0], [3.0, 4.0]])
    >>> lat.tables(num_workers=2, rounds=3).step_s.tolist()
    [[1.0, 2.0], [3.0, 4.0], [1.0, 2.0]]
    """

    step_s: tuple
    up_s: tuple = (0.0,)
    down_s: tuple = (0.0,)

    def __init__(self, step_s, up_s=0.0, down_s=0.0):
        def freeze(v):
            arr = np.atleast_1d(np.asarray(v, dtype=np.float64))
            return tuple(map(tuple, np.atleast_2d(arr)))

        object.__setattr__(self, "step_s", freeze(step_s))
        object.__setattr__(self, "up_s", freeze(up_s))
        object.__setattr__(self, "down_s", freeze(down_s))

    def tables(self, num_workers: int, rounds: int) -> LatencyTables:
        def tile(rows, name):
            arr = np.asarray(rows, dtype=np.float64)
            if arr.shape[1] == 1:
                arr = np.broadcast_to(arr, (arr.shape[0], num_workers))
            if arr.shape[1] != num_workers:
                raise ValueError(
                    f"{name} trace has {arr.shape[1]} workers, fleet has "
                    f"{num_workers}"
                )
            reps = -(-rounds // arr.shape[0])            # ceil division
            return np.tile(arr, (reps, 1))[:rounds].copy()

        return LatencyTables(
            step_s=tile(self.step_s, "step_s"),
            up_s=tile(self.up_s, "up_s"),
            down_s=tile(self.down_s, "down_s"),
        )
