"""Sampled-client rounds: the server draws M of N fleet workers per round.

This is the partial-participation regime of the federated minimax
literature (Sharma et al. 2022; Deng & Mahdavi 2021): the fleet is large
(``PSConfig.num_workers`` = N, possibly 10k+), but each round only a
seed-deterministic subset of ``sample`` = M workers participates — runs
local steps, uplinks, and receives the broadcast. Everyone else keeps
their persistent per-worker state (η accumulators, error-feedback
residuals) frozen in the fleet store until their next draw.

Like every other policy in ``repro.ps`` (schedules, faults, latency), the
sampling tables are a pure function of the config seed, re-derived on
restore rather than checkpointed — a resumed run replays the exact same
participation scenario.

Design notes that the engines rely on:

* ``draws`` rows are **sorted ascending** and **without replacement** —
  the documented, seed-stable participation order within a round.
* ``sample == fleet`` with uniform weights degenerates to full
  participation (every row is ``arange(N)``), though the engines still
  run the gather/scatter path in that case; the bit-exact no-sampling
  guarantee is carried by ``sampler=None``.
"""
from __future__ import annotations

import dataclasses
import zlib

import numpy as np


@dataclasses.dataclass(frozen=True)
class ClientSampler:
    """Seed-deterministic per-round client sampling.

    ``sample`` workers are drawn per round from the fleet of
    ``config.num_workers``, uniformly or with per-worker ``weights``
    (inclusion probability proportional to weight, drawn without
    replacement).

    Examples
    --------
    >>> s = ClientSampler(sample=2, seed=0)
    >>> d = s.draws(num_workers=5, rounds=3)
    >>> d.shape, d.dtype
    ((3, 2), dtype('int32'))
    >>> bool((d[:, 0] < d[:, 1]).all())      # rows sorted ascending
    True
    >>> import numpy as np
    >>> np.array_equal(d, s.draws(5, 3))     # reproducible from seed
    True
    """

    sample: int
    seed: int = 0
    # Optional per-fleet-worker sampling weights, length num_workers.
    weights: tuple[float, ...] | None = None

    def __post_init__(self):
        if self.sample < 1:
            raise ValueError("sample must be >= 1")
        if self.weights is not None:
            w = np.asarray(self.weights, dtype=np.float64)
            if (w < 0).any() or w.sum() <= 0:
                raise ValueError("weights must be nonnegative with a "
                                 "positive sum")

    @property
    def name(self) -> str:
        kind = "uniform" if self.weights is None else "weighted"
        return f"sample{self.sample}-{kind}-seed{self.seed}"

    @property
    def fingerprint(self) -> int:
        """uint32 hash of the sampling law — checkpointed so a resumed run
        is refused if it would replay a *different* participation table."""
        desc = self.name
        if self.weights is not None:
            desc += ":" + ",".join(f"{w:.9g}" for w in self.weights)
        return zlib.crc32(desc.encode()) & 0xFFFFFFFF

    def _probs(self, num_workers: int) -> np.ndarray | None:
        if self.weights is None:
            return None
        w = np.asarray(self.weights, dtype=np.float64)
        if w.shape != (num_workers,):
            raise ValueError(
                f"weights has length {w.shape[0]}, fleet is {num_workers}"
            )
        return w / w.sum()

    def draws(self, num_workers: int, rounds: int) -> np.ndarray:
        """(rounds, sample) int32 table of participating fleet ids, each
        row sorted ascending, drawn without replacement."""
        if self.sample > num_workers:
            raise ValueError(
                f"sample={self.sample} exceeds fleet size {num_workers}"
            )
        p = self._probs(num_workers)
        rng = np.random.default_rng(self.seed)
        out = np.empty((rounds, self.sample), dtype=np.int32)
        for r in range(rounds):
            out[r] = np.sort(rng.choice(
                num_workers, size=self.sample, replace=False, p=p
            ))
        return out

    def participation(self, num_workers: int, rounds: int) -> np.ndarray:
        """(rounds, num_workers) bool mask: True where the worker is drawn
        for that round — the event-driven engine's skip table."""
        mask = np.zeros((rounds, num_workers), dtype=bool)
        draws = self.draws(num_workers, rounds)
        np.put_along_axis(mask, draws, True, axis=1)
        return mask
