"""Byzantine worker models: seed-deterministic adversarial uplinks.

``FaultPolicy`` (:mod:`repro.ps.faults`) models workers that *disappear*;
a ``ByzantinePolicy`` models workers that stay in the round and **lie** —
they run their local steps honestly but corrupt the z̃ uplink before it
leaves the worker. The engines apply the attack after local compute and
*before* compression, so it composes with quantize/top-k codecs and error
feedback exactly like an honest message would (the server cannot tell the
difference from the wire format — that is the point).

Like the schedule/fault/latency tables, membership is a pure function of
``(seed, num_workers, rounds)``: :meth:`ByzantinePolicy.attacked` returns a
``(rounds, num_workers)`` bool table that engines precompute once and
re-derive identically on checkpoint resume. The *values* an attacker sends
are seed-deterministic too: stochastic attacks draw from per-(round, worker)
keys folded off the same round rng chain as the codec keys, so sync, async,
and the τ=0 lockstep path corrupt identically.

Attack zoo (the standard Byzantine-robustness menagerie):

* :class:`SignFlipAttack`   — send ``−scale · z̃`` (scale > 1 also inflates).
* :class:`ScaledNoiseAttack`— send ``z̃ + scale · 𝒩(0, I)``.
* :class:`ZeroAttack`       — send exact zeros (a silent dropout that,
  unlike a crash, still counts toward the weighted mean).
* :class:`CollusionAttack`  — all attackers send the *same* vector,
  ``−eps ×`` the honest lanes' mean: the colluding inner-product attack
  that single-outlier defenses (Krum with small f) struggle with.
"""
from __future__ import annotations

import dataclasses
import zlib

import jax
import jax.numpy as jnp
import numpy as np


def _bcast(v, leaf):
    """(M,) per-worker scalar → broadcastable against a stacked leaf."""
    return v.reshape((-1,) + (1,) * (leaf.ndim - 1))


class ByzantinePolicy:
    """Protocol for Byzantine attack models (the adversarial sibling of
    ``FaultPolicy``).

    Subclasses are frozen dataclasses carrying ``fraction`` (of the fleet
    that is adversarial), ``seed`` (membership draw), and ``per_round``
    (False = a fixed adversarial subset for the whole run — the classic
    static adversary; True = re-drawn each round). They implement
    :meth:`apply`; membership tables come from :meth:`attacked` here.

    Examples
    --------
    Membership is a deterministic ``(rounds, workers)`` bool table:

    >>> import numpy as np
    >>> from repro.ps.robust import SignFlipAttack
    >>> pol = SignFlipAttack(fraction=0.4, seed=3)
    >>> t = pol.attacked(num_workers=5, rounds=3)
    >>> t.shape, t.dtype == np.bool_, int(t[0].sum())
    ((3, 5), True, 2)
    >>> bool(np.array_equal(t, pol.attacked(5, 3)))
    True
    """

    fraction: float = 0.0
    seed: int = 0
    per_round: bool = False

    def count(self, num_workers: int) -> int:
        """Adversarial lanes per round: ``round(fraction · M)``, capped."""
        return min(num_workers, int(round(float(self.fraction)
                                          * num_workers)))

    def attacked(self, num_workers: int, rounds: int) -> np.ndarray:
        """Deterministic ``(rounds, num_workers)`` bool membership table."""
        out = np.zeros((rounds, num_workers), dtype=bool)
        n = self.count(num_workers)
        if n == 0:
            return out
        rng = np.random.default_rng(self.seed)
        if self.per_round:
            for r in range(rounds):
                out[r, rng.choice(num_workers, size=n, replace=False)] = True
        else:
            out[:, rng.choice(num_workers, size=n, replace=False)] = True
        return out

    def apply(self, payload, mask, rngs):
        """Corrupt the stacked uplink: ``payload`` is a worker-stacked
        pytree (leading axis M), ``mask`` (M,) bool selects the attackers
        this round, ``rngs`` (M, 2) per-worker keys for stochastic
        attacks. Honest lanes pass through bit-unchanged."""
        raise NotImplementedError

    @property
    def name(self) -> str:
        raise NotImplementedError

    @property
    def fingerprint(self) -> int:
        """crc32 of the canonical description — checkpointed like the
        worker/sampler fingerprints so a resume cannot silently swap the
        threat model."""
        return zlib.crc32(self.name.encode()) & 0xFFFFFFFF


@dataclasses.dataclass(frozen=True)
class SignFlipAttack(ByzantinePolicy):
    """Attackers send ``−scale · z̃``. ``scale=1`` is the pure sign flip;
    ``scale>1`` additionally inflates the magnitude (the variant that makes
    an unprotected weighted mean diverge rather than merely stall).

    >>> import jax.numpy as jnp
    >>> from repro.ps.robust import SignFlipAttack
    >>> pol = SignFlipAttack(fraction=0.5, scale=2.0)
    >>> z = {"p": jnp.array([[1.0, -2.0], [3.0, 4.0]])}
    >>> mask = jnp.array([True, False])
    >>> out = pol.apply(z, mask, None)
    >>> out["p"].tolist()
    [[-2.0, 4.0], [3.0, 4.0]]
    """

    fraction: float
    scale: float = 1.0
    seed: int = 0
    per_round: bool = False

    @property
    def name(self) -> str:
        return (f"sign_flip(fraction={self.fraction},scale={self.scale},"
                f"seed={self.seed},per_round={self.per_round})")

    def apply(self, payload, mask, rngs):
        m = jnp.asarray(mask)
        return jax.tree.map(
            lambda z: jnp.where(_bcast(m, z), -jnp.float32(self.scale) * z,
                                z).astype(z.dtype),
            payload)


@dataclasses.dataclass(frozen=True)
class ScaledNoiseAttack(ByzantinePolicy):
    """Attackers send ``z̃ + scale · 𝒩(0, I)`` — large isotropic noise
    drawn from the per-(round, worker) keys, so reruns and resumes corrupt
    identically.

    >>> import jax, jax.numpy as jnp, numpy as np
    >>> from repro.ps.robust import ScaledNoiseAttack
    >>> pol = ScaledNoiseAttack(fraction=0.5, scale=10.0)
    >>> z = {"p": jnp.zeros((2, 3))}
    >>> rngs = jax.random.split(jax.random.PRNGKey(0), 2)
    >>> out = pol.apply(z, jnp.array([True, False]), rngs)
    >>> bool(np.all(out["p"][1] == 0)), bool(np.any(out["p"][0] != 0))
    (True, True)
    """

    fraction: float
    scale: float = 10.0
    seed: int = 0
    per_round: bool = False

    @property
    def name(self) -> str:
        return (f"scaled_noise(fraction={self.fraction},scale={self.scale},"
                f"seed={self.seed},per_round={self.per_round})")

    def apply(self, payload, mask, rngs):
        leaves, treedef = jax.tree.flatten(payload)
        keys = jax.vmap(lambda k: jax.random.split(k, len(leaves)))(
            jnp.asarray(rngs))                            # (M, L, 2)
        mv = jnp.asarray(mask)
        outs = []
        for li, z in enumerate(leaves):
            noise = jax.vmap(
                lambda k, zz: jax.random.normal(k, zz.shape, jnp.float32)
            )(keys[:, li], z)
            bad = z + jnp.float32(self.scale) * noise.astype(z.dtype)
            outs.append(jnp.where(_bcast(mv, z), bad, z).astype(z.dtype))
        return treedef.unflatten(outs)


@dataclasses.dataclass(frozen=True)
class ZeroAttack(ByzantinePolicy):
    """Attackers send exact zeros — unlike a crash fault their weight stays
    in the merge, silently dragging the weighted mean toward the origin.

    >>> import jax.numpy as jnp
    >>> from repro.ps.robust import ZeroAttack
    >>> out = ZeroAttack(fraction=0.5).apply(
    ...     {"p": jnp.ones((2, 2))}, jnp.array([False, True]), None)
    >>> out["p"].tolist()
    [[1.0, 1.0], [0.0, 0.0]]
    """

    fraction: float
    seed: int = 0
    per_round: bool = False

    @property
    def name(self) -> str:
        return (f"zero(fraction={self.fraction},seed={self.seed},"
                f"per_round={self.per_round})")

    def apply(self, payload, mask, rngs):
        m = jnp.asarray(mask)
        return jax.tree.map(
            lambda z: jnp.where(_bcast(m, z), jnp.zeros_like(z), z),
            payload)


@dataclasses.dataclass(frozen=True)
class CollusionAttack(ByzantinePolicy):
    """Colluding inner-product attack: every attacker sends the *same*
    vector, ``−eps ×`` the mean of the honest lanes' messages. The
    attackers sit in a tight cluster (mutually distance 0), the shape that
    defeats per-lane outlier tests and stresses Krum's neighbor count.

    >>> import jax.numpy as jnp
    >>> from repro.ps.robust import CollusionAttack
    >>> z = {"p": jnp.array([[2.0, 0.0], [0.0, 2.0], [9.0, 9.0]])}
    >>> out = CollusionAttack(fraction=1 / 3, eps=1.0).apply(
    ...     z, jnp.array([False, False, True]), None)
    >>> out["p"].tolist()   # attacker sends −mean of the two honest rows
    [[2.0, 0.0], [0.0, 2.0], [-1.0, -1.0]]
    """

    fraction: float
    eps: float = 1.0
    seed: int = 0
    per_round: bool = False

    @property
    def name(self) -> str:
        return (f"collusion(fraction={self.fraction},eps={self.eps},"
                f"seed={self.seed},per_round={self.per_round})")

    def apply(self, payload, mask, rngs):
        mv = jnp.asarray(mask)
        honest = (~mv).astype(jnp.float32)
        denom = jnp.maximum(jnp.sum(honest), 1.0)

        def one(z):
            hm = jnp.sum(_bcast(honest, z).astype(z.dtype) * z, axis=0,
                         keepdims=True) / denom.astype(z.dtype)
            bad = jnp.broadcast_to(-jnp.float32(self.eps).astype(z.dtype)
                                   * hm, z.shape)
            return jnp.where(_bcast(mv, z), bad, z).astype(z.dtype)

        return jax.tree.map(one, payload)
