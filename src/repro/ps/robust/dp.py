"""Private uplinks: per-worker l2 clipping + Gaussian DP noise.

The third hostile-fleet layer treats the *server* as the adversary: each
worker clips its whole uplink pytree to an l2 ball of radius ``clip`` and
adds isotropic Gaussian noise with stddev ``sigma · clip`` — the Gaussian
mechanism, whose (ε, δ) budget per round follows from ``sigma`` by the
standard accountant (out of scope here; this module is the mechanism, not
the accountant).

Placement and determinism mirror the Byzantine layer: the transform runs
after local compute (and after any attack — an adversary is not bound by
the privacy protocol's clipping) but *before* compression, so DP composes
with quantize/top-k codecs and error feedback. Noise keys are folded off
the per-(round, worker) codec key chain (the threefry machinery everything
else shares), so sync, async, and the τ=0 lockstep path add bit-identical
noise and checkpoint resume replays it exactly.
"""
from __future__ import annotations

import dataclasses
import zlib

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class DPUplink:
    """l2-clip + Gaussian-noise transform for worker uplinks.

    ``clip`` is the l2 radius across the worker's whole payload pytree
    (leaves are jointly scaled by ``min(1, clip/‖z̃‖₂)``); ``sigma`` the
    noise multiplier (stddev ``sigma · clip`` per coordinate; 0 = clip
    only). ``apply`` takes the worker-stacked payload and (M, 2) per-worker
    keys and returns the privatized stack.

    Examples
    --------
    Clipping bounds every worker's l2 norm; sigma=0 adds no noise:

    >>> import jax, jax.numpy as jnp, numpy as np
    >>> from repro.ps.robust import DPUplink
    >>> dp = DPUplink(clip=1.0, sigma=0.0)
    >>> z = {"p": jnp.array([[3.0, 4.0], [0.3, 0.4]])}
    >>> rngs = jax.random.split(jax.random.PRNGKey(0), 2)
    >>> out = dp.apply(z, rngs)
    >>> [round(float(jnp.linalg.norm(r)), 6) for r in out["p"]]
    [1.0, 0.5]
    """

    clip: float
    sigma: float = 0.0

    def __post_init__(self):
        if self.clip <= 0:
            raise ValueError(f"clip must be > 0, got {self.clip}")
        if self.sigma < 0:
            raise ValueError(f"sigma must be >= 0, got {self.sigma}")

    @property
    def name(self) -> str:
        return f"dp(clip={self.clip},sigma={self.sigma})"

    @property
    def fingerprint(self) -> int:
        return zlib.crc32(self.name.encode()) & 0xFFFFFFFF

    def apply(self, payload, rngs):
        """Privatize a worker-stacked pytree: joint l2 clip per worker
        across all leaves, then (for ``sigma > 0``) per-coordinate Gaussian
        noise from the per-worker keys."""
        leaves, treedef = jax.tree.flatten(payload)
        sq = sum(jnp.sum(jnp.square(z.astype(jnp.float32)
                                    ).reshape(z.shape[0], -1), axis=1)
                 for z in leaves)                           # (M,)
        norm = jnp.sqrt(sq)
        factor = jnp.minimum(1.0, jnp.float32(self.clip)
                             / jnp.maximum(norm, 1e-30))    # (M,)
        if self.sigma:
            keys = jax.vmap(lambda k: jax.random.split(k, len(leaves)))(
                jnp.asarray(rngs))                          # (M, L, 2)
        outs = []
        for li, z in enumerate(leaves):
            fb = factor.reshape((-1,) + (1,) * (z.ndim - 1)).astype(z.dtype)
            out = fb * z
            if self.sigma:
                noise = jax.vmap(
                    lambda k, zz: jax.random.normal(k, zz.shape,
                                                    jnp.float32)
                )(keys[:, li], z)
                out = out + jnp.float32(self.sigma * self.clip) \
                    * noise.astype(z.dtype)
            outs.append(out.astype(z.dtype))
        return treedef.unflatten(outs)
