"""Robust server aggregators: alternative Line-7 merge modes.

A :class:`RobustAggregator` names *how the server combines* the fleet's
uplinks. It is a thin, hashable policy object: the actual math lives in the
``kernels.sync_compress`` merge (fused Pallas + reference twin) — an
aggregator just resolves, for a static fleet width ``m``, to the static
merge spec ``sync_merge_stacked(agg=...)`` understands:

* ``None``               — the exact historical weighted mean. Every
  aggregator returns this at zero robustness budget (β=0 trimming, f=0
  Krum selecting everyone, median of ≤2 lanes), which is what makes the
  clean-fleet degradation guarantee *bit-exact*: the engine compiles the
  very same merge it always did.
* ``("trimmed", b)``     — b-per-side per-coordinate trimmed weighted
  mean (:class:`TrimmedMean`; :class:`CoordinateMedian` is the maximal
  trim ``b = ⌊(m−1)/2⌋``).
* ``("krum", f, m_sel)`` — multi-Krum selection then survivor mean
  (:class:`MultiKrum`).

``reject_frac(m)`` reports the fraction of lanes the aggregator discards
per round (per coordinate for trims, per lane for Krum) — surfaced as the
``agg_reject_frac`` gauge in ``repro.obs`` metrics. ``fingerprint`` is
checkpointed like the optimizer/sampler fingerprints, so a resume cannot
silently change the merge semantics mid-run.
"""
from __future__ import annotations

import dataclasses
import math
import zlib


class RobustAggregator:
    """Protocol for server-side robust merge policies.

    Subclasses implement :meth:`spec` (the static merge spec at fleet width
    ``m`` — ``None`` means "exactly the weighted mean") and ``name``;
    :meth:`reject_frac` and ``fingerprint`` derive from those.

    Examples
    --------
    >>> from repro.ps.robust import TrimmedMean, WeightedMean
    >>> TrimmedMean(beta=0.25).spec(8)
    ('trimmed', 2)
    >>> TrimmedMean(beta=0.0).spec(8) is None   # zero budget ⇒ exact mean
    True
    >>> WeightedMean().fingerprint == WeightedMean().fingerprint
    True
    """

    def spec(self, num_workers: int):
        """Static merge spec at fleet width ``num_workers`` — ``None`` for
        the exact historical weighted mean."""
        raise NotImplementedError

    def reject_frac(self, num_workers: int) -> float:
        """Fraction of lanes discarded per merge (0.0 = none)."""
        s = self.spec(num_workers)
        if s is None:
            return 0.0
        if s[0] == "trimmed":
            return min(1.0, 2 * s[1] / num_workers)
        return (num_workers - s[2]) / num_workers

    @property
    def name(self) -> str:
        raise NotImplementedError

    @property
    def fingerprint(self) -> int:
        """crc32 of the canonical description (checkpoint compatibility
        check, like the worker/sampler fingerprints)."""
        return zlib.crc32(self.name.encode()) & 0xFFFFFFFF


@dataclasses.dataclass(frozen=True)
class WeightedMean(RobustAggregator):
    """The paper's Line-7 merge itself: 1/η-weighted mean, no rejection.
    The do-nothing aggregator (``spec`` is always ``None``), so configs can
    name the default explicitly.

    >>> from repro.ps.robust import WeightedMean
    >>> WeightedMean().spec(16) is None, WeightedMean().reject_frac(16)
    (True, 0.0)
    """

    @property
    def name(self) -> str:
        return "weighted_mean"

    def spec(self, num_workers: int):
        return None


@dataclasses.dataclass(frozen=True)
class TrimmedMean(RobustAggregator):
    """β-trimmed per-coordinate weighted mean: drop the ``b = ⌊β·m⌋``
    smallest and largest values of every coordinate, renormalize the
    surviving weight mass. β=0 degrades bit-exactly to the weighted mean;
    β must stay < 0.5 (you cannot trim more than everything).

    >>> from repro.ps.robust import TrimmedMean
    >>> agg = TrimmedMean(beta=0.2)
    >>> agg.spec(10), agg.reject_frac(10)
    (('trimmed', 2), 0.4)
    >>> agg.spec(4)        # ⌊0.2·4⌋ = 0 ⇒ exact mean at this width
    """

    beta: float

    def __post_init__(self):
        if not 0.0 <= self.beta < 0.5:
            raise ValueError(f"beta must be in [0, 0.5), got {self.beta}")

    @property
    def name(self) -> str:
        return f"trimmed_mean(beta={self.beta})"

    def trim_count(self, num_workers: int) -> int:
        return int(math.floor(self.beta * num_workers))

    def spec(self, num_workers: int):
        b = self.trim_count(num_workers)
        return None if b == 0 else ("trimmed", b)


@dataclasses.dataclass(frozen=True)
class CoordinateMedian(RobustAggregator):
    """Per-coordinate weighted median — the maximal trimmed mean,
    ``b = ⌊(m−1)/2⌋``: only the middle one (odd fleets) or two (even
    fleets) order statistics survive. At m ≤ 2 the median of the fleet *is*
    the mean, so ``spec`` degrades to ``None`` there.

    >>> from repro.ps.robust import CoordinateMedian
    >>> CoordinateMedian().spec(5)
    ('trimmed', 2)
    >>> CoordinateMedian().spec(2) is None
    True
    """

    @property
    def name(self) -> str:
        return "coordinate_median"

    def trim_count(self, num_workers: int) -> int:
        return (num_workers - 1) // 2

    def spec(self, num_workers: int):
        b = self.trim_count(num_workers)
        return None if b == 0 else ("trimmed", b)


@dataclasses.dataclass(frozen=True)
class MultiKrum(RobustAggregator):
    """(Multi-)Krum: score each worker by the sum of its ``max(1, m−f−2)``
    smallest squared distances to other workers, keep the ``m_select``
    (default ``m − f``) best-scoring, then take their renormalized weighted
    mean. ``f`` is the number of adversaries defended against; ``f=0``
    selecting the whole fleet degrades bit-exactly to the weighted mean.

    >>> from repro.ps.robust import MultiKrum
    >>> MultiKrum(f=2).spec(10)
    ('krum', 2, 8)
    >>> MultiKrum(f=0).spec(10) is None
    True
    >>> MultiKrum(f=1, m_select=1).spec(4)   # classic single-Krum
    ('krum', 1, 1)
    """

    f: int
    m_select: int | None = None

    def __post_init__(self):
        if self.f < 0:
            raise ValueError(f"f must be >= 0, got {self.f}")
        if self.m_select is not None and self.m_select < 1:
            raise ValueError(
                f"m_select must be >= 1, got {self.m_select}")

    @property
    def name(self) -> str:
        return f"multi_krum(f={self.f},m_select={self.m_select})"

    def selected(self, num_workers: int) -> int:
        if self.m_select is not None:
            return min(self.m_select, num_workers)
        return max(1, num_workers - self.f)

    def spec(self, num_workers: int):
        m_sel = self.selected(num_workers)
        if self.f == 0 and m_sel >= num_workers:
            return None
        return ("krum", self.f, m_sel)
