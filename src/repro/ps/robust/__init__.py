"""Hostile-fleet subsystem: Byzantine attacks, robust aggregation, DP.

Three composable layers around the honest Parameter-Server round, in wire
order::

    local steps → [attack] → [DP clip+noise] → codec/EF → robust merge

* :mod:`.byzantine` — ``ByzantinePolicy`` attack models (sign-flip,
  scaled-noise, zero, collusion), seed-deterministic per-(round, worker)
  membership tables like the fault/schedule layers.
* :mod:`.aggregators` — ``RobustAggregator`` server merges (trimmed-mean,
  coordinate-median, multi-Krum, and the explicit ``WeightedMean``
  default), resolving to static specs for the fused/reference merge
  kernels, with checkpointed fingerprints.
* :mod:`.dp` — ``DPUplink`` per-worker l2 clipping + Gaussian noise,
  sharing the threefry key machinery with the quantizer.

Selected via ``PSConfig(byzantine=…, aggregator=…, dp=…)`` on both engines
(the async event machine applies attacks at store time and robust merges at
admission). One structural consequence is worth knowing: with any robust
layer active the engines switch the uplink to the *unweighted* wire format
(the async engine's native one) and apply Line-7 weights server-side, so
order statistics rank workers' iterates rather than their weighted
messages; at zero robustness budget (no attack, ``spec(m) is None``, no
DP) configs compile the identical historical path, bit-exactly.
"""
from .aggregators import (
    CoordinateMedian,
    MultiKrum,
    RobustAggregator,
    TrimmedMean,
    WeightedMean,
)
from .byzantine import (
    ByzantinePolicy,
    CollusionAttack,
    ScaledNoiseAttack,
    SignFlipAttack,
    ZeroAttack,
)
from .dp import DPUplink

__all__ = [
    "ByzantinePolicy",
    "SignFlipAttack",
    "ScaledNoiseAttack",
    "ZeroAttack",
    "CollusionAttack",
    "RobustAggregator",
    "WeightedMean",
    "TrimmedMean",
    "CoordinateMedian",
    "MultiKrum",
    "DPUplink",
]
