"""Sync compressors: lossy codecs for the uphill w·z̃ messages (Line 5/7).

Each worker sends its weighted anchor ``w_m · z̃_m`` to the server; the
server sums the (decompressed) messages — so compressing the *messages*
preserves the Line-7 semantics exactly in the identity case and degrades it
gracefully otherwise. We simulate the codec: :meth:`SyncCompressor.compress`
returns the decompressed (lossy) message, and :meth:`message_bytes` gives
the static wire size the real codec would ship, which the trace recorder
turns into per-round bytes-up/bytes-down telemetry.

Compressors with ``error_feedback=True`` get the classic EF treatment from
the engine (Seide et al. '14 / Karimireddy et al. '19): the quantization
residual of round ``r`` is added to the message of round ``r+1``, so the
compression error telescopes instead of accumulating.

``compress`` sees ONE worker's message pytree (no leading worker axis); the
serial engine vmaps it over the stacked worker axis, and the sharded engine
calls it per shard before the psum — same code, both execution paths.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any

import jax
import jax.numpy as jnp
from jax import lax

PyTree = Any


def dense_bytes(tree: PyTree) -> float:
    """Wire size of an uncompressed float32 message."""
    return float(sum(4 * v.size for v in jax.tree.leaves(tree)))


class SyncCompressor:
    name: str = "compressor"
    error_feedback: bool = False
    is_identity: bool = False

    def compress(self, msg: PyTree, rng) -> PyTree:
        """Lossy round-trip (compress + decompress) of one worker's message."""
        raise NotImplementedError

    def message_bytes(self, like: PyTree) -> float:
        """Static wire size of one compressed message."""
        raise NotImplementedError


@dataclasses.dataclass(frozen=True)
class IdentityCompressor(SyncCompressor):
    """No-op codec — the engine short-circuits it so the identity path stays
    bit-exact with ``core.adaseg.sync_weighted_stacked``."""

    name: str = "identity"
    is_identity: bool = True

    def compress(self, msg: PyTree, rng) -> PyTree:
        return msg

    def message_bytes(self, like: PyTree) -> float:
        return dense_bytes(like)


@dataclasses.dataclass(frozen=True)
class StochasticQuantizeCompressor(SyncCompressor):
    """Per-leaf stochastic uniform quantization to ``bits`` bits (QSGD-style):
    values are scaled by the leaf's max-abs, rounded stochastically to one of
    2^bits − 1 levels (unbiased given the scale), and shipped with one f32
    scale per leaf."""

    bits: int = 8
    name: str = "quantize"
    error_feedback: bool = True

    def __post_init__(self):
        if not 1 <= self.bits <= 16:
            raise ValueError(f"bits must be in [1, 16], got {self.bits}")
        object.__setattr__(self, "name", f"q{self.bits}")

    def compress(self, msg: PyTree, rng) -> PyTree:
        levels = float(2 ** self.bits - 1)
        leaves, treedef = jax.tree.flatten(msg)
        rngs = jax.random.split(rng, len(leaves))

        def q(leaf, r):
            scale = jnp.maximum(jnp.max(jnp.abs(leaf)), 1e-30)
            y = jnp.abs(leaf) / scale * levels
            lo = jnp.floor(y)
            up = jax.random.uniform(r, leaf.shape) < (y - lo)
            mag = (lo + up.astype(leaf.dtype)) * (scale / levels)
            return jnp.sign(leaf) * mag

        return treedef.unflatten([q(l, r) for l, r in zip(leaves, rngs)])

    def message_bytes(self, like: PyTree) -> float:
        # bits magnitude levels + 1 sign bit per entry, one f32 scale per leaf
        return float(sum(
            math.ceil(v.size * (self.bits + 1) / 8) + 4
            for v in jax.tree.leaves(like)
        ))


@dataclasses.dataclass(frozen=True)
class TopKCompressor(SyncCompressor):
    """Keep the top ``fraction`` of entries of each leaf by magnitude, zero
    the rest; wire format is (index, value) pairs. Biased — which is exactly
    why it is run under error feedback."""

    fraction: float = 0.1
    name: str = "topk"
    error_feedback: bool = True

    def __post_init__(self):
        if not 0.0 < self.fraction <= 1.0:
            raise ValueError(f"fraction must be in (0, 1], got {self.fraction}")
        object.__setattr__(self, "name", f"top{self.fraction:g}")

    def _k(self, size: int) -> int:
        return max(1, int(math.ceil(self.fraction * size)))

    def compress(self, msg: PyTree, rng) -> PyTree:
        def keep(leaf):
            flat = leaf.reshape(-1)
            k = self._k(flat.size)
            # scatter through the top-k indices so exactly k entries survive
            # (a magnitude-threshold mask would keep every tied entry and
            # undercut the sparsity that message_bytes bills for)
            _, idx = jax.lax.top_k(jnp.abs(flat), k)
            out = jnp.zeros_like(flat).at[idx].set(flat[idx])
            return out.reshape(leaf.shape)

        return jax.tree.map(keep, msg)

    def message_bytes(self, like: PyTree) -> float:
        return float(sum(
            8 * self._k(v.size) for v in jax.tree.leaves(like)  # idx + value
        ))


def make_compressed_psum_sync(axis_names: tuple[str, ...],
                              compressor: SyncCompressor):
    """Compressed-psum hook for ``launch.sharded.run_local_adaseg_sharded``:
    the Line-7 all-reduce with each worker's uphill w·z̃ message run through
    ``compressor`` first (3-argument ``sync_fn`` form — the driver supplies
    a per-worker, per-round rng). Stateless: error feedback needs memory
    across rounds, which is the PS engine's job (``repro.ps.engine``)."""

    def sync(z_tilde: PyTree, inv_eta, rng) -> PyTree:
        denom = lax.psum(inv_eta, axis_names)
        w = inv_eta / denom
        msg = jax.tree.map(lambda v: w.astype(v.dtype) * v, z_tilde)
        sent = compressor.compress(msg, rng)
        return jax.tree.map(lambda v: lax.psum(v, axis_names), sent)

    return sync
