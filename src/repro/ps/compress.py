"""Sync compressors: lossy codecs for the uphill w·z̃ messages (Line 5/7).

Each worker sends its weighted anchor ``w_m · z̃_m`` to the server; the
server sums the (decompressed) messages — so compressing the *messages*
preserves the Line-7 semantics exactly in the identity case and degrades it
gracefully otherwise. We simulate the codec: :meth:`SyncCompressor.compress`
returns the decompressed (lossy) message, and :meth:`message_bytes` gives
the static wire size the real codec would ship, which the trace recorder
turns into per-round bytes-up/bytes-down telemetry.

Compressors with ``error_feedback=True`` get the classic EF treatment from
the engine (Seide et al. '14 / Karimireddy et al. '19): the quantization
residual of round ``r`` is added to the message of round ``r+1``, so the
compression error telescopes instead of accumulating.

``compress`` sees ONE worker's message pytree (no leading worker axis); the
serial engine vmaps it over the stacked worker axis, and the sharded engine
calls it per shard before the psum — same code, both execution paths.

Codec backends
--------------
``compress`` is the *reference* implementation. Each built-in compressor
also exports a static :attr:`SyncCompressor.codec_spec` that the fused
Pallas codec path (``kernels.sync_compress``) consumes when an engine is
configured with ``codec_backend="fused"`` — the whole uplink (error-feedback
add, w-scaling, quantize/top-k, residual write-back) then runs as fused
kernel sweeps instead of separate tree passes. Stochastic quantization draws
its rounding bits from the shared threefry derivation
(:func:`repro.kernels.sync_compress.ref.threefry_uniform`) in BOTH backends,
so fused ≡ reference holds to float tolerance (and bit-exactly for the
deterministic codecs).
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any

import jax
import jax.numpy as jnp
from jax import lax

from ..kernels.sync_compress.ref import threefry_uniform

PyTree = Any


def dense_bytes(tree: PyTree) -> float:
    """Wire size of an uncompressed float32 message.

    Examples
    --------
    >>> import jax.numpy as jnp
    >>> dense_bytes({"a": jnp.ones((4,)), "b": jnp.ones((2, 3))})
    40.0
    """
    return float(sum(4 * v.size for v in jax.tree.leaves(tree)))


class SyncCompressor:
    """Lossy codec contract for the uphill sync messages.

    Subclasses implement :meth:`compress` (the reference round-trip) and
    :meth:`message_bytes` (wire size for telemetry); built-ins additionally
    expose :attr:`codec_spec` so the fused kernel backend can run the same
    codec in-register.

    Examples
    --------
    >>> import jax, jax.numpy as jnp
    >>> comp = TopKCompressor(fraction=0.5)
    >>> msg = {"g": jnp.array([3.0, -0.1, -2.0, 0.2])}
    >>> out = comp.compress(msg, jax.random.PRNGKey(0))
    >>> [float(v) for v in out["g"]]
    [3.0, 0.0, -2.0, 0.0]
    """

    name: str = "compressor"
    error_feedback: bool = False
    is_identity: bool = False
    #: static spec for kernels.sync_compress (None = no fused path)
    codec_spec: tuple | None = None

    def compress(self, msg: PyTree, rng) -> PyTree:
        """Lossy round-trip (compress + decompress) of one worker's message."""
        raise NotImplementedError

    def message_bytes(self, like: PyTree) -> float:
        """Static wire size of one compressed message."""
        raise NotImplementedError


@dataclasses.dataclass(frozen=True)
class IdentityCompressor(SyncCompressor):
    """No-op codec — the engine short-circuits it so the identity path stays
    bit-exact with ``core.adaseg.sync_weighted_stacked``.

    Examples
    --------
    >>> import jax.numpy as jnp
    >>> comp = IdentityCompressor()
    >>> msg = {"g": jnp.ones((3,))}
    >>> comp.compress(msg, None) is msg
    True
    >>> comp.message_bytes(msg)           # 3 × f32
    12.0
    """

    name: str = "identity"
    is_identity: bool = True

    @property
    def codec_spec(self) -> tuple:
        return ("identity",)

    def compress(self, msg: PyTree, rng) -> PyTree:
        return msg

    def message_bytes(self, like: PyTree) -> float:
        return dense_bytes(like)


@dataclasses.dataclass(frozen=True)
class StochasticQuantizeCompressor(SyncCompressor):
    """Per-leaf stochastic uniform quantization to ``bits`` bits (QSGD-style):
    values are scaled by the leaf's max-abs, rounded stochastically to one of
    2^bits − 1 levels (unbiased given the scale), and shipped with one f32
    scale per leaf.

    The rounding decision per element uses the shared threefry uniform
    stream (``kernels.sync_compress.ref.threefry_uniform``) — the same
    derivation the fused kernel generates in-register — so both codec
    backends make identical up/down choices for identical inputs.

    Examples
    --------
    Quantization is a contraction onto the level grid — values stay within
    one level of the input and the max-abs entry is exactly preserved:

    >>> import jax, jax.numpy as jnp
    >>> comp = StochasticQuantizeCompressor(bits=8)
    >>> comp.name
    'q8'
    >>> msg = {"g": jnp.array([1.0, -0.3, 0.004])}
    >>> out = comp.compress(msg, jax.random.PRNGKey(0))
    >>> bool(jnp.max(jnp.abs(out["g"] - msg["g"])) <= 1.0 / 255)
    True
    """

    bits: int = 8
    name: str = "quantize"
    error_feedback: bool = True

    def __post_init__(self):
        if not 1 <= self.bits <= 16:
            raise ValueError(f"bits must be in [1, 16], got {self.bits}")
        object.__setattr__(self, "name", f"q{self.bits}")

    @property
    def codec_spec(self) -> tuple:
        return ("quantize", self.bits)

    def compress(self, msg: PyTree, rng) -> PyTree:
        levels = float(2 ** self.bits - 1)
        leaves, treedef = jax.tree.flatten(msg)
        rngs = jax.random.split(rng, len(leaves))

        def q(leaf, r):
            scale = jnp.maximum(jnp.max(jnp.abs(leaf)), 1e-30)
            y = jnp.abs(leaf) / scale * levels
            lo = jnp.floor(y)
            up = threefry_uniform(r, leaf.size).reshape(leaf.shape) < (y - lo)
            mag = (lo + up.astype(leaf.dtype)) * (scale / levels)
            return jnp.sign(leaf) * mag

        return treedef.unflatten([q(l, r) for l, r in zip(leaves, rngs)])

    def message_bytes(self, like: PyTree) -> float:
        # bits magnitude levels + 1 sign bit per entry, one f32 scale per leaf
        return float(sum(
            math.ceil(v.size * (self.bits + 1) / 8) + 4
            for v in jax.tree.leaves(like)
        ))


@dataclasses.dataclass(frozen=True)
class TopKCompressor(SyncCompressor):
    """Keep the top ``fraction`` of entries of each leaf by magnitude, zero
    the rest; wire format is (index, value) pairs. Biased — which is exactly
    why it is run under error feedback.

    Examples
    --------
    Exactly ``ceil(fraction · size)`` entries survive per leaf:

    >>> import jax, jax.numpy as jnp
    >>> comp = TopKCompressor(fraction=0.5)
    >>> out = comp.compress({"g": jnp.array([5.0, 1.0, -3.0, 0.5])},
    ...                     jax.random.PRNGKey(0))
    >>> [float(v) for v in out["g"]]
    [5.0, 0.0, -3.0, 0.0]
    >>> comp.message_bytes({"g": jnp.zeros((100,))})  # (idx, value) pairs
    400.0
    """

    fraction: float = 0.1
    name: str = "topk"
    error_feedback: bool = True

    def __post_init__(self):
        if not 0.0 < self.fraction <= 1.0:
            raise ValueError(f"fraction must be in (0, 1], got {self.fraction}")
        object.__setattr__(self, "name", f"top{self.fraction:g}")

    @property
    def codec_spec(self) -> tuple:
        return ("topk", self.fraction)

    def _k(self, size: int) -> int:
        return max(1, int(math.ceil(self.fraction * size)))

    def compress(self, msg: PyTree, rng) -> PyTree:
        def keep(leaf):
            flat = leaf.reshape(-1)
            k = self._k(flat.size)
            # scatter through the top-k indices so exactly k entries survive
            # (a magnitude-threshold mask would keep every tied entry and
            # undercut the sparsity that message_bytes bills for)
            _, idx = jax.lax.top_k(jnp.abs(flat), k)
            out = jnp.zeros_like(flat).at[idx].set(flat[idx])
            return out.reshape(leaf.shape)

        return jax.tree.map(keep, msg)

    def message_bytes(self, like: PyTree) -> float:
        return float(sum(
            8 * self._k(v.size) for v in jax.tree.leaves(like)  # idx + value
        ))


def make_compressed_psum_sync(axis_names: tuple[str, ...],
                              compressor: SyncCompressor,
                              codec_backend: str = "reference"):
    """Compressed-psum hook for ``launch.sharded.run_local_adaseg_sharded``:
    the Line-7 all-reduce with each worker's uphill w·z̃ message run through
    ``compressor`` first (3-argument ``sync_fn`` form — the driver supplies
    a per-worker, per-round rng). Stateless: error feedback needs memory
    across rounds, which is the PS engine's job (``repro.ps.engine``).

    ``codec_backend="fused"`` replaces the per-shard message-scale +
    compress tree passes with the fused uplink kernel
    (``kernels.sync_compress.ops.codec_uplink``); the codec must export a
    :attr:`SyncCompressor.codec_spec`.

    Examples
    --------
    The hook is a 3-argument ``sync_fn`` for the sharded driver (it runs
    inside ``shard_map``, so here we only build it):

    >>> sync = make_compressed_psum_sync(("data",),
    ...                                  StochasticQuantizeCompressor(8),
    ...                                  codec_backend="fused")
    >>> callable(sync)
    True
    """
    check_codec_backend(codec_backend, compressor)

    def sync(z_tilde: PyTree, inv_eta, rng) -> PyTree:
        denom = lax.psum(inv_eta, axis_names)
        w = inv_eta / denom
        if codec_backend == "fused":
            from ..kernels.sync_compress.ops import codec_uplink

            sent, _ = codec_uplink(z_tilde, rng, w=w,
                                   codec=compressor.codec_spec)
        else:
            msg = jax.tree.map(lambda v: w.astype(v.dtype) * v, z_tilde)
            sent = compressor.compress(msg, rng)
        return jax.tree.map(lambda v: lax.psum(v, axis_names), sent)

    return sync


def check_codec_backend(codec_backend: str,
                        compressor: SyncCompressor | None) -> None:
    """Validate a ``codec_backend`` setting against a compressor: the fused
    Pallas path needs a static :attr:`SyncCompressor.codec_spec` (all
    built-ins have one; custom codecs fall back to ``"reference"``).

    Examples
    --------
    >>> check_codec_backend("fused", TopKCompressor(0.1))   # fine
    >>> check_codec_backend("turbo", None)
    Traceback (most recent call last):
        ...
    ValueError: unknown codec backend 'turbo'
    """
    if codec_backend not in ("reference", "fused"):
        raise ValueError(f"unknown codec backend {codec_backend!r}")
    if (codec_backend == "fused" and compressor is not None
            and compressor.codec_spec is None):
        raise ValueError(
            f"compressor {compressor.name!r} exports no codec_spec — the "
            "fused codec backend only covers the built-in codecs "
            "(identity / stochastic quantize / top-k)"
        )
