"""Version-compat shims for the Pallas TPU API surface.

JAX renamed ``pltpu.TPUCompilerParams`` (≤ 0.4.x) to ``pltpu.CompilerParams``
(0.5+); the seed code was written against the new name and broke on the
pinned 0.4.37 toolchain. Route every kernel through this helper so the repo
runs on both sides of the rename.
"""
from __future__ import annotations

from jax.experimental.pallas import tpu as pltpu


def tpu_compiler_params(*, dimension_semantics=None, **kwargs):
    """Build TPU compiler params under whichever name this JAX exposes."""
    cls = getattr(pltpu, "CompilerParams", None)
    if cls is None:
        cls = pltpu.TPUCompilerParams
    if dimension_semantics is not None:
        kwargs["dimension_semantics"] = dimension_semantics
    return cls(**kwargs)
