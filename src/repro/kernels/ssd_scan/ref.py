"""Pure-jnp oracle for the SSD scan kernel: the sequential (recurrent)
evaluation of the SSM — numerically the ground truth the chunked kernel and
the jnp chunked implementation must both match.

    h_t = exp(dt_t·a) ⊙ h_{t−1} + dt_t · x_t ⊗ B_t
    y_t = C_t · h_t
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def ssd_ref(x, dt, a, b, c):
    """x: (B, L, H, P); dt: (B, L, H); a: (H,); b, c: (B, L, N)."""
    bsz, l, h, p = x.shape
    n = b.shape[-1]

    def scan_fn(h_prev, inp):
        x_t, dt_t, b_t, c_t = inp  # (B,H,P), (B,H), (B,N), (B,N)
        decay = jnp.exp(dt_t * a)                                  # (B,H)
        h_new = h_prev * decay[..., None, None] + jnp.einsum(
            "bhp,bn,bh->bhpn", x_t, b_t, dt_t
        )
        y_t = jnp.einsum("bhpn,bn->bhp", h_new, c_t)
        return h_new, y_t

    init = jnp.zeros((bsz, h, p, n), jnp.float32)
    xs = (
        x.transpose(1, 0, 2, 3).astype(jnp.float32),
        dt.transpose(1, 0, 2).astype(jnp.float32),
        b.transpose(1, 0, 2).astype(jnp.float32),
        c.transpose(1, 0, 2).astype(jnp.float32),
    )
    _, ys = jax.lax.scan(scan_fn, init, xs)
    return ys.transpose(1, 0, 2, 3).astype(x.dtype)  # (B, L, H, P)
