"""Jit'd wrapper for the SSD scan kernel with CPU interpret fallback.
Examples
--------
The chunked SSD form agrees with the sequential scan reference:

>>> import jax, jax.numpy as jnp, numpy as np
>>> from repro.kernels.ssd_scan.ops import ssd
>>> from repro.kernels.ssd_scan.ref import ssd_ref
>>> ks = jax.random.split(jax.random.PRNGKey(0), 5)
>>> x = jax.random.normal(ks[0], (1, 32, 2, 4))
>>> dt = jax.nn.softplus(jax.random.normal(ks[1], (1, 32, 2)))
>>> a = -jnp.exp(jax.random.normal(ks[2], (2,)))
>>> b = jax.random.normal(ks[3], (1, 32, 8))
>>> c = jax.random.normal(ks[4], (1, 32, 8))
>>> out = ssd(x, dt, a, b, c, chunk=16)
>>> bool(np.allclose(out, ssd_ref(x, dt, a, b, c), atol=1e-4))
True
"""
from __future__ import annotations

import functools

import jax

from .kernel import ssd_scan
from .ref import ssd_ref


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


@functools.partial(jax.jit, static_argnames=("chunk", "use_kernel"))
def ssd(x, dt, a, b, c, *, chunk=128, use_kernel=True):
    if not use_kernel:
        return ssd_ref(x, dt, a, b, c)
    return ssd_scan(x, dt, a, b, c, chunk=chunk, interpret=not _on_tpu())
