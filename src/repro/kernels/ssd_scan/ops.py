"""Jit'd wrapper for the SSD scan kernel with CPU interpret fallback."""
from __future__ import annotations

import functools

import jax

from .kernel import ssd_scan
from .ref import ssd_ref


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


@functools.partial(jax.jit, static_argnames=("chunk", "use_kernel"))
def ssd(x, dt, a, b, c, *, chunk=128, use_kernel=True):
    if not use_kernel:
        return ssd_ref(x, dt, a, b, c)
    return ssd_scan(x, dt, a, b, c, chunk=chunk, interpret=not _on_tpu())
