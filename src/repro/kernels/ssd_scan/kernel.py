"""Mamba2 SSD chunked-scan Pallas kernel.

TPU adaptation of the SSD algorithm (arXiv:2405.21060 §6): the CUDA kernel's
warp-level scan is replaced by a two-level scheme that matches the TPU
memory/compute hierarchy —

* **intra-chunk** (dense, MXU): C·Bᵀ Gram matrix against a lower-triangular
  decay matrix, all (Q×Q)/(Q×N)/(Q×P) tiles resident in VMEM;
* **inter-chunk** (sequential): a per-(batch·head) running summary state
  S ∈ R^{P×N} carried in VMEM scratch across the innermost grid dimension —
  one decay-scale + rank-Q update per chunk.

Grid: (batch·heads, num_chunks), chunk axis "arbitrary" (sequential).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from ..compat import tpu_compiler_params


def _ssd_kernel(a_ref, x_ref, dt_ref, b_ref, c_ref, y_ref, state_scr,
                *, chunk):
    ic = pl.program_id(1)

    @pl.when(ic == 0)
    def _init():
        state_scr[...] = jnp.zeros_like(state_scr)

    a = a_ref[0]                                       # per-head scalar
    x = x_ref[0].astype(jnp.float32)                   # (Q, P)
    dt = dt_ref[0].astype(jnp.float32)                 # (Q,)
    b = b_ref[0].astype(jnp.float32)                   # (Q, N)
    c = c_ref[0].astype(jnp.float32)                   # (Q, N)

    da = dt * a                                        # (Q,) ≤ 0
    da_cum = jnp.cumsum(da)                            # (Q,)
    xdt = x * dt[:, None]                              # (Q, P)

    # intra-chunk: y_d[i] = Σ_{j≤i} (C_i·B_j) e^{cum_i − cum_j} xdt_j
    scores = jax.lax.dot_general(
        c, b, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    )                                                  # (Q, Q)
    ii = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0)
    jj = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1)
    decay = jnp.exp(da_cum[:, None] - da_cum[None, :])
    l_mat = jnp.where(jj <= ii, scores * decay, 0.0)
    y = jax.lax.dot_general(
        l_mat, xdt, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )                                                  # (Q, P)

    # inter-chunk: y_off[i] = C_i e^{cum_i} S_in ;  S ← e^{cum_Q} S_in + ΔS
    s_in = state_scr[...]                              # (P, N)
    y += jnp.exp(da_cum)[:, None] * jax.lax.dot_general(
        c, s_in, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    )
    # ΔS = Σ_j e^{cum_Q − cum_j} xdt_j ⊗ B_j
    w = jnp.exp(da_cum[-1] - da_cum)[:, None] * xdt    # (Q, P)
    delta = jax.lax.dot_general(
        w, b, (((0,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )                                                  # (P, N)
    state_scr[...] = jnp.exp(da_cum[-1]) * s_in + delta

    y_ref[0, ...] = y.astype(y_ref.dtype)


def ssd_scan(
    x, dt, a, b, c, *, chunk: int = 128, interpret: bool = False
):
    """x: (B, L, H, P); dt: (B, L, H); a: (H,); b, c: (B, L, N) → (B, L, H, P)."""
    bsz, l, h, p = x.shape
    n = b.shape[-1]
    chunk = min(chunk, l)
    assert l % chunk == 0, (l, chunk)
    nc = l // chunk

    xf = x.transpose(0, 2, 1, 3).reshape(bsz * h, l, p)     # (BH, L, P)
    dtf = dt.transpose(0, 2, 1).reshape(bsz * h, l)         # (BH, L)

    kernel = functools.partial(_ssd_kernel, chunk=chunk)
    y = pl.pallas_call(
        kernel,
        grid=(bsz * h, nc),
        in_specs=[
            pl.BlockSpec((1,), lambda bh, ic: (bh % h,),
                         memory_space=pltpu.SMEM),
            pl.BlockSpec((1, chunk, p), lambda bh, ic: (bh, ic, 0)),
            pl.BlockSpec((1, chunk), lambda bh, ic: (bh, ic)),
            pl.BlockSpec((1, chunk, n), lambda bh, ic: (bh // h, ic, 0)),
            pl.BlockSpec((1, chunk, n), lambda bh, ic: (bh // h, ic, 0)),
        ],
        out_specs=pl.BlockSpec((1, chunk, p), lambda bh, ic: (bh, ic, 0)),
        out_shape=jax.ShapeDtypeStruct((bsz * h, l, p), x.dtype),
        scratch_shapes=[pltpu.VMEM((p, n), jnp.float32)],
        compiler_params=tpu_compiler_params(
            dimension_semantics=("parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(a.astype(jnp.float32), xf, dtf, b, c)
    return y.reshape(bsz, h, l, p).transpose(0, 2, 1, 3)
