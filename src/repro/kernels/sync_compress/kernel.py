"""Fused sync-codec Pallas kernels: the Line-5/7 uplink and server merge.

The Parameter-Server sync round is memory-bound: the reference path forms
``messages = w·payload``, adds the error-feedback residual, reduces the
quantizer scale, quantizes (or top-k masks), writes the new residual and
finally weighted-sums the fleet — each as its own pass over the parameter
vector (~5 tree sweeps before XLA fusion, ~12 HBM passes by the traffic
model). The kernels here fuse every element-wise stage of that pipeline so
each HBM pass does all the work available at that point:

* :func:`uplink_stats`    — quantize pass 1: the scale reduction
  ``max|w·z + ef|`` computed straight from the raw payload and residual
  (``eff`` is never materialized).
* :func:`quantize_uplink` — quantize pass 2: stochastic uniform quantization
  of ``eff`` with the rounding bits generated **in-register** (explicit
  threefry2x32 on the element counter — the shared derivation of
  :mod:`.ref`), the per-worker Line-7 weight applied on load, and the
  residual ``eff − sent`` written back in the same pass.
* :func:`eff_uplink`      — top-k pass 1: materialize ``eff = w·z + ef``
  (the host selects the top-k indices on it).
* :func:`mask_uplink`     — top-k pass 2: apply the survivor mask and write
  the complementary residual in one pass.
* :func:`merge_stacked`   — the server side: weight normalization
  (optionally over survivors), weighted sum over the worker axis and the
  broadcast back, one read + one write of the stacked fleet payload.

Layout mirrors ``kernels.adaseg_update``: leaves arrive worker-stacked and
flattened as ``(M, n)``, tiled to ``(M, nb·block)``; uplink kernels run on a
``(M, nb)`` grid with per-worker scalars (weight, scale, aliveness, seed) in
SMEM; the merge runs on a ``(nb,)`` grid over full-fleet ``(M, block)``
tiles. Dead workers (``alive = 0``) send exact zeros and keep their residual
frozen — the engines' fault semantics, fused.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from .ref import bits_to_uniform, threefry2x32


def _tile_rows(x, block):
    """Pad a stacked (M, n) leaf to (M, nb·block)."""
    m, n = x.shape
    pad = (-n) % block
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad)))
    return x


def _scalar_spec():
    return pl.BlockSpec((1, 1), lambda i, j: (i, 0), memory_space=pltpu.SMEM)


def _seed_spec():
    return pl.BlockSpec((1, 2), lambda i, j: (i, 0), memory_space=pltpu.SMEM)


def _row_spec(block):
    return pl.BlockSpec((1, block), lambda i, j: (i, j))


def _acc_spec():
    return pl.BlockSpec((1, 1), lambda i, j: (i, j), memory_space=pltpu.SMEM)


def _eff_tile(z_ref, ef_ref, w_ref, *, has_w, has_ef):
    """The codec's effective message for the current (worker, block) tile:
    ``w·z (+ ef)`` — computed in-register, never written to HBM unless the
    kernel's job IS to write it."""
    eff = z_ref[...].astype(jnp.float32)
    if has_w:
        eff = w_ref[0, 0] * eff
    if has_ef:
        eff = eff + ef_ref[...].astype(jnp.float32)
    return eff


def _kernel_uniform(seed_ref, block):
    """The shared uniform stream for this tile's global element indices:
    threefry2x32 bits generated in-kernel, same derivation as
    :func:`.ref.threefry_uniform`."""
    j = pl.program_id(1)
    idx = (j * block
           + jax.lax.broadcasted_iota(jnp.int32, (1, block), 1))
    idx = idx.astype(jnp.uint32)
    bits, _ = threefry2x32(seed_ref[0, 0], seed_ref[0, 1],
                           idx, jnp.zeros_like(idx))
    return bits_to_uniform(bits)


# ---------------------------------------------------------------------------
# Kernel bodies. Argument lists are assembled dynamically from the static
# has_* flags, so optional inputs (weight, residual, aliveness) cost nothing
# when absent. Order: scalars (w, scale, alive, seed) then vectors (z/eff,
# ef, mask), then outputs (sent/eff, ef_out / acc).
# ---------------------------------------------------------------------------

def _stats_kernel(*refs, has_w, has_ef):
    it = iter(refs)
    w_ref = next(it) if has_w else None
    z_ref = next(it)
    ef_ref = next(it) if has_ef else None
    acc_ref = next(it)
    eff = _eff_tile(z_ref, ef_ref, w_ref, has_w=has_w, has_ef=has_ef)
    # pad lanes are zero-filled → |eff| = 0 there, which cannot win the max
    # (the caller clamps the folded scale to ≥ 1e-30 anyway).
    acc_ref[0, 0] = jnp.max(jnp.abs(eff))


def _quantize_kernel(*refs, levels, block, has_w, has_ef, has_alive):
    it = iter(refs)
    w_ref = next(it) if has_w else None
    scale_ref = next(it)
    alive_ref = next(it) if has_alive else None
    seed_ref = next(it)
    z_ref = next(it)
    ef_ref = next(it) if has_ef else None
    sent_ref = next(it)
    ef_out_ref = next(it) if has_ef else None

    eff = _eff_tile(z_ref, ef_ref, w_ref, has_w=has_w, has_ef=has_ef)
    scale = scale_ref[0, 0]
    y = jnp.abs(eff) / scale * levels
    lo = jnp.floor(y)
    up = _kernel_uniform(seed_ref, block) < (y - lo)
    mag = (lo + up.astype(eff.dtype)) * (scale / levels)
    sent = jnp.sign(eff) * mag
    ef_new = eff - sent
    if has_alive:
        ok = alive_ref[0, 0] > 0.0
        sent = jnp.where(ok, sent, jnp.zeros_like(sent))
        if has_ef:
            ef_new = jnp.where(ok, eff - sent,
                               ef_ref[...].astype(jnp.float32))
    sent_ref[...] = sent.astype(sent_ref.dtype)
    if has_ef:
        ef_out_ref[...] = ef_new.astype(ef_out_ref.dtype)


def _eff_kernel(*refs, has_w, has_ef):
    it = iter(refs)
    w_ref = next(it) if has_w else None
    z_ref = next(it)
    ef_ref = next(it) if has_ef else None
    out_ref = next(it)
    eff = _eff_tile(z_ref, ef_ref, w_ref, has_w=has_w, has_ef=has_ef)
    out_ref[...] = eff.astype(out_ref.dtype)


def _mask_kernel(*refs, has_ef, has_alive):
    it = iter(refs)
    alive_ref = next(it) if has_alive else None
    eff_ref = next(it)
    mask_ref = next(it)
    ef_ref = next(it) if (has_ef and has_alive) else None
    sent_ref = next(it)
    ef_out_ref = next(it) if has_ef else None

    eff = eff_ref[...].astype(jnp.float32)
    sent = jnp.where(mask_ref[...] != 0, eff, jnp.zeros_like(eff))
    ef_new = eff - sent
    if has_alive:
        ok = alive_ref[0, 0] > 0.0
        sent = jnp.where(ok, sent, jnp.zeros_like(sent))
        if has_ef:
            ef_new = jnp.where(ok, eff - sent,
                               ef_ref[...].astype(jnp.float32))
    sent_ref[...] = sent.astype(sent_ref.dtype)
    if has_ef:
        ef_out_ref[...] = ef_new.astype(ef_out_ref.dtype)


def _trimmed_kernel(*refs, m, trim, has_recv):
    """Robust server merge: per-coordinate β-trimmed weighted mean via
    sort-free streaming rank selection — same expressions as
    :func:`.ref.trimmed_merge_ref`, on the full-fleet (M, block) tile.

    The rank accumulation is an unrolled Python loop over the static worker
    count: each pass broadcasts one row against the whole tile, so the
    selection stays in-register (no sort network, no gather)."""
    it = iter(refs)
    w_ref = next(it)
    incl_ref = next(it)
    recv_ref = next(it) if has_recv else None
    z_ref = next(it)
    old_ref = next(it) if has_recv else None
    out_ref = next(it)

    z = z_ref[...].astype(jnp.float32)                  # (M, block)
    incl = incl_ref[0, :]                               # (M,) 0/1
    n_incl = jnp.sum(incl)
    b = jnp.minimum(jnp.float32(trim), jnp.floor((n_incl - 1.0) * 0.5))
    row_ids = jax.lax.broadcasted_iota(jnp.int32, z.shape, 0)
    rank = jnp.zeros_like(z)
    for k in range(m):                        # streaming: one row per pass
        zk = z[k:k + 1, :]
        less = (zk < z) | ((zk == z) & (k < row_ids))
        rank = rank + incl[k] * less.astype(jnp.float32)
    keep = ((rank >= b) & (rank <= n_incl - 1.0 - b)
            & (incl.reshape(m, 1) > 0.0))
    wk = w_ref[0, :].reshape(m, 1) * keep.astype(jnp.float32)
    denom = jnp.maximum(jnp.sum(wk, axis=0, keepdims=True), 1e-30)
    mean = jnp.sum(wk * z, axis=0, keepdims=True) / denom
    merged = jnp.broadcast_to(mean, z_ref.shape)
    if has_recv:
        keep_rows = recv_ref[0, :].reshape(m, 1) > 0.0
        merged = jnp.where(keep_rows, merged,
                           old_ref[...].astype(jnp.float32))
    out_ref[...] = merged.astype(out_ref.dtype)


def _merge_kernel(*refs, m, normalize, has_w, has_recv):
    it = iter(refs)
    w_ref = next(it) if has_w else None
    recv_ref = next(it) if has_recv else None
    z_ref = next(it)
    old_ref = next(it) if has_recv else None
    out_ref = next(it)

    z = z_ref[...].astype(jnp.float32)                  # (M, block)
    if has_w:
        w = w_ref[0, :]                                 # (M,)
        if normalize:
            w = w / jnp.sum(w)
        z = w.reshape(m, 1) * z
    mean = jnp.sum(z, axis=0, keepdims=True)            # (1, block)
    merged = jnp.broadcast_to(mean, z_ref.shape)
    if has_recv:
        keep = recv_ref[0, :].reshape(m, 1) > 0.0
        merged = jnp.where(keep, merged,
                           old_ref[...].astype(jnp.float32))
    out_ref[...] = merged.astype(out_ref.dtype)


def _outer_kernel(*refs, spec, slots):
    """Server outer-optimizer pass: form Δ = merged − z and apply one
    moment update + step of the static ``spec`` policy in-register — one
    read of (merged, z, moments), one write of (z′, moments′) per tile,
    the single extra HBM pass the two-level scheme costs. The per-tile
    ``Σ Δ²`` lands in an SMEM accumulator for the ‖Δ‖ telemetry. Exact
    expression sequence of :func:`.ref.outer_apply_ref`."""
    it = iter(refs)
    t_ref = next(it)
    g_ref = next(it)
    z_ref = next(it)
    mom_refs = [next(it) for _ in range(slots)]
    z_out_ref = next(it)
    mom_out_refs = [next(it) for _ in range(slots)]
    acc_ref = next(it)

    kind = spec[0]
    g = g_ref[...].astype(jnp.float32)
    zz = z_ref[...].astype(jnp.float32)
    d = g - zz
    if kind == "momentum":
        _, lr, beta = spec
        m_new = (jnp.float32(beta) * mom_refs[0][...].astype(jnp.float32)
                 + d)
        z_new = zz + jnp.float32(lr) * m_new
        mom_new = (m_new,)
    elif kind == "nesterov":
        _, lr, beta = spec
        m_new = (jnp.float32(beta) * mom_refs[0][...].astype(jnp.float32)
                 + d)
        z_new = zz + jnp.float32(lr) * (d + jnp.float32(beta) * m_new)
        mom_new = (m_new,)
    else:                                               # adam
        _, lr, b1, b2, eps = spec
        t_new = t_ref[0, 0] + 1.0
        m_new = (jnp.float32(b1) * mom_refs[0][...].astype(jnp.float32)
                 + jnp.float32(1.0 - b1) * d)
        v_new = (jnp.float32(b2) * mom_refs[1][...].astype(jnp.float32)
                 + jnp.float32(1.0 - b2) * d * d)
        m_hat = m_new / (1.0 - jnp.float32(b1) ** t_new)
        v_hat = v_new / (1.0 - jnp.float32(b2) ** t_new)
        z_new = zz + jnp.float32(lr) * m_hat / (jnp.sqrt(v_hat)
                                                + jnp.float32(eps))
        mom_new = (m_new, v_new)
    z_out_ref[...] = z_new.astype(z_out_ref.dtype)
    for out_ref, mn in zip(mom_out_refs, mom_new):
        out_ref[...] = mn.astype(out_ref.dtype)
    acc_ref[0, 0] = jnp.sum(d * d)


# ---------------------------------------------------------------------------
# Per-leaf entry points: worker-stacked flat (M, n) leaves; pytree
# composition and the reference/fused switch live in ops.py.
# ---------------------------------------------------------------------------

def _uplink_call(kernel, scalars, vectors, out_vectors, acc, m, n, block,
                 interpret, dtype):
    """Shared pallas_call plumbing for the (M, nb)-grid uplink kernels."""
    nb = (n + (-n) % block) // block
    in_specs, args = [], []
    for spec, a in scalars:
        in_specs.append(spec)
        args.append(a)
    for v in vectors:
        in_specs.append(_row_spec(block))
        args.append(_tile_rows(v, block))
    out_specs, out_shape = [], []
    for _ in range(out_vectors):
        out_specs.append(_row_spec(block))
        out_shape.append(jax.ShapeDtypeStruct((m, nb * block), dtype))
    if acc:
        out_specs.append(_acc_spec())
        out_shape.append(jax.ShapeDtypeStruct((m, nb), jnp.float32))
    outs = pl.pallas_call(
        kernel,
        grid=(m, nb),
        in_specs=in_specs,
        out_specs=out_specs,
        out_shape=out_shape,
        interpret=interpret,
    )(*args)
    outs = list(outs) if isinstance(outs, (list, tuple)) else [outs]
    return [o[:, :n] for o in outs[:out_vectors]] + outs[out_vectors:]


def _w_arg(w):
    return (_scalar_spec(), jnp.asarray(w, jnp.float32).reshape(-1, 1))


def uplink_stats(z, w=None, ef=None, *, block: int = 4096,
                 interpret: bool = False):
    """Quantize pass 1 on a stacked (M, n) leaf: per-worker ``max|w·z+ef|``
    without materializing the effective message. Returns ``(M,)`` maxima
    (caller applies the 1e-30 clamp)."""
    m, n = z.shape
    scalars = [] if w is None else [_w_arg(w)]
    vectors = [z] + ([] if ef is None else [ef])
    kernel = functools.partial(_stats_kernel, has_w=w is not None,
                               has_ef=ef is not None)
    (acc,) = _uplink_call(kernel, scalars, vectors, 0, True, m, n, block,
                          interpret, z.dtype)
    return jnp.max(acc, axis=1)


def quantize_uplink(z, seeds, scale, w=None, ef=None, alive=None, *,
                    levels: float, block: int = 4096,
                    interpret: bool = False):
    """Quantize pass 2: one fused sweep doing EF add + w scaling +
    stochastic quantization (threefry bits in-register) + residual
    write-back on a stacked (M, n) leaf.

    ``seeds`` is (M, 2) uint32 — the per-(worker, leaf) keys of the shared
    derivation; ``scale`` is (M,) clamped maxima from :func:`uplink_stats`.
    Returns ``(sent, ef_new)`` (``ef_new`` is None when ``ef`` is None).
    """
    m, n = z.shape
    scalars = [] if w is None else [_w_arg(w)]
    scalars.append((_scalar_spec(),
                    jnp.asarray(scale, jnp.float32).reshape(-1, 1)))
    if alive is not None:
        scalars.append(_w_arg(alive))
    scalars.append((_seed_spec(),
                    jnp.asarray(seeds, jnp.uint32).reshape(m, 2)))
    vectors = [z] + ([] if ef is None else [ef])
    kernel = functools.partial(
        _quantize_kernel, levels=levels, block=block, has_w=w is not None,
        has_ef=ef is not None, has_alive=alive is not None,
    )
    outs = _uplink_call(kernel, scalars, vectors, 1 + (ef is not None),
                        False, m, n, block, interpret, z.dtype)
    return (outs[0], outs[1]) if ef is not None else (outs[0], None)


def eff_uplink(z, w=None, ef=None, *, block: int = 4096,
               interpret: bool = False):
    """Top-k pass 1: materialize ``eff = w·z + ef`` in one fused sweep."""
    m, n = z.shape
    scalars = [] if w is None else [_w_arg(w)]
    vectors = [z] + ([] if ef is None else [ef])
    kernel = functools.partial(_eff_kernel, has_w=w is not None,
                               has_ef=ef is not None)
    (out,) = _uplink_call(kernel, scalars, vectors, 1, False, m, n, block,
                          interpret, z.dtype)
    return out


def mask_uplink(eff, mask, ef=None, alive=None, *, want_ef: bool = True,
                block: int = 4096, interpret: bool = False):
    """Top-k pass 2: apply the survivor mask and write the complementary
    residual in the same sweep. ``ef`` (the pre-round residual) is only
    read when ``alive`` is given, to freeze dead workers' memory.
    Returns ``(sent, ef_new)`` (``ef_new`` None when ``want_ef`` is False).
    """
    m, n = eff.shape
    scalars = [] if alive is None else [_w_arg(alive)]
    vectors = [eff, mask]
    if want_ef and alive is not None:
        vectors.append(jnp.zeros_like(eff) if ef is None else ef)
    kernel = functools.partial(_mask_kernel, has_ef=want_ef,
                               has_alive=alive is not None)
    outs = _uplink_call(kernel, scalars, vectors, 1 + want_ef, False, m, n,
                        block, interpret, eff.dtype)
    return (outs[0], outs[1]) if want_ef else (outs[0], None)


def merge_stacked(z, w=None, recv=None, old=None, *, normalize: bool = False,
                  block: int = 4096, interpret: bool = False):
    """Fused server merge on a stacked (M, n) leaf: weighted sum over the
    worker axis (weights optionally normalized in-register — the Line-7
    renormalization over survivors) broadcast back to every worker, with
    non-receiving workers (``recv`` falsy) keeping ``old``.
    """
    m, n = z.shape
    nb = (n + (-n) % block) // block
    in_specs, args = [], []

    def vec_smem(v):
        in_specs.append(pl.BlockSpec((1, m), lambda j: (0, 0),
                                     memory_space=pltpu.SMEM))
        args.append(jnp.asarray(v, jnp.float32).reshape(1, m))

    if w is not None:
        vec_smem(w)
    if recv is not None:
        vec_smem(recv)
    full_spec = pl.BlockSpec((m, block), lambda j: (0, j))
    in_specs.append(full_spec)
    args.append(_tile_rows(z, block))
    if recv is not None:
        in_specs.append(full_spec)
        args.append(_tile_rows(z if old is None else old, block))
    kernel = functools.partial(
        _merge_kernel, m=m, normalize=normalize, has_w=w is not None,
        has_recv=recv is not None,
    )
    out = pl.pallas_call(
        kernel,
        grid=(nb,),
        in_specs=in_specs,
        out_specs=full_spec,
        out_shape=jax.ShapeDtypeStruct((m, nb * block), z.dtype),
        interpret=interpret,
    )(*args)
    return out[:, :n]


def trimmed_merge_stacked(z, w, incl, recv=None, old=None, *, trim: int,
                          block: int = 4096, interpret: bool = False):
    """Fused *robust* server merge on a stacked (M, n) leaf: per-coordinate
    ``trim``-per-side trimmed weighted mean over the included rows
    (``incl`` — 0/1, dead/unselected lanes never enter the order
    statistics), renormalized over the survivors' weight mass and broadcast
    back. ``trim = ⌊(M−1)/2⌋`` is the coordinate median. Same (nb,)-grid
    full-fleet tile layout as :func:`merge_stacked`; ``recv``/``old`` gate
    delivery identically.
    """
    m, n = z.shape
    nb = (n + (-n) % block) // block
    in_specs, args = [], []

    def vec_smem(v):
        in_specs.append(pl.BlockSpec((1, m), lambda j: (0, 0),
                                     memory_space=pltpu.SMEM))
        args.append(jnp.asarray(v, jnp.float32).reshape(1, m))

    vec_smem(w)
    vec_smem(incl)
    if recv is not None:
        vec_smem(recv)
    full_spec = pl.BlockSpec((m, block), lambda j: (0, j))
    in_specs.append(full_spec)
    args.append(_tile_rows(z, block))
    if recv is not None:
        in_specs.append(full_spec)
        args.append(_tile_rows(z if old is None else old, block))
    kernel = functools.partial(_trimmed_kernel, m=m, trim=trim,
                               has_recv=recv is not None)
    out = pl.pallas_call(
        kernel,
        grid=(nb,),
        in_specs=in_specs,
        out_specs=full_spec,
        out_shape=jax.ShapeDtypeStruct((m, nb * block), z.dtype),
        interpret=interpret,
    )(*args)
    return out[:, :n]


def outer_apply(merged, z, mom, t, *, spec, block: int = 4096,
                interpret: bool = False):
    """Fused server outer-optimizer step on one server leaf ``(1, n)``:
    Δ = merged − z, one moment update + apply of the static ``spec``
    policy (``repro.ps.server_opt`` tuples), all in-register on the same
    ``(nb,)``-grid full-row tiles as :func:`merge_stacked`.

    ``mom`` is the tuple of moment leaves (matched to the policy's slot
    count), ``t`` the f32 round count before this step (SMEM scalar —
    only adam's bias correction reads it). Returns
    ``(z_new, mom_new, delta_sq)`` with ``delta_sq = Σ Δ²`` reduced from
    the per-tile SMEM accumulator. Padding is zero-filled on every input,
    so pad lanes contribute exact zeros to moments, step and Δ² alike.
    """
    m, n = z.shape
    nb = (n + (-n) % block) // block
    slots = len(mom)
    full_spec = pl.BlockSpec((m, block), lambda j: (0, j))
    t_spec = pl.BlockSpec((1, 1), lambda j: (0, 0),
                          memory_space=pltpu.SMEM)
    acc_spec = pl.BlockSpec((1, 1), lambda j: (0, j),
                            memory_space=pltpu.SMEM)
    args = [jnp.asarray(t, jnp.float32).reshape(1, 1),
            _tile_rows(merged, block), _tile_rows(z, block)]
    args += [_tile_rows(mm, block) for mm in mom]
    out_shape = [jax.ShapeDtypeStruct((m, nb * block), z.dtype)]
    out_shape += [jax.ShapeDtypeStruct((m, nb * block), mm.dtype)
                  for mm in mom]
    out_shape.append(jax.ShapeDtypeStruct((1, nb), jnp.float32))
    kernel = functools.partial(_outer_kernel, spec=spec, slots=slots)
    outs = pl.pallas_call(
        kernel,
        grid=(nb,),
        in_specs=[t_spec] + [full_spec] * (2 + slots),
        out_specs=[full_spec] * (1 + slots) + [acc_spec],
        out_shape=out_shape,
        interpret=interpret,
    )(*args)
    z_new = outs[0][:, :n]
    mom_new = tuple(o[:, :n] for o in outs[1:1 + slots])
    return z_new, mom_new, jnp.sum(outs[-1])
