"""Jit'd pytree-level wrappers for the fused sync-codec kernels.

These are the functions the Parameter-Server runtime calls when a config
says ``codec_backend="fused"``: :func:`codec_uplink_stacked` replaces the
serial engines' message-scale-compress-residual tree pipeline (and
:func:`codec_uplink` the per-shard / single-worker form), while
:func:`sync_merge_stacked` replaces the weighted-sum-broadcast server side
(``core.adaseg.sync_weighted_stacked(backend="fused")`` routes here too).
They fall back to interpret mode automatically off-TPU and to the pure-jnp
references in :mod:`.ref` with ``use_kernel=False``.

Codecs are passed as a static *spec* (mirroring the projection specs of
``kernels.adaseg_update``) so the kernels can fuse them without a semantics
fork — ``repro.ps.compress`` compressors export theirs as
``SyncCompressor.codec_spec``:

* ``("identity",)``        — no codec: the uplink is just the w-scaling;
* ``("quantize", bits)``   — stochastic uniform quantization, two fused
  passes (scale reduction; EF add + quantize + residual write-back with the
  threefry rounding bits generated in-kernel);
* ``("topk", fraction)``   — top-k sparsification, two fused passes around
  a host-side ``lax.top_k`` index selection (EF add / mask + residual).

RNG: ``rngs`` are the engines' per-worker compression keys; per-leaf keys
are derived with the same ``jax.random.split`` chain the reference
compressors use, and the per-*element* bits inside the kernel follow the
shared threefry derivation of :mod:`.ref` — which is exactly why the fused
and reference stochastic-quantize paths agree to float tolerance.

Examples
--------
A q8 uplink for two stacked workers, fused vs reference:

>>> import jax, jax.numpy as jnp, numpy as np
>>> from repro.kernels.sync_compress.ops import codec_uplink_stacked
>>> z = {"p": jnp.array([[0.5, -1.0, 2.0], [1.5, 0.25, -0.75]])}
>>> ef = {"p": jnp.zeros((2, 3))}
>>> w = jnp.array([0.25, 0.75])
>>> rngs = jax.random.split(jax.random.PRNGKey(0), 2)
>>> sent, ef_new = codec_uplink_stacked(z, rngs, w=w, ef=ef,
...                                     codec=("quantize", 8))
>>> ref, ef_ref = codec_uplink_stacked(z, rngs, w=w, ef=ef,
...                                    codec=("quantize", 8),
...                                    use_kernel=False)
>>> bool(np.allclose(sent["p"], ref["p"], rtol=1e-5))
True
"""
from __future__ import annotations

import functools
import math
from typing import Any

import jax
import jax.numpy as jnp

from . import ref as _ref
from .kernel import (
    eff_uplink,
    mask_uplink,
    merge_stacked,
    outer_apply,
    quantize_uplink,
    trimmed_merge_stacked,
    uplink_stats,
)

PyTree = Any

_CODECS = ("identity", "quantize", "topk")


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def _leaf_block(block, n, interp):
    """One block per (worker, leaf) row in interpret mode — a single fused
    jnp sweep off-TPU; the VMEM-sized block stands on hardware."""
    return max(n, 1) if interp else block


def _check_codec(codec):
    if not (isinstance(codec, tuple) and codec and codec[0] in _CODECS):
        raise ValueError(f"unknown codec spec {codec!r}")
    return codec


def _flat2(leaf):
    """Worker-stacked leaf (M, ...) → (M, n)."""
    return leaf.reshape(leaf.shape[0], -1)


def _topk_mask(eff2, fraction):
    """Per-worker top-k survivor mask on a flat (M, n) leaf — the same
    index selection (``lax.top_k`` on magnitudes, ties to lowest index) the
    reference ``TopKCompressor`` scatters through, so fused ≡ reference
    entry-for-entry."""
    n = eff2.shape[1]
    k = max(1, int(math.ceil(fraction * n)))

    def one(e):
        _, idx = jax.lax.top_k(jnp.abs(e), k)
        return jnp.zeros_like(e).at[idx].set(1.0)

    return jax.vmap(one)(eff2)


_STATIC = ("codec", "use_kernel", "block")


@functools.partial(jax.jit, static_argnames=_STATIC)
def codec_uplink_stacked(payload, rngs, w=None, ef=None, alive=None, *,
                         codec, use_kernel=True, block=4096):
    """The whole Line-5 uplink for M stacked workers in fused sweeps:
    per-leaf, apply the Line-7 weight ``w``, add the error-feedback
    residual ``ef``, run the codec, and write the new residual back.

    ``payload``/``ef`` are worker-stacked pytrees (leading axis M);
    ``rngs`` is (M, 2) per-worker keys (consumed only by stochastic
    codecs); ``w`` (M,) weights (None = no scaling — the async wire
    format); ``alive`` (M,) masks dead workers (they send exact zeros and
    keep their residual frozen). Returns ``(sent, ef_new)`` with
    ``ef_new = ef`` (identity) or None when ``ef`` is None.
    """
    kind = _check_codec(codec)[0]
    if rngs is not None:
        rngs = _ref.key_data(rngs)      # typed keys → raw uint32 (M, 2)
    leaves, treedef = jax.tree.flatten(payload)
    ef_leaves = (treedef.flatten_up_to(ef) if ef is not None
                 else [None] * len(leaves))
    interp = not _on_tpu()
    w = None if w is None else jnp.asarray(w, jnp.float32)
    alive = None if alive is None else jnp.asarray(alive, jnp.float32)

    if kind == "quantize":
        levels = float(2 ** codec[1] - 1)
        leaf_keys = jax.vmap(
            lambda k: jax.random.split(k, len(leaves))
        )(jnp.asarray(rngs))                              # (M, L, 2)

    sents, ef_news = [], []
    for li, (z, e) in enumerate(zip(leaves, ef_leaves)):
        shape = z.shape
        z2 = _flat2(z)
        e2 = None if e is None else _flat2(e)
        n = z2.shape[1]
        kw = dict(block=_leaf_block(block, n, interp), interpret=interp)

        if kind == "identity":
            if use_kernel:
                sent2 = eff_uplink(z2, w, e2, **kw) if (
                    w is not None or e2 is not None) else z2
            else:
                sent2 = _ref.eff_uplink_ref(z2, ef=e2, w=None if w is None
                                            else w[:, None])
            ef2 = e2
        elif kind == "quantize":
            keys = leaf_keys[:, li]                       # (M, 2)
            if use_kernel:
                stats = uplink_stats(z2, w, e2, **kw)
                scale = jnp.maximum(stats, 1e-30)
                sent2, ef2 = quantize_uplink(z2, keys, scale, w, e2, alive,
                                             levels=levels, **kw)
            else:
                # per-worker reference oracle, identical expressions
                outs = [
                    _ref.quantize_uplink_ref(
                        z2[m], keys[m],
                        jnp.maximum(_ref.uplink_stats_ref(
                            z2[m], ef=None if e2 is None else e2[m],
                            w=None if w is None else w[m]), 1e-30),
                        levels=levels,
                        ef=None if e2 is None else e2[m],
                        w=None if w is None else w[m],
                        alive=None if alive is None else alive[m] > 0,
                    )
                    for m in range(z2.shape[0])
                ]
                sent2 = jnp.stack([o[0] for o in outs])
                ef2 = (jnp.stack([o[1] for o in outs])
                       if e2 is not None else None)
        else:                                             # topk
            fraction = codec[1]
            if use_kernel:
                eff2 = eff_uplink(z2, w, e2, **kw) if (
                    w is not None or e2 is not None) else z2
                mask2 = _topk_mask(eff2, fraction)
                sent2, ef2 = mask_uplink(eff2, mask2, e2, alive,
                                         want_ef=e2 is not None, **kw)
            else:
                wb = None if w is None else w[:, None]
                eff2 = _ref.eff_uplink_ref(z2, ef=e2, w=wb)
                mask2 = _topk_mask(eff2, fraction)
                ab = None if alive is None else alive[:, None] > 0
                sent2, ef2 = _ref.mask_uplink_ref(eff2, mask2, alive=ab,
                                                  ef=e2)
                if e2 is None:
                    ef2 = None
        sents.append(sent2.reshape(shape))
        ef_news.append(None if ef2 is None else ef2.reshape(shape))

    sent_tree = treedef.unflatten(sents)
    ef_tree = (treedef.unflatten(ef_news) if ef is not None else None)
    return sent_tree, ef_tree


@functools.partial(jax.jit, static_argnames=_STATIC)
def codec_uplink(payload, rng, w=None, ef=None, alive=None, *, codec,
                 use_kernel=True, block=4096):
    """Single-worker form of :func:`codec_uplink_stacked` (no leading worker
    axis) — the per-shard uplink of the ``shard_map`` engines and the
    stateless ``make_compressed_psum_sync`` hook. ``w``/``alive`` are
    scalars, ``rng`` one (2,) key."""
    p1 = jax.tree.map(lambda v: v[None], payload)
    e1 = None if ef is None else jax.tree.map(lambda v: v[None], ef)
    w1 = None if w is None else jnp.asarray(w, jnp.float32).reshape(1)
    a1 = (None if alive is None
          else jnp.asarray(alive, jnp.float32).reshape(1))
    sent, ef_new = codec_uplink_stacked(
        p1, _ref.key_data(rng).reshape(1, 2), w1, e1, a1, codec=codec,
        use_kernel=use_kernel, block=block,
    )
    sent = jax.tree.map(lambda v: v[0], sent)
    if ef_new is not None:
        ef_new = jax.tree.map(lambda v: v[0], ef_new)
    return sent, ef_new


def _krum_select(z2s, w, *, f, m_sel):
    """(Multi-)Krum selection on flat (M, n) leaves: score each included
    worker by the sum of its ``nb = max(1, M − f − 2)`` smallest squared
    distances to *other* included workers, keep the ``m_sel`` lowest-scoring
    (ties to lowest worker index — ``lax.top_k`` order), and return a (M,)
    0/1 selection mask. Zero-weight lanes (dead / unselected) never enter
    the distance pool and are never selected."""
    m = z2s[0].shape[0]
    zc = jnp.concatenate([zz.astype(jnp.float32) for zz in z2s], axis=1)
    sq = jnp.sum(zc * zc, axis=1)
    d = sq[:, None] + sq[None, :] - 2.0 * (zc @ zc.T)
    incl = (jnp.ones((m,), jnp.float32) if w is None
            else jnp.asarray(w, jnp.float32)) > 0
    pair = incl[None, :] & incl[:, None] & ~jnp.eye(m, dtype=bool)
    inf = jnp.float32(jnp.inf)
    d = jnp.where(pair, d, inf)
    nb = max(1, m - f - 2)
    score = jnp.sum(jnp.sort(d, axis=1)[:, :nb], axis=1)
    score = jnp.where(incl, score, inf)
    _, idx = jax.lax.top_k(-score, min(m_sel, m))
    sel = jnp.zeros((m,), jnp.float32).at[idx].set(1.0)
    return sel * incl.astype(jnp.float32)


@functools.partial(jax.jit,
                   static_argnames=("normalize", "agg", "use_kernel",
                                    "block"))
def sync_merge_stacked(z, w=None, recv=None, old=None, *, normalize=False,
                       agg=None, use_kernel=True, block=4096):
    """The fused Line-7 server side on a worker-stacked pytree: weighted sum
    over the worker axis (``w`` raw weights, normalized in-register when
    ``normalize``) broadcast back to every worker — one read + one write of
    the fleet payload per leaf instead of the scale/sum/broadcast tree
    passes. ``recv`` (M,) gates delivery: non-receiving workers keep their
    ``old`` (default: ``z``) row, the engines' fault semantics.

    ``agg`` selects a *robust* merge instead of the plain weighted mean (the
    static specs produced by ``repro.ps.robust`` aggregators; ``None`` is
    the historical mean — robust aggregators at zero budget resolve to
    ``None``, so clean-fleet degradation is the same compiled function):

    * ``("trimmed", b)``     — per-coordinate b-per-side trimmed weighted
      mean over the positive-weight lanes (``b = ⌊(M−1)/2⌋`` is the
      coordinate median), survivor-renormalized; fused via the sort-free
      streaming-rank kernel, reference via :func:`.ref.trimmed_merge_ref`.
    * ``("krum", f, m_sel)`` — multi-Krum: keep the ``m_sel`` workers with
      the smallest sum of ``max(1, M−f−2)`` nearest squared distances, then
      the survivor-renormalized weighted mean of the keepers.
    """
    leaves, treedef = jax.tree.flatten(z)
    old_leaves = (treedef.flatten_up_to(old) if old is not None
                  else [None] * len(leaves))
    interp = not _on_tpu()
    w = None if w is None else jnp.asarray(w, jnp.float32)
    recv = None if recv is None else jnp.asarray(recv, jnp.float32)
    m = leaves[0].shape[0]

    if agg is not None and agg[0] == "krum":
        sel = _krum_select([_flat2(l) for l in leaves], w,
                           f=int(agg[1]), m_sel=int(agg[2]))
        w = sel if w is None else w * sel
        agg, normalize = None, True     # mean over the Krum survivors

    if agg is not None:                 # ("trimmed", b)
        trim = int(agg[1])
        wt = jnp.ones((m,), jnp.float32) if w is None else w
        incl = (wt > 0).astype(jnp.float32)
        outs = []
        for zl, ol in zip(leaves, old_leaves):
            shape = zl.shape
            z2 = _flat2(zl)
            o2 = None if ol is None else _flat2(ol)
            n = z2.shape[1]
            if use_kernel:
                out2 = trimmed_merge_stacked(
                    z2, wt, incl, recv, o2, trim=trim,
                    block=_leaf_block(block, n, interp), interpret=interp,
                )
            else:
                out2 = _ref.trimmed_merge_ref(
                    z2, wt, incl, trim=trim,
                    recv=None if recv is None else recv > 0, old=o2,
                )
            outs.append(out2.reshape(shape))
        return treedef.unflatten(outs)

    outs = []
    for zl, ol in zip(leaves, old_leaves):
        shape = zl.shape
        z2 = _flat2(zl)
        o2 = None if ol is None else _flat2(ol)
        n = z2.shape[1]
        if use_kernel:
            out2 = merge_stacked(
                z2, w, recv, o2, normalize=normalize,
                block=_leaf_block(block, n, interp), interpret=interp,
            )
        else:
            out2 = _ref.merge_ref(z2, w, normalize=normalize,
                                  recv=None if recv is None else recv > 0,
                                  old=o2)
        outs.append(out2.reshape(shape))
    return treedef.unflatten(outs)


@functools.partial(jax.jit,
                   static_argnames=("spec", "use_kernel", "block"))
def server_outer_apply(merged, z, mom, t, *, spec, use_kernel=True,
                       block=4096):
    """The server's outer-optimizer step on pytrees: per leaf, form the
    round delta Δ = merged − z and apply one fused moment update + step of
    the static ``spec`` policy (``repro.ps.server_opt`` tuples) — one
    extra HBM pass over the merged server anchor, downstream of whatever
    (robust) merge produced it.

    ``merged``/``z`` are server-space pytrees (leading axis 1), ``mom`` a
    tuple of z-shaped moment trees (1 for momentum/nesterov, 2 for adam),
    ``t`` the int32 count of outer steps taken so far. Returns
    ``(z_new, mom_new, t_new, eff_lr, delta_norm)`` where ``eff_lr`` is
    the policy's effective step size this round (adam: bias-correction
    folded in) and ``delta_norm = ‖Δ‖₂`` over all leaves — the trace
    telemetry pair.

    Examples
    --------
    Nesterov's first step moves by lr·(1+β)·Δ off a zero moment:

    >>> import jax.numpy as jnp, numpy as np
    >>> from repro.kernels.sync_compress.ops import server_outer_apply
    >>> z = {"p": jnp.zeros((1, 3))}
    >>> merged = {"p": jnp.array([[1.0, -2.0, 0.5]])}
    >>> mom = ({"p": jnp.zeros((1, 3))},)
    >>> zn, mn, tn, lr, dn = server_outer_apply(
    ...     merged, z, mom, jnp.int32(0), spec=("nesterov", 0.5, 0.8))
    >>> bool(np.allclose(zn["p"], 0.5 * 1.8 * merged["p"], rtol=1e-6))
    True
    >>> float(lr), int(tn)
    (0.5, 1)
    >>> bool(np.allclose(dn, jnp.sqrt(jnp.sum(merged["p"] ** 2))))
    True
    """
    interp = not _on_tpu()
    z_leaves, treedef = jax.tree.flatten(z)
    g_leaves = treedef.flatten_up_to(merged)
    mom_leaves = [treedef.flatten_up_to(mm) for mm in mom]
    t_f = jnp.asarray(t, jnp.float32)
    z_new_l = []
    mom_new_l = [[] for _ in mom]
    dsq = jnp.float32(0.0)
    for i, (g, zl) in enumerate(zip(g_leaves, z_leaves)):
        shape = zl.shape
        g2, z2 = _flat2(g), _flat2(zl)
        m2 = tuple(_flat2(ml[i]) for ml in mom_leaves)
        n = z2.shape[1]
        if use_kernel:
            zn2, mn2, ds = outer_apply(
                g2, z2, m2, t_f, spec=spec,
                block=_leaf_block(block, n, interp), interpret=interp,
            )
        else:
            zn2, mn2, ds = _ref.outer_apply_ref(g2, z2, m2, t_f, spec=spec)
        z_new_l.append(zn2.reshape(shape))
        for s, mn in enumerate(mn2):
            mom_new_l[s].append(mn.reshape(shape))
        dsq = dsq + ds
    t_new = jnp.asarray(t, jnp.int32) + 1
    if spec[0] == "adam":
        _, lr, b1, b2, _ = spec
        tf = t_new.astype(jnp.float32)
        eff_lr = (jnp.float32(lr)
                  * jnp.sqrt(1.0 - jnp.float32(b2) ** tf)
                  / (1.0 - jnp.float32(b1) ** tf))
    else:
        eff_lr = jnp.float32(spec[1])
    return (treedef.unflatten(z_new_l),
            tuple(treedef.unflatten(l) for l in mom_new_l),
            t_new, eff_lr, jnp.sqrt(dsq))


# ---------------------------------------------------------------------------
# Analytic HBM-traffic model (benchmarks/bench_ps.py, bench_kernels-style):
# passes over the parameter vector per uplink, reference tree pipeline vs
# fused kernels. Reads and writes both count as one pass.
# ---------------------------------------------------------------------------

#: passes per sync uplink (error-feedback codecs): {codec: (ref, fused)}.
#: reference = message scale + EF add + scale/select reduction + quantize/
#: scatter + residual, each a separate tree sweep; fused = the 2-pass
#: kernels above (stats/eff + codec-with-residual). identity is the
#: degenerate 1-pass scaling vs scale+sum+broadcast.
CODEC_PASS_MODEL = {
    "identity": (4, 2),
    "quantize": (11, 6),
    "topk": (10, 8),
}


def codec_passes(codec) -> tuple[int, int]:
    """(reference, fused) HBM passes per uplink for a codec spec."""
    return CODEC_PASS_MODEL[_check_codec(codec)[0]]
