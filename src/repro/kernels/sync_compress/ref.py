"""Pure-jnp oracles for the fused sync-codec kernels — and the *shared rng
derivation* both codec backends draw from.

The fused uplink kernels (:mod:`.kernel`) generate their stochastic-rounding
bits in-register with an explicit threefry2x32 implementation. For the fused
and reference backends to agree to float tolerance, the rounding decisions
must be bit-identical, so the per-element uniform draw is defined HERE, once,
as a deterministic function of ``(leaf key, element index)``:

    bits(i)    = threefry2x32(k0, k1, x0=i, x1=0)[0]
    uniform(i) = bitcast_f32((bits(i) >> 9) | 0x3F800000) - 1.0   ∈ [0, 1)

``repro.ps.compress.StochasticQuantizeCompressor`` (the reference backend)
calls :func:`threefry_uniform`; the Pallas kernel runs the identical uint32
arithmetic on in-kernel counters. Leaf keys come from the engines' usual
``jax.random.split`` chain, so the derivation composes with the existing
per-round / per-worker rng streams unchanged.

The remaining functions are single-leaf references for each kernel primitive,
with the exact expression sequences the kernels emit (f32 math, same
clamping), so parity tests can compare leaf-by-leaf.

Examples
--------
The uniform stream is a pure function of key and index:

>>> import jax, numpy as np
>>> from repro.kernels.sync_compress.ref import threefry_uniform
>>> u = threefry_uniform(jax.random.PRNGKey(7), 4)
>>> bool((u >= 0).all() and (u < 1).all())
True
>>> bool(np.array_equal(u, threefry_uniform(jax.random.PRNGKey(7), 4)))
True
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

_ROTATIONS = ((13, 15, 26, 6), (17, 29, 16, 24))
_PARITY = np.uint32(0x1BD11BDA)
_MANTISSA = np.uint32(0x3F800000)


def threefry2x32(k0, k1, x0, x1):
    """Threefry-2x32 block cipher (20 rounds), the hash behind JAX's default
    PRNG — here as explicit uint32 arithmetic (adds, xors, rotates) so the
    identical expression runs in pure jnp *and* inside a Pallas kernel body.

    All inputs are uint32 scalars/arrays (broadcastable); returns the two
    output words ``(y0, y1)``.
    """
    ks = (jnp.uint32(k0), jnp.uint32(k1),
          (jnp.uint32(k0) ^ jnp.uint32(k1) ^ _PARITY).astype(jnp.uint32))
    x0 = (jnp.uint32(x0) + ks[0]).astype(jnp.uint32)
    x1 = (jnp.uint32(x1) + ks[1]).astype(jnp.uint32)
    for i in range(5):
        for r in _ROTATIONS[i % 2]:
            x0 = (x0 + x1).astype(jnp.uint32)
            x1 = ((x1 << np.uint32(r)) | (x1 >> np.uint32(32 - r))) ^ x0
        x0 = (x0 + ks[(i + 1) % 3]).astype(jnp.uint32)
        x1 = (x1 + ks[(i + 2) % 3] + np.uint32(i + 1)).astype(jnp.uint32)
    return x0, x1


def bits_to_uniform(bits):
    """uint32 bits → f32 uniform in [0, 1): the top 23 bits become the
    mantissa of a float in [1, 2), minus 1."""
    f = jax.lax.bitcast_convert_type(
        (bits >> np.uint32(9)) | _MANTISSA, jnp.float32
    )
    return f - 1.0


def key_data(key) -> jnp.ndarray:
    """Raw uint32 ``(2,)`` words of a PRNG key (accepts new-style typed keys
    and old-style raw arrays alike)."""
    if jnp.issubdtype(jnp.asarray(key).dtype, jax.dtypes.prng_key):
        return jax.random.key_data(key)
    return jnp.asarray(key, jnp.uint32)


def threefry_uniform(key, n: int) -> jnp.ndarray:
    """The shared per-element uniform stream: ``uniform(i)`` for counters
    ``i = 0..n-1`` under leaf key ``key``. This is THE derivation both codec
    backends use for stochastic quantization."""
    kd = key_data(key)
    idx = jnp.arange(n, dtype=jnp.uint32)
    bits, _ = threefry2x32(kd[0], kd[1], idx, jnp.zeros_like(idx))
    return bits_to_uniform(bits)


# ---------------------------------------------------------------------------
# Single-leaf kernel oracles. All take/return flat 1-D leaves; ``w`` is the
# per-worker Line-7 weight (None = no scaling, the async wire format), ``ef``
# the error-feedback residual (None = stateless codec).
# ---------------------------------------------------------------------------

def _eff(z, ef, w):
    """The effective message the codec sees: w·z (+ ef)."""
    out = z if w is None else jnp.float32(w) * z
    return out if ef is None else out + ef


def uplink_stats_ref(z, ef=None, w=None):
    """Reference for the stats pass: ``max|w·z + ef|`` of one leaf (the
    quantizer's scale, before the 1e-30 clamp)."""
    return jnp.max(jnp.abs(_eff(z, ef, w)))


def quantize_uplink_ref(z, key, scale, *, levels: float, ef=None, w=None,
                        alive=None):
    """Reference for the fused quantize-uplink pass: stochastic uniform
    quantization of ``eff = w·z + ef`` to ``levels`` magnitude levels with
    the shared threefry uniforms, plus the residual write-back.

    Returns ``(sent, ef_new)`` — ``ef_new`` is ``eff − sent`` for survivors
    and the frozen ``ef`` for dead workers (``alive`` falsy ⇒ ``sent = 0``).
    """
    eff = _eff(z, ef, w)
    y = jnp.abs(eff) / scale * levels
    lo = jnp.floor(y)
    up = threefry_uniform(key, eff.size) < (y - lo)
    mag = (lo + up.astype(eff.dtype)) * (scale / levels)
    sent = jnp.sign(eff) * mag
    ef_new = eff - sent
    if alive is not None:
        sent = jnp.where(alive, sent, jnp.zeros_like(sent))
        old = jnp.zeros_like(ef_new) if ef is None else ef
        ef_new = jnp.where(alive, eff - sent, old)
    return sent, ef_new


def eff_uplink_ref(z, ef=None, w=None):
    """Reference for the eff pass (top-k pass 1): materialize w·z + ef."""
    return _eff(z, ef, w)


def mask_uplink_ref(eff, mask, *, alive=None, ef=None):
    """Reference for the mask-apply pass (top-k pass 2): keep the masked
    entries of ``eff``, write the complement back as the new residual.

    Returns ``(sent, ef_new)`` with the same aliveness semantics as
    :func:`quantize_uplink_ref`.
    """
    sent = jnp.where(mask != 0, eff, jnp.zeros_like(eff))
    ef_new = eff - sent
    if alive is not None:
        sent = jnp.where(alive, sent, jnp.zeros_like(sent))
        old = jnp.zeros_like(ef_new) if ef is None else ef
        ef_new = jnp.where(alive, eff - sent, old)
    return sent, ef_new


def trimmed_merge_ref(z, w, incl, *, trim, recv=None, old=None):
    """Reference for the fused *robust* server merge on one worker-stacked
    leaf ``(M, n)``: the per-coordinate β-trimmed weighted mean, computed
    with the same sort-free streaming rank expressions the Pallas kernel
    emits (so fused and reference select the identical survivor set).

    Per coordinate ``j``, worker ``i`` gets the stable rank

        rank_i = Σ_k incl_k · [z_kj < z_ij  or  (z_kj = z_ij and k < i)]

    among the included rows (``incl`` — zero-weight/dead lanes are excluded
    from the order statistics entirely); the effective per-side trim is
    ``b = min(trim, ⌊(n_incl − 1)/2⌋)`` so a depleted fleet degrades toward
    the median rather than trimming itself empty, and the output is the
    ``w``-weighted mean of the surviving window ``b ≤ rank ≤ n_incl−1−b``,
    renormalized per coordinate over the survivors' weight mass. ``trim``
    at its maximum ``⌊(M−1)/2⌋`` IS the coordinate median (weighted mean of
    the middle one/two order statistics). ``recv``/``old`` gate delivery
    exactly like :func:`merge_ref`.
    """
    m = z.shape[0]
    zf = z.astype(jnp.float32)
    wf = jnp.asarray(w, jnp.float32)
    inclf = jnp.asarray(incl, jnp.float32)
    n_incl = jnp.sum(inclf)
    b = jnp.minimum(jnp.float32(trim), jnp.floor((n_incl - 1.0) * 0.5))
    row_ids = jnp.arange(m).reshape((m,) + (1,) * (z.ndim - 1))
    rank = jnp.zeros_like(zf)
    for k in range(m):                        # streaming: one row per pass
        zk = zf[k:k + 1]
        less = (zk < zf) | ((zk == zf) & (k < row_ids))
        rank = rank + inclf[k] * less.astype(jnp.float32)
    keep = ((rank >= b) & (rank <= n_incl - 1.0 - b)
            & (inclf.reshape((m,) + (1,) * (z.ndim - 1)) > 0.0))
    wk = (wf.reshape((m,) + (1,) * (z.ndim - 1))
          * keep.astype(jnp.float32))
    denom = jnp.maximum(jnp.sum(wk, axis=0, keepdims=True), 1e-30)
    mean = jnp.sum(wk * zf, axis=0, keepdims=True) / denom
    merged = jnp.broadcast_to(mean, z.shape).astype(z.dtype)
    if recv is None:
        return merged
    keep_rows = recv.reshape((-1,) + (1,) * (z.ndim - 1))
    return jnp.where(keep_rows, merged, z if old is None else old)


def outer_apply_ref(merged, z, mom, t, *, spec):
    """Reference for the fused server *outer-optimizer* pass on one server
    leaf ``(1, n)``: form the round delta ``Δ = merged − z`` and apply one
    moment update + step of the policy in ``spec`` (the static tuples of
    ``repro.ps.server_opt``) — exact expression sequence of the Pallas
    kernel (f32 math, same order of operations).

    ``mom`` is the tuple of moment leaves (1 for momentum/nesterov, 2 for
    adam), ``t`` the f32 round count *before* this step (adam bias
    correction uses ``t + 1``). Returns ``(z_new, mom_new, delta_sq)``
    where ``delta_sq = Σ Δ²`` is this leaf's contribution to ‖Δ‖².
    """
    kind = spec[0]
    g = merged.astype(jnp.float32)
    zz = z.astype(jnp.float32)
    d = g - zz
    if kind == "momentum":
        _, lr, beta = spec
        m_new = jnp.float32(beta) * mom[0].astype(jnp.float32) + d
        z_new = zz + jnp.float32(lr) * m_new
        mom_new = (m_new.astype(mom[0].dtype),)
    elif kind == "nesterov":
        _, lr, beta = spec
        m_new = jnp.float32(beta) * mom[0].astype(jnp.float32) + d
        z_new = zz + jnp.float32(lr) * (d + jnp.float32(beta) * m_new)
        mom_new = (m_new.astype(mom[0].dtype),)
    elif kind == "adam":
        _, lr, b1, b2, eps = spec
        t_new = t + 1.0
        m_new = (jnp.float32(b1) * mom[0].astype(jnp.float32)
                 + jnp.float32(1.0 - b1) * d)
        v_new = (jnp.float32(b2) * mom[1].astype(jnp.float32)
                 + jnp.float32(1.0 - b2) * d * d)
        m_hat = m_new / (1.0 - jnp.float32(b1) ** t_new)
        v_hat = v_new / (1.0 - jnp.float32(b2) ** t_new)
        z_new = zz + jnp.float32(lr) * m_hat / (jnp.sqrt(v_hat)
                                                + jnp.float32(eps))
        mom_new = (m_new.astype(mom[0].dtype), v_new.astype(mom[1].dtype))
    else:
        raise ValueError(f"unknown server-opt spec {spec!r}")
    return z_new.astype(z.dtype), mom_new, jnp.sum(d * d)


def merge_ref(z, w=None, *, normalize=False, recv=None, old=None):
    """Reference for the fused server merge on one worker-stacked leaf
    ``(M, n)``: weighted sum over workers, broadcast back — with the weight
    normalization and the survivor (``recv``) gating fused in.

    ``w`` is ``(M,)`` raw weights (None = unit). ``recv`` (M,) selects which
    rows receive the merge (others keep ``old``).
    """
    if w is None:
        wb = jnp.ones((z.shape[0],), jnp.float32)
    else:
        wb = jnp.asarray(w, jnp.float32)
    if normalize:
        wb = wb / jnp.sum(wb)
    wb = wb.reshape((-1,) + (1,) * (z.ndim - 1)).astype(z.dtype)
    mean = jnp.sum(wb * z, axis=0, keepdims=True)
    merged = jnp.broadcast_to(mean, z.shape)
    if recv is None:
        return merged
    keep = recv.reshape((-1,) + (1,) * (z.ndim - 1))
    return jnp.where(keep, merged, z if old is None else old)
