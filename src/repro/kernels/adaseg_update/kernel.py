"""Fused LocalAdaSEG extragradient-update Pallas kernel.

The optimizer hot loop is memory-bound: the naive implementation reads
z*, M_t, g_t and writes z_t, z̃ plus re-reads both outputs to form the
adaptive-learning-rate statistic (Z_t)² — ≈9 HBM passes over the parameter
vector. This kernel fuses projection, both updates and the (Z_t)² partial
reduction into a single pass: 3 reads + 2 writes, with the reduction
accumulated in VMEM — a ~1.8× cut of optimizer-step HBM traffic.

Layout: parameters are flattened and tiled as (num_blocks, block); grid is
1-D over blocks; η arrives as a (1, 1) scalar tile; per-block (Z_t)²
partials land in a (num_blocks,) output reduced by the caller.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _update_kernel(eta_ref, z_ref, m_ref, g_ref, zt_ref, ztl_ref, acc_ref,
                   *, lo, hi):
    eta = eta_ref[0, 0]
    z = z_ref[...].astype(jnp.float32)                 # update math in f32
    z_t = z - eta * m_ref[...].astype(jnp.float32)
    z_tl = z - eta * g_ref[...].astype(jnp.float32)
    if lo is not None:
        z_t = jnp.clip(z_t, lo, hi)
        z_tl = jnp.clip(z_tl, lo, hi)
    zt_ref[...] = z_t.astype(zt_ref.dtype)
    ztl_ref[...] = z_tl.astype(ztl_ref.dtype)
    d1 = z_t - z
    d2 = z_t - z_tl
    acc_ref[0, 0] = jnp.sum(d1 * d1 + d2 * d2)


def adaseg_update(
    z_star, m_t, g_t, eta, *, lo=None, hi=None, block: int = 4096,
    interpret: bool = False,
):
    """Flat 1-D leaf update. Returns (z_t, z_tilde, zsq_partial_sum)."""
    (n,) = z_star.shape
    pad = (-n) % block
    if pad:
        z_star = jnp.pad(z_star, (0, pad))
        m_t = jnp.pad(m_t, (0, pad))
        g_t = jnp.pad(g_t, (0, pad))
    nb = (n + pad) // block
    shape2 = (nb, block)
    eta_arr = jnp.asarray(eta, jnp.float32).reshape(1, 1)

    kernel = functools.partial(_update_kernel, lo=lo, hi=hi)
    z_t, z_tl, partials = pl.pallas_call(
        kernel,
        grid=(nb,),
        in_specs=[
            pl.BlockSpec((1, 1), lambda i: (0, 0), memory_space=pltpu.SMEM),
            pl.BlockSpec((1, block), lambda i: (i, 0)),
            pl.BlockSpec((1, block), lambda i: (i, 0)),
            pl.BlockSpec((1, block), lambda i: (i, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, block), lambda i: (i, 0)),
            pl.BlockSpec((1, block), lambda i: (i, 0)),
            pl.BlockSpec((1, 1), lambda i: (i, 0), memory_space=pltpu.SMEM),
        ],
        out_shape=[
            jax.ShapeDtypeStruct(shape2, z_star.dtype),
            jax.ShapeDtypeStruct(shape2, z_star.dtype),
            jax.ShapeDtypeStruct((nb, 1), jnp.float32),
        ],
        interpret=interpret,
    )(eta_arr, z_star.reshape(shape2), m_t.reshape(shape2),
      g_t.reshape(shape2))
    return (
        z_t.reshape(-1)[:n],
        z_tl.reshape(-1)[:n],
        jnp.sum(partials),
    )
