"""Fused LocalAdaSEG extragradient-update Pallas kernels.

The optimizer hot loop is memory-bound: the naive implementation reads
z*, M_t, g_t and writes z_t, z̃ plus re-reads both outputs to form the
adaptive-learning-rate statistic (Z_t)² — ≈9 HBM passes over the parameter
vector. The kernels here fuse the learning-rate computation, the projection,
the updates and the (Z_t)² partial reduction so each pass over HBM does all
the element-wise work at once.

Three per-leaf primitives (composed over pytrees by :mod:`.ops`, and into
the optimizer step by ``core.adaseg.local_step(backend="fused")``):

* :func:`adaseg_explore` — exploration half-step z_t = Π(z* − η·M_t) with a
  fused ‖M_t‖² reduction (1 output pass instead of update + norm passes).
* :func:`adaseg_anchor`  — anchor half-step z̃ = Π(z* − η·g_t) that also
  accumulates the (Z_t)² statistic ‖z_t − z*‖² + ‖z_t − z̃‖² and ‖g_t‖²
  in the same pass.
* :func:`adaseg_update`  — the one-shot double update (both M_t and g_t
  known), used by benchmarks and parity tests.

η fusion: instead of materializing η on the host, each kernel can take the
running AdaGrad accumulator Σ(Z_τ)² as its SMEM scalar and compute
η = D·α/√(G₀² + Σ(Z_τ)²) in-register (``sum_sq=...`` instead of ``eta=...``).

Projections: the box clip Π_[lo,hi] fuses into every kernel directly. The
l2-ball projection needs the *global* norm of the candidate point, so it is
a two-pass scheme: pass 1 writes the raw (unprojected) update and reduces
per-block partial squared norms (``want_norm=True`` / :func:`adaseg_raw`),
the caller folds the partials into the scale min(1, r/‖·‖), and pass 2
(:func:`adaseg_finish`) applies the scale while accumulating (Z_t)².

Layout: parameters are flattened and tiled as (num_blocks, block); grid is
1-D over blocks; scalars arrive as SMEM tiles; per-block partial reductions
land in (num_blocks, ·) SMEM outputs reduced by the caller. Partial sums
mask the zero-padding of the last block so a box with lo > 0 cannot leak
clip(0) into the statistic.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _resolve_eta(sched_ref, *, fuse_eta, g0_sq, d_alpha):
    """η from the SMEM schedule scalar: either η itself, or the AdaGrad
    accumulator Σ(Z_τ)² with η = D·α/√(G₀² + Σ) computed in-register."""
    s = sched_ref[0, 0]
    if fuse_eta:
        return d_alpha / jnp.sqrt(g0_sq + s)
    return s


def _pad_mask(n, block):
    """(1, block) validity mask for the current grid block (pad rows are
    zero-filled; only the statistic reductions need masking)."""
    i = pl.program_id(0)
    idx = i * block + jax.lax.broadcasted_iota(jnp.int32, (1, block), 1)
    return idx < n


def _sched_arg(eta, sum_sq):
    """(SMEM scalar, fuse_eta flag) from the mutually-exclusive η inputs."""
    if (eta is None) == (sum_sq is None):
        raise ValueError("pass exactly one of eta= or sum_sq=")
    if sum_sq is not None:
        return jnp.asarray(sum_sq, jnp.float32).reshape(1, 1), True
    return jnp.asarray(eta, jnp.float32).reshape(1, 1), False


def _tile(x, block):
    (n,) = x.shape
    pad = (-n) % block
    if pad:
        x = jnp.pad(x, (0, pad))
    return x.reshape((n + pad) // block, block)


# ---------------------------------------------------------------------------
# Kernel bodies.
# ---------------------------------------------------------------------------

def _explore_kernel(sched_ref, z_ref, m_ref, out_ref, acc_ref, *,
                    lo, hi, fuse_eta, g0_sq, d_alpha, want_norm):
    eta = _resolve_eta(sched_ref, fuse_eta=fuse_eta, g0_sq=g0_sq,
                       d_alpha=d_alpha)
    z = z_ref[...].astype(jnp.float32)                 # update math in f32
    m = m_ref[...].astype(jnp.float32)
    out = z - eta * m
    if lo is not None:
        out = jnp.clip(out, lo, hi)
    out_ref[...] = out.astype(out_ref.dtype)
    # Raw/l2 pass 1: partial ‖out‖² (pad contributes exact zeros).
    acc_ref[0, 0] = jnp.sum(out * out) if want_norm else jnp.float32(0.0)
    acc_ref[0, 1] = jnp.sum(m * m)                     # fused ‖M_t‖² partial


def _anchor_kernel(sched_ref, z_ref, zt_ref, g_ref, ztl_ref, acc_ref, *,
                   lo, hi, fuse_eta, g0_sq, d_alpha, n, block):
    eta = _resolve_eta(sched_ref, fuse_eta=fuse_eta, g0_sq=g0_sq,
                       d_alpha=d_alpha)
    z = z_ref[...].astype(jnp.float32)
    zt = zt_ref[...].astype(jnp.float32)
    g = g_ref[...].astype(jnp.float32)
    ztl = z - eta * g
    if lo is not None:
        ztl = jnp.clip(ztl, lo, hi)
    ztl_ref[...] = ztl.astype(ztl_ref.dtype)
    d1 = zt - z
    d2 = zt - ztl
    stat = jnp.where(_pad_mask(n, block), d1 * d1 + d2 * d2, 0.0)
    acc_ref[0, 0] = jnp.sum(stat)
    acc_ref[0, 1] = jnp.sum(g * g)                     # fused ‖g_t‖² partial


def _finish_kernel(scales_ref, z_ref, zt_raw_ref, ztl_raw_ref,
                   zt_ref, ztl_ref, acc_ref, *, n, block):
    s_t = scales_ref[0, 0]
    s_l = scales_ref[0, 1]
    z = z_ref[...].astype(jnp.float32)
    zt = s_t * zt_raw_ref[...].astype(jnp.float32)
    ztl = s_l * ztl_raw_ref[...].astype(jnp.float32)
    zt_ref[...] = zt.astype(zt_ref.dtype)
    ztl_ref[...] = ztl.astype(ztl_ref.dtype)
    d1 = zt - z
    d2 = zt - ztl
    stat = jnp.where(_pad_mask(n, block), d1 * d1 + d2 * d2, 0.0)
    acc_ref[0, 0] = jnp.sum(stat)


def _update_kernel(sched_ref, z_ref, m_ref, g_ref, zt_ref, ztl_ref, acc_ref,
                   *, lo, hi, fuse_eta, g0_sq, d_alpha, raw_norms, n, block):
    eta = _resolve_eta(sched_ref, fuse_eta=fuse_eta, g0_sq=g0_sq,
                       d_alpha=d_alpha)
    z = z_ref[...].astype(jnp.float32)
    z_t = z - eta * m_ref[...].astype(jnp.float32)
    z_tl = z - eta * g_ref[...].astype(jnp.float32)
    if raw_norms:
        # l2 pass 1: write raw candidates, reduce their squared norms.
        zt_ref[...] = z_t.astype(zt_ref.dtype)
        ztl_ref[...] = z_tl.astype(ztl_ref.dtype)
        acc_ref[0, 0] = jnp.sum(z_t * z_t)
        acc_ref[0, 1] = jnp.sum(z_tl * z_tl)
        return
    if lo is not None:
        z_t = jnp.clip(z_t, lo, hi)
        z_tl = jnp.clip(z_tl, lo, hi)
    zt_ref[...] = z_t.astype(zt_ref.dtype)
    ztl_ref[...] = z_tl.astype(ztl_ref.dtype)
    d1 = z_t - z
    d2 = z_t - z_tl
    stat = jnp.where(_pad_mask(n, block), d1 * d1 + d2 * d2, 0.0)
    acc_ref[0, 0] = jnp.sum(stat)
    acc_ref[0, 1] = jnp.float32(0.0)


# ---------------------------------------------------------------------------
# Per-leaf entry points (flat 1-D vectors; pytree composition in ops.py).
# ---------------------------------------------------------------------------

def _scalar_spec():
    return pl.BlockSpec((1, 1), lambda i: (0, 0), memory_space=pltpu.SMEM)


def _vec_spec(block):
    return pl.BlockSpec((1, block), lambda i: (i, 0))


def _acc_spec(width):
    return pl.BlockSpec((1, width), lambda i: (i, 0), memory_space=pltpu.SMEM)


def adaseg_explore(z_star, m_t, eta=None, *, sum_sq=None, g0=0.0,
                   d_alpha=1.0, lo=None, hi=None, want_norm=False,
                   block: int = 4096, interpret: bool = False):
    """Exploration half-step z_t = Π_box(z* − η·m_t) on a flat leaf.

    Returns ``(z_t, norm_partial, msq_partial)`` — ``norm_partial`` is
    ‖z_t‖² when ``want_norm`` (the l2 two-pass raw mode; pass ``lo=None``),
    else 0; ``msq_partial`` is the fused ‖m_t‖² reduction.
    """
    (n,) = z_star.shape
    sched, fuse = _sched_arg(eta, sum_sq)
    nb = (n + (-n) % block) // block
    kernel = functools.partial(
        _explore_kernel, lo=lo, hi=hi, fuse_eta=fuse, g0_sq=g0 ** 2,
        d_alpha=d_alpha, want_norm=want_norm,
    )
    out, acc = pl.pallas_call(
        kernel,
        grid=(nb,),
        in_specs=[_scalar_spec(), _vec_spec(block), _vec_spec(block)],
        out_specs=[_vec_spec(block), _acc_spec(2)],
        out_shape=[
            jax.ShapeDtypeStruct((nb, block), z_star.dtype),
            jax.ShapeDtypeStruct((nb, 2), jnp.float32),
        ],
        interpret=interpret,
    )(sched, _tile(z_star, block), _tile(m_t, block))
    return out.reshape(-1)[:n], jnp.sum(acc[:, 0]), jnp.sum(acc[:, 1])


def adaseg_anchor(z_star, z_t, g_t, eta=None, *, sum_sq=None, g0=0.0,
                  d_alpha=1.0, lo=None, hi=None, block: int = 4096,
                  interpret: bool = False):
    """Anchor half-step z̃ = Π_box(z* − η·g_t) given the materialized z_t.

    Returns ``(z_tilde, stat_partial, gsq_partial)`` with
    ``stat_partial = ‖z_t − z*‖² + ‖z_t − z̃‖²`` (caller divides by 5η²).
    """
    (n,) = z_star.shape
    sched, fuse = _sched_arg(eta, sum_sq)
    nb = (n + (-n) % block) // block
    kernel = functools.partial(
        _anchor_kernel, lo=lo, hi=hi, fuse_eta=fuse, g0_sq=g0 ** 2,
        d_alpha=d_alpha, n=n, block=block,
    )
    ztl, acc = pl.pallas_call(
        kernel,
        grid=(nb,),
        in_specs=[_scalar_spec(), _vec_spec(block), _vec_spec(block),
                  _vec_spec(block)],
        out_specs=[_vec_spec(block), _acc_spec(2)],
        out_shape=[
            jax.ShapeDtypeStruct((nb, block), z_star.dtype),
            jax.ShapeDtypeStruct((nb, 2), jnp.float32),
        ],
        interpret=interpret,
    )(sched, _tile(z_star, block), _tile(z_t, block), _tile(g_t, block))
    return ztl.reshape(-1)[:n], jnp.sum(acc[:, 0]), jnp.sum(acc[:, 1])


def adaseg_finish(z_star, zt_raw, ztl_raw, scale_t, scale_tl, *,
                  block: int = 4096, interpret: bool = False):
    """l2 pass 2: scale raw candidates onto the ball, fuse the (Z_t)² stat.

    Returns ``(z_t, z_tilde, stat_partial)``.
    """
    (n,) = z_star.shape
    nb = (n + (-n) % block) // block
    scales = jnp.stack([
        jnp.asarray(scale_t, jnp.float32), jnp.asarray(scale_tl, jnp.float32)
    ]).reshape(1, 2)
    kernel = functools.partial(_finish_kernel, n=n, block=block)
    z_t, z_tl, acc = pl.pallas_call(
        kernel,
        grid=(nb,),
        in_specs=[
            pl.BlockSpec((1, 2), lambda i: (0, 0), memory_space=pltpu.SMEM),
            _vec_spec(block), _vec_spec(block), _vec_spec(block),
        ],
        out_specs=[_vec_spec(block), _vec_spec(block), _acc_spec(1)],
        out_shape=[
            jax.ShapeDtypeStruct((nb, block), z_star.dtype),
            jax.ShapeDtypeStruct((nb, block), z_star.dtype),
            jax.ShapeDtypeStruct((nb, 1), jnp.float32),
        ],
        interpret=interpret,
    )(scales, _tile(z_star, block), _tile(zt_raw, block),
      _tile(ztl_raw, block))
    return z_t.reshape(-1)[:n], z_tl.reshape(-1)[:n], jnp.sum(acc)


def adaseg_update(
    z_star, m_t, g_t, eta=None, *, sum_sq=None, g0=0.0, d_alpha=1.0,
    lo=None, hi=None, raw_norms: bool = False, block: int = 4096,
    interpret: bool = False,
):
    """One-shot fused EG double update on a flat leaf (both oracles known).

    Default mode returns ``(z_t, z_tilde, zsq_partial_sum)`` with the box
    clip applied when ``lo``/``hi`` are given. ``raw_norms=True`` is the l2
    two-pass raw mode: no projection, and the partials are
    ``(‖z_t‖², ‖z̃‖²)`` for the caller's ball-scale computation.
    """
    (n,) = z_star.shape
    sched, fuse = _sched_arg(eta, sum_sq)
    nb = (n + (-n) % block) // block
    kernel = functools.partial(
        _update_kernel, lo=lo, hi=hi, fuse_eta=fuse, g0_sq=g0 ** 2,
        d_alpha=d_alpha, raw_norms=raw_norms, n=n, block=block,
    )
    z_t, z_tl, acc = pl.pallas_call(
        kernel,
        grid=(nb,),
        in_specs=[_scalar_spec(), _vec_spec(block), _vec_spec(block),
                  _vec_spec(block)],
        out_specs=[_vec_spec(block), _vec_spec(block), _acc_spec(2)],
        out_shape=[
            jax.ShapeDtypeStruct((nb, block), z_star.dtype),
            jax.ShapeDtypeStruct((nb, block), z_star.dtype),
            jax.ShapeDtypeStruct((nb, 2), jnp.float32),
        ],
        interpret=interpret,
    )(sched, _tile(z_star, block), _tile(m_t, block), _tile(g_t, block))
    z_t = z_t.reshape(-1)[:n]
    z_tl = z_tl.reshape(-1)[:n]
    if raw_norms:
        return z_t, z_tl, (jnp.sum(acc[:, 0]), jnp.sum(acc[:, 1]))
    return z_t, z_tl, jnp.sum(acc[:, 0])
