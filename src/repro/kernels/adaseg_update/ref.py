"""Pure-jnp oracle for the fused LocalAdaSEG extragradient update."""
from __future__ import annotations

import jax.numpy as jnp


def adaseg_update_ref(z_star, m_t, g_t, eta, lo=None, hi=None):
    """Single-leaf fused EG update.

    z_t  = Π(z* − η·m_t);  z̃ = Π(z* − η·g_t);
    zsq_partial = ‖z_t − z*‖² + ‖z_t − z̃‖²   (caller divides by 5η²).

    Returns (z_t, z_tilde, zsq_partial). Π is the box clip when lo/hi given.
    """
    z_t = z_star - eta * m_t
    z_tilde = z_star - eta * g_t
    if lo is not None:
        z_t = jnp.clip(z_t, lo, hi)
        z_tilde = jnp.clip(z_tilde, lo, hi)
    d1 = (z_t - z_star).astype(jnp.float32)
    d2 = (z_t - z_tilde).astype(jnp.float32)
    return z_t, z_tilde, jnp.sum(d1 * d1 + d2 * d2)
