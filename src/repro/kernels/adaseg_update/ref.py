"""Pure-jnp oracles for the fused LocalAdaSEG extragradient kernels.

One reference per kernel primitive in :mod:`.kernel`, with identical
semantics (f32 update math, same partial definitions) so kernel parity
tests can compare leaf-by-leaf.
"""
from __future__ import annotations

import jax.numpy as jnp


def _eta_ref(eta, sum_sq, g0, d_alpha):
    if (eta is None) == (sum_sq is None):
        raise ValueError("pass exactly one of eta= or sum_sq=")
    if sum_sq is not None:
        return d_alpha / jnp.sqrt(g0 ** 2 + jnp.asarray(sum_sq, jnp.float32))
    return eta


def adaseg_update_ref(z_star, m_t, g_t, eta=None, lo=None, hi=None, *,
                      sum_sq=None, g0=0.0, d_alpha=1.0):
    """Single-leaf fused EG update.

    z_t  = Π(z* − η·m_t);  z̃ = Π(z* − η·g_t);
    zsq_partial = ‖z_t − z*‖² + ‖z_t − z̃‖²   (caller divides by 5η²).

    Returns (z_t, z_tilde, zsq_partial). Π is the box clip when lo/hi given.
    η is computed from the AdaGrad accumulator when ``sum_sq`` is given.
    """
    eta = _eta_ref(eta, sum_sq, g0, d_alpha)
    z_t = z_star - eta * m_t
    z_tilde = z_star - eta * g_t
    if lo is not None:
        z_t = jnp.clip(z_t, lo, hi)
        z_tilde = jnp.clip(z_tilde, lo, hi)
    d1 = (z_t - z_star).astype(jnp.float32)
    d2 = (z_t - z_tilde).astype(jnp.float32)
    return z_t, z_tilde, jnp.sum(d1 * d1 + d2 * d2)


def adaseg_explore_ref(z_star, m_t, eta=None, *, sum_sq=None, g0=0.0,
                       d_alpha=1.0, lo=None, hi=None, want_norm=False):
    """Reference for :func:`kernel.adaseg_explore`: (z_t, norm², ‖m‖²)."""
    eta = _eta_ref(eta, sum_sq, g0, d_alpha)
    out = z_star - eta * m_t
    if lo is not None:
        out = jnp.clip(out, lo, hi)
    outf = out.astype(jnp.float32)
    norm = jnp.sum(outf * outf) if want_norm else jnp.float32(0.0)
    mf = m_t.astype(jnp.float32)
    return out, norm, jnp.sum(mf * mf)


def adaseg_anchor_ref(z_star, z_t, g_t, eta=None, *, sum_sq=None, g0=0.0,
                      d_alpha=1.0, lo=None, hi=None):
    """Reference for :func:`kernel.adaseg_anchor`: (z̃, stat, ‖g‖²)."""
    eta = _eta_ref(eta, sum_sq, g0, d_alpha)
    ztl = z_star - eta * g_t
    if lo is not None:
        ztl = jnp.clip(ztl, lo, hi)
    d1 = (z_t - z_star).astype(jnp.float32)
    d2 = (z_t - ztl).astype(jnp.float32)
    gf = g_t.astype(jnp.float32)
    return ztl, jnp.sum(d1 * d1 + d2 * d2), jnp.sum(gf * gf)


def adaseg_finish_ref(z_star, zt_raw, ztl_raw, scale_t, scale_tl):
    """Reference for :func:`kernel.adaseg_finish`: (z_t, z̃, stat)."""
    z_t = (scale_t * zt_raw.astype(jnp.float32)).astype(z_star.dtype)
    ztl = (scale_tl * ztl_raw.astype(jnp.float32)).astype(z_star.dtype)
    d1 = (z_t - z_star).astype(jnp.float32)
    d2 = (z_t - ztl).astype(jnp.float32)
    return z_t, ztl, jnp.sum(d1 * d1 + d2 * d2)
