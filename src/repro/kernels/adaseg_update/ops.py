"""Jit'd pytree-level wrappers for the fused AdaSEG update kernels.

These are the functions the optimizer actually calls
(``core.adaseg.local_step(backend="fused")`` routes through
:func:`adaseg_tree_explore` + :func:`adaseg_tree_anchor`; benchmarks and
parity tests use the one-shot :func:`adaseg_tree_update`). They fall back to
interpret mode automatically off-TPU so the same call site works in CPU
tests and on real hardware, and to pure-jnp references with
``use_kernel=False``.

Projections are passed as a static *spec* rather than a callable so the
kernel can fuse them without a semantics fork:

* ``("identity",)``      — unconstrained;
* ``("box", lo, hi)``    — per-element clip, fused into every kernel pass;
* ``("l2", radius)``     — joint ball projection over the WHOLE pytree
  (the paper's ‖·‖_Z on the product space): a two-pass scheme — pass 1
  writes raw updates and reduces per-block/per-leaf partial squared norms,
  the scale min(1, r/‖·‖) is folded on the host, pass 2 applies it while
  accumulating the (Z_t)² statistic.

η handling mirrors the kernels: pass ``eta=`` directly, or ``sum_sq=`` (the
AdaGrad accumulator Σ(Z_τ)²) plus static ``g0``/``d_alpha`` to fuse
η = D·α/√(G₀² + Σ) into the kernels.

Examples
--------
The one-shot fused double update with η computed in-kernel from the
AdaGrad accumulator, box projection fused:

>>> import jax, jax.numpy as jnp, numpy as np
>>> from repro.kernels.adaseg_update.ops import adaseg_tree_update
>>> z = {"w": jnp.array([0.5, -0.8, 0.2])}
>>> m = jax.tree.map(lambda v: 0.3 * v, z)
>>> g = jax.tree.map(lambda v: 0.1 * v, z)
>>> z_t, z_tl, zsq = adaseg_tree_update(z, m, g, sum_sq=4.0, g0=1.0,
...                                     d_alpha=2.0, lo=-1.0, hi=1.0)
>>> ref = adaseg_tree_update(z, m, g, sum_sq=4.0, g0=1.0, d_alpha=2.0,
...                          lo=-1.0, hi=1.0, use_kernel=False)
>>> bool(np.allclose(z_t["w"], ref[0]["w"], rtol=1e-6))
True
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from .kernel import (
    adaseg_anchor,
    adaseg_explore,
    adaseg_finish,
    adaseg_update,
)
from .ref import (
    adaseg_anchor_ref,
    adaseg_explore_ref,
    adaseg_finish_ref,
    adaseg_update_ref,
)


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def _leaf_block(block, n, interp):
    """Effective block for one flat leaf of size n.

    In interpret mode (off-TPU) the grid is a traced Python loop and VMEM
    limits don't apply, so one block per leaf keeps the kernel a single
    fused sweep; on hardware the requested (VMEM-sized) block stands.
    """
    return max(n, 1) if interp else block


def _kernel_kwargs(use_kernel, block, interp):
    """Per-leaf kernel kwargs factory shared by the tree wrappers."""

    def kkw(z):
        if not use_kernel:
            return {}
        return dict(block=_leaf_block(block, z.size, interp),
                    interpret=interp)

    return kkw


def _norm_proj(proj, lo, hi):
    """Fold legacy lo/hi kwargs into a projection spec (one-sided boxes
    keep the old jnp.clip semantics via ±inf)."""
    if proj is not None:
        if lo is not None or hi is not None:
            raise ValueError("pass either proj= or lo=/hi=, not both")
        if proj[0] not in ("identity", "box", "l2"):
            raise ValueError(f"unknown projection spec {proj!r}")
        return proj
    if lo is not None or hi is not None:
        return ("box",
                float(lo) if lo is not None else float("-inf"),
                float(hi) if hi is not None else float("inf"))
    return ("identity",)


def _box_bounds(spec):
    return (spec[1], spec[2]) if spec[0] == "box" else (None, None)


def _eta_value(eta, sum_sq, g0, d_alpha):
    """Host-side η (for the 1/(5η²) normalization; kernels recompute it)."""
    if sum_sq is not None:
        return d_alpha / jnp.sqrt(g0 ** 2 + jnp.asarray(sum_sq, jnp.float32))
    return jnp.asarray(eta, jnp.float32)


def _ball_scale(radius, norm_sq):
    """Same formula as core.projections.l2_ball for exact parity."""
    norm = jnp.sqrt(norm_sq)
    return jnp.minimum(1.0, radius / jnp.maximum(norm, 1e-30))


def _flatten_with(treedef, leaves_z, *trees):
    out = [leaves_z]
    for t in trees:
        out.append(treedef.flatten_up_to(t))
    return out


_STATIC = ("g0", "d_alpha", "proj", "lo", "hi", "use_kernel", "block")


@functools.partial(jax.jit, static_argnames=_STATIC)
def adaseg_tree_update(z_star, m_t, g_t, eta=None, *, sum_sq=None,
                       g0=0.0, d_alpha=1.0, proj=None, lo=None, hi=None,
                       use_kernel=True, block=4096):
    """Apply the fused EG double update leaf-wise over a parameter pytree.

    Returns (z_t_tree, z_tilde_tree, z_sq) with
    z_sq = Σ_leaves (‖z_t − z*‖² + ‖z_t − z̃‖²) / (5η²).
    """
    spec = _norm_proj(proj, lo, hi)
    leaves_z, treedef = jax.tree.flatten(z_star)
    leaves_z, leaves_m, leaves_g = _flatten_with(treedef, leaves_z, m_t, g_t)
    interp = not _on_tpu()
    eta_val = _eta_value(eta, sum_sq, g0, d_alpha)
    kw = dict(eta=eta, sum_sq=sum_sq, g0=g0, d_alpha=d_alpha)

    if spec[0] != "l2":
        blo, bhi = _box_bounds(spec)
        zs, zts, parts = [], [], []
        for z, m, g in zip(leaves_z, leaves_m, leaves_g):
            shape = z.shape
            if use_kernel:
                z_t, z_tl, part = adaseg_update(
                    z.reshape(-1), m.reshape(-1), g.reshape(-1),
                    lo=blo, hi=bhi, block=_leaf_block(block, z.size, interp),
                    interpret=interp, **kw,
                )
                z_t, z_tl = z_t.reshape(shape), z_tl.reshape(shape)
            else:
                z_t, z_tl, part = adaseg_update_ref(z, m, g, lo=blo, hi=bhi,
                                                    **kw)
            zs.append(z_t)
            zts.append(z_tl)
            parts.append(part)
        stat = sum(parts)
    else:
        radius = spec[1]
        # Pass 1: raw candidates + per-leaf partial squared norms.
        raws, norms_t, norms_l = [], [], []
        for z, m, g in zip(leaves_z, leaves_m, leaves_g):
            if use_kernel:
                zt_raw, ztl_raw, (nt, nl) = adaseg_update(
                    z.reshape(-1), m.reshape(-1), g.reshape(-1),
                    raw_norms=True, block=_leaf_block(block, z.size, interp),
                    interpret=interp, **kw,
                )
            else:
                zt_raw, ztl_raw, _ = adaseg_update_ref(z, m, g, **kw)
                zt_raw, ztl_raw = zt_raw.reshape(-1), ztl_raw.reshape(-1)
                nt = jnp.sum(zt_raw.astype(jnp.float32) ** 2)
                nl = jnp.sum(ztl_raw.astype(jnp.float32) ** 2)
            raws.append((zt_raw, ztl_raw))
            norms_t.append(nt)
            norms_l.append(nl)
        s_t = _ball_scale(radius, sum(norms_t))
        s_l = _ball_scale(radius, sum(norms_l))
        # Pass 2: scale onto the ball, fuse the (Z_t)² statistic.
        zs, zts, parts = [], [], []
        for z, (zt_raw, ztl_raw) in zip(leaves_z, raws):
            shape = z.shape
            if use_kernel:
                z_t, z_tl, part = adaseg_finish(
                    z.reshape(-1), zt_raw, ztl_raw, s_t, s_l,
                    block=_leaf_block(block, z.size, interp),
                    interpret=interp,
                )
            else:
                z_t, z_tl, part = adaseg_finish_ref(
                    z.reshape(-1), zt_raw, ztl_raw, s_t, s_l,
                )
            zs.append(z_t.reshape(shape))
            zts.append(z_tl.reshape(shape))
            parts.append(part)
        stat = sum(parts)

    z_sq = stat / (5.0 * eta_val ** 2)
    return treedef.unflatten(zs), treedef.unflatten(zts), z_sq


@functools.partial(jax.jit, static_argnames=_STATIC)
def adaseg_tree_explore(z_star, m_t, eta=None, *, sum_sq=None, g0=0.0,
                        d_alpha=1.0, proj=None, lo=None, hi=None,
                        use_kernel=True, block=4096):
    """Exploration half-step z_t = Π(z* − η·M_t) over a pytree.

    Returns ``(z_t_tree, m_sq)`` where m_sq = Σ‖M_t‖² (fused into the same
    pass — the V_t(T) diagnostic comes for free).
    """
    spec = _norm_proj(proj, lo, hi)
    leaves_z, treedef = jax.tree.flatten(z_star)
    leaves_z, leaves_m = _flatten_with(treedef, leaves_z, m_t)
    interp = not _on_tpu()
    kw = dict(eta=eta, sum_sq=sum_sq, g0=g0, d_alpha=d_alpha)
    fn = adaseg_explore if use_kernel else adaseg_explore_ref
    kkw = _kernel_kwargs(use_kernel, block, interp)

    if spec[0] != "l2":
        blo, bhi = _box_bounds(spec)
        outs, msqs = [], []
        for z, m in zip(leaves_z, leaves_m):
            shape = z.shape
            out, _, msq = fn(z.reshape(-1), m.reshape(-1), lo=blo, hi=bhi,
                             **kw, **kkw(z))
            outs.append(out.reshape(shape))
            msqs.append(msq)
        return treedef.unflatten(outs), sum(msqs)

    radius = spec[1]
    raws, norms, msqs = [], [], []
    for z, m in zip(leaves_z, leaves_m):
        out, nrm, msq = fn(z.reshape(-1), m.reshape(-1), want_norm=True,
                           **kw, **kkw(z))
        raws.append(out)
        norms.append(nrm)
        msqs.append(msq)
    scale = _ball_scale(radius, sum(norms))
    outs = [
        (scale * r.astype(jnp.float32)).astype(z.dtype).reshape(z.shape)
        for z, r in zip(leaves_z, raws)
    ]
    return treedef.unflatten(outs), sum(msqs)


@functools.partial(jax.jit, static_argnames=_STATIC)
def adaseg_tree_anchor(z_star, z_t, g_t, eta=None, *, sum_sq=None, g0=0.0,
                       d_alpha=1.0, proj=None, lo=None, hi=None,
                       use_kernel=True, block=4096):
    """Anchor half-step z̃ = Π(z* − η·g_t) over a pytree, given z_t.

    Returns ``(z_tilde_tree, stat, g_sq)`` with
    stat = Σ_leaves ‖z_t − z*‖² + ‖z_t − z̃‖² (caller divides by 5η²) and
    g_sq = Σ‖g_t‖² fused into the same pass.
    """
    spec = _norm_proj(proj, lo, hi)
    leaves_z, treedef = jax.tree.flatten(z_star)
    leaves_z, leaves_t, leaves_g = _flatten_with(treedef, leaves_z, z_t, g_t)
    interp = not _on_tpu()
    kw = dict(eta=eta, sum_sq=sum_sq, g0=g0, d_alpha=d_alpha)

    if spec[0] != "l2":
        blo, bhi = _box_bounds(spec)
        outs, stats, gsqs = [], [], []
        for z, zt, g in zip(leaves_z, leaves_t, leaves_g):
            shape = z.shape
            if use_kernel:
                ztl, stat, gsq = adaseg_anchor(
                    z.reshape(-1), zt.reshape(-1), g.reshape(-1),
                    lo=blo, hi=bhi, block=_leaf_block(block, z.size, interp),
                    interpret=interp, **kw,
                )
                ztl = ztl.reshape(shape)
            else:
                ztl, stat, gsq = adaseg_anchor_ref(z, zt, g, lo=blo, hi=bhi,
                                                   **kw)
            outs.append(ztl)
            stats.append(stat)
            gsqs.append(gsq)
        return treedef.unflatten(outs), sum(stats), sum(gsqs)

    radius = spec[1]
    fn = adaseg_explore if use_kernel else adaseg_explore_ref
    kkw = _kernel_kwargs(use_kernel, block, interp)

    # Pass 1: raw z̃ candidate (an explore with g_t) + partial norms.
    raws, norms, gsqs = [], [], []
    for z, g in zip(leaves_z, leaves_g):
        raw, nrm, gsq = fn(z.reshape(-1), g.reshape(-1), want_norm=True,
                           **kw, **kkw(z))
        raws.append(raw)
        norms.append(nrm)
        gsqs.append(gsq)
    s_l = _ball_scale(radius, sum(norms))
    # Pass 2: scale z̃ onto the ball; z_t is already final (scale 1).
    outs, stats = [], []
    for z, zt, raw in zip(leaves_z, leaves_t, raws):
        shape = z.shape
        if use_kernel:
            _, ztl, stat = adaseg_finish(
                z.reshape(-1), zt.reshape(-1), raw, 1.0, s_l,
                block=_leaf_block(block, z.size, interp), interpret=interp,
            )
        else:
            _, ztl, stat = adaseg_finish_ref(
                z.reshape(-1), zt.reshape(-1), raw, 1.0, s_l,
            )
        outs.append(ztl.reshape(shape))
        stats.append(stat)
    return treedef.unflatten(outs), sum(stats), sum(gsqs)
