"""Jit'd pytree-level wrapper for the fused AdaSEG update kernel.

Falls back to interpret mode automatically off-TPU so the same call site
works in CPU tests and on real hardware.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from .kernel import adaseg_update
from .ref import adaseg_update_ref


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


@functools.partial(jax.jit, static_argnames=("lo", "hi", "use_kernel"))
def adaseg_tree_update(z_star, m_t, g_t, eta, *, lo=None, hi=None,
                       use_kernel=True):
    """Apply the fused EG double update leaf-wise over a parameter pytree.

    Returns (z_t_tree, z_tilde_tree, z_sq) with
    z_sq = Σ_leaves (‖z_t − z*‖² + ‖z_t − z̃‖²) / (5η²).
    """
    leaves_z, treedef = jax.tree.flatten(z_star)
    leaves_m = treedef.flatten_up_to(m_t)
    leaves_g = treedef.flatten_up_to(g_t)

    zs, zts, parts = [], [], []
    for z, m, g in zip(leaves_z, leaves_m, leaves_g):
        shape = z.shape
        if use_kernel:
            z_t, z_tl, part = adaseg_update(
                z.reshape(-1), m.reshape(-1), g.reshape(-1), eta,
                lo=lo, hi=hi, interpret=not _on_tpu(),
            )
            z_t, z_tl = z_t.reshape(shape), z_tl.reshape(shape)
        else:
            z_t, z_tl, part = adaseg_update_ref(z, m, g, eta, lo=lo, hi=hi)
        zs.append(z_t)
        zts.append(z_tl)
        parts.append(part)

    z_sq = sum(parts) / (5.0 * jnp.asarray(eta, jnp.float32) ** 2)
    return treedef.unflatten(zs), treedef.unflatten(zts), z_sq
