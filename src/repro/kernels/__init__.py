"""Pallas TPU kernels for the framework's compute hot-spots.

* ``flash_attention`` — blockwise online-softmax attention (causal / sliding
  window / softcap / GQA), VMEM-tiled via BlockSpec.
* ``adaseg_update``  — fused LocalAdaSEG extragradient double-update +
  (Z_t)² reduction, one HBM pass instead of ~9.
* ``ssd_scan``       — Mamba2 SSD chunked scan (intra-chunk MXU matmuls +
  inter-chunk recurrence over summary states).

Each kernel ships ``kernel.py`` (pl.pallas_call + BlockSpec), ``ops.py``
(jit'd wrapper, CPU interpret fallback) and ``ref.py`` (pure-jnp oracle).
"""
