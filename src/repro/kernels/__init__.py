"""Pallas TPU kernels for the framework's compute hot-spots.

* ``flash_attention`` — blockwise online-softmax attention (causal / sliding
  window / softcap / GQA), VMEM-tiled via BlockSpec.
* ``adaseg_update``  — fused LocalAdaSEG extragradient update kernels
  (explore/anchor/one-shot): η-from-Σ(Z_τ)² computed in-register, box clip
  or two-pass l2-ball projection, and the (Z_t)²/‖G‖² reductions fused into
  the update passes. This is the production step path — selected by
  ``core.adaseg.local_step(backend="fused")``.
* ``sync_compress``  — fused Parameter-Server sync codecs: the Line-5/7
  uplink (error-feedback add + 1/η weighting + stochastic quantize with
  in-kernel threefry bits / top-k masking + residual write-back) and the
  server-side weighted merge, one HBM sweep each where the reference path
  takes ~5 tree passes. Selected by ``codec_backend="fused"`` on
  ``repro.ps`` engine configs.
* ``ssd_scan``       — Mamba2 SSD chunked scan (intra-chunk MXU matmuls +
  inter-chunk recurrence over summary states).

Each kernel ships ``kernel.py`` (pl.pallas_call + BlockSpec), ``ops.py``
(jit'd wrapper, CPU interpret fallback) and ``ref.py`` (pure-jnp oracle).
"""
