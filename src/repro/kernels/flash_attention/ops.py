"""Jit'd wrapper for the flash-attention kernel with CPU interpret fallback
and automatic sequence padding to the block size.

Examples
--------
Causal attention agrees with the pure-jnp reference:

>>> import jax, jax.numpy as jnp, numpy as np
>>> from repro.kernels.flash_attention.ops import attention
>>> from repro.kernels.flash_attention.ref import attention_ref
>>> q = k = v = jax.random.normal(jax.random.PRNGKey(0), (1, 2, 16, 8))
>>> out = attention(q, k, v, causal=True, block_q=16, block_k=16)
>>> bool(np.allclose(out, attention_ref(q, k, v, causal=True), atol=1e-5))
True
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from .kernel import flash_attention
from .ref import attention_ref


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


@functools.partial(
    jax.jit,
    static_argnames=("causal", "window", "softcap", "scale", "block_q",
                     "block_k", "use_kernel"),
)
def attention(q, k, v, *, causal=True, window=None, softcap=None, scale=None,
              block_q=512, block_k=512, use_kernel=True):
    """(B, H, S, D) x (B, Kh, T, D) attention; pads S/T up to block multiples."""
    if not use_kernel:
        return attention_ref(
            q, k, v, causal=causal, window=window, softcap=softcap, scale=scale
        )
    b, h, s, d = q.shape
    t = k.shape[2]
    bq, bk = min(block_q, s), min(block_k, t)
    pad_q = (-s) % bq
    pad_k = (-t) % bk
    if pad_q:
        q = jnp.pad(q, ((0, 0), (0, 0), (0, pad_q), (0, 0)))
    if pad_k:
        k = jnp.pad(k, ((0, 0), (0, 0), (0, pad_k), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, 0), (0, pad_k), (0, 0)))
    # padded K slots sit at positions > every real query → masked by causal;
    # for non-causal the window/mask below would need explicit lengths, so we
    # only allow padding in the causal path.
    assert causal or (pad_q == 0 and pad_k == 0)
    out = flash_attention(
        q, k, v, causal=causal, window=window, softcap=softcap, scale=scale,
        block_q=bq, block_k=bk, interpret=not _on_tpu(),
    )
    return out[:, :, :s, :]
