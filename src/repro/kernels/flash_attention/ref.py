"""Pure-jnp oracle for the flash-attention kernel.

Materializes the full (S, T) logit matrix — O(S·T) memory, numerically exact
reference for correctness sweeps.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def attention_ref(
    q, k, v, *, causal=True, window=None, softcap=None, scale=None
):
    """q: (B, H, S, D); k, v: (B, Kh, T, D) with H % Kh == 0 (GQA).

    Returns (B, H, S, D). Softmax in f32.
    """
    b, h, s, d = q.shape
    kh, t = k.shape[1], k.shape[2]
    g = h // kh
    scale = scale if scale is not None else d**-0.5

    qg = q.reshape(b, kh, g, s, d)
    logits = jnp.einsum("bkgsd,bktd->bkgst", qg, k).astype(jnp.float32) * scale
    if softcap is not None:
        logits = softcap * jnp.tanh(logits / softcap)
    qi = jnp.arange(s)[:, None]
    ki = jnp.arange(t)[None, :]
    mask = jnp.ones((s, t), bool)
    if causal:
        mask &= ki <= qi
    if window is not None:
        mask &= ki > qi - window
    logits = jnp.where(mask, logits, NEG_INF)
    probs = jax.nn.softmax(logits, axis=-1).astype(q.dtype)
    out = jnp.einsum("bkgst,bktd->bkgsd", probs, v)
    return out.reshape(b, h, s, d)
