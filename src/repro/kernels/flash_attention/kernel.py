"""Pallas TPU flash-attention kernel (blockwise online softmax).

Grid layout: ``(batch·heads, num_q_blocks, num_kv_blocks)`` with the KV axis
innermost and sequential ("arbitrary" dimension semantics): the running max
``m``, normalizer ``l`` and output accumulator live in VMEM scratch and are
carried across KV iterations; the normalized output tile is written once on
the final KV step. Q/K/V tiles are (block_q × head_dim) / (block_k ×
head_dim) VMEM blocks — the working set is
``(block_q + 2·block_k)·head_dim·4B + block_q·block_k·4B``, well under VMEM
for the default 512/512 blocking at head_dim ≤ 256.

Supports causal masking, sliding windows (gemma2/mixtral/recurrentgemma
local layers), gemma2 logit soft-capping, and GQA via an index map that
folds query-head groups onto shared KV heads. Fully-masked KV blocks are
skipped with ``pl.when`` — for causal attention that halves the work, and
for sliding windows it reduces it to O(S·W).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from ..compat import tpu_compiler_params

NEG_INF = -1e30


def _flash_kernel(
    q_ref, k_ref, v_ref, o_ref,
    m_scr, l_scr, acc_scr,
    *, scale, causal, window, softcap, block_q, block_k, num_kv_blocks,
):
    iq = pl.program_id(1)
    ik = pl.program_id(2)

    @pl.when(ik == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q_start = iq * block_q
    k_start = ik * block_k

    # Block-level reachability: skip KV tiles that are fully masked.
    reachable = True
    if causal:
        reachable = k_start <= q_start + block_q - 1
    if window is not None:
        # newest query in the block can reach back at most `window`
        reachable = jnp.logical_and(
            reachable, k_start + block_k - 1 > q_start - window
        )

    @pl.when(reachable)
    def _compute():
        q = q_ref[0, ...].astype(jnp.float32)          # (bq, d)
        k = k_ref[0, ...].astype(jnp.float32)          # (bk, d)
        v = v_ref[0, ...].astype(jnp.float32)          # (bk, d)

        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
        ) * scale                                       # (bq, bk)
        if softcap is not None:
            s = softcap * jnp.tanh(s / softcap)

        qi = q_start + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 0)
        ki = k_start + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 1)
        mask = jnp.ones((block_q, block_k), jnp.bool_)
        if causal:
            mask &= ki <= qi
        if window is not None:
            mask &= ki > qi - window
        s = jnp.where(mask, s, NEG_INF)

        m_prev = m_scr[...]                             # (bq,)
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1))
        alpha = jnp.exp(m_prev - m_new)
        p = jnp.exp(s - m_new[:, None])
        p = jnp.where(mask, p, 0.0)                     # guard exp(NEG_INF-…)
        l_scr[...] = l_scr[...] * alpha + jnp.sum(p, axis=-1)
        acc_scr[...] = acc_scr[...] * alpha[:, None] + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
        )
        m_scr[...] = m_new

    @pl.when(ik == num_kv_blocks - 1)
    def _finalize():
        l = jnp.maximum(l_scr[...], 1e-30)
        o_ref[0, ...] = (acc_scr[...] / l[:, None]).astype(o_ref.dtype)


def flash_attention(
    q, k, v,
    *,
    causal: bool = True,
    window: int | None = None,
    softcap: float | None = None,
    scale: float | None = None,
    block_q: int = 512,
    block_k: int = 512,
    interpret: bool = False,
):
    """q: (B, H, S, D); k, v: (B, Kh, T, D). Returns (B, H, S, D)."""
    b, h, s, d = q.shape
    kh, t = k.shape[1], k.shape[2]
    assert h % kh == 0, (h, kh)
    g = h // kh
    scale = scale if scale is not None else d**-0.5
    block_q = min(block_q, s)
    block_k = min(block_k, t)
    assert s % block_q == 0 and t % block_k == 0, (s, t, block_q, block_k)
    nq, nk = s // block_q, t // block_k

    qf = q.reshape(b * h, s, d)
    kf = k.reshape(b * kh, t, d)
    vf = v.reshape(b * kh, t, d)

    kernel = functools.partial(
        _flash_kernel,
        scale=scale,
        causal=causal,
        window=window,
        softcap=softcap,
        block_q=block_q,
        block_k=block_k,
        num_kv_blocks=nk,
    )

    out = pl.pallas_call(
        kernel,
        grid=(b * h, nq, nk),
        in_specs=[
            pl.BlockSpec((1, block_q, d), lambda bh, iq, ik: (bh, iq, 0)),
            pl.BlockSpec((1, block_k, d), lambda bh, iq, ik: (bh // g, ik, 0)),
            pl.BlockSpec((1, block_k, d), lambda bh, iq, ik: (bh // g, ik, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_q, d), lambda bh, iq, ik: (bh, iq, 0)),
        out_shape=jax.ShapeDtypeStruct((b * h, s, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q,), jnp.float32),
            pltpu.VMEM((block_q,), jnp.float32),
            pltpu.VMEM((block_q, d), jnp.float32),
        ],
        compiler_params=tpu_compiler_params(
            dimension_semantics=("parallel", "parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(qf, kf, vf)
    return out.reshape(b, h, s, d)
