"""PartitionSpec utilities: worker-axis stacking, FSDP augmentation, and
mesh-divisibility sanitation.

Placement model (see DESIGN.md §5):

* ``model`` axis — tensor parallel (attention heads / d_ff / experts / vocab),
  encoded in each module's ``(params, specs)`` pair.
* worker axes — LocalAdaSEG's per-worker parameter copies: every param leaf
  gains a leading axis of size M sharded over the worker axes
  (paper-faithful: ``("pod", "data")``; hierarchical: ``("pod",)``).
* ``data`` axis — batch sharding; in hierarchical mode additionally FSDP:
  each param's first model-free divisible dim is sharded over ``data``.
"""
from __future__ import annotations

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding
from jax.sharding import PartitionSpec as P


def abstract_mesh(shape: tuple[int, ...], axes: tuple[str, ...]):
    """Device-free ``AbstractMesh`` across the JAX constructor API change.

    JAX ≤ 0.4.x takes a single ``shape_tuple`` of ``(name, size)`` pairs;
    0.5+ takes ``(axis_sizes, axis_names)``. Spec logic only ever reads
    ``mesh.shape`` / ``mesh.axis_names``, which both forms provide.
    """
    from jax.sharding import AbstractMesh

    try:
        return AbstractMesh(shape, axes)
    except TypeError:
        return AbstractMesh(tuple(zip(axes, shape)))


def _axis_size(mesh: Mesh, name) -> int:
    if name is None:
        return 1
    if isinstance(name, (tuple, list)):
        return int(np.prod([_axis_size(mesh, n) for n in name]))
    return mesh.shape[name]


def stack_spec(spec: P, worker_axes: tuple[str, ...]) -> P:
    """Prepend the worker axis: leaf (…,) → (M, …)."""
    lead = worker_axes if len(worker_axes) > 1 else (worker_axes[0] if worker_axes else None)
    return P(lead, *spec)


def fsdp_spec(spec: P, shape: tuple[int, ...], mesh: Mesh,
              axis: str = "data") -> P:
    """Add ``axis`` to the first dim that is unsharded and divisible.

    Only touches leaves with ≥ 2 dims (norm scales etc. stay replicated —
    gathering them is cheaper than the bookkeeping).
    """
    if len(shape) < 2:
        return spec
    size = _axis_size(mesh, axis)
    entries = list(spec) + [None] * (len(shape) - len(spec))
    for i, (e, d) in enumerate(zip(entries, shape)):
        if e is None and d % size == 0 and d >= size:
            entries[i] = axis
            return P(*entries)
    return spec


def sanitize_spec(spec: P, shape: tuple[int, ...], mesh: Mesh) -> P:
    """Drop axis names whose mesh size does not divide the dim size.

    GSPMD tolerates uneven sharding via padding, but padded KV-head shards
    waste memory and produce misleading memory analyses — we replicate
    instead and let the hillclimb phase re-place them deliberately.
    """
    entries = list(spec) + [None] * (len(shape) - len(spec))
    out = []
    for e, d in zip(entries, shape):
        if e is None:
            out.append(None)
            continue
        names = e if isinstance(e, (tuple, list)) else (e,)
        kept = [n for n in names if d % _axis_size(mesh, n) == 0]
        # partial keeps must still divide jointly
        while kept and d % int(np.prod([_axis_size(mesh, n) for n in kept])):
            kept.pop()
        out.append(tuple(kept) if len(kept) > 1 else (kept[0] if kept else None))
    return P(*out)


def repair_axis(spec: P, shape: tuple[int, ...], mesh: Mesh,
                axis: str = "model", *, skip_dims: tuple[int, ...] = ()) -> P:
    """If ``axis`` was dropped everywhere by sanitation, re-place it on the
    largest divisible free dim (e.g. MoE expert dim 8 < 16-way model axis →
    shard d_ff instead: tensor-parallel within expert)."""
    if any(
        (e == axis or (isinstance(e, tuple) and axis in e)) for e in spec
    ):
        return spec
    size = _axis_size(mesh, axis)
    entries = list(spec) + [None] * (len(shape) - len(spec))
    best = None
    for i in range(len(shape)):
        if i in skip_dims or entries[i] is not None:
            continue
        if shape[i] % size == 0 and shape[i] >= size:
            if best is None or shape[i] > shape[best]:
                best = i
    if best is not None:
        entries[best] = axis
    return P(*entries)


def build_param_shardings(
    params, specs, mesh: Mesh, *, worker_axes: tuple[str, ...] = (),
    fsdp: bool = False, repair_model: bool = False,
):
    """Materialize NamedShardings for a (stacked) parameter tree.

    ``params`` may be abstract (ShapeDtypeStruct) — only shapes are read.
    When ``worker_axes`` is non-empty the params are expected to carry the
    leading worker axis already. ``repair_model=True`` re-places a dropped
    'model' axis on the largest divisible dim (§Perf lever).
    """
    n_skip = (1 + len(worker_axes[1:])) if worker_axes else 0

    def one(leaf, spec):
        shape = leaf.shape
        base_shape = shape[1:] if worker_axes else shape
        s = spec
        if fsdp:
            s = fsdp_spec(s, base_shape, mesh)
        if worker_axes:
            s = stack_spec(s, worker_axes)
        s = sanitize_spec(s, shape, mesh)
        if repair_model and len(base_shape) >= 2:
            skip = (0,) if worker_axes else ()
            s = repair_axis(s, shape, mesh, "model", skip_dims=skip)
            s = sanitize_spec(s, shape, mesh)
        return NamedSharding(mesh, s)

    return jax.tree.map(one, params, specs)


def abstract_like(params, *, stacked: int | None = None, dtype=None):
    """ShapeDtypeStruct pytree mirroring ``params`` (optionally worker-stacked)."""

    def one(leaf):
        shape = (stacked, *leaf.shape) if stacked else leaf.shape
        return jax.ShapeDtypeStruct(shape, dtype or leaf.dtype)

    return jax.tree.map(one, params)
