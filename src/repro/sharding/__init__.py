"""Sharding rules and PartitionSpec utilities."""
from .specs import (
    abstract_like,
    build_param_shardings,
    fsdp_spec,
    sanitize_spec,
    stack_spec,
)
