"""The LocalWorker protocol: one contract for every optimizer the PS runs.

The Parameter-Server engine (``repro.ps.engine``) owns rounds, schedules,
compression, faults, checkpointing and telemetry; a :class:`LocalWorker`
owns everything optimizer-specific inside a round. The split lets the whole
zoo of §4/Fig. 4 (LocalSGDA, LocalSEGDA, Local Adam, the MB-* adaptive
mirror-prox family) run on the exact same production runtime as LocalAdaSEG
— heterogeneous K_m^r, quantized uplinks, worker failures, bit-exact resume
— instead of through a second feature-poor driver stack.

The contract (all methods are pure JAX functions):

* ``init(problem, rng, worker_id)`` — one worker's initial state. The state
  must be a pytree of arrays (NamedTuple/dict) so the engine can vmap it
  over a stacked worker axis, shard it with ``shard_map``, flatten it for
  the compression/telemetry byte accounting, and round-trip it through
  ``checkpoint.serialize`` leaf-by-leaf.
* ``step(problem, state, rng, enabled=...)`` — one local step. ``enabled``
  (bool scalar or None) masks the update: a disabled worker must return its
  state unchanged — the mechanism behind heterogeneous per-round step
  counts K_m^r and fault masking.
* ``sync_weight(state)`` — scalar weight of this worker in the Line-7
  server average. LocalAdaSEG returns 1/η (the paper's inverse-stepsize
  weighting); plain optimizers return 1 (uniform FedAvg weighting).
* ``sync_payload(state)`` / ``merge_synced(state, payload)`` — which part
  of the state is averaged by the server (the anchor z̃ for AdaSEG, the
  iterate z for the zoo) and how the averaged value is installed. Both must
  be *structural* (attribute access / ``_replace``) so the same code works
  on a per-worker state, a vmap-stacked state and a per-shard state.
* ``output(state)`` — the per-worker output iterate (the running average
  z̄); the engine combines these with realized-step-count weights into the
  Line-14 global output.
* ``eta(state)`` — scalar step size, telemetry only (η spread per round).
* ``derive_rngs(rng, num_workers)`` — how the top-level key splits into
  (round stream base, per-worker init keys). This is part of the protocol
  so the engine can reproduce each optimizer family's *pre-existing* rng
  stream bit-exactly: AdaSEG uses ``split(rng, M+1)`` (the historical
  ``run_local_adaseg`` derivation), the zoo uses the historical
  ``run_local`` pair-split. Everything downstream (per-round step keys,
  sync keys) is derived identically by the engine for all workers.
* ``flatten_state`` / ``unflatten_state`` — explicit pytree boundary used
  by checkpointing and byte accounting; the defaults defer to
  ``jax.tree`` and almost never need overriding.

``fingerprint`` hashes ``name`` (which should encode the hyper-parameters)
so the engine can refuse to restore a checkpoint written by a different
optimizer the same way it refuses a different seed.
"""
from __future__ import annotations

import dataclasses
import zlib
from typing import Any

import jax
import jax.numpy as jnp

from .adaseg import AdaSEGConfig, eta_of, init as adaseg_init, local_step
from .types import MinimaxProblem

PyTree = Any


class LocalWorker:
    """Base protocol; subclasses fill in the optimizer-specific pieces.

    Examples
    --------
    Any worker drives the same engine hooks — init, masked step, sync
    weight/payload, output:

    >>> import jax
    >>> from repro.core import AdaSEGConfig
    >>> from repro.problems import make_bilinear_game
    >>> game = make_bilinear_game(jax.random.PRNGKey(0), n=4, sigma=0.1)
    >>> worker = AdaSEGWorker(AdaSEGConfig(g0=1.0, diameter=2.0, k=2))
    >>> st = worker.init(game.problem, jax.random.PRNGKey(1))
    >>> st2 = worker.step(game.problem, st, jax.random.PRNGKey(2))
    >>> int(st2.t), float(worker.sync_weight(st)) > 0
    (1, True)
    >>> frozen = worker.step(game.problem, st, jax.random.PRNGKey(2),
    ...                      enabled=False)
    >>> int(frozen.t)                 # masked step is a structural no-op
    0
    """

    name: str = "worker"

    # -- required ----------------------------------------------------------

    def init(self, problem: MinimaxProblem, rng, worker_id=0) -> PyTree:
        raise NotImplementedError

    def step(self, problem: MinimaxProblem, state: PyTree, rng, *,
             enabled=None) -> PyTree:
        raise NotImplementedError

    def sync_payload(self, state: PyTree) -> PyTree:
        raise NotImplementedError

    def merge_synced(self, state: PyTree, payload: PyTree) -> PyTree:
        raise NotImplementedError

    def output(self, state: PyTree) -> PyTree:
        raise NotImplementedError

    # -- defaults ----------------------------------------------------------

    def sync_weight(self, state: PyTree) -> jax.Array:
        return jnp.float32(1.0)

    def eta(self, state: PyTree) -> jax.Array:
        return 1.0 / self.sync_weight(state)

    def derive_rngs(self, rng, num_workers: int):
        """(rng, M) -> (round-stream base key, (M, 2) per-worker init keys).
        Default: the historical ``optim.base.run_local`` derivation."""
        rng0, sub = jax.random.split(jnp.asarray(rng))
        return rng0, jax.random.split(sub, num_workers)

    def flatten_state(self, state: PyTree):
        return jax.tree.flatten(state)

    def unflatten_state(self, treedef, leaves) -> PyTree:
        return jax.tree.unflatten(treedef, leaves)

    @property
    def fingerprint(self) -> int:
        """uint32 identity hash, stored in checkpoints so a restore with a
        different optimizer (or hyper-parameters) is rejected."""
        return zlib.crc32(self.name.encode())


@dataclasses.dataclass(frozen=True)
class AdaSEGWorker(LocalWorker):
    """LocalAdaSEG as a LocalWorker — the paper's Algorithm 1.

    Wraps ``core.adaseg`` verbatim: the same ``local_step`` (with the
    ``"reference" | "fused"`` Pallas backend passing through), 1/η sync
    weights, the anchor z̃ as sync payload, and the historical
    ``run_local_adaseg`` rng derivation — so the engine with this worker,
    identity compression, no faults and a uniform schedule stays
    **bit-exact** with the one-shot serial driver.

    Examples
    --------
    >>> import jax
    >>> from repro.core import AdaSEGConfig
    >>> w = AdaSEGWorker(AdaSEGConfig(g0=1.0, diameter=2.0, k=3),
    ...                  backend="fused")
    >>> w.name
    'adaseg(g0=1.0,D=2.0,alpha=1.0,avg=True)'
    >>> w.fingerprint == AdaSEGWorker(
    ...     AdaSEGConfig(g0=1.0, diameter=2.0, k=3)).fingerprint
    True
    >>> w.fingerprint != AdaSEGWorker(
    ...     AdaSEGConfig(g0=2.0, diameter=2.0, k=3)).fingerprint
    True
    """

    cfg: AdaSEGConfig
    backend: str = "reference"

    @property
    def name(self) -> str:
        c = self.cfg
        return (f"adaseg(g0={c.g0},D={c.diameter},alpha={c.alpha},"
                f"avg={c.average_output})")

    def init(self, problem, rng, worker_id=0):
        return adaseg_init(problem, self.cfg, rng, worker_id)

    def step(self, problem, state, rng, *, enabled=None):
        new, _ = local_step(problem, self.cfg, state, rng, enabled=enabled,
                            backend=self.backend)
        return new

    def sync_weight(self, state):
        return 1.0 / eta_of(self.cfg, state.sum_sq)

    def eta(self, state):
        return eta_of(self.cfg, state.sum_sq)

    def sync_payload(self, state):
        return state.z_tilde

    def merge_synced(self, state, payload):
        return state._replace(z_tilde=payload)

    def output(self, state):
        return state.z_bar

    def derive_rngs(self, rng, num_workers: int):
        # bit-identical to core.adaseg.run_local_adaseg
        init_rngs = jax.random.split(jnp.asarray(rng), num_workers + 1)
        return init_rngs[0], init_rngs[1:]
