"""Core library: the paper's contribution (LocalAdaSEG) and its substrate."""
from .adaseg import (
    AdaSEGConfig,
    AdaSEGState,
    StepAux,
    eta_of,
    init,
    local_step,
    make_psum_sync,
    run_local_adaseg,
    sync_state,
    sync_weighted_stacked,
    weighted_worker_average,
)
from .metrics import kkt_residual
from .types import MinimaxProblem, from_loss
from .worker import AdaSEGWorker, LocalWorker
from . import projections, tree

__all__ = [
    "AdaSEGConfig",
    "AdaSEGState",
    "AdaSEGWorker",
    "LocalWorker",
    "StepAux",
    "MinimaxProblem",
    "eta_of",
    "from_loss",
    "init",
    "kkt_residual",
    "local_step",
    "make_psum_sync",
    "projections",
    "run_local_adaseg",
    "sync_state",
    "sync_weighted_stacked",
    "tree",
    "weighted_worker_average",
]
