"""Pytree vector-space helpers used by every optimizer in the framework.

All minimax state (the joint primal-dual iterate ``z = (x, y)``) is a pytree;
these helpers implement the (Euclidean) vector-space operations the paper's
analysis is written in: addition, scaling, inner products and the squared
norm ``‖z‖² = ‖x‖² + ‖y‖²`` used in the adaptive learning-rate recursion.
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

PyTree = Any


def tree_add(a: PyTree, b: PyTree) -> PyTree:
    return jax.tree.map(jnp.add, a, b)


def tree_sub(a: PyTree, b: PyTree) -> PyTree:
    return jax.tree.map(jnp.subtract, a, b)


def tree_scale(c, a: PyTree) -> PyTree:
    return jax.tree.map(lambda v: c * v, a)


def tree_axpy(c, a: PyTree, b: PyTree) -> PyTree:
    """c * a + b."""
    return jax.tree.map(lambda u, v: c * u + v, a, b)


def tree_dot(a: PyTree, b: PyTree) -> jax.Array:
    leaves = jax.tree.map(
        lambda u, v: jnp.vdot(u.astype(jnp.float32), v.astype(jnp.float32)), a, b
    )
    return jax.tree.reduce(jnp.add, leaves, jnp.float32(0.0))


def tree_norm_sq(a: PyTree) -> jax.Array:
    leaves = jax.tree.map(lambda u: jnp.sum(jnp.square(u.astype(jnp.float32))), a)
    return jax.tree.reduce(jnp.add, leaves, jnp.float32(0.0))


def tree_norm(a: PyTree) -> jax.Array:
    return jnp.sqrt(tree_norm_sq(a))


def tree_zeros_like(a: PyTree) -> PyTree:
    return jax.tree.map(jnp.zeros_like, a)


def tree_cast(a: PyTree, dtype) -> PyTree:
    return jax.tree.map(lambda v: v.astype(dtype), a)


def tree_size(a: PyTree) -> int:
    return sum(v.size for v in jax.tree.leaves(a))


def tree_where(pred, a: PyTree, b: PyTree) -> PyTree:
    return jax.tree.map(lambda u, v: jnp.where(pred, u, v), a, b)
