"""Solution-quality metrics for minimax problems.

* KKT residual (the paper's Res(x, y), §4.1): ``‖z − Π_Z(z − G(z))‖`` with the
  *mean* operator G — zero iff z is a saddle point.
* Duality gap is problem-specific (needs inner max/min); problems that admit a
  closed form (bilinear over a box) provide their own ``duality_gap``.
"""
from __future__ import annotations

import jax.numpy as jnp

from .tree import tree_axpy, tree_norm_sq, tree_sub
from .types import MinimaxProblem


def kkt_residual(problem: MinimaxProblem, z) -> jnp.ndarray:
    if problem.mean_oracle is None:
        raise ValueError(f"problem {problem.name!r} has no mean_oracle")
    g = problem.mean_oracle(z, None)
    z_step = problem.project(tree_axpy(-1.0, g, z))
    return jnp.sqrt(tree_norm_sq(tree_sub(z, z_step)))
