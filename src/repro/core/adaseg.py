"""LocalAdaSEG — Algorithm 1 of the paper, as composable JAX functions.

Per-worker state and the three ingredients of the method:

1.  Extragradient double update from the (possibly synced) anchor z̃*:
        z_t  = Π_Z[z̃* − η_t · G(z̃*, ξ₁)]          (exploration step)
        z̃_t = Π_Z[z̃* − η_t · G(z_t, ξ₂)]          (anchor update)

2.  AdaGrad-type local learning rate (Line 4):
        η_t = D·α / sqrt(G₀² + Σ_{τ<t} (Z_τ)²),
        (Z_t)² = (‖z_t − z̃*_{t−1}‖² + ‖z_t − z̃_t‖²) / (5 η_t²)

3.  Inverse-stepsize weighted periodic averaging (Line 7):
        w_t^m ∝ 1/η_t^m,  z̃° = Σ_m w_t^m z̃_{t−1}^m        every K steps.

The sync is abstracted as ``sync_fn(z_tilde, inv_eta) -> z̃°`` so that the same
step code runs in three harnesses:
  * serial/vmap over a leading worker axis (CPU experiments, tests),
  * ``shard_map`` with ``lax.psum`` over mesh worker axes (production —
    see ``launch.sharded.run_local_adaseg_sharded``),
  * single worker (degenerates to the serial AdaSEG of Bach & Levy '19).

The configurable production runtime (schedules, compression, faults,
checkpoint/resume) is ``repro.ps.PSEngine``, which consumes this module
through ``core.worker.AdaSEGWorker`` — the LocalWorker-protocol face of
Algorithm 1 — and stays bit-exact with :func:`run_local_adaseg` in the
identity configuration.

Step backends
-------------
The inner extragradient update is pluggable (``backend=`` on
:func:`local_step` / :func:`run_local_adaseg`):

* ``"reference"`` — naive pytree ops (this module): ~9 HBM passes over the
  parameter vector per step; always available, always correct.
* ``"fused"``     — the Pallas kernels in ``kernels.adaseg_update``: the
  η computation, projection, both updates and the (Z_t)²/‖G‖² reductions
  fuse into an exploration pass + an anchor pass (interpret mode off-TPU).
  Selected whenever the problem's projection carries a static spec
  (``projections.spec_of`` — identity/box/l2-ball, which covers the
  paper's BilinearGame and WGAN problems); opaque projections silently
  fall back to the reference math so semantics never fork.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, NamedTuple

import jax
import jax.numpy as jnp
from jax import lax

from . import projections
from .tree import (
    tree_axpy,
    tree_norm_sq,
    tree_scale,
    tree_sub,
    tree_where,
    tree_zeros_like,
)
from .types import MinimaxProblem, draw

PyTree = Any
SyncFn = Callable[[PyTree, jax.Array], PyTree]


@dataclasses.dataclass(frozen=True)
class AdaSEGConfig:
    """Hyper-parameters of LocalAdaSEG(G0, D; K, M, R; alpha)."""

    g0: float          # initial guess of the gradient bound G
    diameter: float    # D, diameter bound of Z (Assumption 1)
    alpha: float = 1.0  # base lr: 1.0 nonsmooth (Thm 1), 1/sqrt(M) smooth (Thm 2)
    k: int = 1         # communication interval K
    average_output: bool = True  # return uniform iterate average (convex-concave)


class AdaSEGState(NamedTuple):
    """Per-worker state. In multi-worker harnesses every leaf gains a leading
    worker axis (vmap) or is the per-shard value (shard_map)."""

    z_tilde: PyTree       # z̃_t  — the anchor iterate
    sum_sq: jax.Array     # Σ_τ (Z_τ)²  (f32 scalar)
    t: jax.Array          # local step counter (int32)
    z_bar: PyTree         # running uniform average of {z_τ}  (output iterate)
    grad_sq_sum: jax.Array  # Σ_τ ‖g_τ‖² + ‖M_τ‖²  — the V_t(T) diagnostic (Fig E1d)
    worker_id: jax.Array  # int32 — used by heterogeneous samplers


class StepAux(NamedTuple):
    eta: jax.Array
    z_sq: jax.Array       # (Z_t)² increment
    grad_norm_sq: jax.Array


def eta_of(cfg: AdaSEGConfig, sum_sq: jax.Array) -> jax.Array:
    return cfg.diameter * cfg.alpha / jnp.sqrt(cfg.g0 ** 2 + sum_sq)


def init(problem: MinimaxProblem, cfg: AdaSEGConfig, rng,
         worker_id=0) -> AdaSEGState:
    z0 = problem.project(problem.init(rng))
    return AdaSEGState(
        z_tilde=z0,
        sum_sq=jnp.float32(0.0),
        t=jnp.int32(0),
        z_bar=tree_zeros_like(z0),
        grad_sq_sum=jnp.float32(0.0),
        worker_id=jnp.int32(worker_id),
    )


def local_step(
    problem: MinimaxProblem,
    cfg: AdaSEGConfig,
    state: AdaSEGState,
    rng,
    *,
    enabled=None,
    backend: str = "reference",
) -> tuple[AdaSEGState, StepAux]:
    """One extragradient step from the current anchor ``state.z_tilde``.

    ``enabled`` (bool scalar, optional) masks the update — used by the
    asynchronous variant where workers run heterogeneous K_m local steps per
    round (Appendix E.1): disabled workers keep their state unchanged.

    ``backend`` selects the update implementation (see module docstring):
    ``"reference"`` runs naive tree ops; ``"fused"`` routes through the
    Pallas extragradient kernels when ``problem.project`` carries a static
    projection spec, and falls back to the reference math otherwise.
    """
    if backend not in ("reference", "fused"):
        raise ValueError(f"unknown step backend {backend!r}")
    spec = projections.spec_of(problem.project) if backend == "fused" else None

    r1, r2 = jax.random.split(rng)
    eta = eta_of(cfg, state.sum_sq)
    z_star = state.z_tilde
    m_t = problem.oracle(z_star, draw(problem, r1, state.worker_id))  # M_t

    if spec is not None:
        # Fused path: η recomputed in-kernel from Σ(Z_τ)², projection and
        # the (Z_t)²/‖G‖² reductions fused into the two update passes.
        from ..kernels.adaseg_update.ops import (
            adaseg_tree_anchor,
            adaseg_tree_explore,
        )

        d_alpha = cfg.diameter * cfg.alpha
        z_t, m_sq = adaseg_tree_explore(
            z_star, m_t, sum_sq=state.sum_sq, g0=cfg.g0, d_alpha=d_alpha,
            proj=spec,
        )
        g_t = problem.oracle(z_t, draw(problem, r2, state.worker_id))  # g_t
        z_tilde_new, stat, g_sq = adaseg_tree_anchor(
            z_star, z_t, g_t, sum_sq=state.sum_sq, g0=cfg.g0,
            d_alpha=d_alpha, proj=spec,
        )
        z_sq = stat / (5.0 * eta ** 2)
        grad_norm_sq = g_sq + m_sq
    else:
        z_t = problem.project(tree_axpy(-eta, m_t, z_star))
        g_t = problem.oracle(z_t, draw(problem, r2, state.worker_id))  # g_t
        z_tilde_new = problem.project(tree_axpy(-eta, g_t, z_star))

        z_sq = (
            tree_norm_sq(tree_sub(z_t, z_star))
            + tree_norm_sq(tree_sub(z_t, z_tilde_new))
        ) / (5.0 * eta ** 2)
        grad_norm_sq = tree_norm_sq(g_t) + tree_norm_sq(m_t)

    t_new = state.t + 1
    # Incremental uniform mean of the exploration iterates z_t (Line 14).
    if cfg.average_output:
        z_bar_new = jax.tree.map(
            lambda zb, zt: zb + (zt - zb) / t_new.astype(zt.dtype),
            state.z_bar,
            z_t,
        )
    else:
        z_bar_new = z_t

    new = AdaSEGState(
        z_tilde=z_tilde_new,
        sum_sq=state.sum_sq + z_sq,
        t=t_new,
        z_bar=z_bar_new,
        grad_sq_sum=state.grad_sq_sum + grad_norm_sq,
        worker_id=state.worker_id,
    )
    if enabled is not None:
        new = AdaSEGState(
            z_tilde=tree_where(enabled, new.z_tilde, state.z_tilde),
            sum_sq=jnp.where(enabled, new.sum_sq, state.sum_sq),
            t=jnp.where(enabled, new.t, state.t),
            z_bar=tree_where(enabled, new.z_bar, state.z_bar),
            grad_sq_sum=jnp.where(enabled, new.grad_sq_sum, state.grad_sq_sum),
            worker_id=state.worker_id,
        )
    aux = StepAux(eta=eta, z_sq=z_sq, grad_norm_sq=grad_norm_sq)
    return new, aux


# ---------------------------------------------------------------------------
# Sync functions (Line 7): serial (stacked worker axis) and psum (shard_map).
# ---------------------------------------------------------------------------

def sync_weighted_stacked(z_tilde: PyTree, inv_eta: jax.Array, *,
                          backend: str = "reference",
                          server=None, srv=None):
    """Weighted average over a leading worker axis; returns the average
    broadcast back to every worker (axis preserved).

    ``backend="fused"`` routes through the Pallas server-merge kernel
    (``kernels.sync_compress.ops.sync_merge_stacked``): the 1/η weight
    normalization, the weighted sum over workers and the broadcast back run
    as one read + one write of the stacked fleet payload per leaf, instead
    of the scale/sum/broadcast tree passes here.

    ``server``/``srv`` compose the server-side outer optimizer
    (:mod:`repro.ps.server_opt`) downstream of the merge: the Line-7
    weighted mean becomes the pseudo-gradient Δ against the server anchor
    ``srv = (z, moments, t)``, the outer update runs (fused under
    ``backend="fused"``), and the *post-step* anchor is broadcast instead
    of the raw mean. The return value then grows to
    ``(synced, srv_new, telem)`` with ``telem = [eff_lr, ‖Δ‖]``; both
    ``None`` (the default) keeps the historical single-pytree return.
    """
    if server is not None:
        from ..kernels.sync_compress.ops import (
            server_outer_apply,
            sync_merge_stacked,
        )

        merged = sync_merge_stacked(
            z_tilde, inv_eta, normalize=True,
            use_kernel=backend == "fused",
        )
        z, mom, t = srv
        merged_row = jax.tree.map(lambda v: v[:1], merged)
        z_new, mom_new, t_new, eff_lr, dn = server_outer_apply(
            merged_row, z, mom, t, spec=server.spec,
            use_kernel=backend == "fused",
        )
        synced = jax.tree.map(
            lambda v, old: jnp.broadcast_to(v, old.shape), z_new, z_tilde
        )
        return synced, (z_new, mom_new, t_new), jnp.stack([eff_lr, dn])
    if backend == "fused":
        from ..kernels.sync_compress.ops import sync_merge_stacked

        return sync_merge_stacked(z_tilde, inv_eta, normalize=True)
    if backend != "reference":
        raise ValueError(f"unknown sync backend {backend!r}")
    w = inv_eta / jnp.sum(inv_eta)                      # (M,) simplex weights

    def avg(leaf):
        wb = w.reshape((-1,) + (1,) * (leaf.ndim - 1)).astype(leaf.dtype)
        mean = jnp.sum(wb * leaf, axis=0, keepdims=True)
        return jnp.broadcast_to(mean, leaf.shape)

    return jax.tree.map(avg, z_tilde)


def make_psum_sync(axis_names: tuple[str, ...]) -> SyncFn:
    """Weighted average across mesh worker axes, for use inside shard_map.

    The parameter-server's gather + weighted-average + broadcast collapses to
    a single all-reduce of w·z̃ (plus a scalar all-reduce for the normalizer).
    """

    def sync(z_tilde: PyTree, inv_eta: jax.Array) -> PyTree:
        denom = lax.psum(inv_eta, axis_names)
        w = inv_eta / denom
        return jax.tree.map(
            lambda v: lax.psum((w.astype(v.dtype)) * v, axis_names), z_tilde
        )

    return sync


def sync_state(state: AdaSEGState, cfg: AdaSEGConfig, sync_fn: SyncFn) -> AdaSEGState:
    """Apply Line 5–8: replace every worker's anchor with the weighted average."""
    inv_eta = 1.0 / eta_of(cfg, state.sum_sq)
    return state._replace(z_tilde=sync_fn(state.z_tilde, inv_eta))


def weighted_worker_average(z_stacked: PyTree, counts: jax.Array) -> PyTree:
    """Line 14 global output: average a leading worker axis with weights
    ∝ per-worker step counts (uniform over all z_t^m). Shared by the serial
    driver and the PS engine so both compute the identical expression."""
    w = counts.astype(jnp.float32) / jnp.sum(counts.astype(jnp.float32))

    def avg(leaf):
        wb = w.reshape((-1,) + (1,) * (leaf.ndim - 1)).astype(leaf.dtype)
        return jnp.sum(wb * leaf, axis=0)

    return jax.tree.map(avg, z_stacked)


# ---------------------------------------------------------------------------
# Serial multi-worker driver (vmap over workers) — used by the paper-
# experiment benchmarks and tests. Communication = weighted mean over axis 0.
# ---------------------------------------------------------------------------

def run_local_adaseg(
    problem: MinimaxProblem,
    cfg: AdaSEGConfig,
    *,
    num_workers: int,
    rounds: int,
    rng,
    local_steps: jax.Array | None = None,
    collect_aux: bool = True,
    backend: str = "reference",
):
    """Run LocalAdaSEG with M stacked workers for R rounds of K local steps.

    ``local_steps`` (int array of shape (M,), optional) gives heterogeneous
    per-worker step counts K_m for the asynchronous variant; by default every
    worker runs cfg.k steps per round. ``backend`` selects the step
    implementation (``"reference"`` tree ops or the ``"fused"`` Pallas
    kernels — see module docstring). Returns ``(z_bar, history)`` where
    z_bar is the global output iterate (Line 14) and history holds per-step
    diagnostics stacked as (R, K, M).
    """
    m = num_workers
    k = int(cfg.k)
    if local_steps is None:
        local_steps = jnp.full((m,), k, dtype=jnp.int32)
    else:
        local_steps = jnp.asarray(local_steps, dtype=jnp.int32)
        k = int(jnp.max(local_steps))

    init_rngs = jax.random.split(rng, m + 1)
    rng, worker_rngs = init_rngs[0], init_rngs[1:]
    state = jax.vmap(lambda r, w: init(problem, cfg, r, w))(
        worker_rngs, jnp.arange(m, dtype=jnp.int32)
    )

    vstep = jax.vmap(
        lambda st, r, en: local_step(problem, cfg, st, r, enabled=en,
                                     backend=backend)
    )

    def round_fn(state: AdaSEGState, rng_round):
        # Line 5–8: weighted sync at the top of each round (t-1 ∈ S).
        inv_eta = 1.0 / eta_of(cfg, state.sum_sq)  # (M,)
        state = state._replace(
            z_tilde=sync_weighted_stacked(state.z_tilde, inv_eta)
        )
        step_rngs = jax.random.split(rng_round, k * m).reshape(k, m, 2)

        def body(st, inputs):
            rngs, i = inputs
            enabled = i < local_steps  # (M,) mask for async variant
            st, aux = vstep(st, rngs, enabled)
            return st, aux

        state, aux = lax.scan(body, state, (step_rngs, jnp.arange(k)))
        return state, aux

    round_rngs = jax.random.split(rng, rounds)
    state, history = lax.scan(round_fn, state, round_rngs)

    # Global output: average worker means weighted by their step counts
    # (uniform over all z_t^m as in Line 14).
    counts = local_steps.astype(jnp.float32) * rounds
    z_bar = weighted_worker_average(state.z_bar, counts)
    return z_bar, (state, history if collect_aux else None)
