"""Euclidean projections Π_Z for the constraint sets used in the paper.

The paper's experiments use the box C^n = [-1, 1]^n (bilinear game); the
theory only needs a compact convex Z with diameter bound D (Assumption 1).
We provide boxes, l2 balls, the probability simplex (for the robust-logistic
example's dual block), and combinators to apply different projections to the
primal and dual blocks of ``z = (x, y)``.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def spec_of(proj_fn):
    """Static description of a projection, or None when opaque.

    The fused Pallas step backend (``core.adaseg.local_step(backend="fused")``)
    uses this to fuse the projection into the update kernel: ``("identity",)``,
    ``("box", lo, hi)`` and ``("l2", radius)`` are recognized; projections
    without a spec (simplex, product combinators, hand-written callables)
    make the fused backend fall back to reference tree-op semantics.
    """
    return getattr(proj_fn, "spec", None)


def identity():
    def proj(z):
        return z

    proj.spec = ("identity",)
    return proj


def box(lo: float = -1.0, hi: float = 1.0):
    def proj(z):
        return jax.tree.map(lambda v: jnp.clip(v, lo, hi), z)

    proj.spec = ("box", float(lo), float(hi))
    return proj


def l2_ball(radius: float = 1.0):
    """Project every leaf jointly onto the l2 ball of the given radius.

    Treats the whole pytree as one flattened vector (this matches the paper's
    ‖z‖_Z norm on the product space).
    """
    from .tree import tree_norm, tree_scale

    def proj(z):
        n = tree_norm(z)
        scale = jnp.minimum(1.0, radius / jnp.maximum(n, 1e-30))
        return tree_scale(scale, z)

    proj.spec = ("l2", float(radius))
    return proj


def simplex():
    """Project each leaf (vector) onto the probability simplex.

    Standard sort-based algorithm (Held/Wolfe/Crowder); O(n log n), jittable.
    """

    def _proj_vec(v):
        n = v.shape[-1]
        u = jnp.sort(v, axis=-1)[..., ::-1]
        css = jnp.cumsum(u, axis=-1) - 1.0
        idx = jnp.arange(1, n + 1, dtype=v.dtype)
        cond = u - css / idx > 0
        rho = jnp.sum(cond, axis=-1, keepdims=True)  # number of positive terms
        # gather css at rho-1
        theta = jnp.take_along_axis(css, rho - 1, axis=-1) / rho.astype(v.dtype)
        return jnp.maximum(v - theta, 0.0)

    def proj(z):
        return jax.tree.map(_proj_vec, z)

    return proj


def product(proj_x, proj_y):
    """Apply proj_x to the primal block and proj_y to the dual block."""

    def proj(z):
        x, y = z
        return (proj_x(x), proj_y(y))

    return proj
