"""Problem abstraction for stochastic minimax optimization.

A :class:`MinimaxProblem` packages everything LocalAdaSEG (and the baseline
optimizers) need about problem (1) of the paper:

    min_{x ∈ X} max_{y ∈ Y}  F(x, y) = E_ξ f(x, y, ξ)

* ``init(rng)``     — an initial joint iterate ``z₀ = (x₀, y₀)`` (pytree pair).
* ``sample(rng)``   — draw ξ (a pytree of arrays; for finite-sum problems a
                      minibatch of data).
* ``oracle(z, ξ)``  — the stochastic gradient field
                      ``G(z, ξ) = [∂x f(x,y,ξ), −∂y f(x,y,ξ)]`` — i.e. a
                      *descent* direction for both blocks, so every update is
                      ``z ← Π_Z(z − η·G)``.
* ``project(z)``    — Euclidean projection Π_Z onto the constraint set
                      (identity for unconstrained problems).

Minimization-only problems (LM training) use an empty ``y`` block; the same
machinery applies verbatim.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable

PyTree = Any
OracleFn = Callable[[PyTree, PyTree], PyTree]          # (z, xi) -> G(z, xi)
SampleFn = Callable[[Any], PyTree]                     # rng -> xi
ProjectFn = Callable[[PyTree], PyTree]                 # z -> Pi_Z(z)
InitFn = Callable[[Any], PyTree]                       # rng -> z0


@dataclasses.dataclass(frozen=True)
class MinimaxProblem:
    init: InitFn
    sample: SampleFn
    oracle: OracleFn
    project: ProjectFn
    # Optional exact operator E[G(z, xi)] when available (bilinear, quadratic);
    # used by metrics and by deterministic tests.
    mean_oracle: OracleFn | None = None
    # Human-readable name (shows up in benchmark CSVs).
    name: str = "problem"
    # Optional heterogeneous sampler: (rng, worker_id) -> xi. When set, the
    # distributed drivers use it so each worker draws from its own local
    # distribution (the paper's federated/Dirichlet setting, §4.2/E.2).
    sample_worker: Any = None


def draw(problem: "MinimaxProblem", rng, worker_id=None):
    if problem.sample_worker is not None and worker_id is not None:
        return problem.sample_worker(rng, worker_id)
    return problem.sample(rng)


def from_loss(loss_fn, init, sample, project=None, name="problem"):
    """Build a MinimaxProblem from a scalar saddle loss f((x, y), xi).

    The oracle is [∇x f, −∇y f] computed with one jax.grad call over the
    joint pytree, then sign-flipping the dual block.
    """
    import jax

    from . import projections

    def oracle(z, xi):
        gx, gy = jax.grad(lambda zz: loss_fn(zz, xi))(z)
        return (gx, jax.tree.map(lambda v: -v, gy))

    if project is None:
        project = projections.identity()
    return MinimaxProblem(
        init=init, sample=sample, oracle=oracle, project=project, name=name
    )
