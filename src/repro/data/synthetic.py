"""Deterministic synthetic data pipeline.

Offline container: token streams are generated from a counter-based PRNG so
every worker/step batch is reproducible, shardable and allocation-free to
*describe* (the dry-run uses the ShapeDtypeStructs from :func:`input_specs`).

The generator is not uniform noise: tokens follow a power-law unigram over
the vocab with a first-order Markov mixing term, so cross-entropy training
has signal (loss decreases measurably within a few hundred steps) and MoE
routers see a non-degenerate distribution.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from ..configs.base import ArchConfig


def _zipf_logits(vocab: int, alpha: float = 1.2):
    ranks = jnp.arange(1, vocab + 1, dtype=jnp.float32)
    return -alpha * jnp.log(ranks)


def sample_tokens(rng, batch: int, seq: int, vocab: int) -> jax.Array:
    """(batch, seq+1) token ids: zipf unigram + deterministic Markov shift."""
    r1, r2 = jax.random.split(rng)
    base = jax.random.categorical(
        r1, _zipf_logits(vocab), shape=(batch, seq + 1)
    )
    # Markov structure: with p=0.3 the next token is prev+1 (mod vocab)
    rep = jax.random.bernoulli(r2, 0.3, (batch, seq + 1))
    shifted = jnp.roll(base, 1, axis=1) + 1
    return jnp.where(rep, shifted % vocab, base).astype(jnp.int32)


def make_batch(rng, cfg: ArchConfig, batch: int, seq: int) -> dict:
    toks = sample_tokens(rng, batch, seq, cfg.vocab_size)
    out = {"tokens": toks[:, :-1], "labels": toks[:, 1:]}
    if cfg.encoder_seq:
        out["frontend"] = 0.02 * jax.random.normal(
            jax.random.fold_in(rng, 1),
            (batch, cfg.encoder_seq, cfg.d_model),
            dtype=jnp.dtype(cfg.compute_dtype),
        )
    return out


# ---------------------------------------------------------------------------
# Dirichlet heterogeneity helpers — the federated/Parameter-Server data layer
# (``repro.ps.partition``) carves per-worker oracles with these.
# ---------------------------------------------------------------------------

def dirichlet_proportions(rng, num_workers: int, num_groups: int,
                          alpha: float) -> jax.Array:
    """(num_workers, num_groups) rows on the simplex, p_m ~ Dir(alpha·1).

    ``alpha → 0`` gives near-disjoint group ownership (maximal heterogeneity),
    ``alpha → ∞`` recovers the uniform/homogeneous split — the standard
    federated-learning skew knob (Hsu et al. '19).
    """
    return jax.random.dirichlet(
        rng, alpha * jnp.ones(num_groups), (num_workers,)
    )


def group_sampling_logits(proportions: jax.Array, group_of: jax.Array,
                          eps: float = 1e-8) -> jax.Array:
    """Per-worker categorical logits over items from per-group proportions.

    ``proportions`` is (M, G) Dirichlet rows, ``group_of`` maps each of the
    n items to its group; the result is (M, n) logits such that worker m
    draws item i with probability ∝ p_m[group_of[i]] — a soft Dirichlet
    partition that keeps every per-worker sampler jittable (no ragged index
    sets)."""
    p_items = proportions[:, group_of]                     # (M, n)
    p_items = p_items / jnp.sum(p_items, axis=1, keepdims=True)
    return jnp.log(p_items + eps)


def quantile_groups(values: jax.Array, num_groups: int) -> jax.Array:
    """Assign each entry of ``values`` to one of ``num_groups`` equal-mass
    quantile bins (int32). Used to carve feature-space groups for problems
    without natural labels."""
    n = values.shape[0]
    ranks = jnp.argsort(jnp.argsort(values))
    return (ranks * num_groups // n).astype(jnp.int32)


def batch_struct(cfg: ArchConfig, lead: tuple[int, ...], batch: int, seq: int,
                 dtype=None) -> dict:
    """ShapeDtypeStruct batch description with optional leading dims
    (local-steps × oracle-calls × workers for the LocalAdaSEG round)."""
    tok = jax.ShapeDtypeStruct((*lead, batch, seq), jnp.int32)
    out = {"tokens": tok, "labels": tok}
    if cfg.encoder_seq:
        out["frontend"] = jax.ShapeDtypeStruct(
            (*lead, batch, cfg.encoder_seq, cfg.d_model),
            dtype or jnp.dtype(cfg.compute_dtype),
        )
    return out
