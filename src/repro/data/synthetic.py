"""Deterministic synthetic data pipeline.

Offline container: token streams are generated from a counter-based PRNG so
every worker/step batch is reproducible, shardable and allocation-free to
*describe* (the dry-run uses the ShapeDtypeStructs from :func:`input_specs`).

The generator is not uniform noise: tokens follow a power-law unigram over
the vocab with a first-order Markov mixing term, so cross-entropy training
has signal (loss decreases measurably within a few hundred steps) and MoE
routers see a non-degenerate distribution.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from ..configs.base import ArchConfig


def _zipf_logits(vocab: int, alpha: float = 1.2):
    ranks = jnp.arange(1, vocab + 1, dtype=jnp.float32)
    return -alpha * jnp.log(ranks)


def sample_tokens(rng, batch: int, seq: int, vocab: int) -> jax.Array:
    """(batch, seq+1) token ids: zipf unigram + deterministic Markov shift."""
    r1, r2 = jax.random.split(rng)
    base = jax.random.categorical(
        r1, _zipf_logits(vocab), shape=(batch, seq + 1)
    )
    # Markov structure: with p=0.3 the next token is prev+1 (mod vocab)
    rep = jax.random.bernoulli(r2, 0.3, (batch, seq + 1))
    shifted = jnp.roll(base, 1, axis=1) + 1
    return jnp.where(rep, shifted % vocab, base).astype(jnp.int32)


def make_batch(rng, cfg: ArchConfig, batch: int, seq: int) -> dict:
    toks = sample_tokens(rng, batch, seq, cfg.vocab_size)
    out = {"tokens": toks[:, :-1], "labels": toks[:, 1:]}
    if cfg.encoder_seq:
        out["frontend"] = 0.02 * jax.random.normal(
            jax.random.fold_in(rng, 1),
            (batch, cfg.encoder_seq, cfg.d_model),
            dtype=jnp.dtype(cfg.compute_dtype),
        )
    return out


def batch_struct(cfg: ArchConfig, lead: tuple[int, ...], batch: int, seq: int,
                 dtype=None) -> dict:
    """ShapeDtypeStruct batch description with optional leading dims
    (local-steps × oracle-calls × workers for the LocalAdaSEG round)."""
    tok = jax.ShapeDtypeStruct((*lead, batch, seq), jnp.int32)
    out = {"tokens": tok, "labels": tok}
    if cfg.encoder_seq:
        out["frontend"] = jax.ShapeDtypeStruct(
            (*lead, batch, cfg.encoder_seq, cfg.d_model),
            dtype or jnp.dtype(cfg.compute_dtype),
        )
    return out
