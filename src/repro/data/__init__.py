"""Deterministic synthetic data pipelines."""
from .synthetic import (
    batch_struct,
    dirichlet_proportions,
    group_sampling_logits,
    make_batch,
    quantile_groups,
    sample_tokens,
)
