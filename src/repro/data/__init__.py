"""Deterministic synthetic data pipelines."""
from .synthetic import batch_struct, make_batch, sample_tokens
