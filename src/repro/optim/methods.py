"""The optimizer zoo: every baseline the paper compares against (§4, Fig. 4).

All methods act on the descent field G(z, ξ) = [∂x f, −∂y f]:

* :func:`sgda`   — (stochastic) simultaneous gradient descent-ascent
                   [LocalSGDA base, Deng & Mahdavi '21].
* :func:`segda`  — stochastic extragradient (Korpelevich / Nemirovski's
                   mirror-prox, Euclidean) with constant lr
                   [MB-SEGDA / LocalSEGDA base].
* :func:`adam_minimax` — Adam applied per-coordinate to G
                   [Local Adam base, Beznosikov et al. '21].
* :func:`ump`    — Universal Mirror-Prox, the serial adaptive EG of
                   Bach & Levy '19 (what LocalAdaSEG runs locally)
                   [MB-UMP].
* :func:`asmp`   — Adaptive Single-gradient Mirror-Prox, the optimistic /
                   past-gradient variant of Ene & Nguyen '20: one oracle call
                   per iteration [MB-ASMP].
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from ..core.tree import tree_axpy, tree_norm_sq, tree_sub, tree_zeros_like
from ..core.types import MinimaxProblem, draw
from .base import MinimaxOptimizer, OptState, base_init, update_mean

PyTree = Any


def sgda(lr: float) -> MinimaxOptimizer:
    def step(problem: MinimaxProblem, state: OptState, rng) -> OptState:
        g = problem.oracle(state.z, draw(problem, rng, state.worker_id))
        z_new = problem.project(tree_axpy(-lr, g, state.z))
        t_new = state.t + 1
        return OptState(
            z=z_new,
            z_bar=update_mean(state.z_bar, z_new, t_new),
            t=t_new,
            inner=(),
            worker_id=state.worker_id,
        )

    return MinimaxOptimizer(name=f"sgda(lr={lr})", init=base_init, step=step)


def segda(lr: float) -> MinimaxOptimizer:
    def step(problem: MinimaxProblem, state: OptState, rng) -> OptState:
        r1, r2 = jax.random.split(rng)
        m = problem.oracle(state.z, draw(problem, r1, state.worker_id))
        w = problem.project(tree_axpy(-lr, m, state.z))          # exploration
        g = problem.oracle(w, draw(problem, r2, state.worker_id))
        z_new = problem.project(tree_axpy(-lr, g, state.z))      # anchor
        t_new = state.t + 1
        return OptState(
            z=z_new,
            z_bar=update_mean(state.z_bar, w, t_new),
            t=t_new,
            inner=(),
            worker_id=state.worker_id,
        )

    return MinimaxOptimizer(name=f"segda(lr={lr})", init=base_init, step=step)


def adam_minimax(
    lr: float, b1: float = 0.9, b2: float = 0.999, eps: float = 1e-8
) -> MinimaxOptimizer:
    def init(problem, rng):
        st = base_init(problem, rng)
        zeros = tree_zeros_like(st.z)
        return st._replace(inner={"m": zeros, "v": zeros})

    def step(problem: MinimaxProblem, state: OptState, rng) -> OptState:
        g = problem.oracle(state.z, draw(problem, rng, state.worker_id))
        t_new = state.t + 1
        tf = t_new.astype(jnp.float32)
        m = jax.tree.map(lambda mm, gg: b1 * mm + (1 - b1) * gg, state.inner["m"], g)
        v = jax.tree.map(
            lambda vv, gg: b2 * vv + (1 - b2) * gg * gg, state.inner["v"], g
        )
        mhat_scale = 1.0 / (1.0 - b1**tf)
        vhat_scale = 1.0 / (1.0 - b2**tf)
        z_new = problem.project(
            jax.tree.map(
                lambda z, mm, vv: z
                - lr * (mm * mhat_scale) / (jnp.sqrt(vv * vhat_scale) + eps),
                state.z,
                m,
                v,
            )
        )
        return OptState(
            z=z_new,
            z_bar=update_mean(state.z_bar, z_new, t_new),
            t=t_new,
            inner={"m": m, "v": v},
            worker_id=state.worker_id,
        )

    return MinimaxOptimizer(
        name=f"adam(lr={lr},b1={b1},b2={b2},eps={eps})", init=init, step=step
    )


def ump(g0: float, diameter: float, alpha: float = 1.0) -> MinimaxOptimizer:
    """Universal Mirror-Prox (Bach & Levy '19): adaptive extragradient.

    Identical to one LocalAdaSEG worker (K→∞, M=1); its 1/η is exposed as
    the sync weight so ``run_local(ump, ...)`` is *unweighted-sync* ablation
    of LocalAdaSEG, while ``repro.core`` carries the paper's weighted version.
    """

    def init(problem, rng):
        st = base_init(problem, rng)
        return st._replace(inner={"sum_sq": jnp.float32(0.0)})

    def step(problem: MinimaxProblem, state: OptState, rng) -> OptState:
        r1, r2 = jax.random.split(rng)
        eta = diameter * alpha / jnp.sqrt(g0**2 + state.inner["sum_sq"])
        m = problem.oracle(state.z, draw(problem, r1, state.worker_id))
        w = problem.project(tree_axpy(-eta, m, state.z))
        g = problem.oracle(w, draw(problem, r2, state.worker_id))
        z_new = problem.project(tree_axpy(-eta, g, state.z))
        z_sq = (
            tree_norm_sq(tree_sub(w, state.z)) + tree_norm_sq(tree_sub(w, z_new))
        ) / (5.0 * eta**2)
        t_new = state.t + 1
        return OptState(
            z=z_new,
            z_bar=update_mean(state.z_bar, w, t_new),
            t=t_new,
            inner={"sum_sq": state.inner["sum_sq"] + z_sq},
            worker_id=state.worker_id,
        )

    def sync_weight(state: OptState) -> jax.Array:
        return jnp.sqrt(g0**2 + state.inner["sum_sq"]) / (diameter * alpha)

    # name carries every hyper-parameter: it is the checkpoint fingerprint
    # (LocalWorker.fingerprint), so a restore with a different D/alpha must
    # hash differently and be rejected, not silently change eta.
    return MinimaxOptimizer(
        name=f"ump(g0={g0},D={diameter},alpha={alpha})",
        init=init, step=step, sync_weight=sync_weight
    )


def asmp(g0: float, diameter: float, alpha: float = 1.0) -> MinimaxOptimizer:
    """Adaptive Single-gradient Mirror-Prox (Ene & Nguyen '20).

    Optimistic variant: the extrapolation reuses the PREVIOUS gradient, so
    each iteration makes a single oracle call. Learning rate adapts to the
    accumulated prediction error ‖g_t − g_{t−1}‖².
    """

    def init(problem, rng):
        st = base_init(problem, rng)
        return st._replace(
            inner={"sum_sq": jnp.float32(0.0), "g_prev": tree_zeros_like(st.z)}
        )

    def step(problem: MinimaxProblem, state: OptState, rng) -> OptState:
        eta = diameter * alpha / jnp.sqrt(g0**2 + state.inner["sum_sq"])
        w = problem.project(tree_axpy(-eta, state.inner["g_prev"], state.z))
        g = problem.oracle(w, draw(problem, rng, state.worker_id))
        z_new = problem.project(tree_axpy(-eta, g, state.z))
        err_sq = tree_norm_sq(tree_sub(g, state.inner["g_prev"]))
        t_new = state.t + 1
        return OptState(
            z=z_new,
            z_bar=update_mean(state.z_bar, w, t_new),
            t=t_new,
            inner={"sum_sq": state.inner["sum_sq"] + err_sq, "g_prev": g},
            worker_id=state.worker_id,
        )

    def sync_weight(state: OptState) -> jax.Array:
        return jnp.sqrt(g0**2 + state.inner["sum_sq"]) / (diameter * alpha)

    return MinimaxOptimizer(
        name=f"asmp(g0={g0},D={diameter},alpha={alpha})",
        init=init, step=step, sync_weight=sync_weight
    )
