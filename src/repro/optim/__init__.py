"""Optimizer zoo (paper baselines) + their door into the unified PS runtime:
``MinimaxWorker`` lifts any zoo optimizer onto ``repro.ps.PSEngine``."""
from .base import (
    MinimaxOptimizer,
    MinimaxWorker,
    OptState,
    average_stacked,
    base_init,
    minibatch,
    run_local,
    run_serial,
)
from .methods import adam_minimax, asmp, segda, sgda, ump

__all__ = [
    "MinimaxOptimizer",
    "MinimaxWorker",
    "OptState",
    "adam_minimax",
    "asmp",
    "average_stacked",
    "base_init",
    "minibatch",
    "run_local",
    "run_serial",
    "segda",
    "sgda",
    "ump",
]
