"""Optimizer zoo + generic serial/local drivers (paper baselines)."""
from .base import (
    MinimaxOptimizer,
    OptState,
    average_stacked,
    base_init,
    minibatch,
    run_local,
    run_serial,
)
from .methods import adam_minimax, asmp, segda, sgda, ump

__all__ = [
    "MinimaxOptimizer",
    "OptState",
    "adam_minimax",
    "asmp",
    "average_stacked",
    "base_init",
    "minibatch",
    "run_local",
    "run_serial",
    "segda",
    "sgda",
    "ump",
]
