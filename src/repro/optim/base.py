"""Unified functional interface for stochastic minimax optimizers.

Every optimizer in the zoo (the paper's comparison set, §4.1 Fig. 4) is a
pair of pure functions over an :class:`OptState`:

    init(problem, rng)          -> OptState
    step(problem, state, rng)   -> OptState

with optimizer-specific extras living in ``state.inner``. Execution:

* :func:`run_serial`  — single worker, T steps (and, combined with
  :func:`minibatch`, the paper's MB-* baselines: R steps of batch K·M).
* :class:`MinimaxWorker` — lifts any :class:`MinimaxOptimizer` onto the
  Parameter-Server runtime (``repro.ps.PSEngine``): the Local* family
  (LocalSGDA, LocalSEGDA, Local Adam, and the local'ized UMP/ASMP) runs on
  the *same* engine as LocalAdaSEG — schedules, compression, faults,
  checkpoint/resume, telemetry, serial and ``shard_map`` paths included.
* :func:`run_local`   — thin convenience wrapper over that engine with the
  historical signature (M stacked workers, R rounds × K local steps with
  periodic weighted iterate averaging). It reproduces the rng stream and
  trajectories of the pre-engine hand-rolled driver.

LocalAdaSEG itself lives in ``repro.core.adaseg`` (with its inverse-η
weighting) and enters the engine through ``core.worker.AdaSEGWorker``.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, NamedTuple

import jax
import jax.numpy as jnp
from jax import lax

from ..core.tree import tree_where, tree_zeros_like
from ..core.types import MinimaxProblem
from ..core.worker import LocalWorker

PyTree = Any


class OptState(NamedTuple):
    z: PyTree        # current (anchor) iterate
    z_bar: PyTree    # running uniform average of exploration iterates
    t: jax.Array     # step count (int32)
    inner: PyTree    # optimizer-specific state
    # int32 heterogeneous-sampler tag. None only for states built outside
    # base_init (every driver in this repo goes through base_init or
    # _replace's it); core.types.draw treats None as "use the iid sampler".
    worker_id: jax.Array | None = None


@dataclasses.dataclass(frozen=True)
class MinimaxOptimizer:
    name: str
    init: Callable[[MinimaxProblem, Any], OptState]
    step: Callable[[MinimaxProblem, OptState, Any], OptState]
    # Scalar weight for periodic averaging; LocalAdaSEG-style optimizers
    # return 1/η, plain optimizers return 1 (uniform FedAvg weighting).
    sync_weight: Callable[[OptState], jax.Array] = staticmethod(
        lambda s: jnp.float32(1.0)
    )


def base_init(problem: MinimaxProblem, rng, inner: PyTree = (),
              worker_id=0) -> OptState:
    z0 = problem.project(problem.init(rng))
    return OptState(z=z0, z_bar=tree_zeros_like(z0), t=jnp.int32(0),
                    inner=inner, worker_id=jnp.int32(worker_id))


def update_mean(z_bar: PyTree, z_new: PyTree, t_new: jax.Array) -> PyTree:
    return jax.tree.map(
        lambda zb, zt: zb + (zt - zb) / t_new.astype(zt.dtype), z_bar, z_new
    )


def minibatch(problem: MinimaxProblem, batch: int) -> MinimaxProblem:
    """Average the stochastic oracle over ``batch`` iid samples (variance/B)."""

    def sample(rng):
        return jax.vmap(problem.sample)(jax.random.split(rng, batch))

    sample_worker = None
    if problem.sample_worker is not None:
        def sample_worker(rng, worker_id):  # noqa: F811
            return jax.vmap(
                lambda r: problem.sample_worker(r, worker_id)
            )(jax.random.split(rng, batch))

    def oracle(z, xis):
        gs = jax.vmap(lambda xi: problem.oracle(z, xi))(xis)
        return jax.tree.map(lambda g: jnp.mean(g, axis=0), gs)

    return dataclasses.replace(
        problem, sample=sample, oracle=oracle, sample_worker=sample_worker,
        name=f"{problem.name}@mb{batch}",
    )


# ---------------------------------------------------------------------------
# LocalWorker adapter — the zoo's door into the PS engine
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class MinimaxWorker(LocalWorker):
    """Any :class:`MinimaxOptimizer` as a Parameter-Server LocalWorker.

    Sync payload is the current iterate ``z`` (periodic iterate averaging,
    weighted by ``opt.sync_weight`` — uniform FedAvg for the fixed-lr
    methods, 1/η for UMP/ASMP); optimizer inner state (Adam moments, UMP
    accumulators) stays local across syncs, matching Local Adam of
    Beznosikov et al. The inherited rng derivation is the historical
    ``run_local`` split, so engine trajectories reproduce the pre-engine
    driver's.
    """

    opt: MinimaxOptimizer

    @property
    def name(self) -> str:
        return self.opt.name

    def init(self, problem, rng, worker_id=0):
        return self.opt.init(problem, rng)._replace(
            worker_id=jnp.int32(worker_id)
        )

    def step(self, problem, state, rng, *, enabled=None):
        new = self.opt.step(problem, state, rng)
        if enabled is None:
            return new
        return tree_where(enabled, new, state)

    def sync_weight(self, state):
        return self.opt.sync_weight(state)

    def sync_payload(self, state):
        return state.z

    def merge_synced(self, state, payload):
        return state._replace(z=payload)

    def output(self, state):
        return state.z_bar


# ---------------------------------------------------------------------------
# Drivers
# ---------------------------------------------------------------------------

def run_serial(
    opt: MinimaxOptimizer,
    problem: MinimaxProblem,
    steps: int,
    rng,
    record_every: int = 1,
):
    """Run ``steps`` optimizer steps; return final state + recorded averages.

    Records ``z_bar`` (the convex-combination output iterate) every
    ``record_every`` steps, stacked on axis 0 — what the benchmark plots use.
    """
    state = opt.init(problem, rng)
    chunks = steps // record_every

    def chunk_fn(state, rng_c):
        rngs = jax.random.split(rng_c, record_every)

        def body(st, r):
            return opt.step(problem, st, r), None

        state, _ = lax.scan(body, state, rngs)
        return state, state.z_bar

    rng, sub = jax.random.split(rng)
    state, history = lax.scan(chunk_fn, state, jax.random.split(sub, chunks))
    return state, history


def average_stacked(z: PyTree, weights: jax.Array) -> PyTree:
    """Weighted mean over the leading worker axis, broadcast back."""
    w = weights / jnp.sum(weights)

    def avg(leaf):
        wb = w.reshape((-1,) + (1,) * (leaf.ndim - 1)).astype(leaf.dtype)
        mean = jnp.sum(wb * leaf, axis=0, keepdims=True)
        return jnp.broadcast_to(mean, leaf.shape)

    return jax.tree.map(avg, z)


def run_local(
    opt: MinimaxOptimizer,
    problem: MinimaxProblem,
    *,
    num_workers: int,
    local_k: int,
    rounds: int,
    rng,
):
    """Local-update periodic-averaging driver (the Local* baseline family),
    as a thin wrapper over the Parameter-Server engine.

    Each round: average all workers' current iterates z (weighted by
    ``opt.sync_weight``), then run ``local_k`` independent local steps.
    Returns the final stacked state plus the per-round global output-average
    history — the historical ``run_local`` contract. Collecting that history
    costs one engine dispatch + host sync per round; when you don't need it
    (or need schedules, compression, faults, sharded execution or
    checkpointing), drive ``repro.ps.PSEngine`` with ``MinimaxWorker(opt)``
    directly and ``run()`` the rounds as one chunk.
    """
    from ..ps.engine import PSConfig, PSEngine  # deferred: ps imports optim users

    engine = PSEngine(
        problem,
        PSConfig(num_workers=num_workers, rounds=rounds,
                 worker=MinimaxWorker(opt), local_k=local_k),
        rng=rng,
    )
    history = []
    for _ in range(rounds):
        engine.step_round()
        history.append(engine.z_bar())
    if history:
        history = jax.tree.map(lambda *xs: jnp.stack(xs), *history)
    else:  # rounds=0: empty history arrays, like the pre-engine lax.scan
        history = jax.tree.map(
            lambda v: jnp.zeros((0,) + v.shape, v.dtype), engine.z_bar()
        )
    return engine.state, history
