"""Unified functional interface for stochastic minimax optimizers.

Every optimizer in the zoo (the paper's comparison set, §4.1 Fig. 4) is a
pair of pure functions over an :class:`OptState`:

    init(problem, rng)          -> OptState
    step(problem, state, rng)   -> OptState

with optimizer-specific extras living in ``state.inner``. Two generic
drivers consume them:

* :func:`run_serial`  — single worker, T steps (and, combined with
  :func:`minibatch`, the paper's MB-* baselines: R steps of batch K·M).
* :func:`run_local`   — M stacked workers, R rounds × K local steps with
  periodic (optionally weighted) iterate averaging — the Local* family
  (LocalSGDA, LocalSEGDA, Local Adam; LocalAdaSEG itself lives in
  ``repro.core.adaseg`` with its inverse-η weighting).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, NamedTuple

import jax
import jax.numpy as jnp
from jax import lax

from ..core.tree import tree_zeros_like
from ..core.types import MinimaxProblem

PyTree = Any


class OptState(NamedTuple):
    z: PyTree        # current (anchor) iterate
    z_bar: PyTree    # running uniform average of exploration iterates
    t: jax.Array     # step count (int32)
    inner: PyTree    # optimizer-specific state
    worker_id: jax.Array = None  # int32 — heterogeneous sampler tag


@dataclasses.dataclass(frozen=True)
class MinimaxOptimizer:
    name: str
    init: Callable[[MinimaxProblem, Any], OptState]
    step: Callable[[MinimaxProblem, OptState, Any], OptState]
    # Scalar weight for periodic averaging; LocalAdaSEG-style optimizers
    # return 1/η, plain optimizers return 1 (uniform FedAvg weighting).
    sync_weight: Callable[[OptState], jax.Array] = staticmethod(
        lambda s: jnp.float32(1.0)
    )


def base_init(problem: MinimaxProblem, rng, inner: PyTree = (),
              worker_id=0) -> OptState:
    z0 = problem.project(problem.init(rng))
    return OptState(z=z0, z_bar=tree_zeros_like(z0), t=jnp.int32(0),
                    inner=inner, worker_id=jnp.int32(worker_id))


def update_mean(z_bar: PyTree, z_new: PyTree, t_new: jax.Array) -> PyTree:
    return jax.tree.map(
        lambda zb, zt: zb + (zt - zb) / t_new.astype(zt.dtype), z_bar, z_new
    )


def minibatch(problem: MinimaxProblem, batch: int) -> MinimaxProblem:
    """Average the stochastic oracle over ``batch`` iid samples (variance/B)."""

    def sample(rng):
        return jax.vmap(problem.sample)(jax.random.split(rng, batch))

    sample_worker = None
    if problem.sample_worker is not None:
        def sample_worker(rng, worker_id):  # noqa: F811
            return jax.vmap(
                lambda r: problem.sample_worker(r, worker_id)
            )(jax.random.split(rng, batch))

    def oracle(z, xis):
        gs = jax.vmap(lambda xi: problem.oracle(z, xi))(xis)
        return jax.tree.map(lambda g: jnp.mean(g, axis=0), gs)

    return dataclasses.replace(
        problem, sample=sample, oracle=oracle, sample_worker=sample_worker,
        name=f"{problem.name}@mb{batch}",
    )


# ---------------------------------------------------------------------------
# Drivers
# ---------------------------------------------------------------------------

def run_serial(
    opt: MinimaxOptimizer,
    problem: MinimaxProblem,
    steps: int,
    rng,
    record_every: int = 1,
):
    """Run ``steps`` optimizer steps; return final state + recorded averages.

    Records ``z_bar`` (the convex-combination output iterate) every
    ``record_every`` steps, stacked on axis 0 — what the benchmark plots use.
    """
    state = opt.init(problem, rng)
    chunks = steps // record_every

    def chunk_fn(state, rng_c):
        rngs = jax.random.split(rng_c, record_every)

        def body(st, r):
            return opt.step(problem, st, r), None

        state, _ = lax.scan(body, state, rngs)
        return state, state.z_bar

    rng, sub = jax.random.split(rng)
    state, history = lax.scan(chunk_fn, state, jax.random.split(sub, chunks))
    return state, history


def average_stacked(z: PyTree, weights: jax.Array) -> PyTree:
    """Weighted mean over the leading worker axis, broadcast back."""
    w = weights / jnp.sum(weights)

    def avg(leaf):
        wb = w.reshape((-1,) + (1,) * (leaf.ndim - 1)).astype(leaf.dtype)
        mean = jnp.sum(wb * leaf, axis=0, keepdims=True)
        return jnp.broadcast_to(mean, leaf.shape)

    return jax.tree.map(avg, z)


def run_local(
    opt: MinimaxOptimizer,
    problem: MinimaxProblem,
    *,
    num_workers: int,
    local_k: int,
    rounds: int,
    rng,
):
    """Local-update periodic-averaging wrapper (the Local* baseline family).

    Each round: average all workers' current iterates z (weighted by
    ``opt.sync_weight``), then run ``local_k`` independent local steps.
    Optimizer inner state (moments, accumulators) stays local — matching
    Local Adam of Beznosikov et al. Returns the final state plus the
    per-round global output-average history.
    """
    m = num_workers
    rng, sub = jax.random.split(rng)
    state = jax.vmap(
        lambda r, w: opt.init(problem, r)._replace(worker_id=w)
    )(jax.random.split(sub, m), jnp.arange(m, dtype=jnp.int32))
    vstep = jax.vmap(lambda st, r: opt.step(problem, st, r))
    vweight = jax.vmap(opt.sync_weight)

    def round_fn(state, rng_round):
        z_avg = average_stacked(state.z, vweight(state))
        state = state._replace(z=z_avg)
        rngs = jax.random.split(rng_round, local_k * m).reshape(local_k, m, 2)

        def body(st, r):
            return vstep(st, r), None

        state, _ = lax.scan(body, state, rngs)
        # Global output = uniform mean of worker averages (all t equal here).
        out = jax.tree.map(lambda v: jnp.mean(v, axis=0), state.z_bar)
        return state, out

    state, history = lax.scan(round_fn, state, jax.random.split(rng, rounds))
    return state, history
