"""Observability: dual-clock spans, metrics, and Perfetto export.

The Parameter-Server story is a *time* story — local compute traded against
uplink cost (sync engines), staleness traded against idle time (the
event-driven engine) — and this package is the layer that makes the time
visible without perturbing a single bit of the numerics:

* :mod:`~repro.obs.spans` — hierarchical :class:`SpanTracer` recording
  host wall-clock **and** (in the async engine) simulated-clock intervals,
  on per-worker tracks;
* :mod:`~repro.obs.metrics` — :class:`MetricsRegistry` counters/gauges/
  histograms with JSONL sinks, plus :func:`modeled_sync_cost` putting the
  ``kernels.sync_compress`` HBM-traffic model and the roofline bandwidth
  constant next to every measured wall time;
* :mod:`~repro.obs.export` — Chrome/Perfetto trace-event JSON of either
  clock (:func:`save_trace_events`), schema-checked by
  :func:`validate_trace_events`.

Every engine takes ``tracer=``/``metrics=`` (defaults are enabled,
in-memory, near-zero overhead); the instrumentation never runs inside jit,
so all bit-exactness and parity pins hold with tracing on — enforced by
``tests/test_obs.py``.

Examples
--------
>>> from repro.obs import SpanTracer, to_trace_events, validate_trace_events
>>> tr = SpanTracer()
>>> _ = tr.add_span("local-compute r0", cat="local-compute",
...                 track="worker/0", sim_t0=0.0, sim_t1=2.0)
>>> validate_trace_events(to_trace_events(tr.spans, clock="sim"))
"""
from .export import save_trace_events, to_trace_events, validate_trace_events
from .metrics import MetricsRegistry, modeled_sync_cost
from .spans import CATEGORIES, Span, SpanTracer

__all__ = [
    "CATEGORIES",
    "MetricsRegistry",
    "Span",
    "SpanTracer",
    "modeled_sync_cost",
    "save_trace_events",
    "to_trace_events",
    "validate_trace_events",
]
