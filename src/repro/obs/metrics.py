"""Counter/gauge/histogram registry with JSONL + in-memory sinks.

The engines emit their quantitative telemetry here — bytes up/down,
effective local steps, η spread, admitted staleness — and, for the sync hot
path, a *modeled* cost next to every measured one: HBM passes per uplink
from the ``kernels.sync_compress`` traffic model
(:func:`repro.kernels.sync_compress.ops.codec_passes`) converted to seconds
with the roofline constants of :mod:`repro.roofline.analysis`, so a single
record answers "how long did the round take, and how long does the traffic
model say it should take on real HBM".

Records are plain dicts (``kind``/``name``/``value``/``labels`` + optional
``t_wall``/``t_sim``) accumulated in memory; :meth:`MetricsRegistry.save_jsonl`
streams them one-per-line and :meth:`MetricsRegistry.load_jsonl` is the
inverse. Like the span tracer, emission is host-side only — nothing touches
a jitted computation, so metrics are inert by construction (and pinned so
by ``tests/test_obs.py``).

Examples
--------
>>> reg = MetricsRegistry()
>>> reg.inc("bytes_up", 80.0, engine="sync")
>>> reg.inc("bytes_up", 40.0, engine="sync")
>>> reg.set_gauge("eta_spread", 1.5)
>>> reg.observe("staleness", 2.0)
>>> reg.total("bytes_up"), reg.last("eta_spread")
(120.0, 1.5)
>>> reg.histogram("staleness")["count"]
1
"""
from __future__ import annotations

import json
import math
from typing import Any


class MetricsRegistry:
    """In-memory metric sink with counter/gauge/histogram semantics.

    Examples
    --------
    >>> reg = MetricsRegistry()
    >>> reg.inc("steps", 12, worker="0")
    >>> reg.total("steps")
    12.0
    """

    def __init__(self, *, enabled: bool = True):
        self.enabled = bool(enabled)
        self.records: list[dict] = []

    # -- emission -----------------------------------------------------------

    def emit(self, kind: str, name: str, value: float,
             t_wall: float | None = None, t_sim: float | None = None,
             **labels: Any) -> None:
        if not self.enabled:
            return
        rec: dict = {"kind": kind, "name": name, "value": float(value)}
        if labels:
            rec["labels"] = labels
        if t_wall is not None:
            rec["t_wall"] = float(t_wall)
        if t_sim is not None:
            rec["t_sim"] = float(t_sim)
        self.records.append(rec)

    def inc(self, name: str, value: float = 1.0, **labels: Any) -> None:
        self.emit("counter", name, value, **labels)

    def set_gauge(self, name: str, value: float, **labels: Any) -> None:
        self.emit("gauge", name, value, **labels)

    def observe(self, name: str, value: float, **labels: Any) -> None:
        self.emit("histogram", name, value, **labels)

    # -- in-memory aggregation ----------------------------------------------

    def _values(self, name: str, kind: str | None = None) -> list[float]:
        return [r["value"] for r in self.records
                if r["name"] == name and (kind is None or r["kind"] == kind)]

    def total(self, name: str) -> float:
        """Sum of every ``counter`` emission under ``name``."""
        return float(sum(self._values(name, "counter")))

    def last(self, name: str) -> float | None:
        """Latest ``gauge`` value under ``name`` (None if never set)."""
        vals = self._values(name, "gauge")
        return vals[-1] if vals else None

    def histogram(self, name: str) -> dict:
        """Summary stats over every ``histogram`` observation of ``name``."""
        vals = self._values(name, "histogram")
        if not vals:
            return {"count": 0}
        return {
            "count": len(vals),
            "sum": float(sum(vals)),
            "min": float(min(vals)),
            "max": float(max(vals)),
            "mean": float(sum(vals) / len(vals)),
        }

    def names(self) -> list[str]:
        seen: dict[str, None] = {}
        for r in self.records:
            seen.setdefault(r["name"])
        return list(seen)

    # -- serialization ------------------------------------------------------

    def save_jsonl(self, path: str) -> None:
        with open(path, "w") as f:
            for r in self.records:
                f.write(json.dumps(r) + "\n")

    @classmethod
    def load_jsonl(cls, path: str) -> "MetricsRegistry":
        reg = cls()
        with open(path) as f:
            for line in f:
                line = line.strip()
                if line:
                    reg.records.append(json.loads(line))
        return reg


def modeled_sync_cost(codec_spec: tuple | None, param_bytes: float, *,
                      workers: int, backend: str = "reference") -> dict:
    """Roofline-modeled cost of one sync round's uplink hot path.

    Reuses the ``kernels.sync_compress`` HBM traffic model (passes per
    uplink for the given codec and backend) and the roofline HBM bandwidth
    constant, so engines can put the *predicted* time next to the measured
    wall time in one metric record. ``codec_spec=None`` (an opaque
    compressor without a spec) returns NaNs rather than guessing.

    Examples
    --------
    >>> c = modeled_sync_cost(("quantize", 8), 4096.0, workers=4)
    >>> c["hbm_passes"], c["hbm_bytes"] == 11 * 4096.0 * 4
    (11, True)
    >>> f = modeled_sync_cost(("quantize", 8), 4096.0, workers=4,
    ...                       backend="fused")
    >>> f["hbm_passes"]
    6
    """
    from ..roofline.analysis import HBM_BW

    if codec_spec is None:
        return {"hbm_passes": math.nan, "hbm_bytes": math.nan,
                "hbm_s": math.nan}
    from ..kernels.sync_compress.ops import codec_passes

    ref_p, fused_p = codec_passes(codec_spec)
    passes = ref_p if backend == "reference" else fused_p
    hbm_bytes = float(passes) * float(param_bytes) * int(workers)
    return {"hbm_passes": passes, "hbm_bytes": hbm_bytes,
            "hbm_s": hbm_bytes / HBM_BW}
