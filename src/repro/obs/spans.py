"""Dual-clock hierarchical span tracer for the three execution engines.

A :class:`Span` is one named interval of work — ``run → round/admission →
phase`` (local-compute, uplink, server-merge, broadcast, eval, checkpoint)
— on a named *track* (``server`` for the engine/server timeline, ``worker/3``
for fleet member 3). Every span can carry **two clocks**:

* ``wall_t0/wall_t1`` — host wall-clock seconds (``time.perf_counter``),
  measured around the host-side dispatch that actually did the work;
* ``sim_t0/sim_t1``  — the *simulated* clock of
  :class:`~repro.ps.async_engine.AsyncPSEngine`, so staleness holds, uplink
  flight time and straggler idle gaps are visible even though the host
  executed everything back-to-back.

Synchronous engines only fill the wall clock; the event-driven engine fills
both (sim intervals are exact — the event machine knows when each phase
started and ended on its clock). Either clock exports to a Perfetto/Chrome
trace-event timeline via :mod:`repro.obs.export`.

The tracer is deliberately dumb and cheap: recording a span is one dataclass
append on the host, never inside a jitted computation — which is why
tracing is *provably inert* (the bit-exactness pins in
``tests/test_obs.py`` run every parity-sensitive path with tracing enabled).
For device-side alignment, :meth:`SpanTracer.span` can additionally enter a
``jax.profiler.TraceAnnotation`` (``profile=True``) so spans line up with
kernel names in a device profile; the jitted round bodies themselves carry
``jax.named_scope`` labels, which are pure metadata.

Examples
--------
>>> tr = SpanTracer()
>>> with tr.span("round 0", cat="round", steps=12) as sp:
...     pass
>>> ph = tr.add_span("local-compute", cat="local-compute", track="worker/1",
...                  parent=sp.id, sim_t0=0.0, sim_t1=3.5)
>>> len(tr.spans), ph.sim_dur, sp.wall_dur is not None
(2, 3.5, True)
"""
from __future__ import annotations

import contextlib
import dataclasses
import json
import time
from typing import Any, Iterator

# Canonical phase categories (``Span.cat``). Free-form strings are allowed,
# but the engines and the Perfetto export color-key on these.
CATEGORIES = (
    "run", "chunk", "round", "admission",
    "local-compute", "uplink", "held", "reboot",
    "uplink-encode", "server-merge", "broadcast",
    "eval", "checkpoint",
)


@dataclasses.dataclass
class Span:
    """One traced interval on one track, on up to two clocks.

    Examples
    --------
    >>> sp = Span(name="uplink", cat="uplink", track="worker/0",
    ...           sim_t0=1.0, sim_t1=1.2, id=0)
    >>> round(sp.sim_dur, 3), sp.wall_dur
    (0.2, None)
    """

    name: str
    cat: str = ""
    track: str = "server"
    wall_t0: float | None = None
    wall_t1: float | None = None
    sim_t0: float | None = None
    sim_t1: float | None = None
    parent: int | None = None
    id: int = -1
    attrs: dict = dataclasses.field(default_factory=dict)

    @property
    def wall_dur(self) -> float | None:
        if self.wall_t0 is None or self.wall_t1 is None:
            return None
        return self.wall_t1 - self.wall_t0

    @property
    def sim_dur(self) -> float | None:
        if self.sim_t0 is None or self.sim_t1 is None:
            return None
        return self.sim_t1 - self.sim_t0

    def to_dict(self) -> dict:
        d = {"name": self.name, "cat": self.cat, "track": self.track,
             "id": self.id}
        for f in ("wall_t0", "wall_t1", "sim_t0", "sim_t1", "parent"):
            v = getattr(self, f)
            if v is not None:
                d[f] = v
        if self.attrs:
            d["attrs"] = self.attrs
        return d

    @classmethod
    def from_dict(cls, d: dict) -> "Span":
        known = {f.name for f in dataclasses.fields(cls)}
        return cls(**{k: v for k, v in d.items() if k in known})


class SpanTracer:
    """Accumulates :class:`Span` records; hierarchy via a host-side stack.

    ``enabled=False`` turns the tracer into a timing-only shell: the context
    manager still measures wall time (the engines read their telemetry
    timings from it either way) but nothing is recorded — the configuration
    the overhead benchmark compares against. ``profile=True`` additionally
    wraps each context-managed span in a ``jax.profiler.TraceAnnotation``
    so device profiles carry the same names.

    Examples
    --------
    >>> tr = SpanTracer()
    >>> with tr.span("run", cat="run"):
    ...     with tr.span("round 0", cat="round") as r0:
    ...         pass
    >>> tr.spans[0].parent == tr.spans[1].id  # children close first
    True
    >>> [s.name for s in tr.spans]
    ['round 0', 'run']
    """

    def __init__(self, *, enabled: bool = True, profile: bool = False):
        self.enabled = bool(enabled)
        self.profile = bool(profile)
        self.spans: list[Span] = []
        self._stack: list[int] = []
        self._next_id = 0

    # -- recording ----------------------------------------------------------

    def _new_id(self) -> int:
        i = self._next_id
        self._next_id += 1
        return i

    @contextlib.contextmanager
    def span(self, name: str, *, cat: str = "", track: str = "server",
             sim_t0: float | None = None, sim_t1: float | None = None,
             **attrs: Any) -> Iterator[Span]:
        """Measure a host-side section; records it when enabled. The yielded
        span is live — callers may set ``sim_t0``/``sim_t1``/attrs inside."""
        sp = Span(name=name, cat=cat, track=track, sim_t0=sim_t0,
                  sim_t1=sim_t1, attrs=attrs)
        prof = None
        if self.enabled:
            sp.id = self._new_id()
            sp.parent = self._stack[-1] if self._stack else None
            self._stack.append(sp.id)
            if self.profile:
                import jax

                prof = jax.profiler.TraceAnnotation(name)
                prof.__enter__()
        sp.wall_t0 = time.perf_counter()
        try:
            yield sp
        finally:
            sp.wall_t1 = time.perf_counter()
            if self.enabled:
                if prof is not None:
                    prof.__exit__(None, None, None)
                self._stack.pop()
                self.spans.append(sp)

    def add_span(self, name: str, *, cat: str = "", track: str = "server",
                 wall_t0: float | None = None, wall_t1: float | None = None,
                 sim_t0: float | None = None, sim_t1: float | None = None,
                 parent: int | None = None, **attrs: Any) -> Span:
        """Record an interval retroactively (the event-driven engine's
        simulated-clock phases are only known once their events fire)."""
        sp = Span(name=name, cat=cat, track=track, wall_t0=wall_t0,
                  wall_t1=wall_t1, sim_t0=sim_t0, sim_t1=sim_t1,
                  parent=parent, attrs=attrs)
        if self.enabled:
            sp.id = self._new_id()
            if sp.parent is None and self._stack:
                sp.parent = self._stack[-1]
            self.spans.append(sp)
        return sp

    # -- queries ------------------------------------------------------------

    def by_cat(self, cat: str) -> list[Span]:
        return [s for s in self.spans if s.cat == cat]

    def tracks(self) -> list[str]:
        seen: dict[str, None] = {}
        for s in self.spans:
            seen.setdefault(s.track)
        return list(seen)

    # -- serialization (JSONL: one span per line) ---------------------------

    def save_jsonl(self, path: str) -> None:
        with open(path, "w") as f:
            for s in self.spans:
                f.write(json.dumps(s.to_dict()) + "\n")

    @classmethod
    def load_jsonl(cls, path: str) -> "SpanTracer":
        """Inverse of :meth:`save_jsonl`; unknown keys from newer writers
        are dropped, like ``TraceRecorder.load``."""
        tr = cls()
        with open(path) as f:
            for line in f:
                line = line.strip()
                if line:
                    tr.spans.append(Span.from_dict(json.loads(line)))
        if tr.spans:
            tr._next_id = max(s.id for s in tr.spans) + 1
        return tr
