"""Chrome/Perfetto trace-event export of either clock of a span trace.

:func:`to_trace_events` converts a list of :class:`~repro.obs.spans.Span`
into the Trace Event Format dict that ``chrome://tracing`` and
https://ui.perfetto.dev open directly: one complete (``"ph": "X"``) event
per span with microsecond ``ts``/``dur``, one *thread* (``tid``) per span
track — so an async fleet renders as per-worker swimlanes with the
server's admissions on their own lane — plus thread-name metadata events.

``clock="wall"`` exports host wall-clock spans (the synchronous engines'
view); ``clock="sim"`` exports the simulated clock (the event-driven
engine's view, where uplink flight time, staleness holds and straggler
gaps are visible). Spans missing the requested clock are skipped, so one
tracer can serve both exports.

:func:`validate_trace_events` is the schema check the tests gate on:
required keys, non-negative durations, and proper nesting (events on one
track either nest or are disjoint — never partially overlap).

Examples
--------
>>> from repro.obs.spans import SpanTracer
>>> tr = SpanTracer()
>>> _ = tr.add_span("uplink r0", cat="uplink", track="worker/0",
...                 sim_t0=0.0, sim_t1=0.2)
>>> _ = tr.add_span("local-compute r0", cat="local-compute",
...                 track="worker/0", sim_t0=0.3, sim_t1=2.3)
>>> payload = to_trace_events(tr.spans, clock="sim")
>>> validate_trace_events(payload)
>>> [e["name"] for e in payload["traceEvents"] if e["ph"] == "X"]
['uplink r0', 'local-compute r0']
"""
from __future__ import annotations

import json
from typing import Iterable

from .spans import Span, SpanTracer

_CLOCKS = ("wall", "sim")


def _interval(span: Span, clock: str) -> tuple[float, float] | None:
    t0 = getattr(span, f"{clock}_t0")
    t1 = getattr(span, f"{clock}_t1")
    if t0 is None or t1 is None:
        return None
    return float(t0), float(t1)


def to_trace_events(spans: Iterable[Span], *, clock: str = "wall",
                    pid: int = 1) -> dict:
    """Spans → Trace Event Format dict (see module docstring)."""
    if clock not in _CLOCKS:
        raise ValueError(f"clock must be one of {_CLOCKS}, got {clock!r}")
    spans = list(spans)
    events: list[dict] = []
    tids: dict[str, int] = {}
    for sp in spans:
        if _interval(sp, clock) is not None:
            tids.setdefault(sp.track, len(tids))
    for track, tid in tids.items():
        events.append({
            "ph": "M", "pid": pid, "tid": tid, "name": "thread_name",
            "args": {"name": track},
        })
        events.append({
            "ph": "M", "pid": pid, "tid": tid, "name": "thread_sort_index",
            "args": {"sort_index": tid},
        })
    t_base = min((_interval(sp, clock)[0] for sp in spans
                  if _interval(sp, clock) is not None), default=0.0)
    for sp in spans:
        iv = _interval(sp, clock)
        if iv is None:
            continue
        t0, t1 = iv
        ev = {
            "ph": "X",
            "pid": pid,
            "tid": tids[sp.track],
            "name": sp.name,
            "cat": sp.cat or "span",
            "ts": (t0 - t_base) * 1e6,          # µs, zero-based
            "dur": (t1 - t0) * 1e6,
        }
        args = dict(sp.attrs)
        if clock == "sim" and sp.wall_dur is not None:
            args["wall_dur_ms"] = sp.wall_dur * 1e3
        if args:
            ev["args"] = {k: v for k, v in args.items()
                          if isinstance(v, (int, float, str, bool))
                          or v is None}
        events.append(ev)
    return {
        "traceEvents": events,
        "displayTimeUnit": "ms",
        "otherData": {"clock": clock, "source": "repro.obs"},
    }


def save_trace_events(path: str, tracer: SpanTracer | Iterable[Span], *,
                      clock: str = "wall", pid: int = 1) -> dict:
    """Write :func:`to_trace_events` output as JSON; returns the payload."""
    spans = tracer.spans if isinstance(tracer, SpanTracer) else tracer
    payload = to_trace_events(spans, clock=clock, pid=pid)
    with open(path, "w") as f:
        json.dump(payload, f, indent=1)
    return payload


def validate_trace_events(payload: dict) -> None:
    """Raise ``ValueError`` unless ``payload`` is well-formed Trace Event
    JSON: required keys per event, non-negative ``ts``/``dur``, and per-track
    events that strictly nest or are disjoint (no partial overlap)."""
    if "traceEvents" not in payload:
        raise ValueError("missing traceEvents")
    complete: dict[int, list[tuple[float, float, str]]] = {}
    for ev in payload["traceEvents"]:
        for key in ("ph", "pid", "tid", "name"):
            if key not in ev:
                raise ValueError(f"event missing {key!r}: {ev}")
        if ev["ph"] == "M":
            continue
        if ev["ph"] != "X":
            raise ValueError(f"unexpected phase {ev['ph']!r}")
        if "ts" not in ev or "dur" not in ev:
            raise ValueError(f"X event missing ts/dur: {ev}")
        if ev["ts"] < 0 or ev["dur"] < 0:
            raise ValueError(
                f"negative timestamp/duration on {ev['name']!r}: "
                f"ts={ev['ts']}, dur={ev['dur']}"
            )
        complete.setdefault(ev["tid"], []).append(
            (float(ev["ts"]), float(ev["ts"]) + float(ev["dur"]), ev["name"])
        )
    eps = 1.0  # µs: tolerate float jitter from uniform wall attribution
    for tid, ivs in complete.items():
        ivs.sort(key=lambda x: (x[0], -(x[1] - x[0])))
        stack: list[tuple[float, float, str]] = []
        for t0, t1, name in ivs:
            while stack and t0 >= stack[-1][1] - eps:
                stack.pop()
            if stack and t1 > stack[-1][1] + eps:
                raise ValueError(
                    f"track {tid}: {name!r} [{t0}, {t1}] partially overlaps "
                    f"{stack[-1][2]!r} [{stack[-1][0]}, {stack[-1][1]}]"
                )
            stack.append((t0, t1, name))
