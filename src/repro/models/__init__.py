"""Model zoo: composable backbones for the assigned architectures."""
from .transformer import (
    cache_specs,
    decode_step,
    encode,
    forward,
    init_cache,
    init_model,
    loss_fn,
)

__all__ = [
    "cache_specs",
    "decode_step",
    "encode",
    "forward",
    "init_cache",
    "init_model",
    "loss_fn",
]
