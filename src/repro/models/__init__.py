"""Model zoo: composable backbones for the assigned architectures, plus the
PS-runtime face of the stack — real models as problems
(:mod:`.problem`) and LocalWorkers (:mod:`.worker`)."""
from .problem import make_eval_loss, make_lm_problem, tiny_lm_config
from .transformer import (
    cache_specs,
    decode_step,
    encode,
    forward,
    init_cache,
    init_model,
    loss_fn,
)
from .worker import ModelWorker

__all__ = [
    "ModelWorker",
    "cache_specs",
    "decode_step",
    "encode",
    "forward",
    "init_cache",
    "init_model",
    "loss_fn",
    "make_eval_loss",
    "make_lm_problem",
    "tiny_lm_config",
]
