"""Model assembly: decoder-only, encoder-decoder, and cross-attn VLM stacks.

Pre-norm residual blocks; the per-layer mixer is attention (global or
sliding-window), a Mamba2 SSD block, or an RG-LRU block, per
``cfg.layer_kinds()``; the channel mixer is a gated MLP or an MoE layer.

**Layer stacking**: layers are organized as ``num_groups`` repetitions of a
``pattern_period``-long stage (e.g. gemma2: (local, global); recurrentgemma:
(rglru, rglru, local)). Parameters for each period position are stacked with
a leading group axis and the full-sequence forward is a rematerialized
``lax.scan`` over groups — one compiled block body regardless of depth,
which keeps both compile time and activation memory O(1) in ``num_layers``.
Decode unrolls the (cheap) per-token graph with static indexing instead.

Everything returns ``(params, specs)`` pairs for GSPMD placement.
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from ..configs.base import ArchConfig
from .attention import (
    apply_attention,
    decode_attention,
    init_attention,
    init_kv_cache,
    kv_cache_specs,
)
from .layers import (
    _normal,
    apply_embedding,
    apply_layernorm,
    apply_rmsnorm,
    init_embedding,
    init_layernorm,
    init_rmsnorm,
    softcap,
)
from .mlp import apply_mlp, init_mlp
from .moe import apply_moe, init_moe
from .rglru import (
    apply_rglru,
    decode_rglru,
    init_rglru,
    init_rglru_cache,
    rglru_cache_specs,
)
from .ssm import apply_ssm, decode_ssm, init_ssm, init_ssm_cache, ssm_cache_specs

PyTree = Any


def init_norm(cfg: ArchConfig):
    dt = jnp.dtype(cfg.param_dtype)
    if cfg.norm == "layernorm":
        return init_layernorm(cfg.d_model, dt)
    return init_rmsnorm(cfg.d_model, dt)


def apply_norm(cfg: ArchConfig, p, x):
    if cfg.norm == "layernorm":
        return apply_layernorm(p, x, cfg.norm_eps)
    return apply_rmsnorm(p, x, cfg.norm_eps)


# ---------------------------------------------------------------------------
# Init
# ---------------------------------------------------------------------------

def _init_block(key, cfg: ArchConfig, kind: dict):
    keys = jax.random.split(key, 8)
    p: dict = {}
    s: dict = {}
    p["pre_norm"], s["pre_norm"] = init_norm(cfg)
    if kind["kind"] == "attn":
        p["mixer"], s["mixer"] = init_attention(keys[0], cfg)
    elif kind["kind"] == "ssm":
        p["mixer"], s["mixer"] = init_ssm(keys[0], cfg)
    elif kind["kind"] == "rglru":
        p["mixer"], s["mixer"] = init_rglru(keys[0], cfg)
    else:
        raise ValueError(kind)
    if kind.get("cross_attn"):
        p["xattn_norm"], s["xattn_norm"] = init_norm(cfg)
        p["xattn"], s["xattn"] = init_attention(keys[1], cfg, cross=True)
        if cfg.cross_attn_every:
            # gating scalar for VLM cross-attn (llama-3.2-vision style, init 0)
            p["xattn_gate"] = jnp.zeros((), jnp.float32)
            s["xattn_gate"] = P()
    if kind.get("moe"):
        p["mlp_norm"], s["mlp_norm"] = init_norm(cfg)
        p["mlp"], s["mlp"] = init_moe(keys[2], cfg)
    elif cfg.d_ff > 0:
        p["mlp_norm"], s["mlp_norm"] = init_norm(cfg)
        p["mlp"], s["mlp"] = init_mlp(keys[2], cfg)
    if cfg.post_norm:
        p["mixer_post"], s["mixer_post"] = init_norm(cfg)
        p["mlp_post"], s["mlp_post"] = init_norm(cfg)
    return p, s


def _init_stage(key, cfg: ArchConfig, kind: dict, n_groups: int):
    """Stack one period-position's block params over the group axis."""
    keys = jax.random.split(key, n_groups)
    box = {}

    def params_only(k):
        p, s = _init_block(k, cfg, kind)
        box["specs"] = s
        return p

    p = jax.vmap(params_only)(keys)
    s = jax.tree.map(
        lambda sp: P(None, *sp), box["specs"],
        is_leaf=lambda x: isinstance(x, P),
    )
    return p, s


def init_model(key, cfg: ArchConfig):
    """Returns (params, specs) for the full stack (+ encoder if enc-dec)."""
    cfg.validate()
    period = cfg.pattern_period()
    n_groups = cfg.num_groups()
    kinds = cfg.layer_kinds()
    keys = jax.random.split(key, period + cfg.tail_layers() + 4)
    p: dict = {}
    s: dict = {}
    p["embed"], s["embed"] = init_embedding(
        keys[0], cfg.vocab_size, cfg.d_model, jnp.dtype(cfg.param_dtype)
    )
    if cfg.pos_embed == "learned":
        p["pos_embed"] = _normal(
            keys[-3], (cfg.max_seq_len, cfg.d_model), 0.02,
            jnp.dtype(cfg.param_dtype),
        )
        s["pos_embed"] = P(None, None)

    stages_p, stages_s = [], []
    for j in range(period):
        sp, ss = _init_stage(keys[1 + j], cfg, kinds[j], n_groups)
        stages_p.append(sp)
        stages_s.append(ss)
    p["stages"], s["stages"] = stages_p, stages_s

    tails_p, tails_s = [], []
    for i in range(cfg.tail_layers()):
        tp, ts = _init_block(
            keys[1 + period + i], cfg, kinds[n_groups * period + i]
        )
        tails_p.append(tp)
        tails_s.append(ts)
    if tails_p:
        p["tail"], s["tail"] = tails_p, tails_s

    p["final_norm"], s["final_norm"] = init_norm(cfg)
    if not cfg.tie_embeddings:
        p["lm_head"] = _normal(
            keys[-2], (cfg.d_model, cfg.vocab_size), cfg.d_model**-0.5,
            jnp.dtype(cfg.param_dtype),
        )
        s["lm_head"] = P(None, "model")
    if cfg.is_encoder_decoder:
        p["encoder"], s["encoder"] = _init_encoder(keys[-1], cfg)
    return p, s


def _init_encoder(key, cfg: ArchConfig):
    """Non-causal encoder stack (whisper-style); frontend conv is a STUB —
    inputs arrive as precomputed frame embeddings of shape (B, S_enc, D)."""
    k_stage, k_norm = jax.random.split(key)
    box = {}

    def params_only(k):
        keys = jax.random.split(k, 2)
        lp, ls = {}, {}
        lp["pre_norm"], ls["pre_norm"] = init_norm(cfg)
        lp["mixer"], ls["mixer"] = init_attention(keys[0], cfg)
        lp["mlp_norm"], ls["mlp_norm"] = init_norm(cfg)
        lp["mlp"], ls["mlp"] = init_mlp(keys[1], cfg)
        box["specs"] = ls
        return lp

    stage = jax.vmap(params_only)(jax.random.split(k_stage, cfg.encoder_layers))
    specs = jax.tree.map(
        lambda sp: P(None, *sp), box["specs"],
        is_leaf=lambda x: isinstance(x, P),
    )
    p = {"stage": stage}
    s = {"stage": specs}
    p["final_norm"], s["final_norm"] = init_norm(cfg)
    return p, s


# ---------------------------------------------------------------------------
# Forward (train / prefill)
# ---------------------------------------------------------------------------

def encode(params, cfg: ArchConfig, frames):
    """Encoder forward. frames: (B, S_enc, D) stub embeddings → states."""
    x = frames.astype(jnp.dtype(cfg.compute_dtype))
    positions = jnp.broadcast_to(jnp.arange(x.shape[1]), x.shape[:2])

    @jax.checkpoint
    def body(x, lp):
        h = apply_norm(cfg, lp["pre_norm"], x)
        x = x + apply_attention(lp["mixer"], cfg, h, positions, causal=False)
        h = apply_norm(cfg, lp["mlp_norm"], x)
        x = x + apply_mlp(lp["mlp"], cfg, h)
        return x, None

    if cfg.scan_layers:
        x, _ = jax.lax.scan(body, x, params["encoder"]["stage"])
    else:
        for g in range(cfg.encoder_layers):
            x, _ = body(x, jax.tree.map(lambda v: v[g],
                                        params["encoder"]["stage"]))
    return apply_norm(cfg, params["encoder"]["final_norm"], x)


def _block_forward(lp, cfg: ArchConfig, kind, x, positions, enc_states):
    h = apply_norm(cfg, lp["pre_norm"], x)
    if kind["kind"] == "attn":
        out = apply_attention(lp["mixer"], cfg, h, positions, window=kind["window"])
    elif kind["kind"] == "ssm":
        out = apply_ssm(lp["mixer"], cfg, h)
    else:
        out = apply_rglru(lp["mixer"], cfg, h)
    if cfg.post_norm:
        out = apply_norm(cfg, lp["mixer_post"], out)
    x = x + out
    aux = jnp.float32(0.0)
    if kind.get("cross_attn") and enc_states is not None:
        h = apply_norm(cfg, lp["xattn_norm"], x)
        xo = apply_attention(lp["xattn"], cfg, h, positions, cross_states=enc_states)
        if "xattn_gate" in lp:
            xo = jnp.tanh(lp["xattn_gate"]).astype(x.dtype) * xo
        x = x + xo
    if "mlp" not in lp:  # attn/ssm-only blocks (mamba2 stacks)
        return x, aux
    h = apply_norm(cfg, lp["mlp_norm"], x)
    if kind.get("moe"):
        out, aux = apply_moe(lp["mlp"], cfg, h,
                             shard_dispatch=cfg.moe_shard_dispatch)
    else:
        out = apply_mlp(lp["mlp"], cfg, h)
    if cfg.post_norm:
        out = apply_norm(cfg, lp["mlp_post"], out)
    return x + out, aux


def forward(params, cfg: ArchConfig, tokens, *, enc_states=None,
            head_last_only: bool = False):
    """tokens: (B, S) → (logits, moe_aux). enc_states: (B, S_enc, D) for
    enc-dec / VLM cross-attention (stub frontend output).

    ``head_last_only``: apply the LM head to the final position only —
    logits (B, 1, V) instead of (B, S, V). Serving prefill uses this: the
    full-vocab logits tensor is O(S·V) and dominates prefill HBM otherwise.
    """
    cdt = jnp.dtype(cfg.compute_dtype)
    x = apply_embedding(params["embed"], tokens).astype(cdt)
    if cfg.scale_embed:
        x = x * jnp.asarray(cfg.d_model**0.5, cdt)
    positions = jnp.broadcast_to(jnp.arange(tokens.shape[1]), tokens.shape)
    if "pos_embed" in params:
        x = x + params["pos_embed"][positions].astype(cdt)

    kinds = cfg.layer_kinds()
    period = cfg.pattern_period()

    @jax.checkpoint
    def stage_body(x, stage_slice):
        aux_sum = jnp.float32(0.0)
        for j in range(period):
            x, aux = _block_forward(
                stage_slice[j], cfg, kinds[j], x, positions, enc_states
            )
            aux_sum = aux_sum + aux
        return x, aux_sum

    if cfg.scan_layers:
        x, auxs = jax.lax.scan(stage_body, x, tuple(params["stages"]))
        aux_total = jnp.sum(auxs)
    else:
        aux_total = jnp.float32(0.0)
        for g in range(cfg.num_groups()):
            stage_slice = jax.tree.map(lambda v: v[g], tuple(params["stages"]))
            x, aux = stage_body(x, stage_slice)
            aux_total = aux_total + aux
    for i, lp in enumerate(params.get("tail", [])):
        x, aux = _block_forward(
            lp, cfg, kinds[cfg.num_groups() * period + i], x, positions,
            enc_states,
        )
        aux_total = aux_total + aux

    x = apply_norm(cfg, params["final_norm"], x)
    if head_last_only:
        x = x[:, -1:, :]
    head = params["embed"]["table"].T if cfg.tie_embeddings else params["lm_head"]
    logits = (x @ head.astype(cdt)).astype(jnp.float32)
    if cfg.final_softcap:
        logits = softcap(logits, cfg.final_softcap)
    return logits, aux_total


def loss_fn(params, cfg: ArchConfig, batch):
    """Next-token cross-entropy (+ MoE router aux). batch: {tokens, labels,
    frontend?}. labels = tokens shifted, −1 = masked."""
    enc_states = None
    if cfg.is_encoder_decoder:
        enc_states = encode(params, cfg, batch["frontend"])
    elif cfg.cross_attn_every:
        enc_states = batch["frontend"].astype(jnp.dtype(cfg.compute_dtype))
    logits, aux = forward(params, cfg, batch["tokens"], enc_states=enc_states)
    labels = batch["labels"]
    mask = labels >= 0
    safe = jnp.maximum(labels, 0)
    logp = jax.nn.log_softmax(logits, axis=-1)
    nll = -jnp.take_along_axis(logp, safe[..., None], axis=-1)[..., 0]
    loss = jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1)
    return loss + cfg.router_aux_weight * aux


# ---------------------------------------------------------------------------
# Decode
# ---------------------------------------------------------------------------

def _layer_params(params, cfg: ArchConfig, i: int):
    """Static lookup of layer i's params from the stacked representation."""
    period = cfg.pattern_period()
    n_stacked = cfg.num_groups() * period
    if i < n_stacked:
        g, j = divmod(i, period)
        return jax.tree.map(lambda v: v[g], params["stages"][j])
    return params["tail"][i - n_stacked]


def init_cache(cfg: ArchConfig, batch, max_len, dtype=jnp.float32):
    """Per-layer cache pytree sized for decode at context ``max_len``.
    Sliding-window layers allocate only O(window) slots."""
    caches = []
    for kind in cfg.layer_kinds():
        if kind["kind"] == "attn":
            slots = min(kind["window"] or max_len, max_len)
            caches.append(init_kv_cache(cfg, batch, slots, dtype))
        elif kind["kind"] == "ssm":
            caches.append(init_ssm_cache(cfg, batch, dtype))
        else:
            caches.append(init_rglru_cache(cfg, batch, dtype))
    return caches


def cache_specs(cfg: ArchConfig, worker_axes=()):
    specs = []
    for kind in cfg.layer_kinds():
        if kind["kind"] == "attn":
            specs.append(kv_cache_specs(worker_axes))
        elif kind["kind"] == "ssm":
            specs.append(ssm_cache_specs(worker_axes))
        else:
            specs.append(rglru_cache_specs(worker_axes))
    return specs


def decode_step(params, cfg: ArchConfig, token, pos, cache, *, enc_states=None):
    """token: (B, 1) int32; pos: (B,) int32 → (logits (B, 1, V), new_cache)."""
    cdt = jnp.dtype(cfg.compute_dtype)
    x = apply_embedding(params["embed"], token).astype(cdt)
    if cfg.scale_embed:
        x = x * jnp.asarray(cfg.d_model**0.5, cdt)
    if "pos_embed" in params:
        x = x + params["pos_embed"][pos[:, None]].astype(cdt)
    new_caches = []
    for i, (kind, lc) in enumerate(zip(cfg.layer_kinds(), cache)):
        lp = _layer_params(params, cfg, i)
        h = apply_norm(cfg, lp["pre_norm"], x)
        if kind["kind"] == "attn":
            out, lc = decode_attention(
                lp["mixer"], cfg, h, pos, lc, window=kind["window"]
            )
        elif kind["kind"] == "ssm":
            out, lc = decode_ssm(lp["mixer"], cfg, h, lc)
        else:
            out, lc = decode_rglru(lp["mixer"], cfg, h, lc)
        if cfg.post_norm:
            out = apply_norm(cfg, lp["mixer_post"], out)
        x = x + out
        if kind.get("cross_attn") and enc_states is not None:
            h = apply_norm(cfg, lp["xattn_norm"], x)
            xo, _ = decode_attention(
                lp["xattn"], cfg, h, pos, None, cross_states=enc_states
            )
            if "xattn_gate" in lp:
                xo = jnp.tanh(lp["xattn_gate"]).astype(x.dtype) * xo
            x = x + xo
        if "mlp" in lp:
            h = apply_norm(cfg, lp["mlp_norm"], x)
            if kind.get("moe"):
                out, _ = apply_moe(lp["mlp"], cfg, h,
                                   shard_dispatch=cfg.moe_shard_dispatch)
            else:
                out = apply_mlp(lp["mlp"], cfg, h)
            if cfg.post_norm:
                out = apply_norm(cfg, lp["mlp_post"], out)
            x = x + out
        new_caches.append(lc)
    x = apply_norm(cfg, params["final_norm"], x)
    head = params["embed"]["table"].T if cfg.tie_embeddings else params["lm_head"]
    logits = (x @ head.astype(cdt)).astype(jnp.float32)
    if cfg.final_softcap:
        logits = softcap(logits, cfg.final_softcap)
    return logits, new_caches
