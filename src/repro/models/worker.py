"""ModelWorker — real architectures as LocalWorkers on the PS runtime.

``ModelWorker`` is the DiLoCo-shaped door between the model zoo and the
Parameter-Server engines: its state is a real train state (the model's
parameter pytree as the AdaSEG anchor/explore iterates plus the adaptive-η
accumulators), its ``step`` is one jitted extragradient model train step —
two ``jax.grad`` calls of ``models.loss_fn`` (transformers) or of the WGAN
minimax loss (``problems.wgan``) — and the engine runs its K local steps as
a ``lax.scan`` with one weighted all-reduce per round, exactly the local
scan + periodic delta sync of the DiLoCo exemplar.

It subclasses :class:`~repro.core.worker.AdaSEGWorker`, so the whole PR-1…5
runtime stack applies to real models unchanged: serial vmap, ``shard_map``
with the fused ``sync_compress`` codec, ``AsyncPSEngine`` τ-staleness,
heterogeneous K_m^r, q8/top-k error-feedback uplinks, faults, per-round
telemetry and bit-exact mid-stream resume. The only addition is the
``arch`` identity: it is folded into the worker fingerprint, so restoring a
checkpoint into an engine built for a *different architecture* is rejected
exactly like a wrong seed or wrong optimizer.

Examples
--------
A tiny transformer trains through the synchronous engine:

>>> import jax
>>> from repro.core import AdaSEGConfig
>>> from repro.models.problem import make_lm_problem, tiny_lm_config
>>> from repro.models.worker import ModelWorker
>>> from repro.ps import PSConfig, PSEngine
>>> cfg = tiny_lm_config()
>>> prob = make_lm_problem(cfg, batch=2, seq=8)
>>> w = ModelWorker(AdaSEGConfig(g0=5.0, diameter=1.0, k=2), arch=cfg.name)
>>> eng = PSEngine(prob, PSConfig(worker=w, local_k=2, num_workers=2,
...                               rounds=1), rng=jax.random.PRNGKey(0))
>>> params = eng.run()                       # z̄ — a real parameter pytree
>>> len(jax.tree.leaves(params)) > 4
True

The architecture is part of the checkpoint identity:

>>> a = ModelWorker(AdaSEGConfig(g0=5.0, diameter=1.0, k=2), arch="tiny-lm")
>>> b = ModelWorker(AdaSEGConfig(g0=5.0, diameter=1.0, k=2), arch="wgan_gp")
>>> a.fingerprint != b.fingerprint
True
"""
from __future__ import annotations

import dataclasses

from ..core.worker import AdaSEGWorker

__all__ = ["ModelWorker"]


@dataclasses.dataclass(frozen=True)
class ModelWorker(AdaSEGWorker):
    """LocalAdaSEG over a real model's parameters.

    ``arch`` names the architecture (an ``ArchConfig.name``, a WGAN problem
    name, …) and should encode anything that changes the parameter pytree —
    it is hashed into :attr:`fingerprint` so cross-architecture restores
    fail loudly. ``backend`` selects the AdaSEG step implementation like
    any other AdaSEG worker (the fused Pallas step kernels apply to model
    pytrees too — identity projections carry a static spec).
    """

    arch: str = "model"

    @property
    def name(self) -> str:
        c = self.cfg
        return (f"model[{self.arch}]+adaseg(g0={c.g0},D={c.diameter},"
                f"alpha={c.alpha},avg={c.average_output})")
