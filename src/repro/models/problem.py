"""Real models as :class:`~repro.core.types.MinimaxProblem` instances.

This is the bridge that lets the dormant LM stack (``repro.models`` +
``repro.configs``) train through the Parameter-Server runtime: a language
model is a *minimization-only* minimax problem (``core.types`` docstring —
the dual block is empty, the same machinery applies verbatim), so wrapping
``models.loss_fn`` as a problem oracle puts million+-parameter transformers
on the exact same engine code path as the paper's bilinear game — schedules,
compression + error feedback, faults, τ-staleness, bit-exact resume and all.

* ``init(rng)``    — ``models.init_model`` parameters (specs discarded; the
  engine stacks/shards the param pytree like any other worker state).
* ``sample(rng)``  — one Markov-Zipf batch from ``data.synthetic``; the
  engine's existing per-(round, step, worker) rng derivation therefore *is*
  the per-worker data stream.
* ``oracle(z, ξ)`` — ``jax.grad`` of the next-token cross-entropy (+ MoE
  router aux); with ``cfg.attn_backend="pallas"`` / ``ssm_backend="pallas"``
  the forward/backward hot path runs the ``kernels.flash_attention`` /
  ``kernels.ssd_scan`` Pallas kernels.
* ``project``      — identity (unconstrained), which also makes the fused
  AdaSEG step kernels eligible (``core.projections.spec_of``).

Heterogeneity: ``hetero_workers=M`` installs a ``sample_worker`` whose
Markov repetition probability varies per worker id — each worker draws from
its own local token distribution, the federated skew the paper studies in
§4.2, threaded through the engine's ``worker_id`` plumbing.

Examples
--------
A tiny transformer as a problem — one oracle call is one model gradient:

>>> import jax
>>> from repro.configs.base import ArchConfig
>>> from repro.models.problem import make_lm_problem, tiny_lm_config
>>> cfg = tiny_lm_config()
>>> prob = make_lm_problem(cfg, batch=2, seq=8)
>>> z0 = prob.init(jax.random.PRNGKey(0))
>>> g = prob.oracle(z0, prob.sample(jax.random.PRNGKey(1)))
>>> jax.tree.structure(g) == jax.tree.structure(z0)
True
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from ..configs.base import ArchConfig
from ..core import projections
from ..core.types import MinimaxProblem
from ..data.synthetic import make_batch, sample_tokens
from .transformer import init_model, loss_fn


def tiny_lm_config(name: str = "tiny-lm", *, vocab: int = 64,
                   d_model: int = 32, layers: int = 2,
                   attn_backend: str = "reference") -> ArchConfig:
    """A CPU-second-scale dense transformer config for tests/benchmarks."""
    return ArchConfig(
        name=name, arch_type="dense", num_layers=layers, d_model=d_model,
        num_heads=2, num_kv_heads=1, d_ff=2 * d_model, vocab_size=vocab,
        head_dim=d_model // 2, max_seq_len=64, attn_backend=attn_backend,
    )


def _hetero_sampler(cfg: ArchConfig, batch: int, seq: int,
                    hetero_workers: int):
    """Per-worker Markov-Zipf stream: the repetition probability sweeps
    0.1 → 0.8 across worker ids, so each worker's token distribution is
    genuinely local (and a function of the engine-provided worker_id)."""
    span = max(hetero_workers - 1, 1)

    def sample_worker(rng, worker_id):
        p_rep = 0.1 + 0.7 * jnp.asarray(worker_id, jnp.float32) / span
        r1, r2 = jax.random.split(jax.random.fold_in(rng, 11))
        base = sample_tokens(r1, batch, seq, cfg.vocab_size)
        rep = jax.random.bernoulli(r2, p_rep, base.shape)
        shifted = (jnp.roll(base, 1, axis=1) + 1) % cfg.vocab_size
        toks = jnp.where(rep, shifted, base).astype(jnp.int32)
        out = {"tokens": toks[:, :-1], "labels": toks[:, 1:]}
        if cfg.encoder_seq:
            out["frontend"] = 0.02 * jax.random.normal(
                jax.random.fold_in(rng, 1),
                (batch, cfg.encoder_seq, cfg.d_model),
                dtype=jnp.dtype(cfg.compute_dtype),
            )
        return out

    return sample_worker


def make_lm_problem(cfg: ArchConfig, *, batch: int, seq: int,
                    hetero_workers: int | None = None) -> MinimaxProblem:
    """Language-model training as a minimization-only MinimaxProblem.

    ``batch``/``seq`` are per-worker, per-oracle-call shapes; the engine's
    extragradient step makes two oracle calls per local step, each with its
    own derived key, so every (round, step, worker, call) sees a fresh
    deterministic batch.
    """
    cfg.validate()

    def init(rng):
        return init_model(rng, cfg)[0]

    def sample(rng):
        return make_batch(rng, cfg, batch, seq)

    def oracle(z, xi):
        return jax.grad(loss_fn)(z, cfg, xi)

    return MinimaxProblem(
        init=init,
        sample=sample,
        oracle=oracle,
        project=projections.identity(),
        name=f"lm[{cfg.name}]x{batch}x{seq}",
        sample_worker=(_hetero_sampler(cfg, batch, seq, hetero_workers)
                       if hetero_workers else None),
    )


def make_eval_loss(cfg: ArchConfig, *, batch: int, seq: int,
                   rng=None):
    """Held-out-loss ``eval_fn`` for the engines: cross-entropy of the
    global output iterate z̄ on one fixed deterministic batch."""
    rng = jax.random.PRNGKey(987) if rng is None else rng
    eval_batch = make_batch(rng, cfg, batch, seq)

    @jax.jit
    def eval_fn(params):
        return loss_fn(params, cfg, eval_batch)

    return eval_fn
