"""Gated MLPs (SwiGLU / GeGLU) — Megatron col/row parallel placement."""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from ..configs.base import ArchConfig
from .layers import MODEL, _normal


def _act(name):
    return {"silu": jax.nn.silu, "gelu": jax.nn.gelu, "gelu_plain": jax.nn.gelu}[name]


def init_mlp(key, cfg: ArchConfig, d_ff=None):
    dm = cfg.d_model
    ff = d_ff or cfg.d_ff
    dtype = jnp.dtype(cfg.param_dtype)
    k1, k2, k3 = jax.random.split(key, 3)
    gated = cfg.activation in ("silu", "gelu")
    p = {
        "w_in": _normal(k1, (dm, ff), dm**-0.5, dtype),
        "w_out": _normal(k3, (ff, dm), ff**-0.5, dtype),
    }
    s = {"w_in": P(None, MODEL), "w_out": P(MODEL, None)}
    if gated:
        p["w_gate"] = _normal(k2, (dm, ff), dm**-0.5, dtype)
        s["w_gate"] = P(None, MODEL)
    return p, s


def apply_mlp(p, cfg: ArchConfig, x):
    act = _act(cfg.activation)
    h = x @ p["w_in"]
    if "w_gate" in p:
        h = act(x @ p["w_gate"]) * h
    else:
        h = act(h)
    return h @ p["w_out"]
