"""RG-LRU recurrent block (RecurrentGemma / Griffin) [arXiv:2402.19427].

    r_t = σ(W_a x_t + b_a)            recurrence gate
    i_t = σ(W_x x_t + b_x)            input gate
    a_t = exp(−c·softplus(Λ)·r_t)     per-channel decay, c = 8
    h_t = a_t ⊙ h_{t−1} + sqrt(1 − a_t²) ⊙ (i_t ⊙ x_t)

Training uses ``lax.associative_scan`` over the linear recurrence (parallel,
log-depth — the TPU-idiomatic replacement for Griffin's custom scan); decode
keeps an O(d_rnn) hidden state plus the conv window. The block is the
Griffin "recurrent block": x → [gate branch, rnn branch]; rnn branch goes
conv1d → RG-LRU; merged as GeLU(gate) ⊙ h → out-projection.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from ..configs.base import ArchConfig
from .layers import MODEL, _normal, apply_conv1d, conv1d_step, init_conv1d

_C = 8.0


def init_rglru(key, cfg: ArchConfig):
    dm, dr = cfg.d_model, cfg.d_rnn
    dtype = jnp.dtype(cfg.param_dtype)
    keys = jax.random.split(key, 6)
    p = {
        "in_proj": _normal(keys[0], (dm, 2 * dr), dm**-0.5, dtype),  # [gate, x]
        "out_proj": _normal(keys[1], (dr, dm), dr**-0.5, dtype),
        "w_a": _normal(keys[2], (dr, dr), dr**-0.5, dtype),
        "b_a": jnp.zeros((dr,), jnp.float32),
        "w_x": _normal(keys[3], (dr, dr), dr**-0.5, dtype),
        "b_x": jnp.zeros((dr,), jnp.float32),
        # Λ init so that softplus(Λ) gives decay in [0.9, 0.999] range
        "lam": jnp.linspace(-2.0, 2.0, dr).astype(jnp.float32),
    }
    s = {
        "in_proj": P(None, MODEL),
        "out_proj": P(MODEL, None),
        "w_a": P(None, MODEL),
        "b_a": P(MODEL),
        "w_x": P(None, MODEL),
        "b_x": P(MODEL),
        "lam": P(MODEL),
    }
    p["conv"], s["conv"] = init_conv1d(keys[4], dr, cfg.rglru_conv_width, dtype)
    return p, s


def _gates(p, x):
    """x: (..., dr) → decay a_t (f32) and gated input (x dtype)."""
    r = jax.nn.sigmoid((x @ p["w_a"]).astype(jnp.float32) + p["b_a"])
    i = jax.nn.sigmoid((x @ p["w_x"]).astype(jnp.float32) + p["b_x"])
    log_a = -_C * jax.nn.softplus(p["lam"]) * r        # ≤ 0
    a = jnp.exp(log_a)
    beta = jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2.0 * log_a), 1e-12))
    u = beta * i * x.astype(jnp.float32)
    return a, u


def apply_rglru(p, cfg: ArchConfig, x):
    """Full-sequence Griffin recurrent block. x: (B, S, D) → (B, S, D)."""
    dr = cfg.d_rnn
    proj = x @ p["in_proj"]
    gate, xr = jnp.split(proj, 2, axis=-1)
    xr = apply_conv1d(p["conv"], xr)
    a, u = _gates(p, xr)                                # (B, S, dr) f32

    # h_t = a_t h_{t−1} + u_t  — associative scan with pairs (a, u)
    def combine(lhs, rhs):
        a1, u1 = lhs
        a2, u2 = rhs
        return a1 * a2, a2 * u1 + u2

    _, h = jax.lax.associative_scan(combine, (a, u), axis=1)
    h = h.astype(x.dtype)
    y = jax.nn.gelu(gate) * h
    return y @ p["out_proj"]


def init_rglru_cache(cfg: ArchConfig, batch, dtype):
    return {
        "conv": jnp.zeros((batch, cfg.rglru_conv_width - 1, cfg.d_rnn), dtype),
        "h": jnp.zeros((batch, cfg.d_rnn), jnp.float32),
    }


def rglru_cache_specs(worker_axes=()):
    data_axes = ("data",) if "data" not in worker_axes else ()
    bspec = tuple(worker_axes) + data_axes
    bs = bspec if bspec else None
    return {"conv": P(bs, None, MODEL), "h": P(bs, MODEL)}


def decode_rglru(p, cfg: ArchConfig, x_t, cache):
    """One-token decode. x_t: (B, 1, D)."""
    proj = x_t[:, 0, :] @ p["in_proj"]
    gate, xr = jnp.split(proj, 2, axis=-1)
    xr, conv_win = conv1d_step(p["conv"], cache["conv"], xr)
    a, u = _gates(p, xr)
    h = a * cache["h"] + u
    y = jax.nn.gelu(gate) * h.astype(x_t.dtype)
    return (y @ p["out_proj"])[:, None, :], {"conv": conv_win, "h": h}
