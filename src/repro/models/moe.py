"""Mixture-of-Experts layer with top-k routing and capacity-bounded dispatch.

Expert-parallel placement: the expert axis of every expert weight is sharded
on the ``model`` mesh axis, so the dispatch/combine einsums lower to the
all-to-all-style collectives the roofline analysis tracks. Token dropping
follows the standard capacity-factor discipline (dropped tokens pass through
the residual). The router load-balance auxiliary loss (Switch/Mixtral style)
is returned to be added to the training objective.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from ..configs.base import ArchConfig
from .layers import MODEL, _normal
from .mlp import _act


def init_moe(key, cfg: ArchConfig):
    dm, ff, e = cfg.d_model, cfg.d_ff, cfg.num_experts
    dtype = jnp.dtype(cfg.param_dtype)
    k1, k2, k3, k4 = jax.random.split(key, 4)
    p = {
        "router": _normal(k1, (dm, e), dm**-0.5, jnp.float32),
        "w_in": _normal(k2, (e, dm, ff), dm**-0.5, dtype),
        "w_gate": _normal(k3, (e, dm, ff), dm**-0.5, dtype),
        "w_out": _normal(k4, (e, ff, dm), ff**-0.5, dtype),
    }
    s = {
        "router": P(None, None),
        "w_in": P(MODEL, None, None),    # expert-parallel
        "w_gate": P(MODEL, None, None),
        "w_out": P(MODEL, None, None),
    }
    return p, s


def _capacity(cfg: ArchConfig, num_tokens: int) -> int:
    cap = int(cfg.capacity_factor * num_tokens * cfg.experts_per_token
              / cfg.num_experts)
    return max(8, (cap + 7) // 8 * 8)  # pad to 8 for tiling


def _constrain(v, *spec):
    """Best-effort sharding hint — inert off-mesh / under unsupported vmap."""
    try:
        from jax.sharding import PartitionSpec
        return jax.lax.with_sharding_constraint(v, PartitionSpec(*spec))
    except Exception:  # noqa: BLE001 — no mesh in scope / vmap limitation
        return v


def apply_moe(p, cfg: ArchConfig, x, *, shard_dispatch: bool = False):
    """x: (B, S, D) → (out (B, S, D), aux_loss scalar).

    ``shard_dispatch`` (§Perf lever): constrain the (E, cap, D) dispatch
    buffers to capacity-sharded-over-'data' so the expert einsums stay local
    instead of GSPMD replicating them."""
    b, s, d = x.shape
    e, k = cfg.num_experts, cfg.experts_per_token
    tokens = x.reshape(b * s, d)
    n = tokens.shape[0]
    cap = _capacity(cfg, n)

    logits = (tokens.astype(jnp.float32) @ p["router"])          # (n, E)
    probs = jax.nn.softmax(logits, axis=-1)
    top_p, top_e = jax.lax.top_k(probs, k)                       # (n, k)
    top_p = top_p / jnp.sum(top_p, axis=-1, keepdims=True)       # renormalize

    # Load-balance aux loss (Switch): E · Σ_e f_e · P_e
    me = jnp.mean(probs, axis=0)                                 # mean router prob
    ce = jnp.zeros((e,)).at[top_e.reshape(-1)].add(1.0) / (n * k)  # token frac
    aux = e * jnp.sum(me * ce)

    # Position of each (token, choice) within its expert's capacity buffer.
    # one-hot over experts per choice, cumsum over flattened (choice-major)
    # order gives intra-expert positions; entries ≥ cap are dropped.
    choice_eh = jax.nn.one_hot(top_e, e, dtype=jnp.int32)        # (n, k, E)
    flat = choice_eh.reshape(n * k, e)
    pos_in_e = jnp.cumsum(flat, axis=0) - flat                   # (n·k, E)
    pos = jnp.sum(pos_in_e * flat, axis=-1).reshape(n, k)        # (n, k)
    keep = pos < cap

    # dispatch: (n, k) → (E, cap) gather indices built by scatter
    tok_ids = jnp.broadcast_to(jnp.arange(n)[:, None], (n, k))
    gate = jnp.where(keep, top_p, 0.0)
    e_flat = jnp.where(keep, top_e, e)                           # drop → expert E
    p_flat = jnp.where(keep, pos, cap - 1)
    slot_tok = jnp.full((e + 1, cap), 0, jnp.int32)
    slot_tok = slot_tok.at[e_flat.reshape(-1), p_flat.reshape(-1)].set(
        tok_ids.reshape(-1)
    )
    slot_gate = jnp.zeros((e + 1, cap))
    slot_gate = slot_gate.at[e_flat.reshape(-1), p_flat.reshape(-1)].add(
        gate.reshape(-1)
    )
    slot_tok, slot_gate = slot_tok[:e], slot_gate[:e]            # (E, cap)

    xe = tokens[slot_tok]                                        # (E, cap, D)
    if shard_dispatch:
        xe = _constrain(xe, None, "data", None)
    act = _act(cfg.activation)
    h = act(jnp.einsum("ecd,edf->ecf", xe, p["w_gate"])) * jnp.einsum(
        "ecd,edf->ecf", xe, p["w_in"]
    )
    ye = jnp.einsum("ecf,efd->ecd", h, p["w_out"])               # (E, cap, D)
    if shard_dispatch:
        ye = _constrain(ye, None, "data", None)

    # combine: weighted scatter-add back to token order
    out = jnp.zeros((n, d), ye.dtype)
    out = out.at[slot_tok.reshape(-1)].add(
        (ye * slot_gate[..., None].astype(ye.dtype)).reshape(e * cap, d)
    )
    return out.reshape(b, s, d), aux
