"""Primitive layers: norms, projections, embeddings, RoPE, causal conv.

Every ``init_*`` returns ``(params, specs)`` — a param pytree and a
structurally identical :class:`jax.sharding.PartitionSpec` tree. Tensor-
parallel placement follows the Megatron convention on the ``model`` mesh
axis: column-parallel in-projections, row-parallel out-projections, vocab-
sharded embeddings. GSPMD inserts the matching collectives.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

MODEL = "model"


def _normal(key, shape, scale, dtype):
    return (scale * jax.random.normal(key, shape)).astype(dtype)


# --- linear ------------------------------------------------------------------

def init_linear(key, in_dim, out_dim, *, shard_out=True, bias=False,
                dtype=jnp.float32, scale=None):
    """weight (in, out). shard_out=True → column-parallel P(None, 'model');
    shard_out=False → row-parallel P('model', None)."""
    scale = scale if scale is not None else in_dim ** -0.5
    p = {"w": _normal(key, (in_dim, out_dim), scale, dtype)}
    s = {"w": P(None, MODEL) if shard_out else P(MODEL, None)}
    if bias:
        p["b"] = jnp.zeros((out_dim,), dtype)
        s["b"] = P(MODEL) if shard_out else P(None)
    return p, s


def apply_linear(p, x):
    y = x @ p["w"]
    if "b" in p:
        y = y + p["b"]
    return y


# --- norms ---------------------------------------------------------------------

def init_rmsnorm(dim, dtype=jnp.float32):
    return {"scale": jnp.ones((dim,), dtype)}, {"scale": P(None)}


def apply_rmsnorm(p, x, eps=1e-6):
    var = jnp.mean(jnp.square(x.astype(jnp.float32)), axis=-1, keepdims=True)
    y = x * jax.lax.rsqrt(var + eps).astype(x.dtype)
    return y * p["scale"].astype(x.dtype)


def init_layernorm(dim, dtype=jnp.float32):
    return (
        {"scale": jnp.ones((dim,), dtype), "bias": jnp.zeros((dim,), dtype)},
        {"scale": P(None), "bias": P(None)},
    )


def apply_layernorm(p, x, eps=1e-6):
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    y = (xf - mu) * jax.lax.rsqrt(var + eps)
    return (y * p["scale"].astype(jnp.float32) + p["bias"].astype(jnp.float32)).astype(
        x.dtype
    )


# --- embedding -------------------------------------------------------------------

def init_embedding(key, vocab, dim, dtype=jnp.float32):
    p = {"table": _normal(key, (vocab, dim), 0.02, dtype)}
    s = {"table": P(MODEL, None)}  # vocab-sharded
    return p, s


def apply_embedding(p, tokens):
    return p["table"][tokens]


# --- RoPE ----------------------------------------------------------------------

def rope_frequencies(head_dim, theta):
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim))


def apply_rope(x, positions, theta=10000.0):
    """x: (..., seq, heads, head_dim); positions: (..., seq)."""
    head_dim = x.shape[-1]
    freqs = rope_frequencies(head_dim, theta)                   # (hd/2,)
    angles = positions[..., :, None].astype(jnp.float32) * freqs  # (..., S, hd/2)
    cos = jnp.cos(angles)[..., :, None, :]                      # (..., S, 1, hd/2)
    sin = jnp.sin(angles)[..., :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# --- causal depthwise conv (mamba / RG-LRU temporal conv) -------------------------

def init_conv1d(key, channels, width, dtype=jnp.float32):
    p = {
        "w": _normal(key, (width, channels), channels ** -0.5, dtype),
        "b": jnp.zeros((channels,), dtype),
    }
    s = {"w": P(None, MODEL), "b": P(MODEL)}
    return p, s


def apply_conv1d(p, x):
    """Causal depthwise conv. x: (B, S, C) → (B, S, C)."""
    width = p["w"].shape[0]
    pad = jnp.pad(x, ((0, 0), (width - 1, 0), (0, 0)))
    out = sum(
        pad[:, i : i + x.shape[1], :] * p["w"][i] for i in range(width)
    )
    return out + p["b"]


def conv1d_step(p, window, x_t):
    """Single decode step. window: (B, width-1, C) past inputs; x_t: (B, C)."""
    width = p["w"].shape[0]
    full = jnp.concatenate([window, x_t[:, None, :]], axis=1)  # (B, width, C)
    out = jnp.einsum("bwc,wc->bc", full, p["w"]) + p["b"]
    return out, full[:, 1:, :]


def softcap(x, cap):
    return cap * jnp.tanh(x / cap)
