"""Grouped-query attention with RoPE, qk-norm, logit softcap, sliding window,
cross-attention, and a unified ring-buffer KV cache for decode.

Reference (pure-jnp) path — the Pallas flash kernel in ``repro.kernels``
computes the same math and is validated against this implementation.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from ..configs.base import ArchConfig
from ..kernels.flash_attention import ops as flash_ops
from ..kernels.flash_attention.ref import attention_ref
from .layers import (
    MODEL,
    _normal,
    apply_rmsnorm,
    apply_rope,
    init_rmsnorm,
    softcap,
)

NEG_INF = -1e30


def init_attention(key, cfg: ArchConfig, *, cross: bool = False):
    dm, h, kh, dh = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.head_dim_
    dtype = jnp.dtype(cfg.param_dtype)
    keys = jax.random.split(key, 6)
    scale = dm ** -0.5
    p = {
        "wq": _normal(keys[0], (dm, h, dh), scale, dtype),
        "wk": _normal(keys[1], (dm, kh, dh), scale, dtype),
        "wv": _normal(keys[2], (dm, kh, dh), scale, dtype),
        "wo": _normal(keys[3], (h, dh, dm), (h * dh) ** -0.5, dtype),
    }
    s = {
        "wq": P(None, MODEL, None),
        "wk": P(None, MODEL, None),
        "wv": P(None, MODEL, None),
        "wo": P(MODEL, None, None),
    }
    if cfg.qkv_bias and not cross:
        p["bq"] = jnp.zeros((h, dh), dtype)
        p["bk"] = jnp.zeros((kh, dh), dtype)
        p["bv"] = jnp.zeros((kh, dh), dtype)
        s["bq"] = P(MODEL, None)
        s["bk"] = P(MODEL, None)
        s["bv"] = P(MODEL, None)
    if cfg.qk_norm:
        for n in ("q_norm", "k_norm"):
            p[n], s[n] = init_rmsnorm(dh, dtype)
    return p, s


def _project_qkv(p, cfg: ArchConfig, x, kv_x):
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"])
    k = jnp.einsum("bsd,dhk->bshk", kv_x, p["wk"])
    v = jnp.einsum("bsd,dhk->bshk", kv_x, p["wv"])
    if "bq" in p:
        q, k, v = q + p["bq"], k + p["bk"], v + p["bv"]
    if cfg.qk_norm:
        q = apply_rmsnorm(p["q_norm"], q, cfg.norm_eps)
        k = apply_rmsnorm(p["k_norm"], k, cfg.norm_eps)
    return q, k, v


def gqa_scores(q, k, v, mask, *, scale, cap=None):
    """q: (B,S,H,Dh), k/v: (B,T,Kh,Dh), mask: broadcastable to (B,Kh,G,S,T)."""
    b, s, h, dh = q.shape
    kh = k.shape[2]
    g = h // kh
    qg = q.reshape(b, s, kh, g, dh)
    logits = jnp.einsum("bskgd,btkd->bkgst", qg, k).astype(jnp.float32) * scale
    if cap is not None:
        logits = softcap(logits, cap)
    logits = jnp.where(mask, logits, NEG_INF)
    probs = jax.nn.softmax(logits, axis=-1).astype(q.dtype)
    out = jnp.einsum("bkgst,btkd->bskgd", probs, v)
    return out.reshape(b, s, h, dh)


def causal_mask(s, t, *, offset=0, window=None):
    """(s, t) boolean mask. offset = (t - s) for prefill continuation."""
    qi = jnp.arange(s)[:, None] + offset
    ki = jnp.arange(t)[None, :]
    m = ki <= qi
    if window is not None:
        m &= ki > qi - window
    return m


# Sequences at or above this length use the chunked (flash-style) path:
# the O(S²) logit tensor is never materialized in HBM — the XLA analogue of
# the Pallas flash kernel (which replaces this on real TPUs). 4k training
# stays on the dense path (fits VMEM-tiled fusion fine); 32k+ does not.
CHUNKED_ATTN_THRESHOLD = 8192


def _chunked_attention(q, k, v, *, scale, cap, causal, window, block=1024):
    """Online-softmax attention over query blocks. q: (B,S,H,D); k/v:
    (B,T,Kh,D). O(S·D) HBM footprint; logits live only per (block × T)."""
    b, s, h, dh = q.shape
    kh, t = k.shape[2], k.shape[1]
    g = h // kh
    assert s % block == 0, (s, block)
    nq = s // block
    qb = q.reshape(b, nq, block, kh, g, dh).transpose(1, 0, 3, 4, 2, 5)
    ki = jnp.arange(t)

    def one_block(carry, inp):
        qi_block, idx = inp                       # (B,Kh,G,bq,D), scalar
        logits = jnp.einsum(
            "bkgsd,btkd->bkgst", qi_block.astype(jnp.float32),
            k.astype(jnp.float32)
        ) * scale
        if cap is not None:
            logits = cap * jnp.tanh(logits / cap)
        qi = idx * block + jnp.arange(block)
        mask = jnp.ones((block, t), bool)
        if causal:
            mask &= ki[None, :] <= qi[:, None]
        if window is not None:
            mask &= ki[None, :] > qi[:, None] - window
        logits = jnp.where(mask, logits, NEG_INF)
        probs = jax.nn.softmax(logits, axis=-1).astype(v.dtype)
        out = jnp.einsum("bkgst,btkd->bkgsd", probs, v)
        return carry, out

    _, outs = jax.lax.scan(one_block, None, (qb, jnp.arange(nq)))
    # outs: (nq, B, Kh, G, block, D) → (B, S, H, D)
    return outs.transpose(1, 0, 4, 2, 3, 5).reshape(b, s, h, dh)


def _flash_self_attention(q, k, v, *, scale, cap, window):
    """Self-causal attention on the model's (B, S, H, D) layout via the
    Pallas flash kernel (``cfg.attn_backend="pallas"``).

    The kernel has no transpose rule, so the backward pass differentiates
    the pure-jnp reference (`attention_ref`, validated against the kernel
    at rtol 1e-5) — forward Pallas, backward reference VJP.
    """
    def _ref(q, k, v):
        out = attention_ref(
            q.transpose(0, 2, 1, 3), k.transpose(0, 2, 1, 3),
            v.transpose(0, 2, 1, 3),
            causal=True, window=window, softcap=cap, scale=scale,
        )
        return out.transpose(0, 2, 1, 3)

    @jax.custom_vjp
    def f(q, k, v):
        out = flash_ops.attention(
            q.transpose(0, 2, 1, 3), k.transpose(0, 2, 1, 3),
            v.transpose(0, 2, 1, 3),
            causal=True, window=window, softcap=cap, scale=scale,
        )
        return out.transpose(0, 2, 1, 3)

    def fwd(q, k, v):
        return f(q, k, v), (q, k, v)

    def bwd(res, g):
        _, pull = jax.vjp(_ref, *res)
        return pull(g)

    f.defvjp(fwd, bwd)
    return f(q, k, v)


def apply_attention(
    p,
    cfg: ArchConfig,
    x,
    positions,
    *,
    window=None,
    causal=True,
    cross_states=None,
):
    """Full-sequence attention (train / prefill). x: (B, S, D)."""
    kv_x = cross_states if cross_states is not None else x
    q, k, v = _project_qkv(p, cfg, x, kv_x)
    if cross_states is None and cfg.pos_embed == "rope":
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)
    s_len, t_len = q.shape[1], k.shape[1]
    scale = cfg.attn_scale or cfg.head_dim_ ** -0.5
    is_self_causal = causal and cross_states is None
    if is_self_causal and cfg.attn_backend == "pallas":
        out = _flash_self_attention(
            q, k, v, scale=scale, cap=cfg.attn_softcap, window=window,
        )
    elif is_self_causal and s_len >= CHUNKED_ATTN_THRESHOLD:
        out = _chunked_attention(
            q, k, v, scale=scale, cap=cfg.attn_softcap,
            causal=True, window=window,
        )
    else:
        if is_self_causal:
            mask = causal_mask(s_len, t_len, window=window)
        else:
            mask = jnp.ones((s_len, t_len), dtype=bool)
        out = gqa_scores(q, k, v, mask, scale=scale, cap=cfg.attn_softcap)
    return jnp.einsum("bshk,hkd->bsd", out, p["wo"])


# ---------------------------------------------------------------------------
# KV cache (decode). Unified ring buffer: full attention uses W = max_seq,
# sliding-window layers use W = window — O(window) memory for long contexts.
# ---------------------------------------------------------------------------

def init_kv_cache(cfg: ArchConfig, batch, slots, dtype):
    kh, dh = cfg.num_kv_heads, cfg.head_dim_
    return {
        "k": jnp.zeros((batch, slots, kh, dh), dtype),
        "v": jnp.zeros((batch, slots, kh, dh), dtype),
        "pos": jnp.full((batch, slots), -1, jnp.int32),  # -1 = empty slot
    }


def kv_cache_specs(worker_axes=()):
    data_axes = ("data",) if "data" not in worker_axes else ()
    batch_spec = tuple(worker_axes) + data_axes
    spec = P(batch_spec if batch_spec else None, None, MODEL, None)
    return {"k": spec, "v": spec, "pos": P(batch_spec if batch_spec else None, None)}


def decode_attention(p, cfg: ArchConfig, x_t, pos_t, cache, *, window=None,
                     cross_states=None):
    """One-token decode. x_t: (B, 1, D); pos_t: (B,) current position.

    Returns (out (B,1,D), new_cache). Cross-attention decodes against the
    full encoder states instead of the cache.
    """
    if cross_states is not None:
        q, k, v = _project_qkv(p, cfg, x_t, cross_states)
        mask = jnp.ones((1, k.shape[1]), dtype=bool)
        scale = cfg.attn_scale or cfg.head_dim_ ** -0.5
        out = gqa_scores(q, k, v, mask, scale=scale, cap=cfg.attn_softcap)
        return jnp.einsum("bshk,hkd->bsd", out, p["wo"]), cache

    q, k, v = _project_qkv(p, cfg, x_t, x_t)
    if cfg.pos_embed == "rope":
        q = apply_rope(q, pos_t[:, None], cfg.rope_theta)
        k = apply_rope(k, pos_t[:, None], cfg.rope_theta)

    slots = cache["k"].shape[1]
    slot = (pos_t % slots).astype(jnp.int32)                     # (B,)
    b_idx = jnp.arange(k.shape[0])
    new_k = cache["k"].at[b_idx, slot].set(k[:, 0])
    new_v = cache["v"].at[b_idx, slot].set(v[:, 0])
    new_pos = cache["pos"].at[b_idx, slot].set(pos_t)
    new_cache = {"k": new_k, "v": new_v, "pos": new_pos}

    # validity: slot holds a real position, ≤ current, and within window
    valid = (new_pos >= 0) & (new_pos <= pos_t[:, None])
    if window is not None:
        valid &= new_pos > pos_t[:, None] - window
    mask = valid[:, None, None, None, :]                         # (B,1,1,1,T)
    scale = cfg.attn_scale or cfg.head_dim_ ** -0.5
    out = gqa_scores(q, new_k, new_v, mask, scale=scale, cap=cfg.attn_softcap)
    return jnp.einsum("bshk,hkd->bsd", out, p["wo"]), new_cache
