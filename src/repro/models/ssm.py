"""Mamba-2 block — SSD (state-space duality) formulation [arXiv:2405.21060].

Training/prefill uses the chunked SSD algorithm: intra-chunk work is dense
(MXU-friendly) matmuls against a lower-triangular decay matrix; inter-chunk
work is a tiny recurrence over per-chunk summary states — the TPU-idiomatic
adaptation of Mamba2's CUDA scan kernel. Decode carries an O(H·P·N) state.

Shapes: d_inner = expand·d_model, heads H = d_inner / P (P = head dim),
state size N, single B/C group shared across heads (n_groups = 1).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from ..configs.base import ArchConfig
from ..kernels.ssd_scan import ops as ssd_ops
from .layers import MODEL, _normal, apply_conv1d, apply_rmsnorm, conv1d_step, init_conv1d, init_rmsnorm


def init_ssm(key, cfg: ArchConfig):
    dm, di, n, h = cfg.d_model, cfg.d_inner, cfg.ssm_state, cfg.ssm_heads
    dtype = jnp.dtype(cfg.param_dtype)
    keys = jax.random.split(key, 5)
    conv_ch = di + 2 * n  # conv over [x, B, C]
    p = {
        # in_proj → [z (gate, di), x (di), B (n), C (n), dt (h)]
        "in_proj": _normal(keys[0], (dm, 2 * di + 2 * n + h), dm**-0.5, dtype),
        "out_proj": _normal(keys[1], (di, dm), di**-0.5, dtype),
        "a_log": jnp.log(jnp.linspace(1.0, 16.0, h)).astype(jnp.float32),
        "d_skip": jnp.ones((h,), jnp.float32),
        "dt_bias": jnp.zeros((h,), jnp.float32),
    }
    s = {
        "in_proj": P(None, MODEL),
        "out_proj": P(MODEL, None),
        "a_log": P(None),
        "d_skip": P(None),
        "dt_bias": P(None),
    }
    p["conv"], s["conv"] = init_conv1d(keys[2], conv_ch, cfg.ssm_conv_width, dtype)
    p["norm"], s["norm"] = init_rmsnorm(di, dtype)
    return p, s


def _segsum(x):
    """Stable segment-sum: out[..., i, j] = sum_{j < k <= i} x[..., k].

    x: (..., q) → (..., q, q) lower-triangular (−inf above diagonal).
    """
    q = x.shape[-1]
    cs = jnp.cumsum(x, axis=-1)
    diff = cs[..., :, None] - cs[..., None, :]
    mask = jnp.tril(jnp.ones((q, q), bool), k=0)
    return jnp.where(mask, diff, -jnp.inf)


def ssd_chunked(x, dt, a, b, c, chunk):
    """Chunked SSD scan.

    x: (B, L, H, P) · dt: (B, L, H) (post-softplus) · a: (H,) (negative)
    b, c: (B, L, N) (single group) → y: (B, L, H, P).
    """
    bsz, l, h, pdim = x.shape
    n = b.shape[-1]
    q = chunk
    nc = l // q
    assert l % q == 0, f"seq {l} not divisible by chunk {q}"

    out_dtype = x.dtype
    # SSD runs in f32: the cumulative decay products underflow in bf16
    x = x.astype(jnp.float32)
    b = b.astype(jnp.float32)
    c = c.astype(jnp.float32)
    dt = dt.astype(jnp.float32)

    # dt-discretized decay (log) and input
    da = dt * a  # (B, L, H), ≤ 0
    xdt = x * dt[..., None]

    def r(t, shape):
        return t.reshape(shape)

    xc = r(xdt, (bsz, nc, q, h, pdim))
    dac = r(da, (bsz, nc, q, h)).transpose(0, 1, 3, 2)       # (B,C,H,Q)
    bc = r(b, (bsz, nc, q, n))
    cc = r(c, (bsz, nc, q, n))

    da_cum = jnp.cumsum(dac, axis=-1)                        # (B,C,H,Q)

    # 1) intra-chunk (diagonal blocks): Y_d[i] = Σ_{j≤i} C_i·B_j e^{ΣdA} x_j
    ldecay = jnp.exp(_segsum(dac))                           # (B,C,H,Q,Q)
    scores = jnp.einsum("bcin,bcjn->bcij", cc, bc)           # (B,C,Q,Q)
    y_diag = jnp.einsum(
        "bcij,bchij,bcjhp->bcihp", scores, ldecay, xc
    )

    # 2) chunk summary states: S_c = Σ_j e^{Σ_{j<k≤Q} dA} B_j x_j
    decay_states = jnp.exp(da_cum[..., -1:] - da_cum)        # (B,C,H,Q)
    states = jnp.einsum("bcjn,bchj,bcjhp->bchpn", bc, decay_states, xc)

    # 3) inter-chunk recurrence over summary states
    chunk_decay = jnp.exp(da_cum[..., -1])                   # (B,C,H)

    def scan_fn(carry, inp):
        s_c, g_c = inp
        new = carry * g_c[..., None, None] + s_c
        return new, carry  # emit state *entering* the chunk

    init = jnp.zeros((bsz, h, pdim, n), x.dtype)
    _, states_in = jax.lax.scan(
        scan_fn,
        init,
        (states.transpose(1, 0, 2, 3, 4), chunk_decay.transpose(1, 0, 2)),
    )
    states_in = states_in.transpose(1, 0, 2, 3, 4)           # (B,C,H,P,N)

    # 4) inter-chunk output: Y_off[i] = C_i e^{Σ_{0<k≤i} dA} S_in
    in_decay = jnp.exp(da_cum)                               # (B,C,H,Q)
    y_off = jnp.einsum("bcin,bchi,bchpn->bcihp", cc, in_decay, states_in)

    return (y_diag + y_off).reshape(bsz, l, h, pdim).astype(out_dtype)


def _ssd_pallas(xh, dt, a, b, c, chunk):
    """SSD mixing via the Pallas scan kernel (``cfg.ssm_backend="pallas"``).

    The kernel has no transpose rule, so the backward pass differentiates
    the chunked jnp reference — forward Pallas, backward reference VJP.
    """
    @jax.custom_vjp
    def f(x, dt, a, b, c):
        return ssd_ops.ssd(x, dt, a, b, c, chunk=chunk)

    def fwd(x, dt, a, b, c):
        return f(x, dt, a, b, c), (x, dt, a, b, c)

    def bwd(res, g):
        _, pull = jax.vjp(lambda *z: ssd_chunked(*z, chunk), *res)
        return pull(g)

    f.defvjp(fwd, bwd)
    return f(xh, dt, a, b, c)


def apply_ssm(p, cfg: ArchConfig, x):
    """Full-sequence Mamba2 block. x: (B, S, D) → (B, S, D)."""
    di, n, h, pd = cfg.d_inner, cfg.ssm_state, cfg.ssm_heads, cfg.ssm_head_dim
    proj = x @ p["in_proj"]
    z, xbc, dt = jnp.split(proj, [di, 2 * di + 2 * n], axis=-1)
    xbc = apply_conv1d(p["conv"], xbc)
    xbc = jax.nn.silu(xbc)
    xs, b, c = jnp.split(xbc, [di, di + n], axis=-1)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])
    a = -jnp.exp(p["a_log"])
    xh = xs.reshape(*xs.shape[:2], h, pd)
    if cfg.ssm_backend == "pallas":
        y = _ssd_pallas(xh, dt, a, b, c, cfg.ssm_chunk)
    else:
        y = ssd_chunked(xh, dt, a, b, c, cfg.ssm_chunk)
    y = y + p["d_skip"][:, None].astype(y.dtype) * xh
    y = y.reshape(*xs.shape)
    y = apply_rmsnorm(p["norm"], y * jax.nn.silu(z), cfg.norm_eps)
    return y @ p["out_proj"]


def init_ssm_cache(cfg: ArchConfig, batch, dtype):
    di, n = cfg.d_inner, cfg.ssm_state
    conv_ch = di + 2 * n
    return {
        "conv": jnp.zeros((batch, cfg.ssm_conv_width - 1, conv_ch), dtype),
        "state": jnp.zeros((batch, cfg.ssm_heads, cfg.ssm_head_dim, n), jnp.float32),
    }


def ssm_cache_specs(worker_axes=()):
    data_axes = ("data",) if "data" not in worker_axes else ()
    bspec = tuple(worker_axes) + data_axes
    bs = bspec if bspec else None
    return {"conv": P(bs, None, MODEL), "state": P(bs, MODEL, None, None)}


def decode_ssm(p, cfg: ArchConfig, x_t, cache):
    """One-token decode. x_t: (B, 1, D) → (out, new_cache)."""
    di, n, h, pd = cfg.d_inner, cfg.ssm_state, cfg.ssm_heads, cfg.ssm_head_dim
    proj = x_t[:, 0, :] @ p["in_proj"]                        # (B, ·)
    z, xbc, dt = jnp.split(proj, [di, 2 * di + 2 * n], axis=-1)
    xbc, conv_win = conv1d_step(p["conv"], cache["conv"], xbc)
    xbc = jax.nn.silu(xbc)
    xs, b, c = jnp.split(xbc, [di, di + n], axis=-1)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])  # (B, H)
    a = -jnp.exp(p["a_log"])
    da = jnp.exp(dt * a)                                      # (B, H)
    xh = xs.reshape(-1, h, pd)
    # state' = e^{dtA} state + dt · x ⊗ B ;  y = C·state' + D·x
    state = cache["state"] * da[..., None, None]
    state = state + jnp.einsum("bhp,bn,bh->bhpn", xh.astype(jnp.float32),
                               b.astype(jnp.float32), dt)
    y = jnp.einsum("bhpn,bn->bhp", state, c.astype(jnp.float32)).astype(x_t.dtype)
    y = y + p["d_skip"][:, None].astype(y.dtype) * xh
    y = y.reshape(-1, di)
    y = apply_rmsnorm(p["norm"], y * jax.nn.silu(z), cfg.norm_eps)
    out = (y @ p["out_proj"])[:, None, :]
    return out, {"conv": conv_win, "state": state}
