"""Checkpointing: msgpack-serialized pytrees with dtype/shape manifests.

Host-side (gathers to host then writes) — adequate for the CPU container;
on a real pod this would be wrapped with per-host sharded writes, which the
manifest format already supports (each leaf records its PartitionSpec-less
global shape; loaders re-shard via ``jax.device_put``).
"""
from .serialize import load_pytree, save_pytree, restore_train_state, save_train_state

__all__ = [
    "load_pytree",
    "save_pytree",
    "restore_train_state",
    "save_train_state",
]
