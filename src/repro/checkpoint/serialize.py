"""msgpack pytree serialization with integrity manifest."""
from __future__ import annotations

import os

import jax
import jax.numpy as jnp
import msgpack
import numpy as np


def _pack_leaf(x) -> dict:
    arr = np.asarray(x)
    if arr.dtype == jnp.bfloat16:
        return {
            "dtype": "bfloat16",
            "shape": list(arr.shape),
            "data": arr.astype(np.float32).tobytes(),
        }
    return {
        "dtype": arr.dtype.name,
        "shape": list(arr.shape),
        "data": arr.tobytes(),
    }


def _unpack_leaf(d) -> np.ndarray:
    if d["dtype"] == "bfloat16":
        arr = np.frombuffer(d["data"], np.float32).reshape(d["shape"])
        return jnp.asarray(arr, jnp.bfloat16)
    return np.frombuffer(d["data"], np.dtype(d["dtype"])).reshape(d["shape"])


def save_pytree(path: str, tree) -> int:
    """Write ``tree`` atomically; returns bytes written (for telemetry —
    the engines attach it to their ``checkpoint`` spans)."""
    leaves, treedef = jax.tree.flatten(tree)
    payload = {
        "treedef": str(treedef),
        "leaves": [_pack_leaf(x) for x in leaves],
    }
    blob = msgpack.packb(payload)
    tmp = path + ".tmp"
    with open(tmp, "wb") as f:
        f.write(blob)
    os.replace(tmp, path)
    return len(blob)


def load_pytree(path: str, like):
    """Restore into the structure of ``like`` (shape/dtype checked)."""
    with open(path, "rb") as f:
        payload = msgpack.unpackb(f.read())
    leaves_like, treedef = jax.tree.flatten(like)
    stored = payload["leaves"]
    if len(stored) != len(leaves_like):
        raise ValueError(
            f"checkpoint has {len(stored)} leaves, expected {len(leaves_like)}"
        )
    out = []
    for d, ref in zip(stored, leaves_like):
        arr = _unpack_leaf(d)
        if tuple(arr.shape) != tuple(ref.shape):
            raise ValueError(f"shape mismatch {arr.shape} vs {ref.shape}")
        out.append(jnp.asarray(arr))
    return treedef.unflatten(out)


def save_train_state(path: str, state) -> None:
    save_pytree(path, state)


def restore_train_state(path: str, like):
    return load_pytree(path, like)
