"""Architecture + experiment configs."""
from .base import ArchConfig
from .registry import get_config, list_archs, smoke_config

__all__ = ["ArchConfig", "get_config", "list_archs", "smoke_config"]
