"""recurrentgemma-9b — [hybrid] RG-LRU + local attn, 1:2 [arXiv:2402.19427]"""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="recurrentgemma-9b",
    arch_type="hybrid",
    num_layers=38, d_model=4096, num_heads=16, num_kv_heads=1,
    d_ff=12288, vocab_size=256000, head_dim=256,
    layer_pattern="rg", sliding_window=2048,
    rglru_expand=1.0, rglru_conv_width=4,
    scale_embed=True, tie_embeddings=True, activation="gelu",
)
