"""whisper-small — [audio] enc-dec, conv frontend STUB [arXiv:2212.04356]"""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="whisper-small",
    arch_type="audio",
    num_layers=12, d_model=768, num_heads=12, num_kv_heads=12,
    d_ff=3072, vocab_size=51865,
    encoder_layers=12, encoder_seq=1500, frontend_dim=768,
    activation="gelu_plain", norm="layernorm", pos_embed="learned",
    max_seq_len=32768,   # decode_32k support; real whisper caps at 448
)
