"""Assigned-architecture registry: ``get_config(name)`` / ``--arch`` lookup.

One module per architecture under ``repro/configs/`` reproduces the published
configuration exactly (source cited in each module docstring);
``smoke_config`` derives the reduced CPU-testable variant (≤2 layers,
d_model ≤ 512, ≤4 experts) of the same family.
"""
from __future__ import annotations

import dataclasses

from .base import ArchConfig
from . import (
    codeqwen1_5_7b,
    gemma2_27b,
    granite_moe_1b_a400m,
    llama_3_2_vision_11b,
    mamba2_370m,
    mixtral_8x22b,
    qwen2_0_5b,
    qwen3_8b,
    recurrentgemma_9b,
    whisper_small,
)

_MODULES = (
    granite_moe_1b_a400m,
    qwen3_8b,
    mamba2_370m,
    codeqwen1_5_7b,
    gemma2_27b,
    whisper_small,
    qwen2_0_5b,
    mixtral_8x22b,
    llama_3_2_vision_11b,
    recurrentgemma_9b,
)

_REGISTRY: dict[str, ArchConfig] = {}
for _m in _MODULES:
    _m.CONFIG.validate()
    _REGISTRY[_m.CONFIG.name] = _m.CONFIG


def list_archs() -> list[str]:
    return sorted(_REGISTRY)


def get_config(name: str) -> ArchConfig:
    if name not in _REGISTRY:
        raise KeyError(f"unknown arch {name!r}; available: {list_archs()}")
    return _REGISTRY[name]


def smoke_config(name: str) -> ArchConfig:
    """Reduced same-family variant: ≤2 layers (3 for the rg pattern so a
    full recurrent-recurrent-attention period is exercised), d_model ≤ 512,
    ≤4 experts — runs a forward/train step on CPU in seconds."""
    cfg = get_config(name)
    kv = max(1, 4 * cfg.num_kv_heads // cfg.num_heads)
    layers = 3 if cfg.layer_pattern == "rg" else 2
    return dataclasses.replace(
        cfg,
        num_layers=layers,
        d_model=256,
        num_heads=4,
        num_kv_heads=kv,
        head_dim=64,
        d_ff=0 if cfg.d_ff == 0 else 512,
        vocab_size=512,
        num_experts=min(4, cfg.num_experts),
        experts_per_token=min(2, cfg.experts_per_token),
        # no token dropping in the reduced variant → decode ≡ forward exactly
        capacity_factor=8.0 if cfg.num_experts else cfg.capacity_factor,
        encoder_layers=2 if cfg.encoder_layers else 0,
        encoder_seq=16 if cfg.encoder_seq else 0,
        frontend_dim=256 if cfg.frontend_dim else 0,
        cross_attn_every=2 if cfg.cross_attn_every else 0,
        sliding_window=8 if cfg.sliding_window else None,
        ssm_state=16 if cfg.ssm_state else 0,
        ssm_head_dim=16,
        ssm_chunk=8,
        max_seq_len=128,
    )
