"""codeqwen1.5-7b — [dense] qwen1.5-arch (QKV bias, MHA) [hf:Qwen/CodeQwen1.5-7B]"""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="codeqwen1.5-7b",
    arch_type="dense",
    num_layers=32, d_model=4096, num_heads=32, num_kv_heads=32,
    d_ff=13440, vocab_size=92416,
    qkv_bias=True, rope_theta=1_000_000.0,
)
