"""mixtral-8x22b — [moe] 8 experts top-2, SWA [arXiv:2401.04088]"""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="mixtral-8x22b",
    arch_type="moe",
    num_layers=56, d_model=6144, num_heads=48, num_kv_heads=8,
    d_ff=16384, vocab_size=32768, head_dim=128,
    num_experts=8, experts_per_token=2,
    layer_pattern="swa", sliding_window=4096,
    rope_theta=1_000_000.0,
)
