"""gemma2-27b — [dense] local+global alternating, logit softcap [arXiv:2408.00118]"""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="gemma2-27b",
    arch_type="dense",
    num_layers=46, d_model=4608, num_heads=32, num_kv_heads=16,
    d_ff=36864, vocab_size=256000, head_dim=128,
    layer_pattern="local_global", sliding_window=4096,
    attn_softcap=50.0, final_softcap=30.0,
    attn_scale=144.0**-0.5,           # query_pre_attn_scalar = d_model/heads
    post_norm=True, scale_embed=True, tie_embeddings=True,
    activation="gelu",
)
