"""granite-moe-1b-a400m — [moe] 32 experts top-8 [hf:ibm-granite/granite-3.0-1b-a400m-base]"""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="granite-moe-1b-a400m",
    arch_type="moe",
    num_layers=24, d_model=1024, num_heads=16, num_kv_heads=8,
    d_ff=512, vocab_size=49155,
    num_experts=32, experts_per_token=8,
    tie_embeddings=True, activation="silu",
)
