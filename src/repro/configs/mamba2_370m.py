"""mamba2-370m — [ssm] SSD (state-space duality) [arXiv:2405.21060]"""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="mamba2-370m",
    arch_type="ssm",
    num_layers=48, d_model=1024, num_heads=1, num_kv_heads=1,
    d_ff=0, vocab_size=50280,
    layer_pattern="ssm",
    ssm_state=128, ssm_head_dim=64, ssm_expand=2, ssm_conv_width=4,
    tie_embeddings=True, norm_eps=1e-5,
)
