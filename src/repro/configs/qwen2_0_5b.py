"""qwen2-0.5b — [dense] GQA kv=2, QKV bias [arXiv:2407.10671]"""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="qwen2-0.5b",
    arch_type="dense",
    num_layers=24, d_model=896, num_heads=14, num_kv_heads=2,
    d_ff=4864, vocab_size=151936,
    qkv_bias=True, tie_embeddings=True, rope_theta=1_000_000.0,
)
