"""Architecture configuration schema.

One :class:`ArchConfig` instance fully describes a backbone in the model zoo
(dense GQA / MoE / SSM / hybrid / enc-dec / VLM). `layer_kinds()` expands the
per-layer block pattern the stack builder consumes.
"""
from __future__ import annotations

import dataclasses
from typing import Literal

LayerKind = Literal["attn", "attn_local", "ssm", "rglru"]


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    arch_type: str                 # dense | moe | ssm | hybrid | audio | vlm
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int | None = None    # default d_model // num_heads

    # --- attention features -------------------------------------------------
    qk_norm: bool = False          # qwen3
    qkv_bias: bool = False         # qwen2 / codeqwen (qwen1.5 arch)
    attn_softcap: float | None = None    # gemma2: 50.0
    final_softcap: float | None = None   # gemma2: 30.0
    sliding_window: int | None = None    # window size for local layers
    # layer pattern: 'global' (all full attn), 'local_global' (gemma2
    # alternation), 'swa' (all sliding window — mixtral), 'rg' (recurrentgemma
    # 2×RG-LRU : 1×local-attn), 'ssm' (all mamba2 blocks)
    layer_pattern: str = "global"
    rope_theta: float = 10000.0
    attn_scale: float | None = None      # override 1/sqrt(head_dim)

    # --- MoE -----------------------------------------------------------------
    num_experts: int = 0
    experts_per_token: int = 0
    capacity_factor: float = 1.25
    router_aux_weight: float = 0.01
    moe_shard_dispatch: bool = False   # §Perf: constrain dispatch buffers

    # --- SSM (mamba2 / SSD) --------------------------------------------------
    ssm_state: int = 0             # N (state dim per head)
    ssm_head_dim: int = 64         # P
    ssm_expand: int = 2            # d_inner = expand * d_model
    ssm_conv_width: int = 4
    ssm_chunk: int = 128           # SSD chunk length

    # --- RG-LRU (recurrentgemma) ----------------------------------------------
    rglru_expand: float = 1.5      # d_rnn ≈ expand * d_model (griffin uses 4/3·?; RG 9B: 4096→d_rnn 4096? use expand=1)
    rglru_conv_width: int = 4

    # --- enc-dec (whisper) / VLM (llama-3.2-vision) ----------------------------
    encoder_layers: int = 0        # >0 → encoder-decoder; encoder is non-causal
    encoder_seq: int = 0           # frames/patches provided by the stub frontend
    cross_attn_every: int = 0      # VLM: insert cross-attn layer every N layers
    frontend_dim: int = 0          # stub embedding dim (== d_model after projector)

    # --- misc ------------------------------------------------------------------
    activation: str = "silu"       # silu (SwiGLU) | gelu (GeGLU) | gelu_plain
    norm_eps: float = 1e-6
    tie_embeddings: bool = False
    max_seq_len: int = 8192
    post_norm: bool = False        # gemma2: extra norm after each sub-block
    scale_embed: bool = False      # gemma family: embed ·= sqrt(d_model)
    norm: str = "rmsnorm"          # rmsnorm | layernorm (whisper)
    pos_embed: str = "rope"        # rope | learned (whisper)

    # --- kernel backends --------------------------------------------------------
    # "reference" = pure-jnp paths; "pallas" routes self-causal attention
    # through kernels.flash_attention and SSD mixing through kernels.ssd_scan
    # (forward Pallas, backward via the reference VJP).
    attn_backend: str = "reference"
    ssm_backend: str = "reference"

    # --- numerics ---------------------------------------------------------------
    param_dtype: str = "float32"
    compute_dtype: str = "float32"
    # scan layer groups (fast compile, O(1) HLO in depth) vs unroll (slower
    # compile; XLA cost_analysis then counts every layer — used by §Roofline)
    scan_layers: bool = True

    @property
    def head_dim_(self) -> int:
        return self.head_dim or self.d_model // self.num_heads

    @property
    def is_encoder_decoder(self) -> bool:
        return self.encoder_layers > 0

    @property
    def d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def ssm_heads(self) -> int:
        return self.d_inner // self.ssm_head_dim

    @property
    def d_rnn(self) -> int:
        # round to a multiple of 128 for TPU-friendly tiling
        d = int(self.rglru_expand * self.d_model)
        return (d + 127) // 128 * 128

    def layer_kinds(self) -> list[dict]:
        """Expand the pattern into per-layer block descriptors."""
        kinds: list[dict] = []
        for i in range(self.num_layers):
            if self.layer_pattern == "global":
                kind = {"kind": "attn", "window": None}
            elif self.layer_pattern == "swa":
                kind = {"kind": "attn", "window": self.sliding_window}
            elif self.layer_pattern == "local_global":
                # gemma2: even layers local (SW), odd layers global
                w = self.sliding_window if i % 2 == 0 else None
                kind = {"kind": "attn", "window": w}
            elif self.layer_pattern == "rg":
                # recurrentgemma: (RG-LRU, RG-LRU, local attn) repeating
                if i % 3 == 2:
                    kind = {"kind": "attn", "window": self.sliding_window}
                else:
                    kind = {"kind": "rglru", "window": None}
            elif self.layer_pattern == "ssm":
                kind = {"kind": "ssm", "window": None}
            else:
                raise ValueError(f"unknown layer_pattern {self.layer_pattern}")
            kind["moe"] = self.num_experts > 0
            kind["cross_attn"] = bool(
                self.cross_attn_every
                and (i % self.cross_attn_every == self.cross_attn_every - 1)
            ) or (self.is_encoder_decoder and kind["kind"].startswith("attn"))
            kinds.append(kind)
        return kinds

    def pattern_period(self) -> int:
        """Length of the repeating layer-kind period — the scan body covers
        one period (layers are stacked across period repetitions)."""
        import math

        base = {"global": 1, "swa": 1, "ssm": 1, "local_global": 2, "rg": 3}[
            self.layer_pattern
        ]
        if self.cross_attn_every:
            base = math.lcm(base, self.cross_attn_every)
        if self.is_encoder_decoder:
            base = 1  # enc-dec decoders are uniform (cross-attn every layer)
        return base

    def num_groups(self) -> int:
        return self.num_layers // self.pattern_period()

    def tail_layers(self) -> int:
        return self.num_layers % self.pattern_period()

    def validate(self) -> None:
        assert self.attn_backend in ("reference", "pallas"), self.attn_backend
        assert self.ssm_backend in ("reference", "pallas"), self.ssm_backend
        assert self.d_model % self.num_heads == 0 or self.head_dim
        assert self.num_heads % max(self.num_kv_heads, 1) == 0
        if self.num_experts:
            assert 0 < self.experts_per_token <= self.num_experts
        if self.layer_pattern in ("swa", "local_global", "rg"):
            assert self.sliding_window
