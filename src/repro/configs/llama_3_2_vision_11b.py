"""llama-3.2-vision-11b — [vlm] cross-attn image layers [hf:meta-llama/Llama-3.2-11B-Vision]"""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="llama-3.2-vision-11b",
    arch_type="vlm",
    num_layers=40, d_model=4096, num_heads=32, num_kv_heads=8,
    d_ff=14336, vocab_size=128256,
    cross_attn_every=5,                 # 8 cross-attn layers of 40
    encoder_seq=6404, frontend_dim=4096,  # 4 tiles × 1601 patches, post-projector
    rope_theta=500_000.0,
)
