"""Per-architecture smoke tests: reduced config, one forward + one LocalAdaSEG
train step on CPU; asserts output shapes and absence of NaNs."""
import jax
import jax.numpy as jnp
import pytest

from repro.configs import get_config, list_archs, smoke_config
from repro.core.adaseg import AdaSEGConfig
from repro.launch.mesh import make_test_mesh
from repro.launch.train import (
    TrainPlan,
    init_train_state,
    make_batches,
    make_round_fn,
)
from repro.models import forward, init_model, loss_fn
from repro.models.transformer import encode

ARCHS = list_archs()
B, S = 2, 16


def _batch(cfg, rng):
    tokens = jax.random.randint(rng, (B, S), 0, cfg.vocab_size)
    batch = {"tokens": tokens, "labels": tokens}
    if cfg.encoder_seq:
        batch["frontend"] = 0.1 * jax.random.normal(
            rng, (B, cfg.encoder_seq, cfg.d_model)
        )
    return batch


@pytest.mark.parametrize("arch", ARCHS)
def test_full_config_matches_assignment(arch):
    cfg = get_config(arch)
    cfg.validate()
    assert cfg.num_layers >= 12
    assert cfg.vocab_size > 1000


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_forward_shapes_no_nan(arch):
    cfg = smoke_config(arch)
    params, specs = init_model(jax.random.PRNGKey(0), cfg)
    assert jax.tree.structure(params) == jax.tree.structure(specs)
    batch = _batch(cfg, jax.random.PRNGKey(1))
    enc = None
    if cfg.is_encoder_decoder:
        enc = encode(params, cfg, batch["frontend"])
        assert enc.shape == (B, cfg.encoder_seq, cfg.d_model)
    elif cfg.cross_attn_every:
        enc = batch["frontend"]
    logits, aux = forward(params, cfg, batch["tokens"], enc_states=enc)
    assert logits.shape == (B, S, cfg.vocab_size)
    assert bool(jnp.all(jnp.isfinite(logits)))
    assert bool(jnp.isfinite(aux))


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_train_step(arch):
    """One LocalAdaSEG round (2 workers × 2 local EG steps) on CPU."""
    cfg = smoke_config(arch)
    mesh = make_test_mesh(1, 1)
    plan = TrainPlan(
        cfg=cfg,
        adaseg=AdaSEGConfig(g0=5.0, diameter=1.0, alpha=1.0, k=2,
                            average_output=False),
        worker_mode="paper",
        k_local=2,
        global_batch=2,
        seq=S,
    )
    state = init_train_state(jax.random.PRNGKey(0), plan, mesh)
    batches = make_batches(jax.random.PRNGKey(1), plan, mesh)
    round_fn = jax.jit(make_round_fn(plan))
    new_state, metrics = round_fn(state, batches)
    assert bool(jnp.all(jnp.isfinite(metrics["loss"])))
    assert float(new_state.sum_sq.sum()) > 0.0
    assert int(new_state.t) == 2
    # params actually moved
    moved = any(
        float(jnp.max(jnp.abs(a - b))) > 0
        for a, b in zip(
            jax.tree.leaves(new_state.params), jax.tree.leaves(state.params)
        )
    )
    assert moved


def test_loss_decreases_qwen2_smoke():
    """A few hundred LocalAdaSEG steps reduce LM loss on the synthetic
    Markov-Zipf stream (end-to-end trainability)."""
    cfg = smoke_config("qwen2-0.5b")
    mesh = make_test_mesh(1, 1)
    plan = TrainPlan(
        cfg=cfg,
        adaseg=AdaSEGConfig(g0=20.0, diameter=2.0, alpha=1.0, k=5,
                            average_output=False),
        worker_mode="paper",
        k_local=5,
        global_batch=4,
        seq=32,
    )
    state = init_train_state(jax.random.PRNGKey(0), plan, mesh)
    round_fn = jax.jit(make_round_fn(plan))
    losses = []
    for r in range(12):
        batches = make_batches(jax.random.PRNGKey(100 + r), plan, mesh)
        state, metrics = round_fn(state, batches)
        losses.append(float(metrics["loss"].mean()))
    assert losses[-1] < losses[0] - 0.3, losses
