"""Docs integrity: every relative link and anchor in docs/*.md and README.md
resolves, and every ``repro.*`` code reference in the docs imports — so the
paper-map table cannot silently rot when code moves."""
import importlib
import re
from pathlib import Path

import pytest

REPO = Path(__file__).resolve().parent.parent
DOC_FILES = sorted((REPO / "docs").glob("*.md")) + [REPO / "README.md"]

_LINK = re.compile(r"\[[^\]]+\]\(([^)\s]+)\)")
_HEADING = re.compile(r"^#{1,6}\s+(.*)$", re.MULTILINE)
_CODE_REF = re.compile(r"`(repro(?:\.\w+)+)`")


def _github_slug(heading: str) -> str:
    """GitHub's anchor slugification: lowercase, drop punctuation, spaces
    and slashes to hyphens."""
    h = heading.strip().lower()
    h = re.sub(r"[^\w\- ]", "", h)
    return h.replace(" ", "-")


def _anchors(path: Path) -> set[str]:
    return {_github_slug(m.group(1))
            for m in _HEADING.finditer(path.read_text())}


def test_docs_tree_exists():
    for name in ("paper_map.md", "architecture.md", "formats.md"):
        assert (REPO / "docs" / name).is_file(), f"docs/{name} missing"


@pytest.mark.parametrize("md", DOC_FILES, ids=lambda p: p.name)
def test_markdown_links_resolve(md):
    text = md.read_text()
    for m in _LINK.finditer(text):
        target = m.group(1)
        if target.startswith(("http://", "https://", "mailto:")):
            continue
        path_part, _, anchor = target.partition("#")
        dest = (md.parent / path_part).resolve() if path_part else md
        assert dest.exists(), f"{md.name}: broken link {target!r}"
        if anchor:
            assert dest.suffix == ".md", (
                f"{md.name}: anchor on non-markdown target {target!r}")
            assert anchor in _anchors(dest), (
                f"{md.name}: missing anchor {target!r} "
                f"(have: {sorted(_anchors(dest))})")


@pytest.mark.parametrize("md", DOC_FILES, ids=lambda p: p.name)
def test_code_references_import(md):
    """Backticked ``repro.x.y.z`` references must resolve to real modules /
    attributes (module prefix imported, remainder getattr-chained)."""
    refs = sorted({m.group(1) for m in _CODE_REF.finditer(md.read_text())})
    for ref in refs:
        parts = ref.split(".")
        mod, i = None, len(parts)
        while i > 1:
            try:
                mod = importlib.import_module(".".join(parts[:i]))
                break
            except ModuleNotFoundError:
                i -= 1
        assert mod is not None, f"{md.name}: unimportable reference {ref!r}"
        obj = mod
        for attr in parts[i:]:
            assert hasattr(obj, attr), (
                f"{md.name}: {ref!r} — {'.'.join(parts[:i])} has no "
                f"attribute chain {'.'.join(parts[i:])!r}")
            obj = getattr(obj, attr)


def test_readme_links_into_docs():
    text = (REPO / "README.md").read_text()
    for name in ("paper_map", "architecture", "formats"):
        assert f"docs/{name}.md" in text, f"README does not link docs/{name}"


def test_every_ps_export_has_a_doctest_example():
    """The PR's doctest guarantee: every symbol exported by repro.ps carries
    a runnable ``>>>`` example (in its own docstring or its class's)."""
    import repro.ps as ps

    missing = []
    for name in ps.__all__:
        obj = getattr(ps, name)
        doc = obj.__doc__ or ""
        if ">>>" not in doc:
            # dataclass bases: the example may live on the parent protocol
            bases = getattr(obj, "__mro__", ())[1:2]
            if not any(">>>" in (b.__doc__ or "") for b in bases):
                missing.append(name)
    assert not missing, f"ps exports without doctest examples: {missing}"
