"""Benchmark trajectory persistence and the CI perf-regression gate:
append-only BENCH_*.json entries, baseline selection by backend, the
warn/fail tolerance bands, the exact-metric class, and — the acceptance
pin — a nonzero exit on an injected synthetic regression."""
import json

import pytest

from benchmarks import common, regress
from benchmarks.run import EXEMPT, _check_registry, registry


@pytest.fixture()
def json_dir(tmp_path):
    """Point the persistence layer at a scratch dir, restore after."""
    old = common._JSON_DIR
    common.set_json_dir(tmp_path)
    yield tmp_path
    common.set_json_dir(old)


def _entry(results, run=0, backend="cpu"):
    return {"run": run, "backend": backend, "results": results}


def _write(json_dir, bench, entries):
    (json_dir / f"BENCH_{bench}.json").write_text(
        json.dumps({"bench": bench, "entries": entries}))


BASE = {"step_us": 100.0, "steps_per_sec": 1000.0,
        "bytes_up_per_round": 80.0, "residual": 0.2}


# ---------------------------------------------------------------------------
# Persistence
# ---------------------------------------------------------------------------

def test_persist_trajectory_appends(json_dir, capsys):
    common.persist_trajectory("demo", {"x_us": 1.0})
    common.persist_trajectory("demo", {"x_us": 2.0})
    payload = json.loads((json_dir / "BENCH_demo.json").read_text())
    assert payload["bench"] == "demo"
    assert [e["run"] for e in payload["entries"]] == [0, 1]
    assert all("backend" in e for e in payload["entries"])
    assert payload == common.load_trajectory("demo")
    assert "demo:persist" in capsys.readouterr().out


def test_load_missing_trajectory_is_empty(json_dir):
    assert common.load_trajectory("nope") == {"bench": "nope", "entries": []}


# ---------------------------------------------------------------------------
# Gate verdicts
# ---------------------------------------------------------------------------

def test_gate_ok_on_flat_trajectory(json_dir):
    _write(json_dir, "ps", [_entry(BASE, 0), _entry(BASE, 1)])
    assert regress.gate(("ps",), verbose=False) == 0


def test_gate_fails_on_injected_regression(json_dir):
    slow = dict(BASE, step_us=1000.0)          # 10× synthetic regression
    _write(json_dir, "ps", [_entry(BASE, 0), _entry(slow, 1)])
    assert regress.gate(("ps",), verbose=False) == 1
    # and through the CLI, which is what the CI job invokes
    assert regress.main(["--json-dir", str(json_dir), "--bench", "ps"]) == 1


def test_gate_fails_on_throughput_drop(json_dir):
    slow = dict(BASE, steps_per_sec=100.0)     # higher-better metric
    _write(json_dir, "ps", [_entry(BASE, 0), _entry(slow, 1)])
    assert regress.gate(("ps",), verbose=False) == 1


def test_gate_warns_inside_band(json_dir, capsys):
    meh = dict(BASE, step_us=140.0)            # +40%: warn < 0.6 fail
    _write(json_dir, "ps", [_entry(BASE, 0), _entry(meh, 1)])
    assert regress.gate(("ps",), verbose=True) == 0
    assert "WARN" in capsys.readouterr().out


def test_exact_metric_drift_is_a_hard_failure(json_dir):
    drift = dict(BASE, bytes_up_per_round=81.0)  # deterministic quantity
    _write(json_dir, "ps", [_entry(BASE, 0), _entry(drift, 1)])
    assert regress.gate(("ps",), verbose=False) == 1


def test_gate_skips_cross_backend_and_short_trajectories(json_dir):
    _write(json_dir, "ps", [_entry(BASE, 0, backend="tpu"),
                            _entry(dict(BASE, step_us=1e6), 1)])
    assert regress.gate(("ps",), verbose=False) == 0   # no cpu baseline
    _write(json_dir, "kernels", [_entry(BASE, 0)])
    assert regress.gate(("kernels",), verbose=False) == 0  # single entry


def test_nested_results_are_flattened(json_dir):
    base = {"codec_per_round_us": {"q8/reference": 100.0}}
    bad = {"codec_per_round_us": {"q8/reference": 1000.0}}
    _write(json_dir, "ps", [_entry(base, 0), _entry(bad, 1)])
    assert regress.gate(("ps",), verbose=False) == 1


def test_improvements_pass(json_dir):
    fast = dict(BASE, step_us=10.0, steps_per_sec=9000.0)
    _write(json_dir, "ps", [_entry(BASE, 0), _entry(fast, 1)])
    assert regress.gate(("ps",), verbose=False) == 0


# ---------------------------------------------------------------------------
# Orchestrator registry
# ---------------------------------------------------------------------------

def test_registry_covers_every_bench_module():
    """Every benchmarks/bench_*.py is wired into run.py (or EXEMPT)."""
    _check_registry(registry())                # raises on a missing module


def test_registry_check_catches_missing():
    benches = [b for b in registry() if "kernels" not in b[0]]
    with pytest.raises(RuntimeError, match="bench_kernels"):
        _check_registry(benches)
    assert "bench_roofline" in EXEMPT          # env-gated separate entry


def test_select_filters_by_substring():
    """--only keeps matching labels, --skip drops them, and a filter that
    matches nothing is an error (a typo must not silently run everything)."""
    from benchmarks.run import select

    benches = registry()
    only = select(benches, only=["fleet"])
    assert [lbl for lbl, _ in only] == ["extra:fleet"]
    skipped = select(benches, skip=["fleet"])
    assert len(skipped) == len(benches) - 1
    assert all("fleet" not in lbl for lbl, _ in skipped)
    assert select(benches) == benches          # no filters: identity
    with pytest.raises(SystemExit, match="matches no bench label"):
        select(benches, only=["nope"])
    with pytest.raises(SystemExit, match="matches no bench label"):
        select(benches, skip=["nope"])


def test_committed_trajectories_are_gateable():
    """The repo ships ≥3 trajectories the CI perf-gate runs against, each
    loadable and carrying ≥1 complete entry."""
    found = 0
    for bench in regress.BENCHES:
        payload = common.load_trajectory(bench)
        if not payload["entries"]:
            continue
        found += 1
        for e in payload["entries"]:
            assert {"run", "backend", "results"} <= e.keys()
            assert regress._flatten(e["results"])   # gateable scalars
    assert found >= 3
