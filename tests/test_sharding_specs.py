"""Unit tests for PartitionSpec utilities and the distributed sync math."""
import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.sharding.specs import abstract_mesh, fsdp_spec, sanitize_spec, stack_spec


def _mesh22():
    # device-free stand-in: spec logic reads only shape/axis names
    return abstract_mesh((2, 2), ("data", "model"))


def test_stack_spec():
    assert stack_spec(P(None, "model"), ("pod", "data")) == P(
        ("pod", "data"), None, "model"
    )
    assert stack_spec(P("model"), ("data",)) == P("data", "model")
    assert stack_spec(P(), ()) == P(None)


def test_sanitize_drops_nondivisible():
    mesh = _mesh22()
    # 7 not divisible by model=2 → dropped
    assert sanitize_spec(P(None, "model"), (4, 7), mesh) == P(None, None)
    assert sanitize_spec(P(None, "model"), (4, 8), mesh) == P(None, "model")
    # tuple axes partially kept
    got = sanitize_spec(P(("data", "model"), None), (2, 8), mesh)
    assert got == P("data", None)


def test_fsdp_spec_picks_first_free_divisible_dim():
    mesh = _mesh22()
    assert fsdp_spec(P(None, "model"), (4, 8), mesh) == P("data", "model")
    # dim0 occupied → dim1
    assert fsdp_spec(P("model", None), (4, 8), mesh) == P("model", "data")
    # 1-D leaves untouched
    assert fsdp_spec(P(None), (4,), mesh) == P(None)
    # nothing divisible → unchanged
    assert fsdp_spec(P(None, "model"), (3, 8), mesh) == P(None, "model")


def test_weighted_sync_math_matches_serial():
    """The stacked weighted average equals the explicit PS-model average."""
    from repro.core import sync_weighted_stacked

    m, d = 4, 6
    z = {"w": jnp.arange(m * d, dtype=jnp.float32).reshape(m, d)}
    inv_eta = jnp.array([0.5, 1.0, 1.5, 2.0])
    w = np.asarray(inv_eta / inv_eta.sum())
    expect = (w[:, None] * np.asarray(z["w"])).sum(0)
    got = sync_weighted_stacked(z, inv_eta)
    for i in range(m):
        np.testing.assert_allclose(got["w"][i], expect, rtol=1e-6)
