"""Fused sync-codec path (kernels/sync_compress): kernel↔reference parity,
engine-level codec-backend parity, and the error-feedback telescoping
invariant that makes biased codecs safe.

Parity bars (the PR's acceptance criteria, same structure as the PR-1 step
kernels): identity and top-k are bit-exact between backends; stochastic
quantize agrees within rtol=1e-5 under the shared threefry derivation (both
backends draw identical rounding bits; residual float noise is jit
fusion-level only)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import AdaSEGConfig
from repro.core.adaseg import sync_weighted_stacked
from repro.kernels.sync_compress import ref as sc_ref
from repro.kernels.sync_compress.ops import (
    codec_passes,
    codec_uplink,
    codec_uplink_stacked,
    sync_merge_stacked,
)
from repro.problems import make_bilinear_game
from repro.ps import (
    AsyncPSConfig,
    AsyncPSEngine,
    BernoulliFaults,
    ConstantLatency,
    IdentityCompressor,
    PSConfig,
    PSEngine,
    StochasticQuantizeCompressor,
    StragglerSchedule,
    TopKCompressor,
)

M = 4

CODECS = [
    (("identity",), True),
    (("topk", 0.25), True),
    (("quantize", 8), False),
]


@pytest.fixture(scope="module")
def stacked():
    key = jax.random.PRNGKey(0)
    z = {
        "a": jax.random.normal(key, (M, 333)),
        "b": jax.random.normal(jax.random.fold_in(key, 1), (M, 7, 5)),
    }
    ef = jax.tree.map(lambda v: 0.05 * v, z)
    return z, ef


@pytest.fixture(scope="module")
def game():
    return make_bilinear_game(jax.random.PRNGKey(0), n=8, sigma=0.1)


def _cfg(k=4):
    return AdaSEGConfig(g0=1.0, diameter=2.0, alpha=1.0, k=k)


def _assert_parity(a, b, exact, rtol=1e-5, atol=1e-6):
    for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(b)):
        if exact:
            np.testing.assert_array_equal(np.asarray(x), np.asarray(y))
        else:
            np.testing.assert_allclose(np.asarray(x), np.asarray(y),
                                       rtol=rtol, atol=atol)


# ---------------------------------------------------------------------------
# Ops-level parity: fused kernels vs pure-jnp references, same jit context.
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("codec,exact", CODECS)
@pytest.mark.parametrize("with_alive", [False, True])
def test_uplink_fused_matches_reference(stacked, codec, exact, with_alive):
    z, ef = stacked
    w = jnp.array([0.1, 0.4, 0.2, 0.3])
    alive = jnp.array([1.0, 0.0, 1.0, 1.0]) if with_alive else None
    e = None if codec[0] == "identity" else ef
    rngs = jax.random.split(jax.random.PRNGKey(3), M)
    out_f = codec_uplink_stacked(z, rngs, w=w, ef=e, alive=alive,
                                 codec=codec)
    out_r = codec_uplink_stacked(z, rngs, w=w, ef=e, alive=alive,
                                 codec=codec, use_kernel=False)
    _assert_parity(out_f[0], out_r[0], exact)
    if out_f[1] is not None:
        _assert_parity(out_f[1], out_r[1], exact)


def test_uplink_dead_worker_sends_zero_and_freezes_ef(stacked):
    z, ef = stacked
    alive = jnp.array([1.0, 0.0, 1.0, 1.0])
    rngs = jax.random.split(jax.random.PRNGKey(3), M)
    sent, ef_new = codec_uplink_stacked(z, rngs, ef=ef, alive=alive,
                                        codec=("quantize", 8))
    for s, e_new, e_old in zip(jax.tree.leaves(sent),
                               jax.tree.leaves(ef_new),
                               jax.tree.leaves(ef)):
        assert float(jnp.abs(s[1]).max()) == 0.0
        np.testing.assert_array_equal(np.asarray(e_new[1]),
                                      np.asarray(e_old[1]))
        assert float(jnp.abs(s[0]).max()) > 0.0


def test_topk_keeps_exactly_k_entries(stacked):
    z, _ = stacked
    rngs = jax.random.split(jax.random.PRNGKey(3), M)
    sent, _ = codec_uplink_stacked(z, rngs, codec=("topk", 0.25))
    for s in jax.tree.leaves(sent):
        n = s[0].size
        k = max(1, int(np.ceil(0.25 * n)))
        nz = (np.asarray(s).reshape(M, -1) != 0).sum(axis=1)
        assert (nz <= k).all() and (nz >= 1).all()


def test_quantize_shared_rng_derivation_is_the_compressors():
    """The fused uplink (no weights, no EF) must reproduce the reference
    ``StochasticQuantizeCompressor.compress`` — the two backends draw from
    one rng derivation, not two streams that merely look alike."""
    comp = StochasticQuantizeCompressor(bits=8)
    msg = {"g": jax.random.normal(jax.random.PRNGKey(1), (257,))}
    rng = jax.random.PRNGKey(9)
    ref = jax.jit(comp.compress)(msg, rng)
    fused, _ = codec_uplink(msg, rng, codec=("quantize", 8))
    np.testing.assert_allclose(np.asarray(fused["g"]), np.asarray(ref["g"]),
                               rtol=1e-5, atol=1e-7)
    # identical rounding decisions: same level index everywhere
    scale = float(jnp.maximum(jnp.max(jnp.abs(msg["g"])), 1e-30))
    lvl = scale / 255.0
    np.testing.assert_array_equal(
        np.rint(np.asarray(fused["g"]) / lvl).astype(int),
        np.rint(np.asarray(ref["g"]) / lvl).astype(int))


def test_merge_fused_matches_sync_weighted_stacked(stacked):
    z, _ = stacked
    inv_eta = jnp.array([0.5, 1.0, 1.5, 2.0])
    expected = jax.jit(sync_weighted_stacked)(z, inv_eta)
    fused = sync_weighted_stacked(z, inv_eta, backend="fused")
    _assert_parity(fused, expected, exact=True)
    # survivor gating: non-receivers keep their old row
    recv = jnp.array([1.0, 0.0, 1.0, 1.0])
    old = jax.tree.map(lambda v: v + 7.0, z)
    gated = sync_merge_stacked(z, inv_eta, recv=recv, old=old,
                               normalize=True)
    ref = sync_merge_stacked(z, inv_eta, recv=recv, old=old, normalize=True,
                             use_kernel=False)
    _assert_parity(gated, ref, exact=True)
    for g, o in zip(jax.tree.leaves(gated), jax.tree.leaves(old)):
        np.testing.assert_array_equal(np.asarray(g[1]), np.asarray(o[1]))


def test_codec_pass_model_is_a_traffic_win():
    for codec in (("identity",), ("quantize", 8), ("topk", 0.25)):
        ref_passes, fused_passes = codec_passes(codec)
        assert fused_passes < ref_passes


# ---------------------------------------------------------------------------
# Error-feedback telescoping: Σ_r sent_r = Σ_r msg_r + ef_0 − ef_R, so the
# compression error never accumulates — for BOTH backends.
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("codec", [("quantize", 4), ("topk", 0.25)])
@pytest.mark.parametrize("use_kernel", [True, False])
def test_error_feedback_telescopes(codec, use_kernel):
    key = jax.random.PRNGKey(0)
    w = jnp.array([0.25, 0.35, 0.4])
    ef = {"g": jnp.zeros((3, 101))}
    sent_sum = {"g": jnp.zeros((3, 101))}
    msg_sum = {"g": jnp.zeros((3, 101))}
    for r in range(6):
        key, kz, kc = jax.random.split(key, 3)
        z = {"g": jax.random.normal(kz, (3, 101))}
        rngs = jax.random.split(kc, 3)
        sent, ef = codec_uplink_stacked(z, rngs, w=w, ef=ef, codec=codec,
                                        use_kernel=use_kernel)
        msg = jax.tree.map(lambda v: w[:, None] * v, z)
        sent_sum = jax.tree.map(jnp.add, sent_sum, sent)
        msg_sum = jax.tree.map(jnp.add, msg_sum, msg)
    np.testing.assert_allclose(
        np.asarray(sent_sum["g"]) + np.asarray(ef["g"]),
        np.asarray(msg_sum["g"]), rtol=1e-4, atol=1e-5)
    # and the residual actually carries mass for a biased codec
    assert float(jnp.abs(ef["g"]).max()) > 0.0


# ---------------------------------------------------------------------------
# Engine-level parity: the codec_backend switch end to end (serial + async;
# the sharded path is pinned in tests/test_distributed.py).
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("codec_cls,exact", [
    (IdentityCompressor, True),
    (lambda: TopKCompressor(fraction=0.25), True),
    (lambda: StochasticQuantizeCompressor(bits=8), False),
])
@pytest.mark.parametrize("hostile", [False, True])
def test_engine_codec_backend_parity(game, codec_cls, exact, hostile):
    comp = codec_cls() if callable(codec_cls) else codec_cls
    faults = BernoulliFaults(p=0.3, seed=5) if hostile else None
    schedule = (StragglerSchedule(k=4, min_frac=0.5, seed=7)
                if hostile else None)
    outs = {}
    for cb in ("reference", "fused"):
        pscfg = PSConfig(adaseg=_cfg(), num_workers=M, rounds=3,
                         compressor=comp, faults=faults, schedule=schedule,
                         codec_backend=cb)
        eng = PSEngine(game.problem, pscfg, rng=jax.random.PRNGKey(2))
        outs[cb] = (eng.run(), eng.state, eng._ef)
        assert eng.trace.meta["codec_backend"] == cb
    _assert_parity(outs["reference"], outs["fused"], exact)


def test_async_engine_codec_backend_parity(game):
    lat = ConstantLatency(step_s=(1.0, 1.0, 4.0, 1.0), up_s=0.2, down_s=0.1)
    outs = {}
    for cb in ("reference", "fused"):
        acfg = AsyncPSConfig(adaseg=_cfg(), num_workers=M, rounds=3,
                             latency=lat, staleness_bound=1.0,
                             compressor=StochasticQuantizeCompressor(bits=8),
                             codec_backend=cb)
        eng = AsyncPSEngine(game.problem, acfg, rng=jax.random.PRNGKey(2))
        outs[cb] = (eng.run(), eng.state, eng._ef)
    _assert_parity(outs["reference"], outs["fused"], exact=False)


def test_fused_lockstep_still_bit_exact_with_sync_engine(game):
    """The async engine's sync-as-special-case guarantee must survive the
    fused codec backend: degenerate latency + identity compression executes
    the synchronous engine's own (fused-merge) round chunk, so the two
    engines agree bit-exactly by shared code. (The guarantee is scoped to
    identity compression, as in PR 4: lossy codecs have per-payload async
    wire semantics — the server, not the sender, applies the Line-7
    weights — so sync and async quantize different tensors by design.)"""
    pscfg = PSConfig(adaseg=_cfg(), num_workers=M, rounds=3,
                     codec_backend="fused")
    sync_eng = PSEngine(game.problem, pscfg, rng=jax.random.PRNGKey(2))
    z_sync = sync_eng.run()
    acfg = AsyncPSConfig(adaseg=_cfg(), num_workers=M, rounds=3,
                         codec_backend="fused", staleness_bound=0.0)
    async_eng = AsyncPSEngine(game.problem, acfg, rng=jax.random.PRNGKey(2))
    z_async = async_eng.run()
    assert async_eng._lockstep_chunk is not None
    _assert_parity((z_sync, sync_eng.state), (z_async, async_eng.state),
                   exact=True)


def test_unknown_codec_backend_rejected(game):
    with pytest.raises(ValueError, match="codec backend"):
        PSEngine(game.problem,
                 PSConfig(adaseg=_cfg(), num_workers=M, rounds=2,
                          codec_backend="turbo"),
                 rng=jax.random.PRNGKey(0))


def test_custom_compressor_without_spec_rejected_on_fused(game):
    class Custom(IdentityCompressor):
        @property
        def codec_spec(self):
            return None

    with pytest.raises(ValueError, match="codec_spec"):
        PSEngine(game.problem,
                 PSConfig(adaseg=_cfg(), num_workers=M, rounds=2,
                          compressor=Custom(), codec_backend="fused"),
                 rng=jax.random.PRNGKey(0))


def test_threefry_uniform_matches_kernel_counters():
    """The shared derivation is blocking-invariant: in-kernel counters at
    any block size reproduce the reference stream bit-for-bit."""
    from repro.kernels.sync_compress.kernel import quantize_uplink

    key = jax.random.PRNGKey(11)
    z = jax.random.normal(jax.random.PRNGKey(1), (1, 700))
    seeds = key.reshape(1, 2)
    scale = jnp.maximum(jnp.max(jnp.abs(z), axis=1), 1e-30)
    outs = [
        quantize_uplink(z, seeds, scale, levels=255.0, block=b,
                        interpret=True)[0]
        for b in (64, 256, 700)
    ]
    for o in outs[1:]:
        np.testing.assert_array_equal(np.asarray(outs[0]), np.asarray(o))
    # and the stream itself is the compressor's
    u = sc_ref.threefry_uniform(key, 700)
    y = jnp.abs(z[0]) / scale[0] * 255.0
    lo = jnp.floor(y)
    expect = jnp.sign(z[0]) * (lo + (u < y - lo)) * (scale[0] / 255.0)
    np.testing.assert_allclose(np.asarray(outs[0][0]), np.asarray(expect),
                               rtol=1e-5, atol=1e-7)
