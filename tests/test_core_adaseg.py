"""Unit + property tests for the core LocalAdaSEG algorithm (Algorithm 1)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (
    AdaSEGConfig,
    eta_of,
    init,
    local_step,
    run_local_adaseg,
    sync_weighted_stacked,
)
from repro.problems import make_bilinear_game, make_quadratic_game


@pytest.fixture(scope="module")
def game():
    return make_bilinear_game(jax.random.PRNGKey(0), n=10, sigma=0.1)


def test_eta_schedule_matches_hand_rolled(game):
    """η_t = D·α/sqrt(G0² + Σ (Z_τ)²), recomputed from the aux trace."""
    cfg = AdaSEGConfig(g0=1.0, diameter=2.0, alpha=1.0, k=5)
    state = init(game.problem, cfg, jax.random.PRNGKey(1))
    rngs = jax.random.split(jax.random.PRNGKey(2), 20)
    etas, zsqs = [], []
    for r in rngs:
        state, aux = local_step(game.problem, cfg, state, r)
        etas.append(float(aux.eta))
        zsqs.append(float(aux.z_sq))
    expected = [cfg.diameter * cfg.alpha / np.sqrt(cfg.g0**2 + sum(zsqs[:i]))
                for i in range(len(zsqs))]
    np.testing.assert_allclose(etas, expected, rtol=1e-5)


def test_eta_monotone_nonincreasing(game):
    cfg = AdaSEGConfig(g0=1.0, diameter=2.0, alpha=1.0, k=5)
    state = init(game.problem, cfg, jax.random.PRNGKey(1))
    last = np.inf
    for r in jax.random.split(jax.random.PRNGKey(3), 50):
        state, aux = local_step(game.problem, cfg, state, r)
        assert float(aux.eta) <= last + 1e-12
        last = float(aux.eta)


def test_z_bounded_by_projection(game):
    """All iterates stay in the box (Assumption 1 enforcement)."""
    cfg = AdaSEGConfig(g0=1.0, diameter=2.0, alpha=1.0, k=5)
    state = init(game.problem, cfg, jax.random.PRNGKey(1))
    for r in jax.random.split(jax.random.PRNGKey(4), 20):
        state, _ = local_step(game.problem, cfg, state, r)
        for leaf in jax.tree.leaves(state.z_tilde):
            assert jnp.all(jnp.abs(leaf) <= 1.0 + 1e-6)


def test_sync_weights_form_simplex():
    z = {"x": jnp.arange(12.0).reshape(4, 3)}
    inv_eta = jnp.array([1.0, 2.0, 3.0, 4.0])
    out = sync_weighted_stacked(z, inv_eta)
    # all workers share the same average afterwards
    for m in range(1, 4):
        np.testing.assert_allclose(out["x"][0], out["x"][m], rtol=1e-6)
    w = inv_eta / inv_eta.sum()
    np.testing.assert_allclose(
        out["x"][0], (w[:, None] * z["x"]).sum(0), rtol=1e-6
    )


def test_single_worker_sync_is_noop(game):
    """With M=1 the weighted sync must leave the iterate unchanged, so
    LocalAdaSEG degenerates to the serial adaptive EG of Bach & Levy."""
    from repro.core import sync_state

    cfg = AdaSEGConfig(g0=1.0, diameter=2.0, alpha=1.0, k=10)
    state = init(game.problem, cfg, jax.random.PRNGKey(7))
    stacked = jax.tree.map(lambda v: v[None] if hasattr(v, "ndim") else v,
                           state)
    synced = sync_state(stacked, cfg, sync_weighted_stacked)
    for a, b in zip(jax.tree.leaves(stacked.z_tilde),
                    jax.tree.leaves(synced.z_tilde)):
        np.testing.assert_allclose(a, b, rtol=1e-6)
    # determinism of the full driver
    z1, _ = run_local_adaseg(
        game.problem, cfg, num_workers=1, rounds=4, rng=jax.random.PRNGKey(7)
    )
    z2, _ = run_local_adaseg(
        game.problem, cfg, num_workers=1, rounds=4, rng=jax.random.PRNGKey(7)
    )
    for a, b in zip(jax.tree.leaves(z1), jax.tree.leaves(z2)):
        np.testing.assert_allclose(a, b, rtol=0, atol=0)


def test_convergence_bilinear(game):
    z0 = game.problem.init(jax.random.PRNGKey(1))
    r0 = float(game.residual(z0))
    cfg = AdaSEGConfig(g0=1.0, diameter=float(np.sqrt(40.0)), alpha=1.0, k=50)
    zbar, _ = run_local_adaseg(
        game.problem, cfg, num_workers=4, rounds=20, rng=jax.random.PRNGKey(2)
    )
    r = float(game.residual(zbar))
    assert r < r0 / 10, (r0, r)
    assert float(game.duality_gap(zbar)) >= -1e-5


def test_convergence_quadratic_smooth():
    qg = make_quadratic_game(jax.random.PRNGKey(5), n=10, sigma=0.1)
    m = 4
    cfg = AdaSEGConfig(g0=1.0, diameter=10.0, alpha=1.0 / np.sqrt(m), k=10)
    zbar, _ = run_local_adaseg(
        qg.problem, cfg, num_workers=m, rounds=100, rng=jax.random.PRNGKey(6)
    )
    assert float(qg.distance_to_saddle(zbar)) < 0.2


def test_async_variant_converges(game):
    """Heterogeneous K_m (Appendix E.1) still converges."""
    cfg = AdaSEGConfig(g0=1.0, diameter=float(np.sqrt(40.0)), alpha=1.0, k=50)
    zbar, (state, _) = run_local_adaseg(
        game.problem, cfg, num_workers=4, rounds=20,
        rng=jax.random.PRNGKey(8),
        local_steps=jnp.array([50, 45, 40, 35]),
    )
    assert float(game.residual(zbar)) < 0.5
    # workers really did different numbers of steps
    np.testing.assert_array_equal(
        np.asarray(state.t), np.array([50, 45, 40, 35]) * 20
    )


def test_output_average_in_domain(game):
    cfg = AdaSEGConfig(g0=1.0, diameter=2.0, alpha=1.0, k=5)
    zbar, _ = run_local_adaseg(
        game.problem, cfg, num_workers=3, rounds=5, rng=jax.random.PRNGKey(9)
    )
    for leaf in jax.tree.leaves(zbar):
        assert jnp.all(jnp.abs(leaf) <= 1.0 + 1e-6)
