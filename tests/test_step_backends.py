"""Parity tests for the pluggable step backend (reference vs fused Pallas).

The acceptance bar: ``run_local_adaseg(..., backend="fused")`` must trace
the same trajectory as the reference tree-op backend within rtol=1e-5 on
the bilinear game, across every projection the kernels fuse (box for
BilinearGame, identity for WGAN, l2-ball) — and opaque projections must
fall back to the reference math bit-for-bit.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (
    AdaSEGConfig,
    init,
    local_step,
    projections,
    run_local_adaseg,
)
from repro.problems import make_bilinear_game
from repro.problems.wgan import make_wgan_problem


@pytest.fixture(scope="module")
def game():
    return make_bilinear_game(jax.random.PRNGKey(0), n=10, sigma=0.1)


def _assert_trees_close(a, b, rtol=1e-5, atol=1e-7):
    for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(b)):
        np.testing.assert_allclose(np.asarray(x), np.asarray(y),
                                   rtol=rtol, atol=atol)


def test_fused_step_matches_reference_box(game):
    """Single fused step (box clip) == reference step: iterate and aux."""
    cfg = AdaSEGConfig(g0=1.0, diameter=2.0, alpha=1.0, k=5)
    state = init(game.problem, cfg, jax.random.PRNGKey(1))
    for r in jax.random.split(jax.random.PRNGKey(2), 5):
        s_ref, a_ref = local_step(game.problem, cfg, state, r)
        s_fus, a_fus = local_step(game.problem, cfg, state, r,
                                  backend="fused")
        # atol absorbs FMA-contraction ulp noise on near-zero elements
        # (the two programs fuse differently under XLA CPU)
        _assert_trees_close(s_ref.z_tilde, s_fus.z_tilde, atol=1e-6)
        np.testing.assert_allclose(float(a_ref.z_sq), float(a_fus.z_sq),
                                   rtol=1e-5)
        np.testing.assert_allclose(float(a_ref.grad_norm_sq),
                                   float(a_fus.grad_norm_sq), rtol=1e-5)
        np.testing.assert_allclose(float(a_ref.eta), float(a_fus.eta),
                                   rtol=0, atol=0)
        state = s_ref


def test_fused_trajectory_matches_reference_bilinear(game):
    """Multi-round multi-worker trajectories agree to rtol=1e-5 (the PR's
    acceptance criterion) on the paper's box-constrained bilinear game."""
    cfg = AdaSEGConfig(g0=1.0, diameter=2.0, alpha=1.0, k=5)
    z_ref, (s_ref, _) = run_local_adaseg(
        game.problem, cfg, num_workers=4, rounds=4, rng=jax.random.PRNGKey(2)
    )
    z_fus, (s_fus, _) = run_local_adaseg(
        game.problem, cfg, num_workers=4, rounds=4,
        rng=jax.random.PRNGKey(2), backend="fused",
    )
    _assert_trees_close(z_ref, z_fus)
    _assert_trees_close(s_ref.z_tilde, s_fus.z_tilde)
    np.testing.assert_allclose(np.asarray(s_ref.sum_sq),
                               np.asarray(s_fus.sum_sq), rtol=1e-5)
    np.testing.assert_allclose(np.asarray(s_ref.grad_sq_sum),
                               np.asarray(s_fus.grad_sq_sum), rtol=1e-5)
    np.testing.assert_array_equal(np.asarray(s_ref.t), np.asarray(s_fus.t))


def test_fused_trajectory_l2_ball(game):
    """The l2-ball projection routes through the two-pass kernel scheme."""
    cfg = AdaSEGConfig(g0=1.0, diameter=3.0, alpha=1.0, k=5)
    prob = dataclasses.replace(game.problem,
                               project=projections.l2_ball(1.5))
    z_ref, (s_ref, _) = run_local_adaseg(
        prob, cfg, num_workers=3, rounds=4, rng=jax.random.PRNGKey(3)
    )
    z_fus, (s_fus, _) = run_local_adaseg(
        prob, cfg, num_workers=3, rounds=4, rng=jax.random.PRNGKey(3),
        backend="fused",
    )
    _assert_trees_close(z_ref, z_fus)
    np.testing.assert_allclose(np.asarray(s_ref.sum_sq),
                               np.asarray(s_fus.sum_sq), rtol=1e-5)
    # iterates actually live on the ball boundary at least once → the
    # projection was exercised, not a no-op
    from repro.core.tree import tree_norm

    assert float(tree_norm(s_fus.z_tilde)) > 0.0
    for leaf in jax.tree.leaves(z_fus):
        assert np.all(np.isfinite(np.asarray(leaf)))


def test_fused_trajectory_wgan_identity():
    """Unconstrained WGAN (identity projection, nested MLP pytrees) routes
    through the kernel without projection. The gradient-penalty double
    backward chaotically amplifies ulp-level reduction/fusion noise, so the
    tolerance is looser here (the bilinear tests carry the rtol=1e-5 bar)."""
    wg = make_wgan_problem(jax.random.PRNGKey(1), hidden=16, batch=16)
    cfg = AdaSEGConfig(g0=5.0, diameter=10.0, alpha=0.5, k=2)
    z_ref, _ = run_local_adaseg(
        wg.problem, cfg, num_workers=2, rounds=2, rng=jax.random.PRNGKey(3)
    )
    z_fus, _ = run_local_adaseg(
        wg.problem, cfg, num_workers=2, rounds=2, rng=jax.random.PRNGKey(3),
        backend="fused",
    )
    _assert_trees_close(z_ref, z_fus, rtol=1e-3, atol=1e-4)


def test_opaque_projection_falls_back_bitwise(game):
    """Projections without a spec (simplex) must run the reference math —
    bit-identical results, no semantics fork."""
    cfg = AdaSEGConfig(g0=1.0, diameter=3.0, alpha=1.0, k=5)
    prob = dataclasses.replace(game.problem, project=projections.simplex())
    z_ref, _ = run_local_adaseg(
        prob, cfg, num_workers=2, rounds=2, rng=jax.random.PRNGKey(4)
    )
    z_fus, _ = run_local_adaseg(
        prob, cfg, num_workers=2, rounds=2, rng=jax.random.PRNGKey(4),
        backend="fused",
    )
    for a, b in zip(jax.tree.leaves(z_ref), jax.tree.leaves(z_fus)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_projection_specs_tagged():
    assert projections.spec_of(projections.identity()) == ("identity",)
    assert projections.spec_of(projections.box(-1.0, 1.0)) == \
        ("box", -1.0, 1.0)
    assert projections.spec_of(projections.l2_ball(2.0)) == ("l2", 2.0)
    assert projections.spec_of(projections.simplex()) is None
    assert projections.spec_of(lambda z: z) is None


def test_unknown_backend_raises(game):
    cfg = AdaSEGConfig(g0=1.0, diameter=2.0, alpha=1.0, k=1)
    state = init(game.problem, cfg, jax.random.PRNGKey(0))
    with pytest.raises(ValueError):
        local_step(game.problem, cfg, state, jax.random.PRNGKey(1),
                   backend="turbo")


def test_fused_backend_converges(game):
    """End-to-end: the fused backend actually solves the bilinear game."""
    z0 = game.problem.init(jax.random.PRNGKey(1))
    r0 = float(game.residual(z0))
    cfg = AdaSEGConfig(g0=1.0, diameter=float(np.sqrt(40.0)), alpha=1.0,
                       k=50)
    zbar, _ = run_local_adaseg(
        game.problem, cfg, num_workers=4, rounds=10,
        rng=jax.random.PRNGKey(2), backend="fused",
    )
    assert float(game.residual(zbar)) < r0 / 5
