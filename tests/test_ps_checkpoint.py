"""Checkpoint round-tripping for the PS engine: save mid-run, resume, and
the trajectory must match an uninterrupted run bit-exactly (serial path).
The sharded-path resume (rtol=1e-5) lives in tests/test_distributed.py."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import AdaSEGConfig
from repro.problems import make_bilinear_game
from repro.ps import (
    BernoulliFaults,
    PSConfig,
    PSEngine,
    StochasticQuantizeCompressor,
    StragglerSchedule,
)

M, R = 4, 6


@pytest.fixture(scope="module")
def game():
    return make_bilinear_game(jax.random.PRNGKey(0), n=10, sigma=0.1)


def _pscfg(**kw):
    return PSConfig(
        adaseg=AdaSEGConfig(g0=1.0, diameter=2.0, alpha=1.0, k=5),
        num_workers=M, rounds=R, **kw)


def _assert_trees_equal(a, b):
    for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(b)):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


@pytest.mark.parametrize("stop_at", [1, 3, 5])
def test_resume_is_bit_exact(game, tmp_path, stop_at):
    pscfg = _pscfg()
    straight = PSEngine(game.problem, pscfg, rng=jax.random.PRNGKey(9))
    z_straight = straight.run()

    path = str(tmp_path / "engine.msgpack")
    first = PSEngine(game.problem, pscfg, rng=jax.random.PRNGKey(9))
    first.run(until_round=stop_at)
    first.save(path)

    resumed = PSEngine(game.problem, pscfg, rng=jax.random.PRNGKey(9))
    resumed.restore(path)
    assert resumed.round == stop_at
    z_resumed = resumed.run()

    _assert_trees_equal(z_straight, z_resumed)
    _assert_trees_equal(straight.state, resumed.state)


def test_resume_with_all_policies_bit_exact(game, tmp_path):
    """The full gauntlet: stragglers + error-feedback quantization + faults.
    Error-feedback memory must round-trip through the checkpoint too."""
    pscfg = _pscfg(
        schedule=StragglerSchedule(k=5, min_frac=0.4, seed=3),
        compressor=StochasticQuantizeCompressor(bits=8),
        faults=BernoulliFaults(p=0.2, seed=5),
    )
    straight = PSEngine(game.problem, pscfg, rng=jax.random.PRNGKey(11))
    z_straight = straight.run()

    path = str(tmp_path / "engine.msgpack")
    first = PSEngine(game.problem, pscfg, rng=jax.random.PRNGKey(11))
    first.run(until_round=3)
    first.save(path)

    resumed = PSEngine(game.problem, pscfg, rng=jax.random.PRNGKey(11))
    resumed.restore(path)
    z_resumed = resumed.run()

    _assert_trees_equal(z_straight, z_resumed)
    _assert_trees_equal(straight.state, resumed.state)
    _assert_trees_equal(straight._ef, resumed._ef)


def test_checkpoint_every_writes_resumable_file(game, tmp_path):
    path = str(tmp_path / "periodic.msgpack")
    engine = PSEngine(game.problem, _pscfg(), rng=jax.random.PRNGKey(4))
    engine.run(checkpoint_path=path, checkpoint_every=2)
    resumed = PSEngine(game.problem, _pscfg(), rng=jax.random.PRNGKey(4))
    resumed.restore(path)
    assert resumed.round == R           # final checkpoint covers the run
    _assert_trees_equal(engine.state, resumed.state)


def test_restore_rejects_wrong_seed(game, tmp_path):
    path = str(tmp_path / "engine.msgpack")
    engine = PSEngine(game.problem, _pscfg(), rng=jax.random.PRNGKey(0))
    engine.run(until_round=2)
    engine.save(path)
    other = PSEngine(game.problem, _pscfg(), rng=jax.random.PRNGKey(1))
    with pytest.raises(ValueError, match="different seed"):
        other.restore(path)
