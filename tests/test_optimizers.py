"""Optimizer-zoo sanity on the strongly-convex-concave quadratic (closed-form
saddle): every method must make progress; EG-family beats SGDA; minibatch
reduces variance."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.optim import (
    adam_minimax,
    asmp,
    minibatch,
    run_local,
    run_serial,
    segda,
    sgda,
    ump,
)
from repro.problems import make_quadratic_game


@pytest.fixture(scope="module")
def game():
    return make_quadratic_game(jax.random.PRNGKey(0), n=8, sigma=0.1)


@pytest.mark.parametrize("make_opt", [
    lambda: sgda(0.05),
    lambda: segda(0.05),
    lambda: adam_minimax(0.05),
    lambda: ump(2.0, 8.0),
    lambda: asmp(2.0, 8.0),
])
def test_serial_progress(game, make_opt):
    opt = make_opt()
    st0 = opt.init(game.problem, jax.random.PRNGKey(1))
    d0 = float(game.distance_to_saddle(st0.z))
    st, _ = run_serial(opt, game.problem, steps=600,
                       rng=jax.random.PRNGKey(1), record_every=100)
    d = float(game.distance_to_saddle(st.z_bar))
    assert d < d0 * 0.5, (opt.name, d0, d)


def test_minibatch_reduces_variance(game):
    """Minibatched oracle must have ~1/B the gradient variance."""
    p = game.problem
    z = p.init(jax.random.PRNGKey(2))

    def sample_grads(problem, n, rng):
        gs = []
        for r in jax.random.split(rng, n):
            g = problem.oracle(z, problem.sample(r))
            gs.append(jnp.concatenate([g[0], g[1]]))
        return jnp.stack(gs)

    g1 = sample_grads(p, 64, jax.random.PRNGKey(3))
    g16 = sample_grads(minibatch(p, 16), 64, jax.random.PRNGKey(4))
    v1 = float(jnp.mean(jnp.var(g1, axis=0)))
    v16 = float(jnp.mean(jnp.var(g16, axis=0)))
    assert v16 < v1 / 8, (v1, v16)


def test_local_wrapper_syncs(game):
    """After run_local, all workers hold the same anchor (last sync +
    divergence bounded), and the averaged output is sensible."""
    st, hist = run_local(segda(0.05), game.problem, num_workers=4,
                         local_k=10, rounds=20, rng=jax.random.PRNGKey(5))
    zg = jax.tree.map(lambda v: v.mean(0), st.z_bar)
    assert float(game.distance_to_saddle(zg)) < 2.0
    # history improves over rounds
    d_first = float(game.distance_to_saddle(
        jax.tree.map(lambda v: v[0], hist)))
    d_last = float(game.distance_to_saddle(
        jax.tree.map(lambda v: v[-1], hist)))
    assert d_last < d_first


def test_ump_sync_weight_is_inverse_eta(game):
    opt = ump(2.0, 8.0)
    st = opt.init(game.problem, jax.random.PRNGKey(6))
    w0 = float(opt.sync_weight(st))
    st, _ = run_serial(opt, game.problem, steps=50,
                       rng=jax.random.PRNGKey(6), record_every=50)
    w1 = float(opt.sync_weight(st))
    assert w1 > w0  # accumulates → η shrinks → weight 1/η grows
