"""Roofline HLO-parser tests on synthetic HLO text."""
from repro.roofline.analysis import collective_stats, model_flops

HLO = """
HloModule jit_round_fn

fused_computation {
  ...
}

ENTRY main {
  %p0 = bf16[16,4096]{1,0} parameter(0)
  %ag = bf16[256,4096]{1,0} all-gather(bf16[16,4096]{1,0} %p0), dimensions={0}
  %ar = f32[1024]{0} all-reduce(f32[1024]{0} %x), to_apply=%add
  %ars = f32[2048]{0} all-reduce-start(f32[2048]{0} %y), to_apply=%add
  %ard = f32[2048]{0} all-reduce-done(f32[2048]{0} %ars)
  %rs = bf16[8,128]{1,0} reduce-scatter(bf16[64,128]{1,0} %z), dimensions={0}
  %a2a = f32[4,256]{1,0} all-to-all(f32[4,256]{1,0} %w), dimensions={0}
  %cp = u32[2]{0} collective-permute(u32[2]{0} %v), source_target_pairs={{0,1}}
}
"""


def test_collective_bytes_counted():
    stats = collective_stats(HLO)
    by = stats["bytes_by_kind"]
    assert by["all-gather"] == 256 * 4096 * 2
    # plain all-reduce + async start counted once each
    assert by["all-reduce"] == 1024 * 4 + 2048 * 4
    assert by["reduce-scatter"] == 8 * 128 * 2
    assert by["all-to-all"] == 4 * 256 * 4
    assert by["collective-permute"] == 2 * 4
    assert stats["count_by_kind"]["all-reduce"] == 2


def test_done_not_double_counted():
    stats = collective_stats(HLO)
    assert stats["count_by_kind"]["all-reduce"] == 2  # ar + ars, not ard


def test_model_flops():
    assert model_flops(1e9, 1e6) == 6e15
