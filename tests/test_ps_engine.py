"""PS engine semantics: parity with the one-shot serial driver, policy
behavior (schedules, compression, faults) and telemetry."""
import json

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import AdaSEGConfig, run_local_adaseg
from repro.problems import make_bilinear_game
from repro.ps import (
    BernoulliFaults,
    ElasticSchedule,
    FixedSchedule,
    IdentityCompressor,
    OutageFaults,
    PSConfig,
    PSEngine,
    StochasticQuantizeCompressor,
    StragglerSchedule,
    TopKCompressor,
    UniformSchedule,
)

M, R = 4, 4


@pytest.fixture(scope="module")
def game():
    return make_bilinear_game(jax.random.PRNGKey(0), n=10, sigma=0.1)


def _cfg(k=5):
    return AdaSEGConfig(g0=1.0, diameter=2.0, alpha=1.0, k=k)


def _assert_trees_equal(a, b):
    for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(b)):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


@pytest.mark.parametrize("backend", ["reference", "fused"])
def test_engine_reproduces_serial_driver_bit_exact(game, backend):
    """Identity compressor + no faults + uniform K must give the exact same
    trajectory as run_local_adaseg — the acceptance bar for the subsystem."""
    z_ser, (s_ser, _) = run_local_adaseg(
        game.problem, _cfg(), num_workers=M, rounds=R,
        rng=jax.random.PRNGKey(2), backend=backend)
    engine = PSEngine(
        game.problem,
        PSConfig(adaseg=_cfg(), num_workers=M, rounds=R, backend=backend),
        rng=jax.random.PRNGKey(2))
    z_eng = engine.run()
    _assert_trees_equal(z_ser, z_eng)
    _assert_trees_equal(s_ser.z_tilde, engine.state.z_tilde)
    np.testing.assert_array_equal(np.asarray(s_ser.sum_sq),
                                  np.asarray(engine.state.sum_sq))
    np.testing.assert_array_equal(np.asarray(s_ser.t),
                                  np.asarray(engine.state.t))


def test_engine_fixed_schedule_matches_serial_local_steps(game):
    """FixedSchedule == the serial driver's heterogeneous local_steps."""
    ks = jnp.array([5, 4, 3, 2])
    z_ser, (s_ser, _) = run_local_adaseg(
        game.problem, _cfg(), num_workers=M, rounds=R,
        rng=jax.random.PRNGKey(3), local_steps=ks)
    engine = PSEngine(
        game.problem,
        PSConfig(adaseg=_cfg(), num_workers=M, rounds=R,
                 schedule=FixedSchedule(ks)),
        rng=jax.random.PRNGKey(3))
    z_eng = engine.run()
    _assert_trees_equal(z_ser, z_eng)
    np.testing.assert_array_equal(np.asarray(s_ser.t),
                                  np.asarray(engine.state.t))


def test_engine_run_is_chunking_invariant(game):
    """run() in one chunk == round-by-round step_round() — the property the
    checkpoint/resume machinery rests on."""
    pscfg = PSConfig(adaseg=_cfg(), num_workers=M, rounds=R,
                     compressor=StochasticQuantizeCompressor(bits=8))
    e1 = PSEngine(game.problem, pscfg, rng=jax.random.PRNGKey(4))
    z1 = e1.run()
    e2 = PSEngine(game.problem, pscfg, rng=jax.random.PRNGKey(4))
    for _ in range(R):
        e2.step_round()
    _assert_trees_equal(z1, e2.z_bar())
    _assert_trees_equal(e1.state, e2.state)
    _assert_trees_equal(e1._ef, e2._ef)


def test_quantized_sync_stays_close(game):
    """≥8-bit stochastic quantization must not blow up the trajectory: the
    residual stays within 2× of the uncompressed one (PR acceptance bar)."""
    res = {}
    for comp in (IdentityCompressor(), StochasticQuantizeCompressor(bits=8)):
        engine = PSEngine(
            game.problem,
            PSConfig(adaseg=_cfg(k=10), num_workers=M, rounds=10,
                     compressor=comp),
            rng=jax.random.PRNGKey(5))
        res[comp.name] = float(game.residual(engine.run()))
    assert np.isfinite(res["q8"])
    assert res["q8"] < 2.0 * res["identity"]


def test_compression_reduces_bytes(game):
    z_like = jax.tree.map(lambda v: v, game.problem.init(jax.random.PRNGKey(0)))
    dense = IdentityCompressor().message_bytes(z_like)
    q8 = StochasticQuantizeCompressor(bits=8).message_bytes(z_like)
    topk = TopKCompressor(fraction=0.1).message_bytes(z_like)
    assert q8 < dense
    assert topk < dense


def test_faults_exclude_dead_workers(game):
    """A worker down for rounds [1, 3) runs no steps there, keeps its stale
    anchor through the sync, and the survivors' weighted average still
    propagates (renormalized over survivors)."""
    engine = PSEngine(
        game.problem,
        PSConfig(adaseg=_cfg(), num_workers=M, rounds=R,
                 faults=OutageFaults(events=((2, 1, 3),))),
        rng=jax.random.PRNGKey(6))
    engine.run(until_round=1)
    z_before = jax.tree.map(
        lambda v: np.asarray(v[2]).copy(), engine.state.z_tilde)
    t_before = int(engine.state.t[2])
    engine.run(until_round=2)
    # dead worker: no local steps, anchor unchanged by the round-2 sync
    assert int(engine.state.t[2]) == t_before
    _assert_trees_equal(
        z_before, jax.tree.map(lambda v: np.asarray(v[2]),
                               engine.state.z_tilde))
    # survivors stepped
    assert int(engine.state.t[0]) == t_before + 5
    z = engine.run()
    assert np.isfinite(float(game.residual(z)))
    # trace reflects the outage
    assert engine.trace.rounds[1].alive == [True, True, False, True]
    assert engine.trace.rounds[1].local_steps[2] == 0
    assert engine.trace.rounds[1].bytes_up < engine.trace.rounds[0].bytes_up


def test_elastic_schedule_masks_steps(game):
    """Workers sitting out a round (K_m^r = 0) skip local work but still
    count as members: step counters must match the schedule table exactly."""
    sched = ElasticSchedule(UniformSchedule(5), dropout=0.4, seed=11)
    engine = PSEngine(
        game.problem,
        PSConfig(adaseg=_cfg(), num_workers=M, rounds=R, schedule=sched),
        rng=jax.random.PRNGKey(7))
    engine.run()
    expect = sched.steps(M, R).sum(axis=0)
    assert expect.min() >= 0 and (sched.steps(M, R) == 0).any()
    np.testing.assert_array_equal(np.asarray(engine.state.t), expect)


def test_straggler_schedule_deterministic():
    s = StragglerSchedule(k=10, min_frac=0.5, seed=3, slow_workers=(1,))
    a, b = s.steps(4, 6), s.steps(4, 6)
    np.testing.assert_array_equal(a, b)
    assert (a[:, 1] == 5).all()          # pinned straggler
    assert a.min() >= 5 and a.max() <= 10


def test_faults_deterministic_and_protected():
    f = BernoulliFaults(p=0.5, seed=9)
    a, b = f.alive(4, 8), f.alive(4, 8)
    np.testing.assert_array_equal(a, b)
    assert a[:, 0].all()                 # protected worker 0
    assert not a.all()                   # some failures at p=0.5


def test_trace_json_roundtrip(game, tmp_path):
    engine = PSEngine(
        game.problem,
        PSConfig(adaseg=_cfg(), num_workers=M, rounds=R,
                 compressor=StochasticQuantizeCompressor(bits=8)),
        rng=jax.random.PRNGKey(8), eval_fn=game.residual)
    engine.run()
    payload = json.loads(engine.trace.to_json())
    assert payload["summary"]["rounds"] == R
    assert payload["meta"]["compressor"] == "q8"
    assert all(r["bytes_up"] > 0 for r in payload["rounds"])
    assert all(r["residual"] is not None for r in payload["rounds"])
    assert all(r["eta_max"] >= r["eta_min"] > 0 for r in payload["rounds"])
    path = str(tmp_path / "trace.json")
    engine.trace.save(path)
    from repro.ps import TraceRecorder
    loaded = TraceRecorder.load(path)
    assert loaded.summary() == engine.trace.summary()


def test_trace_load_accepts_other_vintages(tmp_path):
    """TraceRecorder.load is the inverse of save across format versions:
    records written before the async engine existed (no sim_time_s /
    staleness / idle_frac keys) fall back to defaults, and keys from a
    newer version than this one are dropped instead of crashing."""
    old = {
        "meta": {"optimizer": "adaseg", "compressor": "identity"},
        "summary": {"rounds": 1},
        "rounds": [{
            "round": 0,
            "local_steps": [5, 5],
            "alive": [True, True],
            "bytes_up": 80.0,
            "bytes_down": 80.0,
            "eta_min": 0.5,
            "eta_max": 0.7,
            "eta_mean": 0.6,
            # pre-PR-4 file: no residual/wall/sim-time keys at all
        }],
    }
    path = tmp_path / "old_trace.json"
    path.write_text(json.dumps(old))
    from repro.ps import TraceRecorder
    tr = TraceRecorder.load(str(path))
    rec = tr.rounds[0]
    assert rec.local_steps == [5, 5]
    assert rec.residual is None and rec.sim_time_s is None
    assert rec.staleness is None and rec.idle_frac is None
    assert tr.sim_time_s is None
    assert "sim_time_s" not in tr.summary()

    future = dict(old)
    future["rounds"] = [dict(old["rounds"][0],
                             from_the_future=123, sim_time_s=4.2)]
    path2 = tmp_path / "future_trace.json"
    path2.write_text(json.dumps(future))
    tr2 = TraceRecorder.load(str(path2))
    assert tr2.rounds[0].sim_time_s == 4.2
    assert not hasattr(tr2.rounds[0], "from_the_future")


def test_engine_rejects_mismatched_schedule(game):
    with pytest.raises(ValueError):
        PSEngine(
            game.problem,
            PSConfig(adaseg=_cfg(), num_workers=M, rounds=R,
                     schedule=FixedSchedule([5, 4])),
            rng=jax.random.PRNGKey(1))
