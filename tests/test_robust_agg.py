"""Hostile-fleet subsystem (ps/robust): clean-fleet degradation to the
weighted mean, robust-merge correctness against numpy oracles, fused↔
reference parity, attack efficacy on both engines, DP uplinks, checkpoint
fingerprints, and the serial-path-only pins.

Degradation bar (the PR's satellite #1): every robust aggregator at zero
robustness budget — β=0 trimmed mean, f=0 multi-Krum, coordinate median of
≤2 workers, and the explicit ``WeightedMean`` — reproduces
``sync_weighted_stacked``'s Line-7 weighted average *bit-exactly* on the
reference backend and within rtol=1e-5 on the fused one, because the
resolved spec is ``None`` and the historical merge path compiles unchanged.
"""
import dataclasses
import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import AdaSEGConfig
from repro.core.adaseg import sync_weighted_stacked
from repro.kernels.sync_compress.ops import sync_merge_stacked
from repro.problems import make_bilinear_game
from repro.ps import (
    AsyncPSConfig,
    AsyncPSEngine,
    ClientSampler,
    ConstantLatency,
    CoordinateMedian,
    DPUplink,
    LognormalLatency,
    MultiKrum,
    PSConfig,
    PSEngine,
    SignFlipAttack,
    StochasticQuantizeCompressor,
    TraceRecorder,
    TrimmedMean,
    WeightedMean,
    ZeroAttack,
)

M, R, K, N = 5, 6, 4, 10


@pytest.fixture(scope="module")
def game():
    return make_bilinear_game(jax.random.PRNGKey(0), n=N, sigma=0.1)


@pytest.fixture(scope="module")
def stacked():
    key = jax.random.PRNGKey(0)
    return {
        "a": jax.random.normal(key, (M, 257)),
        "b": jax.random.normal(jax.random.fold_in(key, 1), (M, 7, 3)),
    }


def _cfg(k=K):
    return AdaSEGConfig(g0=1.0, diameter=2.0, alpha=1.0, k=k)


def _as_async(pscfg: PSConfig, **extra) -> AsyncPSConfig:
    base = {f.name: getattr(pscfg, f.name)
            for f in dataclasses.fields(PSConfig)}
    return AsyncPSConfig(**base, **extra)


def _assert_trees(a, b, exact=True, rtol=1e-5, atol=1e-6):
    for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(b)):
        if exact:
            np.testing.assert_array_equal(np.asarray(x), np.asarray(y))
        else:
            np.testing.assert_allclose(np.asarray(x), np.asarray(y),
                                       rtol=rtol, atol=atol)


# ---------------------------------------------------------------------------
# Satellite #1 — clean-fleet degradation: zero budget ⇒ the weighted mean
# ---------------------------------------------------------------------------

ZERO_BUDGET = [
    (WeightedMean(), M),
    (TrimmedMean(beta=0.0), M),
    (MultiKrum(f=0), M),
    (CoordinateMedian(), 2),     # median of ≤2 inliers trims nobody
]


@pytest.mark.parametrize("agg,m", ZERO_BUDGET,
                         ids=lambda p: getattr(p, "name", p))
@pytest.mark.parametrize("use_kernel", [False, True],
                         ids=["reference", "fused"])
def test_zero_budget_reproduces_weighted_mean(stacked, agg, m, use_kernel):
    z = jax.tree.map(lambda v: v[:m], stacked)
    w = jnp.linspace(0.5, 2.0, m)
    assert agg.spec(m) is None          # the degradation is *static*
    got = sync_merge_stacked(z, w, normalize=True, agg=agg.spec(m),
                             use_kernel=use_kernel)
    want = sync_weighted_stacked(z, w)
    _assert_trees(got, want, exact=not use_kernel)


@pytest.mark.parametrize("agg", [TrimmedMean(beta=0.0), MultiKrum(f=0),
                                 WeightedMean()],
                         ids=lambda a: a.name)
def test_zero_budget_engine_bit_exact(game, agg):
    """A zero-budget robust config compiles the historical engine path:
    the whole trajectory is bit-identical to a plain run, and the trace
    carries no robust metadata."""
    plain = PSEngine(game.problem,
                     PSConfig(adaseg=_cfg(), num_workers=M, rounds=R),
                     rng=jax.random.PRNGKey(2))
    z0 = plain.run()
    robust = PSEngine(game.problem,
                      PSConfig(adaseg=_cfg(), num_workers=M, rounds=R,
                               aggregator=agg),
                      rng=jax.random.PRNGKey(2))
    z1 = robust.run()
    _assert_trees(z0, z1)
    _assert_trees(plain.state, robust.state)
    assert "aggregator" not in robust.trace.meta
    assert robust.trace.rounds[-1].byzantine_workers is None


# ---------------------------------------------------------------------------
# Robust merges against numpy oracles
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("use_kernel", [False, True],
                         ids=["reference", "fused"])
def test_coordinate_median_matches_numpy(use_kernel):
    z = {"w": jnp.asarray(np.random.RandomState(0).randn(M, 33), jnp.float32)}
    agg = CoordinateMedian()
    got = sync_merge_stacked(z, jnp.ones(M), normalize=True,
                             agg=agg.spec(M), use_kernel=use_kernel)
    want = np.median(np.asarray(z["w"]), axis=0)
    np.testing.assert_allclose(np.asarray(got["w"][0]), want,
                               rtol=1e-5, atol=1e-6)


@pytest.mark.parametrize("use_kernel", [False, True],
                         ids=["reference", "fused"])
def test_trimmed_mean_excludes_outlier_lane(use_kernel):
    z = {"w": jnp.asarray(np.random.RandomState(1).randn(M, 17), jnp.float32)}
    hostile = z["w"].at[2].set(1e6)
    agg = TrimmedMean(beta=0.2)       # trims 1 lane per side at M=5
    got = sync_merge_stacked({"w": hostile}, jnp.ones(M), normalize=True,
                             agg=agg.spec(M), use_kernel=use_kernel)
    assert float(jnp.abs(got["w"]).max()) < 1e3   # outlier never averaged in
    # oracle: drop min and max per coordinate, average the rest
    s = np.sort(np.asarray(hostile), axis=0)[1:-1]
    np.testing.assert_allclose(np.asarray(got["w"][0]), s.mean(axis=0),
                               rtol=1e-5, atol=1e-6)


def test_trimmed_fused_matches_reference_weighted_recv(stacked):
    w = jnp.linspace(0.5, 2.0, M).at[1].set(0.0)   # a dead lane too
    recv = jnp.array([1.0, 0.0, 1.0, 1.0, 1.0])
    old = jax.tree.map(lambda v: v + 1.0, stacked)
    kw = dict(w=w, recv=recv, old=old, normalize=True,
              agg=("trimmed", 1))
    got_f = sync_merge_stacked(stacked, **kw, use_kernel=True)
    got_r = sync_merge_stacked(stacked, **kw, use_kernel=False)
    _assert_trees(got_f, got_r, exact=False)
    # non-receiving lane keeps its old payload on both backends
    for leaf_g, leaf_o in zip(jax.tree.leaves(got_r), jax.tree.leaves(old)):
        np.testing.assert_array_equal(np.asarray(leaf_g[1]),
                                      np.asarray(leaf_o[1]))


def test_krum_rejects_planted_outlier(stacked):
    hostile = jax.tree.map(lambda v: v.at[3].set(50.0), stacked)
    agg = MultiKrum(f=1)
    got = sync_merge_stacked(hostile, jnp.ones(M), normalize=True,
                             agg=agg.spec(M))
    # selection averages only the m_select closest lanes: the planted
    # outlier cannot appear in the merge
    honest = jax.tree.map(lambda v: jnp.delete(v, 3, axis=0), hostile)
    for g, h in zip(jax.tree.leaves(got), jax.tree.leaves(honest)):
        assert float(jnp.abs(g[0]).max()) <= float(jnp.abs(h).max()) + 1e-5


# ---------------------------------------------------------------------------
# Attack efficacy — the acceptance criterion in miniature (bench_fig4 runs
# the full matrix): robust merges survive 20% sign-flip, the mean does not
# ---------------------------------------------------------------------------

def _residual(game, agg, byz, m=10, rounds=12):
    cfg = PSConfig(adaseg=_cfg(), num_workers=m, rounds=rounds,
                   byzantine=byz, aggregator=agg)
    eng = PSEngine(game.problem, cfg, rng=jax.random.PRNGKey(2))
    return float(game.residual(eng.run()))


def test_robust_aggregators_survive_sign_flip(game):
    byz = SignFlipAttack(fraction=0.2, scale=3.0, seed=5)
    clean = _residual(game, None, None)
    mean = _residual(game, None, byz)
    median = _residual(game, CoordinateMedian(), byz)
    trimmed = _residual(game, TrimmedMean(beta=0.2), byz)
    assert median <= 2.0 * clean
    assert trimmed <= 2.0 * clean
    assert mean > 2.0 * clean           # the plain mean stalls/diverges


def test_byzantine_ids_recorded_and_composable_with_codec(game):
    byz = SignFlipAttack(fraction=0.4, scale=3.0, seed=5)
    cfg = PSConfig(adaseg=_cfg(), num_workers=M, rounds=R,
                   byzantine=byz, aggregator=CoordinateMedian(),
                   compressor=StochasticQuantizeCompressor(
                       bits=8, error_feedback=True),
                   codec_backend="fused")
    eng = PSEngine(game.problem, cfg, rng=jax.random.PRNGKey(2))
    eng.run()
    ids = [r.byzantine_workers for r in eng.trace.rounds]
    table = byz.attacked(M, R)
    assert ids == [sorted(np.nonzero(table[r])[0].tolist())
                   for r in range(R)]
    assert eng.trace.meta["byzantine"] == byz.name
    assert eng.trace.meta["aggregator"] == "coordinate_median"
    assert eng.metrics.total("byzantine_workers") == int(table.sum())


# ---------------------------------------------------------------------------
# Both engines: τ=0 lockstep parity and a genuinely-async robust run
# ---------------------------------------------------------------------------

def test_async_lockstep_robust_parity_bit_exact(game):
    pscfg = PSConfig(adaseg=_cfg(), num_workers=M, rounds=R,
                     byzantine=SignFlipAttack(fraction=0.4, seed=5),
                     aggregator=TrimmedMean(beta=0.2),
                     dp=DPUplink(clip=5.0, sigma=0.01))
    eng = PSEngine(game.problem, pscfg, rng=jax.random.PRNGKey(2))
    z_sync = eng.run()
    a = AsyncPSEngine(
        game.problem,
        _as_async(pscfg, latency=ConstantLatency(step_s=1.0, up_s=0.5),
                  staleness_bound=0.0),
        rng=jax.random.PRNGKey(2))
    z_async = a.run()
    assert a._lockstep_chunk is not None
    _assert_trees(z_sync, z_async)
    _assert_trees(eng.state, a.state)
    assert ([r.byzantine_workers for r in eng.trace.rounds]
            == [r.byzantine_workers for r in a.trace.rounds][:R])


def test_async_staleness_robust_run_records_attacks(game):
    pscfg = PSConfig(adaseg=_cfg(), num_workers=M, rounds=R,
                     byzantine=SignFlipAttack(fraction=0.4, seed=5),
                     aggregator=CoordinateMedian())
    a = AsyncPSEngine(
        game.problem,
        _as_async(pscfg, latency=LognormalLatency(step_s=0.01, sigma=0.8,
                                                  seed=3),
                  staleness_bound=3.0),
        rng=jax.random.PRNGKey(2), eval_fn=game.residual)
    z = a.run()
    assert np.isfinite(float(game.residual(z)))
    assert any(r.byzantine_workers for r in a.trace.rounds)
    assert a.metrics.total("byzantine_workers") > 0
    assert "agg_reject_frac" in a.metrics.names()


# ---------------------------------------------------------------------------
# DP uplinks
# ---------------------------------------------------------------------------

def test_dp_clips_joint_l2_and_noise_is_seeded(stacked):
    dp = DPUplink(clip=1.0, sigma=0.5)
    rngs = jax.random.split(jax.random.PRNGKey(7), M)
    out1 = dp.apply(stacked, rngs)
    out2 = dp.apply(stacked, rngs)
    _assert_trees(out1, out2)                     # same keys ⇒ same noise
    clip_only = DPUplink(clip=1.0).apply(stacked, rngs)
    flat = np.concatenate([np.asarray(v).reshape(M, -1)
                           for v in jax.tree.leaves(clip_only)], axis=1)
    np.testing.assert_array_less(np.linalg.norm(flat, axis=1), 1.0 + 1e-5)


def test_dp_engine_run_attack_not_clipped_before_corruption(game):
    """DP composes with attacks and codecs end-to-end, and the run's
    uplinks stay bounded — a sanity bar, not a privacy accountant."""
    cfg = PSConfig(adaseg=_cfg(), num_workers=M, rounds=R,
                   byzantine=ZeroAttack(fraction=0.2, seed=1),
                   aggregator=TrimmedMean(beta=0.2),
                   dp=DPUplink(clip=0.5, sigma=0.1),
                   compressor=StochasticQuantizeCompressor(bits=8))
    eng = PSEngine(game.problem, cfg, rng=jax.random.PRNGKey(2))
    z = eng.run()
    assert np.isfinite(float(game.residual(z)))
    assert eng.trace.meta["dp"] == "dp(clip=0.5,sigma=0.1)"


# ---------------------------------------------------------------------------
# Checkpoint fingerprints + crash/resume mid-attack
# ---------------------------------------------------------------------------

def test_resume_mid_attack_bit_exact_and_fp_rejected(game, tmp_path):
    cfg = PSConfig(adaseg=_cfg(), num_workers=M, rounds=R,
                   byzantine=SignFlipAttack(fraction=0.4, seed=5),
                   aggregator=TrimmedMean(beta=0.2))
    p = os.path.join(tmp_path, "ck.npz")
    e1 = PSEngine(game.problem, cfg, rng=jax.random.PRNGKey(2))
    e1.run(until_round=3)
    e1.save(p)
    z1 = e1.run()
    e2 = PSEngine(game.problem, cfg, rng=jax.random.PRNGKey(2))
    e2.restore(p)
    _assert_trees(z1, e2.run())

    other = PSEngine(game.problem,
                     dataclasses.replace(cfg, aggregator=CoordinateMedian()),
                     rng=jax.random.PRNGKey(2))
    with pytest.raises(ValueError, match="robust aggregator"):
        other.restore(p)


def test_zero_budget_checkpoint_layout_unchanged(game, tmp_path):
    """Plain checkpoints carry no aggregator_fp — a robust-capable build
    still round-trips historical checkpoints."""
    cfg = PSConfig(adaseg=_cfg(), num_workers=M, rounds=R)
    eng = PSEngine(game.problem, cfg, rng=jax.random.PRNGKey(2))
    assert "aggregator_fp" not in eng._ckpt_tree()


# ---------------------------------------------------------------------------
# Sampled rounds + the serial-path-only pins (satellite #2)
# ---------------------------------------------------------------------------

def test_sampled_rounds_with_robust_attack(game):
    fleet, sample = 8, 4
    cfg = PSConfig(adaseg=_cfg(), num_workers=fleet, rounds=R,
                   sampler=ClientSampler(sample=sample, seed=3),
                   byzantine=SignFlipAttack(fraction=0.5, seed=5),
                   aggregator=TrimmedMean(beta=0.25))
    eng = PSEngine(game.problem, cfg, rng=jax.random.PRNGKey(2))
    z = eng.run()
    assert np.isfinite(float(game.residual(z)))
    for r, rec in enumerate(eng.trace.rounds):
        assert rec.byzantine_workers is not None
        drawn = set(rec.sampled_workers)
        # recorded attackers are fleet ids inside this round's draw
        assert set(rec.byzantine_workers) <= drawn


def test_sampler_with_mesh_raises_not_implemented(game):
    from repro.launch.mesh import make_test_mesh

    mesh = make_test_mesh(1, 1)
    cfg = PSConfig(adaseg=_cfg(), num_workers=1, rounds=2,
                   sampler=ClientSampler(sample=1, seed=0))
    with pytest.raises(NotImplementedError, match="serial path only"):
        PSEngine(game.problem, cfg, rng=jax.random.PRNGKey(2),
                 mesh=mesh, worker_axes=("data",))


def test_robust_with_mesh_raises_not_implemented(game):
    from repro.launch.mesh import make_test_mesh

    mesh = make_test_mesh(1, 1)
    cfg = PSConfig(adaseg=_cfg(), num_workers=1, rounds=2,
                   byzantine=SignFlipAttack(fraction=1.0, seed=0))
    with pytest.raises(NotImplementedError, match="serial path only"):
        PSEngine(game.problem, cfg, rng=jax.random.PRNGKey(2),
                 mesh=mesh, worker_axes=("data",))


# ---------------------------------------------------------------------------
# Satellite #4 backstop — trace v7 round-trips, v6 still loads
# ---------------------------------------------------------------------------

def test_trace_v7_roundtrip_and_v6_loads(game, tmp_path):
    cfg = PSConfig(adaseg=_cfg(), num_workers=M, rounds=3,
                   byzantine=SignFlipAttack(fraction=0.4, seed=5),
                   aggregator=CoordinateMedian())
    eng = PSEngine(game.problem, cfg, rng=jax.random.PRNGKey(2))
    eng.run()
    p = os.path.join(tmp_path, "t.json")
    eng.trace.save(p)
    back = TraceRecorder.load(p)
    assert back.version == 8
    assert ([r.byzantine_workers for r in back.rounds]
            == [r.byzantine_workers for r in eng.trace.rounds])
    assert back.meta["aggregator"] == "coordinate_median"

    # a v6-era trace (no hostile-fleet fields) loads with the new defaults
    with open(p) as f:
        payload = json.load(f)
    payload["version"] = 6
    payload["meta"].pop("byzantine"), payload["meta"].pop("aggregator")
    for r in payload["rounds"]:
        r.pop("byzantine_workers")
    p6 = os.path.join(tmp_path, "t6.json")
    with open(p6, "w") as f:
        json.dump(payload, f)
    old = TraceRecorder.load(p6)
    assert old.version == 6
    assert all(r.byzantine_workers is None for r in old.rounds)
