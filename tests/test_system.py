"""End-to-end behaviour tests for the paper's system.

These are the 'does the whole thing hang together' tests: the paper's
central claims exercised through the public API.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import AdaSEGConfig, run_local_adaseg
from repro.problems import make_bilinear_game


@pytest.fixture(scope="module")
def game():
    return make_bilinear_game(jax.random.PRNGKey(0), n=10, sigma=0.1)


def test_paper_claim_larger_K_fewer_rounds(game):
    """Fig 3(b)(d): per communication ROUND, larger K converges faster.

    At an equal number of rounds R=20, K=50 must beat K=1 decisively --
    this is the communication-efficiency claim."""
    d = float(np.sqrt(20.0))
    res = {}
    for k in (1, 50):
        cfg = AdaSEGConfig(g0=1.0, diameter=d, alpha=1.0, k=k)
        zbar, _ = run_local_adaseg(
            game.problem, cfg, num_workers=4, rounds=20,
            rng=jax.random.PRNGKey(1),
        )
        res[k] = float(game.residual(zbar))
    assert res[50] < res[1] / 2, res


def test_paper_claim_variance_dominates(game):
    """Fig 3(a)(c): larger oracle noise slows convergence at equal T."""
    d = float(np.sqrt(20.0))
    res = {}
    for sigma in (0.1, 0.5):
        g = make_bilinear_game(jax.random.PRNGKey(0), n=10, sigma=sigma)
        cfg = AdaSEGConfig(g0=1.0, diameter=d, alpha=1.0, k=50)
        zbar, _ = run_local_adaseg(
            g.problem, cfg, num_workers=4, rounds=30,
            rng=jax.random.PRNGKey(1),
        )
        res[sigma] = float(g.residual(zbar))
    assert res[0.5] > res[0.1], res


def test_paper_claim_linear_speedup_in_M(game):
    """Theorems 1-2: the variance term scales 1/sqrt(MT) -- more workers at
    the same per-worker budget must not hurt in the noise-dominated regime."""
    d = float(np.sqrt(20.0))
    g = make_bilinear_game(jax.random.PRNGKey(0), n=10, sigma=0.5)
    res = {}
    for m in (1, 8):
        cfg = AdaSEGConfig(g0=1.0, diameter=d, alpha=1.0, k=25)
        zbar, _ = run_local_adaseg(
            g.problem, cfg, num_workers=m, rounds=40,
            rng=jax.random.PRNGKey(2),
        )
        res[m] = float(g.residual(zbar))
    assert res[8] < res[1] * 1.1, res


def test_tuning_free_adaptivity(game):
    """The adaptive eta must absorb a badly mis-specified G0 (gamma-
    robustness): off-by-10x guesses still converge."""
    d = float(np.sqrt(20.0))
    res = {}
    for g0 in (0.1, 1.0, 10.0):
        cfg = AdaSEGConfig(g0=g0, diameter=d, alpha=1.0, k=50)
        zbar, _ = run_local_adaseg(
            game.problem, cfg, num_workers=4, rounds=30,
            rng=jax.random.PRNGKey(3),
        )
        res[g0] = float(game.residual(zbar))
    assert all(v < 0.6 for v in res.values()), res


def test_weighted_vs_uniform_averaging(game):
    """The paper's inverse-eta weighting is the algorithmic delta vs FedAvg;
    on a homogeneous problem both converge (w ~= 1/M) -- assert the weighted
    variant is competitive with uniform averaging of the same local method."""
    from repro.optim import run_local, ump

    d = float(np.sqrt(20.0))
    cfg = AdaSEGConfig(g0=1.0, diameter=d, alpha=1.0, k=50)
    zb_w, _ = run_local_adaseg(
        game.problem, cfg, num_workers=4, rounds=20,
        rng=jax.random.PRNGKey(4),
    )
    res_weighted = float(game.residual(zb_w))
    st, _ = run_local(ump(1.0, d), game.problem, num_workers=4, local_k=50,
                      rounds=20, rng=jax.random.PRNGKey(4))
    zg = jax.tree.map(lambda v: v.mean(0), st.z_bar)
    res_uniform = float(game.residual(zg))
    assert res_weighted < 2 * res_uniform + 0.05, (res_weighted, res_uniform)
