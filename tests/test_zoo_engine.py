"""The optimizer zoo on the unified Parameter-Server runtime.

Parity pins: every zoo optimizer driven through ``PSEngine`` +
``MinimaxWorker`` must reproduce the *pre-refactor* ``run_local``
trajectory (the hand-rolled sync/scan driver, kept verbatim below as the
reference) within rtol=1e-5, on the bilinear and robust problems; the
``run_local`` wrapper keeps its historical contract; optimizer-specific
``inner`` state (Adam moments, UMP accumulators) round-trips through
checkpoints bit-exactly; and wrong-optimizer restores are rejected like
wrong-seed ones. The sharded-path zoo parity lives in
``tests/test_distributed.py``.
"""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax import lax

from repro.optim import (
    MinimaxWorker,
    adam_minimax,
    asmp,
    average_stacked,
    run_local,
    segda,
    sgda,
    ump,
)
from repro.problems import make_bilinear_game, make_robust_logistic
from repro.ps import (
    BernoulliFaults,
    PSConfig,
    PSEngine,
    StochasticQuantizeCompressor,
    StragglerSchedule,
)

M, K, R = 4, 5, 4

ZOO = {
    "sgda": lambda: sgda(0.05),
    "segda": lambda: segda(0.05),
    "adam": lambda: adam_minimax(0.02),
    "ump": lambda: ump(1.0, 2.0),
    "asmp": lambda: asmp(1.0, 2.0),
}


def reference_run_local(opt, problem, *, num_workers, local_k, rounds, rng):
    """The pre-engine ``optim.base.run_local`` driver, verbatim — the
    trajectory the unified runtime must reproduce."""
    m = num_workers
    rng, sub = jax.random.split(rng)
    state = jax.vmap(
        lambda r, w: opt.init(problem, r)._replace(worker_id=w)
    )(jax.random.split(sub, m), jnp.arange(m, dtype=jnp.int32))
    vstep = jax.vmap(lambda st, r: opt.step(problem, st, r))
    vweight = jax.vmap(opt.sync_weight)

    def round_fn(state, rng_round):
        z_avg = average_stacked(state.z, vweight(state))
        state = state._replace(z=z_avg)
        rngs = jax.random.split(rng_round, local_k * m).reshape(local_k, m, 2)

        def body(st, r):
            return vstep(st, r), None

        state, _ = lax.scan(body, state, rngs)
        out = jax.tree.map(lambda v: jnp.mean(v, axis=0), state.z_bar)
        return state, out

    state, history = lax.scan(round_fn, state, jax.random.split(rng, rounds))
    return state, history


@pytest.fixture(scope="module")
def game():
    return make_bilinear_game(jax.random.PRNGKey(0), n=10, sigma=0.1)


@pytest.fixture(scope="module")
def robust():
    return make_robust_logistic(jax.random.PRNGKey(1), n=32, d=8, batch=8)


def _zoo_cfg(opt, rounds=R, **kw):
    return PSConfig(num_workers=M, rounds=rounds, worker=MinimaxWorker(opt),
                    local_k=K, **kw)


def _assert_close(a, b, **kw):
    for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(b)):
        np.testing.assert_allclose(np.asarray(x), np.asarray(y), **kw)


def _assert_equal(a, b):
    for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(b)):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


@pytest.mark.parametrize("name", sorted(ZOO))
@pytest.mark.parametrize("prob", ["bilinear", "robust"])
def test_engine_matches_prerefactor_run_local(game, robust, name, prob):
    """Acceptance pin: PSEngine(MinimaxWorker(opt)) == the pre-refactor
    run_local trajectory, rtol=1e-5, on bilinear and robust problems."""
    problem = game.problem if prob == "bilinear" else robust.problem
    opt = ZOO[name]()
    st_ref, hist_ref = reference_run_local(
        opt, problem, num_workers=M, local_k=K, rounds=R,
        rng=jax.random.PRNGKey(3))
    engine = PSEngine(problem, _zoo_cfg(opt), rng=jax.random.PRNGKey(3))
    engine.run()
    _assert_close(st_ref, engine.state, rtol=1e-5, atol=1e-7)
    # the engine's Line-14 output equals the last reference history entry
    out_ref = jax.tree.map(lambda v: v[-1], hist_ref)
    _assert_close(out_ref, engine.z_bar(), rtol=1e-5, atol=1e-7)


@pytest.mark.parametrize("name", ["segda", "ump"])
def test_run_local_wrapper_keeps_contract(game, name):
    """The thin run_local wrapper returns the historical (state, history)
    shape and reproduces the reference trajectory."""
    opt = ZOO[name]()
    st_ref, hist_ref = reference_run_local(
        opt, game.problem, num_workers=M, local_k=K, rounds=R,
        rng=jax.random.PRNGKey(5))
    st, hist = run_local(opt, game.problem, num_workers=M, local_k=K,
                         rounds=R, rng=jax.random.PRNGKey(5))
    _assert_close(st_ref, st, rtol=1e-5, atol=1e-7)
    _assert_close(hist_ref, hist, rtol=1e-5, atol=1e-7)
    assert jax.tree.leaves(hist)[0].shape[0] == R


def test_zoo_engine_full_policy_stack_runs(game):
    """A zoo optimizer under stragglers + q8 error-feedback compression +
    faults: runs, converges to something finite, and the trace carries the
    optimizer name and throughput telemetry."""
    engine = PSEngine(
        game.problem,
        _zoo_cfg(ZOO["segda"](), rounds=6,
                 schedule=StragglerSchedule(k=K, min_frac=0.4, seed=3),
                 compressor=StochasticQuantizeCompressor(bits=8),
                 faults=BernoulliFaults(p=0.2, seed=5)),
        rng=jax.random.PRNGKey(7))
    z = engine.run()
    assert np.isfinite(float(game.residual(z)))
    assert engine.trace.meta["optimizer"].startswith("segda")
    assert all(r.wall_time_s is not None and r.wall_time_s > 0
               for r in engine.trace.rounds)
    assert engine.trace.steps_per_sec is not None
    assert engine.trace.steps_per_sec > 0


def test_zoo_sync_weight_weighting_applies(game):
    """UMP's 1/η sync weights must reach the engine average: its round-end
    η telemetry is the adaptive step size, not the generic constant 1."""
    engine = PSEngine(game.problem, _zoo_cfg(ZOO["ump"]()),
                      rng=jax.random.PRNGKey(11))
    engine.run()
    etas = [r.eta_mean for r in engine.trace.rounds]
    assert etas[-1] < etas[0]            # Σ(Z)² grows → η shrinks
    const = PSEngine(game.problem, _zoo_cfg(ZOO["sgda"]()),
                     rng=jax.random.PRNGKey(11))
    const.run()
    assert all(r.eta_min == r.eta_max == 1.0 for r in const.trace.rounds)


# ---------------------------------------------------------------------------
# Checkpoint/resume of optimizer-specific inner state
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("name,inner_keys", [
    ("adam", ("m", "v")),
    ("ump", ("sum_sq",)),
    ("asmp", ("sum_sq", "g_prev")),
])
def test_inner_state_survives_checkpoint_bit_exact(game, tmp_path, name,
                                                   inner_keys):
    """Adam moments / UMP + ASMP accumulators must round-trip through
    save/restore bit-exactly, and the resumed trajectory must equal the
    uninterrupted one."""
    opt_f = ZOO[name]
    path = str(tmp_path / "zoo.msgpack")

    straight = PSEngine(game.problem, _zoo_cfg(opt_f(), rounds=6),
                        rng=jax.random.PRNGKey(9))
    z_straight = straight.run()

    first = PSEngine(game.problem, _zoo_cfg(opt_f(), rounds=6),
                     rng=jax.random.PRNGKey(9))
    first.run(until_round=3)
    first.save(path)

    resumed = PSEngine(game.problem, _zoo_cfg(opt_f(), rounds=6),
                       rng=jax.random.PRNGKey(9))
    resumed.restore(path)
    assert resumed.round == 3
    for key in inner_keys:
        _assert_equal(first.state.inner[key], resumed.state.inner[key])
    z_resumed = resumed.run()
    _assert_equal(z_straight, z_resumed)
    _assert_equal(straight.state, resumed.state)


def test_restore_rejects_wrong_optimizer_same_structure(game, tmp_path):
    """sgda and segda share the exact state layout — only the optimizer
    fingerprint tells their checkpoints apart."""
    path = str(tmp_path / "sgda.msgpack")
    writer = PSEngine(game.problem, _zoo_cfg(ZOO["sgda"]()),
                      rng=jax.random.PRNGKey(1))
    writer.run(until_round=2)
    writer.save(path)
    reader = PSEngine(game.problem, _zoo_cfg(ZOO["segda"]()),
                      rng=jax.random.PRNGKey(1))
    with pytest.raises(ValueError, match="different optimizer"):
        reader.restore(path)


def test_restore_rejects_wrong_optimizer_structure_mismatch(game, tmp_path):
    """An Adam checkpoint cannot be read into a UMP engine: the inner-state
    layouts differ and the restore must fail loudly."""
    path = str(tmp_path / "adam.msgpack")
    writer = PSEngine(game.problem, _zoo_cfg(ZOO["adam"]()),
                      rng=jax.random.PRNGKey(1))
    writer.run(until_round=2)
    writer.save(path)
    reader = PSEngine(game.problem, _zoo_cfg(ZOO["ump"]()),
                      rng=jax.random.PRNGKey(1))
    with pytest.raises(ValueError):
        reader.restore(path)
    assert not os.path.exists(path + ".tmp")


def test_restore_rejects_wrong_hyperparameters(game, tmp_path):
    """Same optimizer family, different hyper-parameters, identical state
    layout: the fingerprint must still tell the checkpoints apart (a UMP
    restore with a different diameter would silently change every η)."""
    path = str(tmp_path / "ump.msgpack")
    writer = PSEngine(game.problem, _zoo_cfg(ump(1.0, 2.0)),
                      rng=jax.random.PRNGKey(1))
    writer.run(until_round=2)
    writer.save(path)
    reader = PSEngine(game.problem, _zoo_cfg(ump(1.0, 8.0)),
                      rng=jax.random.PRNGKey(1))
    with pytest.raises(ValueError, match="different optimizer"):
        reader.restore(path)


def test_run_local_zero_rounds_returns_empty_history(game):
    st, hist = run_local(ZOO["sgda"](), game.problem, num_workers=M,
                         local_k=K, rounds=0, rng=jax.random.PRNGKey(0))
    assert all(v.shape[0] == 0 for v in jax.tree.leaves(hist))
    assert jax.tree.leaves(st.z)[0].shape[0] == M


def test_config_rejects_backend_on_explicit_worker(game):
    with pytest.raises(ValueError, match="backend"):
        PSEngine(game.problem,
                 PSConfig(num_workers=M, rounds=R,
                          worker=MinimaxWorker(ZOO["sgda"]()), local_k=K,
                          backend="fused"),
                 rng=jax.random.PRNGKey(0))


def test_restore_rejects_wrong_seed_for_zoo(game, tmp_path):
    path = str(tmp_path / "seed.msgpack")
    writer = PSEngine(game.problem, _zoo_cfg(ZOO["adam"]()),
                      rng=jax.random.PRNGKey(0))
    writer.run(until_round=2)
    writer.save(path)
    reader = PSEngine(game.problem, _zoo_cfg(ZOO["adam"]()),
                      rng=jax.random.PRNGKey(1))
    with pytest.raises(ValueError, match="different seed"):
        reader.restore(path)


# ---------------------------------------------------------------------------
# Config validation for the generic runtime
# ---------------------------------------------------------------------------

def test_generic_worker_requires_schedule_or_local_k(game):
    with pytest.raises(ValueError, match="local_k"):
        PSEngine(game.problem,
                 PSConfig(num_workers=M, rounds=R,
                          worker=MinimaxWorker(ZOO["sgda"]())),
                 rng=jax.random.PRNGKey(0))


def test_config_rejects_both_adaseg_and_worker(game):
    from repro.core import AdaSEGConfig
    with pytest.raises(ValueError, match="not both"):
        PSEngine(game.problem,
                 PSConfig(num_workers=M, rounds=R,
                          adaseg=AdaSEGConfig(g0=1.0, diameter=2.0, k=5),
                          worker=MinimaxWorker(ZOO["sgda"]())),
                 rng=jax.random.PRNGKey(0))


def test_config_requires_some_worker(game):
    with pytest.raises(ValueError, match="adaseg= or worker="):
        PSEngine(game.problem, PSConfig(num_workers=M, rounds=R),
                 rng=jax.random.PRNGKey(0))
