"""Distributed-path tests. These need >1 XLA device, which requires setting
``xla_force_host_platform_device_count`` BEFORE jax initializes — so they run
in a subprocess (the main pytest process keeps the default 1-device view, as
required for the smoke tests)."""
import json
import os
import subprocess
import sys
import textwrap

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def run_in_subprocess(code: str, devices: int = 8, timeout: int = 560) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    out = subprocess.run(
        [sys.executable, "-c", textwrap.dedent(code)],
        env=env, capture_output=True, text=True, timeout=timeout,
    )
    assert out.returncode == 0, f"stderr:\n{out.stderr[-3000:]}"
    return out.stdout


def test_psum_sync_equals_stacked_sync():
    """The shard_map/psum weighted sync must equal the serial stacked sync."""
    out = run_in_subprocess("""
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import PartitionSpec as P
        from jax.experimental.shard_map import shard_map
        from repro.core import make_psum_sync, sync_weighted_stacked
        from repro.launch.mesh import make_test_mesh

        mesh = make_test_mesh(4, 2)
        m = 4
        z = {"w": jnp.arange(4 * 6, dtype=jnp.float32).reshape(4, 6)}
        inv_eta = jnp.array([0.5, 1.0, 1.5, 2.0])

        expected = sync_weighted_stacked(z, inv_eta)

        sync = make_psum_sync(("data",))
        def shard_fn(z, ie):
            # per-shard: z {"w": (1, 6)}, ie (1,)
            out = sync({"w": z["w"][0]}, ie[0])
            return {"w": out["w"][None]}, None
        got, _ = shard_map(
            shard_fn, mesh=mesh,
            in_specs=(P("data", None), P("data")),
            out_specs=(P("data", None), None),
        )({"w": z["w"]}, inv_eta)
        np.testing.assert_allclose(np.asarray(got["w"]),
                                   np.asarray(expected["w"]), rtol=1e-6)
        print("PSUM_SYNC_OK")
    """)
    assert "PSUM_SYNC_OK" in out


def test_sharded_driver_matches_serial_both_backends():
    """run_local_adaseg_sharded (shard_map + psum sync, 4 workers on a 4×2
    mesh) must reproduce the serial vmap driver's trajectory for BOTH step
    backends — reference tree ops and the fused Pallas kernels — within the
    PR's rtol=1e-5 acceptance bar on the bilinear game."""
    out = run_in_subprocess("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.core import AdaSEGConfig, run_local_adaseg
        from repro.launch.mesh import make_test_mesh
        from repro.launch.sharded import run_local_adaseg_sharded
        from repro.problems import make_bilinear_game

        game = make_bilinear_game(jax.random.PRNGKey(0), n=10, sigma=0.1)
        cfg = AdaSEGConfig(g0=1.0, diameter=2.0, alpha=1.0, k=5)
        mesh = make_test_mesh(4, 2)
        for backend in ("reference", "fused"):
            z_ser, (s_ser, _) = run_local_adaseg(
                game.problem, cfg, num_workers=4, rounds=4,
                rng=jax.random.PRNGKey(2), backend=backend)
            z_sh, (s_sh, hist) = run_local_adaseg_sharded(
                game.problem, cfg, mesh=mesh, worker_axes=("data",),
                rounds=4, rng=jax.random.PRNGKey(2), backend=backend,
                collect_aux=True)
            for a, b in zip(jax.tree.leaves(z_ser), jax.tree.leaves(z_sh)):
                np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                           rtol=1e-5, atol=1e-7)
            np.testing.assert_allclose(np.asarray(s_ser.sum_sq),
                                       np.asarray(s_sh.sum_sq), rtol=1e-5)
            np.testing.assert_array_equal(np.asarray(s_ser.t),
                                          np.asarray(s_sh.t))
            assert hist.eta.shape == (4, 5, 4)   # (R, K, M)
        print("SHARDED_PARITY_OK")
    """)
    assert "SHARDED_PARITY_OK" in out


def test_sharded_hetero_local_steps_and_sync_hook():
    """The lifted uniform-K restriction: per-worker local_steps through the
    sharded driver must match the serial driver's masking semantics (both
    backends, rtol=1e-5), and the compressed-psum sync hook must stay close
    to the dense all-reduce."""
    out = run_in_subprocess("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.core import AdaSEGConfig, run_local_adaseg
        from repro.launch.mesh import make_test_mesh
        from repro.launch.sharded import run_local_adaseg_sharded
        from repro.problems import make_bilinear_game
        from repro.ps import StochasticQuantizeCompressor, make_compressed_psum_sync

        game = make_bilinear_game(jax.random.PRNGKey(0), n=10, sigma=0.1)
        cfg = AdaSEGConfig(g0=1.0, diameter=2.0, alpha=1.0, k=5)
        mesh = make_test_mesh(4, 2)
        ks = jnp.array([5, 4, 3, 2])
        for backend in ("reference", "fused"):
            z_ser, (s_ser, _) = run_local_adaseg(
                game.problem, cfg, num_workers=4, rounds=4,
                rng=jax.random.PRNGKey(3), local_steps=ks, backend=backend)
            z_sh, (s_sh, hist) = run_local_adaseg_sharded(
                game.problem, cfg, mesh=mesh, worker_axes=("data",),
                rounds=4, rng=jax.random.PRNGKey(3), local_steps=ks,
                backend=backend, collect_aux=True)
            for a, b in zip(jax.tree.leaves(z_ser), jax.tree.leaves(z_sh)):
                np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                           rtol=1e-5, atol=1e-7)
            np.testing.assert_allclose(np.asarray(s_ser.sum_sq),
                                       np.asarray(s_sh.sum_sq), rtol=1e-5)
            np.testing.assert_array_equal(np.asarray(s_ser.t),
                                          np.asarray(s_sh.t))
            assert hist.eta.shape == (4, 5, 4)

        z_dense, _ = run_local_adaseg_sharded(
            game.problem, cfg, mesh=mesh, rounds=4,
            rng=jax.random.PRNGKey(2))
        sync = make_compressed_psum_sync(
            ("data",), StochasticQuantizeCompressor(bits=8))
        z_q, _ = run_local_adaseg_sharded(
            game.problem, cfg, mesh=mesh, rounds=4,
            rng=jax.random.PRNGKey(2), sync_fn=sync)
        rd, rq = float(game.residual(z_dense)), float(game.residual(z_q))
        assert np.isfinite(rq) and rq < 2.0 * rd + 0.1, (rd, rq)
        print("HETERO_SHARDED_OK")
    """)
    assert "HETERO_SHARDED_OK" in out


def test_ps_engine_sharded_matches_serial_and_resumes():
    """PS engine acceptance on the sharded path: identity/no-fault engine
    reproduces the serial engine (rtol=1e-5, both backends); the full
    policy stack (hetero K + q8 + faults) agrees across execution paths;
    and a killed sharded run resumes within rtol=1e-5."""
    out = run_in_subprocess("""
        import os, tempfile
        import jax, jax.numpy as jnp, numpy as np
        from repro.core import AdaSEGConfig
        from repro.launch.mesh import make_test_mesh
        from repro.problems import make_bilinear_game
        from repro.ps import (BernoulliFaults, FixedSchedule, PSConfig,
                              PSEngine, StochasticQuantizeCompressor)

        game = make_bilinear_game(jax.random.PRNGKey(0), n=10, sigma=0.1)
        cfg = AdaSEGConfig(g0=1.0, diameter=2.0, alpha=1.0, k=5)
        mesh = make_test_mesh(4, 2)

        def close(a, b, **kw):
            for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(b)):
                np.testing.assert_allclose(np.asarray(x), np.asarray(y), **kw)

        for backend in ("reference", "fused"):
            pscfg = PSConfig(adaseg=cfg, num_workers=4, rounds=4,
                             backend=backend)
            es = PSEngine(game.problem, pscfg, rng=jax.random.PRNGKey(2))
            eh = PSEngine(game.problem, pscfg, rng=jax.random.PRNGKey(2),
                          mesh=mesh, worker_axes=("data",))
            close(es.run(), eh.run(), rtol=1e-5, atol=1e-7)
            np.testing.assert_allclose(np.asarray(es.state.sum_sq),
                                       np.asarray(eh.state.sum_sq),
                                       rtol=1e-5)

        pscfg = PSConfig(adaseg=cfg, num_workers=4, rounds=6,
                         schedule=FixedSchedule([5, 4, 3, 2]),
                         compressor=StochasticQuantizeCompressor(bits=8),
                         faults=BernoulliFaults(p=0.25, seed=5))
        es = PSEngine(game.problem, pscfg, rng=jax.random.PRNGKey(3))
        eh = PSEngine(game.problem, pscfg, rng=jax.random.PRNGKey(3),
                      mesh=mesh)
        close(es.run(), eh.run(), rtol=1e-5, atol=1e-6)
        np.testing.assert_array_equal(np.asarray(es.state.t),
                                      np.asarray(eh.state.t))

        z_full = eh.z_bar()
        with tempfile.TemporaryDirectory() as d:
            p = os.path.join(d, "ps.msgpack")
            e2 = PSEngine(game.problem, pscfg, rng=jax.random.PRNGKey(3),
                          mesh=mesh)
            e2.run(until_round=2)
            e2.save(p)
            e3 = PSEngine(game.problem, pscfg, rng=jax.random.PRNGKey(3),
                          mesh=mesh)
            e3.restore(p)
            assert e3.round == 2
            close(z_full, e3.run(), rtol=1e-5, atol=1e-7)
        print("PS_SHARDED_OK")
    """)
    assert "PS_SHARDED_OK" in out


def test_zoo_engine_sharded_matches_serial():
    """Zoo acceptance on the sharded path: a MinimaxWorker (Adam with its
    moments, UMP with its 1/η sync weighting) through the shard_map engine
    must match the serial engine within rtol=1e-5 — identity config and the
    full heterogeneity + q8 + faults policy stack."""
    out = run_in_subprocess("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.launch.mesh import make_test_mesh
        from repro.optim import MinimaxWorker, adam_minimax, ump
        from repro.problems import make_bilinear_game
        from repro.ps import (BernoulliFaults, FixedSchedule, PSConfig,
                              PSEngine, StochasticQuantizeCompressor)

        game = make_bilinear_game(jax.random.PRNGKey(0), n=10, sigma=0.1)
        mesh = make_test_mesh(4, 2)

        def close(a, b, **kw):
            for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(b)):
                np.testing.assert_allclose(np.asarray(x), np.asarray(y), **kw)

        for opt in (adam_minimax(0.02), ump(1.0, 2.0)):
            pscfg = PSConfig(num_workers=4, rounds=4,
                             worker=MinimaxWorker(opt), local_k=5)
            es = PSEngine(game.problem, pscfg, rng=jax.random.PRNGKey(2))
            eh = PSEngine(game.problem, pscfg, rng=jax.random.PRNGKey(2),
                          mesh=mesh, worker_axes=("data",))
            close(es.run(), eh.run(), rtol=1e-5, atol=1e-7)
            close(es.state, eh.state, rtol=1e-5, atol=1e-7)

            pscfg = PSConfig(num_workers=4, rounds=4,
                             worker=MinimaxWorker(opt),
                             schedule=FixedSchedule([5, 4, 3, 2]),
                             compressor=StochasticQuantizeCompressor(bits=8),
                             faults=BernoulliFaults(p=0.25, seed=5))
            es = PSEngine(game.problem, pscfg, rng=jax.random.PRNGKey(3))
            eh = PSEngine(game.problem, pscfg, rng=jax.random.PRNGKey(3),
                          mesh=mesh)
            close(es.run(), eh.run(), rtol=1e-5, atol=1e-6)
            np.testing.assert_array_equal(np.asarray(es.state.t),
                                          np.asarray(eh.state.t))
        print("ZOO_SHARDED_OK")
    """)
    assert "ZOO_SHARDED_OK" in out


def test_train_round_multidevice_matches_singledevice():
    """One LocalAdaSEG round on a 4×2 mesh must equal the same round on one
    device (GSPMD partitioning is semantics-preserving for our round_fn)."""
    out = run_in_subprocess("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.configs import smoke_config
        from repro.core.adaseg import AdaSEGConfig
        from repro.launch.mesh import make_test_mesh
        from repro.launch.train import (TrainPlan, init_train_state,
                                        make_batches, make_round_fn,
                                        make_shardings)
        cfg = smoke_config("qwen2-0.5b")
        mesh = make_test_mesh(4, 2)
        plan = TrainPlan(cfg=cfg,
                         adaseg=AdaSEGConfig(g0=5.0, diameter=1.0, alpha=0.5,
                                             k=2, average_output=False),
                         worker_mode="paper", k_local=2,
                         global_batch=8, seq=16)
        state = init_train_state(jax.random.PRNGKey(0), plan, mesh)
        batches = make_batches(jax.random.PRNGKey(1), plan, mesh)
        round_fn = make_round_fn(plan)

        # single-device reference
        ref_state, ref_metrics = jax.jit(round_fn)(state, batches)

        state_sh, batch_sh = make_shardings(plan, mesh)
        with mesh:
            got_state, got_metrics = jax.jit(
                round_fn, in_shardings=(state_sh, batch_sh),
                out_shardings=(state_sh, None),
            )(jax.device_put(state, state_sh),
              jax.device_put(batches, batch_sh))
        np.testing.assert_allclose(np.asarray(ref_metrics["loss"]),
                                   np.asarray(got_metrics["loss"]),
                                   rtol=2e-4, atol=2e-5)
        np.testing.assert_allclose(np.asarray(ref_state.sum_sq),
                                   np.asarray(got_state.sum_sq),
                                   rtol=2e-3)
        print("ROUND_MATCH_OK")
    """)
    assert "ROUND_MATCH_OK" in out


def test_dryrun_smoke_mesh():
    """Lower + compile one train round and one serve step on a small mesh
    end-to-end through the dryrun entry points."""
    out = run_in_subprocess("""
        import jax, json
        from repro.launch.mesh import make_test_mesh
        from repro.launch.dryrun import run_one
        mesh = make_test_mesh(4, 2)
        recs = []
        for arch, shape in [("qwen2-0.5b", "train_4k"),
                            ("granite-moe-1b-a400m", "decode_32k"),
                            ("mamba2-370m", "long_500k")]:
            rec = run_one(arch, shape, mesh, "test4x2", k_local=2)
            assert rec["flops"] > 0
            assert rec["bytes_per_device"] > 0
            recs.append(rec["arch"])
        print("DRYRUN_OK", json.dumps(recs))
    """)
    assert "DRYRUN_OK" in out


def test_multipod_axis_shards():
    """The 'pod' axis must actually shard: hierarchical worker mode on a
    (2, 2, 2) mesh gives M = 2 pod-workers and the sync crosses 'pod'."""
    out = run_in_subprocess("""
        import jax
        from repro.launch.mesh import make_test_mesh
        from repro.launch.dryrun import lower_train
        from repro.roofline.analysis import analyze_compiled
        mesh = make_test_mesh(2, 2, pods=2)
        lowered, compiled, plan = lower_train(
            "qwen2-0.5b", "train_4k", mesh, k_local=1,
            worker_mode="hierarchical")
        assert plan.num_workers(mesh) == 2
        rec = analyze_compiled(lowered, compiled, mesh)
        axes = rec["collective_bytes_by_axis"]
        assert any("pod" in a for a in axes), axes
        print("MULTIPOD_OK", axes)
    """)
    assert "MULTIPOD_OK" in out


def test_fused_codec_sharded_matches_serial():
    """Fused-codec acceptance on the sharded path (the PR's codec_backend
    switch through shard_map): for every built-in codec the sharded fused
    engine must match the serial fused engine within the rtol=1e-5 bar
    (and the sharded reference engine at the same tolerance), under the
    full hetero-K + faults policy stack; the fused compressed-psum hook
    must agree with the reference hook through the one-shot driver."""
    out = run_in_subprocess("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.core import AdaSEGConfig
        from repro.launch.mesh import make_test_mesh
        from repro.launch.sharded import run_local_adaseg_sharded
        from repro.problems import make_bilinear_game
        from repro.ps import (BernoulliFaults, FixedSchedule, PSConfig,
                              PSEngine, StochasticQuantizeCompressor,
                              TopKCompressor, make_compressed_psum_sync)

        game = make_bilinear_game(jax.random.PRNGKey(0), n=10, sigma=0.1)
        cfg = AdaSEGConfig(g0=1.0, diameter=2.0, alpha=1.0, k=5)
        mesh = make_test_mesh(4, 2)

        def close(a, b, **kw):
            for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(b)):
                np.testing.assert_allclose(np.asarray(x), np.asarray(y), **kw)

        for comp in (TopKCompressor(fraction=0.25),
                     StochasticQuantizeCompressor(bits=8)):
            kw = dict(adaseg=cfg, num_workers=4, rounds=4,
                      schedule=FixedSchedule([5, 4, 3, 2]), compressor=comp,
                      faults=BernoulliFaults(p=0.25, seed=5))
            serial = PSEngine(game.problem,
                              PSConfig(codec_backend="fused", **kw),
                              rng=jax.random.PRNGKey(3))
            sharded = PSEngine(game.problem,
                               PSConfig(codec_backend="fused", **kw),
                               rng=jax.random.PRNGKey(3), mesh=mesh)
            sharded_ref = PSEngine(game.problem,
                                   PSConfig(codec_backend="reference", **kw),
                                   rng=jax.random.PRNGKey(3), mesh=mesh)
            z_ser, z_sh, z_ref = serial.run(), sharded.run(), sharded_ref.run()
            close(z_ser, z_sh, rtol=1e-5, atol=1e-6)
            close(z_ref, z_sh, rtol=1e-5, atol=1e-6)
            close(serial.state, sharded.state, rtol=1e-5, atol=1e-6)
            np.testing.assert_array_equal(np.asarray(serial.state.t),
                                          np.asarray(sharded.state.t))

        # stateless fused hook through the one-shot driver: identical keys →
        # identical quantization decisions, so reference ≡ fused to rtol.
        for frac_comp in (StochasticQuantizeCompressor(bits=8),):
            z_r, _ = run_local_adaseg_sharded(
                game.problem, cfg, mesh=mesh, rounds=4,
                rng=jax.random.PRNGKey(2),
                sync_fn=make_compressed_psum_sync(("data",), frac_comp))
            z_f, _ = run_local_adaseg_sharded(
                game.problem, cfg, mesh=mesh, rounds=4,
                rng=jax.random.PRNGKey(2),
                sync_fn=make_compressed_psum_sync(("data",), frac_comp,
                                                  codec_backend="fused"))
            close(z_r, z_f, rtol=1e-5, atol=1e-6)
        print("FUSED_CODEC_SHARDED_OK")
    """)
    assert "FUSED_CODEC_SHARDED_OK" in out


def test_model_worker_sharded_matches_serial():
    """The unified stack's acceptance bar: a real transformer ModelWorker
    runs the shard_map engine path (q8-EF uplinks through the fused Pallas
    sync codec) and matches the serial vmap engine at rtol=1e-5 — real
    model pytrees get the PR-2…5 runtime for free."""
    out = run_in_subprocess("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.core import AdaSEGConfig
        from repro.launch.mesh import make_test_mesh
        from repro.models import ModelWorker, make_lm_problem, tiny_lm_config
        from repro.ps import PSConfig, PSEngine, StochasticQuantizeCompressor

        problem = make_lm_problem(tiny_lm_config(), batch=2, seq=8)
        worker = ModelWorker(
            AdaSEGConfig(g0=20.0, diameter=2.0, alpha=1.0, k=2,
                         average_output=False),
            arch="tiny-lm")
        mesh = make_test_mesh(2, 2)
        kw = dict(worker=worker, local_k=2, num_workers=2, rounds=2,
                  compressor=StochasticQuantizeCompressor(bits=8),
                  codec_backend="fused")
        serial = PSEngine(problem, PSConfig(**kw),
                          rng=jax.random.PRNGKey(1))
        sharded = PSEngine(problem, PSConfig(**kw),
                           rng=jax.random.PRNGKey(1), mesh=mesh,
                           worker_axes=("data",))
        z_ser, z_sh = serial.run(), sharded.run()
        for a, b in zip(jax.tree.leaves(z_ser), jax.tree.leaves(z_sh)):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                       rtol=1e-5, atol=1e-6)
        for a, b in zip(jax.tree.leaves(serial.state),
                        jax.tree.leaves(sharded.state)):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                       rtol=1e-5, atol=1e-6)
        print("MODEL_WORKER_SHARDED_OK")
    """, devices=4)
    assert "MODEL_WORKER_SHARDED_OK" in out
