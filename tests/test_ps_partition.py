"""Dirichlet heterogeneity layer: proportions, per-problem partitions, and
end-to-end engine runs on heterogeneous oracles."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import AdaSEGConfig
from repro.data import dirichlet_proportions, group_sampling_logits, quantile_groups
from repro.problems import (
    make_bilinear_game,
    make_robust_logistic,
    make_wgan_problem,
)
from repro.ps import (
    PSConfig,
    PSEngine,
    heterogeneous_bilinear,
    heterogeneous_robust,
    heterogeneous_wgan,
    heterogenize,
)

M = 4


def test_dirichlet_proportions_simplex():
    p = dirichlet_proportions(jax.random.PRNGKey(0), M, 8, alpha=0.5)
    assert p.shape == (M, 8)
    np.testing.assert_allclose(np.asarray(jnp.sum(p, axis=1)),
                               np.ones(M), rtol=1e-5)
    # small alpha skews: some worker puts well-above-uniform mass somewhere
    assert float(jnp.max(p)) > 3.0 / 8.0


def test_group_sampling_logits_shapes():
    p = dirichlet_proportions(jax.random.PRNGKey(0), M, 4, alpha=0.5)
    group_of = quantile_groups(jnp.arange(32, dtype=jnp.float32), 4)
    assert set(np.asarray(group_of).tolist()) == {0, 1, 2, 3}
    logits = group_sampling_logits(p, group_of)
    assert logits.shape == (M, 32)
    probs = jax.nn.softmax(logits, axis=1)
    np.testing.assert_allclose(np.asarray(jnp.sum(probs, axis=1)),
                               np.ones(M), rtol=1e-5)


def test_heterogeneous_bilinear_preserves_global_mean():
    """The across-worker mean of the per-worker noise shifts must vanish, so
    the federated objective equals the original game."""
    game = make_bilinear_game(jax.random.PRNGKey(0), n=10, sigma=0.1)
    p = heterogeneous_bilinear(game, M, jax.random.PRNGKey(1), alpha=0.3)
    assert p.sample_worker is not None and p.name.endswith("@hetero")
    # E[xi | worker] is the worker shift; average over workers ≈ 0
    means = []
    for m in range(M):
        rngs = jax.random.split(jax.random.PRNGKey(2), 256)
        xs = jax.vmap(lambda r: p.sample_worker(r, m))(rngs)
        means.append(np.asarray(jnp.mean(xs, axis=0)))
    np.testing.assert_allclose(np.mean(means, axis=0), np.zeros(10),
                               atol=2e-2)
    # workers actually differ
    assert np.abs(np.asarray(means[0]) - np.asarray(means[1])).max() > 1e-3


def test_heterogeneous_robust_samples_valid_indices():
    rl = make_robust_logistic(jax.random.PRNGKey(0), n=64, d=8, batch=8)
    p = heterogeneous_robust(rl, M, jax.random.PRNGKey(1), alpha=0.2)
    idx = p.sample_worker(jax.random.PRNGKey(2), 1)
    assert idx.shape == (8,)
    assert (np.asarray(idx) >= 0).all() and (np.asarray(idx) < 64).all()
    # skewed: two workers see visibly different index distributions
    draws = lambda m: np.asarray(jax.vmap(
        lambda r: p.sample_worker(r, m)
    )(jax.random.split(jax.random.PRNGKey(3), 128))).ravel()
    h0, _ = np.histogram(draws(0), bins=8, range=(0, 64))
    h1, _ = np.histogram(draws(1), bins=8, range=(0, 64))
    assert np.abs(h0 / h0.sum() - h1 / h1.sum()).max() > 0.05


def test_heterogeneous_wgan_batch_structure():
    wg = make_wgan_problem(jax.random.PRNGKey(0), batch=16)
    p = heterogeneous_wgan(wg, M, jax.random.PRNGKey(1), alpha=0.3)
    xi = p.sample_worker(jax.random.PRNGKey(2), 0)
    assert set(xi) == {"real", "z", "eps"}
    assert xi["real"].shape == (16, 2)
    assert xi["z"].shape == (16, wg.latent_dim)


def test_heterogenize_dispatch():
    game = make_bilinear_game(jax.random.PRNGKey(0), n=6)
    rl = make_robust_logistic(jax.random.PRNGKey(0), n=32, d=4, batch=4)
    wg = make_wgan_problem(jax.random.PRNGKey(0), batch=8)
    for obj in (game, rl, wg):
        p = heterogenize(obj, M, jax.random.PRNGKey(1), alpha=0.5)
        assert p.sample_worker is not None
    with pytest.raises(TypeError):
        heterogenize(object(), M, jax.random.PRNGKey(1))


def test_engine_runs_on_heterogeneous_problem():
    """End to end: Dirichlet-skewed bilinear oracles through the PS engine
    still converge to a finite residual."""
    game = make_bilinear_game(jax.random.PRNGKey(0), n=10, sigma=0.1)
    p = heterogeneous_bilinear(game, M, jax.random.PRNGKey(1), alpha=0.3)
    engine = PSEngine(
        p,
        PSConfig(adaseg=AdaSEGConfig(g0=1.0, diameter=2.0, alpha=1.0, k=5),
                 num_workers=M, rounds=6),
        rng=jax.random.PRNGKey(2), eval_fn=game.residual)
    z = engine.run()
    res = float(game.residual(z))
    assert np.isfinite(res)
    # heterogeneous workers develop different adaptive stepsizes
    assert engine.trace.rounds[-1].eta_spread > 1.0
