"""Problem-construction invariants (oracle unbiasedness, metrics, data)."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import kkt_residual
from repro.data import make_batch, sample_tokens
from repro.configs import smoke_config
from repro.problems import (
    make_bilinear_game,
    make_quadratic_game,
    make_robust_logistic,
    make_wgan_problem,
)


def test_bilinear_oracle_unbiased():
    game = make_bilinear_game(jax.random.PRNGKey(0), n=8, sigma=0.3)
    p = game.problem
    z = p.init(jax.random.PRNGKey(1))
    mean = p.mean_oracle(z, None)
    gs = [p.oracle(z, p.sample(r))
          for r in jax.random.split(jax.random.PRNGKey(2), 512)]
    emp = jax.tree.map(lambda *v: jnp.mean(jnp.stack(v), 0), *gs)
    for a, b in zip(jax.tree.leaves(emp), jax.tree.leaves(mean)):
        np.testing.assert_allclose(a, b, atol=0.08)


def test_bilinear_residual_zero_iff_saddle():
    game = make_bilinear_game(jax.random.PRNGKey(0), n=6, sigma=0.1)
    # run long enough to get near the saddle, residual must shrink
    from repro.core import AdaSEGConfig, run_local_adaseg
    cfg = AdaSEGConfig(g0=1.0, diameter=4.0, alpha=1.0, k=20)
    zbar, _ = run_local_adaseg(game.problem, cfg, num_workers=4, rounds=50,
                               rng=jax.random.PRNGKey(3))
    assert float(game.residual(zbar)) < 0.1
    assert float(game.duality_gap(zbar)) < 0.5
    assert float(game.duality_gap(zbar)) >= -1e-5


def test_quadratic_saddle_is_stationary():
    qg = make_quadratic_game(jax.random.PRNGKey(1), n=8, sigma=0.0)
    g = qg.problem.mean_oracle(qg.z_star, None)
    for leaf in jax.tree.leaves(g):
        np.testing.assert_allclose(leaf, 0.0, atol=1e-4)
    assert float(kkt_residual(qg.problem, qg.z_star)) < 1e-3


def test_robust_logistic_oracle_unbiased():
    rl = make_robust_logistic(jax.random.PRNGKey(2), n=32, d=4, batch=8)
    p = rl.problem
    z = p.init(jax.random.PRNGKey(3))
    mean = p.mean_oracle(z, None)
    gs = [p.oracle(z, p.sample(r))
          for r in jax.random.split(jax.random.PRNGKey(4), 768)]
    emp = jax.tree.map(lambda *v: jnp.mean(jnp.stack(v), 0), *gs)
    for a, b in zip(jax.tree.leaves(emp), jax.tree.leaves(mean)):
        np.testing.assert_allclose(a, b, atol=0.25)


def test_wgan_loss_finite_and_gp_active():
    wg = make_wgan_problem(jax.random.PRNGKey(5))
    p = wg.problem
    z = p.init(jax.random.PRNGKey(6))
    g = p.oracle(z, p.sample(jax.random.PRNGKey(7)))
    assert all(bool(jnp.all(jnp.isfinite(v))) for v in jax.tree.leaves(g))
    # discriminator block gradient nonzero (GP term active)
    gd_norm = sum(float(jnp.sum(v**2)) for v in jax.tree.leaves(g[1]))
    assert gd_norm > 0


def test_synthetic_tokens_deterministic_and_structured():
    cfg = smoke_config("qwen2-0.5b")
    a = sample_tokens(jax.random.PRNGKey(0), 4, 64, cfg.vocab_size)
    b = sample_tokens(jax.random.PRNGKey(0), 4, 64, cfg.vocab_size)
    np.testing.assert_array_equal(a, b)
    assert a.min() >= 0 and a.max() < cfg.vocab_size
    # zipf skew: token 0 much more frequent than the tail
    big = sample_tokens(jax.random.PRNGKey(1), 64, 256, cfg.vocab_size)
    freq0 = float(jnp.mean(big == 0))
    assert freq0 > 3.0 / cfg.vocab_size


def test_make_batch_shapes():
    cfg = smoke_config("whisper-small")
    batch = make_batch(jax.random.PRNGKey(0), cfg, 4, 32)
    assert batch["tokens"].shape == (4, 32)
    assert batch["labels"].shape == (4, 32)
    assert batch["frontend"].shape == (4, cfg.encoder_seq, cfg.d_model)
