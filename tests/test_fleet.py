"""Fleet scale: compiled-chunk caching, batched-admission tie-breaks,
sampled-client rounds, and the v6 trace schema."""
import dataclasses
import json
import math

import jax
import numpy as np
import pytest

from repro.core import AdaSEGConfig
from repro.problems import make_bilinear_game
from repro.ps import (
    AsyncPSConfig,
    AsyncPSEngine,
    ClientSampler,
    ConstantLatency,
    PSConfig,
    PSEngine,
    StochasticQuantizeCompressor,
    TraceRecorder,
)
from repro.ps.engine import serial_chunk_traces
from repro.ps.trace import TRACE_VERSION

M, R, K = 4, 6, 3
N_DIM = 10


@pytest.fixture(scope="module")
def game():
    return make_bilinear_game(jax.random.PRNGKey(0), n=N_DIM, sigma=0.1)


def _cfg(k=K):
    return AdaSEGConfig(g0=1.0, diameter=2.0, alpha=1.0, k=k)


def _as_async(pscfg: PSConfig, **extra) -> AsyncPSConfig:
    base = {f.name: getattr(pscfg, f.name)
            for f in dataclasses.fields(PSConfig)}
    return AsyncPSConfig(**base, **extra)


def _assert_trees_equal(a, b):
    for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(b)):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


# ---------------------------------------------------------------------------
# Compiled-chunk cache: remainder chunks and same-config engines don't
# retrace
# ---------------------------------------------------------------------------

def test_remainder_chunk_and_second_engine_do_not_recompile(game):
    """checkpoint_every chunking (7 = 3+3+1 rounds) costs at most one trace
    per distinct scan length — and a second engine with the same config
    costs ZERO new traces: the compiled chunk is cached process-wide."""
    # k=2 gives this test a chunk-cache key no other test compiles
    cfg = PSConfig(adaseg=_cfg(k=2), num_workers=3, rounds=7)

    before = serial_chunk_traces()
    e1 = PSEngine(game.problem, cfg, rng=jax.random.PRNGKey(2))
    e1.run(checkpoint_every=3)              # chunk lengths 3, 3, 1
    mid = serial_chunk_traces()
    assert mid - before <= 2, (
        f"chunked run traced {mid - before}× — the remainder chunk "
        "retriggered compilation beyond one trace per distinct length"
    )

    e2 = PSEngine(game.problem, cfg, rng=jax.random.PRNGKey(3))
    e2.run(checkpoint_every=3)
    assert serial_chunk_traces() == mid, (
        "a second engine with an identical config re-traced the chunk — "
        "the process-wide cache missed"
    )
    # different chunking of the same rounds computes the same trajectory
    e3 = PSEngine(game.problem, cfg, rng=jax.random.PRNGKey(2))
    e3.run()
    _assert_trees_equal(e1.state, e3.state)


# ---------------------------------------------------------------------------
# Async event queue: deterministic tie-break for simultaneous arrivals
# ---------------------------------------------------------------------------

def _simultaneous_cfg():
    """Worker-equal latency + compression (disables the lockstep shortcut):
    every round, all M uplinks arrive at the same simulated instant through
    the per-arrival machinery."""
    return _as_async(
        PSConfig(adaseg=_cfg(), num_workers=M, rounds=R,
                 compressor=StochasticQuantizeCompressor(bits=8)),
        latency=ConstantLatency(step_s=1.0, up_s=0.5, down_s=0.25),
        staleness_bound=math.inf,
    )


def test_simultaneous_arrivals_admit_ascending_and_rerun_stable(game):
    """Identical timestamps across workers admit as ONE batch in ascending
    worker id — the documented tie-break — and the order is a pure function
    of the deterministic tables, so a rerun reproduces it exactly."""
    def run():
        eng = AsyncPSEngine(game.problem, _simultaneous_cfg(),
                            rng=jax.random.PRNGKey(2))
        eng.run()
        return eng

    e1, e2 = run(), run()
    # every admission is the whole fleet at one instant...
    assert e1.n_admissions == R
    for rec in e1.trace.rounds[:-1]:
        assert rec.alive == [True] * M
    # ...and the per-worker span sequence inside each admission is ascending
    # worker id (the batch order all per-worker server work follows)
    for cat in ("broadcast", "local-compute"):
        # per-worker simulated-clock spans only (the wall-clock phase-batch
        # span shares the local-compute cat but carries no single worker)
        spans = [sp for sp in e1.tracer.spans
                 if sp.cat == cat and "worker" in sp.attrs]
        assert spans, f"no per-worker {cat} spans recorded"
        per_batch = [sp.attrs["worker"] for sp in spans]
        for i in range(0, len(per_batch), M):
            batch = per_batch[i:i + M]
            assert batch == sorted(batch) == list(range(M))
    # seed-stable: the rerun's trace is record-for-record identical
    assert len(e1.trace.rounds) == len(e2.trace.rounds)
    for r1, r2 in zip(e1.trace.rounds, e2.trace.rounds):
        assert dataclasses.asdict(r1) == dataclasses.asdict(r2)
    _assert_trees_equal(e1.state, e2.state)


def test_tie_break_survives_resume(game, tmp_path):
    """Checkpoint mid-queue and resume: the admission order (and thus the
    whole trace tail) is re-derived identically — the tie-break is part of
    the deterministic replay contract."""
    ck = str(tmp_path / "tie.ckpt")
    e1 = AsyncPSEngine(game.problem, _simultaneous_cfg(),
                       rng=jax.random.PRNGKey(2))
    e1.run(until_admissions=3)
    e1.save(ck)
    e2 = AsyncPSEngine(game.problem, _simultaneous_cfg(),
                       rng=jax.random.PRNGKey(2)).restore(ck)
    e1.run()
    e2.run()
    _assert_trees_equal(e1.state, e2.state)
    tail1 = [r for r in e1.trace.rounds if r.round >= 3]
    tail2 = [r for r in e2.trace.rounds if r.round >= 3]
    assert len(tail1) == len(tail2) > 0
    for r1, r2 in zip(tail1, tail2):
        assert dataclasses.asdict(r1) == dataclasses.asdict(r2)


# ---------------------------------------------------------------------------
# Sampled-client rounds: sync engine
# ---------------------------------------------------------------------------

def test_sampled_sync_smoke_and_ledger(game):
    fleet, sample = 10, 4
    cfg = PSConfig(adaseg=_cfg(), num_workers=fleet, rounds=R,
                   sampler=ClientSampler(sample=sample, seed=1))
    eng = PSEngine(game.problem, cfg, rng=jax.random.PRNGKey(2),
                   eval_fn=game.residual)
    eng.run()
    assert eng.trace.meta["sampler"] == "sample4-uniform-seed1"
    assert eng.trace.meta["sample"] == sample
    assert eng.trace.meta["workers"] == fleet
    # per-record lists are per sampled lane, ids ascending in [0, fleet)
    draws = cfg.sampler.draws(fleet, R)
    for r, rec in enumerate(eng.trace.rounds):
        assert rec.sampled_workers == draws[r].tolist()
        assert len(rec.local_steps) == sample
        assert rec.local_steps == [K] * sample
    # the step ledger counts only sampled work
    assert eng.trace.total_steps == R * sample * K
    assert np.isfinite(eng.trace.rounds[-1].residual)


def test_sampled_sync_deterministic_and_full_sample_matches_dense(game):
    fleet = 6
    mk = lambda sampler: PSEngine(
        game.problem,
        PSConfig(adaseg=_cfg(), num_workers=fleet, rounds=R,
                 sampler=sampler),
        rng=jax.random.PRNGKey(2))

    s = ClientSampler(sample=3, seed=7)
    e1, e2 = mk(s), mk(s)
    _assert_trees_equal(e1.run(), e2.run())
    _assert_trees_equal(e1.state, e2.state)

    # sample == fleet draws every worker every round: the gather/scatter
    # path must agree with the dense serial chunk (same math, permutation-
    # identity data movement)
    full = mk(ClientSampler(sample=fleet, seed=7))
    z_full = full.run()
    dense = mk(None)
    z_dense = dense.run()
    for a, b in zip(jax.tree.leaves(z_full), jax.tree.leaves(z_dense)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-5, atol=1e-6)


def test_sampled_sync_checkpoint_resume_and_fingerprint(game, tmp_path):
    ck = str(tmp_path / "fleet.ckpt")
    sampler = ClientSampler(sample=3, seed=1)
    cfg = PSConfig(adaseg=_cfg(), num_workers=8, rounds=R, sampler=sampler)
    e1 = PSEngine(game.problem, cfg, rng=jax.random.PRNGKey(2))
    e1.run(until_round=3)
    e1.save(ck)
    e2 = PSEngine(game.problem, cfg, rng=jax.random.PRNGKey(2)).restore(ck)
    e1.run()
    e2.run()
    _assert_trees_equal(e1.state, e2.state)

    # a different sampling law is refused: the checkpointed participation
    # table wouldn't replay
    other = dataclasses.replace(cfg, sampler=ClientSampler(sample=3, seed=9))
    with pytest.raises(ValueError, match="sampler"):
        PSEngine(game.problem, other, rng=jax.random.PRNGKey(2)).restore(ck)
    # ...and so is restoring into a full-participation engine (the state
    # layout itself differs — sampler_fp is only present in sampled runs)
    dense = dataclasses.replace(cfg, sampler=None)
    with pytest.raises(ValueError):
        PSEngine(game.problem, dense, rng=jax.random.PRNGKey(2)).restore(ck)


def test_sampled_gather_scatter_stays_inside_the_compiled_scan(game):
    """PR-8 follow-up, resolved: the sampled gather/scatter does NOT
    round-trip through a host round loop. The whole R-round sampled run is
    ONE compiled program — the ``lax.scan`` carries the (N, …) fleet store
    and each round's lane gather/scatter happens inside the scan body
    (``gather-sampled`` / ``scatter-sampled`` named scopes in the chunk).

    Pinned strictly via the chunk trace counter: a host-side per-round
    loop would invoke/trace one program per round, tripping both asserts
    below. k=7 gives this test a chunk-cache key nothing else compiles."""
    fleet, rounds = 7, 5
    cfg = PSConfig(adaseg=_cfg(k=7), num_workers=fleet, rounds=rounds,
                   sampler=ClientSampler(sample=3, seed=11))
    before = serial_chunk_traces()
    PSEngine(game.problem, cfg, rng=jax.random.PRNGKey(0)).run()
    assert serial_chunk_traces() == before + 1, (
        "sampled R-round run must trace exactly one scan program"
    )
    # a second engine, same config: zero new traces — still one program
    PSEngine(game.problem, cfg, rng=jax.random.PRNGKey(1)).run()
    assert serial_chunk_traces() == before + 1


def test_sampler_validation():
    with pytest.raises(ValueError, match="sample"):
        ClientSampler(sample=0)
    with pytest.raises(ValueError, match="exceeds fleet"):
        ClientSampler(sample=9).draws(4, 2)
    with pytest.raises(ValueError, match="weights"):
        ClientSampler(sample=1, weights=(-1.0, 1.0))


def test_sampler_weighted_marginals():
    """sample=1 inclusion probability is exactly w/Σw — empirical draw
    frequencies over many rounds match within tolerance. (The hypothesis
    suite in test_properties.py covers the law more broadly; this
    deterministic pin runs even without hypothesis installed.)"""
    w = (1.0, 2.0, 4.0, 8.0)
    sampler = ClientSampler(sample=1, seed=0, weights=w)
    rounds = 6000
    freq = np.bincount(sampler.draws(4, rounds).ravel(),
                       minlength=4) / rounds
    np.testing.assert_allclose(freq, np.asarray(w) / sum(w), atol=0.02)


# ---------------------------------------------------------------------------
# Sampled-client rounds: async engine
# ---------------------------------------------------------------------------

def _sampled_async_cfg(fleet=8, sample=3, tau=math.inf):
    return _as_async(
        PSConfig(adaseg=_cfg(), num_workers=fleet, rounds=R,
                 sampler=ClientSampler(sample=sample, seed=1)),
        latency=ConstantLatency(step_s=1.0, up_s=0.2, down_s=0.1),
        staleness_bound=tau,
    )


@pytest.mark.parametrize("tau", [math.inf, 2.0])
def test_sampled_async_smoke_and_ledger(game, tau):
    """Un-drawn rounds are skipped at zero simulated cost, progress
    advances through the skips (no staleness deadlock), and the step
    ledger still balances: Σ local_steps ≡ sampled work."""
    fleet, sample = 8, 3
    eng = AsyncPSEngine(game.problem, _sampled_async_cfg(tau=tau),
                        rng=jax.random.PRNGKey(2), eval_fn=game.residual)
    eng.run()
    assert eng.done
    assert eng.trace.meta["sampler"] == "sample3-uniform-seed1"
    assert eng.trace.total_steps == R * sample * K
    assert np.isfinite(eng.trace.rounds[-1].residual)


def test_sampled_async_resume_bit_exact(game, tmp_path):
    ck = str(tmp_path / "fleet-async.ckpt")
    e1 = AsyncPSEngine(game.problem, _sampled_async_cfg(),
                       rng=jax.random.PRNGKey(2))
    e1.run(until_admissions=2)
    e1.save(ck)
    e2 = AsyncPSEngine(game.problem, _sampled_async_cfg(),
                       rng=jax.random.PRNGKey(2)).restore(ck)
    e1.run()
    e2.run()
    _assert_trees_equal(e1.state, e2.state)
    assert e1.sim_time == e2.sim_time
    tail1 = [r for r in e1.trace.rounds if r.round >= 2]
    tail2 = [r for r in e2.trace.rounds if r.round >= 2]
    assert len(tail1) == len(tail2) > 0
    for r1, r2 in zip(tail1, tail2):
        assert dataclasses.asdict(r1) == dataclasses.asdict(r2)


# ---------------------------------------------------------------------------
# Trace schema v6: load-compat
# ---------------------------------------------------------------------------

def test_trace_v6_roundtrip_and_v5_compat(game, tmp_path):
    fleet, sample = 10, 4
    cfg = PSConfig(adaseg=_cfg(), num_workers=fleet, rounds=R,
                   sampler=ClientSampler(sample=sample, seed=1))
    eng = PSEngine(game.problem, cfg, rng=jax.random.PRNGKey(2))
    eng.run()
    path = str(tmp_path / "v6.json")
    eng.trace.save(path)
    back = TraceRecorder.load(path)
    assert back.version == TRACE_VERSION == 8
    assert back.meta["sampler"] == "sample4-uniform-seed1"
    assert back.rounds[0].sampled_workers == eng.trace.rounds[0].sampled_workers

    # a v5-era file (no sampled_workers, no sampler meta) still loads, the
    # new field defaulting to None = full participation
    payload = json.loads(open(path).read())
    payload["version"] = 5
    del payload["meta"]["sampler"], payload["meta"]["sample"]
    for rec in payload["rounds"]:
        del rec["sampled_workers"]
    old = str(tmp_path / "v5.json")
    with open(old, "w") as f:
        json.dump(payload, f)
    b5 = TraceRecorder.load(old)
    assert b5.version == 5
    assert all(r.sampled_workers is None for r in b5.rounds)
    assert b5.total_steps == back.total_steps
