"""Serving correctness: token-by-token decode with KV/SSM/RG-LRU caches must
reproduce the full-sequence forward logits for every architecture family."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import list_archs, smoke_config
from repro.core import AdaSEGConfig
from repro.models import (
    ModelWorker,
    decode_step,
    forward,
    init_cache,
    init_model,
    make_lm_problem,
    tiny_lm_config,
)
from repro.models.transformer import encode
from repro.ps import PSConfig, PSEngine

B, S = 2, 16


@pytest.mark.parametrize("arch", list_archs())
def test_decode_matches_forward(arch):
    cfg = smoke_config(arch)
    params, _ = init_model(jax.random.PRNGKey(0), cfg)
    tokens = jax.random.randint(jax.random.PRNGKey(1), (B, S), 0,
                                cfg.vocab_size)
    enc = None
    if cfg.is_encoder_decoder:
        frames = 0.1 * jax.random.normal(
            jax.random.PRNGKey(2), (B, cfg.encoder_seq, cfg.d_model)
        )
        enc = encode(params, cfg, frames)
    elif cfg.cross_attn_every:
        enc = 0.1 * jax.random.normal(
            jax.random.PRNGKey(2), (B, cfg.encoder_seq, cfg.d_model)
        )
    ref, _ = forward(params, cfg, tokens, enc_states=enc)
    cache = init_cache(cfg, B, max_len=S)
    step = jax.jit(
        lambda p, t, pos, c: decode_step(p, cfg, t, pos, c, enc_states=enc)
    )
    outs = []
    for t in range(S):
        lg, cache = step(params, tokens[:, t:t + 1],
                         jnp.full((B,), t, jnp.int32), cache)
        outs.append(lg[:, 0])
    dec = jnp.stack(outs, axis=1)
    np.testing.assert_allclose(dec, ref, rtol=2e-3, atol=2e-4)


def test_sliding_window_cache_is_ring_buffer():
    """Window layers must allocate O(window) slots and still match forward
    for sequences longer than the window."""
    cfg = smoke_config("mixtral-8x22b")  # all layers SWA, window=8
    assert cfg.sliding_window == 8
    params, _ = init_model(jax.random.PRNGKey(0), cfg)
    s = 24  # 3× window
    tokens = jax.random.randint(jax.random.PRNGKey(1), (B, s), 0,
                                cfg.vocab_size)
    ref, _ = forward(params, cfg, tokens)
    cache = init_cache(cfg, B, max_len=s)
    # ring-buffer allocation: slots == window, not seq
    assert cache[0]["k"].shape[1] == cfg.sliding_window
    outs = []
    for t in range(s):
        lg, cache = decode_step(params, cfg, tokens[:, t:t + 1],
                                jnp.full((B,), t, jnp.int32), cache)
        outs.append(lg[:, 0])
    np.testing.assert_allclose(jnp.stack(outs, 1), ref, rtol=2e-3, atol=2e-4)


def test_long_context_state_size_constant_mamba():
    """SSM cache is O(1) in context length."""
    cfg = smoke_config("mamba2-370m")
    c1 = init_cache(cfg, 1, max_len=64)
    c2 = init_cache(cfg, 1, max_len=4096)
    n1 = sum(v.size for v in jax.tree.leaves(c1))
    n2 = sum(v.size for v in jax.tree.leaves(c2))
    assert n1 == n2


# ---------------------------------------------------------------------------
# Serving from the PS runtime (ROADMAP item 5): the decode path consumes a
# real mid-training PSEngine checkpoint — train a tiny LM through the engine,
# checkpoint it, restore in a fresh "serving" engine, and run the
# decode-vs-forward consistency check on the *trained* z̄ instead of private
# init_model stub weights.

def _lm_engine(cfg, prob):
    worker = ModelWorker(AdaSEGConfig(g0=5.0, diameter=1.0, k=2),
                         arch=cfg.name)
    return PSEngine(
        prob,
        PSConfig(worker=worker, local_k=2, num_workers=2, rounds=2),
        rng=jax.random.PRNGKey(0),
    )


def test_decode_from_ps_checkpoint(tmp_path):
    cfg = tiny_lm_config()
    prob = make_lm_problem(cfg, batch=B, seq=8)
    path = str(tmp_path / "lm.ckpt")

    trained = _lm_engine(cfg, prob)
    z_train = trained.run(checkpoint_path=path, checkpoint_every=1)

    # the serving process: a fresh engine restores the checkpoint and its
    # z̄ IS the parameter pytree the decode stack consumes
    server = _lm_engine(cfg, prob).restore(path)
    params = server.z_bar()
    for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(z_train)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    # genuinely trained weights, not the init_model stub
    stub, _ = init_model(jax.random.PRNGKey(0), cfg)
    assert any(
        not np.array_equal(np.asarray(a), np.asarray(b))
        for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(stub))
    )

    s = 8
    tokens = jax.random.randint(jax.random.PRNGKey(1), (B, s), 0,
                                cfg.vocab_size)
    ref, _ = forward(params, cfg, tokens)
    cache = init_cache(cfg, B, max_len=s)
    outs = []
    for t in range(s):
        lg, cache = decode_step(params, cfg, tokens[:, t:t + 1],
                                jnp.full((B,), t, jnp.int32), cache)
        outs.append(lg[:, 0])
    np.testing.assert_allclose(jnp.stack(outs, 1), ref, rtol=2e-3, atol=2e-4)


def test_wrong_arch_ps_restore_rejected(tmp_path):
    """An engine built for a different architecture must refuse the
    checkpoint (the arch label is folded into the worker fingerprint)."""
    cfg = tiny_lm_config()
    prob = make_lm_problem(cfg, batch=B, seq=8)
    path = str(tmp_path / "lm.ckpt")
    _lm_engine(cfg, prob).run(checkpoint_path=path, checkpoint_every=2)

    wrong = PSEngine(
        prob,
        PSConfig(worker=ModelWorker(AdaSEGConfig(g0=5.0, diameter=1.0, k=2),
                                    arch="qwen2-0.5b"),
                 local_k=2, num_workers=2, rounds=2),
        rng=jax.random.PRNGKey(0),
    )
    with pytest.raises(ValueError, match="different optimizer"):
        wrong.restore(path)
