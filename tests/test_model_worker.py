"""The unified training stack: real models (transformer LM / WGAN) as
ModelWorkers on the PS runtime.

Covers the PR's acceptance bars: models train through PSEngine with q8-EF
compression, AsyncPSEngine at τ=0 is bit-exact with the sync engine,
ModelWorker checkpoints round-trip bit-exactly mid-stream (serial and
async) with wrong-architecture restores rejected, the Pallas
flash-attention/SSD kernels on the model hot path agree with the reference
math under grad, and the refactored ``launch.train.make_round_fn``
reproduces the pre-refactor trajectory bit-exactly (the η/norm/sync math
now comes from ``core.adaseg``)."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import AdaSEGConfig, run_local_adaseg
from repro.core.adaseg import eta_of
from repro.core.tree import tree_norm_sq
from repro.launch.mesh import make_test_mesh
from repro.launch.train import (
    TrainPlan,
    init_train_state,
    make_batches,
    make_ps_engine,
    make_round_fn,
)
from repro.models import (
    ModelWorker,
    loss_fn,
    make_lm_problem,
    tiny_lm_config,
)
from repro.problems import make_wgan_problem
from repro.ps import (
    AsyncPSConfig,
    AsyncPSEngine,
    ConstantLatency,
    PSConfig,
    PSEngine,
    StochasticQuantizeCompressor,
)

M, R, K = 2, 2, 2
BATCH, SEQ = 2, 8


@pytest.fixture(scope="module")
def lm_problem():
    return make_lm_problem(tiny_lm_config(), batch=BATCH, seq=SEQ)


@pytest.fixture(scope="module")
def wgan():
    return make_wgan_problem(jax.random.PRNGKey(0))


def _acfg(**kw):
    base = dict(g0=20.0, diameter=2.0, alpha=1.0, k=K, average_output=False)
    base.update(kw)
    return AdaSEGConfig(**base)


def _assert_trees_equal(a, b):
    la, lb = jax.tree.leaves(a), jax.tree.leaves(b)
    assert len(la) == len(lb)
    for x, y in zip(la, lb):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


def _as_async(pscfg: PSConfig, **extra) -> AsyncPSConfig:
    base = {f.name: getattr(pscfg, f.name)
            for f in dataclasses.fields(PSConfig)}
    return AsyncPSConfig(**base, **extra)


# ---------------------------------------------------------------------------
# Real models train through the engine (q8-EF on)
# ---------------------------------------------------------------------------

def test_lm_trains_through_engine_with_q8_ef(lm_problem):
    worker = ModelWorker(_acfg(), arch="tiny-lm")
    eng = PSEngine(
        lm_problem,
        PSConfig(worker=worker, local_k=K, num_workers=M, rounds=R,
                 compressor=StochasticQuantizeCompressor(bits=8)),
        rng=jax.random.PRNGKey(1),
        eval_fn=lambda z: loss_fn(z, tiny_lm_config(),
                                  lm_problem.sample(jax.random.PRNGKey(9))),
    )
    z = eng.run()
    # z̄ is a real parameter pytree and the eval loss is finite
    assert jax.tree.structure(z) == jax.tree.structure(
        lm_problem.init(jax.random.PRNGKey(0)))
    assert np.isfinite(eng.trace.rounds[-1].residual)
    # q8 uplinks genuinely compress vs the dense broadcast
    rec = eng.trace.rounds[-1]
    assert 0 < rec.bytes_up < 0.5 * rec.bytes_down


def test_wgan_modelworker_matches_serial_driver(wgan):
    """ModelWorker adds only the architecture fingerprint — on identity
    compression the engine must reproduce ``run_local_adaseg`` bit-exactly
    for the real WGAN minimax problem."""
    cfg = _acfg(g0=50.0, diameter=1.0)
    z_ser, _ = run_local_adaseg(
        wgan.problem, cfg, num_workers=M, rounds=R,
        rng=jax.random.PRNGKey(2))
    eng = PSEngine(
        wgan.problem,
        PSConfig(worker=ModelWorker(cfg, arch=wgan.problem.name),
                 local_k=K, num_workers=M, rounds=R),
        rng=jax.random.PRNGKey(2))
    _assert_trees_equal(z_ser, eng.run())


# ---------------------------------------------------------------------------
# Async engine: τ=0 bit-exact with sync on model payloads
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("case", ["lm", "wgan"])
def test_async_tau0_bit_exact_with_sync(case, lm_problem, wgan):
    if case == "lm":
        problem, cfg, arch = lm_problem, _acfg(), "tiny-lm"
    else:
        problem, cfg, arch = (wgan.problem, _acfg(g0=50.0, diameter=1.0),
                              wgan.problem.name)
    pscfg = PSConfig(worker=ModelWorker(cfg, arch=arch), local_k=K,
                     num_workers=M, rounds=R)
    eng = PSEngine(problem, pscfg, rng=jax.random.PRNGKey(3))
    z_sync = eng.run()
    a = AsyncPSEngine(
        problem,
        _as_async(pscfg,
                  latency=ConstantLatency(step_s=(1.0, 3.0), up_s=0.5),
                  staleness_bound=0.0),
        rng=jax.random.PRNGKey(3))
    _assert_trees_equal(z_sync, a.run())
    _assert_trees_equal(eng.state, a.state)


# ---------------------------------------------------------------------------
# Checkpointing: bit-exact mid-stream resume, wrong-arch rejected
# ---------------------------------------------------------------------------

def test_model_checkpoint_roundtrip_serial(lm_problem, tmp_path):
    path = str(tmp_path / "lm.ckpt")
    mk = lambda: PSEngine(
        lm_problem,
        PSConfig(worker=ModelWorker(_acfg(), arch="tiny-lm"), local_k=K,
                 num_workers=M, rounds=3,
                 compressor=StochasticQuantizeCompressor(bits=8)),
        rng=jax.random.PRNGKey(4))
    ref = mk()
    z_ref = ref.run()

    eng = mk()
    eng.run(until_round=1)
    eng.save(path)
    resumed = mk().restore(path)
    assert resumed.round == 1
    _assert_trees_equal(eng.state, resumed.state)
    _assert_trees_equal(z_ref, resumed.run())


def test_model_checkpoint_roundtrip_async(lm_problem, tmp_path):
    path = str(tmp_path / "lm_async.ckpt")
    cfg = _as_async(
        PSConfig(worker=ModelWorker(_acfg(), arch="tiny-lm"), local_k=K,
                 num_workers=M, rounds=3),
        latency=ConstantLatency(step_s=(1.0, 2.0), up_s=0.3),
        staleness_bound=1.0)
    mk = lambda: AsyncPSEngine(lm_problem, cfg, rng=jax.random.PRNGKey(5))
    ref = mk()
    z_ref = ref.run()

    eng = mk()
    eng.run(until_admissions=2)          # kill mid-event-queue
    eng.save(path)
    resumed = mk().restore(path)
    _assert_trees_equal(eng.state, resumed.state)
    _assert_trees_equal(z_ref, resumed.run())


def test_wrong_architecture_restore_rejected(lm_problem, tmp_path):
    path = str(tmp_path / "arch.ckpt")
    eng = PSEngine(
        lm_problem,
        PSConfig(worker=ModelWorker(_acfg(), arch="tiny-lm"), local_k=K,
                 num_workers=M, rounds=R),
        rng=jax.random.PRNGKey(6))
    eng.run(until_round=1)
    eng.save(path)
    other = PSEngine(
        lm_problem,
        PSConfig(worker=ModelWorker(_acfg(), arch="other-arch"), local_k=K,
                 num_workers=M, rounds=R),
        rng=jax.random.PRNGKey(6))
    with pytest.raises(ValueError, match="different optimizer"):
        other.restore(path)


# ---------------------------------------------------------------------------
# Pallas kernels on the model hot path
# ---------------------------------------------------------------------------

def test_pallas_attention_backend_matches_reference_under_grad():
    cfg_r = tiny_lm_config()
    cfg_p = tiny_lm_config(attn_backend="pallas")
    from repro.models import init_model
    from repro.data.synthetic import make_batch

    params, _ = init_model(jax.random.PRNGKey(0), cfg_r)
    batch = make_batch(jax.random.PRNGKey(1), cfg_r, BATCH, 16)
    lr, gr = jax.value_and_grad(loss_fn)(params, cfg_r, batch)
    lp, gp = jax.value_and_grad(loss_fn)(params, cfg_p, batch)
    np.testing.assert_allclose(float(lr), float(lp), rtol=1e-5)
    for a, b in zip(jax.tree.leaves(gr), jax.tree.leaves(gp)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-3, atol=1e-4)


def test_pallas_ssd_backend_matches_reference_under_grad():
    from repro.models import init_model
    from repro.data.synthetic import make_batch

    base = dataclasses.replace(
        tiny_lm_config(name="tiny-ssm"), arch_type="ssm",
        layer_pattern="ssm", ssm_state=8, ssm_head_dim=16, ssm_chunk=8)
    cfg_p = dataclasses.replace(base, ssm_backend="pallas")
    params, _ = init_model(jax.random.PRNGKey(0), base)
    batch = make_batch(jax.random.PRNGKey(1), base, BATCH, 16)
    lr, gr = jax.value_and_grad(loss_fn)(params, base, batch)
    lp, gp = jax.value_and_grad(loss_fn)(params, cfg_p, batch)
    np.testing.assert_allclose(float(lr), float(lp), rtol=1e-5)
    for a, b in zip(jax.tree.leaves(gr), jax.tree.leaves(gp)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-3, atol=1e-4)


# ---------------------------------------------------------------------------
# launch.train refactor: η math deduped, trajectory pinned
# ---------------------------------------------------------------------------

def _old_stacked_norm_sq(tree):
    """Pre-refactor launch.train._stacked_norm_sq, vendored verbatim."""
    def one(leaf):
        x = leaf.astype(jnp.float32)
        return jnp.sum(x * x, axis=tuple(range(1, x.ndim)))

    return jax.tree.reduce(jnp.add, jax.tree.map(one, tree))


def _old_round_fn(plan):
    """Pre-refactor launch.train.make_round_fn, vendored verbatim: private
    η formula, private per-worker norm reduction, private f32 weighted
    sync. The refactored module must reproduce it bit-exactly."""
    from repro.launch.train import TrainState, _bcast

    cfg, acfg = plan.cfg, plan.adaseg

    def worker_loss(params, batch):
        return loss_fn(params, cfg, batch)

    vgrad = jax.vmap(jax.value_and_grad(worker_loss))

    def eta_of_(sum_sq):
        return acfg.diameter * acfg.alpha / jnp.sqrt(acfg.g0**2 + sum_sq)

    def local_step(carry, batch_k):
        b1 = jax.tree.map(lambda v: v[0], batch_k)
        b2 = jax.tree.map(lambda v: v[1], batch_k)
        eta = eta_of_(carry.sum_sq)

        _, m_t = vgrad(carry.params, b1)
        z_t = jax.tree.map(
            lambda z, g: z - _bcast(eta, z) * g, carry.params, m_t)
        loss, g_t = vgrad(z_t, b2)
        z_new = jax.tree.map(
            lambda z, g: z - _bcast(eta, z) * g, carry.params, g_t)

        diff1 = jax.tree.map(jnp.subtract, z_t, carry.params)
        diff2 = jax.tree.map(jnp.subtract, z_t, z_new)
        z_sq = (_old_stacked_norm_sq(diff1) + _old_stacked_norm_sq(diff2)) / (
            5.0 * eta**2)
        gss = (carry.grad_sq_sum + _old_stacked_norm_sq(g_t)
               + _old_stacked_norm_sq(m_t))
        new = TrainState(params=z_new, sum_sq=carry.sum_sq + z_sq,
                         t=carry.t + 1, grad_sq_sum=gss)
        return new, jnp.mean(loss)

    def sync(state):
        inv_eta = 1.0 / eta_of_(state.sum_sq)
        w = inv_eta / jnp.sum(inv_eta)

        def avg(leaf):
            wb = _bcast(w, leaf)
            mean = jnp.sum(wb * leaf.astype(jnp.float32), axis=0,
                           keepdims=True)
            return jnp.broadcast_to(mean, leaf.shape).astype(leaf.dtype)

        return state._replace(params=jax.tree.map(avg, state.params))

    def round_fn(state, batches):
        state = sync(state)
        state, losses = jax.lax.scan(local_step, state, batches)
        return state, {"loss": losses, "eta": eta_of_(state.sum_sq)}

    return round_fn


def _tiny_plan():
    return TrainPlan(
        cfg=tiny_lm_config(), adaseg=_acfg(), worker_mode="paper",
        k_local=K, global_batch=BATCH * M, seq=SEQ, workers_override=M)


def test_round_fn_reproduces_pre_refactor_trajectory():
    """Acceptance bar: the refactored round loop (η/sync delegated to
    core.adaseg) is bit-exact with the vendored pre-refactor code over
    several rounds."""
    plan = _tiny_plan()
    mesh = make_test_mesh(1, 1)
    state_old = state_new = init_train_state(jax.random.PRNGKey(0), plan,
                                             mesh)
    old_fn = jax.jit(_old_round_fn(plan))
    new_fn = jax.jit(make_round_fn(plan))
    for r in range(3):
        batches = make_batches(jax.random.PRNGKey(100 + r), plan, mesh)
        state_old, m_old = old_fn(state_old, batches)
        state_new, m_new = new_fn(state_new, batches)
    _assert_trees_equal(state_old, state_new)
    _assert_trees_equal(m_old, m_new)


def test_eta_and_norm_dedup_numerically_identical():
    """Satellite: the deleted private implementations and the canonical
    core.adaseg/core.tree versions are the same function, bit for bit."""
    acfg = _acfg()
    sum_sq = jnp.abs(jax.random.normal(jax.random.PRNGKey(0), (7,))) * 40.0
    old_eta = acfg.diameter * acfg.alpha / jnp.sqrt(acfg.g0**2 + sum_sq)
    np.testing.assert_array_equal(np.asarray(old_eta),
                                  np.asarray(eta_of(acfg, sum_sq)))

    ks = jax.random.split(jax.random.PRNGKey(1), 3)
    tree = {"a": jax.random.normal(ks[0], (4, 3, 5)),
            "b": {"c": jax.random.normal(ks[1], (4, 7)),
                  "d": jax.random.normal(ks[2], (4, 2, 2, 2))}}
    np.testing.assert_array_equal(
        np.asarray(_old_stacked_norm_sq(tree)),
        np.asarray(jax.vmap(tree_norm_sq)(tree)))


def test_make_ps_engine_adapter(lm_problem):
    """A TrainPlan drives the PS engine directly — the examples' code
    path: same architecture, same M/K, telemetry populated."""
    eng = make_ps_engine(_tiny_plan(), jax.random.PRNGKey(0), rounds=R)
    z = eng.run()
    assert jax.tree.structure(z) == jax.tree.structure(
        lm_problem.init(jax.random.PRNGKey(0)))
    assert len(eng.trace.rounds) == R
    assert np.isfinite(eng.trace.rounds[-1].residual)
    assert eng.config.num_workers == M and eng.config.local_k == K
