"""Server-side outer optimizer (ps/server_opt): the DiLoCo/FedOpt
two-level-optimization layer over the PS runtime.

The PR's acceptance bars: ``server_opt="none"`` reproduces the PR-9 merge
**bit-exactly** on every engine path (serial, sharded, async τ>0, τ=0
lockstep, sampled, robust) because the resolved policy is ``None`` and the
historical closures compile unchanged; the fused Pallas outer step agrees
with the reference twin at rtol=1e-5 (and with a numpy oracle of the
moment recurrences); mid-stream checkpoints round-trip the outer moments
bit-exactly on both engines; restores under a different outer policy are
rejected (same-layout swaps via ``server_opt_fp``, different-layout swaps
via the structure check); and the outer step composes downstream of robust
aggregation, q8-EF codecs, client sampling, bounded staleness, and real
``ModelWorker`` payloads.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import AdaSEGConfig
from repro.core.adaseg import sync_weighted_stacked
from repro.kernels.sync_compress import ops as sync_ops
from repro.kernels.sync_compress import ref as sync_ref
from repro.problems import make_bilinear_game
from repro.ps import (
    AsyncPSConfig,
    AsyncPSEngine,
    ClientSampler,
    ConstantLatency,
    NoServerOpt,
    PSConfig,
    PSEngine,
    ServerAdam,
    ServerMomentum,
    ServerNesterov,
    SignFlipAttack,
    StochasticQuantizeCompressor,
    TraceRecorder,
    TrimmedMean,
    resolve_server_opt,
)

M, R, K, N = 4, 5, 3, 8

POLICIES = [
    ServerMomentum(lr=0.8, beta=0.9),
    ServerNesterov(lr=0.7, beta=0.85),
    ServerAdam(lr=0.5, beta1=0.9, beta2=0.95, eps=1e-8),
]


@pytest.fixture(scope="module")
def game():
    return make_bilinear_game(jax.random.PRNGKey(0), n=N, sigma=0.1)


def _cfg(k=K):
    return AdaSEGConfig(g0=1.0, diameter=2.0, alpha=1.0, k=k)


def _ps(game, **kw):
    kw.setdefault("adaseg", _cfg())
    kw.setdefault("num_workers", M)
    kw.setdefault("rounds", R)
    return PSConfig(**kw)


def _as_async(pscfg: PSConfig, **extra) -> AsyncPSConfig:
    base = {f.name: getattr(pscfg, f.name)
            for f in dataclasses.fields(PSConfig)}
    return AsyncPSConfig(**base, **extra)


def _assert_trees(a, b, exact=True, rtol=1e-5, atol=1e-6):
    la, lb = jax.tree.leaves(a), jax.tree.leaves(b)
    assert len(la) == len(lb)
    for x, y in zip(la, lb):
        if exact:
            np.testing.assert_array_equal(np.asarray(x), np.asarray(y))
        else:
            np.testing.assert_allclose(np.asarray(x), np.asarray(y),
                                       rtol=rtol, atol=atol)


STRAGGLER = ConstantLatency(step_s=(1.0, 1.0, 1.0, 3.0), up_s=0.1,
                            down_s=0.1)


# ---------------------------------------------------------------------------
# Policy layer: specs, slots, fingerprints, validation
# ---------------------------------------------------------------------------

def test_specs_and_slots():
    assert NoServerOpt().spec is None and NoServerOpt().slots == 0
    assert ServerMomentum(lr=0.5, beta=0.8).spec == ("momentum", 0.5, 0.8)
    assert ServerNesterov().spec == ("nesterov", 1.0, 0.9)
    assert ServerAdam().spec == ("adam", 1.0, 0.9, 0.99, 1e-8)
    assert ServerMomentum().slots == ServerNesterov().slots == 1
    assert ServerAdam().slots == 2


def test_fingerprints_separate_policies_and_hypers():
    fps = {p.fingerprint for p in POLICIES}
    fps.add(NoServerOpt().fingerprint)
    fps.add(ServerMomentum(lr=0.8, beta=0.5).fingerprint)
    assert len(fps) == 5          # every policy/hyper combination distinct


def test_validation_rejects_bad_hypers():
    with pytest.raises(ValueError):
        ServerMomentum(lr=0.0)
    with pytest.raises(ValueError):
        ServerNesterov(beta=1.0)
    with pytest.raises(ValueError):
        ServerAdam(beta2=-0.1)
    with pytest.raises(ValueError):
        ServerAdam(eps=0.0)


def test_resolve_none_and_noserveropt(game):
    assert resolve_server_opt(_ps(game)) is None
    assert resolve_server_opt(_ps(game, server_opt=NoServerOpt())) is None
    resolved = resolve_server_opt(
        _ps(game, server_opt=ServerNesterov())
    )
    assert resolved is not None and resolved.spec[0] == "nesterov"


# ---------------------------------------------------------------------------
# Kernel layer: fused ≡ reference ≡ numpy oracle
# ---------------------------------------------------------------------------

def _rand_srv(slots, n=37, seed=0):
    key = jax.random.PRNGKey(seed)
    ks = jax.random.split(key, 2 + slots)
    merged = {"a": jax.random.normal(ks[0], (1, n)),
              "b": jax.random.normal(ks[1], (1, n // 2))}
    z = jax.tree.map(lambda v: v * 0.5, merged)
    mom = tuple(
        jax.tree.map(lambda v, kk=kk: jax.random.normal(kk, v.shape) * 0.1,
                     z)
        for kk in ks[2:]
    )
    return merged, z, mom


@pytest.mark.parametrize("policy", POLICIES,
                         ids=[p.spec[0] for p in POLICIES])
def test_fused_matches_reference_three_chained_steps(policy):
    merged, z, mom = _rand_srv(policy.slots)
    t = jnp.int32(0)
    z_r, mom_r, t_r = z, mom, t
    z_k, mom_k, t_k = z, mom, t
    for step in range(3):
        m2 = jax.tree.map(lambda v: v * (1.0 + 0.3 * step), merged)
        z_r, mom_r, t_r, lr_r, dn_r = sync_ops.server_outer_apply(
            m2, z_r, mom_r, t_r, spec=policy.spec, use_kernel=False)
        z_k, mom_k, t_k, lr_k, dn_k = sync_ops.server_outer_apply(
            m2, z_k, mom_k, t_k, spec=policy.spec, use_kernel=True,
            block=16)
        _assert_trees(z_r, z_k, exact=False)
        _assert_trees(mom_r, mom_k, exact=False)
        assert int(t_r) == int(t_k) == step + 1
        np.testing.assert_allclose(float(lr_r), float(lr_k), rtol=1e-6)
        np.testing.assert_allclose(float(dn_r), float(dn_k), rtol=1e-5)


@pytest.mark.parametrize("use_kernel", [False, True],
                         ids=["reference", "fused"])
def test_outer_math_matches_numpy_oracle(use_kernel):
    """Both backends against a from-scratch numpy recurrence, 3 steps."""
    rng = np.random.default_rng(7)
    g = rng.standard_normal((1, 23)).astype(np.float32)
    z0 = rng.standard_normal((1, 23)).astype(np.float32)
    lr, b1, b2, eps = 0.5, 0.9, 0.95, 1e-8
    spec = ("adam", lr, b1, b2, eps)
    z, mom, t = jnp.asarray(z0), (jnp.zeros_like(jnp.asarray(z0)),) * 2, \
        jnp.int32(0)
    zn, mn, vn = z0.copy(), np.zeros_like(z0), np.zeros_like(z0)
    for step in range(1, 4):
        z, mom, t, eff_lr, dn = sync_ops.server_outer_apply(
            jnp.asarray(g), z, mom, t, spec=spec, use_kernel=use_kernel,
            block=16)
        d = g - zn
        mn = b1 * mn + (1 - b1) * d
        vn = b2 * vn + (1 - b2) * d * d
        mh, vh = mn / (1 - b1 ** step), vn / (1 - b2 ** step)
        zn = zn + lr * mh / (np.sqrt(vh) + eps)
        np.testing.assert_allclose(np.asarray(z), zn, rtol=1e-5, atol=1e-6)
        np.testing.assert_allclose(float(dn), np.sqrt((d * d).sum()),
                                   rtol=1e-5)
        want_lr = lr * np.sqrt(1 - b2 ** step) / (1 - b1 ** step)
        np.testing.assert_allclose(float(eff_lr), want_lr, rtol=1e-6)


def test_nesterov_first_step_closed_form():
    """Off a zero moment, one Nesterov step is z + lr·(1+β)·Δ."""
    policy = ServerNesterov(lr=0.5, beta=0.9)
    merged, z, mom = _rand_srv(1, seed=3)
    mom = tuple(jax.tree.map(jnp.zeros_like, m) for m in mom)
    z_new, _, _, eff_lr, _ = sync_ops.server_outer_apply(
        merged, z, mom, jnp.int32(0), spec=policy.spec, use_kernel=False)
    want = jax.tree.map(
        lambda zz, gg: zz + 0.5 * 1.9 * (gg - zz), z, merged)
    _assert_trees(z_new, want, exact=False)
    assert float(eff_lr) == pytest.approx(0.5)


def test_zero_delta_is_fixed_point_from_rest():
    """Δ=0 off zero moments: every policy leaves z (and telemetry) at rest."""
    for policy in POLICIES:
        merged, z, mom = _rand_srv(policy.slots, seed=5)
        mom = tuple(jax.tree.map(jnp.zeros_like, m) for m in mom)
        z_new, mom_new, _, _, dn = sync_ops.server_outer_apply(
            z, z, mom, jnp.int32(0), spec=policy.spec, use_kernel=False)
        _assert_trees(z_new, z, exact=False, atol=1e-7)
        assert float(dn) == 0.0


def test_outer_apply_ref_rejects_unknown_spec():
    z = jnp.zeros((1, 4))
    with pytest.raises(ValueError):
        sync_ref.outer_apply_ref(z, z, (), jnp.float32(0.0),
                                 spec=("rmsprop", 1.0))


# ---------------------------------------------------------------------------
# `none` bit-exactness: every engine path compiles the PR-9 merge unchanged
# ---------------------------------------------------------------------------

def test_none_bit_exact_serial(game):
    e0 = PSEngine(game.problem, _ps(game), rng=jax.random.PRNGKey(1),
                  eval_fn=game.residual)
    e1 = PSEngine(game.problem, _ps(game, server_opt=NoServerOpt()),
                  rng=jax.random.PRNGKey(1), eval_fn=game.residual)
    _assert_trees(e0.run(), e1.run())
    assert "server_opt" not in e1.trace.meta
    assert all(r.outer_lr is None for r in e1.trace.rounds)


def test_none_bit_exact_sharded(game):
    from repro.launch.mesh import make_test_mesh

    mesh = make_test_mesh(1, 1)
    mk = lambda so: PSEngine(
        game.problem, _ps(game, server_opt=so, num_workers=1),
        rng=jax.random.PRNGKey(1), mesh=mesh, worker_axes=("data",))
    _assert_trees(mk(None).run(), mk(NoServerOpt()).run())


def test_none_bit_exact_async_straggler(game):
    mk = lambda so: AsyncPSEngine(
        game.problem,
        _as_async(_ps(game, server_opt=so), latency=STRAGGLER,
                  staleness_bound=1.0),
        rng=jax.random.PRNGKey(2), eval_fn=game.residual)
    _assert_trees(mk(None).run(), mk(NoServerOpt()).run())


def test_none_bit_exact_lockstep(game):
    sync = PSEngine(game.problem, _ps(game, server_opt=NoServerOpt()),
                    rng=jax.random.PRNGKey(1))
    a = AsyncPSEngine(
        game.problem,
        _as_async(_ps(game, server_opt=NoServerOpt()),
                  latency=ConstantLatency(), staleness_bound=0.0),
        rng=jax.random.PRNGKey(1))
    _assert_trees(sync.run(), a.run())


def test_none_bit_exact_sampled_and_robust(game):
    for extra in ({"sampler": ClientSampler(sample=3, seed=1),
                   "num_workers": 6},
                  {"aggregator": TrimmedMean(beta=0.25)}):
        mk = lambda so: PSEngine(game.problem, _ps(game, server_opt=so,
                                                   **extra),
                                 rng=jax.random.PRNGKey(3))
        _assert_trees(mk(None).run(), mk(NoServerOpt()).run())


# ---------------------------------------------------------------------------
# Active policies through the engines
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("policy", POLICIES,
                         ids=[p.spec[0] for p in POLICIES])
def test_sync_engine_trains_with_telemetry(game, policy):
    eng = PSEngine(game.problem, _ps(game, server_opt=policy),
                   rng=jax.random.PRNGKey(1), eval_fn=game.residual)
    eng.run()
    assert eng.trace.meta["server_opt"] == policy.name
    for rec in eng.trace.rounds:
        assert rec.outer_lr is not None and rec.delta_norm is not None
        assert np.isfinite(rec.delta_norm) and rec.delta_norm >= 0.0
    assert np.isfinite(eng.trace.rounds[-1].residual)
    # adam's bias-corrected effective lr moves round to round
    if policy.spec[0] == "adam":
        lrs = [r.outer_lr for r in eng.trace.rounds]
        assert len(set(np.round(lrs, 8))) > 1


@pytest.mark.parametrize("policy", POLICIES,
                         ids=[p.spec[0] for p in POLICIES])
def test_lockstep_shares_compiled_chunk_bit_exact(game, policy):
    """τ=0 async with an ACTIVE outer optimizer still runs PSEngine's own
    compiled chunk — bit-exact by shared code, not by accident."""
    sync = PSEngine(game.problem, _ps(game, server_opt=policy),
                    rng=jax.random.PRNGKey(1))
    a = AsyncPSEngine(
        game.problem,
        _as_async(_ps(game, server_opt=policy),
                  latency=ConstantLatency(), staleness_bound=0.0),
        rng=jax.random.PRNGKey(1))
    _assert_trees(sync.run(), a.run())
    recs = [r for r in a.trace.rounds if r.outer_lr is not None]
    assert len(recs) == R


def test_async_straggler_applies_at_admission(game):
    eng = AsyncPSEngine(
        game.problem,
        _as_async(_ps(game, server_opt=ServerMomentum(lr=0.8, beta=0.9)),
                  latency=STRAGGLER, staleness_bound=1.0),
        rng=jax.random.PRNGKey(2), eval_fn=game.residual)
    eng.run()
    outs = [r for r in eng.trace.rounds if r.outer_lr is not None]
    # partial batches step the outer optimizer more often than R rounds
    assert len(outs) == eng.n_admissions > R
    assert eng.trace.meta["server_opt"].startswith("momentum")
    assert np.isfinite(eng.trace.rounds[-1].residual)


def test_composes_with_robust_q8ef_and_byzantine(game):
    cfg = _ps(game, num_workers=6,
              server_opt=ServerNesterov(lr=0.9, beta=0.8),
              aggregator=TrimmedMean(beta=0.2),
              byzantine=SignFlipAttack(fraction=0.2, seed=3),
              compressor=StochasticQuantizeCompressor(bits=8))
    eng = PSEngine(game.problem, cfg, rng=jax.random.PRNGKey(4),
                   eval_fn=game.residual)
    eng.run()
    assert np.isfinite(eng.trace.rounds[-1].residual)
    assert eng.trace.rounds[-1].outer_lr is not None
    # and the same hostile pipeline through the event-driven engine
    lat6 = ConstantLatency(step_s=(1.0,) * 5 + (3.0,), up_s=0.1,
                           down_s=0.1)
    a = AsyncPSEngine(
        game.problem,
        _as_async(cfg, latency=lat6, staleness_bound=2.0),
        rng=jax.random.PRNGKey(4), eval_fn=game.residual)
    a.run()
    assert np.isfinite(a.trace.rounds[-1].residual)


def test_composes_with_client_sampling(game):
    eng = PSEngine(
        game.problem,
        _ps(game, num_workers=6, sampler=ClientSampler(sample=3, seed=1),
            server_opt=ServerAdam(lr=0.3)),
        rng=jax.random.PRNGKey(5), eval_fn=game.residual)
    eng.run()
    # ONE global outer clock: t advances once per round, not per lane
    assert int(eng._srv[2]) == R
    assert np.isfinite(eng.trace.rounds[-1].residual)


def test_mesh_with_active_server_raises(game):
    from repro.launch.mesh import make_test_mesh

    mesh = make_test_mesh(1, 1)
    with pytest.raises(NotImplementedError, match="serial path only"):
        PSEngine(game.problem,
                 _ps(game, server_opt=ServerNesterov(), num_workers=1),
                 rng=jax.random.PRNGKey(1), mesh=mesh,
                 worker_axes=("data",))


def test_sync_weighted_stacked_composition(game):
    """core.adaseg's Line-7 helper grows the optional outer hook: the
    post-step anchor is broadcast, the srv carry advances, and the
    no-server call is untouched."""
    key = jax.random.PRNGKey(0)
    z_tilde = {"p": jax.random.normal(key, (M, 11))}
    inv_eta = jnp.abs(jax.random.normal(jax.random.fold_in(key, 1),
                                        (M,))) + 0.1
    plain = sync_weighted_stacked(z_tilde, inv_eta)
    server = resolve_server_opt(_ps(game, server_opt=ServerNesterov(lr=0.5)))
    z0 = jax.tree.map(lambda v: jnp.mean(v, axis=0, keepdims=True), z_tilde)
    srv = (z0, server.init_moments(z0), jnp.int32(0))
    synced, srv_new, telem = sync_weighted_stacked(
        z_tilde, inv_eta, server=server, srv=srv)
    mean_row = jax.tree.map(lambda v: v[:1], plain)
    want = jax.tree.map(
        lambda zz, gg: zz + 0.5 * 1.9 * (gg - zz), z0, mean_row)
    _assert_trees(jax.tree.map(lambda v: v[:1], synced), want, exact=False)
    assert int(srv_new[2]) == 1 and float(telem[0]) == pytest.approx(0.5)


# ---------------------------------------------------------------------------
# Checkpoints: moments round-trip, wrong policies rejected, `none` layout
# ---------------------------------------------------------------------------

def test_serial_resume_round_trips_moments_bit_exact(game, tmp_path):
    cfg = _ps(game, server_opt=ServerAdam(lr=0.5, beta2=0.95))
    mk = lambda: PSEngine(game.problem, cfg, rng=jax.random.PRNGKey(1),
                          eval_fn=game.residual)
    full = mk()
    z_full = full.run()
    path = str(tmp_path / "srv.msgpack")
    e1 = mk()
    e1.run(until_round=3)
    e1.save(path)
    e2 = mk()
    e2.restore(path)
    _assert_trees(e2._srv, e1._srv)      # moments restored bit-exactly
    z_res = e2.run()
    _assert_trees(z_full, z_res)


def test_async_resume_round_trips_moments_bit_exact(game, tmp_path):
    acfg = _as_async(_ps(game, server_opt=ServerMomentum(lr=0.8)),
                     latency=STRAGGLER, staleness_bound=1.0)
    mk = lambda: AsyncPSEngine(game.problem, acfg,
                               rng=jax.random.PRNGKey(2))
    full = mk()
    z_full = full.run()
    path = str(tmp_path / "asrv.msgpack")
    e1 = mk()
    e1.run(until_admissions=3)
    e1.save(path)
    e2 = mk().restore(path)
    _assert_trees(e2._srv, e1._srv)
    _assert_trees(z_full, e2.run())


@pytest.mark.parametrize("engine", ["sync", "async"])
def test_wrong_server_opt_fp_rejected(game, tmp_path, engine):
    """Same moment layout (momentum vs nesterov): only the fingerprint can
    tell them apart — the restore must refuse."""
    path = str(tmp_path / "fp.msgpack")

    def mk(so):
        if engine == "sync":
            return PSEngine(game.problem, _ps(game, server_opt=so),
                            rng=jax.random.PRNGKey(1))
        return AsyncPSEngine(
            game.problem,
            _as_async(_ps(game, server_opt=so), latency=STRAGGLER,
                      staleness_bound=1.0),
            rng=jax.random.PRNGKey(1))

    writer = mk(ServerMomentum(lr=0.8, beta=0.9))
    writer.save(path)
    with pytest.raises(ValueError, match="outer optimizer"):
        mk(ServerNesterov(lr=0.8, beta=0.9)).restore(path)


def test_different_slot_count_rejected(game, tmp_path):
    """adam (2 slots) into momentum (1 slot): the layout check fires even
    before the fingerprint could."""
    path = str(tmp_path / "slots.msgpack")
    PSEngine(game.problem, _ps(game, server_opt=ServerAdam()),
             rng=jax.random.PRNGKey(1)).save(path)
    with pytest.raises(ValueError):
        PSEngine(game.problem, _ps(game, server_opt=ServerMomentum()),
                 rng=jax.random.PRNGKey(1)).restore(path)


def test_none_checkpoint_layout_byte_identical(game, tmp_path):
    """`none` keeps the historical checkpoint layout byte-for-byte: a file
    written under NoServerOpt is indistinguishable from one written with
    no server_opt at all."""
    p0 = str(tmp_path / "legacy.msgpack")
    p1 = str(tmp_path / "none.msgpack")
    e0 = PSEngine(game.problem, _ps(game), rng=jax.random.PRNGKey(1))
    e0.run(until_round=2)
    e0.save(p0)
    e1 = PSEngine(game.problem, _ps(game, server_opt=NoServerOpt()),
                  rng=jax.random.PRNGKey(1))
    e1.run(until_round=2)
    e1.save(p1)
    with open(p0, "rb") as f0, open(p1, "rb") as f1:
        assert f0.read() == f1.read()


# ---------------------------------------------------------------------------
# Trace v8
# ---------------------------------------------------------------------------

def test_trace_v8_round_trips_outer_telemetry(game, tmp_path):
    eng = PSEngine(game.problem,
                   _ps(game, server_opt=ServerNesterov(lr=0.7)),
                   rng=jax.random.PRNGKey(1), eval_fn=game.residual)
    eng.run()
    path = str(tmp_path / "t.json")
    eng.trace.save(path)
    back = TraceRecorder.load(path)
    assert back.version == 8
    assert back.meta["server_opt"] == eng.trace.meta["server_opt"]
    for a, b in zip(eng.trace.rounds, back.rounds):
        assert b.outer_lr == a.outer_lr
        assert b.delta_norm == a.delta_norm


def test_v7_trace_loads_with_defaulted_outer_fields(tmp_path):
    import json

    payload = {
        "version": 7,
        "meta": {"problem": "legacy"},
        "rounds": [{
            "round": 0, "local_steps": [2, 2], "alive": [True, True],
            "bytes_up": 8.0, "bytes_down": 8.0,
            "eta_min": 1.0, "eta_max": 1.0, "eta_mean": 1.0,
        }],
    }
    path = str(tmp_path / "v7.json")
    with open(path, "w") as f:
        json.dump(payload, f)
    back = TraceRecorder.load(path)
    assert back.version == 7
    assert back.rounds[0].outer_lr is None
    assert back.rounds[0].delta_norm is None


# ---------------------------------------------------------------------------
# ModelWorker: a real transformer under outer Nesterov
# ---------------------------------------------------------------------------

def test_model_worker_trains_under_outer_nesterov():
    from repro.models import ModelWorker, loss_fn, make_lm_problem, \
        tiny_lm_config
    from repro.ps import ModelWorker as _  # noqa: F401 (export pin)

    problem = make_lm_problem(tiny_lm_config(), batch=2, seq=8)
    acfg = AdaSEGConfig(g0=20.0, diameter=2.0, alpha=1.0, k=2,
                        average_output=False)
    eng = PSEngine(
        problem,
        PSConfig(worker=ModelWorker(acfg, arch="tiny-lm"), local_k=2,
                 num_workers=2, rounds=2,
                 server_opt=ServerNesterov(lr=0.5, beta=0.9)),
        rng=jax.random.PRNGKey(1),
        eval_fn=lambda z: loss_fn(z, tiny_lm_config(),
                                  problem.sample(jax.random.PRNGKey(9))),
    )
    z = eng.run()
    assert jax.tree.structure(z) == jax.tree.structure(
        problem.init(jax.random.PRNGKey(0)))
    rec = eng.trace.rounds[-1]
    assert np.isfinite(rec.residual)
    assert rec.outer_lr == pytest.approx(0.5)
    assert rec.delta_norm is not None and rec.delta_norm > 0.0
