"""Observability layer: span/metric JSONL round-trips, Perfetto export
validity on both clocks, the span→trace bridge, and — the load-bearing
pins — proof that tracing is *inert*: every parity-sensitive path produces
bit-identical numerics with tracing enabled and disabled."""
import dataclasses
import json
import math

import jax
import numpy as np
import pytest

from repro.obs import (
    MetricsRegistry,
    Span,
    SpanTracer,
    modeled_sync_cost,
    save_trace_events,
    to_trace_events,
    validate_trace_events,
)
from repro.problems import make_bilinear_game
from repro.ps import (
    AsyncPSConfig,
    AsyncPSEngine,
    ConstantLatency,
    PSConfig,
    PSEngine,
    StochasticQuantizeCompressor,
    TraceRecorder,
)
from repro.core import AdaSEGConfig
from repro.ps.trace import TRACE_VERSION

M, R, K = 4, 5, 4
N = 10


@pytest.fixture(scope="module")
def game():
    return make_bilinear_game(jax.random.PRNGKey(0), n=N, sigma=0.1)


def _cfg(k=K):
    return AdaSEGConfig(g0=1.0, diameter=2.0, alpha=1.0, k=k)


def _sync_engine(game, **kw):
    cfg = PSConfig(adaseg=_cfg(), num_workers=M, rounds=R,
                   **{k: v for k, v in kw.items()
                      if k in ("compressor", "codec_backend")})
    eng_kw = {k: v for k, v in kw.items() if k in ("tracer", "metrics")}
    return PSEngine(game.problem, cfg, rng=jax.random.PRNGKey(4),
                    eval_fn=game.residual, **eng_kw)


def _async_engine(game, *, tau=0.0, latency=None, **eng_kw):
    cfg = AsyncPSConfig(
        adaseg=_cfg(), num_workers=M, rounds=R,
        latency=latency or ConstantLatency(step_s=1.0),
        staleness_bound=tau,
    )
    return AsyncPSEngine(game.problem, cfg, rng=jax.random.PRNGKey(4),
                         eval_fn=game.residual, **eng_kw)


def _assert_trees_equal(a, b):
    for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(b)):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


# ---------------------------------------------------------------------------
# Span / metric primitives
# ---------------------------------------------------------------------------

def test_span_jsonl_roundtrip(tmp_path):
    tr = SpanTracer()
    with tr.span("run", cat="run", engine="sync"):
        with tr.span("round 0", cat="round", steps=7):
            pass
    tr.add_span("uplink r0", cat="uplink", track="worker/2",
                sim_t0=0.5, sim_t1=0.7, bytes=128.0)
    path = tmp_path / "spans.jsonl"
    tr.save_jsonl(str(path))
    back = SpanTracer.load_jsonl(str(path))
    assert [s.to_dict() for s in back.spans] == [
        s.to_dict() for s in tr.spans]
    # hierarchy survives: "round 0" closed inside "run"
    by_name = {s.name: s for s in back.spans}
    assert by_name["round 0"].parent == by_name["run"].id
    assert by_name["uplink r0"].sim_dur == pytest.approx(0.2)


def test_span_unknown_keys_dropped():
    sp = Span.from_dict({"name": "x", "cat": "round", "track": "server",
                         "id": 3, "frobnicate": 1})
    assert sp.name == "x" and not hasattr(sp, "frobnicate")


def test_disabled_tracer_times_but_records_nothing():
    tr = SpanTracer(enabled=False)
    with tr.span("chunk", cat="chunk") as sp:
        pass
    assert sp.wall_dur is not None and sp.wall_dur >= 0.0
    assert tr.spans == [] and tr.add_span("x", cat="round").id == -1
    assert tr.spans == []


def test_metrics_jsonl_roundtrip(tmp_path):
    reg = MetricsRegistry()
    reg.inc("bytes_up", 80.0, engine="sync")
    reg.inc("bytes_up", 40.0, engine="sync")
    reg.set_gauge("eta_spread", 1.25)
    reg.observe("round_wall_s", 0.01, t_sim=3.0, modeled_hbm_passes=11)
    path = tmp_path / "metrics.jsonl"
    reg.save_jsonl(str(path))
    back = MetricsRegistry.load_jsonl(str(path))
    assert back.records == reg.records
    assert back.total("bytes_up") == 120.0
    assert back.last("eta_spread") == 1.25
    assert back.histogram("round_wall_s")["count"] == 1
    assert back.names() == ["bytes_up", "eta_spread", "round_wall_s"]


def test_disabled_metrics_record_nothing():
    reg = MetricsRegistry(enabled=False)
    reg.inc("bytes_up", 80.0)
    assert reg.records == [] and reg.total("bytes_up") == 0.0


def test_modeled_sync_cost_matches_traffic_model():
    c = modeled_sync_cost(("quantize", 8), 4096.0, workers=4)
    assert c["hbm_passes"] == 11
    f = modeled_sync_cost(("quantize", 8), 4096.0, workers=4,
                          backend="fused")
    assert f["hbm_passes"] == 6 and f["hbm_s"] < c["hbm_s"]
    assert math.isnan(modeled_sync_cost(None, 1.0, workers=1)["hbm_s"])


# ---------------------------------------------------------------------------
# Perfetto export — both clocks
# ---------------------------------------------------------------------------

def test_perfetto_export_sync_wall(game, tmp_path):
    engine = _sync_engine(game)
    engine.run(checkpoint_every=2)
    path = tmp_path / "sync.json"
    payload = save_trace_events(str(path), engine.tracer, clock="wall")
    validate_trace_events(payload)              # nesting + non-negative durs
    assert json.loads(path.read_text()) == payload
    names = {e["name"] for e in payload["traceEvents"] if e["ph"] == "X"}
    assert {f"round {r}" for r in range(R)} <= names
    assert any(n.startswith("chunk") for n in names)
    assert any(n.startswith("run") for n in names)
    # round spans nest inside their chunk span by construction
    rounds = engine.tracer.by_cat("round")
    chunks = {s.id: s for s in engine.tracer.by_cat("chunk")}
    for sp in rounds:
        ch = chunks[sp.parent]
        assert ch.wall_t0 <= sp.wall_t0 and sp.wall_t1 <= ch.wall_t1 + 1e-9


def test_perfetto_export_async_sim(game, tmp_path):
    engine = _async_engine(
        game, tau=2.0,
        latency=ConstantLatency(step_s=(1.0, 1.0, 1.0, 6.0),
                                up_s=0.2, down_s=0.1),
    )
    engine.run()
    payload = to_trace_events(engine.tracer.spans, clock="sim")
    validate_trace_events(payload)
    tracks = {e["args"]["name"] for e in payload["traceEvents"]
              if e["ph"] == "M" and e["name"] == "thread_name"}
    assert {f"worker/{m}" for m in range(M)} <= tracks  # per-worker lanes
    cats = {s.cat for s in engine.tracer.spans}
    assert {"uplink", "broadcast", "local-compute", "admission"} <= cats
    assert engine.tracer.by_cat("held")         # τ=2 + a 6× straggler holds
    # the sim story is consistent: every span's sim interval is ordered
    for sp in engine.tracer.spans:
        if sp.sim_dur is not None:
            assert sp.sim_dur >= 0.0
    # wall clock of the same tracer also exports cleanly
    validate_trace_events(to_trace_events(engine.tracer.spans, clock="wall"))


def test_export_rejects_bad_payloads():
    with pytest.raises(ValueError, match="traceEvents"):
        validate_trace_events({})
    bad = {"traceEvents": [{"ph": "X", "pid": 1, "tid": 0, "name": "a",
                            "ts": 0.0, "dur": -5.0}]}
    with pytest.raises(ValueError, match="negative"):
        validate_trace_events(bad)
    overlap = {"traceEvents": [
        {"ph": "X", "pid": 1, "tid": 0, "name": "a", "ts": 0.0, "dur": 10.0},
        {"ph": "X", "pid": 1, "tid": 0, "name": "b", "ts": 5.0, "dur": 10.0},
    ]}
    with pytest.raises(ValueError, match="partially overlaps"):
        validate_trace_events(overlap)


# ---------------------------------------------------------------------------
# Inertness: tracing-enabled ≡ tracing-disabled, bit for bit
# ---------------------------------------------------------------------------

def _off():
    return dict(tracer=SpanTracer(enabled=False),
                metrics=MetricsRegistry(enabled=False))


def test_sync_engine_tracing_inert(game):
    z_on = _sync_engine(game).run()
    z_off = _sync_engine(game, **_off()).run()
    _assert_trees_equal(z_on, z_off)


def test_sync_fused_codec_tracing_inert(game):
    kw = dict(compressor=StochasticQuantizeCompressor(bits=8),
              codec_backend="fused")
    e_on = _sync_engine(game, **kw)
    e_off = _sync_engine(game, **kw, **_off())
    _assert_trees_equal(e_on.run(), e_off.run())
    _assert_trees_equal(e_on.state, e_off.state)


def test_async_tau0_tracing_inert(game):
    e_on = _async_engine(game, tau=0.0)
    e_off = _async_engine(game, tau=0.0, **_off())
    _assert_trees_equal(e_on.run(), e_off.run())
    _assert_trees_equal(e_on.state, e_off.state)
    # the recorded telemetry itself is deterministic (wall timings live in
    # the span layer, not the trace), so it matches dict-for-dict too
    assert [dataclasses.asdict(r) for r in e_on.trace.rounds] == [
        dataclasses.asdict(r) for r in e_off.trace.rounds]
    assert e_on.tracer.spans and not e_off.tracer.spans


# ---------------------------------------------------------------------------
# Span→trace bridge and trace versioning
# ---------------------------------------------------------------------------

def test_from_spans_rebuilds_sync_trace(game):
    engine = _sync_engine(game)
    engine.run()
    bridged = TraceRecorder.from_spans(engine.tracer)
    assert [dataclasses.asdict(r) for r in bridged.rounds] == [
        dataclasses.asdict(r) for r in engine.trace.rounds]


def test_from_spans_derives_async_wall_from_spans(game):
    engine = _async_engine(game, tau=0.0)
    engine.run()
    bridged = TraceRecorder.from_spans(engine.tracer)
    assert len(bridged.rounds) == len(engine.trace.rounds)
    for b, r in zip(bridged.rounds, engine.trace.rounds):
        assert r.wall_time_s is None            # engine trace: deterministic
        db, dr = dataclasses.asdict(b), dataclasses.asdict(r)
        if b.round < R:                          # admission spans are timed
            assert db.pop("wall_time_s") > 0.0   # bridge: from the span
            db.pop("steps_per_sec"), dr.pop("wall_time_s"), \
                dr.pop("steps_per_sec")
        assert db == dr


def test_trace_version_roundtrip_and_legacy_load(game, tmp_path):
    engine = _sync_engine(game)
    engine.run(until_round=2)
    path = tmp_path / "trace.json"
    engine.trace.save(str(path))
    payload = json.loads(path.read_text())
    assert payload["version"] == TRACE_VERSION
    back = TraceRecorder.load(str(path))
    assert back.version == TRACE_VERSION and len(back.rounds) == 2
    # a versionless (pre-observability) trace still loads, as version 1
    del payload["version"]
    path.write_text(json.dumps(payload))
    legacy = TraceRecorder.load(str(path))
    assert legacy.version == 1
    assert [dataclasses.asdict(r) for r in legacy.rounds] == [
        dataclasses.asdict(r) for r in back.rounds]


def test_sync_metrics_carry_modeled_cost(game):
    engine = _sync_engine(game,
                          compressor=StochasticQuantizeCompressor(bits=8))
    engine.run()
    assert engine.metrics.total("bytes_up") == engine.trace.total_bytes_up
    hist = engine.metrics.histogram("round_wall_s")
    assert hist["count"] == R and hist["min"] > 0.0
    rec = [r for r in engine.metrics.records
           if r["name"] == "round_wall_s"][0]
    assert rec["labels"]["modeled_hbm_passes"] == 11    # q8 reference codec
    assert rec["labels"]["modeled_hbm_s"] > 0.0
