"""Hypothesis property tests for the projection operators (Π_Z invariants)."""
import jax.numpy as jnp
import numpy as np
import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings
from hypothesis import strategies as st
from hypothesis.extra import numpy as hnp

from repro.core import projections

_vec = hnp.arrays(
    np.float32,
    st.integers(2, 16),
    elements=st.floats(-10, 10, width=32, allow_nan=False),
)


@given(_vec)
@settings(max_examples=50, deadline=None)
def test_box_idempotent(v):
    proj = projections.box(-1.0, 1.0)
    once = proj(jnp.asarray(v))
    twice = proj(once)
    np.testing.assert_allclose(once, twice)
    assert jnp.all(jnp.abs(once) <= 1.0)


@given(_vec, _vec)
@settings(max_examples=50, deadline=None)
def test_box_nonexpansive(u, v):
    n = min(len(u), len(v))
    u, v = jnp.asarray(u[:n]), jnp.asarray(v[:n])
    proj = projections.box(-1.0, 1.0)
    d_before = float(jnp.linalg.norm(u - v))
    d_after = float(jnp.linalg.norm(proj(u) - proj(v)))
    assert d_after <= d_before + 1e-5


@given(_vec)
@settings(max_examples=50, deadline=None)
def test_l2_ball_radius(v):
    proj = projections.l2_ball(2.5)
    out = proj(jnp.asarray(v))
    assert float(jnp.linalg.norm(out)) <= 2.5 + 1e-4


@given(_vec)
@settings(max_examples=50, deadline=None)
def test_l2_ball_identity_inside(v):
    v = jnp.asarray(v)
    r = float(jnp.linalg.norm(v)) + 1.0
    out = projections.l2_ball(r)(v)
    np.testing.assert_allclose(out, v, rtol=1e-5, atol=1e-6)


@given(_vec)
@settings(max_examples=50, deadline=None)
def test_simplex_output_valid(v):
    out = projections.simplex()(jnp.asarray(v))
    assert jnp.all(out >= -1e-6)
    np.testing.assert_allclose(float(jnp.sum(out)), 1.0, rtol=1e-4)


@given(_vec)
@settings(max_examples=30, deadline=None)
def test_simplex_idempotent(v):
    proj = projections.simplex()
    once = proj(jnp.asarray(v))
    twice = proj(once)
    np.testing.assert_allclose(once, twice, rtol=1e-4, atol=1e-6)


def test_product_projection():
    proj = projections.product(
        projections.box(-1.0, 1.0), projections.simplex()
    )
    x = jnp.array([3.0, -2.0])
    y = jnp.array([0.5, 0.5, 3.0])
    px, py = proj((x, y))
    assert jnp.all(jnp.abs(px) <= 1.0)
    np.testing.assert_allclose(float(py.sum()), 1.0, rtol=1e-5)
