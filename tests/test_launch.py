"""Launch-layer unit tests: shapes, plans, worker placement, flops model."""
import jax.numpy as jnp
import pytest

from repro.configs import get_config, list_archs
from repro.launch.mesh import make_test_mesh, num_workers, worker_axes_for
from repro.launch.shapes import (
    INPUT_SHAPES,
    LONG_CONTEXT_ARCHS,
    applicable_shapes,
    default_worker_mode,
    plan_for,
)
from repro.roofline.flops import estimate


def test_input_shapes_exactly_as_assigned():
    assert INPUT_SHAPES["train_4k"].seq == 4096
    assert INPUT_SHAPES["train_4k"].batch == 256
    assert INPUT_SHAPES["prefill_32k"].seq == 32768
    assert INPUT_SHAPES["prefill_32k"].batch == 32
    assert INPUT_SHAPES["decode_32k"].seq == 32768
    assert INPUT_SHAPES["decode_32k"].batch == 128
    assert INPUT_SHAPES["long_500k"].seq == 524288
    assert INPUT_SHAPES["long_500k"].batch == 1


def test_long_context_skips_documented():
    """Exactly the sub-quadratic archs run long_500k (DESIGN.md)."""
    runs_long = {a for a in list_archs() if "long_500k" in applicable_shapes(a)}
    assert runs_long == LONG_CONTEXT_ARCHS
    for a in list_archs():
        assert len(applicable_shapes(a)) == (4 if a in runs_long else 3)


def _abstract_mesh(pods=None, data=2, model=2):
    """Device-free mesh stand-in: shape/axis logic works on 1-device CPU."""
    from repro.sharding.specs import abstract_mesh

    if pods:
        return abstract_mesh((pods, data, model), ("pod", "data", "model"))
    return abstract_mesh((data, model), ("data", "model"))


def test_worker_axes_modes():
    mesh = _abstract_mesh(pods=2)
    assert worker_axes_for(mesh, "paper") == ("pod", "data")
    assert worker_axes_for(mesh, "hierarchical") == ("pod",)
    assert num_workers(mesh, ("pod", "data")) == 4
    mesh1 = _abstract_mesh()
    assert worker_axes_for(mesh1, "paper") == ("data",)
    assert worker_axes_for(mesh1, "hierarchical") == ()
    assert num_workers(mesh1, ()) == 1


@pytest.mark.parametrize("arch", list_archs())
def test_plan_batch_divisibility(arch):
    mesh = _abstract_mesh(pods=2)
    for shape in applicable_shapes(arch):
        if INPUT_SHAPES[shape].kind != "train":
            continue
        plan = plan_for(arch, shape, mesh)
        assert plan.global_batch % plan.num_workers(mesh) == 0
        assert plan.cfg.param_dtype == "bfloat16"


def test_flops_estimator_known_magnitudes():
    """Sanity: params match published sizes within tolerance."""
    fb = estimate(get_config("qwen2-0.5b"), 4096)
    assert 0.4e9 < fb.params < 0.7e9           # "0.5B"
    fb = estimate(get_config("qwen3-8b"), 4096)
    assert 6e9 < fb.params < 10e9              # "8B"
    fb = estimate(get_config("mixtral-8x22b"), 4096)
    assert 120e9 < fb.params < 160e9           # "~141B total"
    assert 35e9 < fb.params_active < 50e9      # "~39B active"
    fb = estimate(get_config("mamba2-370m"), 4096)
    assert 0.25e9 < fb.params < 0.55e9
    fb = estimate(get_config("gemma2-27b"), 4096)
    assert 22e9 < fb.params < 32e9


def test_flops_decode_linear_in_context():
    cfg = get_config("qwen2-0.5b")
    f1 = estimate(cfg, 0, kv_len=8192, decode=True).forward
    f2 = estimate(cfg, 0, kv_len=16384, decode=True).forward
    assert f2 > f1
    # attention part doubles; projections constant → ratio in (1, 2)
    assert 1.0 < f2 / f1 < 2.0


def test_flops_window_caps_attention():
    cfg = get_config("mixtral-8x22b")  # SWA 4096 on all layers
    dense_like = estimate(cfg, 32768)
    # windowed attention: per-token context capped at 4096 — compare with a
    # hypothetical full-attention model of the same size
    import dataclasses

    full = dataclasses.replace(cfg, layer_pattern="global", sliding_window=None)
    f_full = estimate(full, 32768).forward
    assert dense_like.forward < f_full


def test_eg_step_is_2x_grad():
    fb = estimate(get_config("qwen2-0.5b"), 1024)
    assert fb.eg_local_step() == 2 * fb.train_step()
    assert fb.train_step(remat=True) == 4 * fb.forward
