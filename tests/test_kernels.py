"""Per-kernel correctness sweeps: Pallas (interpret mode) vs pure-jnp oracle
across shapes and dtypes."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.adaseg_update.kernel import adaseg_update
from repro.kernels.adaseg_update.ref import adaseg_update_ref
from repro.kernels.flash_attention.kernel import flash_attention
from repro.kernels.flash_attention.ref import attention_ref
from repro.kernels.ssd_scan.kernel import ssd_scan
from repro.kernels.ssd_scan.ref import ssd_ref


def _tol(dtype):
    return dict(rtol=2e-2, atol=2e-2) if dtype == jnp.bfloat16 else dict(
        rtol=2e-5, atol=2e-5
    )


@pytest.mark.parametrize("b,h,kh,s,d", [
    (1, 2, 2, 64, 32),      # MHA
    (2, 4, 2, 128, 64),     # GQA 2:1
    (1, 8, 1, 64, 64),      # MQA
    (1, 4, 4, 96, 32),      # non-power-of-two seq
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_shapes_dtypes(b, h, kh, s, d, dtype):
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(ks[0], (b, h, s, d), dtype)
    k = jax.random.normal(ks[1], (b, kh, s, d), dtype)
    v = jax.random.normal(ks[2], (b, kh, s, d), dtype)
    out = flash_attention(q, k, v, block_q=32, block_k=32, interpret=True)
    ref = attention_ref(q, k, v)
    np.testing.assert_allclose(
        out.astype(np.float32), ref.astype(np.float32), **_tol(dtype)
    )


@pytest.mark.parametrize("feature", ["window", "softcap", "noncausal", "scale"])
def test_flash_attention_features(feature):
    ks = jax.random.split(jax.random.PRNGKey(1), 3)
    q = jax.random.normal(ks[0], (2, 4, 128, 64))
    k = jax.random.normal(ks[1], (2, 2, 128, 64))
    v = jax.random.normal(ks[2], (2, 2, 128, 64))
    kwargs = {
        "window": dict(causal=True, window=32),
        "softcap": dict(causal=True, softcap=20.0),
        "noncausal": dict(causal=False),
        "scale": dict(causal=True, scale=0.05),
    }[feature]
    out = flash_attention(q, k, v, block_q=32, block_k=32, interpret=True,
                          **kwargs)
    ref = attention_ref(q, k, v, **kwargs)
    np.testing.assert_allclose(out, ref, rtol=2e-5, atol=2e-5)


def test_flash_attention_block_shape_invariance():
    """Output must not depend on the BlockSpec tiling."""
    ks = jax.random.split(jax.random.PRNGKey(2), 3)
    q = jax.random.normal(ks[0], (1, 2, 256, 64))
    k = jax.random.normal(ks[1], (1, 2, 256, 64))
    v = jax.random.normal(ks[2], (1, 2, 256, 64))
    outs = [
        flash_attention(q, k, v, block_q=bq, block_k=bk, interpret=True)
        for bq, bk in [(32, 32), (64, 128), (256, 64), (128, 256)]
    ]
    for o in outs[1:]:
        np.testing.assert_allclose(outs[0], o, rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("n", [64, 1000, 4096, 5000])
@pytest.mark.parametrize("box", [None, (-1.0, 1.0)])
def test_adaseg_update_kernel(n, box):
    ks = jax.random.split(jax.random.PRNGKey(3), 3)
    z = jax.random.normal(ks[0], (n,))
    m = jax.random.normal(ks[1], (n,))
    g = jax.random.normal(ks[2], (n,))
    lo, hi = box if box else (None, None)
    z_t, z_tl, part = adaseg_update(z, m, g, 0.3, lo=lo, hi=hi,
                                    block=1024, interpret=True)
    rz, rtl, rpart = adaseg_update_ref(z, m, g, 0.3, lo=lo, hi=hi)
    np.testing.assert_allclose(z_t, rz, rtol=1e-5, atol=1e-7)
    np.testing.assert_allclose(z_tl, rtl, rtol=1e-5, atol=1e-7)
    np.testing.assert_allclose(float(part), float(rpart), rtol=1e-4)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_adaseg_update_dtypes(dtype):
    ks = jax.random.split(jax.random.PRNGKey(4), 3)
    z = jax.random.normal(ks[0], (512,), dtype)
    m = jax.random.normal(ks[1], (512,), dtype)
    g = jax.random.normal(ks[2], (512,), dtype)
    z_t, z_tl, part = adaseg_update(z, m, g, 0.1, block=128, interpret=True)
    rz, rtl, rpart = adaseg_update_ref(z, m, g, jnp.asarray(0.1, dtype))
    np.testing.assert_allclose(
        z_t.astype(np.float32), rz.astype(np.float32), **_tol(dtype)
    )


@pytest.mark.parametrize("l,chunk", [(64, 8), (64, 16), (128, 64), (96, 32)])
@pytest.mark.parametrize("h,p,n", [(2, 16, 32), (4, 32, 16)])
def test_ssd_scan_kernel(l, chunk, h, p, n):
    ks = jax.random.split(jax.random.PRNGKey(5), 5)
    x = jax.random.normal(ks[0], (2, l, h, p))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (2, l, h)))
    a = -jnp.exp(jax.random.normal(ks[2], (h,)))
    b = jax.random.normal(ks[3], (2, l, n))
    c = jax.random.normal(ks[4], (2, l, n))
    out = ssd_scan(x, dt, a, b, c, chunk=chunk, interpret=True)
    ref = ssd_ref(x, dt, a, b, c)
    np.testing.assert_allclose(out, ref, rtol=2e-4, atol=2e-4)


def test_ssd_chunk_invariance():
    ks = jax.random.split(jax.random.PRNGKey(6), 5)
    x = jax.random.normal(ks[0], (1, 128, 2, 16))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (1, 128, 2)))
    a = -jnp.exp(jax.random.normal(ks[2], (2,)))
    b = jax.random.normal(ks[3], (1, 128, 8))
    c = jax.random.normal(ks[4], (1, 128, 8))
    outs = [ssd_scan(x, dt, a, b, c, chunk=ch, interpret=True)
            for ch in (8, 16, 32, 128)]
    for o in outs[1:]:
        np.testing.assert_allclose(outs[0], o, rtol=1e-4, atol=1e-4)
