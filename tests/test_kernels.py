"""Per-kernel correctness sweeps: Pallas (interpret mode) vs pure-jnp oracle
across shapes and dtypes."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.adaseg_update.kernel import adaseg_update
from repro.kernels.adaseg_update.ref import adaseg_update_ref
from repro.kernels.flash_attention.kernel import flash_attention
from repro.kernels.flash_attention.ref import attention_ref
from repro.kernels.ssd_scan.kernel import ssd_scan
from repro.kernels.ssd_scan.ref import ssd_ref


def _tol(dtype):
    return dict(rtol=2e-2, atol=2e-2) if dtype == jnp.bfloat16 else dict(
        rtol=2e-5, atol=2e-5
    )


@pytest.mark.parametrize("b,h,kh,s,d", [
    (1, 2, 2, 64, 32),      # MHA
    (2, 4, 2, 128, 64),     # GQA 2:1
    (1, 8, 1, 64, 64),      # MQA
    (1, 4, 4, 96, 32),      # non-power-of-two seq
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_shapes_dtypes(b, h, kh, s, d, dtype):
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(ks[0], (b, h, s, d), dtype)
    k = jax.random.normal(ks[1], (b, kh, s, d), dtype)
    v = jax.random.normal(ks[2], (b, kh, s, d), dtype)
    out = flash_attention(q, k, v, block_q=32, block_k=32, interpret=True)
    ref = attention_ref(q, k, v)
    np.testing.assert_allclose(
        out.astype(np.float32), ref.astype(np.float32), **_tol(dtype)
    )


@pytest.mark.parametrize("feature", ["window", "softcap", "noncausal", "scale"])
def test_flash_attention_features(feature):
    ks = jax.random.split(jax.random.PRNGKey(1), 3)
    q = jax.random.normal(ks[0], (2, 4, 128, 64))
    k = jax.random.normal(ks[1], (2, 2, 128, 64))
    v = jax.random.normal(ks[2], (2, 2, 128, 64))
    kwargs = {
        "window": dict(causal=True, window=32),
        "softcap": dict(causal=True, softcap=20.0),
        "noncausal": dict(causal=False),
        "scale": dict(causal=True, scale=0.05),
    }[feature]
    out = flash_attention(q, k, v, block_q=32, block_k=32, interpret=True,
                          **kwargs)
    ref = attention_ref(q, k, v, **kwargs)
    np.testing.assert_allclose(out, ref, rtol=2e-5, atol=2e-5)


def test_flash_attention_block_shape_invariance():
    """Output must not depend on the BlockSpec tiling."""
    ks = jax.random.split(jax.random.PRNGKey(2), 3)
    q = jax.random.normal(ks[0], (1, 2, 256, 64))
    k = jax.random.normal(ks[1], (1, 2, 256, 64))
    v = jax.random.normal(ks[2], (1, 2, 256, 64))
    outs = [
        flash_attention(q, k, v, block_q=bq, block_k=bk, interpret=True)
        for bq, bk in [(32, 32), (64, 128), (256, 64), (128, 256)]
    ]
    for o in outs[1:]:
        np.testing.assert_allclose(outs[0], o, rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("n", [64, 1000, 4096, 5000])
@pytest.mark.parametrize("box", [None, (-1.0, 1.0)])
def test_adaseg_update_kernel(n, box):
    ks = jax.random.split(jax.random.PRNGKey(3), 3)
    z = jax.random.normal(ks[0], (n,))
    m = jax.random.normal(ks[1], (n,))
    g = jax.random.normal(ks[2], (n,))
    lo, hi = box if box else (None, None)
    z_t, z_tl, part = adaseg_update(z, m, g, 0.3, lo=lo, hi=hi,
                                    block=1024, interpret=True)
    rz, rtl, rpart = adaseg_update_ref(z, m, g, 0.3, lo=lo, hi=hi)
    np.testing.assert_allclose(z_t, rz, rtol=1e-5, atol=1e-7)
    np.testing.assert_allclose(z_tl, rtl, rtol=1e-5, atol=1e-7)
    np.testing.assert_allclose(float(part), float(rpart), rtol=1e-4)


def test_adaseg_update_fused_eta_matches_host_eta():
    """η = D·α/√(G₀²+Σ) computed in-kernel must equal passing η directly."""
    ks = jax.random.split(jax.random.PRNGKey(5), 3)
    n = 1000
    z, m, g = (jax.random.normal(k, (n,)) for k in ks)
    g0, d_alpha, sum_sq = 1.5, 2.0, 7.0
    eta = d_alpha / np.sqrt(g0**2 + sum_sq)
    z_t, z_tl, part = adaseg_update(
        z, m, g, sum_sq=jnp.float32(sum_sq), g0=g0, d_alpha=d_alpha,
        lo=-1.0, hi=1.0, block=256, interpret=True,
    )
    rz, rtl, rpart = adaseg_update_ref(z, m, g, eta, lo=-1.0, hi=1.0)
    np.testing.assert_allclose(z_t, rz, rtol=1e-6, atol=1e-7)
    np.testing.assert_allclose(z_tl, rtl, rtol=1e-6, atol=1e-7)
    np.testing.assert_allclose(float(part), float(rpart), rtol=1e-5)


def test_adaseg_update_pad_mask_box_above_zero():
    """A box with lo > 0 must not leak clip(0) from the zero-padded tail
    into the (Z_t)² statistic (n chosen to force padding)."""
    ks = jax.random.split(jax.random.PRNGKey(6), 3)
    n = 1000  # pad = 24 at block=128
    z, m, g = (jax.random.normal(k, (n,)) for k in ks)
    z_t, z_tl, part = adaseg_update(z, m, g, 0.3, lo=0.5, hi=1.0,
                                    block=128, interpret=True)
    rz, rtl, rpart = adaseg_update_ref(z, m, g, 0.3, lo=0.5, hi=1.0)
    np.testing.assert_allclose(z_t, rz, rtol=1e-6, atol=1e-7)
    np.testing.assert_allclose(float(part), float(rpart), rtol=1e-5)


def test_adaseg_explore_anchor_match_refs():
    """The step-path primitives (explore + anchor) against their oracles."""
    from repro.kernels.adaseg_update.kernel import (adaseg_anchor,
                                                    adaseg_explore)
    from repro.kernels.adaseg_update.ref import (adaseg_anchor_ref,
                                                 adaseg_explore_ref)

    ks = jax.random.split(jax.random.PRNGKey(7), 3)
    n = 777
    z, m, g = (jax.random.normal(k, (n,)) for k in ks)
    kw = dict(sum_sq=jnp.float32(3.0), g0=1.0, d_alpha=2.0)
    z_t, nrm, msq = adaseg_explore(z, m, lo=-1.0, hi=1.0, block=256,
                                   interpret=True, **kw)
    rz, rnrm, rmsq = adaseg_explore_ref(z, m, lo=-1.0, hi=1.0, **kw)
    np.testing.assert_allclose(z_t, rz, rtol=1e-6, atol=1e-7)
    np.testing.assert_allclose(float(msq), float(rmsq), rtol=1e-5)

    ztl, stat, gsq = adaseg_anchor(z, z_t, g, lo=-1.0, hi=1.0, block=256,
                                   interpret=True, **kw)
    rtl, rstat, rgsq = adaseg_anchor_ref(z, rz, g, lo=-1.0, hi=1.0, **kw)
    np.testing.assert_allclose(ztl, rtl, rtol=1e-6, atol=1e-7)
    np.testing.assert_allclose(float(stat), float(rstat), rtol=1e-5)
    np.testing.assert_allclose(float(gsq), float(rgsq), rtol=1e-5)


def test_adaseg_tree_update_l2_matches_tree_reference():
    """The kernel two-pass l2 scheme == reference tree-level projection."""
    from repro.core import projections
    from repro.core.tree import tree_norm
    from repro.kernels.adaseg_update.ops import adaseg_tree_update

    ks = jax.random.split(jax.random.PRNGKey(8), 6)
    tree = {"a": jax.random.normal(ks[0], (300,)),
            "b": jax.random.normal(ks[1], (4, 50))}
    m = {"a": jax.random.normal(ks[2], (300,)),
         "b": jax.random.normal(ks[3], (4, 50))}
    g = {"a": jax.random.normal(ks[4], (300,)),
         "b": jax.random.normal(ks[5], (4, 50))}
    radius, eta = 1.2, 0.4
    z_t, z_tl, z_sq = adaseg_tree_update(tree, m, g, eta,
                                         proj=("l2", radius), block=128)

    proj = projections.l2_ball(radius)
    from repro.core.tree import tree_axpy, tree_norm_sq, tree_sub

    rz_t = proj(tree_axpy(-eta, m, tree))
    rz_tl = proj(tree_axpy(-eta, g, tree))
    rz_sq = (tree_norm_sq(tree_sub(rz_t, tree))
             + tree_norm_sq(tree_sub(rz_t, rz_tl))) / (5.0 * eta**2)
    for k in tree:
        np.testing.assert_allclose(z_t[k], rz_t[k], rtol=1e-6, atol=1e-7)
        np.testing.assert_allclose(z_tl[k], rz_tl[k], rtol=1e-6, atol=1e-7)
    np.testing.assert_allclose(float(z_sq), float(rz_sq), rtol=1e-4)
    # the candidates genuinely left the ball, so the scaling pass fired
    assert float(tree_norm(z_t)) <= radius + 1e-5


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_adaseg_update_dtypes(dtype):
    ks = jax.random.split(jax.random.PRNGKey(4), 3)
    z = jax.random.normal(ks[0], (512,), dtype)
    m = jax.random.normal(ks[1], (512,), dtype)
    g = jax.random.normal(ks[2], (512,), dtype)
    z_t, z_tl, part = adaseg_update(z, m, g, 0.1, block=128, interpret=True)
    rz, rtl, rpart = adaseg_update_ref(z, m, g, jnp.asarray(0.1, dtype))
    np.testing.assert_allclose(
        z_t.astype(np.float32), rz.astype(np.float32), **_tol(dtype)
    )


@pytest.mark.parametrize("l,chunk", [(64, 8), (64, 16), (128, 64), (96, 32)])
@pytest.mark.parametrize("h,p,n", [(2, 16, 32), (4, 32, 16)])
def test_ssd_scan_kernel(l, chunk, h, p, n):
    ks = jax.random.split(jax.random.PRNGKey(5), 5)
    x = jax.random.normal(ks[0], (2, l, h, p))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (2, l, h)))
    a = -jnp.exp(jax.random.normal(ks[2], (h,)))
    b = jax.random.normal(ks[3], (2, l, n))
    c = jax.random.normal(ks[4], (2, l, n))
    out = ssd_scan(x, dt, a, b, c, chunk=chunk, interpret=True)
    ref = ssd_ref(x, dt, a, b, c)
    np.testing.assert_allclose(out, ref, rtol=2e-4, atol=2e-4)


def test_ssd_chunk_invariance():
    ks = jax.random.split(jax.random.PRNGKey(6), 5)
    x = jax.random.normal(ks[0], (1, 128, 2, 16))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (1, 128, 2)))
    a = -jnp.exp(jax.random.normal(ks[2], (2,)))
    b = jax.random.normal(ks[3], (1, 128, 8))
    c = jax.random.normal(ks[4], (1, 128, 8))
    outs = [ssd_scan(x, dt, a, b, c, chunk=ch, interpret=True)
            for ch in (8, 16, 32, 128)]
    for o in outs[1:]:
        np.testing.assert_allclose(outs[0], o, rtol=1e-4, atol=1e-4)
