"""Checkpoint round-trip tests."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import load_pytree, save_pytree


def test_roundtrip(tmp_path):
    tree = {
        "a": jnp.arange(12, dtype=jnp.float32).reshape(3, 4),
        "b": {"c": jnp.ones((5,), jnp.int32),
              "d": jnp.full((2, 2), 0.5, jnp.bfloat16)},
    }
    path = str(tmp_path / "ckpt.msgpack")
    save_pytree(path, tree)
    restored = load_pytree(path, tree)
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(restored)):
        assert a.dtype == b.dtype
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32))


def test_shape_mismatch_rejected(tmp_path):
    path = str(tmp_path / "ckpt.msgpack")
    save_pytree(path, {"a": jnp.zeros((3,))})
    with pytest.raises(ValueError):
        load_pytree(path, {"a": jnp.zeros((4,))})


def test_leaf_count_mismatch_rejected(tmp_path):
    path = str(tmp_path / "ckpt.msgpack")
    save_pytree(path, {"a": jnp.zeros((3,))})
    with pytest.raises(ValueError):
        load_pytree(path, {"a": jnp.zeros((3,)), "b": jnp.zeros((3,))})


def test_train_state_roundtrip(tmp_path):
    from repro.configs import smoke_config
    from repro.core.adaseg import AdaSEGConfig
    from repro.launch.mesh import make_test_mesh
    from repro.launch.train import TrainPlan, init_train_state

    plan = TrainPlan(
        cfg=smoke_config("qwen2-0.5b"),
        adaseg=AdaSEGConfig(g0=1.0, diameter=1.0, alpha=1.0, k=1),
        worker_mode="paper", k_local=1, global_batch=2, seq=8,
    )
    mesh = make_test_mesh(1, 1)
    state = init_train_state(jax.random.PRNGKey(0), plan, mesh)
    path = str(tmp_path / "state.msgpack")
    save_pytree(path, state)
    restored = load_pytree(path, state)
    for a, b in zip(jax.tree.leaves(state), jax.tree.leaves(restored)):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32))
