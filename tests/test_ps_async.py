"""Event-driven async PS engine: sync-parity anchor, bounded staleness,
latency models, simulated-time telemetry, and event-queue crash/resume."""
import dataclasses
import math

import jax
import numpy as np
import pytest

from repro.core import AdaSEGConfig
from repro.optim import MinimaxWorker, adam_minimax, segda
from repro.problems import make_bilinear_game
from repro.ps import (
    AsyncPSConfig,
    AsyncPSEngine,
    BernoulliFaults,
    ConstantLatency,
    FixedSchedule,
    LognormalLatency,
    MarkovLatency,
    PSConfig,
    PSEngine,
    StochasticQuantizeCompressor,
    StragglerSchedule,
    TraceLatency,
)

M, R, K = 4, 6, 5
N = 10


@pytest.fixture(scope="module")
def game():
    return make_bilinear_game(jax.random.PRNGKey(0), n=N, sigma=0.1)


def _cfg(k=K):
    return AdaSEGConfig(g0=1.0, diameter=2.0, alpha=1.0, k=k)


def _as_async(pscfg: PSConfig, **extra) -> AsyncPSConfig:
    base = {f.name: getattr(pscfg, f.name)
            for f in dataclasses.fields(PSConfig)}
    return AsyncPSConfig(**base, **extra)


def _assert_trees_equal(a, b):
    for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(b)):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


# ---------------------------------------------------------------------------
# Parity anchor: degenerate latency reproduces the synchronous engine
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("tau", [math.inf, 0.0])
def test_lockstep_parity_adaseg_bit_exact(game, tau):
    """Worker-equal constant latency + identity compression + no faults:
    the event-driven engine must be bit-exact with PSEngine's serial path —
    the subsystem's acceptance bar (both at τ=∞, where nothing ever
    blocks, and τ=0, where the staleness bound degenerates to a barrier)."""
    pscfg = PSConfig(adaseg=_cfg(), num_workers=M, rounds=R)
    eng = PSEngine(game.problem, pscfg, rng=jax.random.PRNGKey(2))
    z_sync = eng.run()
    a = AsyncPSEngine(
        game.problem,
        _as_async(pscfg, latency=ConstantLatency(step_s=1.0, up_s=0.5,
                                                 down_s=0.25),
                  staleness_bound=tau),
        rng=jax.random.PRNGKey(2))
    z_async = a.run()
    _assert_trees_equal(z_sync, z_async)
    _assert_trees_equal(eng.state, a.state)
    # the simulated clock actually advanced (R compute phases + comm)
    assert a.sim_time == pytest.approx(R * (K * 1.0 + 0.75))


def test_lockstep_parity_zoo_worker(game):
    """Same anchor for a MinimaxWorker: the zoo runs unmodified on the
    event-driven runtime and stays bit-exact with the sync engine."""
    pscfg = PSConfig(worker=MinimaxWorker(segda(0.05)), local_k=K,
                     num_workers=M, rounds=R)
    eng = PSEngine(game.problem, pscfg, rng=jax.random.PRNGKey(3))
    z_sync = eng.run()
    a = AsyncPSEngine(
        game.problem,
        _as_async(pscfg, latency=ConstantLatency(step_s=1.0)),
        rng=jax.random.PRNGKey(3))
    _assert_trees_equal(z_sync, a.run())
    _assert_trees_equal(eng.state, a.state)


def test_barrier_parity_under_straggler_latency(game):
    """τ=0 holds every uplink until the whole fleet's round has landed, so
    even under heterogeneous latency *and* a heterogeneous schedule the
    barrier run equals the synchronous engine bit-exactly — only the
    simulated clock (paced by the slowest worker) knows the difference."""
    pscfg = PSConfig(adaseg=_cfg(), num_workers=M, rounds=R,
                     schedule=FixedSchedule((5, 4, 3, 2)))
    eng = PSEngine(game.problem, pscfg, rng=jax.random.PRNGKey(4))
    z_sync = eng.run()
    a = AsyncPSEngine(
        game.problem,
        _as_async(pscfg,
                  latency=ConstantLatency(step_s=(1., 2., 1., 3.),
                                          up_s=0.5, down_s=0.1),
                  staleness_bound=0.0),
        rng=jax.random.PRNGKey(4))
    _assert_trees_equal(z_sync, a.run())
    _assert_trees_equal(eng.state, a.state)
    # barrier rounds are paced by the slowest (worker 3: 2 steps × 3 s/step)
    assert a.idle_fraction() > 0.2


def test_adam_zoo_barrier_parity(game):
    """Inner optimizer state (Adam moments) rides through the async engine:
    τ=0 under straggler latency still reproduces the sync trajectory."""
    pscfg = PSConfig(worker=MinimaxWorker(adam_minimax(0.05)), local_k=K,
                     num_workers=M, rounds=R)
    eng = PSEngine(game.problem, pscfg, rng=jax.random.PRNGKey(7))
    z_sync = eng.run()
    a = AsyncPSEngine(
        game.problem,
        _as_async(pscfg,
                  latency=ConstantLatency(step_s=(1., 2., 1., 3.),
                                          up_s=0.5, down_s=0.1),
                  staleness_bound=0.0),
        rng=jax.random.PRNGKey(7))
    _assert_trees_equal(z_sync, a.run())
    _assert_trees_equal(eng.state, a.state)


# ---------------------------------------------------------------------------
# Genuinely asynchronous semantics
# ---------------------------------------------------------------------------

def test_bounded_staleness_is_enforced(game):
    """With a 6× straggler and τ=2, no admission may average an entry more
    than τ rounds behind a *live* contribution's arrival window; with τ=∞
    the straggler's entry is allowed to age far beyond that."""
    lat = ConstantLatency(step_s=(1., 1., 1., 6.), up_s=0.2, down_s=0.1)
    base = PSConfig(adaseg=_cfg(), num_workers=M, rounds=10)

    bounded = AsyncPSEngine(
        game.problem, _as_async(base, latency=lat, staleness_bound=2.0),
        rng=jax.random.PRNGKey(5))
    bounded.run()
    # staleness telemetry present and capped: an entry can lag at most
    # τ + 1 rounds (the gate holds round r until r − τ has *arrived*;
    # the binding worker's own in-flight round adds one)
    assert bounded.trace.max_staleness <= 3
    assert any(r.staleness and max(s for s in r.staleness if s is not None) > 0
               for r in bounded.trace.rounds)

    free = AsyncPSEngine(
        game.problem, _as_async(base, latency=lat, staleness_bound=math.inf),
        rng=jax.random.PRNGKey(5))
    free.run()
    assert free.trace.max_staleness > 3


def test_async_beats_sync_time_to_target(game):
    """The PR's speed-up bar: under straggler latency, async-τ reaches the
    barrier run's final residual in strictly less simulated time."""
    lat = ConstantLatency(step_s=(1., 1., 1., 6.), up_s=0.2, down_s=0.1)
    D = float(np.sqrt(2 * N))
    base = PSConfig(adaseg=AdaSEGConfig(g0=1.0, diameter=D, alpha=1.0, k=10),
                    num_workers=M, rounds=20)

    def run(tau):
        e = AsyncPSEngine(
            game.problem, _as_async(base, latency=lat, staleness_bound=tau),
            rng=jax.random.PRNGKey(1), eval_fn=game.residual)
        e.run()
        return e

    sync = run(0.0)
    target = sync.trace.summary()["final_residual"]
    for tau in (2.0, math.inf):
        t = run(tau).trace.time_to_residual(target)
        assert t is not None
        assert t < sync.sim_time, (tau, t, sync.sim_time)


def test_per_arrival_broadcast_only_reaches_sender(game):
    """With τ=∞ and a straggler, fast workers' admissions must not touch
    the slow worker's state (per-arrival broadcast, not per-barrier): while
    the straggler computes its first phase, the fast workers complete
    several rounds and the straggler's iterate stays at zero steps. The
    uplink is staggered so no admission is ever full-fleet lockstep (a
    lockstep batch legitimately pre-executes its phases — see the engine
    docstring)."""
    lat = ConstantLatency(step_s=(1., 1., 1., 20.), up_s=(0., 0., 0., 0.3))
    a = AsyncPSEngine(
        game.problem,
        _as_async(PSConfig(adaseg=_cfg(), num_workers=M, rounds=3),
                  latency=lat),
        rng=jax.random.PRNGKey(6))
    # run past the fast workers' first round-trips but stop well before the
    # slow worker's first phase (K × 20 s) completes
    a.run(until_time=K * 2.0 + 0.5)
    assert a.n_admissions >= 2
    z3_before = jax.tree.map(
        lambda v: np.asarray(v[3]).copy(), a.state.z_tilde)
    assert int(a.state.t[3]) == 0      # straggler: zero completed steps
    assert int(a.state.t[0]) > 0       # fast workers: several rounds in
    # fast workers' later admissions never re-broadcast to the straggler
    a.run(until_time=K * 20.0 * 0.5)
    assert int(a.state.t[3]) == 0
    _assert_trees_equal(
        z3_before,
        jax.tree.map(lambda v: np.asarray(v[3]), a.state.z_tilde))


def test_faults_skip_round_and_rejoin(game):
    """A worker dead for its own round r sends/receives/steps nothing and
    rejoins afterwards; the run stays finite and the trace shows the gap."""
    from repro.ps import OutageFaults

    pscfg = PSConfig(adaseg=_cfg(), num_workers=M, rounds=R,
                     faults=OutageFaults(events=((2, 1, 3),)))
    a = AsyncPSEngine(
        game.problem,
        _as_async(pscfg, latency=ConstantLatency(step_s=1.0, up_s=0.1)),
        rng=jax.random.PRNGKey(8), eval_fn=game.residual)
    z = a.run()
    assert np.isfinite(float(game.residual(z)))
    # worker 2 skipped exactly rounds 1 and 2 (K steps each)
    assert int(a.state.t[2]) == (R - 2) * K
    assert int(a.state.t[0]) == R * K
    # every admission it missed shows it as non-participating
    missed = [r for r in a.trace.rounds
              if not r.alive[2] and any(r.alive)]
    assert missed


def test_compression_and_ef_compose(game):
    """Quantized uplinks with error feedback run per-payload on the async
    wire; trajectory stays close to dense and bytes-up shrinks."""
    base = PSConfig(adaseg=_cfg(k=10), num_workers=M, rounds=10)
    lat = ConstantLatency(step_s=(1., 1., 1., 3.), up_s=0.2)
    res = {}
    for comp in (None, StochasticQuantizeCompressor(bits=8)):
        pscfg = dataclasses.replace(base, compressor=comp)
        e = AsyncPSEngine(
            game.problem, _as_async(pscfg, latency=lat),
            rng=jax.random.PRNGKey(9))
        res[comp.name if comp else "dense"] = (
            float(game.residual(e.run())), e.trace.total_bytes_up)
    assert np.isfinite(res["q8"][0])
    assert res["q8"][0] < 2.0 * res["dense"][0]
    assert res["q8"][1] < res["dense"][1]


# ---------------------------------------------------------------------------
# Latency models
# ---------------------------------------------------------------------------

def test_latency_models_deterministic():
    for model in (
        ConstantLatency(step_s=(1., 2., 1., 3.), up_s=0.5),
        LognormalLatency(step_s=1.0, sigma=0.7, up_s=0.1, net_sigma=0.3,
                         seed=11),
        MarkovLatency(step_s=1.0, slow_factor=8.0, p_slow=0.2,
                      p_recover=0.3, seed=12, start_slow=(1,)),
        TraceLatency(step_s=[[1., 2., 1., 4.], [2., 1., 1., 1.]],
                     up_s=0.3),
    ):
        a, b = model.tables(4, 9), model.tables(4, 9)
        np.testing.assert_array_equal(a.step_s, b.step_s)
        np.testing.assert_array_equal(a.up_s, b.up_s)
        np.testing.assert_array_equal(a.down_s, b.down_s)
        assert a.step_s.shape == (9, 4)
        assert (a.step_s >= 0).all()


def test_markov_latency_start_slow_and_recovers():
    m = MarkovLatency(step_s=1.0, slow_factor=5.0, p_slow=0.0,
                      p_recover=1.0, seed=0, start_slow=(0,))
    t = m.tables(2, 4)
    assert t.step_s[0, 0] == 5.0          # starts slow
    assert (t.step_s[1:, 0] == 1.0).all()  # p_recover=1 → fast from round 1
    assert (t.step_s[:, 1] == 1.0).all()   # p_slow=0 → never degrades


def test_trace_latency_tiles_rounds():
    t = TraceLatency(step_s=[[1., 2.], [3., 4.]]).tables(2, 5)
    np.testing.assert_array_equal(t.step_s[:, 0], [1., 3., 1., 3., 1.])
    with pytest.raises(ValueError):
        TraceLatency(step_s=[[1., 2., 3.]]).tables(2, 4)


# ---------------------------------------------------------------------------
# Telemetry and checkpoint/resume
# ---------------------------------------------------------------------------

def test_async_trace_fields_and_roundtrip(game, tmp_path):
    lat = LognormalLatency(step_s=1.0, sigma=0.5, up_s=0.2, seed=3)
    a = AsyncPSEngine(
        game.problem,
        _as_async(PSConfig(adaseg=_cfg(), num_workers=M, rounds=R),
                  latency=lat, staleness_bound=3.0),
        rng=jax.random.PRNGKey(10), eval_fn=game.residual)
    a.run()
    recs = a.trace.rounds
    assert all(r.sim_time_s is not None for r in recs)
    assert all(recs[i].sim_time_s <= recs[i + 1].sim_time_s
               for i in range(len(recs) - 1))
    assert all(r.staleness is not None for r in recs)
    assert any(r.idle_frac is not None and r.idle_frac > 0 for r in recs)
    summary = a.trace.summary()
    assert summary["sim_time_s"] == pytest.approx(a.sim_time)
    assert "idle_frac" in summary and "max_staleness" in summary
    # save → load round-trips the new fields
    path = str(tmp_path / "async_trace.json")
    a.trace.save(path)
    from repro.ps import TraceRecorder

    loaded = TraceRecorder.load(path)
    assert loaded.summary() == summary
    assert loaded.rounds[0].staleness == recs[0].staleness
    assert loaded.time_to_residual(summary["final_residual"]) is not None


def test_event_queue_crash_resume_bit_exact(game):
    """Kill the simulation mid-event-queue, restore from disk (policies and
    latency draws re-derived from seeds), and finish: state, simulated
    clock, admission count and the trace tail all match the uninterrupted
    run bit-exactly — under the full hostile configuration."""
    cfg = _as_async(
        PSConfig(
            adaseg=_cfg(), num_workers=M, rounds=10,
            schedule=StragglerSchedule(k=K, min_frac=0.5, seed=2,
                                       slow_workers=(3,)),
            compressor=StochasticQuantizeCompressor(bits=8),
            faults=BernoulliFaults(p=0.1, seed=3),
        ),
        latency=MarkovLatency(step_s=1.0, slow_factor=6.0, p_slow=0.2,
                              p_recover=0.4, up_s=0.3, down_s=0.2, seed=5,
                              start_slow=(1,)),
        staleness_bound=2.0,
    )

    def fresh():
        return AsyncPSEngine(game.problem, cfg, rng=jax.random.PRNGKey(4),
                             eval_fn=game.residual)

    ref = fresh()
    z_ref = ref.run()

    import os
    import tempfile

    with tempfile.TemporaryDirectory() as tmp:
        ck = os.path.join(tmp, "engine.msgpack")
        e1 = fresh()
        e1.run(until_time=ref.sim_time / 2)
        assert not e1.done
        e1.save(ck)
        e2 = fresh().restore(ck)
        z2 = e2.run()

    _assert_trees_equal(z_ref, z2)
    _assert_trees_equal(ref.state, e2.state)
    assert ref.sim_time == e2.sim_time
    assert ref.n_admissions == e2.n_admissions
    tail = [r for r in ref.trace.rounds
            if r.round >= e2.trace.rounds[0].round]
    assert [dataclasses.asdict(r) for r in tail] == [
        dataclasses.asdict(r) for r in e2.trace.rounds]


def test_restore_rejects_wrong_seed_and_optimizer(game, tmp_path):
    cfg = _as_async(PSConfig(adaseg=_cfg(), num_workers=M, rounds=R),
                    latency=ConstantLatency(step_s=1.0))
    path = str(tmp_path / "a.msgpack")
    e1 = AsyncPSEngine(game.problem, cfg, rng=jax.random.PRNGKey(4))
    e1.run(until_admissions=2)
    e1.save(path)
    with pytest.raises(ValueError, match="different seed"):
        AsyncPSEngine(game.problem, cfg,
                      rng=jax.random.PRNGKey(5)).restore(path)
    zoo = _as_async(PSConfig(worker=MinimaxWorker(segda(0.05)), local_k=K,
                             num_workers=M, rounds=R),
                    latency=ConstantLatency(step_s=1.0))
    with pytest.raises(ValueError):
        AsyncPSEngine(game.problem, zoo,
                      rng=jax.random.PRNGKey(4)).restore(path)


def test_run_until_admissions_and_resume_points(game):
    """Chunked driving: run(until_admissions=n) repeatedly equals one
    uninterrupted run — the invariant checkpoint_every rides on."""
    cfg = _as_async(PSConfig(adaseg=_cfg(), num_workers=M, rounds=R),
                    latency=ConstantLatency(step_s=(1., 2., 1., 3.),
                                            up_s=0.2),
                    staleness_bound=1.0)
    e1 = AsyncPSEngine(game.problem, cfg, rng=jax.random.PRNGKey(11))
    z1 = e1.run()
    e2 = AsyncPSEngine(game.problem, cfg, rng=jax.random.PRNGKey(11))
    n = 0
    while not e2.done:
        n += 2
        e2.run(until_admissions=n)
    _assert_trees_equal(z1, e2.z_bar())
    _assert_trees_equal(e1.state, e2.state)
    assert e1.sim_time == e2.sim_time


def test_partial_first_admission_telemetry(game):
    """Per-worker uplink delays make the first admission partial (some
    workers unheard → staleness None): the trace summary, max_staleness and
    save must all still work, and total_steps must equal the work actually
    done — including the final phases no admission covers."""
    a = AsyncPSEngine(
        game.problem,
        _as_async(PSConfig(adaseg=_cfg(), num_workers=M, rounds=R),
                  latency=ConstantLatency(step_s=1.0,
                                          up_s=(0.0, 0.1, 0.2, 0.3))),
        rng=jax.random.PRNGKey(12))
    a.run()
    first = a.trace.rounds[0]
    assert None in first.staleness           # someone was unheard
    assert isinstance(a.trace.max_staleness, int)
    summary = a.trace.summary()              # must not raise
    assert summary["total_steps"] == int(a._steps_cum.sum()) == M * R * K


def test_all_dead_fleet_completes(game):
    """A fleet that never uplinks anything (every round dead for every
    worker) still finishes: reboots burn simulated time, the heap drains,
    and the terminal record is written instead of crashing."""
    from repro.ps import OutageFaults

    pscfg = PSConfig(adaseg=_cfg(), num_workers=2, rounds=2,
                     faults=OutageFaults(events=((0, 0, 2), (1, 0, 2))))
    a = AsyncPSEngine(
        game.problem,
        _as_async(pscfg, latency=ConstantLatency(step_s=1.0)),
        rng=jax.random.PRNGKey(13))
    z = a.run()
    assert a.done and a.n_admissions == 0
    assert np.isfinite(float(game.residual(z)))
    assert a.trace.rounds[-1].staleness == [None, None]
    assert a.trace.summary()["total_steps"] == 0


def test_async_rejects_negative_tau(game):
    with pytest.raises(ValueError):
        AsyncPSEngine(
            game.problem,
            _as_async(PSConfig(adaseg=_cfg(), num_workers=M, rounds=R),
                      staleness_bound=-1.0),
            rng=jax.random.PRNGKey(0))
