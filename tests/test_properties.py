"""Hypothesis property tests on system invariants beyond projections."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings
from hypothesis import strategies as st
from hypothesis.extra import numpy as hnp

from repro.core import AdaSEGConfig, sync_weighted_stacked
from repro.core.adaseg import eta_of
from repro.ps import (
    ClientSampler,
    ElasticSchedule,
    FixedSchedule,
    StragglerSchedule,
    UniformSchedule,
)
from repro.roofline.hlo_parse import _decode_groups, classify_axes

_pos_floats = st.floats(0.01, 100.0, width=32, allow_nan=False,
                        allow_subnormal=False)


@given(st.lists(st.floats(0.01, 100.0), min_size=2, max_size=8))
@settings(max_examples=50, deadline=None)
def test_sync_is_convex_combination(inv_eta):
    """The synced anchor lies in the convex hull of worker anchors —
    componentwise between min and max — for ANY positive weights."""
    inv_eta = np.asarray(inv_eta, np.float32)
    m = len(inv_eta)
    z = {"w": jnp.asarray(np.random.RandomState(0).randn(m, 5), jnp.float32)}
    out = sync_weighted_stacked(z, jnp.asarray(inv_eta))
    lo = jnp.min(z["w"], axis=0)
    hi = jnp.max(z["w"], axis=0)
    assert bool(jnp.all(out["w"][0] >= lo - 1e-5))
    assert bool(jnp.all(out["w"][0] <= hi + 1e-5))


@given(hnp.arrays(np.float32, st.integers(1, 20),
                  elements=st.floats(0, 1000, width=32, allow_nan=False)))
@settings(max_examples=50, deadline=None)
def test_eta_antitone_in_accumulator(z_sqs):
    """η is antitone in Σ(Z_τ)² for any nonnegative increment sequence."""
    cfg = AdaSEGConfig(g0=1.0, diameter=3.0, alpha=1.0, k=1)
    acc = np.concatenate([[0.0], np.cumsum(z_sqs)])
    etas = [float(eta_of(cfg, jnp.float32(a))) for a in acc]
    assert all(a >= b - 1e-9 for a, b in zip(etas, etas[1:]))
    assert etas[0] == cfg.diameter * cfg.alpha / cfg.g0


@given(st.floats(0.01, 100.0), st.floats(0.01, 100.0))
@settings(max_examples=50, deadline=None)
def test_eta_scale_covariance(d, alpha):
    """η scales linearly in D·α (the theory's D-dependence)."""
    cfg1 = AdaSEGConfig(g0=1.0, diameter=d, alpha=alpha, k=1)
    cfg2 = AdaSEGConfig(g0=1.0, diameter=2 * d, alpha=alpha, k=1)
    s = jnp.float32(3.7)
    np.testing.assert_allclose(
        2 * float(eta_of(cfg1, s)), float(eta_of(cfg2, s)), rtol=1e-6
    )


# --- Worker-schedule properties ---------------------------------------------
#
# Every WorkerSchedule must be (a) reproducible from its config alone — the
# engines never store the (R, M) table, they re-derive it, which is what
# makes checkpoint/resume (sync round counter, async event queue) bit-exact
# — and (b) bounded by max_steps, the static scan length both engines pad
# to (a larger entry would silently truncate local work while still being
# counted).

@st.composite
def _schedules(draw):
    m = draw(st.integers(1, 8))
    k = draw(st.integers(1, 64))
    seed = draw(st.integers(0, 2**31 - 1))
    kind = draw(st.sampled_from(["uniform", "fixed", "straggler", "elastic"]))
    if kind == "uniform":
        sched = UniformSchedule(k)
    elif kind == "fixed":
        sched = FixedSchedule(tuple(
            draw(st.lists(st.integers(1, 64), min_size=m, max_size=m))))
    elif kind == "straggler":
        slow = draw(st.lists(st.integers(0, m - 1), max_size=m, unique=True))
        sched = StragglerSchedule(
            k=k, min_frac=draw(st.floats(0.05, 1.0, allow_nan=False)),
            seed=seed, slow_workers=tuple(slow))
    else:
        sched = ElasticSchedule(
            UniformSchedule(k), dropout=draw(st.floats(0.0, 1.0,
                                                       allow_nan=False)),
            seed=seed)
    return sched, m, draw(st.integers(1, 30))


@given(_schedules())
@settings(max_examples=80, deadline=None)
def test_schedule_reproducible_and_bounded(case):
    sched, m, rounds = case
    a = sched.steps(m, rounds)
    b = sched.steps(m, rounds)          # re-derived, as resume does
    np.testing.assert_array_equal(a, b)
    assert a.shape == (rounds, m)
    assert np.issubdtype(a.dtype, np.integer)
    assert (a >= 0).all()
    assert (a <= sched.max_steps(m)).all()


# --- ClientSampler properties ------------------------------------------------
#
# Like schedules, the sampling tables must be reproducible from the config
# alone (the engines re-derive them on resume; the checkpoint only carries a
# fingerprint) and exact: every round draws exactly M distinct workers of N,
# rows sorted ascending — the documented participation order.

@st.composite
def _samplers(draw):
    n = draw(st.integers(1, 12))
    sample = draw(st.integers(1, n))
    seed = draw(st.integers(0, 2**31 - 1))
    weights = None
    if draw(st.booleans()):
        weights = tuple(draw(st.lists(
            st.floats(0.1, 10.0, allow_nan=False),
            min_size=n, max_size=n)))
    return ClientSampler(sample=sample, seed=seed, weights=weights), n, \
        draw(st.integers(1, 20))


@given(_samplers())
@settings(max_examples=80, deadline=None)
def test_sampler_reproducible_and_exactly_m_of_n(case):
    sampler, n, rounds = case
    a = sampler.draws(n, rounds)
    b = sampler.draws(n, rounds)        # re-derived, as resume does
    np.testing.assert_array_equal(a, b)
    assert a.shape == (rounds, sampler.sample)
    assert a.dtype == np.int32
    for row in a:
        ids = row.tolist()
        assert len(set(ids)) == sampler.sample      # without replacement
        assert ids == sorted(ids)                   # ascending
        assert 0 <= min(ids) and max(ids) < n
    mask = sampler.participation(n, rounds)
    assert mask.shape == (rounds, n)
    assert (mask.sum(axis=1) == sampler.sample).all()


@given(_samplers())
@settings(max_examples=30, deadline=None)
def test_sampler_fingerprint_separates_laws(case):
    sampler, _, _ = case
    bumped = ClientSampler(sample=sampler.sample, seed=sampler.seed + 1,
                           weights=sampler.weights)
    assert sampler.fingerprint == ClientSampler(
        sample=sampler.sample, seed=sampler.seed,
        weights=sampler.weights).fingerprint
    assert sampler.fingerprint != bumped.fingerprint


def test_sampler_weighted_marginals():
    """Weighted draws match the requested marginals: with sample=1 the
    inclusion probability is exactly w/Σw, so empirical frequencies over
    many rounds converge to it. (Deterministic — one fixed seed, enough
    rounds that a law change trips the tolerance.)"""
    w = (1.0, 2.0, 4.0, 8.0)
    sampler = ClientSampler(sample=1, seed=0, weights=w)
    rounds = 6000
    hits = np.bincount(sampler.draws(4, rounds).ravel(), minlength=4)
    freq = hits / rounds
    expect = np.asarray(w) / sum(w)
    np.testing.assert_allclose(freq, expect, atol=0.02)


# --- Byzantine attack-table properties ---------------------------------------

@st.composite
def _attacks(draw):
    from repro.ps import (
        CollusionAttack,
        ScaledNoiseAttack,
        SignFlipAttack,
        ZeroAttack,
    )

    cls = draw(st.sampled_from([SignFlipAttack, ScaledNoiseAttack,
                                ZeroAttack, CollusionAttack]))
    policy = cls(
        fraction=draw(st.floats(0.0, 1.0, allow_nan=False)),
        seed=draw(st.integers(0, 2**31 - 1)),
        per_round=draw(st.booleans()),
    )
    return policy, draw(st.integers(1, 16)), draw(st.integers(1, 20))


@given(_attacks())
@settings(max_examples=80, deadline=None)
def test_byzantine_table_reproducible_shaped_and_bounded(case):
    """The attack-membership law the engines (and checkpoint resume)
    rely on: ``attacked`` is a pure function of (seed, fraction,
    per_round) with shape (rounds, workers), and every round corrupts
    exactly ``count(m) = min(m, round(fraction·m))`` workers — the
    configured attack fraction is a hard bound, not an expectation."""
    policy, workers, rounds = case
    a = np.asarray(policy.attacked(workers, rounds))
    b = np.asarray(policy.attacked(workers, rounds))   # re-derived on resume
    np.testing.assert_array_equal(a, b)
    assert a.shape == (rounds, workers)
    assert a.dtype == bool
    want = policy.count(workers)
    assert want <= workers
    assert (a.sum(axis=1) == want).all()
    if not policy.per_round:
        # fixed conspiracy: the same subset every round
        assert (a == a[0]).all()


@given(_attacks())
@settings(max_examples=30, deadline=None)
def test_byzantine_fingerprint_separates_laws(case):
    policy, _, _ = case
    import dataclasses as dc

    same = dc.replace(policy)
    bumped = dc.replace(policy, seed=policy.seed + 1)
    assert policy.fingerprint == same.fingerprint
    assert policy.fingerprint != bumped.fingerprint
    assert policy.name == same.name


# --- HLO parser properties ---------------------------------------------------

def test_iota_replica_groups_decode():
    assert _decode_groups("replica_groups=[2,2]<=[4]") == [[0, 1], [2, 3]]
    assert _decode_groups("replica_groups=[2,2]<=[2,2]T(1,0)") == [
        [0, 2], [1, 3]
    ]
    assert _decode_groups("replica_groups={{0,1},{2,3}}") == [[0, 1], [2, 3]]


@given(st.sampled_from([(2, 2), (4, 2), (2, 4), (4, 4)]))
@settings(max_examples=12, deadline=None)
def test_iota_decode_partitions_devices(shape):
    g, s = shape
    groups = _decode_groups(f"replica_groups=[{g},{s}]<=[{g*s}]")
    flat = sorted(d for grp in groups for d in grp)
    assert flat == list(range(g * s))        # exact partition
    assert all(len(grp) == s for grp in groups)


def test_classify_axes_abstract():
    """Axis classification against a mesh with known device layout."""
    import dataclasses

    class FakeDev:
        def __init__(self, i):
            self.id = i

    class FakeMesh:
        axis_names = ("data", "model")
        shape = {"data": 2, "model": 2}
        devices = np.array([[FakeDev(0), FakeDev(1)],
                            [FakeDev(2), FakeDev(3)]])

    mesh = FakeMesh()
    assert classify_axes([[0, 1], [2, 3]], mesh) == "model"
    assert classify_axes([[0, 2], [1, 3]], mesh) == "data"
    assert classify_axes([[0, 1, 2, 3]], mesh) == "data,model"


# --- ServerOptimizer properties ----------------------------------------------
#
# The outer-optimizer math (ps/server_opt + the kernel twins) has laws the
# engines lean on: momentum states are geometric sums of past deltas (so
# they stay bounded when the deltas do), a zero-momentum unit-lr policy IS
# the historical Line-7 merge, fingerprints separate any two hyperparameter
# settings, and a fixed seed fully determines the trajectory across rerun
# AND checkpoint/resume. The math properties drive the eager reference twin
# (``outer_apply_ref``) directly — no jit cache pollution across examples.

def _ref_chain(spec, deltas, z0):
    """Run the reference outer update over a sequence of deltas; returns
    final (z, mom) plus every intermediate moment tuple."""
    from repro.kernels.sync_compress.ref import outer_apply_ref

    slots = 2 if spec[0] == "adam" else 1
    z = jnp.asarray(z0)
    mom = tuple(jnp.zeros_like(z) for _ in range(slots))
    t = jnp.float32(0.0)
    moms = []
    for d in deltas:
        g = z + jnp.asarray(d)           # merged such that merged − z = d
        z, mom, _ = outer_apply_ref(g, z, mom, t, spec=spec)
        t = t + 1.0
        moms.append(mom)
    return z, moms


@given(
    st.floats(0.0, 0.95, allow_nan=False),
    st.lists(hnp.arrays(np.float32, 6,
                        elements=st.floats(-5.0, 5.0, width=32,
                                           allow_nan=False)),
             min_size=1, max_size=8),
)
@settings(max_examples=40, deadline=None)
def test_momentum_moment_geometric_bound(beta, deltas):
    """Heavy-ball moment is a geometric sum of past deltas: for any
    bounded delta sequence, ‖m‖∞ ≤ max‖Δ‖∞ / (1 − β)."""
    deltas = [d.reshape(1, -1) for d in deltas]
    dmax = max(float(np.abs(d).max()) for d in deltas)
    _, moms = _ref_chain(("momentum", 1.0, beta), deltas,
                         np.zeros((1, 6), np.float32))
    bound = dmax / (1.0 - beta) + 1e-4
    for mom in moms:
        assert float(jnp.abs(mom[0]).max()) <= bound


@given(
    st.floats(0.0, 0.99, allow_nan=False),
    st.floats(0.0, 0.99, allow_nan=False),
    st.lists(hnp.arrays(np.float32, 4,
                        elements=st.floats(-3.0, 3.0, width=32,
                                           allow_nan=False)),
             min_size=1, max_size=6),
)
@settings(max_examples=40, deadline=None)
def test_adam_moments_are_convex_averages(b1, b2, deltas):
    """Adam's m/v are exponential *averages* (decay + (1−β)·new), so they
    never escape the range of the deltas: ‖m‖∞ ≤ max‖Δ‖∞ and
    v ≤ max(Δ²) componentwise — with no 1/(1−β) inflation."""
    deltas = [d.reshape(1, -1) for d in deltas]
    dmax = max(float(np.abs(d).max()) for d in deltas)
    _, moms = _ref_chain(("adam", 0.5, b1, b2, 1e-8), deltas,
                         np.zeros((1, 4), np.float32))
    for m, v in moms:
        assert float(jnp.abs(m).max()) <= dmax + 1e-4
        assert float(v.max()) <= dmax * dmax + 1e-4
        assert float(v.min()) >= -1e-7                 # v is a square average


@given(hnp.arrays(np.float32, (3, 7),
                  elements=st.floats(-10.0, 10.0, width=32,
                                     allow_nan=False)))
@settings(max_examples=40, deadline=None)
def test_unit_lr_zero_beta_momentum_is_line7_identity(rows):
    """β=0, lr=1 heavy-ball IS the historical merge: z′ = z + Δ = merged,
    for any merged/anchor pair — the algebraic root of the `none`
    bit-exactness guarantee."""
    from repro.kernels.sync_compress.ref import outer_apply_ref

    merged = jnp.asarray(rows[:1])
    z = jnp.asarray(rows[1:2])
    z_new, _, _ = outer_apply_ref(merged, z, (jnp.zeros_like(z),),
                                  jnp.float32(0.0),
                                  spec=("momentum", 1.0, 0.0))
    np.testing.assert_allclose(np.asarray(z_new), np.asarray(merged),
                               rtol=1e-6, atol=1e-6)


@given(st.floats(0.01, 10.0), st.floats(0.0, 0.99),
       st.floats(0.01, 10.0), st.floats(0.0, 0.99))
@settings(max_examples=50, deadline=None)
def test_server_opt_fingerprints_separate_hypers(lr1, b1, lr2, b2):
    from repro.ps import ServerMomentum, ServerNesterov

    a = ServerMomentum(lr=lr1, beta=b1)
    b = ServerMomentum(lr=lr2, beta=b2)
    if a.name == b.name:
        assert a.fingerprint == b.fingerprint
    else:
        assert a.fingerprint != b.fingerprint
    # policy kind always separates, even at identical hypers
    assert (ServerMomentum(lr=lr1, beta=b1).fingerprint
            != ServerNesterov(lr=lr1, beta=b1).fingerprint)


@given(st.integers(0, 2**31 - 1))
@settings(max_examples=8, deadline=None)
def test_server_opt_seed_determinism_rerun_and_resume(seed):
    """One seed, one trajectory: rerunning an outer-Nesterov engine from
    the same rng reproduces z̄ and the outer telemetry bit-exactly, and a
    mid-stream save/restore lands on the identical trajectory."""
    import tempfile, os
    from repro.problems import make_bilinear_game
    from repro.ps import PSConfig, PSEngine, ServerNesterov

    game = make_bilinear_game(jax.random.PRNGKey(7), n=4, sigma=0.1)
    cfg = PSConfig(adaseg=AdaSEGConfig(g0=1.0, diameter=2.0, k=2),
                   num_workers=2, rounds=3,
                   server_opt=ServerNesterov(lr=0.7, beta=0.9))
    mk = lambda: PSEngine(game.problem, cfg,
                          rng=jax.random.PRNGKey(seed),
                          eval_fn=game.residual)
    e1, e2 = mk(), mk()
    z1, z2 = e1.run(), e2.run()
    for a, b in zip(jax.tree.leaves(z1), jax.tree.leaves(z2)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    assert ([(r.outer_lr, r.delta_norm) for r in e1.trace.rounds]
            == [(r.outer_lr, r.delta_norm) for r in e2.trace.rounds])
    with tempfile.TemporaryDirectory() as d:
        path = os.path.join(d, "srv.msgpack")
        e3 = mk()
        e3.run(until_round=2)
        e3.save(path)
        e4 = mk()
        e4.restore(path)
        z4 = e4.run()
        for a, b in zip(jax.tree.leaves(z1), jax.tree.leaves(z4)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


# --- Cross-version trace property --------------------------------------------
#
# Every trace vintage (v5 explicit-version onward, through v8's outer-
# optimizer fields) loads through TraceRecorder.load with missing optional
# fields defaulted — the loader contract the bench/plot stack relies on.

_V_FIELDS = {
    5: [],
    6: ["sampled_workers"],
    7: ["sampled_workers", "byzantine_workers"],
    8: ["sampled_workers", "byzantine_workers", "outer_lr", "delta_norm"],
}


@given(st.sampled_from([5, 6, 7, 8]), st.randoms(use_true_random=False))
@settings(max_examples=40, deadline=None)
def test_any_trace_vintage_loads_with_defaults(version, rnd, tmp_path_factory):
    import json
    from repro.ps import TraceRecorder

    base = {"round": 0, "local_steps": [1, 1], "alive": [True, True],
            "bytes_up": 4.0, "bytes_down": 4.0,
            "eta_min": 1.0, "eta_max": 2.0, "eta_mean": 1.5}
    # a random subset of the vintage's optional fields is present
    extras = {}
    if _V_FIELDS[version] and rnd.random() < 0.7:
        for f in rnd.sample(_V_FIELDS[version],
                            rnd.randint(1, len(_V_FIELDS[version]))):
            extras[f] = [0] if f.endswith("workers") else 0.5
    payload = {"version": version, "meta": {"v": version},
               "rounds": [dict(base, **extras)]}
    path = tmp_path_factory.mktemp("traces") / f"v{version}.json"
    path.write_text(json.dumps(payload))
    rec = TraceRecorder.load(str(path))
    assert rec.version == version
    r = rec.rounds[0]
    for f in ("sampled_workers", "byzantine_workers", "outer_lr",
              "delta_norm"):
        assert getattr(r, f) == extras.get(f)   # present ⇒ kept, absent ⇒ None
    assert r.eta_spread == pytest.approx(2.0)
