"""Hypothesis property tests on system invariants beyond projections."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings
from hypothesis import strategies as st
from hypothesis.extra import numpy as hnp

from repro.core import AdaSEGConfig, sync_weighted_stacked
from repro.core.adaseg import eta_of
from repro.ps import (
    ClientSampler,
    ElasticSchedule,
    FixedSchedule,
    StragglerSchedule,
    UniformSchedule,
)
from repro.roofline.hlo_parse import _decode_groups, classify_axes

_pos_floats = st.floats(0.01, 100.0, width=32, allow_nan=False,
                        allow_subnormal=False)


@given(st.lists(st.floats(0.01, 100.0), min_size=2, max_size=8))
@settings(max_examples=50, deadline=None)
def test_sync_is_convex_combination(inv_eta):
    """The synced anchor lies in the convex hull of worker anchors —
    componentwise between min and max — for ANY positive weights."""
    inv_eta = np.asarray(inv_eta, np.float32)
    m = len(inv_eta)
    z = {"w": jnp.asarray(np.random.RandomState(0).randn(m, 5), jnp.float32)}
    out = sync_weighted_stacked(z, jnp.asarray(inv_eta))
    lo = jnp.min(z["w"], axis=0)
    hi = jnp.max(z["w"], axis=0)
    assert bool(jnp.all(out["w"][0] >= lo - 1e-5))
    assert bool(jnp.all(out["w"][0] <= hi + 1e-5))


@given(hnp.arrays(np.float32, st.integers(1, 20),
                  elements=st.floats(0, 1000, width=32, allow_nan=False)))
@settings(max_examples=50, deadline=None)
def test_eta_antitone_in_accumulator(z_sqs):
    """η is antitone in Σ(Z_τ)² for any nonnegative increment sequence."""
    cfg = AdaSEGConfig(g0=1.0, diameter=3.0, alpha=1.0, k=1)
    acc = np.concatenate([[0.0], np.cumsum(z_sqs)])
    etas = [float(eta_of(cfg, jnp.float32(a))) for a in acc]
    assert all(a >= b - 1e-9 for a, b in zip(etas, etas[1:]))
    assert etas[0] == cfg.diameter * cfg.alpha / cfg.g0


@given(st.floats(0.01, 100.0), st.floats(0.01, 100.0))
@settings(max_examples=50, deadline=None)
def test_eta_scale_covariance(d, alpha):
    """η scales linearly in D·α (the theory's D-dependence)."""
    cfg1 = AdaSEGConfig(g0=1.0, diameter=d, alpha=alpha, k=1)
    cfg2 = AdaSEGConfig(g0=1.0, diameter=2 * d, alpha=alpha, k=1)
    s = jnp.float32(3.7)
    np.testing.assert_allclose(
        2 * float(eta_of(cfg1, s)), float(eta_of(cfg2, s)), rtol=1e-6
    )


# --- Worker-schedule properties ---------------------------------------------
#
# Every WorkerSchedule must be (a) reproducible from its config alone — the
# engines never store the (R, M) table, they re-derive it, which is what
# makes checkpoint/resume (sync round counter, async event queue) bit-exact
# — and (b) bounded by max_steps, the static scan length both engines pad
# to (a larger entry would silently truncate local work while still being
# counted).

@st.composite
def _schedules(draw):
    m = draw(st.integers(1, 8))
    k = draw(st.integers(1, 64))
    seed = draw(st.integers(0, 2**31 - 1))
    kind = draw(st.sampled_from(["uniform", "fixed", "straggler", "elastic"]))
    if kind == "uniform":
        sched = UniformSchedule(k)
    elif kind == "fixed":
        sched = FixedSchedule(tuple(
            draw(st.lists(st.integers(1, 64), min_size=m, max_size=m))))
    elif kind == "straggler":
        slow = draw(st.lists(st.integers(0, m - 1), max_size=m, unique=True))
        sched = StragglerSchedule(
            k=k, min_frac=draw(st.floats(0.05, 1.0, allow_nan=False)),
            seed=seed, slow_workers=tuple(slow))
    else:
        sched = ElasticSchedule(
            UniformSchedule(k), dropout=draw(st.floats(0.0, 1.0,
                                                       allow_nan=False)),
            seed=seed)
    return sched, m, draw(st.integers(1, 30))


@given(_schedules())
@settings(max_examples=80, deadline=None)
def test_schedule_reproducible_and_bounded(case):
    sched, m, rounds = case
    a = sched.steps(m, rounds)
    b = sched.steps(m, rounds)          # re-derived, as resume does
    np.testing.assert_array_equal(a, b)
    assert a.shape == (rounds, m)
    assert np.issubdtype(a.dtype, np.integer)
    assert (a >= 0).all()
    assert (a <= sched.max_steps(m)).all()


# --- ClientSampler properties ------------------------------------------------
#
# Like schedules, the sampling tables must be reproducible from the config
# alone (the engines re-derive them on resume; the checkpoint only carries a
# fingerprint) and exact: every round draws exactly M distinct workers of N,
# rows sorted ascending — the documented participation order.

@st.composite
def _samplers(draw):
    n = draw(st.integers(1, 12))
    sample = draw(st.integers(1, n))
    seed = draw(st.integers(0, 2**31 - 1))
    weights = None
    if draw(st.booleans()):
        weights = tuple(draw(st.lists(
            st.floats(0.1, 10.0, allow_nan=False),
            min_size=n, max_size=n)))
    return ClientSampler(sample=sample, seed=seed, weights=weights), n, \
        draw(st.integers(1, 20))


@given(_samplers())
@settings(max_examples=80, deadline=None)
def test_sampler_reproducible_and_exactly_m_of_n(case):
    sampler, n, rounds = case
    a = sampler.draws(n, rounds)
    b = sampler.draws(n, rounds)        # re-derived, as resume does
    np.testing.assert_array_equal(a, b)
    assert a.shape == (rounds, sampler.sample)
    assert a.dtype == np.int32
    for row in a:
        ids = row.tolist()
        assert len(set(ids)) == sampler.sample      # without replacement
        assert ids == sorted(ids)                   # ascending
        assert 0 <= min(ids) and max(ids) < n
    mask = sampler.participation(n, rounds)
    assert mask.shape == (rounds, n)
    assert (mask.sum(axis=1) == sampler.sample).all()


@given(_samplers())
@settings(max_examples=30, deadline=None)
def test_sampler_fingerprint_separates_laws(case):
    sampler, _, _ = case
    bumped = ClientSampler(sample=sampler.sample, seed=sampler.seed + 1,
                           weights=sampler.weights)
    assert sampler.fingerprint == ClientSampler(
        sample=sampler.sample, seed=sampler.seed,
        weights=sampler.weights).fingerprint
    assert sampler.fingerprint != bumped.fingerprint


def test_sampler_weighted_marginals():
    """Weighted draws match the requested marginals: with sample=1 the
    inclusion probability is exactly w/Σw, so empirical frequencies over
    many rounds converge to it. (Deterministic — one fixed seed, enough
    rounds that a law change trips the tolerance.)"""
    w = (1.0, 2.0, 4.0, 8.0)
    sampler = ClientSampler(sample=1, seed=0, weights=w)
    rounds = 6000
    hits = np.bincount(sampler.draws(4, rounds).ravel(), minlength=4)
    freq = hits / rounds
    expect = np.asarray(w) / sum(w)
    np.testing.assert_allclose(freq, expect, atol=0.02)


# --- Byzantine attack-table properties ---------------------------------------

@st.composite
def _attacks(draw):
    from repro.ps import (
        CollusionAttack,
        ScaledNoiseAttack,
        SignFlipAttack,
        ZeroAttack,
    )

    cls = draw(st.sampled_from([SignFlipAttack, ScaledNoiseAttack,
                                ZeroAttack, CollusionAttack]))
    policy = cls(
        fraction=draw(st.floats(0.0, 1.0, allow_nan=False)),
        seed=draw(st.integers(0, 2**31 - 1)),
        per_round=draw(st.booleans()),
    )
    return policy, draw(st.integers(1, 16)), draw(st.integers(1, 20))


@given(_attacks())
@settings(max_examples=80, deadline=None)
def test_byzantine_table_reproducible_shaped_and_bounded(case):
    """The attack-membership law the engines (and checkpoint resume)
    rely on: ``attacked`` is a pure function of (seed, fraction,
    per_round) with shape (rounds, workers), and every round corrupts
    exactly ``count(m) = min(m, round(fraction·m))`` workers — the
    configured attack fraction is a hard bound, not an expectation."""
    policy, workers, rounds = case
    a = np.asarray(policy.attacked(workers, rounds))
    b = np.asarray(policy.attacked(workers, rounds))   # re-derived on resume
    np.testing.assert_array_equal(a, b)
    assert a.shape == (rounds, workers)
    assert a.dtype == bool
    want = policy.count(workers)
    assert want <= workers
    assert (a.sum(axis=1) == want).all()
    if not policy.per_round:
        # fixed conspiracy: the same subset every round
        assert (a == a[0]).all()


@given(_attacks())
@settings(max_examples=30, deadline=None)
def test_byzantine_fingerprint_separates_laws(case):
    policy, _, _ = case
    import dataclasses as dc

    same = dc.replace(policy)
    bumped = dc.replace(policy, seed=policy.seed + 1)
    assert policy.fingerprint == same.fingerprint
    assert policy.fingerprint != bumped.fingerprint
    assert policy.name == same.name


# --- HLO parser properties ---------------------------------------------------

def test_iota_replica_groups_decode():
    assert _decode_groups("replica_groups=[2,2]<=[4]") == [[0, 1], [2, 3]]
    assert _decode_groups("replica_groups=[2,2]<=[2,2]T(1,0)") == [
        [0, 2], [1, 3]
    ]
    assert _decode_groups("replica_groups={{0,1},{2,3}}") == [[0, 1], [2, 3]]


@given(st.sampled_from([(2, 2), (4, 2), (2, 4), (4, 4)]))
@settings(max_examples=12, deadline=None)
def test_iota_decode_partitions_devices(shape):
    g, s = shape
    groups = _decode_groups(f"replica_groups=[{g},{s}]<=[{g*s}]")
    flat = sorted(d for grp in groups for d in grp)
    assert flat == list(range(g * s))        # exact partition
    assert all(len(grp) == s for grp in groups)


def test_classify_axes_abstract():
    """Axis classification against a mesh with known device layout."""
    import dataclasses

    class FakeDev:
        def __init__(self, i):
            self.id = i

    class FakeMesh:
        axis_names = ("data", "model")
        shape = {"data": 2, "model": 2}
        devices = np.array([[FakeDev(0), FakeDev(1)],
                            [FakeDev(2), FakeDev(3)]])

    mesh = FakeMesh()
    assert classify_axes([[0, 1], [2, 3]], mesh) == "model"
    assert classify_axes([[0, 2], [1, 3]], mesh) == "data"
    assert classify_axes([[0, 1, 2, 3]], mesh) == "data,model"
