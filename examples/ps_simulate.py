"""Parameter-Server fleet simulation: stragglers, 8-bit sync, faults, resume.

    PYTHONPATH=src python examples/ps_simulate.py

Runs LocalAdaSEG on the paper's §4.1 bilinear game through the PS runtime
(``repro.ps``) in a deliberately hostile fleet: Dirichlet-heterogeneous
worker data, a straggler schedule, per-round worker failures, and 8-bit
stochastically-quantized uplinks with error feedback. Mid-run the engine is
"killed" (checkpointed + discarded) and resumed from disk — the resumed
trajectory is the one an uninterrupted run would have produced.

The runtime is optimizer-generic: the same hostile fleet then runs a zoo
baseline (LocalSEGDA via ``MinimaxWorker``) for comparison — the paper's
Fig. 4 match-up, but under production conditions.
"""
import dataclasses
import os
import tempfile

import jax
import numpy as np

from repro.core import AdaSEGConfig
from repro.optim import MinimaxWorker, segda
from repro.problems import make_bilinear_game
from repro.ps import (
    BernoulliFaults,
    PSConfig,
    PSEngine,
    StochasticQuantizeCompressor,
    StragglerSchedule,
    heterogeneous_bilinear,
)

M, K, R = 4, 20, 30
N = 10


def main():
    game = make_bilinear_game(jax.random.PRNGKey(0), n=N, sigma=0.1)
    problem = heterogeneous_bilinear(game, M, jax.random.PRNGKey(1), alpha=0.4)
    pscfg = PSConfig(
        adaseg=AdaSEGConfig(g0=1.0, diameter=float(np.sqrt(2 * N)),
                            alpha=1.0, k=K),
        num_workers=M,
        rounds=R,
        schedule=StragglerSchedule(k=K, min_frac=0.5, seed=2,
                                   slow_workers=(3,)),
        compressor=StochasticQuantizeCompressor(bits=8),
        faults=BernoulliFaults(p=0.1, seed=3),
    )

    def fresh():
        return PSEngine(problem, pscfg, rng=jax.random.PRNGKey(4),
                        eval_fn=game.residual)

    with tempfile.TemporaryDirectory() as tmp:
        ckpt = os.path.join(tmp, "engine.msgpack")

        engine = fresh()
        engine.run(until_round=R // 2, checkpoint_path=ckpt,
                   checkpoint_every=5)
        print(f"ran {engine.round}/{R} rounds, 'crashed'; "
              f"checkpoint at {os.path.basename(ckpt)}")

        engine = fresh().restore(ckpt)        # new process, same config+seed
        zbar = engine.run()

    res = float(game.residual(zbar))
    tr = engine.trace                      # covers the resumed half
    print(f"resumed and finished at round {engine.round}")
    print(f"KKT residual:  {res:.4f}")
    print(f"since resume:  {tr.total_steps} local steps "
          f"(ideal {M * K * (R - R // 2)} — stragglers/faults ate the rest)")
    print(f"throughput:    {tr.steps_per_sec:,.0f} local steps/sec")
    print(f"bytes up:      {tr.total_bytes_up:,.0f} "
          f"(dense would be {tr.total_bytes_down:,.0f}, like the downlink)")
    for r in tr.rounds[:3]:
        print(f"  round {r.round:2d}: K={r.local_steps} alive={r.alive} "
              f"η∈[{r.eta_min:.3f},{r.eta_max:.3f}] res={r.residual:.4f}")

    # Same fleet, same policies — a Fig. 4 baseline through the same engine.
    zoo_cfg = dataclasses.replace(
        pscfg, adaseg=None, worker=MinimaxWorker(segda(0.05)), local_k=K)
    baseline = PSEngine(problem, zoo_cfg, rng=jax.random.PRNGKey(4),
                        eval_fn=game.residual)
    res_zoo = float(game.residual(baseline.run()))
    print(f"\nsame hostile fleet, LocalSEGDA (uniform averaging): "
          f"residual {res_zoo:.4f} vs LocalAdaSEG {res:.4f} "
          f"at {baseline.trace.steps_per_sec:,.0f} steps/sec")


if __name__ == "__main__":
    main()
