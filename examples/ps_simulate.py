"""Parameter-Server fleet simulation: stragglers, 8-bit sync, faults, resume.

    PYTHONPATH=src python examples/ps_simulate.py

Runs LocalAdaSEG on the paper's §4.1 bilinear game through the PS runtime
(``repro.ps``) in a deliberately hostile fleet: Dirichlet-heterogeneous
worker data, a straggler schedule, per-round worker failures, and 8-bit
stochastically-quantized uplinks with error feedback. Mid-run the engine is
"killed" (checkpointed + discarded) and resumed from disk — the resumed
trajectory is the one an uninterrupted run would have produced.

The runtime is optimizer-generic: the same hostile fleet then runs a zoo
baseline (LocalSEGDA via ``MinimaxWorker``) for comparison — the paper's
Fig. 4 match-up, but under production conditions.

The third act drops the barrier entirely: the *event-driven* engine
(``AsyncPSEngine``) runs the same algorithm over simulated time with one
Markov-slow worker and a τ=2 staleness bound, crashes mid-event-queue, and
resumes bit-exactly — admissions, simulated clock and all.

The final act makes the fleet *hostile* (``repro.ps.robust``): 20% of the
workers sign-flip their uplinks every round, the server swaps its weighted
mean for a trimmed-mean merge, and the run is killed and resumed
mid-attack — the resumed trajectory is bit-exact because the attack table,
like every other policy, is a deterministic function of its seed. The same
attacked fleet under the plain mean shows why the robust merge earns its
keep.

Both engines record ``repro.obs`` spans as they go; the script exports two
Perfetto/Chrome timelines next to itself (open them at
https://ui.perfetto.dev):

* ``perfetto_sync_wall.json``  — the synchronous run on the host wall clock;
* ``perfetto_async_sim.json``  — the τ=2 straggler run on the *simulated*
  clock, one swimlane per worker: uplink flights, staleness holds,
  broadcasts, local compute and the slow worker's long phases.
"""
import dataclasses
import math
import os
import tempfile

import jax
import numpy as np

from repro.core import AdaSEGConfig
from repro.obs import save_trace_events, validate_trace_events
from repro.optim import MinimaxWorker, segda
from repro.problems import make_bilinear_game
from repro.ps import (
    AsyncPSConfig,
    AsyncPSEngine,
    BernoulliFaults,
    MarkovLatency,
    PSConfig,
    PSEngine,
    SignFlipAttack,
    StochasticQuantizeCompressor,
    StragglerSchedule,
    TrimmedMean,
    heterogeneous_bilinear,
)

M, K, R = 4, 20, 30
N = 10


def main():
    game = make_bilinear_game(jax.random.PRNGKey(0), n=N, sigma=0.1)
    problem = heterogeneous_bilinear(game, M, jax.random.PRNGKey(1), alpha=0.4)
    pscfg = PSConfig(
        adaseg=AdaSEGConfig(g0=1.0, diameter=float(np.sqrt(2 * N)),
                            alpha=1.0, k=K),
        num_workers=M,
        rounds=R,
        schedule=StragglerSchedule(k=K, min_frac=0.5, seed=2,
                                   slow_workers=(3,)),
        compressor=StochasticQuantizeCompressor(bits=8),
        faults=BernoulliFaults(p=0.1, seed=3),
    )

    def fresh():
        return PSEngine(problem, pscfg, rng=jax.random.PRNGKey(4),
                        eval_fn=game.residual)

    with tempfile.TemporaryDirectory() as tmp:
        ckpt = os.path.join(tmp, "engine.msgpack")

        engine = fresh()
        engine.run(until_round=R // 2, checkpoint_path=ckpt,
                   checkpoint_every=5)
        print(f"ran {engine.round}/{R} rounds, 'crashed'; "
              f"checkpoint at {os.path.basename(ckpt)}")

        engine = fresh().restore(ckpt)        # new process, same config+seed
        zbar = engine.run()

    res = float(game.residual(zbar))
    tr = engine.trace                      # covers the resumed half
    print(f"resumed and finished at round {engine.round}")
    print(f"KKT residual:  {res:.4f}")
    print(f"since resume:  {tr.total_steps} local steps "
          f"(ideal {M * K * (R - R // 2)} — stragglers/faults ate the rest)")
    print(f"throughput:    {tr.steps_per_sec:,.0f} local steps/sec")
    print(f"bytes up:      {tr.total_bytes_up:,.0f} "
          f"(dense would be {tr.total_bytes_down:,.0f}, like the downlink)")
    for r in tr.rounds[:3]:
        print(f"  round {r.round:2d}: K={r.local_steps} alive={r.alive} "
              f"η∈[{r.eta_min:.3f},{r.eta_max:.3f}] res={r.residual:.4f}")

    # Same fleet, same policies — a Fig. 4 baseline through the same engine.
    zoo_cfg = dataclasses.replace(
        pscfg, adaseg=None, worker=MinimaxWorker(segda(0.05)), local_k=K)
    baseline = PSEngine(problem, zoo_cfg, rng=jax.random.PRNGKey(4),
                        eval_fn=game.residual)
    res_zoo = float(game.residual(baseline.run()))
    print(f"\nsame hostile fleet, LocalSEGDA (uniform averaging): "
          f"residual {res_zoo:.4f} vs LocalAdaSEG {res:.4f} "
          f"at {baseline.trace.steps_per_sec:,.0f} steps/sec")

    # Wall-clock timeline of the resumed synchronous run.
    out = os.path.join(os.path.dirname(__file__), "perfetto_sync_wall.json")
    validate_trace_events(save_trace_events(out, engine.tracer, clock="wall"))
    print(f"wall-clock Perfetto trace -> {out} "
          f"({len(engine.tracer.spans)} spans; open at ui.perfetto.dev)")

    async_demo(game, problem)
    hostile_demo(game)


def hostile_demo(game):
    """The fleet turns adversarial: 20% sign-flip uplinks vs a trimmed-mean
    server, with a crash and a bit-exact resume *mid-attack* — the attack
    table re-derives from its seed like every other policy."""
    m, rounds, k = 10, 12, 4
    byz = SignFlipAttack(fraction=0.2, scale=8.0, seed=11)
    robust_cfg = PSConfig(
        adaseg=AdaSEGConfig(g0=1.0, diameter=float(np.sqrt(2 * N)),
                            alpha=1.0, k=k),
        num_workers=m, rounds=rounds,
        byzantine=byz, aggregator=TrimmedMean(beta=0.2),
    )

    def fresh(cfg):
        return PSEngine(game.problem, cfg, rng=jax.random.PRNGKey(4),
                        eval_fn=game.residual)

    reference = fresh(robust_cfg)
    z_ref = reference.run()               # the uninterrupted hostile run

    with tempfile.TemporaryDirectory() as tmp:
        ckpt = os.path.join(tmp, "hostile_engine.msgpack")
        engine = fresh(robust_cfg)
        engine.run(until_round=rounds // 2)
        engine.save(ckpt)
        attacked_so_far = sum(
            len(r.byzantine_workers) for r in engine.trace.rounds)
        print(f"\n-- hostile: 'crashed' at round {engine.round} with "
              f"{attacked_so_far} corrupted uplinks already admitted "
              f"({byz.name})")
        engine = fresh(robust_cfg).restore(ckpt)
        zbar = engine.run()

    exact = all(
        np.array_equal(np.asarray(a), np.asarray(b))
        for a, b in zip(jax.tree.leaves(z_ref), jax.tree.leaves(zbar))
    )
    res_robust = float(game.residual(zbar))
    print(f"-- hostile: resumed mid-attack, bit-exact with the "
          f"uninterrupted run: {exact}")

    clean = fresh(dataclasses.replace(robust_cfg, byzantine=None,
                                      aggregator=None))
    res_clean = float(game.residual(clean.run()))
    mean = fresh(dataclasses.replace(robust_cfg, aggregator=None))
    res_mean = float(game.residual(mean.run()))
    print(f"   residuals: clean fleet {res_clean:.4f} | attacked, "
          f"trimmed-mean {res_robust:.4f} ({res_robust / res_clean:.2f}x) | "
          f"attacked, plain mean {res_mean:.4f} "
          f"({res_mean / res_clean:.2f}x — the mean never recovers)")
    last = engine.trace.rounds[-1]
    print(f"   final round corrupted workers: {last.byzantine_workers}, "
          f"server rejecting "
          f"{engine.aggregator.reject_frac(m):.0%} of lanes per coordinate")


def async_demo(game, problem):
    """No barrier: the event-driven engine over simulated time — one
    Markov-slow worker, τ=2 bounded staleness, and a mid-event-queue crash
    with bit-exact resume."""
    acfg = AsyncPSConfig(
        adaseg=AdaSEGConfig(g0=1.0, diameter=float(np.sqrt(2 * N)),
                            alpha=1.0, k=K),
        num_workers=M,
        rounds=R,
        latency=MarkovLatency(step_s=1.0, slow_factor=8.0, p_slow=0.05,
                              p_recover=0.25, up_s=0.2, down_s=0.1,
                              seed=6, start_slow=(3,)),
        staleness_bound=2.0,
    )

    def fresh():
        return AsyncPSEngine(problem, acfg, rng=jax.random.PRNGKey(4),
                             eval_fn=game.residual)

    reference = fresh()
    z_ref = reference.run()               # the uninterrupted timeline

    # Simulated-clock timeline of the τ=2 straggler run: per-worker
    # swimlanes of uplink / held / broadcast / compute, server admissions
    # on their own lane.
    out = os.path.join(os.path.dirname(__file__), "perfetto_async_sim.json")
    validate_trace_events(
        save_trace_events(out, reference.tracer, clock="sim")
    )
    print(f"\nsim-clock Perfetto trace -> {out} "
          f"({len(reference.tracer.spans)} spans on "
          f"{len(reference.tracer.tracks())} tracks)")

    with tempfile.TemporaryDirectory() as tmp:
        ckpt = os.path.join(tmp, "async_engine.msgpack")
        engine = fresh()
        engine.run(until_time=reference.sim_time / 2)
        engine.save(ckpt)
        print(f"\n-- async: 'crashed' at simulated t={engine.sim_time:.1f}s "
              f"({engine.n_admissions} admissions in the books)")
        engine = fresh().restore(ckpt)    # event queue rebuilt from disk
        zbar = engine.run()

    exact = all(
        np.array_equal(np.asarray(a), np.asarray(b))
        for a, b in zip(jax.tree.leaves(z_ref), jax.tree.leaves(zbar))
    )
    tr = engine.trace
    print(f"-- async: resumed to completion at t={engine.sim_time:.1f}s, "
          f"bit-exact with the uninterrupted run: {exact}")
    print(f"   residual {float(game.residual(zbar)):.4f}, "
          f"fleet idle {engine.idle_fraction():.1%}, "
          f"max admitted staleness {tr.max_staleness} rounds")
    for r in tr.rounds[:3]:
        stale = [s if s is not None else "-" for s in r.staleness]
        print(f"   t={r.sim_time_s:7.2f}s  admitted="
              f"{[i for i, a in enumerate(r.alive) if a]} "
              f"staleness={stale} res={r.residual:.4f}")
    barrier = dataclasses.replace(acfg, staleness_bound=0.0)
    sync_ref = AsyncPSEngine(problem, barrier, rng=jax.random.PRNGKey(4),
                             eval_fn=game.residual)
    sync_ref.run()
    target = sync_ref.trace.summary()["final_residual"]
    # the resumed engine's trace covers only the second half; the reference
    # run holds the full residual-vs-time curve
    ttt = reference.trace.time_to_residual(target)
    if ttt is not None and not math.isinf(ttt):
        print(f"   τ=2 reached the barrier run's final residual at "
              f"t={ttt:.1f}s vs the barrier's t={sync_ref.sim_time:.1f}s")
    else:
        print(f"   barrier baseline finished at t={sync_ref.sim_time:.1f}s "
              f"with residual {target:.4f}")


if __name__ == "__main__":
    main()
