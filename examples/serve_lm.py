"""Serve a model: batched greedy decoding against the ring-buffer KV cache.

    PYTHONPATH=src python examples/serve_lm.py --arch gemma2-27b --tokens 32

Uses the reduced (smoke) variant of the chosen architecture so it runs on
CPU; the same ``decode_step`` is what ``repro.launch.serve`` lowers against
the production mesh for the decode_32k / long_500k shapes.

The production loop — **checkpoints → live traffic** — is closed against the
PS runtime: ``--ps-train PATH`` trains the tiny-lm demo config through
``PSEngine`` + ``ModelWorker`` and writes a mid-training checkpoint, and
``--ps-ckpt PATH`` restores that checkpoint into a fresh engine and serves
greedy decodes from its trained z̄ instead of stub init weights:

    PYTHONPATH=src python examples/serve_lm.py --ps-train /tmp/lm.ckpt
    PYTHONPATH=src python examples/serve_lm.py --ps-ckpt /tmp/lm.ckpt
"""
import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs import list_archs, smoke_config
from repro.core import AdaSEGConfig
from repro.models import (
    ModelWorker,
    decode_step,
    init_cache,
    init_model,
    make_lm_problem,
    tiny_lm_config,
)
from repro.models.transformer import encode
from repro.ps import PSConfig, PSEngine


def _demo_engine(*, rounds: int, workers: int, local_k: int):
    """The canonical tiny-lm training engine: ``--ps-ckpt`` must rebuild the
    exact engine that wrote the checkpoint (worker fingerprint + seed are
    validated on restore), so train and serve share this constructor."""
    cfg = tiny_lm_config()
    prob = make_lm_problem(cfg, batch=2, seq=8)
    worker = ModelWorker(AdaSEGConfig(g0=5.0, diameter=1.0, k=local_k),
                         arch=cfg.name)
    eng = PSEngine(
        prob,
        PSConfig(worker=worker, local_k=local_k, num_workers=workers,
                 rounds=rounds),
        rng=jax.random.PRNGKey(0),
    )
    return cfg, eng


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-0.5b", choices=list_archs())
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--tokens", type=int, default=32)
    ap.add_argument("--prompt-len", type=int, default=8)
    ap.add_argument("--ps-train", metavar="PATH", default=None,
                    help="train the tiny-lm demo config on the PS runtime "
                         "and write a checkpoint to PATH, then exit")
    ap.add_argument("--ps-ckpt", metavar="PATH", default=None,
                    help="serve from a PSEngine checkpoint written by "
                         "--ps-train instead of stub init weights")
    ap.add_argument("--ps-rounds", type=int, default=2,
                    help="rounds for the --ps-train/--ps-ckpt demo engine")
    args = ap.parse_args()

    if args.ps_train:
        _, eng = _demo_engine(rounds=args.ps_rounds, workers=2, local_k=2)
        eng.run(checkpoint_path=args.ps_train, checkpoint_every=1)
        print(f"trained tiny-lm for {eng.round} PS rounds -> "
              f"{args.ps_train}")
        return

    if args.ps_ckpt:
        cfg, eng = _demo_engine(rounds=args.ps_rounds, workers=2, local_k=2)
        eng.restore(args.ps_ckpt)
        params = eng.z_bar()
        print(f"serving tiny-lm from PS checkpoint {args.ps_ckpt} "
              f"(round {eng.round})")
    else:
        cfg = smoke_config(args.arch)
        params, _ = init_model(jax.random.PRNGKey(0), cfg)
    max_len = args.prompt_len + args.tokens
    cache = init_cache(cfg, args.batch, max_len=max_len)

    enc = None
    if cfg.is_encoder_decoder:
        frames = 0.1 * jax.random.normal(
            jax.random.PRNGKey(1), (args.batch, cfg.encoder_seq, cfg.d_model)
        )
        enc = encode(params, cfg, frames)
        print(f"encoded {cfg.encoder_seq} frontend frames")
    elif cfg.cross_attn_every:
        enc = 0.1 * jax.random.normal(
            jax.random.PRNGKey(1), (args.batch, cfg.encoder_seq, cfg.d_model)
        )
        print(f"conditioning on {cfg.encoder_seq} image patch embeddings")

    step = jax.jit(
        lambda p, t, pos, c: decode_step(p, cfg, t, pos, c, enc_states=enc)
    )
    prompt = jax.random.randint(
        jax.random.PRNGKey(2), (args.batch, args.prompt_len), 0, cfg.vocab_size
    )
    # prefill token-by-token (production prefill lowers the batched forward)
    tok = prompt[:, :1]
    for t in range(args.prompt_len):
        logits, cache = step(params, prompt[:, t:t + 1],
                             jnp.full((args.batch,), t, jnp.int32), cache)
    tok = jnp.argmax(logits[:, -1], axis=-1)[:, None].astype(jnp.int32)

    out = [tok]
    t0 = time.time()
    for i in range(args.tokens - 1):
        pos = jnp.full((args.batch,), args.prompt_len + i, jnp.int32)
        logits, cache = step(params, tok, pos, cache)
        tok = jnp.argmax(logits[:, -1], axis=-1)[:, None].astype(jnp.int32)
        out.append(tok)
    dt = time.time() - t0
    gen = jnp.concatenate(out, axis=1)
    print(f"{cfg.name} (reduced): generated {gen.shape} tokens "
          f"in {dt:.2f}s ({args.batch * (args.tokens-1) / dt:.1f} tok/s)")
    print(gen)


if __name__ == "__main__":
    main()
