"""Serve a model: batched greedy decoding against the ring-buffer KV cache.

    PYTHONPATH=src python examples/serve_lm.py --arch gemma2-27b --tokens 32

Uses the reduced (smoke) variant of the chosen architecture so it runs on
CPU; the same ``decode_step`` is what ``repro.launch.serve`` lowers against
the production mesh for the decode_32k / long_500k shapes.
"""
import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs import list_archs, smoke_config
from repro.models import decode_step, init_cache, init_model
from repro.models.transformer import encode


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-0.5b", choices=list_archs())
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--tokens", type=int, default=32)
    ap.add_argument("--prompt-len", type=int, default=8)
    args = ap.parse_args()

    cfg = smoke_config(args.arch)
    params, _ = init_model(jax.random.PRNGKey(0), cfg)
    max_len = args.prompt_len + args.tokens
    cache = init_cache(cfg, args.batch, max_len=max_len)

    enc = None
    if cfg.is_encoder_decoder:
        frames = 0.1 * jax.random.normal(
            jax.random.PRNGKey(1), (args.batch, cfg.encoder_seq, cfg.d_model)
        )
        enc = encode(params, cfg, frames)
        print(f"encoded {cfg.encoder_seq} frontend frames")
    elif cfg.cross_attn_every:
        enc = 0.1 * jax.random.normal(
            jax.random.PRNGKey(1), (args.batch, cfg.encoder_seq, cfg.d_model)
        )
        print(f"conditioning on {cfg.encoder_seq} image patch embeddings")

    step = jax.jit(
        lambda p, t, pos, c: decode_step(p, cfg, t, pos, c, enc_states=enc)
    )
    prompt = jax.random.randint(
        jax.random.PRNGKey(2), (args.batch, args.prompt_len), 0, cfg.vocab_size
    )
    # prefill token-by-token (production prefill lowers the batched forward)
    tok = prompt[:, :1]
    for t in range(args.prompt_len):
        logits, cache = step(params, prompt[:, t:t + 1],
                             jnp.full((args.batch,), t, jnp.int32), cache)
    tok = jnp.argmax(logits[:, -1], axis=-1)[:, None].astype(jnp.int32)

    out = [tok]
    t0 = time.time()
    for i in range(args.tokens - 1):
        pos = jnp.full((args.batch,), args.prompt_len + i, jnp.int32)
        logits, cache = step(params, tok, pos, cache)
        tok = jnp.argmax(logits[:, -1], axis=-1)[:, None].astype(jnp.int32)
        out.append(tok)
    dt = time.time() - t0
    gen = jnp.concatenate(out, axis=1)
    print(f"{args.arch} (reduced): generated {gen.shape} tokens "
          f"in {dt:.2f}s ({args.batch * (args.tokens-1) / dt:.1f} tok/s)")
    print(gen)


if __name__ == "__main__":
    main()
