"""Train a WGAN-GP on the 8-mode Gaussian mixture with LocalAdaSEG
(paper §4.2, offline proxy — see DESIGN.md §7 for metric substitutions).

    PYTHONPATH=src python examples/wgan_train.py
    PYTHONPATH=src python examples/wgan_train.py --hetero --alpha 0.3

--hetero partitions the mixture modes across workers with a Dirichlet(α)
prior (the paper's federated-GAN setting, Fig. E3–E5).
"""
import argparse

import jax
import jax.numpy as jnp

from repro.core import AdaSEGConfig, run_local_adaseg
from repro.problems import make_wgan_problem


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--workers", type=int, default=4)
    ap.add_argument("--k-local", type=int, default=20)
    ap.add_argument("--rounds", type=int, default=10)
    ap.add_argument("--rounds-total", type=int, default=50)
    ap.add_argument("--hetero", action="store_true")
    ap.add_argument("--alpha", type=float, default=0.6)
    args = ap.parse_args()

    wg = make_wgan_problem(jax.random.PRNGKey(0))
    problem = wg.problem
    if args.hetero:
        from benchmarks.bench_wgan import _dirichlet_mode_logits, _heterogeneous

        logits = _dirichlet_mode_logits(
            jax.random.PRNGKey(7), args.alpha, args.workers
        )
        problem = _heterogeneous(problem, wg, logits)
        print(f"heterogeneous: Dirichlet(α={args.alpha}) mode weights/worker")

    cfg = AdaSEGConfig(g0=50.0, diameter=1.0, alpha=1.0, k=args.k_local,
                       average_output=False)
    eval_rng = jax.random.PRNGKey(99)
    for r in range(args.rounds, args.rounds_total + 1, args.rounds):
        z, _ = run_local_adaseg(
            problem, cfg, num_workers=args.workers, rounds=r,
            rng=jax.random.PRNGKey(1),
        )
        w_est = float(wg.wasserstein_estimate(z, eval_rng))
        md = float(wg.moment_distance(z, eval_rng))
        print(f"rounds {r:3d}: W-estimate = {w_est:+.4f}   "
              f"moment-distance = {md:.4f}")
    samples = wg.generate(z[0], jax.random.PRNGKey(3), 8)
    print("generated samples (first 8):")
    print(jnp.round(samples, 2))


if __name__ == "__main__":
    main()
