"""Train a WGAN-GP on the 8-mode Gaussian mixture with LocalAdaSEG — through
the Parameter-Server runtime (paper §4.2, offline proxy — see DESIGN.md §7
for metric substitutions).

    PYTHONPATH=src python examples/wgan_train.py
    PYTHONPATH=src python examples/wgan_train.py --hetero --alpha 0.3
    PYTHONPATH=src python examples/wgan_train.py --q8

The generator/discriminator minimax game runs as a ``repro.ps.ModelWorker``
on ``PSEngine`` — the same engine code path as the transformer LM and the
synthetic zoo, so ``--q8`` error-feedback compression, schedules, faults and
mid-stream checkpointing all apply. The engine is driven *incrementally*
(``run(until_round=r)``), evaluating the Wasserstein estimate on the global
output iterate z̄ (Line 14) as training progresses.

--hetero partitions the mixture modes across workers with a Dirichlet(α)
prior (the paper's federated-GAN setting, Fig. E3–E5).
"""
import argparse

import jax
import jax.numpy as jnp

from repro.core import AdaSEGConfig
from repro.problems import make_wgan_problem
from repro.ps import ModelWorker, PSConfig, PSEngine, StochasticQuantizeCompressor


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--workers", type=int, default=4)
    ap.add_argument("--k-local", type=int, default=20)
    ap.add_argument("--rounds", type=int, default=10)
    ap.add_argument("--rounds-total", type=int, default=50)
    ap.add_argument("--hetero", action="store_true")
    ap.add_argument("--alpha", type=float, default=0.6)
    ap.add_argument("--q8", action="store_true",
                    help="q8 stochastic-quantize uplinks + error feedback")
    args = ap.parse_args()

    wg = make_wgan_problem(jax.random.PRNGKey(0))
    problem = wg.problem
    if args.hetero:
        from benchmarks.bench_wgan import _dirichlet_mode_logits, _heterogeneous

        logits = _dirichlet_mode_logits(
            jax.random.PRNGKey(7), args.alpha, args.workers
        )
        problem = _heterogeneous(problem, wg, logits)
        print(f"heterogeneous: Dirichlet(α={args.alpha}) mode weights/worker")

    cfg = AdaSEGConfig(g0=50.0, diameter=1.0, alpha=1.0, k=args.k_local,
                       average_output=False)
    worker = ModelWorker(cfg, arch=problem.name)
    eval_rng = jax.random.PRNGKey(99)
    engine = PSEngine(
        problem,
        PSConfig(
            worker=worker, local_k=args.k_local,
            num_workers=args.workers, rounds=args.rounds_total,
            compressor=(StochasticQuantizeCompressor(bits=8) if args.q8
                        else None),
        ),
        rng=jax.random.PRNGKey(1),
        eval_fn=lambda z: wg.wasserstein_estimate(z, eval_rng),
    )
    for r in range(args.rounds, args.rounds_total + 1, args.rounds):
        z = engine.run(until_round=r)
        w_est = float(wg.wasserstein_estimate(z, eval_rng))
        md = float(wg.moment_distance(z, eval_rng))
        print(f"rounds {r:3d}: W-estimate = {w_est:+.4f}   "
              f"moment-distance = {md:.4f}")
    samples = wg.generate(engine.z_bar()[0], jax.random.PRNGKey(3), 8)
    print("generated samples (first 8):")
    print(jnp.round(samples, 2))


if __name__ == "__main__":
    main()
