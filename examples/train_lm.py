"""End-to-end driver: train a language model with LocalAdaSEG.

    PYTHONPATH=src python examples/train_lm.py                     # ~20M model
    PYTHONPATH=src python examples/train_lm.py --preset 100m --rounds 40
    PYTHONPATH=src python examples/train_lm.py --arch mamba2-370m --smoke

Uses the full production stack: ArchConfig model zoo, synthetic Markov-Zipf
pipeline, the distributed LocalAdaSEG round function (M workers × K local
extragradient steps + weighted sync), and msgpack checkpointing. On CPU the
mesh is 1×1; on a real slice the same TrainPlan lowers against the
production mesh (see repro/launch/dryrun.py).
"""
import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp

from repro.checkpoint import save_pytree
from repro.configs import get_config, smoke_config
from repro.core.adaseg import AdaSEGConfig
from repro.launch.mesh import make_test_mesh
from repro.launch.train import (
    TrainPlan,
    init_train_state,
    make_batches,
    make_round_fn,
)

PRESETS = {
    # name: (layers, d_model, heads, kv, d_ff, vocab) — ~20M / ~100M params
    "20m": (8, 384, 6, 2, 1536, 8192),
    "100m": (12, 768, 12, 4, 3072, 16384),
}


def build_config(args):
    if args.arch:
        return smoke_config(args.arch) if args.smoke else get_config(args.arch)
    layers, dm, h, kv, ff, vocab = PRESETS[args.preset]
    base = get_config("qwen2-0.5b")  # dense GQA family
    return dataclasses.replace(
        base, name=f"lm-{args.preset}", num_layers=layers, d_model=dm,
        num_heads=h, num_kv_heads=kv, d_ff=ff, vocab_size=vocab,
        head_dim=dm // h, max_seq_len=args.seq,
    )


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--preset", default="20m", choices=sorted(PRESETS))
    ap.add_argument("--arch", default=None, help="use a zoo architecture")
    ap.add_argument("--smoke", action="store_true",
                    help="reduced variant of --arch")
    ap.add_argument("--rounds", type=int, default=20)
    ap.add_argument("--k-local", type=int, default=5)
    ap.add_argument("--workers", type=int, default=2)
    ap.add_argument("--batch", type=int, default=8, help="global batch")
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--ckpt", default=None, help="checkpoint path")
    args = ap.parse_args()

    cfg = build_config(args)
    mesh = make_test_mesh(1, 1)
    plan = TrainPlan(
        cfg=cfg,
        adaseg=AdaSEGConfig(g0=20.0, diameter=2.0,
                            alpha=1.0 / args.workers**0.5,
                            k=args.k_local, average_output=False),
        worker_mode="paper",
        k_local=args.k_local,
        global_batch=args.batch * args.workers,
        seq=args.seq,
        workers_override=args.workers,
    )
    state = init_train_state(jax.random.PRNGKey(0), plan, mesh)
    n_params = sum(v.size for v in jax.tree.leaves(state.params)) // max(
        args.workers, 1)
    print(f"model {cfg.name}: {n_params/1e6:.1f}M params/worker, "
          f"M={args.workers} workers, K={plan.k_local}, "
          f"batch={plan.global_batch}×{plan.seq}")

    round_fn = jax.jit(make_round_fn(plan))
    t_start = time.time()
    for r in range(args.rounds):
        batches = make_batches(jax.random.PRNGKey(1000 + r), plan, mesh)
        state, metrics = round_fn(state, batches)
        loss = float(metrics["loss"].mean())
        eta = float(metrics["eta"].mean())
        print(f"round {r+1:3d}/{args.rounds}  loss={loss:.4f}  "
              f"mean η={eta:.5f}  t={int(state.t)}  "
              f"({time.time()-t_start:.1f}s)")
    if args.ckpt:
        save_pytree(args.ckpt, state)
        print(f"checkpoint written to {args.ckpt}")


if __name__ == "__main__":
    main()
