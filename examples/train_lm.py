"""End-to-end driver: train a language model with LocalAdaSEG — through the
Parameter-Server runtime (the unified stack).

    PYTHONPATH=src python examples/train_lm.py                     # ~20M model
    PYTHONPATH=src python examples/train_lm.py --preset 100m --rounds 40
    PYTHONPATH=src python examples/train_lm.py --arch mamba2-370m --smoke
    PYTHONPATH=src python examples/train_lm.py --q8 --pallas       # q8-EF uplinks
    PYTHONPATH=src python examples/train_lm.py --tau 1             # async SSP τ=1

The model (ArchConfig zoo + synthetic Markov-Zipf pipeline) runs as a
``repro.ps.ModelWorker`` on ``PSEngine`` via ``launch.train.make_ps_engine``:
M workers × K local extragradient steps (a ``lax.scan``), inverse-η weighted
sync, per-round telemetry, and msgpack checkpointing all come from the same
engine that drives the paper's bilinear/WGAN experiments. ``--tau`` switches
to the discrete-event ``AsyncPSEngine`` under bounded staleness; ``--q8``
compresses the uplinks with error feedback; ``--pallas`` puts the flash
attention kernel on the forward hot path.
"""
import argparse
import dataclasses
import time

import jax

from repro.configs import get_config, smoke_config
from repro.core.adaseg import AdaSEGConfig
from repro.launch.train import TrainPlan, make_ps_engine
from repro.ps import LognormalLatency, StochasticQuantizeCompressor

PRESETS = {
    # name: (layers, d_model, heads, kv, d_ff, vocab) — ~20M / ~100M params
    "20m": (8, 384, 6, 2, 1536, 8192),
    "100m": (12, 768, 12, 4, 3072, 16384),
}


def build_config(args):
    if args.arch:
        cfg = smoke_config(args.arch) if args.smoke else get_config(args.arch)
    else:
        layers, dm, h, kv, ff, vocab = PRESETS[args.preset]
        base = get_config("qwen2-0.5b")  # dense GQA family
        cfg = dataclasses.replace(
            base, name=f"lm-{args.preset}", num_layers=layers, d_model=dm,
            num_heads=h, num_kv_heads=kv, d_ff=ff, vocab_size=vocab,
            head_dim=dm // h, max_seq_len=args.seq,
        )
    if args.pallas:
        cfg = dataclasses.replace(cfg, attn_backend="pallas")
    return cfg


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--preset", default="20m", choices=sorted(PRESETS))
    ap.add_argument("--arch", default=None, help="use a zoo architecture")
    ap.add_argument("--smoke", action="store_true",
                    help="reduced variant of --arch")
    ap.add_argument("--rounds", type=int, default=20)
    ap.add_argument("--k-local", type=int, default=5)
    ap.add_argument("--workers", type=int, default=2)
    ap.add_argument("--batch", type=int, default=8, help="global batch")
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--hetero", action="store_true",
                    help="per-worker Markov-Zipf token distributions")
    ap.add_argument("--q8", action="store_true",
                    help="q8 stochastic-quantize uplinks + error feedback")
    ap.add_argument("--pallas", action="store_true",
                    help="flash-attention Pallas kernel on the hot path")
    ap.add_argument("--tau", type=float, default=None,
                    help="async engine with SSP staleness bound τ")
    ap.add_argument("--ckpt", default=None, help="checkpoint path")
    args = ap.parse_args()

    cfg = build_config(args)
    plan = TrainPlan(
        cfg=cfg,
        adaseg=AdaSEGConfig(g0=20.0, diameter=2.0,
                            alpha=1.0 / args.workers**0.5,
                            k=args.k_local, average_output=False),
        worker_mode="paper",
        k_local=args.k_local,
        global_batch=args.batch * args.workers,
        seq=args.seq,
        workers_override=args.workers,
    )
    engine = make_ps_engine(
        plan, jax.random.PRNGKey(0), rounds=args.rounds,
        hetero=args.hetero,
        compressor=StochasticQuantizeCompressor(bits=8) if args.q8 else None,
        latency=LognormalLatency(sigma=0.4) if args.tau is not None else None,
        staleness_bound=args.tau,
    )
    n_params = sum(
        v.size for v in jax.tree.leaves(engine.problem.init(
            jax.random.PRNGKey(0)))
    )
    mode = (f"async τ={args.tau}" if args.tau is not None else "sync")
    print(f"model {cfg.name}: {n_params/1e6:.1f}M params/worker, "
          f"M={args.workers} workers, K={plan.k_local}, "
          f"batch={plan.global_batch}×{plan.seq}, {mode}, "
          f"codec={'q8+EF' if args.q8 else 'identity'}")

    t_start = time.time()
    if args.tau is not None:
        engine.run()                       # drive the event queue to the end
        for rec in engine.trace.rounds:
            loss = ("-" if rec.residual is None else f"{rec.residual:.4f}")
            idle = ("-" if rec.idle_frac is None else f"{rec.idle_frac:.0%}")
            print(f"admission {rec.round:3d}  eval-loss={loss}  "
                  f"mean η={rec.eta_mean:.5f}  "
                  f"sim_t={rec.sim_time_s:.1f}s  idle={idle}")
    else:
        for r in range(1, args.rounds + 1):
            engine.run(until_round=r)
            rec = engine.trace.rounds[-1]
            print(f"round {r:3d}/{args.rounds}  "
                  f"eval-loss={rec.residual:.4f}  "
                  f"mean η={rec.eta_mean:.5f}  "
                  f"up={rec.bytes_up/1e6:.2f}MB  "
                  f"({time.time()-t_start:.1f}s)")
    if args.ckpt:
        engine.save(args.ckpt)
        print(f"checkpoint written to {args.ckpt}")


if __name__ == "__main__":
    main()
