"""Quickstart: solve a stochastic bilinear saddle game with LocalAdaSEG.

    PYTHONPATH=src python examples/quickstart.py

Builds the paper's §4.1 problem (min_x max_y xᵀAy + bᵀx + cᵀy over the box,
noisy oracle), runs LocalAdaSEG with M=4 workers × K=50 local steps, and
prints the KKT residual as rounds of communication proceed.
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import AdaSEGConfig, run_local_adaseg
from repro.problems import make_bilinear_game


def main():
    game = make_bilinear_game(jax.random.PRNGKey(0), n=10, sigma=0.1)
    cfg = AdaSEGConfig(
        g0=1.0,                        # guess of the gradient bound G
        diameter=float(np.sqrt(20.0)),  # D for the box [-1,1]^10 × [-1,1]^10
        alpha=1.0,                     # nonsmooth base lr (Theorem 1)
        k=50,                          # local steps between communications
    )
    z0 = game.problem.init(jax.random.PRNGKey(1))
    print(f"round  0: residual = {float(game.residual(z0)):.4f}  (init)")

    for rounds in (1, 2, 5, 10, 20):
        zbar, (state, _) = run_local_adaseg(
            game.problem, cfg, num_workers=4, rounds=rounds,
            rng=jax.random.PRNGKey(2),
        )
        res = float(game.residual(zbar))
        gap = float(game.duality_gap(zbar))
        eta = jnp.mean(
            cfg.diameter * cfg.alpha
            / jnp.sqrt(cfg.g0**2 + state.sum_sq)
        )
        print(f"round {rounds:2d}: residual = {res:.4f}  duality-gap = "
              f"{gap:.4f}  mean η = {float(eta):.4f}")


if __name__ == "__main__":
    main()
