"""Theorem 1/2/5 base-learning-rate regimes (paper §3.3 + Remark 6).

The paper prescribes α by problem class:
  * nonsmooth:  α = 1            (Theorem 1)
  * smooth:     α = 1/√M         (Theorem 2 — kills the M³ᐟ²/T terms)
  * smooth, V₁(T)-free: α = Tᵉ/√M (Theorem 5, any ε ∈ (0, ½), T ≥ M^(1/2ε))

This bench validates the prescriptions empirically: on the NONSMOOTH
bilinear game α=1 should win; on the SMOOTH quadratic α=1/√M should beat
α=1; the Theorem-5 α sits between (it trades a T^2ε factor for removing
V₁(T)). Also sweeps K per Remark 5 (K = Θ(√M·T^b) keeps communication
efficiency without hurting the rate).
"""
from __future__ import annotations

import time

import jax
import numpy as np

from repro.core import AdaSEGConfig, run_local_adaseg
from repro.problems import make_bilinear_game, make_quadratic_game

from .common import emit

M = 4


def theorem5_alpha(total_t: int, m: int, eps: float = 0.25) -> float:
    return total_t**eps / np.sqrt(m)


def run(seed: int = 0) -> dict:
    out = {}
    # --- nonsmooth (bilinear, box constraints): Theorem 1 says α = 1 -----
    game = make_bilinear_game(jax.random.PRNGKey(seed), n=10, sigma=0.1)
    d = float(np.sqrt(20.0))
    k, rounds = 50, 40
    t_total = k * rounds
    for name, alpha in (
        ("thm1_a1", 1.0),
        ("thm2_a1/sqrtM", 1.0 / np.sqrt(M)),
        ("thm5_aT^e/sqrtM", theorem5_alpha(t_total, M)),
    ):
        t0 = time.perf_counter()
        zbar, _ = run_local_adaseg(
            game.problem, AdaSEGConfig(g0=1.0, diameter=d, alpha=alpha, k=k),
            num_workers=M, rounds=rounds, rng=jax.random.PRNGKey(seed + 1),
        )
        res = float(game.residual(zbar))
        out[("bilinear", name)] = res
        emit(f"alpha[bilinear,{name}]", (time.perf_counter() - t0) * 1e6,
             f"residual={res:.4f};alpha={alpha:.3f}")

    # --- smooth (quadratic): Theorem 2 says α = 1/√M ---------------------
    qg = make_quadratic_game(jax.random.PRNGKey(seed + 7), n=10, sigma=0.1)
    for name, alpha in (
        ("thm1_a1", 1.0),
        ("thm2_a1/sqrtM", 1.0 / np.sqrt(M)),
        ("thm5_aT^e/sqrtM", theorem5_alpha(t_total, M)),
    ):
        t0 = time.perf_counter()
        zbar, _ = run_local_adaseg(
            qg.problem, AdaSEGConfig(g0=2.0, diameter=10.0, alpha=alpha, k=k),
            num_workers=M, rounds=rounds, rng=jax.random.PRNGKey(seed + 2),
        )
        dist = float(qg.distance_to_saddle(zbar))
        out[("quadratic", name)] = dist
        emit(f"alpha[quadratic,{name}]", (time.perf_counter() - t0) * 1e6,
             f"dist_to_saddle={dist:.4f};alpha={alpha:.3f}")

    # --- Remark 5: K = Θ(√M·T^b) keeps comm-efficiency at equal T --------
    for k_r5 in (10, int(np.sqrt(M) * t_total**0.4), 200):
        rounds_r5 = t_total // k_r5
        t0 = time.perf_counter()
        zbar, _ = run_local_adaseg(
            game.problem,
            AdaSEGConfig(g0=1.0, diameter=d, alpha=1.0, k=k_r5),
            num_workers=M, rounds=rounds_r5, rng=jax.random.PRNGKey(seed + 3),
        )
        res = float(game.residual(zbar))
        emit(f"alpha[remark5,K={k_r5}]", (time.perf_counter() - t0) * 1e6,
             f"residual={res:.4f};rounds={rounds_r5}")
    return out


def main() -> None:
    out = run()
    emit("alpha[check]", 0.0,
         f"smooth_prefers_small_alpha="
         f"{out[('quadratic','thm2_a1/sqrtM')] <= out[('quadratic','thm1_a1')] * 1.5}")


if __name__ == "__main__":
    main()
