"""§Perf hillclimbing driver — hypothesis → change → re-lower → measure.

Three chosen (arch × shape) pairs (see EXPERIMENTS.md §Perf for the
rationale and the recorded iteration log):

  moe   mixtral-8x22b × train_4k   — most collective-bound baseline
  vlm   llama-3.2-vision-11b × train_4k — involuntary-resharding victim
  sync  qwen2-0.5b × train_4k (paper mode) — the paper's own lever:
        worker-sync amortization vs K, paper vs hierarchical placement

Run (needs the 512-device env var BEFORE jax import, hence module main):

    PYTHONPATH=src python -m benchmarks.hillclimb --pair moe
"""
import os

if "XLA_FLAGS" not in os.environ:
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

import argparse
import dataclasses
import json
import time


def measure(plan, mesh, label):
    import jax

    from repro.launch.train import (
        abstract_batches,
        abstract_train_state,
        make_round_fn,
        make_shardings,
    )
    from repro.roofline.analysis import analyze_compiled

    round_fn = make_round_fn(plan)
    state_sh, batch_sh = make_shardings(plan, mesh)
    state = abstract_train_state(plan, mesh)
    batches = abstract_batches(plan, mesh)
    t0 = time.time()
    with mesh:
        lowered = jax.jit(
            round_fn, in_shardings=(state_sh, batch_sh),
            out_shardings=(state_sh, None),
        ).lower(state, batches)
        compiled = lowered.compile()
    rec = analyze_compiled(lowered, compiled, mesh)
    rec["label"] = label
    rec["compile_s"] = round(time.time() - t0, 1)
    print(f"[{label}] compile={rec['compile_s']}s "
          f"flops={rec['flops']:.3e} hbm={rec['hbm_bytes']:.3e} "
          f"coll={rec['collective_bytes']:.3e} bytes/dev="
          f"{rec['bytes_per_device']:.3e}")
    print(f"    by-axis: " + ", ".join(
        f"{a}={v:.3e}" for a, v in
        sorted(rec["collective_bytes_by_axis"].items())))
    print(f"    by-kind: " + ", ".join(
        f"{k}={v:.3e}" for k, v in
        sorted(rec["collective_bytes_by_kind"].items())))
    return rec


def pair_moe(mesh, out):
    from repro.launch.shapes import plan_for

    base = plan_for("mixtral-8x22b", "train_4k", mesh)
    out.append(measure(base, mesh, "moe/baseline"))
    # H1: experts (8) < model axis (16) → expert weights lost their 'model'
    # sharding → every step all-gathers full expert stacks over 'data'
    # (FSDP). repair_model places 'model' on d_ff: TP within expert.
    out.append(measure(
        dataclasses.replace(base, repair_model=True), mesh, "moe/repair_model"
    ))
    # H2: with weights TP'd, raising K amortizes nothing here (M=1 single
    # pod ⇒ no worker sync) — verify collective bytes scale ~linearly in K
    # (pure per-step traffic), i.e. the remaining term is FSDP/TP, not sync.
    out.append(measure(
        dataclasses.replace(base, repair_model=True, k_local=8),
        mesh, "moe/repair_model+k8",
    ))


def pair_vlm(mesh, out):
    from repro.launch.shapes import plan_for

    base = plan_for("llama-3.2-vision-11b", "train_4k", mesh)
    out.append(measure(base, mesh, "vlm/baseline"))
    # H1: 6404 patches not divisible by any mesh axis → GSPMD involuntarily
    # replicates cross-attn K/V. Pad to 6656 = 16·416 and shard over 'model'.
    out.append(measure(
        dataclasses.replace(base, frontend_pad_to=6656),
        mesh, "vlm/pad6656",
    ))
    # H2: pad to a 'data'-divisible count as well (6656 works for both 16s);
    # try 8192 (power of two, more padding waste but best layouts)
    out.append(measure(
        dataclasses.replace(base, frontend_pad_to=8192),
        mesh, "vlm/pad8192",
    ))


def pair_sync(mesh, out, multi_mesh=None):
    from repro.launch.shapes import plan_for

    for k in (1, 4, 16):
        plan = plan_for("qwen2-0.5b", "train_4k", mesh, k_local=k,
                        worker_mode="paper")
        out.append(measure(plan, mesh, f"sync/paper-K{k}"))
    if multi_mesh is not None:
        for mode in ("paper", "hierarchical"):
            plan = plan_for("qwen2-0.5b", "train_4k", multi_mesh, k_local=4,
                            worker_mode=mode)
            out.append(measure(plan, multi_mesh, f"sync/2pod-{mode}-K4"))


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--pair", required=True,
                    choices=("moe", "vlm", "sync"))
    ap.add_argument("--out", default=None)
    args = ap.parse_args()

    from repro.launch.mesh import make_production_mesh

    mesh = make_production_mesh(multi_pod=False)
    out = []
    if args.pair == "moe":
        pair_moe(mesh, out)
    elif args.pair == "vlm":
        pair_vlm(mesh, out)
    else:
        pair_sync(mesh, out, make_production_mesh(multi_pod=True))
    if args.out:
        with open(args.out, "w") as f:
            json.dump(out, f, indent=1)


if __name__ == "__main__":
    main()
