"""Parameter-Server runtime sweeps (beyond the paper's figures).

Three sweeps on the §4.1 bilinear game, all through ``repro.ps.PSEngine``:

* **compression** — identity vs 8/4-bit stochastic quantization vs top-25%
  sparsification of the uphill w·z̃ messages (error feedback on): KKT
  residual vs bytes shipped. Acceptance bar: ≥8-bit quantized sync stays
  within 2× of the uncompressed residual.
* **dropout** — Bernoulli per-round worker failures at p ∈ {0, 0.1, 0.3}
  with the Line-7 weights renormalized over survivors.
* **heterogeneity** — Dirichlet-skewed worker oracles (α ∈ {∞, 0.5, 0.1})
  plus a straggler schedule: the federated setting where local methods earn
  their keep.
"""
from __future__ import annotations

import time

import jax
import numpy as np

from repro.core import AdaSEGConfig
from repro.problems import make_bilinear_game
from repro.ps import (
    BernoulliFaults,
    IdentityCompressor,
    PSConfig,
    PSEngine,
    StochasticQuantizeCompressor,
    StragglerSchedule,
    TopKCompressor,
    heterogeneous_bilinear,
)

from .common import emit

M, K, R = 4, 20, 40
N = 10
D = float(np.sqrt(2 * N))


def _engine(problem, seed, *, schedule=None, compressor=None, faults=None,
            eval_fn=None):
    cfg = PSConfig(
        adaseg=AdaSEGConfig(g0=1.0, diameter=D, alpha=1.0, k=K),
        num_workers=M, rounds=R,
        schedule=schedule, compressor=compressor, faults=faults,
    )
    return PSEngine(problem, cfg, rng=jax.random.PRNGKey(seed + 1),
                    eval_fn=eval_fn)


def run(seed: int = 0) -> dict:
    game = make_bilinear_game(jax.random.PRNGKey(seed), n=N, sigma=0.1)
    out = {}

    compressors = [
        IdentityCompressor(),
        StochasticQuantizeCompressor(bits=8),
        StochasticQuantizeCompressor(bits=4),
        TopKCompressor(fraction=0.25),
    ]
    dense_up = None
    for comp in compressors:
        engine = _engine(game.problem, seed, compressor=comp)
        t0 = time.perf_counter()
        zbar = engine.run()
        dt = time.perf_counter() - t0
        res = float(game.residual(zbar))
        up = engine.trace.total_bytes_up
        if dense_up is None:
            dense_up = up
        out[comp.name] = res
        sps = engine.trace.steps_per_sec or 0.0
        emit(f"ps[compress,{comp.name}]", dt * 1e6,
             f"residual={res:.4f};bytes_up={up:.0f};"
             f"ratio={dense_up / max(up, 1.0):.2f}x;"
             f"steps_per_sec={sps:.0f}")

    for p_fail in (0.0, 0.1, 0.3):
        faults = BernoulliFaults(p=p_fail, seed=seed + 3) if p_fail else None
        engine = _engine(game.problem, seed, faults=faults)
        t0 = time.perf_counter()
        zbar = engine.run()
        dt = time.perf_counter() - t0
        res = float(game.residual(zbar))
        out[f"dropout-{p_fail}"] = res
        alive = sum(sum(r.alive) for r in engine.trace.rounds)
        sps = engine.trace.steps_per_sec or 0.0
        emit(f"ps[dropout,p={p_fail}]", dt * 1e6,
             f"residual={res:.4f};alive_worker_rounds={alive}/{M * R};"
             f"steps_per_sec={sps:.0f}")

    for alpha in (None, 0.5, 0.1):
        problem = game.problem if alpha is None else heterogeneous_bilinear(
            game, M, jax.random.PRNGKey(seed + 7), alpha=alpha
        )
        schedule = StragglerSchedule(k=K, min_frac=0.5, seed=seed + 5)
        engine = _engine(problem, seed, schedule=schedule)
        t0 = time.perf_counter()
        zbar = engine.run()
        dt = time.perf_counter() - t0
        res = float(game.residual(zbar))
        tag = "iid" if alpha is None else f"a={alpha}"
        out[f"hetero-{tag}"] = res
        sps = engine.trace.steps_per_sec or 0.0
        emit(f"ps[hetero,{tag}+stragglers]", dt * 1e6,
             f"residual={res:.4f};steps={engine.trace.total_steps};"
             f"steps_per_sec={sps:.0f}")

    return out


def main() -> None:
    out = run()
    emit("ps[check]", 0.0,
         f"q8_within_2x={out['q8'] < 2.0 * out['identity']};"
         f"dropout_degrades_gracefully={out['dropout-0.3'] < 4.0 * out['dropout-0.0']}")


if __name__ == "__main__":
    main()
