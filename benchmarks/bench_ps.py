"""Parameter-Server runtime sweeps (beyond the paper's figures).

Four sweeps through ``repro.ps.PSEngine``:

* **compression** — identity vs 8/4-bit stochastic quantization vs top-25%
  sparsification of the uphill w·z̃ messages (error feedback on): KKT
  residual vs bytes shipped, on the §4.1 bilinear game. Acceptance bar:
  ≥8-bit quantized sync stays within 2× of the uncompressed residual.
* **dropout** — Bernoulli per-round worker failures at p ∈ {0, 0.1, 0.3}
  with the Line-7 weights renormalized over survivors.
* **heterogeneity** — Dirichlet-skewed worker oracles (α ∈ {∞, 0.5, 0.1})
  plus a straggler schedule: the federated setting where local methods earn
  their keep.
* **codec backend** — reference tree-op sync codec vs the fused Pallas
  uplink/merge kernels (``codec_backend="fused"``) on the same
  1.25M-parameter pytree ``bench_kernels.bench_step_backends`` times, with
  the analytic HBM-pass counts of the ``kernels.sync_compress`` traffic
  model reported alongside (CPU interpret wall-times are not
  hardware-indicative; the pass counts are the meaningful number).
* **span overhead** — the same engine run with the ``repro.obs`` span/metric
  layer enabled vs disabled (``SpanTracer(enabled=False)`` is the
  timing-only shell), per-round chunks so every round records spans: the
  enabled/disabled wall ratio is the instrumentation tax, which the PR's
  acceptance bar caps at 5%.

Headline numbers persist to ``BENCH_ps.json`` via
:func:`benchmarks.common.persist_trajectory` for the CI regression gate.
"""
from __future__ import annotations

import statistics
import time

import jax
import numpy as np

from repro.core import AdaSEGConfig, projections
from repro.core.types import MinimaxProblem
from repro.kernels.sync_compress.ops import codec_passes
from repro.obs import MetricsRegistry, SpanTracer
from repro.problems import make_bilinear_game
from repro.ps import (
    BernoulliFaults,
    IdentityCompressor,
    PSConfig,
    PSEngine,
    StochasticQuantizeCompressor,
    StragglerSchedule,
    TopKCompressor,
    heterogeneous_bilinear,
)

from .common import emit, persist_trajectory

M, K, R = 4, 20, 40
N = 10
D = float(np.sqrt(2 * N))


def _engine(problem, seed, *, schedule=None, compressor=None, faults=None,
            eval_fn=None):
    cfg = PSConfig(
        adaseg=AdaSEGConfig(g0=1.0, diameter=D, alpha=1.0, k=K),
        num_workers=M, rounds=R,
        schedule=schedule, compressor=compressor, faults=faults,
    )
    return PSEngine(problem, cfg, rng=jax.random.PRNGKey(seed + 1),
                    eval_fn=eval_fn)


def run(seed: int = 0) -> dict:
    game = make_bilinear_game(jax.random.PRNGKey(seed), n=N, sigma=0.1)
    out = {}

    compressors = [
        IdentityCompressor(),
        StochasticQuantizeCompressor(bits=8),
        StochasticQuantizeCompressor(bits=4),
        TopKCompressor(fraction=0.25),
    ]
    dense_up = None
    for comp in compressors:
        engine = _engine(game.problem, seed, compressor=comp)
        t0 = time.perf_counter()
        zbar = engine.run()
        dt = time.perf_counter() - t0
        res = float(game.residual(zbar))
        up = engine.trace.total_bytes_up
        if dense_up is None:
            dense_up = up
        out[comp.name] = res
        sps = engine.trace.steps_per_sec or 0.0
        emit(f"ps[compress,{comp.name}]", dt * 1e6,
             f"residual={res:.4f};bytes_up={up:.0f};"
             f"ratio={dense_up / max(up, 1.0):.2f}x;"
             f"steps_per_sec={sps:.0f}")

    for p_fail in (0.0, 0.1, 0.3):
        faults = BernoulliFaults(p=p_fail, seed=seed + 3) if p_fail else None
        engine = _engine(game.problem, seed, faults=faults)
        t0 = time.perf_counter()
        zbar = engine.run()
        dt = time.perf_counter() - t0
        res = float(game.residual(zbar))
        out[f"dropout-{p_fail}"] = res
        alive = sum(sum(r.alive) for r in engine.trace.rounds)
        sps = engine.trace.steps_per_sec or 0.0
        emit(f"ps[dropout,p={p_fail}]", dt * 1e6,
             f"residual={res:.4f};alive_worker_rounds={alive}/{M * R};"
             f"steps_per_sec={sps:.0f}")

    for alpha in (None, 0.5, 0.1):
        problem = game.problem if alpha is None else heterogeneous_bilinear(
            game, M, jax.random.PRNGKey(seed + 7), alpha=alpha
        )
        schedule = StragglerSchedule(k=K, min_frac=0.5, seed=seed + 5)
        engine = _engine(problem, seed, schedule=schedule)
        t0 = time.perf_counter()
        zbar = engine.run()
        dt = time.perf_counter() - t0
        res = float(game.residual(zbar))
        tag = "iid" if alpha is None else f"a={alpha}"
        out[f"hetero-{tag}"] = res
        sps = engine.trace.steps_per_sec or 0.0
        emit(f"ps[hetero,{tag}+stragglers]", dt * 1e6,
             f"residual={res:.4f};steps={engine.trace.total_steps};"
             f"steps_per_sec={sps:.0f}")

    return out


def _bench_problem(n: int):
    """The 1.25M-param pytree of ``bench_kernels.bench_step_backends``:
    {x: (n,), y: (n/4,)} with a cheap linear oracle, so the timing isolates
    the sync machinery rather than the gradient."""

    def pinit(rng):
        r1, r2 = jax.random.split(rng)
        return {"x": 0.1 * jax.random.normal(r1, (n,)),
                "y": 0.1 * jax.random.normal(r2, (n // 4,))}

    def sample(rng):
        return jax.random.normal(rng, (2,))

    def oracle(z, xi):
        return jax.tree.map(lambda v: 0.3 * v + xi[0] * 1e-3, z)

    return MinimaxProblem(init=pinit, sample=sample, oracle=oracle,
                          project=projections.box(-1.0, 1.0), name="bench")


def run_codec_backends(seed: int = 0, n: int = 1 << 20, workers: int = 4,
                       rounds: int = 2, k: int = 2) -> dict:
    """Reference vs fused sync codec on the 1.25M-param pytree.

    One `ps[codec,...]` row per (codec, backend) with the median per-round
    wall time and the traffic model's HBM passes per uplink; a final
    summary row carries the speedups. CPU interpret mode executes the fused
    kernels as single jnp sweeps — indicative of fusion, not of TPU HBM
    bandwidth, which is what the pass counts model.
    """
    prob = _bench_problem(n)
    params = n + n // 4
    out = {}
    for comp in (StochasticQuantizeCompressor(bits=8),
                 TopKCompressor(fraction=0.1)):
        for backend in ("reference", "fused"):
            cfg = PSConfig(
                adaseg=AdaSEGConfig(g0=1.0, diameter=2.0, alpha=1.0, k=k),
                num_workers=workers, rounds=rounds, compressor=comp,
                codec_backend=backend,
            )
            engine = PSEngine(prob, cfg, rng=jax.random.PRNGKey(seed + 1))
            engine.step_round()                       # compile 1-round chunk
            t0 = time.perf_counter()
            # checkpoint_every=1 (no path) keeps every remaining chunk at
            # length 1, so the timed loop reuses the compiled chunk instead
            # of tracing a fresh (rounds-1)-length scan
            engine.run(checkpoint_every=1)
            dt = time.perf_counter() - t0
            per_round = dt / max(rounds - 1, 1) * 1e6
            out[(comp.name, backend)] = per_round
            ref_p, fused_p = codec_passes(comp.codec_spec)
            passes = ref_p if backend == "reference" else fused_p
            emit(f"ps[codec,{comp.name},{backend},params={params}]",
                 per_round,
                 f"hbm_passes_per_uplink={passes};"
                 f"pass_ratio_vs_ref={passes / ref_p:.2f}")
    for name in ("q8", "top0.1"):
        ref, fused = out[(name, "reference")], out[(name, "fused")]
        emit(f"ps[codec,{name},summary]", 0.0,
             f"wall_speedup_fused={ref / fused:.2f}x;"
             f"note=cpu_interpret_wall_not_hw_indicative")
    return out


def run_span_overhead(seed: int = 0, rounds: int = 60, reps: int = 5) -> dict:
    """Wall cost of the ``repro.obs`` span/metric layer on the main sweep's
    engine, worst-cased with one-round chunks (spans recorded every round).

    Order-balanced interleaved medians of (tracing-enabled,
    tracing-disabled) runs — the order flips every rep so cache/thermal
    drift doesn't bias one side; each engine warms its compiled one-round
    chunk before timing. Reported as the enabled/disabled ratio − 1 — the
    acceptance bar is < 5%.
    """
    game = make_bilinear_game(jax.random.PRNGKey(seed), n=N, sigma=0.1)

    def _timed_run(enabled: bool) -> float:
        cfg = PSConfig(
            adaseg=AdaSEGConfig(g0=1.0, diameter=D, alpha=1.0, k=K),
            num_workers=M, rounds=rounds,
        )
        engine = PSEngine(
            game.problem, cfg, rng=jax.random.PRNGKey(seed + 1),
            tracer=SpanTracer(enabled=enabled),
            metrics=MetricsRegistry(enabled=enabled),
        )
        engine.step_round()                       # compile one-round chunk
        t0 = time.perf_counter()
        engine.run(checkpoint_every=1)            # per-round chunks
        dt = time.perf_counter() - t0
        if enabled:
            # warmup round + the timed ones each recorded a round span
            assert len(engine.tracer.by_cat("round")) == rounds
        return dt / (rounds - 1)

    _timed_run(True)      # discard: first run pays one-time global jit
    on, off = [], []      # compiles (z_bar etc.), not instrumentation
    for i in range(reps):
        for enabled in ((True, False) if i % 2 == 0 else (False, True)):
            (on if enabled else off).append(_timed_run(enabled))
    per_on, per_off = statistics.median(on), statistics.median(off)
    overhead = per_on / per_off - 1.0
    emit(f"ps[span_overhead,rounds={rounds}]", per_on * 1e6,
         f"disabled_us={per_off * 1e6:.1f};overhead={overhead * 100:.2f}%;"
         f"within_5pct={overhead < 0.05}")
    return {"per_round_us_traced": per_on * 1e6,
            "per_round_us_untraced": per_off * 1e6,
            "overhead_frac": overhead}


def main() -> None:
    out = run()
    emit("ps[check]", 0.0,
         f"q8_within_2x={out['q8'] < 2.0 * out['identity']};"
         f"dropout_degrades_gracefully={out['dropout-0.3'] < 4.0 * out['dropout-0.0']}")
    codec = run_codec_backends()
    overhead = run_span_overhead()
    persist_trajectory("ps", {
        "residuals": out,
        "codec_per_round_us": {f"{c}/{b}": v for (c, b), v in codec.items()},
        "span_overhead": overhead,
    })


if __name__ == "__main__":
    main()
