"""Paper Fig. 4, but on the production runtime: the full optimizer zoo +
LocalAdaSEG through ONE PSEngine config under hostile-fleet conditions.

The paper's comparison (LocalAdaSEG vs LocalSGDA / LocalSEGDA / Local Adam
and the adaptive mirror-prox family) is run twice through the *same*
engine configuration:

* **clean**   — homogeneous data, uniform K, dense sync, no faults
  (the idealized Fig. 4 setting, engine edition);
* **hostile** — Dirichlet-heterogeneous worker data (α=0.4), a straggler
  schedule with per-round elastic dropout (K_m^r ∈ {0, …, K}), 8-bit
  stochastically-quantized uplinks with error feedback, and Bernoulli
  worker failures — the scenario the ROADMAP's north star demands and the
  one the pre-refactor zoo drivers could not express.

Every optimizer emits one telemetry row per scenario: residual, bytes up,
effective local steps, local-steps/sec and η spread, all from the engine's
per-round trace. Expected shape of the result: the adaptive methods
(LocalAdaSEG, local'ized UMP/ASMP) degrade more gracefully under the
hostile config than the fixed-lr baselines.

PR 9 grows the harness into an **adversarial matrix** over the
hostile-fleet subsystem: {iid, hetero+stragglers} × {dense, q8-EF} ×
Byzantine sign-flip fraction {0, 0.2} × server aggregator {weighted mean,
coordinate-median, trimmed-mean(0.2)}, LocalAdaSEG throughout. Headline
residuals persist to ``BENCH_fig4.json`` (gated by
``benchmarks/regress.py``), including the PR's acceptance ratios: under
20% sign-flip on the bilinear game the robust merges stay within 2× of
the clean fleet's final residual while the plain mean stalls.
"""
from __future__ import annotations

import jax
import numpy as np

from repro.core import AdaSEGConfig
from repro.optim import MinimaxWorker, adam_minimax, asmp, segda, sgda, ump
from repro.problems import make_bilinear_game
from repro.ps import (
    AsyncPSConfig,
    AsyncPSEngine,
    BernoulliFaults,
    ConstantLatency,
    CoordinateMedian,
    ElasticSchedule,
    PSConfig,
    PSEngine,
    ServerNesterov,
    SignFlipAttack,
    StochasticQuantizeCompressor,
    StragglerSchedule,
    TrimmedMean,
    heterogeneous_bilinear,
)

from .common import emit, persist_trajectory

M, K, R = 4, 20, 30
N = 10
D = float(np.sqrt(2 * N))


def _zoo():
    """Every baseline of §4/Fig. 4, engine-ready."""
    return {
        "LocalSGDA": MinimaxWorker(sgda(0.05)),
        "LocalSEGDA": MinimaxWorker(segda(0.05)),
        "LocalAdam": MinimaxWorker(adam_minimax(0.02)),
        "LocalUMP": MinimaxWorker(ump(1.0, D)),
        "LocalASMP": MinimaxWorker(asmp(1.0, D)),
    }


def _scenarios(seed: int) -> dict:
    hostile = dict(
        schedule=ElasticSchedule(
            StragglerSchedule(k=K, min_frac=0.5, seed=seed + 5,
                              slow_workers=(3,)),
            dropout=0.15, seed=seed + 6,
        ),
        compressor=StochasticQuantizeCompressor(bits=8),
        faults=BernoulliFaults(p=0.1, seed=seed + 3),
    )
    return {"clean": {}, "hostile": hostile}


def run(seed: int = 0) -> dict:
    game = make_bilinear_game(jax.random.PRNGKey(seed), n=N, sigma=0.1)
    results: dict = {}
    for scen_name, policies in _scenarios(seed).items():
        problem = (
            game.problem if scen_name == "clean"
            else heterogeneous_bilinear(game, M, jax.random.PRNGKey(seed + 7),
                                        alpha=0.4)
        )
        rows = {}

        def engine_for(**opt_kw):
            # One engine config for everyone — only the optimizer differs.
            cfg = PSConfig(num_workers=M, rounds=R, **opt_kw, **policies)
            return PSEngine(problem, cfg, rng=jax.random.PRNGKey(seed + 1),
                            trace_meta={"scenario": scen_name})

        engines = {"LocalAdaSEG": engine_for(
            adaseg=AdaSEGConfig(g0=1.0, diameter=D, alpha=1.0, k=K))}
        for name, worker in _zoo().items():
            engines[name] = engine_for(worker=worker, local_k=K)

        for name, engine in engines.items():
            res = float(game.residual(engine.run()))
            tr = engine.trace
            rows[name] = res
            sps = tr.steps_per_sec or 0.0
            eta_spread = max(r.eta_spread for r in tr.rounds)
            emit(
                f"fig4x[{scen_name},{name}]", tr.total_wall_time_s * 1e6,
                f"residual={res:.4f};steps={tr.total_steps};"
                f"bytes_up={tr.total_bytes_up:.0f};"
                f"steps_per_sec={sps:.0f};eta_spread={eta_spread:.2f}",
            )
        results[scen_name] = rows
    return results


# -- PR 9: the adversarial matrix -------------------------------------------

BM, BR, BK = 10, 12, 4          # matrix fleet: 20% sign-flip = 2 attackers


def _aggregators():
    return {
        "mean": None,
        "median": CoordinateMedian(),
        "trimmed": TrimmedMean(beta=0.2),
    }


def run_adversarial(seed: int = 0) -> dict:
    """The hostile-fleet matrix: every cell is one LocalAdaSEG run through
    the PS engine; rows are ``scenario.codec.attack.aggregator``."""
    game = make_bilinear_game(jax.random.PRNGKey(seed), n=N, sigma=0.1)
    datas = {
        "iid": (game.problem, {}),
        "hetero": (
            heterogeneous_bilinear(game, BM, jax.random.PRNGKey(seed + 7),
                                   alpha=0.4),
            {"schedule": StragglerSchedule(k=BK, min_frac=0.5,
                                           seed=seed + 5,
                                           slow_workers=(BM - 1,))},
        ),
    }
    codecs = {
        "dense": None,
        "q8ef": StochasticQuantizeCompressor(bits=8, error_feedback=True),
    }
    byz = SignFlipAttack(fraction=0.2, scale=8.0, seed=seed + 11)
    out: dict = {}
    for dname, (problem, policies) in datas.items():
        for cname, comp in codecs.items():
            cells = {}
            for aname, agg in _aggregators().items():
                # the mean runs clean AND attacked (the clean cell is the
                # matrix's reference — zero-budget robust cells would be
                # bit-identical to it); robust cells always face the attack
                for attack in ([None, byz] if aname == "mean" else [byz]):
                    cfg = PSConfig(
                        adaseg=AdaSEGConfig(g0=1.0, diameter=D, alpha=1.0,
                                            k=BK),
                        num_workers=BM, rounds=BR, byzantine=attack,
                        aggregator=agg, compressor=comp, **policies,
                    )
                    eng = PSEngine(problem, cfg,
                                   rng=jax.random.PRNGKey(seed + 1),
                                   trace_meta={"scenario": dname})
                    res = float(game.residual(eng.run()))
                    frac = 0.0 if attack is None else attack.fraction
                    key = f"{aname}_f{frac:g}"
                    cells[key] = {"residual": res,
                                  "bytes_up": eng.trace.total_bytes_up}
                    emit(f"fig4[{dname},{cname},{key}]",
                         eng.trace.total_wall_time_s * 1e6,
                         f"residual={res:.4f};"
                         f"bytes_up={eng.trace.total_bytes_up:.0f}")
            out[f"{dname}.{cname}"] = cells
    return out


def check_adversarial(matrix: dict) -> dict:
    """The PR's acceptance bar, computed from the iid/dense face of the
    matrix: robust merges within 2× of the clean fleet under 20%
    sign-flip; the plain mean is not."""
    face = matrix["iid.dense"]
    clean = face["mean_f0"]["residual"]
    checks = {
        "clean_residual": clean,
        "median_within_2x": face["median_f0.2"]["residual"] <= 2 * clean,
        "trimmed_within_2x": face["trimmed_f0.2"]["residual"] <= 2 * clean,
        "mean_stalls": face["mean_f0.2"]["residual"] > 2 * clean,
    }
    emit("fig4[check]", 0.0,
         ";".join(f"{k}={v}" for k, v in checks.items()))
    return checks


# -- PR 10: two-level optimization under hostility ---------------------------

def run_outer(seed: int = 0) -> dict:
    """Outer Nesterov vs plain 1/η merging — the ROADMAP item-2 question —
    under the full hostile stack: Dirichlet-heterogeneous data (α=0.4), a
    3× straggler on the async engine with bounded staleness τ=2, and a
    Byzantine sign-flip fraction ∈ {0, 0.2} behind a trimmed-mean(0.2)
    merge. Each cell is one LocalAdaSEG run; rows are
    ``f{fraction}.{plain|nesterov}`` with the final residual and the
    simulated time-to-target (first admission at or under the *plain*
    merge's final residual under the same attack — plain's own cell is
    its total simulated time by construction)."""
    game = make_bilinear_game(jax.random.PRNGKey(seed), n=N, sigma=0.1)
    problem = heterogeneous_bilinear(game, BM, jax.random.PRNGKey(seed + 7),
                                     alpha=0.4)
    latency = ConstantLatency(step_s=(1.0,) * (BM - 1) + (3.0,),
                              up_s=0.2, down_s=0.1)
    attacks = {
        "f0": None,
        "f0.2": SignFlipAttack(fraction=0.2, scale=8.0, seed=seed + 11),
    }
    # gentle momentum: heavy DiLoCo-style β=0.9 overshoots on the bilinear
    # saddle at this horizon; β=0.3 filters staleness noise without it
    servers = {
        "plain": None,
        "nesterov": ServerNesterov(lr=1.0, beta=0.3),
    }
    out: dict = {}
    for fname, attack in attacks.items():
        traces = {}
        for sname, server in servers.items():
            cfg = AsyncPSConfig(
                adaseg=AdaSEGConfig(g0=1.0, diameter=D, alpha=1.0, k=BK),
                num_workers=BM, rounds=BR,
                latency=latency, staleness_bound=2,
                byzantine=attack,
                aggregator=TrimmedMean(beta=0.2) if attack else None,
                server_opt=server,
            )
            eng = AsyncPSEngine(problem, cfg,
                                rng=jax.random.PRNGKey(seed + 1),
                                eval_fn=game.residual)
            res = float(game.residual(eng.run()))
            traces[sname] = (res, eng.trace)
        target = traces["plain"][0]
        for sname, (res, tr) in traces.items():
            ttt = tr.time_to_residual(target)
            out[f"{fname}.{sname}"] = {
                "residual": res,
                "sim_time_s": tr.sim_time_s,
                "time_to_plain_residual_s": ttt,
            }
            emit(f"fig4o[{fname},{sname}]",
                 tr.total_wall_time_s * 1e6,
                 f"residual={res:.4f};sim_time={tr.sim_time_s:.1f};"
                 f"ttt={ttt if ttt is None else round(ttt, 1)}")
    return out


def main() -> None:
    matrix = run_adversarial()
    checks = check_adversarial(matrix)
    assert checks["median_within_2x"] and checks["trimmed_within_2x"], checks
    assert checks["mean_stalls"], checks
    outer = run_outer()
    assert all(np.isfinite(c["residual"]) for c in outer.values()), outer
    persist_trajectory("fig4", {
        "matrix": matrix,
        "outer": outer,
        "workers": BM,
        "byzantine_fraction": 0.2,
    })
    results = run()
    clean, hostile = results["clean"], results["hostile"]
    finite = all(np.isfinite(v) for r in results.values() for v in r.values())
    adaptive = min(hostile["LocalAdaSEG"], hostile["LocalUMP"],
                   hostile["LocalASMP"])
    fixed = min(hostile["LocalSGDA"], hostile["LocalSEGDA"])
    emit("fig4x[check]", 0.0,
         f"all_finite={finite};"
         f"hostile_best_adaptive={adaptive:.4f};"
         f"hostile_best_fixed={fixed:.4f};"
         f"adaseg_clean={clean['LocalAdaSEG']:.4f};"
         f"adaseg_hostile={hostile['LocalAdaSEG']:.4f}")


if __name__ == "__main__":
    main()
