"""Paper Fig. 4, but on the production runtime: the full optimizer zoo +
LocalAdaSEG through ONE PSEngine config under hostile-fleet conditions.

The paper's comparison (LocalAdaSEG vs LocalSGDA / LocalSEGDA / Local Adam
and the adaptive mirror-prox family) is run twice through the *same*
engine configuration:

* **clean**   — homogeneous data, uniform K, dense sync, no faults
  (the idealized Fig. 4 setting, engine edition);
* **hostile** — Dirichlet-heterogeneous worker data (α=0.4), a straggler
  schedule with per-round elastic dropout (K_m^r ∈ {0, …, K}), 8-bit
  stochastically-quantized uplinks with error feedback, and Bernoulli
  worker failures — the scenario the ROADMAP's north star demands and the
  one the pre-refactor zoo drivers could not express.

Every optimizer emits one telemetry row per scenario: residual, bytes up,
effective local steps, local-steps/sec and η spread, all from the engine's
per-round trace. Expected shape of the result: the adaptive methods
(LocalAdaSEG, local'ized UMP/ASMP) degrade more gracefully under the
hostile config than the fixed-lr baselines.
"""
from __future__ import annotations

import jax
import numpy as np

from repro.core import AdaSEGConfig
from repro.optim import MinimaxWorker, adam_minimax, asmp, segda, sgda, ump
from repro.problems import make_bilinear_game
from repro.ps import (
    BernoulliFaults,
    ElasticSchedule,
    PSConfig,
    PSEngine,
    StochasticQuantizeCompressor,
    StragglerSchedule,
    heterogeneous_bilinear,
)

from .common import emit

M, K, R = 4, 20, 30
N = 10
D = float(np.sqrt(2 * N))


def _zoo():
    """Every baseline of §4/Fig. 4, engine-ready."""
    return {
        "LocalSGDA": MinimaxWorker(sgda(0.05)),
        "LocalSEGDA": MinimaxWorker(segda(0.05)),
        "LocalAdam": MinimaxWorker(adam_minimax(0.02)),
        "LocalUMP": MinimaxWorker(ump(1.0, D)),
        "LocalASMP": MinimaxWorker(asmp(1.0, D)),
    }


def _scenarios(seed: int) -> dict:
    hostile = dict(
        schedule=ElasticSchedule(
            StragglerSchedule(k=K, min_frac=0.5, seed=seed + 5,
                              slow_workers=(3,)),
            dropout=0.15, seed=seed + 6,
        ),
        compressor=StochasticQuantizeCompressor(bits=8),
        faults=BernoulliFaults(p=0.1, seed=seed + 3),
    )
    return {"clean": {}, "hostile": hostile}


def run(seed: int = 0) -> dict:
    game = make_bilinear_game(jax.random.PRNGKey(seed), n=N, sigma=0.1)
    results: dict = {}
    for scen_name, policies in _scenarios(seed).items():
        problem = (
            game.problem if scen_name == "clean"
            else heterogeneous_bilinear(game, M, jax.random.PRNGKey(seed + 7),
                                        alpha=0.4)
        )
        rows = {}

        def engine_for(**opt_kw):
            # One engine config for everyone — only the optimizer differs.
            cfg = PSConfig(num_workers=M, rounds=R, **opt_kw, **policies)
            return PSEngine(problem, cfg, rng=jax.random.PRNGKey(seed + 1),
                            trace_meta={"scenario": scen_name})

        engines = {"LocalAdaSEG": engine_for(
            adaseg=AdaSEGConfig(g0=1.0, diameter=D, alpha=1.0, k=K))}
        for name, worker in _zoo().items():
            engines[name] = engine_for(worker=worker, local_k=K)

        for name, engine in engines.items():
            res = float(game.residual(engine.run()))
            tr = engine.trace
            rows[name] = res
            sps = tr.steps_per_sec or 0.0
            eta_spread = max(r.eta_spread for r in tr.rounds)
            emit(
                f"fig4x[{scen_name},{name}]", tr.total_wall_time_s * 1e6,
                f"residual={res:.4f};steps={tr.total_steps};"
                f"bytes_up={tr.total_bytes_up:.0f};"
                f"steps_per_sec={sps:.0f};eta_spread={eta_spread:.2f}",
            )
        results[scen_name] = rows
    return results


def main() -> None:
    results = run()
    clean, hostile = results["clean"], results["hostile"]
    finite = all(np.isfinite(v) for r in results.values() for v in r.values())
    adaptive = min(hostile["LocalAdaSEG"], hostile["LocalUMP"],
                   hostile["LocalASMP"])
    fixed = min(hostile["LocalSGDA"], hostile["LocalSEGDA"])
    emit("fig4x[check]", 0.0,
         f"all_finite={finite};"
         f"hostile_best_adaptive={adaptive:.4f};"
         f"hostile_best_fixed={fixed:.4f};"
         f"adaseg_clean={clean['LocalAdaSEG']:.4f};"
         f"adaseg_hostile={hostile['LocalAdaSEG']:.4f}")


if __name__ == "__main__":
    main()
