"""Benchmark orchestrator — one harness per paper table/figure.

Emits ``name,us_per_call,derived`` CSV rows (see each bench module's
docstring for the figure it reproduces):

    fig3   bench_bilinear_ksweep      K/σ sweep on the bilinear game
    fig4   bench_bilinear_optimizers  optimizer-zoo comparison
    fig4x  bench_fig4_scenarios       the zoo + LocalAdaSEG on the PS engine
                                      under hetero/compression/dropout/faults
    figE1  bench_async                time-to-target: sync barrier vs
                                      bounded-staleness async (sim clock)
    extra  bench_ps                   PS runtime: compression/dropout/hetero
    extra  bench_ps_models            real-model ModelWorkers (tiny LM +
                                      WGAN) on the engine → BENCH_ps_models.json
    figE1d bench_vt_growth            V_t cumulative gradient growth
    figE2  bench_wgan                 WGAN-GP (homog + Dirichlet hetero)
    extra  bench_robust               robust logistic (beyond paper)
    extra  bench_kernels              kernel micro-benches + traffic models
    extra  bench_fleet                fleet scale: scan-vs-loop speedup,
                                      sampled-client sweep to 10k workers

``--only``/``--skip`` filter the sweep by substring of the bench label
(e.g. ``--skip fleet`` keeps the heavy fleet bench out of a quick local
run); the registry-completeness check always sees the full list.

The roofline/dry-run table is produced by ``repro.launch.dryrun`` +
``benchmarks/bench_roofline.py`` (it needs the 512-device env var and is
therefore a separate entry point).
"""
from __future__ import annotations

import argparse
import pathlib
import sys
import time
import traceback

#: bench modules with their own entry point (env-gated), exempt from the
#: registry-completeness check below
EXEMPT = {"bench_roofline"}


def _check_registry(benches) -> None:
    """Every ``bench_*.py`` in this directory must be wired into the
    orchestrator (or listed in EXEMPT) — a new bench that silently never
    runs is how perf trajectories go stale."""
    here = pathlib.Path(__file__).resolve().parent
    on_disk = {p.stem for p in here.glob("bench_*.py")}
    wired = {fn.__module__.rsplit(".", 1)[-1] for _, fn in benches}
    missing = on_disk - wired - EXEMPT
    if missing:
        raise RuntimeError(
            f"bench modules not in the run.py registry: {sorted(missing)} "
            "(add them to `benches` or to EXEMPT)"
        )


def registry() -> list:
    """The orchestrator's bench list: (label, entry point) per module."""
    from . import (
        bench_alpha_theory,
        bench_async,
        bench_bilinear_ksweep,
        bench_bilinear_optimizers,
        bench_fig4_scenarios,
        bench_fleet,
        bench_kernels,
        bench_ps,
        bench_ps_models,
        bench_robust,
        bench_vt_growth,
        bench_wgan,
    )

    return [
        ("fig3:bilinear_ksweep", bench_bilinear_ksweep.main),
        ("fig4:bilinear_optimizers", bench_bilinear_optimizers.main),
        ("fig4x:fig4_scenarios", bench_fig4_scenarios.main),
        ("figE1:async", bench_async.main),
        ("extra:ps_runtime", bench_ps.main),
        ("extra:ps_models", bench_ps_models.main),
        ("figE1d:vt_growth", bench_vt_growth.main),
        ("figE2-E5:wgan", bench_wgan.main),
        ("thm1-2-5:alpha_regimes", bench_alpha_theory.main),
        ("extra:robust_logistic", bench_robust.main),
        ("extra:kernels", bench_kernels.main),
        ("extra:fleet", bench_fleet.main),
    ]


def select(benches, only=None, skip=None) -> list:
    """Filter (label, fn) rows by substring: keep labels matching any
    ``--only`` term (all, when none given), then drop any matching a
    ``--skip`` term. Raises on a filter that matches nothing — a typo'd
    filter silently running everything (or nothing) is worse than an
    error."""
    out = benches
    if only:
        out = [row for row in out if any(t in row[0] for t in only)]
        if not out:
            raise SystemExit(f"--only {only} matches no bench label")
    if skip:
        dropped = [row for row in out if any(t in row[0] for t in skip)]
        if not dropped:
            raise SystemExit(f"--skip {skip} matches no bench label")
        out = [row for row in out if row not in dropped]
    return out


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description="run every benchmark harness")
    ap.add_argument("--json-dir", default=None,
                    help="redirect BENCH_*.json trajectory persistence "
                         "(default: repo root)")
    ap.add_argument("--only", action="append", default=None,
                    help="run only benches whose label contains this "
                         "substring (repeatable)")
    ap.add_argument("--skip", action="append", default=None,
                    help="skip benches whose label contains this substring "
                         "(repeatable), e.g. --skip fleet")
    args = ap.parse_args(argv)
    if args.json_dir is not None:
        from .common import set_json_dir

        set_json_dir(args.json_dir)

    benches = registry()
    # completeness check runs on the UNFILTERED registry: filtering is for
    # this invocation, wiring is forever
    _check_registry(benches)
    benches = select(benches, only=args.only, skip=args.skip)
    print("name,us_per_call,derived")
    failures = 0
    for name, fn in benches:
        t0 = time.perf_counter()
        try:
            fn()
            print(f"{name},{(time.perf_counter()-t0)*1e6:.0f},status=ok")
        except Exception as e:  # noqa: BLE001
            failures += 1
            print(f"{name},0,status=FAILED:{type(e).__name__}:{e}")
            traceback.print_exc()
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
