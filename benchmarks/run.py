"""Benchmark orchestrator — one harness per paper table/figure.

Emits ``name,us_per_call,derived`` CSV rows (see each bench module's
docstring for the figure it reproduces):

    fig3   bench_bilinear_ksweep      K/σ sweep on the bilinear game
    fig4   bench_bilinear_optimizers  optimizer-zoo comparison
    fig4x  bench_fig4_scenarios       the zoo + LocalAdaSEG on the PS engine
                                      under hetero/compression/dropout/faults
    figE1  bench_async                time-to-target: sync barrier vs
                                      bounded-staleness async (sim clock)
    extra  bench_ps                   PS runtime: compression/dropout/hetero
    extra  bench_ps_models            real-model ModelWorkers (tiny LM +
                                      WGAN) on the engine → BENCH_ps_models.json
    figE1d bench_vt_growth            V_t cumulative gradient growth
    figE2  bench_wgan                 WGAN-GP (homog + Dirichlet hetero)
    extra  bench_robust               robust logistic (beyond paper)
    extra  bench_kernels              kernel micro-benches + traffic models

The roofline/dry-run table is produced by ``repro.launch.dryrun`` +
``benchmarks/bench_roofline.py`` (it needs the 512-device env var and is
therefore a separate entry point).
"""
from __future__ import annotations

import sys
import time
import traceback


def main() -> int:
    from . import (
        bench_alpha_theory,
        bench_async,
        bench_bilinear_ksweep,
        bench_bilinear_optimizers,
        bench_fig4_scenarios,
        bench_kernels,
        bench_ps,
        bench_ps_models,
        bench_robust,
        bench_vt_growth,
        bench_wgan,
    )

    benches = [
        ("fig3:bilinear_ksweep", bench_bilinear_ksweep.main),
        ("fig4:bilinear_optimizers", bench_bilinear_optimizers.main),
        ("fig4x:fig4_scenarios", bench_fig4_scenarios.main),
        ("figE1:async", bench_async.main),
        ("extra:ps_runtime", bench_ps.main),
        ("extra:ps_models", bench_ps_models.main),
        ("figE1d:vt_growth", bench_vt_growth.main),
        ("figE2-E5:wgan", bench_wgan.main),
        ("thm1-2-5:alpha_regimes", bench_alpha_theory.main),
        ("extra:robust_logistic", bench_robust.main),
        ("extra:kernels", bench_kernels.main),
    ]
    print("name,us_per_call,derived")
    failures = 0
    for name, fn in benches:
        t0 = time.perf_counter()
        try:
            fn()
            print(f"{name},{(time.perf_counter()-t0)*1e6:.0f},status=ok")
        except Exception as e:  # noqa: BLE001
            failures += 1
            print(f"{name},0,status=FAILED:{type(e).__name__}:{e}")
            traceback.print_exc()
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
