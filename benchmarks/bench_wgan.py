"""Paper §4.2 / Fig. E2–E5 (proxy): WGAN-GP on the 8-mode Gaussian mixture,
homogeneous and heterogeneous (Dirichlet-partitioned modes per worker).

Metrics (no inception net offline — DESIGN.md §7): the Wasserstein critic
estimate E D(real) − E D(fake) and the data-space moment distance (the
FID formula applied in data space). Compared: LocalAdaSEG, MB-UMP, MB-ASMP,
LocalAdam — the four optimizers the paper keeps for its GAN figures.
"""
from __future__ import annotations

import dataclasses
import time

import jax
import jax.numpy as jnp

from repro.core import AdaSEGConfig, run_local_adaseg
from repro.optim import (
    MinimaxWorker,
    adam_minimax,
    asmp,
    minibatch,
    run_serial,
    ump,
)
from repro.problems import make_wgan_problem
from repro.ps import PSConfig, PSEngine, heterogeneous_wgan

from .common import emit

M, K, R = 4, 20, 40


def run(seed: int = 0, heterogeneous: bool = False, alpha: float = 0.6):
    wg = make_wgan_problem(jax.random.PRNGKey(seed))
    p = wg.problem
    tag = f"hetero(a={alpha})" if heterogeneous else "homog"
    if heterogeneous:
        p = heterogeneous_wgan(wg, M, jax.random.PRNGKey(seed + 9),
                               alpha=alpha)
    eval_rng = jax.random.PRNGKey(seed + 5)
    out = {}

    def scores(z):
        return (float(wg.wasserstein_estimate(z, eval_rng)),
                float(wg.moment_distance(z, eval_rng)))

    t0 = time.perf_counter()
    zbar, _ = run_local_adaseg(
        p, AdaSEGConfig(g0=50.0, diameter=1.0, alpha=1.0, k=K,
                        average_output=False),
        num_workers=M, rounds=R, rng=jax.random.PRNGKey(seed + 1),
    )
    out["LocalAdaSEG"] = scores(zbar) + ((time.perf_counter() - t0),)

    # centralized MB baselines see the MIXTURE of worker distributions
    p_central = p
    if heterogeneous:
        def mixed_sample(rng):
            r1, r2 = jax.random.split(rng)
            wid = jax.random.randint(r1, (), 0, M)
            return p.sample_worker(r2, wid)

        p_central = dataclasses.replace(p, sample=mixed_sample,
                                        sample_worker=None)

    for name, opt in (("MB-UMP", ump(50.0, 1.0)), ("MB-ASMP", asmp(50.0, 1.0))):
        mb = minibatch(p_central, M)  # modest minibatch to keep CPU time sane
        t0 = time.perf_counter()
        st, _ = run_serial(opt, mb, steps=R * K,
                           rng=jax.random.PRNGKey(seed + 2),
                           record_every=R * K)
        out[name] = scores(st.z) + ((time.perf_counter() - t0),)

    # engine in one chunk (history discarded anyway) — same trajectory/seed
    # as the historical run_local driver
    t0 = time.perf_counter()
    engine = PSEngine(
        p, PSConfig(num_workers=M, rounds=R,
                    worker=MinimaxWorker(adam_minimax(2e-3)), local_k=K),
        rng=jax.random.PRNGKey(seed + 3))
    engine.run()
    z_adam = jax.tree.map(lambda v: v[0], engine.state.z)
    out["LocalAdam"] = scores(z_adam) + ((time.perf_counter() - t0),)

    for name, (w_est, md, dt) in out.items():
        emit(f"wgan[{tag},{name}]", dt * 1e6,
             f"w_estimate={w_est:.4f};moment_dist={md:.4f};rounds={R}")
    return out


def main() -> None:
    homog = run(heterogeneous=False)
    het = run(heterogeneous=True, alpha=0.6)
    emit("wgan[check]", 0.0,
         f"adaseg_moment_homog={homog['LocalAdaSEG'][1]:.3f};"
         f"adaseg_moment_hetero={het['LocalAdaSEG'][1]:.3f}")


if __name__ == "__main__":
    main()
