"""Real-model ModelWorker sweep through the PS engine — the perf trajectory.

Trains a tiny dense transformer and the §4.2 WGAN-GP as
:class:`repro.ps.ModelWorker` fleets on :class:`repro.ps.PSEngine` (identity
and q8+error-feedback uplinks) and records throughput and traffic:

* ``steps_per_sec``       — effective local extragradient steps / wall s
* ``rounds_per_sec``      — communication rounds / wall s (post-compile)
* ``bytes_up_per_round``  — Σ survivor compressed uplink bytes
* ``bytes_down_per_round``— Σ survivor dense broadcast bytes

The sweep is *persisted*: every run appends an entry to
``BENCH_ps_models.json`` at the repo root via
:func:`benchmarks.common.persist_trajectory` (committed), so perf is
comparable across PRs and gated by ``benchmarks/regress.py``. Wall-clock
numbers are CPU-host indicative only; the bytes columns are exact.
"""
from __future__ import annotations

import time

import jax

from repro.core import AdaSEGConfig
from repro.models import ModelWorker, make_lm_problem, tiny_lm_config
from repro.problems import make_wgan_problem
from repro.ps import PSConfig, PSEngine, StochasticQuantizeCompressor

from .common import emit, persist_trajectory

M, ROUNDS, WARMUP = 2, 4, 1


def _sweep_cases():
    lm = make_lm_problem(tiny_lm_config(), batch=2, seq=16)
    lm_cfg = AdaSEGConfig(g0=20.0, diameter=2.0, alpha=1.0, k=2,
                          average_output=False)
    wg = make_wgan_problem(jax.random.PRNGKey(0))
    wg_cfg = AdaSEGConfig(g0=50.0, diameter=1.0, alpha=1.0, k=5,
                          average_output=False)
    for codec_name, codec in (("identity", None),
                              ("q8ef", StochasticQuantizeCompressor(bits=8))):
        yield (f"tiny-lm/{codec_name}", lm, lm_cfg, 2,
               "tiny-lm", codec)
        yield (f"wgan/{codec_name}", wg.problem, wg_cfg, 5,
               wg.problem.name, codec)


def _measure(name, problem, acfg, local_k, arch, compressor):
    worker = ModelWorker(acfg, arch=arch)
    engine = PSEngine(
        problem,
        PSConfig(worker=worker, local_k=local_k, num_workers=M,
                 rounds=WARMUP + ROUNDS, compressor=compressor),
        rng=jax.random.PRNGKey(1),
    )
    engine.run(until_round=WARMUP)          # compile + first-round warmup
    t0 = time.perf_counter()
    engine.run()
    dt = time.perf_counter() - t0
    recs = engine.trace.rounds[WARMUP:]
    steps = sum(sum(r.local_steps) for r in recs)
    result = {
        "steps_per_sec": round(steps / dt, 2),
        "rounds_per_sec": round(len(recs) / dt, 3),
        "bytes_up_per_round": sum(r.bytes_up for r in recs) / len(recs),
        "bytes_down_per_round": sum(r.bytes_down for r in recs) / len(recs),
        "workers": M,
        "local_k": local_k,
    }
    emit(f"ps_models:{name}", dt * 1e6 / len(recs),
         f"steps/s={result['steps_per_sec']};"
         f"up_B={result['bytes_up_per_round']:.0f}")
    return result


def main() -> None:
    results = {name: _measure(name, *rest) for name, *rest in _sweep_cases()}
    persist_trajectory("ps_models", results)


if __name__ == "__main__":
    main()
