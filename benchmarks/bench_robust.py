"""Beyond-paper experiment: distributionally-robust logistic regression — a
real convex-concave finite-sum minimax exercising the simplex projection.
LocalAdaSEG vs MB-SEGDA vs LocalSGDA at matched compute/communication.
"""
from __future__ import annotations

import time

import jax

from repro.core import AdaSEGConfig, kkt_residual, run_local_adaseg
from repro.optim import MinimaxWorker, minibatch, run_serial, segda, sgda
from repro.problems import make_robust_logistic
from repro.ps import PSConfig, PSEngine

from .common import emit

M, K, R = 4, 20, 30


def run(seed: int = 0) -> dict:
    rl = make_robust_logistic(jax.random.PRNGKey(seed))
    p = rl.problem
    out = {}

    t0 = time.perf_counter()
    zbar, _ = run_local_adaseg(
        p, AdaSEGConfig(g0=5.0, diameter=5.0, alpha=1.0, k=K),
        num_workers=M, rounds=R, rng=jax.random.PRNGKey(seed + 1),
    )
    out["LocalAdaSEG"] = (float(kkt_residual(p, zbar)),
                          float(rl.objective(zbar)),
                          time.perf_counter() - t0)

    t0 = time.perf_counter()
    st, _ = run_serial(segda(0.05), minibatch(p, K * M), steps=R,
                       rng=jax.random.PRNGKey(seed + 2), record_every=R)
    out["MB-SEGDA"] = (float(kkt_residual(p, st.z_bar)),
                       float(rl.objective(st.z_bar)),
                       time.perf_counter() - t0)

    # engine in one chunk (no per-round history) — same trajectory/seed as
    # the historical run_local driver
    t0 = time.perf_counter()
    engine = PSEngine(
        p, PSConfig(num_workers=M, rounds=R,
                    worker=MinimaxWorker(sgda(0.05)), local_k=K),
        rng=jax.random.PRNGKey(seed + 3))
    zg = engine.run()
    out["LocalSGDA"] = (float(kkt_residual(p, zg)), float(rl.objective(zg)),
                        time.perf_counter() - t0)

    for name, (res, obj, dt) in out.items():
        emit(f"robust[{name}]", dt * 1e6,
             f"kkt_residual={res:.4f};objective={obj:.4f}")
    return out


def main() -> None:
    out = run()
    emit("robust[check]", 0.0,
         f"adaseg_residual={out['LocalAdaSEG'][0]:.4f}")


if __name__ == "__main__":
    main()
