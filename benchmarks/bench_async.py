"""Time-to-target-residual: synchronous barrier vs bounded-staleness async.

The paper's Appendix E.1 "Asynch" only varied K_m per worker — every round
still ended at one barrier, so the old version of this benchmark could only
count rounds. The event-driven engine (``repro.ps.AsyncPSEngine``) gives the
comparison a genuine time axis: a straggler latency model (one worker 6×
slower), the *same* seeds/schedule everywhere, and three staleness policies

* ``sync``  — τ=0, a true barrier: every admission waits for the whole
  fleet, so each round costs the straggler's compute time;
* ``tau-2`` — bounded staleness: fast workers run at most 2 rounds ahead;
* ``async`` — τ=∞: the server admits every uplink as it arrives.

For LocalAdaSEG and zoo baselines (full-zoo flag inside), we report the
final residual, the total simulated time, the fleet idle fraction, the
maximum admitted staleness, and **time-to-target**: the first simulated
instant the run's residual reaches the sync run's final residual. The PR's
acceptance bar is that async-τ gets there in strictly less simulated time.

Traces are saved to JSON and reloaded through ``TraceRecorder.load`` (not
re-parsed ad hoc) — the file is what an offline plotting notebook would
consume for the residual-vs-sim-time curves.
"""
from __future__ import annotations

import math
import os
import tempfile
import time

import jax
import numpy as np

from repro.core import AdaSEGConfig
from repro.optim import MinimaxWorker, adam_minimax, segda, sgda
from repro.problems import make_bilinear_game
from repro.ps import (
    AsyncPSConfig,
    AsyncPSEngine,
    ConstantLatency,
    TraceRecorder,
)

from .common import emit, persist_trajectory

M, R, K = 4, 24, 10
N = 10
D = float(np.sqrt(2 * N))

# One persistent 6× straggler plus mild network delay — the adversarial
# fleet the communication-skipping story is supposed to win on.
LATENCY = ConstantLatency(step_s=(1.0, 1.0, 1.0, 6.0), up_s=0.2, down_s=0.1)

TAUS = {"sync": 0.0, "tau-2": 2.0, "async": math.inf}


def _optimizers(full_zoo: bool) -> dict:
    opts = {
        "LocalAdaSEG": dict(
            adaseg=AdaSEGConfig(g0=1.0, diameter=D, alpha=1.0, k=K)),
        "LocalSEGDA": dict(worker=MinimaxWorker(segda(0.05)), local_k=K),
    }
    if full_zoo:
        opts["LocalSGDA"] = dict(worker=MinimaxWorker(sgda(0.05)),
                                 local_k=K)
        opts["LocalAdam"] = dict(
            worker=MinimaxWorker(adam_minimax(0.05)), local_k=K)
    return opts


def run(seed: int = 0, full_zoo: bool = True) -> dict:
    game = make_bilinear_game(jax.random.PRNGKey(seed), n=N, sigma=0.1)
    p = game.problem
    out = {}

    with tempfile.TemporaryDirectory() as tmp:
        for opt_name, opt_kw in _optimizers(full_zoo).items():
            target = None
            for pol_name, tau in TAUS.items():
                cfg = AsyncPSConfig(num_workers=M, rounds=R, latency=LATENCY,
                                    staleness_bound=tau, **opt_kw)
                engine = AsyncPSEngine(p, cfg, rng=jax.random.PRNGKey(seed + 1),
                                       eval_fn=game.residual)
                t0 = time.perf_counter()
                engine.run()
                wall = time.perf_counter() - t0

                path = os.path.join(tmp, f"{opt_name}-{pol_name}.json")
                engine.trace.save(path)
                trace = TraceRecorder.load(path)      # the plotting-side API
                summary = trace.summary()
                if pol_name == "sync":
                    target = summary["final_residual"]
                ttt = trace.time_to_residual(target)
                out[(opt_name, pol_name)] = {
                    "residual": summary["final_residual"],
                    "sim_time_s": summary["sim_time_s"],
                    "time_to_target_s": ttt,
                    "idle_frac": summary.get("idle_frac"),
                    "max_staleness": summary.get("max_staleness", 0),
                }
                emit(f"async[{opt_name}/{pol_name}]", wall * 1e6,
                     f"residual={summary['final_residual']:.4f};"
                     f"sim_time_s={summary['sim_time_s']:.1f};"
                     f"time_to_target_s="
                     f"{ttt if ttt is None else round(ttt, 1)};"
                     f"idle_frac={summary.get('idle_frac', 0):.3f};"
                     f"max_staleness={summary.get('max_staleness', 0)};"
                     f"admissions={len(trace.rounds)}")
    return out


def main() -> None:
    out = run()
    checks = []
    for opt_name in {k[0] for k in out}:
        sync = out[(opt_name, "sync")]
        for pol in ("tau-2", "async"):
            row = out[(opt_name, pol)]
            ok = (row["time_to_target_s"] is not None
                  and row["time_to_target_s"] < sync["sim_time_s"])
            checks.append(ok)
            speedup = (sync["sim_time_s"] / row["time_to_target_s"]
                       if ok else float("nan"))
            emit(f"async[check:{opt_name}/{pol}]", 0.0,
                 f"beats_sync_to_target={ok};speedup={speedup:.2f}x")
    emit("async[check]", 0.0, f"all_async_beat_sync={all(checks)}")
    persist_trajectory("async", {
        f"{opt}/{pol}": row for (opt, pol), row in out.items()
    })


if __name__ == "__main__":
    main()
