"""Paper Fig. E1 (a)–(c): asynchronous LocalAdaSEG (heterogeneous K_m per
worker) vs synchronous, and vs single-thread SEGDA with M·K·R iterations.

'Asynch-50' = K_m ∈ {50,45,40,35}; 'Synch-50' = K=50 everywhere.

Runs on the Parameter-Server engine (``repro.ps``): the synchronous variants
are a ``UniformSchedule``, the asynchronous ones a ``FixedSchedule`` — the
engine reproduces the old hand-built ``local_steps`` arrays bit-exactly and
additionally reports the communication volume from its trace.
"""
from __future__ import annotations

import time

import jax
import numpy as np

from repro.core import AdaSEGConfig
from repro.optim import run_serial, segda
from repro.problems import make_bilinear_game
from repro.ps import FixedSchedule, PSConfig, PSEngine, UniformSchedule

from .common import emit

M, R = 4, 40
N = 10
D = float(np.sqrt(2 * N))


def run(seed: int = 0) -> dict:
    game = make_bilinear_game(jax.random.PRNGKey(seed), n=N, sigma=0.1)
    p = game.problem
    out = {}

    variants = {
        "Synch-50": UniformSchedule(50),
        "Asynch-50": FixedSchedule((50, 45, 40, 35)),
        "Synch-100": UniformSchedule(100),
        "Asynch-100": FixedSchedule((100, 90, 80, 70)),
    }
    for name, schedule in variants.items():
        cfg = PSConfig(
            adaseg=AdaSEGConfig(g0=1.0, diameter=D, alpha=1.0,
                                k=schedule.max_steps(M)),
            num_workers=M, rounds=R, schedule=schedule,
        )
        engine = PSEngine(p, cfg, rng=jax.random.PRNGKey(seed + 1))
        t0 = time.perf_counter()
        zbar = engine.run()
        dt = time.perf_counter() - t0
        res = float(game.residual(zbar))
        out[name] = res
        sps = engine.trace.steps_per_sec or 0.0
        emit(f"async[{name}]", dt * 1e6,
             f"residual={res:.4f};rounds={R};"
             f"steps={engine.trace.total_steps};"
             f"bytes_up={engine.trace.total_bytes_up:.0f};"
             f"steps_per_sec={sps:.0f}")

    # single-thread SEGDA with M·K·R iterations, batch = 1 (paper E.1 second)
    t0 = time.perf_counter()
    st, _ = run_serial(segda(0.05), p, steps=M * 50 * R,
                       rng=jax.random.PRNGKey(seed + 2), record_every=M * 50 * R)
    dt = time.perf_counter() - t0
    res = float(game.residual(st.z_bar))
    out["SEGDA-MKR"] = res
    emit(f"async[SEGDA-MKR]", dt * 1e6, f"residual={res:.4f};steps={M*50*R}")
    return out


def main() -> None:
    out = run()
    emit("async[check]", 0.0,
         f"async_close_to_sync={abs(out['Asynch-50'] - out['Synch-50']) < 0.3};"
         f"beats_single_thread={out['Synch-50'] < out['SEGDA-MKR'] * 2}")


if __name__ == "__main__":
    main()
