"""Kernel micro-benchmarks.

CPU wall-times of interpret-mode Pallas are NOT hardware-indicative (the
kernel body is executed per-block in Python); the meaningful derived
numbers are the analytic HBM-traffic / FLOP models reported alongside:

* adaseg_update: fused = 3 reads + 2 writes of the parameter vector vs
  ~9 passes unfused → traffic ratio 5/9.
* flash attention: O(S·W) compute for sliding windows vs O(S²) dense.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.adaseg_update.ops import adaseg_tree_update
from repro.kernels.flash_attention.ref import attention_ref
from repro.kernels.ssd_scan.ref import ssd_ref
from repro.models.ssm import ssd_chunked

from .common import emit, timed


def run() -> None:
    # --- adaseg update: jnp reference path (the production CPU path) -------
    n = 1 << 20
    tree = {"w": jax.random.normal(jax.random.PRNGKey(0), (n,))}
    m = jax.tree.map(lambda v: 0.3 * v, tree)
    g = jax.tree.map(lambda v: 0.1 * v, tree)
    _, us = timed(
        lambda: adaseg_tree_update(tree, m, g, 0.1, use_kernel=False)
    )
    bytes_fused = 5 * n * 4
    bytes_unfused = 9 * n * 4
    emit("kernel[adaseg_update_ref,n=1M]", us,
         f"hbm_bytes_fused={bytes_fused};unfused={bytes_unfused};"
         f"traffic_ratio={bytes_fused/bytes_unfused:.2f}")

    # --- attention: dense vs sliding window FLOPs --------------------------
    b, h, s, d, w = 1, 4, 1024, 64, 128
    q = jax.random.normal(jax.random.PRNGKey(1), (b, h, s, d), jnp.float32)
    k = jax.random.normal(jax.random.PRNGKey(2), (b, h, s, d), jnp.float32)
    v = jax.random.normal(jax.random.PRNGKey(3), (b, h, s, d), jnp.float32)
    dense = jax.jit(lambda q, k, v: attention_ref(q, k, v, causal=True))
    _, us_d = timed(dense, q, k, v)
    local = jax.jit(
        lambda q, k, v: attention_ref(q, k, v, causal=True, window=w)
    )
    _, us_l = timed(local, q, k, v)
    flops_dense = 4 * b * h * s * (s / 2) * d
    flops_win = 4 * b * h * s * w * d
    emit("kernel[attention_dense,s=1024]", us_d, f"flops={flops_dense:.3e}")
    emit("kernel[attention_window128,s=1024]", us_l,
         f"flops={flops_win:.3e};flop_ratio={flops_win/flops_dense:.3f}")

    # --- SSD: chunked (MXU formulation) vs sequential scan ------------------
    bsz, l, heads, p, nst = 2, 512, 4, 32, 64
    ks = jax.random.split(jax.random.PRNGKey(4), 5)
    x = jax.random.normal(ks[0], (bsz, l, heads, p))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (bsz, l, heads)))
    a = -jnp.exp(jax.random.normal(ks[2], (heads,)))
    bb = jax.random.normal(ks[3], (bsz, l, nst))
    cc = jax.random.normal(ks[4], (bsz, l, nst))
    seq = jax.jit(lambda *t: ssd_ref(*t))
    _, us_seq = timed(seq, x, dt, a, bb, cc)
    chk = jax.jit(lambda *t: ssd_chunked(*t, 128))
    _, us_chk = timed(chk, x, dt, a, bb, cc)
    emit("kernel[ssd_sequential,s=512]", us_seq, "impl=lax.scan")
    emit("kernel[ssd_chunked,s=512]", us_chk,
         f"impl=SSD;speedup_vs_scan={us_seq/us_chk:.2f}x")


def main() -> None:
    run()


if __name__ == "__main__":
    main()
