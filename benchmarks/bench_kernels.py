"""Kernel micro-benchmarks.

CPU wall-times of interpret-mode Pallas are NOT hardware-indicative (the
kernel body is executed per-block in Python); the meaningful derived
numbers are the analytic HBM-traffic / FLOP models reported alongside:

* adaseg_update: fused = 3 reads + 2 writes of the parameter vector vs
  ~9 passes unfused → traffic ratio 5/9.
* flash attention: O(S·W) compute for sliding windows vs O(S²) dense.

The ``step[...]`` rows time the full optimizer step through both step
backends (``core.adaseg.local_step``) on a ≥1M-parameter pytree — the
comparison the tentpole cares about: reference tree ops vs the fused
explore/anchor kernel path.
"""
from __future__ import annotations

import functools
import statistics
import time

import jax
import jax.numpy as jnp

from repro.core import AdaSEGConfig, init, local_step, projections
from repro.core.types import MinimaxProblem
from repro.kernels.adaseg_update.ops import adaseg_tree_update
from repro.kernels.flash_attention.ref import attention_ref
from repro.kernels.ssd_scan.ref import ssd_ref
from repro.models.ssm import ssd_chunked

from .common import emit, persist_trajectory, timed


def bench_step_backends(n: int = 1 << 20) -> dict:
    """Fused Pallas step vs reference tree-op step, identical problem.

    The pytree is {x: (n,), y: (n/4,)} → 1.25M params at the default n;
    the oracle is a cheap linear field so the timing isolates the update
    machinery (projection, double update, (Z_t)²/‖G‖² statistics).
    """

    def pinit(rng):
        r1, r2 = jax.random.split(rng)
        return {"x": 0.1 * jax.random.normal(r1, (n,)),
                "y": 0.1 * jax.random.normal(r2, (n // 4,))}

    def sample(rng):
        return jax.random.normal(rng, (2,))

    def oracle(z, xi):
        return jax.tree.map(lambda v: 0.3 * v + xi[0] * 1e-3, z)

    prob = MinimaxProblem(init=pinit, sample=sample, oracle=oracle,
                          project=projections.box(-1.0, 1.0), name="bench")
    cfg = AdaSEGConfig(g0=1.0, diameter=2.0, alpha=1.0, k=1)
    state = init(prob, cfg, jax.random.PRNGKey(0))
    rng = jax.random.PRNGKey(1)
    params = sum(v.size for v in jax.tree.leaves(state.z_tilde))

    steps = {b: jax.jit(functools.partial(local_step, prob, cfg, backend=b))
             for b in ("reference", "fused")}
    for fn in steps.values():
        jax.block_until_ready(fn(state, rng))       # compile

    # Interleaved medians: CPU wall-time is noisy, alternate the backends.
    times = {b: [] for b in steps}
    for _ in range(6):
        for b, fn in steps.items():
            t0 = time.perf_counter()
            for _ in range(5):
                out = fn(state, rng)
            jax.block_until_ready(out)
            times[b].append((time.perf_counter() - t0) / 5 * 1e6)
    med = {b: statistics.median(ts) for b, ts in times.items()}
    emit(f"step[reference,params={params}]", med["reference"],
         "backend=tree_ops;hbm_passes~9")
    emit(f"step[fused,params={params}]", med["fused"],
         f"backend=pallas_explore_anchor;hbm_passes~7;"
         f"speedup_vs_reference={med['reference'] / med['fused']:.2f}x")
    return {"step_reference_us": med["reference"],
            "step_fused_us": med["fused"]}


def run() -> dict:
    # --- adaseg update: jnp reference path (the production CPU path) -------
    n = 1 << 20
    tree = {"w": jax.random.normal(jax.random.PRNGKey(0), (n,))}
    m = jax.tree.map(lambda v: 0.3 * v, tree)
    g = jax.tree.map(lambda v: 0.1 * v, tree)
    _, us = timed(
        lambda: adaseg_tree_update(tree, m, g, 0.1, use_kernel=False)
    )
    bytes_fused = 5 * n * 4
    bytes_unfused = 9 * n * 4
    emit("kernel[adaseg_update_ref,n=1M]", us,
         f"hbm_bytes_fused={bytes_fused};unfused={bytes_unfused};"
         f"traffic_ratio={bytes_fused/bytes_unfused:.2f}")
    results = {"adaseg_update_ref_us": us}

    # --- full optimizer step: fused Pallas backend vs reference tree ops ---
    results.update(bench_step_backends())

    # --- attention: dense vs sliding window FLOPs --------------------------
    b, h, s, d, w = 1, 4, 1024, 64, 128
    q = jax.random.normal(jax.random.PRNGKey(1), (b, h, s, d), jnp.float32)
    k = jax.random.normal(jax.random.PRNGKey(2), (b, h, s, d), jnp.float32)
    v = jax.random.normal(jax.random.PRNGKey(3), (b, h, s, d), jnp.float32)
    dense = jax.jit(lambda q, k, v: attention_ref(q, k, v, causal=True))
    _, us_d = timed(dense, q, k, v)
    local = jax.jit(
        lambda q, k, v: attention_ref(q, k, v, causal=True, window=w)
    )
    _, us_l = timed(local, q, k, v)
    flops_dense = 4 * b * h * s * (s / 2) * d
    flops_win = 4 * b * h * s * w * d
    emit("kernel[attention_dense,s=1024]", us_d, f"flops={flops_dense:.3e}")
    emit("kernel[attention_window128,s=1024]", us_l,
         f"flops={flops_win:.3e};flop_ratio={flops_win/flops_dense:.3f}")
    results["attention_dense_us"] = us_d
    results["attention_window_us"] = us_l

    # --- SSD: chunked (MXU formulation) vs sequential scan ------------------
    bsz, l, heads, p, nst = 2, 512, 4, 32, 64
    ks = jax.random.split(jax.random.PRNGKey(4), 5)
    x = jax.random.normal(ks[0], (bsz, l, heads, p))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (bsz, l, heads)))
    a = -jnp.exp(jax.random.normal(ks[2], (heads,)))
    bb = jax.random.normal(ks[3], (bsz, l, nst))
    cc = jax.random.normal(ks[4], (bsz, l, nst))
    seq = jax.jit(lambda *t: ssd_ref(*t))
    _, us_seq = timed(seq, x, dt, a, bb, cc)
    chk = jax.jit(lambda *t: ssd_chunked(*t, 128))
    _, us_chk = timed(chk, x, dt, a, bb, cc)
    emit("kernel[ssd_sequential,s=512]", us_seq, "impl=lax.scan")
    emit("kernel[ssd_chunked,s=512]", us_chk,
         f"impl=SSD;speedup_vs_scan={us_seq/us_chk:.2f}x")
    results["ssd_sequential_us"] = us_seq
    results["ssd_chunked_us"] = us_chk
    return results


def main() -> None:
    persist_trajectory("kernels", run())


if __name__ == "__main__":
    main()
