"""Fleet scale: compiled round scans + sampled-client rounds at 10k workers.

The paper's headline claim is near-linear speedup in the worker count M, so
the runtime must make fleet size a *compiled-code axis*, not a Python-loop
axis. This bench pins the three mechanisms that get it there:

* **seed path vs cached scan** (M=512, full participation): the pre-PR
  engine path — every engine constructed its own ``jax.jit`` of the round
  chunk (no process-wide cache, no donation), so every benchmark loop,
  checkpoint drill or lockstep async engine re-paid the full trace+compile
  — against the cached/donated chunk, both end-to-end (construct + run).
  Acceptance bar: the cached path clears **≥10× rounds/sec**. A second
  loop-vs-scan pair isolates the per-round dispatch amortization (driving
  ``step_round()`` R times vs one scan chunk) with compilation excluded
  from both sides.
* **fleet sweep** M ∈ {8, 64, 512, 4096, 10000}: sampled-client rounds
  (``ClientSampler``, ``sample=min(M, 64)``) materialize only the drawn
  lanes per round, so rounds/sec stays interactive while the fleet store
  grows to 10k workers — including a full M=10k sampled sweep.
* **async batched admission** (M=512 fleet, 64 sampled): the event-driven
  engine's vectorized event machine (arrays-as-queue, batched phase
  execution) driving a sampled fleet on the simulated clock.

Headline numbers persist via ``persist_trajectory`` into
``BENCH_fleet.json`` so ``benchmarks/regress.py`` gates rounds/sec from the
first CI run. Metric naming: ``*_per_sec`` / ``*speedup`` are the gate's
higher-better classes.
"""
from __future__ import annotations

import time

import jax
import numpy as np

from repro.core import AdaSEGConfig
from repro.problems import make_bilinear_game
from repro.ps import (
    AsyncPSConfig,
    AsyncPSEngine,
    ClientSampler,
    ConstantLatency,
    PSConfig,
    PSEngine,
)
from repro.obs import SpanTracer

from .common import emit, persist_trajectory

N_DIM = 8
D = float(np.sqrt(2 * N_DIM))
K = 4
ROUNDS = 24          # headline-comparison rounds (a representative short run)
SWEEP_ROUNDS = 12    # fleet-width sweep rounds
FLEETS = (8, 64, 512, 4096, 10_000)
SAMPLE_CAP = 64      # sampled lanes per round in the sweep


def _cfg(m: int, rounds: int, sampler: ClientSampler | None = None
         ) -> PSConfig:
    return PSConfig(
        adaseg=AdaSEGConfig(g0=1.0, diameter=D, alpha=1.0, k=K),
        num_workers=m, rounds=rounds, sampler=sampler,
    )


def _engine(problem, cfg, *, trace: bool = False) -> PSEngine:
    # span recording off: this bench measures the engine hot path, and the
    # per-round span/metric bookkeeping is what the scan path amortizes
    return PSEngine(problem, cfg, rng=jax.random.PRNGKey(1),
                    tracer=SpanTracer(enabled=trace))


def seed_vs_cached(problem, m: int = 512) -> dict:
    """End-to-end (construct + run) at fleet M: the pre-PR engine path —
    a fresh per-engine ``jax.jit`` of the round chunk, so every engine
    construction re-pays the full trace+compile, with no buffer donation —
    against the process-wide cached/donated chunk."""
    from repro.ps.engine import make_serial_chunk

    cfg = _cfg(m, ROUNDS)

    def fresh(pre_pr: bool) -> PSEngine:
        eng = _engine(problem, cfg)
        if pre_pr:
            # exactly what PSEngine.__init__ did before the chunk cache:
            # jit the builder output per engine (fresh callable ⇒ fresh
            # trace+compile), no donate_argnums
            eng._chunk_fn = jax.jit(make_serial_chunk(
                problem, eng.worker, eng.compressor, m, eng._k_pad,
                None, True, "reference"))
        return eng

    fresh(False).run()            # warm the cached path once

    t0 = time.perf_counter()
    fresh(True).run()
    seed_s = time.perf_counter() - t0

    t0 = time.perf_counter()
    eng = fresh(False)
    eng.run()
    cached_s = time.perf_counter() - t0

    steps = int(sum(sum(r.local_steps) for r in eng.trace.rounds))
    out = {
        "seed_rounds_per_sec": ROUNDS / seed_s,
        "cached_rounds_per_sec": ROUNDS / cached_s,
        "cached_steps_per_sec": steps / cached_s,
        "speedup_vs_seed": seed_s / cached_s,
    }
    emit(f"fleet[seed-vs-cached m={m}]", cached_s * 1e6 / ROUNDS,
         f"seed_rounds_per_sec={out['seed_rounds_per_sec']:.1f};"
         f"cached_rounds_per_sec={out['cached_rounds_per_sec']:.1f};"
         f"speedup_vs_seed={out['speedup_vs_seed']:.1f}x")
    return out


def loop_vs_scan(problem, m: int = 512) -> dict:
    """Per-round driving vs one donated scan chunk, compilation excluded
    from both sides: isolates what the chunked scan amortizes (dispatch,
    per-round host sync, telemetry transfer)."""
    cfg = _cfg(m, ROUNDS)
    # warm the process-wide compiled-chunk cache for both scan lengths
    # (full-run chunk and the loop's length-1 chunk), so the timed engines
    # measure execution, not compilation
    warm = _engine(problem, cfg)
    warm.run()
    warm = _engine(problem, cfg)
    warm.step_round()

    eng = _engine(problem, cfg)
    t0 = time.perf_counter()
    for _ in range(ROUNDS):
        eng.step_round()          # host sync + telemetry every round
    loop_s = time.perf_counter() - t0

    eng = _engine(problem, cfg)
    t0 = time.perf_counter()
    eng.run()                     # one chunk: donated buffers, bulk telemetry
    scan_s = time.perf_counter() - t0

    out = {
        "loop_rounds_per_sec": ROUNDS / loop_s,
        "scan_rounds_per_sec": ROUNDS / scan_s,
        "dispatch_amortization": loop_s / scan_s,
    }
    emit(f"fleet[loop-vs-scan m={m}]", scan_s * 1e6 / ROUNDS,
         f"loop_rounds_per_sec={out['loop_rounds_per_sec']:.1f};"
         f"scan_rounds_per_sec={out['scan_rounds_per_sec']:.1f};"
         f"dispatch_amortization={out['dispatch_amortization']:.1f}x")
    return out


def sampled_sweep(problem) -> dict:
    """Rounds/sec across fleet widths with sampled-client rounds: each round
    gathers ``sample`` lanes from the (fleet, ...) store, so the compiled
    round cost is set by the sample, not the fleet."""
    out = {}
    for fleet in FLEETS:
        sample = min(fleet, SAMPLE_CAP)
        cfg = _cfg(fleet, SWEEP_ROUNDS,
                   sampler=ClientSampler(sample=sample, seed=3))
        _engine(problem, cfg).run()           # compile warmup (cached chunk)
        eng = _engine(problem, cfg)
        t0 = time.perf_counter()
        eng.run()
        dt = time.perf_counter() - t0
        steps = int(sum(sum(r.local_steps) for r in eng.trace.rounds))
        out[f"fleet{fleet}"] = {
            "rounds_per_sec": SWEEP_ROUNDS / dt,
            "steps_per_sec": steps / dt,
        }
        emit(f"fleet[sweep fleet={fleet} sample={sample}]",
             dt * 1e6 / SWEEP_ROUNDS,
             f"rounds_per_sec={SWEEP_ROUNDS / dt:.1f};"
             f"steps_per_sec={steps / dt:.0f}")
    return out


def async_sampled(problem, fleet: int = 512, sample: int = 64) -> dict:
    """Event-driven engine on a sampled fleet: the vectorized event machine
    admits arrivals in batches on the simulated clock."""
    cfg = AsyncPSConfig(
        adaseg=AdaSEGConfig(g0=1.0, diameter=D, alpha=1.0, k=K),
        num_workers=fleet, rounds=SWEEP_ROUNDS,
        sampler=ClientSampler(sample=sample, seed=3),
        latency=ConstantLatency(step_s=1.0, up_s=0.2, down_s=0.1),
    )
    eng = AsyncPSEngine(problem, cfg, rng=jax.random.PRNGKey(1),
                        tracer=SpanTracer(enabled=False))
    t0 = time.perf_counter()
    eng.run()
    dt = time.perf_counter() - t0
    n_adm = eng.n_admissions
    out = {
        "admissions_per_sec": n_adm / dt,
        "sim_time_s": eng.sim_time,
    }
    emit(f"fleet[async fleet={fleet} sample={sample}]", dt * 1e6,
         f"admissions={n_adm};admissions_per_sec={n_adm / dt:.1f};"
         f"sim_time_s={eng.sim_time:.1f}")
    return out


def main() -> None:
    game = make_bilinear_game(jax.random.PRNGKey(0), n=N_DIM, sigma=0.1)
    p = game.problem
    results = {
        "m512": seed_vs_cached(p),
        "m512_dispatch": loop_vs_scan(p),
        "sweep": sampled_sweep(p),
        "async512": async_sampled(p),
    }
    ok = results["m512"]["speedup_vs_seed"] >= 10.0
    emit("fleet[check]", 0.0,
         f"speedup_vs_seed_ge_10x={ok};"
         f"speedup={results['m512']['speedup_vs_seed']:.1f}x")
    persist_trajectory("fleet", results)


if __name__ == "__main__":
    main()
