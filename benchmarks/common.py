"""Shared benchmark utilities: timing, CSV emission, metric evaluation."""
from __future__ import annotations

import time

import jax


def timed(fn, *args, warmup: int = 1, iters: int = 3):
    """(result, us_per_call). Blocks on async dispatch."""
    result = None
    for _ in range(warmup):
        result = fn(*args)
        jax.block_until_ready(result)
    t0 = time.perf_counter()
    for _ in range(iters):
        result = fn(*args)
        jax.block_until_ready(result)
    dt = (time.perf_counter() - t0) / iters
    return result, dt * 1e6


def emit(name: str, us_per_call: float, derived: str) -> None:
    """CSV row: name,us_per_call,derived."""
    print(f"{name},{us_per_call:.1f},{derived}")
