"""Shared benchmark utilities: timing, CSV emission, trajectory persistence.

Every bench harness persists its headline numbers through
:func:`persist_trajectory` into one ``BENCH_<name>.json`` per bench at the
repo root (committed), so perf is comparable across PRs: each run *appends*
an entry carrying its run index, the JAX backend it measured on, and the
results dict — the trajectory ``benchmarks/regress.py`` gates on in CI.
``set_json_dir`` (or ``run.py --json-dir``) redirects the files, e.g. to a
scratch dir for the injected-regression test.
"""
from __future__ import annotations

import json
import pathlib
import time

import jax

_JSON_DIR = pathlib.Path(__file__).resolve().parent.parent


def timed(fn, *args, warmup: int = 1, iters: int = 3):
    """(result, us_per_call). Blocks on async dispatch."""
    result = None
    for _ in range(warmup):
        result = fn(*args)
        jax.block_until_ready(result)
    t0 = time.perf_counter()
    for _ in range(iters):
        result = fn(*args)
        jax.block_until_ready(result)
    dt = (time.perf_counter() - t0) / iters
    return result, dt * 1e6


def emit(name: str, us_per_call: float, derived: str) -> None:
    """CSV row: name,us_per_call,derived."""
    print(f"{name},{us_per_call:.1f},{derived}")


def set_json_dir(path) -> None:
    """Redirect where :func:`persist_trajectory` reads/writes BENCH files."""
    global _JSON_DIR
    _JSON_DIR = pathlib.Path(path)


def trajectory_path(bench: str) -> pathlib.Path:
    return _JSON_DIR / f"BENCH_{bench}.json"


def load_trajectory(bench: str) -> dict:
    """The persisted ``{"bench": ..., "entries": [...]}`` payload
    (an empty trajectory if the file doesn't exist yet)."""
    path = trajectory_path(bench)
    if not path.exists():
        return {"bench": bench, "entries": []}
    return json.loads(path.read_text())


def persist_trajectory(bench: str, results: dict) -> dict:
    """Append one run's ``results`` to ``BENCH_<bench>.json``.

    The entry records the run index and ``jax.default_backend()`` so the
    regression gate only ever compares runs measured on the same backend.
    Returns the appended entry.
    """
    payload = load_trajectory(bench)
    entry = {
        "run": len(payload["entries"]),
        "backend": jax.default_backend(),
        "results": results,
    }
    payload["entries"].append(entry)
    trajectory_path(bench).write_text(
        json.dumps({"bench": bench, "entries": payload["entries"]}, indent=1)
        + "\n"
    )
    emit(f"{bench}:persist", 0.0,
         f"entries={len(payload['entries'])};file={trajectory_path(bench).name}")
    return entry
