"""Roofline table builder (deliverable g).

Reads the dry-run JSON records (written by ``repro.launch.dryrun --out``),
combines the measured per-device HLO costs with the analytic FLOP model
(``repro.roofline.flops`` — exact matmul accounting; XLA while-bodies are
cost-counted once, see EXPERIMENTS.md §Roofline/methodology), and emits the
three roofline terms, the dominant bottleneck, MODEL_FLOPS = 6·N·D, and the
useful-compute ratio per (arch × shape × mesh).

    PYTHONPATH=src python -m benchmarks.bench_roofline \
        results/dryrun_single.json [results/dryrun_multi.json ...] \
        [--markdown results/roofline.md]
"""
from __future__ import annotations

import argparse
import json

from repro.configs import get_config
from repro.launch.shapes import INPUT_SHAPES
from repro.roofline.analysis import HBM_BW, ICI_BW, PEAK_FLOPS
from repro.roofline.flops import estimate


def enrich(rec: dict) -> dict:
    cfg = get_config(rec["arch"])
    shape = INPUT_SHAPES[rec["shape"]]
    n_dev = rec["num_devices"]
    k = rec.get("k_local", 1)

    if shape.kind == "train":
        fb = estimate(cfg, shape.seq)
        # per-device analytic flops for the lowered unit (K EG steps + sync)
        flops_analytic = fb.eg_local_step() * k * shape.batch / n_dev
        tokens = shape.batch * shape.seq * k
        model_flops = 6.0 * fb.params_active * tokens / n_dev
    elif shape.kind == "prefill":
        fb = estimate(cfg, shape.seq)
        flops_analytic = fb.forward * shape.batch / n_dev
        model_flops = 2.0 * fb.params_active * shape.batch * shape.seq / n_dev
    else:  # decode
        fb = estimate(cfg, shape.seq, kv_len=shape.seq, decode=True)
        flops_analytic = fb.forward * shape.batch / n_dev
        model_flops = 2.0 * fb.params_active * shape.batch / n_dev

    t_compute = flops_analytic / PEAK_FLOPS
    # memory term: measured per-device HLO bytes (upper bound: CPU-backend
    # fusion is weaker than TPU's)
    t_memory = rec["hbm_bytes"] / HBM_BW
    t_coll = rec["collective_bytes"] / (rec["num_devices"] * ICI_BW)
    terms = {"compute": t_compute, "memory": t_memory, "collective": t_coll}
    by_axis = rec.get("collective_bytes_by_axis", {})
    worker_bytes = sum(
        v for a, v in by_axis.items()
        if set(a.split(",")) & {"pod"} or a == "data" and
        rec.get("worker_mode") == "paper"
    )
    rec.update(
        flops_analytic=flops_analytic,
        model_flops=model_flops,
        useful_ratio=model_flops / max(flops_analytic, 1.0),
        t_compute_s=t_compute,
        t_memory_s=t_memory,
        t_collective_s=t_coll,
        bottleneck=max(terms, key=terms.get),
        worker_sync_bytes=worker_bytes,
    )
    return rec


COLS = ("arch", "shape", "mesh", "bottleneck", "t_compute_s", "t_memory_s",
        "t_collective_s", "flops_analytic", "model_flops", "useful_ratio",
        "bytes_per_device", "collective_bytes", "worker_sync_bytes")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("jsons", nargs="+")
    ap.add_argument("--markdown", default=None)
    args = ap.parse_args()

    records = []
    for path in args.jsons:
        with open(path) as f:
            records.extend(json.load(f))
    rows = [enrich(r) for r in records]

    print(",".join(COLS))
    for r in rows:
        print(",".join(
            f"{r.get(c, ''):.4e}" if isinstance(r.get(c), float)
            else str(r.get(c, "")) for c in COLS
        ))

    if args.markdown:
        with open(args.markdown, "w") as f:
            f.write("| " + " | ".join(COLS) + " |\n")
            f.write("|" + "---|" * len(COLS) + "\n")
            for r in rows:
                f.write("| " + " | ".join(
                    f"{r.get(c, ''):.3e}" if isinstance(r.get(c), float)
                    else str(r.get(c, "")) for c in COLS
                ) + " |\n")
        print(f"wrote {args.markdown}")


if __name__ == "__main__":
    main()
