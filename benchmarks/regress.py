"""CI perf-regression gate over the persisted ``BENCH_*.json`` trajectories.

Every bench harness appends one entry per run (via
:func:`benchmarks.common.persist_trajectory`), so the repo carries each
benchmark's full perf trajectory. This gate diffs the **newest** entry of
each trajectory against its **baseline** — the most recent earlier entry
measured on the same JAX backend (wall numbers from different backends are
not comparable) — with per-metric-class tolerances:

* **exact** (``bytes*``, ``workers``, ``local_k``, ``max_staleness``) —
  deterministic outputs of seeded runs: *any* drift is a hard failure, it
  means the numerics changed, not the machine.
* **lower-better** (``*_us``, ``*residual*``, ``*time*``, ``idle_frac``)
  — regression ratio = (new − base) / base.
* **higher-better** (``*per_sec*``, ``*speedup*``) — ratio mirrored.

Timing ratios inside ``(warn, fail)`` print a report-only warning; above
``fail`` they fail the gate. The defaults are generous because CI hosts are
noisy CPUs — the gate is for catching step-function regressions (an
accidental recompile per round, a dropped fusion), not ±10% jitter.

Exit status: nonzero iff any hard failure. ``--json-dir`` points at a
different trajectory directory (used by the injected-regression test).
"""
from __future__ import annotations

import argparse
import sys

from .common import load_trajectory, trajectory_path

EXACT = ("bytes", "workers", "local_k", "max_staleness")
HIGHER_BETTER = ("per_sec", "speedup")
# (span overhead_frac is deliberately ungated: it hovers near zero, so the
# ratio of two noisy near-zero numbers is meaningless — the <5% bar lives
# in bench_ps itself; its absolute per-round _us times ARE gated.)
LOWER_BETTER = ("_us", "us_", "residual", "time", "idle_frac", "wall")

#: Benches whose trajectories the gate knows how to read.
BENCHES = ("ps", "ps_models", "async", "kernels", "fleet", "fig4")


def _classify(name: str) -> str | None:
    if any(t in name for t in EXACT):
        return "exact"
    if any(t in name for t in HIGHER_BETTER):
        return "higher"
    if any(t in name for t in LOWER_BETTER):
        return "lower"
    return None  # informational — not gated


def _flatten(results: dict, prefix: str = "") -> dict:
    out = {}
    for k, v in results.items():
        key = f"{prefix}{k}"
        if isinstance(v, dict):
            out.update(_flatten(v, prefix=f"{key}."))
        elif isinstance(v, (int, float)) and not isinstance(v, bool):
            out[key] = float(v)
    return out


def _baseline(entries: list[dict], new: dict) -> dict | None:
    """Most recent entry before ``new`` on the same backend."""
    for e in reversed(entries[:-1]):
        if e.get("backend") == new.get("backend"):
            return e
    return None


def compare(base: dict, new: dict, *, warn: float, fail: float
            ) -> list[dict]:
    """Per-metric verdicts between two flattened results dicts."""
    rows = []
    b, n = _flatten(base), _flatten(new)
    for name in sorted(set(b) & set(n)):
        cls = _classify(name)
        if cls is None:
            continue
        bv, nv = b[name], n[name]
        if cls == "exact":
            drift = abs(nv - bv) / max(abs(bv), 1e-30)
            status = "fail" if drift > 1e-9 else "ok"
            rows.append({"metric": name, "class": cls, "base": bv,
                         "new": nv, "ratio": drift, "status": status})
            continue
        denom = max(abs(bv), 1e-30)
        ratio = (nv - bv) / denom if cls == "lower" else (bv - nv) / denom
        status = ("fail" if ratio > fail
                  else "warn" if ratio > warn else "ok")
        rows.append({"metric": name, "class": cls, "base": bv, "new": nv,
                     "ratio": ratio, "status": status})
    return rows


def gate(benches=BENCHES, *, warn: float = 0.25, fail: float = 0.60,
         verbose: bool = True) -> int:
    """Run the gate over every trajectory; returns the exit status."""
    failures = warnings = compared = 0
    for bench in benches:
        payload = load_trajectory(bench)
        entries = payload.get("entries", [])
        if len(entries) < 2:
            if verbose:
                print(f"regress[{bench}]: skipped "
                      f"({len(entries)} entries in "
                      f"{trajectory_path(bench).name})")
            continue
        new = entries[-1]
        base = _baseline(entries, new)
        if base is None:
            if verbose:
                print(f"regress[{bench}]: skipped (no prior entry on "
                      f"backend={new.get('backend')})")
            continue
        compared += 1
        for row in compare(base["results"], new["results"],
                           warn=warn, fail=fail):
            if row["status"] == "fail":
                failures += 1
            elif row["status"] == "warn":
                warnings += 1
            if verbose and row["status"] != "ok":
                print(f"regress[{bench}] {row['status'].upper()} "
                      f"{row['metric']} ({row['class']}): "
                      f"{row['base']:.4g} -> {row['new']:.4g} "
                      f"(ratio {row['ratio']:+.2%})")
        if verbose:
            print(f"regress[{bench}]: run {base['run']} -> {new['run']} "
                  f"on {new.get('backend')}")
    if verbose:
        print(f"regress: {compared} trajectories compared, "
              f"{warnings} warnings, {failures} failures")
    return 1 if failures else 0


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--json-dir", default=None,
                    help="directory holding the BENCH_*.json trajectories "
                         "(default: repo root)")
    ap.add_argument("--warn", type=float, default=0.25,
                    help="report-only threshold on the regression ratio")
    ap.add_argument("--fail", type=float, default=0.60,
                    help="hard-failure threshold on the regression ratio")
    ap.add_argument("--bench", action="append", default=None,
                    help="gate only this bench (repeatable)")
    args = ap.parse_args(argv)
    if args.json_dir is not None:
        from .common import set_json_dir

        set_json_dir(args.json_dir)
    return gate(tuple(args.bench) if args.bench else BENCHES,
                warn=args.warn, fail=args.fail)


if __name__ == "__main__":
    sys.exit(main())
