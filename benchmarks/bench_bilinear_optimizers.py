"""Paper Fig. 4 (a)–(d): LocalAdaSEG vs the optimizer zoo on the stochastic
bilinear game, matched computation/communication structure (M = 4, K = 50):

* LocalAdaSEG (ours)         — K local adaptive EG steps, weighted sync
* MB-SEGDA / MB-UMP / MB-ASMP — R steps of minibatch K·M
* LocalSGDA / LocalSEGDA / LocalAdam — K local steps, uniform averaging

Every Local* method (LocalAdaSEG included) runs through the unified
Parameter-Server engine (``repro.ps.PSEngine``) — identity config, so the
trajectories equal the historical one-shot drivers — and reports the
engine's local-steps/sec throughput; the MB-* baselines are single-worker
``run_serial`` over the K·M minibatch oracle.

Expected reproduction: adaptive methods (LocalAdaSEG, MB-UMP, MB-ASMP)
beat the fixed-lr ones; per communication round LocalAdaSEG converges
fastest (paper Fig. 4 b/d).
"""
from __future__ import annotations

import time

import jax
import numpy as np

from repro.core import AdaSEGConfig
from repro.optim import (
    MinimaxWorker,
    adam_minimax,
    asmp,
    minibatch,
    run_serial,
    segda,
    sgda,
    ump,
)
from repro.problems import make_bilinear_game
from repro.ps import PSConfig, PSEngine

from .common import emit

M, K, R = 4, 50, 50
N = 10
D = float(np.sqrt(2 * N))


def run(seed: int = 0) -> dict:
    results = {}
    for sigma in (0.1, 0.5):
        game = make_bilinear_game(jax.random.PRNGKey(seed), n=N, sigma=sigma)
        p = game.problem
        runs = {}

        local = {"LocalAdaSEG": dict(
            adaseg=AdaSEGConfig(g0=1.0, diameter=D, alpha=1.0, k=K))}
        for name, opt in (
            ("LocalSGDA", sgda(0.05)),
            ("LocalSEGDA", segda(0.05)),
            ("LocalAdam", adam_minimax(0.02)),
        ):
            local[name] = dict(worker=MinimaxWorker(opt), local_k=K)

        for name, opt_kw in local.items():
            engine = PSEngine(
                p, PSConfig(num_workers=M, rounds=R, **opt_kw),
                rng=jax.random.PRNGKey(
                    seed + 1 if name == "LocalAdaSEG" else seed + 3),
            )
            zbar = engine.run()
            runs[name] = (game.residual(zbar), engine.trace.total_wall_time_s)

        mb = minibatch(p, K * M)
        for name, opt in (
            ("MB-SEGDA", segda(0.1)),
            ("MB-UMP", ump(1.0, D)),
            ("MB-ASMP", asmp(1.0, D)),
        ):
            t0 = time.perf_counter()
            st, _ = run_serial(opt, mb, steps=R, rng=jax.random.PRNGKey(seed + 2),
                               record_every=R)
            runs[name] = (game.residual(st.z_bar), time.perf_counter() - t0)

        for name, (res, dt) in runs.items():
            emit(f"bilinear_opt[sigma={sigma},{name}]", dt * 1e6,
                 f"residual={float(res):.4f};rounds={R}")
        results[sigma] = {k: float(v[0]) for k, v in runs.items()}
    return results


def main() -> None:
    results = run()
    r = results[0.1]
    adaptives = min(r["LocalAdaSEG"], r["MB-UMP"], r["MB-ASMP"])
    fixed = min(r["LocalSGDA"], r["LocalSEGDA"], r["MB-SEGDA"])
    emit("bilinear_opt[check]", 0.0,
         f"best_adaptive={adaptives:.4f};best_fixed={fixed:.4f};"
         f"adaptive_wins={adaptives < fixed}")


if __name__ == "__main__":
    main()
