"""Paper Fig. E1 (d): the cumulative gradient-norm quantity
V_t = sqrt(Σ_τ ‖g_τ‖² + ‖M_τ‖²) against √t and t^{2/5}.

The paper's claim (Remark 1): V_t grows strictly slower than G·√(2t), so
the V₁(T)-dependent term in Theorem 2 is not the bottleneck and near-linear
speed-up holds.
"""
from __future__ import annotations

import time

import jax
import numpy as np

from repro.core import AdaSEGConfig, run_local_adaseg
from repro.problems import make_bilinear_game

from .common import emit

M, K, R = 4, 50, 40
N = 10
D = float(np.sqrt(2 * N))


def run(seed: int = 0) -> dict:
    game = make_bilinear_game(jax.random.PRNGKey(seed), n=N, sigma=0.1)
    cfg = AdaSEGConfig(g0=1.0, diameter=D, alpha=1.0, k=K)
    t0 = time.perf_counter()
    zbar, (state, hist) = run_local_adaseg(
        game.problem, cfg, num_workers=M, rounds=R,
        rng=jax.random.PRNGKey(seed + 1),
    )
    dt = time.perf_counter() - t0
    # hist.grad_norm_sq: (R, K, M) per-step increments → V_t per worker
    inc = np.asarray(hist.grad_norm_sq).reshape(R * K, M)
    v_t = np.sqrt(np.cumsum(inc, axis=0))       # (T, M)
    t_axis = np.arange(1, R * K + 1)
    g_bound = float(np.sqrt(np.max(inc)))       # ≈ per-step bound G
    out = {}
    for frac in (0.25, 0.5, 1.0):
        t = int(R * K * frac) - 1
        ratio_sqrt = float(v_t[t, 0] / (g_bound * np.sqrt(2 * t_axis[t])))
        out[frac] = ratio_sqrt
        emit(
            f"vt_growth[t={t_axis[t]}]", dt * 1e6 * frac,
            f"V_t={v_t[t,0]:.3f};G*sqrt(2t)={g_bound*np.sqrt(2*t_axis[t]):.3f};"
            f"ratio={ratio_sqrt:.3f}",
        )
    return out


def main() -> None:
    out = run()
    emit("vt_growth[check]", 0.0,
         f"V_T_below_trivial_bound={out[1.0] < 1.0}")


if __name__ == "__main__":
    main()
