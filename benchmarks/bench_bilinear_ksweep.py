"""Paper Fig. 3 (a)–(d): stochastic bilinear game, residual vs total
iterations T and vs communication rounds R, sweeping the local-step count
K ∈ {1, 5, 10, 50, 100} and noise σ ∈ {0.1, 0.5}. M = 4 workers, n = 10.

Expected qualitative reproduction (paper §4.1): (i) larger T = KR improves
the residual; (ii) per ROUND, larger K converges faster (more local work
per communication); (iii) larger σ gives noisier, slower trajectories.
"""
from __future__ import annotations

import time

import jax
import numpy as np

from repro.core import AdaSEGConfig, run_local_adaseg
from repro.problems import make_bilinear_game

from .common import emit

M = 4
N = 10
TOTAL_T = 2500
DIAMETER = float(np.sqrt(2 * N))  # sup ½‖z‖² over the box → D = √(2n)


def run(seed: int = 0) -> dict:
    results = {}
    for sigma in (0.1, 0.5):
        game = make_bilinear_game(jax.random.PRNGKey(seed), n=N, sigma=sigma)
        for k in (1, 5, 10, 50, 100):
            rounds = TOTAL_T // k
            cfg = AdaSEGConfig(g0=1.0, diameter=DIAMETER, alpha=1.0, k=k)
            t0 = time.perf_counter()
            zbar, (state, hist) = run_local_adaseg(
                game.problem, cfg, num_workers=M, rounds=rounds,
                rng=jax.random.PRNGKey(seed + 1),
            )
            us = (time.perf_counter() - t0) * 1e6
            res = float(game.residual(zbar))
            gap = float(game.duality_gap(zbar))
            results[(sigma, k)] = (res, gap)
            emit(
                f"bilinear_ksweep[sigma={sigma},K={k},R={rounds}]",
                us,
                f"residual={res:.4f};dualgap={gap:.4f};T={k * rounds}",
            )
    return results


def main() -> None:
    results = run()
    # qualitative check from the paper: at fixed T, K=50 should not be
    # far worse than K=1 (communication saved 50×), for the low-noise run
    r_k1 = results[(0.1, 1)][0]
    r_k50 = results[(0.1, 50)][0]
    emit("bilinear_ksweep[check]", 0.0,
         f"K50_vs_K1_ratio={r_k50 / max(r_k1, 1e-9):.2f}")


if __name__ == "__main__":
    main()
